//! Minimal, dependency-free shim of the `anyhow` API surface this workspace
//! uses: [`Error`], [`Result`], and the `anyhow!` / `bail!` / `ensure!`
//! macros. Vendored so the crate builds without registry access; if the real
//! `anyhow` ever becomes available, swapping the path dependency for the
//! crates.io version is a drop-in change.

use std::fmt;

/// A string-backed error value. Unlike the real `anyhow::Error` it carries
/// no backtrace or typed cause chain — the source error's `Display` output
/// is captured at conversion time, which is all the callers here rely on.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any displayable message (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `Error` deliberately does not implement `std::error::Error`; that keeps
// this blanket conversion coherent (the same trick the real anyhow uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built as in [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    fn fails(flag: bool) -> crate::Result<u32> {
        crate::ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_and_conversions() {
        let e = crate::anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");
        assert_eq!(fails(true).unwrap(), 7);
        assert_eq!(fails(false).unwrap_err().to_string(), "flag was false");
        let io: crate::Result<()> = Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            "disk on fire",
        )
        .into());
        assert!(io.unwrap_err().to_string().contains("disk on fire"));
    }

    #[test]
    fn ensure_without_message() {
        fn check(v: usize) -> crate::Result<()> {
            crate::ensure!(v > 1);
            Ok(())
        }
        assert!(check(2).is_ok());
        assert!(check(0).unwrap_err().to_string().contains("v > 1"));
    }
}
