//! Run-cache behavior of the campaign scheduler: completed runs are
//! skipped (execution counter at zero) while regenerating byte-identical
//! CSV outputs, and partial runs resume from their stored snapshot and
//! land exactly where a straight execution would.

use std::path::{Path, PathBuf};

use ota_dsgd::campaign::{scheduler, CampaignReport, RunStore, TrainerSnapshot};
use ota_dsgd::config::{presets, CampaignConfig, RunConfig, Scheme};
use ota_dsgd::coordinator::Trainer;
use ota_dsgd::experiments::{runner, ExperimentSpec};
use ota_dsgd::model::PARAM_DIM;

fn lean(scheme: Scheme) -> RunConfig {
    RunConfig {
        scheme,
        iterations: 4,
        eval_every: 2,
        channel_uses: PARAM_DIM / 8,
        sparsity: PARAM_DIM / 16,
        ..presets::smoke()
    }
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn read(path: &Path) -> Vec<u8> {
    std::fs::read(path).unwrap_or_else(|e| panic!("read {path:?}: {e}"))
}

#[test]
fn cache_skips_completed_runs_with_byte_identical_outputs() {
    let base = fresh_dir("ota_campaign_cache_test");
    let spec = || ExperimentSpec {
        id: "tcache".into(),
        title: "cache skip".into(),
        runs: vec![
            ("error-free".into(), lean(Scheme::ErrorFree)),
            ("signsgd".into(), lean(Scheme::SignSgd)),
        ],
    };
    let campaign = CampaignConfig {
        snapshot_every: 2,
        store_dir: base.join("store").to_str().unwrap().to_string(),
        ..CampaignConfig::default()
    };
    let out1 = base.join("out1");
    let out2 = base.join("out2");

    let (_, rep1) = scheduler::run_experiment_cached(&spec(), out1.to_str().unwrap(), false, &campaign);
    assert_eq!(
        rep1,
        CampaignReport { executed: 2, resumed: 0, cached: 0 },
        "first invocation executes everything"
    );
    let (_, rep2) = scheduler::run_experiment_cached(&spec(), out2.to_str().unwrap(), false, &campaign);
    assert_eq!(
        rep2,
        CampaignReport { executed: 0, resumed: 0, cached: 2 },
        "second invocation is served entirely from the cache"
    );

    // summary.csv byte-identical; cached per-run CSVs byte-identical too
    // (the stored log carries the original wall-clock values verbatim).
    assert_eq!(
        read(&out1.join("tcache/summary.csv")),
        read(&out2.join("tcache/summary.csv")),
        "summary.csv must be byte-identical from cache"
    );
    for label in ["error-free", "signsgd"] {
        assert_eq!(
            read(&out1.join(format!("tcache/{label}.csv"))),
            read(&out2.join(format!("tcache/{label}.csv"))),
            "{label}.csv must be byte-identical from cache"
        );
    }
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn partial_runs_resume_and_match_straight_execution() {
    let base = fresh_dir("ota_campaign_partial_test");
    // QSGD exercises the stochastic-rounding RNG through the whole
    // store → scheduler → trainer restore path.
    let cfg = RunConfig {
        iterations: 6,
        ..lean(Scheme::Qsgd)
    };
    let spec = || ExperimentSpec {
        id: "tpartial".into(),
        title: "partial resume".into(),
        runs: vec![("qsgd".into(), cfg.clone())],
    };

    // Straight no-cache reference.
    let out_ref = base.join("ref");
    let straight = runner::run_experiment(&spec(), out_ref.to_str().unwrap(), false);

    // Simulate an interrupted campaign: snapshot at round 3 lands in the
    // store, no result blob.
    let store_dir = base.join("store").to_str().unwrap().to_string();
    let store = RunStore::open(&store_dir).unwrap();
    let mut snaps: Vec<TrainerSnapshot> = Vec::new();
    Trainer::new(cfg.clone())
        .unwrap()
        .run_with_snapshots(None, 3, &mut |s| {
            if s.next_round == 3 {
                snaps.push(s.clone());
            }
        });
    store.save_snapshot(&cfg, "qsgd", &snaps[0]).unwrap();

    // The scheduler resumes rather than restarting…
    let campaign = CampaignConfig {
        snapshot_every: 3,
        store_dir,
        ..CampaignConfig::default()
    };
    let out = base.join("out");
    let (logs, rep) =
        scheduler::run_experiment_cached(&spec(), out.to_str().unwrap(), false, &campaign);
    assert_eq!(
        rep,
        CampaignReport { executed: 0, resumed: 1, cached: 0 },
        "a stored snapshot must be resumed, not recomputed"
    );
    // …and the resumed trajectory is the straight one, bit for bit.
    let bits = |log: &ota_dsgd::coordinator::TrainLog| {
        log.records.iter().map(|r| r.grad_norm.to_bits()).collect::<Vec<_>>()
    };
    assert_eq!(bits(&straight[0]), bits(&logs[0]));
    assert_eq!(
        read(&out_ref.join("tpartial/summary.csv")),
        read(&out.join("tpartial/summary.csv")),
        "summary.csv of a resumed campaign must match the straight run"
    );

    // The finished run is now cached: a third invocation executes nothing.
    let out3 = base.join("out3");
    let (_, rep3) =
        scheduler::run_experiment_cached(&spec(), out3.to_str().unwrap(), false, &campaign);
    assert_eq!(rep3, CampaignReport { executed: 0, resumed: 0, cached: 1 });
    std::fs::remove_dir_all(&base).ok();
}
