//! The link-diagnostics determinism contract, end to end:
//!
//! * **Probes are invisible to training.** The same campaign executed
//!   with diagnostics on and off writes byte-identical `summary.csv`
//!   files, and the replayed *training* series (grad norm, accuracy)
//!   are bit-identical — probes are read-only by construction (extra
//!   f64 norms over existing buffers, no RNG draws, no f32 op-order
//!   changes), and this test pins it.
//! * **Diagnostics are deterministic.** A 1-worker and a 4-worker
//!   fleet over the same campaign emit the same `device`-event
//!   payloads and the same round-level link aggregates once events
//!   are deterministically sorted and wall clocks masked — the
//!   deterministic core extends to diagnostics.
//! * **Payloads are sane.** Every probed scheme reports the fields
//!   its channel model defines, with physically coherent values.

use std::path::{Path, PathBuf};

use ota_dsgd::campaign::{scheduler, RunStore};
use ota_dsgd::config::{presets, CampaignConfig, FleetConfig, RunConfig, Scheme};
use ota_dsgd::experiments::runner::ExperimentSpec;
use ota_dsgd::fleet;
use ota_dsgd::fleet::events::EventKind;
use ota_dsgd::model::PARAM_DIM;

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn lean(scheme: Scheme) -> RunConfig {
    RunConfig {
        scheme,
        iterations: 4,
        eval_every: 2,
        channel_uses: PARAM_DIM / 8,
        sparsity: PARAM_DIM / 16,
        ..presets::smoke()
    }
}

fn spec() -> ExperimentSpec {
    ExperimentSpec {
        id: "tdiag".into(),
        title: "link diagnostics".into(),
        runs: vec![
            ("adsgd".into(), lean(Scheme::ADsgd)),
            ("blind".into(), lean(Scheme::BlindADsgd)),
            ("signsgd".into(), lean(Scheme::SignSgd)),
        ],
    }
}

fn campaign_for(store_dir: &str, diagnostics: bool) -> CampaignConfig {
    let mut c = CampaignConfig {
        snapshot_every: 2,
        store_dir: store_dir.to_string(),
        ..CampaignConfig::default()
    };
    c.telemetry.diagnostics = diagnostics;
    c
}

/// `summary.csv` byte-identity and training-series bit-identity with
/// probes on vs off: the headline read-only guarantee.
#[test]
fn diag_probes_do_not_perturb_summary_or_series() {
    let base = fresh_dir("ota_diag_readonly_test");
    let run = |name: &str, diagnostics: bool| {
        let store_dir = base.join(name).join("store").to_str().unwrap().to_string();
        let out = base.join(name).join("out").to_str().unwrap().to_string();
        let campaign = campaign_for(&store_dir, diagnostics);
        let (logs, _) = scheduler::run_experiment_cached(&spec(), &out, false, &campaign);
        let csv = std::fs::read(Path::new(&out).join("tdiag/summary.csv")).unwrap();
        let series: Vec<Vec<u64>> = logs
            .iter()
            .map(|l| l.records.iter().map(|r| r.grad_norm.to_bits()).collect())
            .collect();
        (csv, series, store_dir)
    };
    let (csv_on, series_on, store_on) = run("probes_on", true);
    let (csv_off, series_off, store_off) = run("probes_off", false);
    assert_eq!(csv_on, csv_off, "summary.csv must be byte-identical probes on/off");
    assert_eq!(series_on, series_off, "grad-norm trajectories must be bit-identical");

    // Probes on → device events in the log; probes off → none.
    let count_device = |store_dir: &str| {
        let store = RunStore::open(store_dir).unwrap();
        fleet::read_events(store.root())
            .events
            .iter()
            .filter(|e| e.kind == EventKind::Device)
            .count()
    };
    assert!(count_device(&store_on) > 0, "diagnostics on must emit device events");
    assert_eq!(count_device(&store_off), 0, "diagnostics off must emit none");
    std::fs::remove_dir_all(&base).ok();
}

/// Drain the spec with `n` in-process workers into `base/name`.
fn drain(base: &Path, name: &str, n: usize) -> String {
    let store_dir = base.join(name).to_str().unwrap().to_string();
    {
        let store = RunStore::open(&store_dir).unwrap();
        fleet::enqueue_specs(&store, &[spec()]).unwrap();
    }
    let campaign = campaign_for(&store_dir, true);
    let fleet_cfg = FleetConfig::default();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let store_dir = &store_dir;
                let campaign = &campaign;
                let fleet_cfg = &fleet_cfg;
                scope.spawn(move || {
                    fleet::run_worker(store_dir, fleet_cfg, campaign, &format!("w{i}"), false)
                        .unwrap()
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    store_dir
}

/// The worker-independent view of a store's diagnostics: every device
/// event's `(key, round, payload-bits)` plus the deterministic core
/// (which now carries snr/headroom/participation/consensus gauges and
/// the device-point count), after seq-sort + wall-clock masking and
/// with the fleet-shape-dependent writer id erased.
fn diag_core(store_dir: &str) -> (Vec<(String, Option<u64>, Vec<(String, u64)>)>, String) {
    let store = RunStore::open(store_dir).unwrap();
    let mut report = fleet::read_events(store.root());
    assert_eq!(report.unreadable_files, 0);
    assert_eq!(report.skipped_lines, 0);
    fleet::mask_wallclock(&mut report.events);
    fleet::sort_events(&mut report.events);
    let mut devices: Vec<(String, Option<u64>, Vec<(String, u64)>)> = report
        .events
        .iter()
        .filter(|e| e.kind == EventKind::Device)
        .map(|e| {
            let payload: Vec<(String, u64)> =
                e.data.iter().map(|(k, v)| (k.clone(), v.to_bits())).collect();
            (e.key.clone(), e.round, payload)
        })
        .collect();
    // A reclaimed run can re-emit a round's device events from a second
    // worker; dedup the payloads the way the reducer dedups points.
    devices.dedup();
    let core = fleet::reduce(&report.events).deterministic_core();
    (devices, core)
}

/// Fleet-shape invariance of the diagnostics themselves: same device
/// payloads, same extended deterministic core, 1 vs 4 workers.
#[test]
fn diag_device_events_identical_across_fleet_shapes() {
    let base = fresh_dir("ota_diag_fleet_shape_test");
    let store4 = drain(&base, "store4", 4);
    let store1 = drain(&base, "store1", 1);
    let (dev4, core4) = diag_core(&store4);
    let (dev1, core1) = diag_core(&store1);
    assert!(!dev4.is_empty(), "probed fleet must emit device events");
    assert_eq!(dev4, dev1, "device payloads must be fleet-shape independent");
    assert_eq!(core4, core1, "extended deterministic core must match");
    assert!(core4.contains("device_points="), "core carries the device-point count");
    assert!(core4.contains("snr_last="), "core carries the SNR gauge");
    std::fs::remove_dir_all(&base).ok();
}

/// Field-level sanity per scheme, through the full trainer + scheduler
/// path: analog reports SNR/AMP, blind fading reports per-device gains
/// and outcomes, digital reports bits within budget.
#[test]
fn diag_payloads_are_physically_coherent_per_scheme() {
    let base = fresh_dir("ota_diag_payload_test");
    let store_dir = base.join("store").to_str().unwrap().to_string();
    let out = base.join("out").to_str().unwrap().to_string();
    let campaign = campaign_for(&store_dir, true);
    scheduler::run_experiment_cached(&spec(), &out, false, &campaign);
    let store = RunStore::open(&store_dir).unwrap();
    let events = fleet::read_events(store.root()).events;

    // Map cache key -> scheme via the round events' co-resident runs:
    // instead, look at rounds: every Round event with link payloads.
    let rounds: Vec<_> = events.iter().filter(|e| e.kind == EventKind::Round).collect();
    assert!(
        rounds.iter().any(|e| e.field("snr_db").is_some()),
        "noisy links must aggregate SNR into round events"
    );
    assert!(
        rounds.iter().all(|e| e.field("participating").is_some()),
        "every probed round reports a participating count"
    );

    let devices: Vec<_> = events.iter().filter(|e| e.kind == EventKind::Device).collect();
    assert!(!devices.is_empty());
    let m = lean(Scheme::ADsgd).devices as f64;
    for d in &devices {
        let idx = d.field("device").expect("device index");
        assert!(idx >= 0.0 && idx < m, "device index in range");
        let outcome = d.field("outcome").expect("outcome code");
        assert!((0.0..=3.0).contains(&outcome), "known outcome code");
        let pre = d.field("pre_sparsify_norm").unwrap();
        let post = d.field("post_sparsify_norm").unwrap();
        assert!(pre >= 0.0 && post >= 0.0 && pre + 1e-9 >= post, "norms coherent");
        assert!(d.field("tx_energy").unwrap() >= 0.0);
    }
    // Digital payloads carry bits; at least the transmitting devices of
    // the signsgd run must show them.
    assert!(
        devices.iter().any(|d| d.field("payload_bits").is_some()),
        "digital scheme must report payload bits"
    );
    // Blind fading reports per-device gains.
    assert!(
        devices.iter().any(|d| d.field("fading_gain").is_some()),
        "fading scheme must report per-device gains"
    );
    std::fs::remove_dir_all(&base).ok();
}
