//! The network-native observability plane, end to end:
//!
//! * **Byte-identity over the wire** — for the same store, `GET
//!   /metrics` and a remote client reducing streamed `/events` produce
//!   Prometheus text byte-identical to the local `repro metrics` path,
//!   and a bit-identical `deterministic_core()` — including against a
//!   live store that grows a second campaign, garbage lines, and torn
//!   tails between scrapes (the server's incremental reducer and the
//!   local batch reducer must never drift).
//! * **Cursor semantics** — `/events?after=` returns only whole lines
//!   appended past the cursor, parks the cursor before a torn tail,
//!   resumes mid-segment once the tail terminates, and picks up writer
//!   segments that appear later.
//! * **HTTP robustness** — malformed request lines, oversized heads,
//!   unknown paths, non-GET methods, wrong versions, and request heads
//!   dribbled across many TCP segments.
//! * **Concurrency** — tailing clients racing a live writer only ever
//!   see lines that parse.
//! * **Fail-soft accounting** — the `unreadable: N` count of a torn
//!   queue item survives the JSON round-trip to a remote
//!   `fleet-status`.
//! * **Observe-only** — serving every endpoint leaves every byte of
//!   the store untouched.
//!
//! Every test here is named `remote_*` so CI's main Test step can skip
//! the whole suite with one `--skip remote_` (it runs as its own named
//! step).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::time::Duration;

use ota_dsgd::campaign::RunStore;
use ota_dsgd::config::{presets, CampaignConfig, FleetConfig, RunConfig, Scheme};
use ota_dsgd::experiments::runner::ExperimentSpec;
use ota_dsgd::fleet;
use ota_dsgd::model::PARAM_DIM;

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn lean(scheme: Scheme) -> RunConfig {
    RunConfig {
        scheme,
        iterations: 4,
        eval_every: 2,
        channel_uses: PARAM_DIM / 8,
        sparsity: PARAM_DIM / 16,
        ..presets::smoke()
    }
}

fn spec(id: &str, schemes: &[Scheme]) -> ExperimentSpec {
    ExperimentSpec {
        id: id.into(),
        title: format!("remote observability {id}"),
        runs: schemes
            .iter()
            .map(|&s| (format!("{id}-{}", s.name()), lean(s)))
            .collect(),
    }
}

/// Enqueue `sp` into the store at `store_dir` and drain it with one
/// in-process worker.
fn drain(store_dir: &str, sp: &ExperimentSpec) {
    {
        let store = RunStore::open(store_dir).unwrap();
        fleet::enqueue_specs(&store, std::slice::from_ref(sp)).unwrap();
    }
    let campaign = CampaignConfig {
        snapshot_every: 1,
        store_dir: store_dir.to_string(),
        ..CampaignConfig::default()
    };
    fleet::run_worker(store_dir, &FleetConfig::default(), &campaign, "w0", false).unwrap();
}

fn serve(store_dir: &str) -> (fleet::Server, String) {
    let server =
        fleet::Server::bind(store_dir, "127.0.0.1:0", fleet::ServeOptions::default()).unwrap();
    let addr = server.addr().to_string();
    (server, addr)
}

/// The local `repro metrics` path, verbatim.
fn local_prometheus(store: &RunStore) -> String {
    fleet::reduce_report(&fleet::read_events(store.root())).to_prometheus()
}

fn local_core(store: &RunStore) -> String {
    fleet::reduce_report(&fleet::read_events(store.root())).deterministic_core()
}

/// Assert the full over-the-wire determinism contract against one
/// server at one point in time.
fn assert_wire_identity(store: &RunStore, addr: &str, when: &str) {
    let local_prom = local_prometheus(store);
    let local_core = local_core(store);
    // The server's own rendering (incremental reducer behind /metrics).
    let resp = fleet::http_get(addr, "/metrics").unwrap();
    assert_eq!(resp.status, 200, "{when}: /metrics must serve");
    assert_eq!(
        String::from_utf8_lossy(&resp.body),
        local_prom,
        "{when}: GET /metrics must be byte-identical to local `repro metrics`"
    );
    // The remote client's rendering (streamed /events through the same
    // reducer).
    let remote = fleet::remote_metrics(addr).unwrap();
    assert_eq!(
        remote.to_prometheus(),
        local_prom,
        "{when}: remote client Prometheus text must be byte-identical"
    );
    assert_eq!(
        remote.deterministic_core(),
        local_core,
        "{when}: remote client deterministic core must be bit-identical"
    );
}

/// Byte-identity over the wire, pinned against a *live* store: after
/// the first campaign, after a second campaign lands in the same store
/// (the long-lived server's cursor must absorb the growth), and after
/// garbage + torn-tail injection (both sides must account skips
/// identically).
#[test]
fn remote_prometheus_and_core_stay_byte_identical_as_the_store_grows() {
    let base = fresh_dir("ota_remote_identity_test");
    let store_dir = base.join("store").to_str().unwrap().to_string();
    drain(&store_dir, &spec("ph1", &[Scheme::ErrorFree, Scheme::SignSgd]));
    let store = RunStore::open(&store_dir).unwrap();
    let (_server, addr) = serve(&store_dir);
    assert_wire_identity(&store, &addr, "after campaign 1");

    // A second campaign grows the same store mid-flight; the same
    // server instance must stay identical to a fresh batch read.
    drain(&store_dir, &spec("ph2", &[Scheme::Qsgd]));
    assert_wire_identity(&store, &addr, "after campaign 2");
    let m = fleet::remote_metrics(&addr).unwrap();
    assert_eq!(m.completed.len(), 3, "both campaigns visible remotely");
    assert_eq!(m.skipped_lines, 0);

    // Garbage + torn tail: consumed garbage accumulates, the pending
    // tail is a point-in-time count — and both must match the batch
    // reader's accounting byte-for-byte in the exposition.
    let segment = fleet::events_dir(store.root()).join("w0.jsonl");
    let mut fh = std::fs::OpenOptions::new().append(true).open(&segment).unwrap();
    fh.write_all(b"this is not json\n").unwrap();
    fh.write_all(b"{\"v\":1,\"kind\":\"round\",\"key\":\"torn-mid-wri").unwrap();
    drop(fh);
    assert_wire_identity(&store, &addr, "with garbage + torn tail");
    let m = fleet::remote_metrics(&addr).unwrap();
    assert_eq!(m.skipped_lines, 2, "garbage + torn tail both counted");

    // Terminating the torn line as more garbage moves it from pending
    // to consumed on both sides.
    let mut fh = std::fs::OpenOptions::new().append(true).open(&segment).unwrap();
    fh.write_all(b"GARBAGE-END\n").unwrap();
    drop(fh);
    assert_wire_identity(&store, &addr, "after the tail terminates");
    std::fs::remove_dir_all(&base).ok();
}

/// `/events?after=` cursor semantics: whole lines only, torn tails
/// never shipped and never consumed, mid-segment resume, late writers
/// picked up from zero, and a malformed cursor rejected with 400.
#[test]
fn remote_events_cursor_tails_incrementally_without_tearing() {
    let base = fresh_dir("ota_remote_cursor_test");
    let store_dir = base.join("store").to_str().unwrap().to_string();
    let store = RunStore::open(&store_dir).unwrap();
    let log = fleet::EventLog::open(store.root(), "w0").unwrap();
    for r in 0..3 {
        log.emit(fleet::EventKind::Round, "k1", Some(r), &[("grad_norm", 1.0)]);
    }
    let (_server, addr) = serve(&store_dir);

    let t1 = fleet::fetch_events(&addr, &fleet::Cursor::default()).unwrap();
    assert_eq!(t1.events.len(), 3, "zero cursor replays everything");
    assert_eq!(t1.consumed_skipped + t1.pending_tails + t1.unreadable_files, 0);
    assert!(t1.cursor.offset("w0") > 0, "cursor advanced past the lines");

    // Two more whole lines plus a torn half-line.
    log.emit(fleet::EventKind::Round, "k1", Some(3), &[("grad_norm", 0.5)]);
    log.emit(fleet::EventKind::Completed, "k1", None, &[("final_accuracy", 0.9)]);
    let segment = fleet::events_dir(store.root()).join("w0.jsonl");
    let mut fh = std::fs::OpenOptions::new().append(true).open(&segment).unwrap();
    fh.write_all(b"{\"v\":1,\"kind\":\"round\",\"key\":\"tail").unwrap();
    drop(fh);

    let t2 = fleet::fetch_events(&addr, &t1.cursor).unwrap();
    assert_eq!(t2.events.len(), 2, "only the whole new lines arrive");
    assert_eq!(t2.events[0].round, Some(3));
    assert_eq!(t2.pending_tails, 1, "the torn tail is visible in accounting");
    assert_eq!(t2.consumed_skipped, 0, "…but never consumed");

    // A re-read from the same cursor is identical: the cursor was
    // parked at the line boundary, not past the tail.
    let t2b = fleet::fetch_events(&addr, &t1.cursor).unwrap();
    assert_eq!(t2b.events.len(), 2);
    assert_eq!(t2b.cursor.render(), t2.cursor.render());

    // Terminate the tail into a valid event; a new writer appears.
    let mut fh = std::fs::OpenOptions::new().append(true).open(&segment).unwrap();
    fh.write_all(b"\",\"ms\":7}\n").unwrap();
    drop(fh);
    let w1 = fleet::EventLog::open(store.root(), "w1").unwrap();
    w1.emit(fleet::EventKind::Heartbeat, "k1", None, &[]);

    let t3 = fleet::fetch_events(&addr, &t2.cursor).unwrap();
    assert_eq!(t3.events.len(), 2, "completed tail + the new writer's event");
    assert_eq!(t3.events[0].key, "tail", "the once-torn line resumed mid-segment");
    assert_eq!(t3.events[1].worker, "w1", "late segments start from zero");
    assert_eq!(t3.pending_tails, 0);
    assert!(t3.cursor.offset("w1") > 0);

    // Chained tails reassemble exactly the batch read.
    let all = fleet::read_events(store.root());
    assert_eq!(
        t1.events.len() + t2.events.len() + t3.events.len(),
        all.events.len(),
        "cursor chain covers the log exactly once"
    );

    let bad = fleet::http_get(&addr, "/events?after=::").unwrap();
    assert_eq!(bad.status, 400, "malformed cursors are rejected");
    std::fs::remove_dir_all(&base).ok();
}

/// Send raw bytes and read the whole response back.
fn raw_request(addr: &str, payload: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let _ = s.write_all(payload);
    let _ = s.flush();
    let mut out = Vec::new();
    let _ = s.read_to_end(&mut out);
    String::from_utf8_lossy(&out).into_owned()
}

/// The hand-rolled HTTP layer: malformed request lines, oversized
/// heads, unknown paths, non-GET methods, unsupported versions — and a
/// request head dribbled byte-by-byte across many TCP segments.
#[test]
fn remote_http_rejects_malformed_oversized_and_unknown_requests() {
    let base = fresh_dir("ota_remote_http_test");
    let store_dir = base.join("store").to_str().unwrap().to_string();
    let store = RunStore::open(&store_dir).unwrap();
    fleet::EventLog::open(store.root(), "w0")
        .unwrap()
        .emit(fleet::EventKind::Executed, "k1", None, &[]);
    let (_server, addr) = serve(&store_dir);

    assert!(
        raw_request(&addr, b"garbage\r\n\r\n").starts_with("HTTP/1.1 400"),
        "a one-token request line is malformed"
    );
    assert!(
        raw_request(&addr, b"GET /metrics HTTP/1.1 extra\r\n\r\n").starts_with("HTTP/1.1 400"),
        "a four-token request line is malformed"
    );
    assert!(
        raw_request(&addr, b"GET metrics HTTP/1.1\r\n\r\n").starts_with("HTTP/1.1 400"),
        "a target not starting with / is malformed"
    );
    assert!(
        raw_request(&addr, b"POST /metrics HTTP/1.1\r\n\r\n").starts_with("HTTP/1.1 405"),
        "only GET is spoken"
    );
    assert!(
        raw_request(&addr, b"GET /metrics SPDY/3\r\n\r\n").starts_with("HTTP/1.1 505"),
        "unsupported protocol versions are refused"
    );
    assert!(
        raw_request(&addr, b"GET /nope HTTP/1.1\r\n\r\n").starts_with("HTTP/1.1 404"),
        "unknown paths are 404"
    );
    let mut huge = b"GET /metrics HTTP/1.1\r\n".to_vec();
    while huge.len() <= 10 * 1024 {
        huge.extend_from_slice(b"x-padding: aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa\r\n");
    }
    huge.extend_from_slice(b"\r\n");
    assert!(
        raw_request(&addr, &huge).starts_with("HTTP/1.1 431"),
        "an oversized request head is refused, not buffered"
    );

    // A valid request split across many tiny TCP segments must still
    // parse and serve the byte-identical body.
    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    for chunk in b"GET /metrics HTTP/1.1\r\nhost: x\r\n\r\n".chunks(3) {
        s.write_all(chunk).unwrap();
        s.flush().unwrap();
        std::thread::sleep(Duration::from_millis(5));
    }
    let mut out = Vec::new();
    let _ = s.read_to_end(&mut out);
    let text = String::from_utf8_lossy(&out);
    assert!(text.starts_with("HTTP/1.1 200"), "dribbled head still parses: {text}");
    let body = text.split("\r\n\r\n").nth(1).unwrap_or("");
    assert_eq!(body, local_prometheus(&store), "dribbled request serves the same bytes");
    std::fs::remove_dir_all(&base).ok();
}

/// Two tailing clients race a live writer: every line either client
/// ever receives parses (no torn lines over the wire), nothing is
/// skipped, and both reassemble the complete log.
#[test]
fn remote_concurrent_scrapes_see_only_whole_lines() {
    let base = fresh_dir("ota_remote_concurrent_test");
    let store_dir = base.join("store").to_str().unwrap().to_string();
    let store = RunStore::open(&store_dir).unwrap();
    let (_server, addr) = serve(&store_dir);
    const N: u64 = 50;

    let tail_all = |addr: String| {
        move || {
            let mut cursor = fleet::Cursor::default();
            let mut got = 0u64;
            let mut skipped = 0usize;
            let deadline = std::time::Instant::now() + Duration::from_secs(60);
            while got < N {
                assert!(std::time::Instant::now() < deadline, "tailing client stalled");
                let tail = fleet::fetch_events(&addr, &cursor).unwrap();
                skipped += tail.consumed_skipped;
                got += tail.events.len() as u64;
                cursor = tail.cursor;
                std::thread::sleep(Duration::from_millis(3));
            }
            (got, skipped)
        }
    };
    std::thread::scope(|scope| {
        let a = scope.spawn(tail_all(addr.clone()));
        let b = scope.spawn(tail_all(addr.clone()));
        let log = fleet::EventLog::open(store.root(), "w0").unwrap();
        for r in 0..N {
            log.emit(fleet::EventKind::Round, "k1", Some(r), &[("grad_norm", 1.0)]);
            // Interleave scrapes of the stateful endpoints to exercise
            // the server-side mutex under write load.
            if r % 16 == 0 {
                assert_eq!(fleet::http_get(&addr, "/metrics").unwrap().status, 200);
                assert_eq!(fleet::http_get(&addr, "/health").unwrap().status, 200);
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        for h in [a, b] {
            let (got, skipped) = h.join().unwrap();
            assert_eq!(got, N, "every event arrived exactly once");
            assert_eq!(skipped, 0, "no line a client saw failed to parse");
        }
    });
    let m = fleet::remote_metrics(&addr).unwrap();
    assert_eq!(m.events_total, N, "the server view converges to the full log");
    std::fs::remove_dir_all(&base).ok();
}

/// The fail-soft `unreadable` accounting crosses the wire intact, and
/// the `/status` JSON round-trips through the client parser.
#[test]
fn remote_status_roundtrip_keeps_unreadable_accounting() {
    let base = fresh_dir("ota_remote_status_test");
    let store_dir = base.join("store").to_str().unwrap().to_string();
    let store = RunStore::open(&store_dir).unwrap();
    fleet::enqueue_specs(&store, &[spec("st", &[Scheme::ErrorFree, Scheme::SignSgd])]).unwrap();
    // Truncate one queue item mid-byte — the torn shape a live replace
    // leaves behind.
    let qdir = fleet::queue_dir(store.root());
    let victim = std::fs::read_dir(&qdir)
        .unwrap()
        .filter_map(|e| e.ok().map(|e| e.path()))
        .find(|p| p.extension().and_then(|e| e.to_str()) == Some("toml"))
        .unwrap();
    // An unterminated string with the `seq` key missing entirely —
    // unparseable no matter how lenient the TOML subset is.
    std::fs::write(&victim, "[item]\nkey = \"tor").unwrap();

    let (_server, addr) = serve(&store_dir);
    let (remote_dir, st) = fleet::fetch_status(&addr).unwrap();
    assert_eq!(remote_dir, store_dir, "the server names its own store");
    assert_eq!(st.unreadable, 1, "the torn item is counted, not dropped");
    assert_eq!(st.items.len(), 1, "the readable item survives");
    let rendered = fleet::render_status(&remote_dir, &st);
    assert!(rendered.contains("unreadable: 1"), "{rendered}");

    // Full field-level round-trip through render + parse.
    let json = fleet::status_to_json(&store_dir, &st);
    let (dir2, st2) = fleet::parse_status(&json).unwrap();
    assert_eq!(dir2, store_dir);
    assert_eq!(st2.unreadable, st.unreadable);
    assert_eq!(st2.items.len(), st.items.len());
    assert_eq!(st2.items[0].key, st.items[0].key);
    assert_eq!(st2.items[0].state, st.items[0].state);
    assert_eq!(st2.items[0].rounds_total, st.items[0].rounds_total);
    assert_eq!((st2.complete, st2.running, st2.stale), (st.complete, st.running, st.stale));
    std::fs::remove_dir_all(&base).ok();
}

/// The satellite-1 pin for the local `repro watch` path: a frame-by-
/// frame incremental reduction (cursor + reducer kept alive across
/// frames) stays byte-identical to a from-scratch batch reduce of the
/// full log at every frame — through appends, a torn tail, and its
/// completion.
#[test]
fn remote_watch_incremental_frames_equal_batch_reduce() {
    let base = fresh_dir("ota_remote_frames_test");
    let store_dir = base.join("store").to_str().unwrap().to_string();
    let store = RunStore::open(&store_dir).unwrap();
    let log = fleet::EventLog::open(store.root(), "w0").unwrap();
    let segment = fleet::events_dir(store.root()).join("w0.jsonl");

    let mut cursor = fleet::Cursor::default();
    let mut reducer = fleet::Reducer::default();
    let mut check = |frame: &str| {
        let tail = fleet::read_events_from(store.root(), &cursor);
        cursor = tail.cursor.clone();
        reducer.absorb_tail(&tail);
        let inc = reducer.metrics();
        let batch = fleet::reduce_report(&fleet::read_events(store.root()));
        assert_eq!(
            inc.to_prometheus(),
            batch.to_prometheus(),
            "frame `{frame}`: incremental Prometheus text must equal batch"
        );
        assert_eq!(
            inc.deterministic_core(),
            batch.deterministic_core(),
            "frame `{frame}`: incremental core must equal batch"
        );
    };

    check("empty store");
    log.emit(fleet::EventKind::Executed, "k1", None, &[]);
    log.emit(fleet::EventKind::Round, "k1", Some(0), &[("grad_norm", 2.0)]);
    check("first events");
    log.emit(fleet::EventKind::Round, "k1", Some(1), &[("grad_norm", 1.0)]);
    let mut fh = std::fs::OpenOptions::new().append(true).open(&segment).unwrap();
    fh.write_all(b"{\"v\":1,\"kind\":\"round\",\"key\":\"to").unwrap();
    drop(fh);
    check("torn tail pending");
    check("torn tail still pending"); // idempotent while the writer stalls
    let mut fh = std::fs::OpenOptions::new().append(true).open(&segment).unwrap();
    fh.write_all(b"rn\",\"ms\":9}\n").unwrap();
    drop(fh);
    log.emit(fleet::EventKind::Completed, "k1", None, &[("final_accuracy", 0.9)]);
    check("tail completed + more events");
    std::fs::remove_dir_all(&base).ok();
}

/// Serving is observe-only: hitting every endpoint leaves every byte
/// of the store untouched (content-addresses, results, goldens, queue,
/// and the event log itself).
#[test]
fn remote_serving_leaves_every_store_byte_untouched() {
    let base = fresh_dir("ota_remote_readonly_test");
    let store_dir = base.join("store").to_str().unwrap().to_string();
    drain(&store_dir, &spec("ro", &[Scheme::ErrorFree]));

    fn snapshot(dir: &Path, out: &mut BTreeMap<PathBuf, Vec<u8>>) {
        for entry in std::fs::read_dir(dir).unwrap().filter_map(|e| e.ok()) {
            let path = entry.path();
            if path.is_dir() {
                snapshot(&path, out);
            } else {
                out.insert(path.clone(), std::fs::read(&path).unwrap());
            }
        }
    }
    let mut before = BTreeMap::new();
    snapshot(&base, &mut before);
    assert!(!before.is_empty(), "the drained store has content to protect");

    let (_server, addr) = serve(&store_dir);
    for path in ["/metrics", "/status", "/events", "/events?after=", "/health", "/nope"] {
        fleet::http_get(&addr, path).unwrap();
    }
    let mut after = BTreeMap::new();
    snapshot(&base, &mut after);
    assert_eq!(
        before.keys().collect::<Vec<_>>(),
        after.keys().collect::<Vec<_>>(),
        "no file created or removed"
    );
    for (path, bytes) in &before {
        assert_eq!(&after[path], bytes, "{} must be byte-identical", path.display());
    }
    std::fs::remove_dir_all(&base).ok();
}
