//! Cross-module integration tests: pipelines composed of several modules,
//! plus failure injection at module boundaries.

use ota_dsgd::amp::AmpConfig;
use ota_dsgd::analog::{AnalogDevice, AnalogPs, Projection};
use ota_dsgd::channel::{GaussianMac, PowerAllocator};
use ota_dsgd::config::{presets, DatasetSpec, PowerSchedule, RunConfig, Scheme};
use ota_dsgd::coordinator::Trainer;
use ota_dsgd::data::{load_corpus, partition, synthetic};
use ota_dsgd::digital::{aggregate, capacity_bits, DigitalDevice};
use ota_dsgd::model::{self, PARAM_DIM};
use ota_dsgd::tensor;
use ota_dsgd::util::rng::Pcg64;

/// Devices computing real model gradients → digital pipe → PS aggregate:
/// the averaged reconstruction should point "the same way" as the true
/// average gradient (positive cosine similarity, substantial at good SNR).
#[test]
fn digital_pipeline_preserves_gradient_direction() {
    let corpus = synthetic::generate(600, 3, 0);
    let mut rng = Pcg64::new(1);
    let shards = partition::iid(&corpus, 6, 100, &mut rng);
    let mut params = vec![0f32; PARAM_DIM];
    let mut prng = Pcg64::new(2);
    for p in params.iter_mut() {
        *p = prng.normal_ms(0.0, 0.02) as f32;
    }
    let grads = model::per_device_gradients(&params, &corpus, &shards, 1);

    let mut true_avg = vec![0f32; PARAM_DIM];
    for m in 0..6 {
        tensor::axpy(1.0 / 6.0, grads.row(m), &mut true_avg);
    }

    let budget = capacity_bits(PARAM_DIM / 2, 6, 500.0, 1.0);
    let mut devices: Vec<DigitalDevice> = (0..6)
        .map(|i| DigitalDevice::new(Scheme::DDsgd, PARAM_DIM, 2, i as u64))
        .collect();
    let payloads: Vec<_> = devices
        .iter_mut()
        .enumerate()
        .map(|(m, dev)| dev.transmit(grads.row(m), budget))
        .collect();
    let ghat = aggregate(&payloads, PARAM_DIM);

    // SBC keeps ~q entries at the winning-sign mean, so against the *dense*
    // average the achievable cosine is bounded by the kept energy fraction;
    // we require the direction to be clearly preserved, not identical.
    let cos = tensor::dot(&ghat, &true_avg) as f64
        / (tensor::norm(&ghat) * tensor::norm(&true_avg)).max(1e-12);
    assert!(cos > 0.15, "cosine similarity {cos}");
}

/// Same check for the analog pipeline through the actual MAC + AMP.
#[test]
fn analog_pipeline_preserves_gradient_direction() {
    // M = 25 as in the paper: over-the-air superposition needs enough
    // devices for the coherent sum to dominate the channel noise at
    // P̄/s per-symbol power (Remark 4).
    let corpus = synthetic::generate(2500, 5, 0);
    let mut rng = Pcg64::new(4);
    let m_devices = 25;
    let shards = partition::iid(&corpus, m_devices, 100, &mut rng);
    let mut params = vec![0f32; PARAM_DIM];
    let mut prng = Pcg64::new(5);
    for p in params.iter_mut() {
        *p = prng.normal_ms(0.0, 0.02) as f32;
    }
    let grads = model::per_device_gradients(&params, &corpus, &shards, 1);

    let s = PARAM_DIM / 4;
    // Assumption 3 (paper): the support of Σ_m g_m^sp must stay below
    // s−1, guaranteed by k ≪ s; staying under the Donoho–Tanner phase
    // transition (δ = s/d = 0.25 → recoverable support ≈ 0.35·s̃) keeps
    // AMP in its provable regime even with imperfect support overlap.
    let k = s / 32;
    // A-DSGD's decode target is the average of the *sparsified* gradients
    // (Alg. 1 — the dense remainder lives in the error accumulators).
    let mut sparse_avg = vec![0f32; PARAM_DIM];
    for m in 0..m_devices {
        let sp = tensor::sparsify_topk(grads.row(m), k);
        tensor::axpy(1.0 / m_devices as f32, &sp, &mut sparse_avg);
    }
    let proj = Projection::generate(s - 1, PARAM_DIM, 42);
    let mut mac = GaussianMac::new(s, m_devices, 1.0, 9);
    let mut devices: Vec<AnalogDevice> = (0..m_devices)
        .map(|_| AnalogDevice::new(PARAM_DIM, k))
        .collect();
    let frames: Vec<Vec<f32>> = devices
        .iter_mut()
        .enumerate()
        .map(|(m, dev)| dev.transmit(grads.row(m), &proj, 500.0).x)
        .collect();
    let y = mac.transmit(&frames);
    let ps = AnalogPs::new(proj, AmpConfig::default());
    let (ghat, trace) = ps.decode(&y);
    assert!(trace.iterations > 0);

    let cos = tensor::dot(&ghat, &sparse_avg) as f64
        / (tensor::norm(&ghat) * tensor::norm(&sparse_avg)).max(1e-12);
    assert!(cos > 0.5, "cosine similarity vs sparsified average: {cos}");
}

/// Power allocator + trainer integration: a non-constant schedule still
/// meets the measured Eq. 6 audit inside a full run.
#[test]
fn trainer_meets_power_constraint_under_hl_schedule() {
    let cfg = RunConfig {
        scheme: Scheme::ADsgd,
        power: PowerSchedule::Hl,
        iterations: 9,
        eval_every: 3,
        ..presets::smoke()
    };
    let log = Trainer::new(cfg).unwrap().run();
    assert!(
        log.power_constraint_ok(1e-6),
        "avg powers {:?} vs P̄ {}",
        log.measured_avg_power,
        log.pbar
    );
    // HL: first-third rounds get more power than last-third.
    let p_first = log.records[0].p_t;
    let p_last = log.records[8].p_t;
    assert!(p_first > p_last);
}

/// Failure injection: a corrupted artifact manifest fails loudly with a
/// actionable message, not a panic.
#[test]
fn corrupt_manifest_fails_cleanly() {
    let dir = std::env::temp_dir().join("ota_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), "name=x kind=grad file=missing.hlo devices=abc\n")
        .unwrap();
    let err = ota_dsgd::runtime::Manifest::load(&dir).unwrap_err();
    assert!(err.to_string().contains("non-numeric"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Failure injection: non-IID partitioning on a corpus with a missing class
/// still produces full shards (wrap-around path).
#[test]
fn noniid_survives_skewed_corpus() {
    let mut ds = synthetic::generate(300, 8, 0);
    // Erase class 0 entirely by relabeling to 1.
    for l in ds.labels.iter_mut() {
        if *l == 0 {
            *l = 1;
        }
    }
    let mut rng = Pcg64::new(3);
    let shards = partition::non_iid(&ds, 8, 40, &mut rng);
    for s in &shards {
        assert_eq!(s.len(), 40);
    }
}

/// Config → corpus plumbing: MNIST spec falls back with an error when the
/// directory is absent, synthetic always works.
#[test]
fn corpus_loading_paths() {
    assert!(load_corpus(
        &DatasetSpec::MnistIdx {
            dir: "/no/such/dir".into()
        },
        1
    )
    .is_err());
    let corpus = load_corpus(
        &DatasetSpec::Synthetic {
            train: 100,
            test: 50,
        },
        1,
    )
    .unwrap();
    assert_eq!(corpus.train.len(), 100);
    assert_eq!(corpus.test.len(), 50);
}

/// The PowerAllocator paper schedules integrate with capacity: more power
/// in late iterations buys more bits late (Fig. 3's mechanism).
#[test]
fn lh_schedule_shifts_bits_to_late_iterations() {
    let alloc = PowerAllocator::new(PowerSchedule::Lh, 200.0, 300);
    let s = PARAM_DIM / 2;
    let bits_early = capacity_bits(s, 25, alloc.p(10), 1.0);
    let bits_late = capacity_bits(s, 25, alloc.p(290), 1.0);
    assert!(bits_late > bits_early * 1.2, "{bits_early} vs {bits_late}");
}

/// Determinism across the whole stack: same seed → identical accuracy
/// series; different seed → different series.
#[test]
fn full_run_determinism() {
    let mut cfg = presets::smoke();
    cfg.iterations = 5;
    let a = Trainer::new(cfg.clone()).unwrap().run();
    let b = Trainer::new(cfg.clone()).unwrap().run();
    let series = |l: &ota_dsgd::coordinator::TrainLog| {
        l.records.iter().map(|r| r.grad_norm).collect::<Vec<_>>()
    };
    assert_eq!(series(&a), series(&b));
    cfg.seed += 1;
    let c = Trainer::new(cfg).unwrap().run();
    assert_ne!(series(&a), series(&c));
}
