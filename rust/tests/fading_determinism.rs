//! Seeded determinism of the fading scenario subsystem: same seed ⇒
//! identical gain sequences, device subsets, and training trajectories —
//! across runs *and* across thread-pool sizes. The generators are
//! counter-based (a fresh RNG per `(seed, device, round)` cell), so the
//! encode fan-out schedule cannot perturb them; these tests pin that.

use ota_dsgd::channel::{FadingProcess, LatencyModel};
use ota_dsgd::config::{presets, FadingDist, ParticipationPolicy, RunConfig, Scheme};
use ota_dsgd::coordinator::link::{FadingAnalogLink, LinkScheme, RoundCtx};
use ota_dsgd::coordinator::{ParticipationSelector, Trainer};
use ota_dsgd::tensor::Matf;
use ota_dsgd::util::rng::Pcg64;

#[test]
fn gain_sequences_identical_across_runs_and_query_orders() {
    for dist in [
        FadingDist::Rayleigh,
        FadingDist::Uniform(0.2, 1.8),
        FadingDist::Constant(0.9),
    ] {
        let a = FadingProcess::new(dist, 77);
        let b = FadingProcess::new(dist, 77);
        let (m, rounds) = (12usize, 8usize);
        // Run A queries row-major, run B column-major (a proxy for any
        // thread interleaving): every cell must agree.
        let mut grid_a = vec![vec![0f64; m]; rounds];
        for (t, row) in grid_a.iter_mut().enumerate() {
            for (dev, cell) in row.iter_mut().enumerate() {
                *cell = a.gain(dev, t);
            }
        }
        for dev in 0..m {
            for (t, row) in grid_a.iter().enumerate() {
                assert_eq!(row[dev], b.gain(dev, t), "{dist:?} dev={dev} t={t}");
            }
        }
    }
}

/// The Gauss–Markov (AR(1)) variant keeps the counter-based purity: any
/// query order over the (device, round) grid sees identical gains.
#[test]
fn ar1_gain_sequences_identical_across_runs_and_query_orders() {
    for dist in [FadingDist::Rayleigh, FadingDist::Uniform(0.2, 1.8)] {
        for rho in [0.3, 0.9] {
            let a = FadingProcess::with_rho(dist, 91, rho);
            let b = FadingProcess::with_rho(dist, 91, rho);
            let (m, rounds) = (8usize, 6usize);
            let mut grid_a = vec![vec![0f64; m]; rounds];
            for (t, row) in grid_a.iter_mut().enumerate() {
                for (dev, cell) in row.iter_mut().enumerate() {
                    *cell = a.gain(dev, t);
                }
            }
            // Query B column-major (a proxy for any thread interleaving).
            for dev in 0..m {
                for (t, row) in grid_a.iter().enumerate() {
                    assert_eq!(
                        row[dev],
                        b.gain(dev, t),
                        "{dist:?} rho={rho} dev={dev} t={t}"
                    );
                }
            }
        }
    }
}

#[test]
fn participation_subsets_identical_across_runs() {
    let gains: Vec<f64> = (0..10).map(|i| 0.1 * (i + 1) as f64).collect();
    for policy in [
        ParticipationPolicy::Full,
        ParticipationPolicy::UniformK(4),
        ParticipationPolicy::GainThreshold(0.55),
    ] {
        let a = ParticipationSelector::new(policy, 123);
        let b = ParticipationSelector::new(policy, 123);
        for t in 0..16 {
            assert_eq!(a.select(t, &gains), b.select(t, &gains), "{policy:?} t={t}");
        }
    }
}

#[test]
fn latency_sequences_identical_across_runs() {
    let a = LatencyModel::new(0.01, 5);
    let b = LatencyModel::new(0.01, 5);
    for dev in 0..8 {
        for t in 0..8 {
            assert_eq!(a.latency(dev, t), b.latency(dev, t));
        }
    }
}

fn link_cfg() -> RunConfig {
    RunConfig {
        scheme: Scheme::FadingADsgd,
        devices: 9,
        channel_uses: 101,
        sparsity: 25,
        mean_removal_rounds: 1,
        amp_iters: 20,
        fading: FadingDist::Rayleigh,
        csi_threshold: 0.2,
        participation: ParticipationPolicy::UniformK(6),
        latency_mean_secs: 0.004,
        deadline_secs: 0.02,
        ..presets::smoke()
    }
}

/// The full fading round — gains, selection, straggler drops, scaling,
/// channel, AMP — is bit-identical whether the device encode fan-out runs
/// sequentially or on a multi-worker pool.
#[test]
fn fading_round_invariant_to_thread_pool_size() {
    let d = 420;
    let cfg = link_cfg();
    let grads = {
        let mut rng = Pcg64::new(31);
        Matf::from_vec(
            cfg.devices,
            d,
            (0..cfg.devices * d)
                .map(|_| rng.normal_ms(0.0, 0.2) as f32)
                .collect(),
        )
    };
    for csi in [true, false] {
        let run = |workers: usize| {
            let mut link = FadingAnalogLink::with_workers(&cfg, d, csi, workers);
            let mut out = Vec::new();
            for t in 0..4 {
                let round = link.round(
                    &RoundCtx {
                        t,
                        p_t: cfg.pbar,
                        deadline: cfg.deadline(),
                    },
                    &grads,
                );
                out.push((round.ghat, round.telemetry.participation));
            }
            (out, link.measured_avg_power())
        };
        let seq = run(1);
        for workers in [2usize, 4, 8] {
            assert_eq!(seq, run(workers), "csi={csi} workers={workers}");
        }
    }
}

/// The full fading round under time-correlated (AR(1)) gains is
/// bit-identical across thread-pool sizes: the Gauss–Markov chain is
/// recomputed per (device, round) cell, so the encode fan-out schedule
/// cannot perturb it.
#[test]
fn ar1_fading_round_invariant_to_thread_pool_size() {
    let d = 420;
    let cfg = RunConfig {
        fading_rho: 0.7,
        ..link_cfg()
    };
    let grads = {
        let mut rng = Pcg64::new(37);
        Matf::from_vec(
            cfg.devices,
            d,
            (0..cfg.devices * d)
                .map(|_| rng.normal_ms(0.0, 0.2) as f32)
                .collect(),
        )
    };
    for csi in [true, false] {
        let run = |workers: usize| {
            let mut link = FadingAnalogLink::with_workers(&cfg, d, csi, workers);
            let mut out = Vec::new();
            for t in 0..4 {
                let round = link.round(
                    &RoundCtx {
                        t,
                        p_t: cfg.pbar,
                        deadline: cfg.deadline(),
                    },
                    &grads,
                );
                out.push((round.ghat, round.telemetry.participation));
            }
            (out, link.measured_avg_power())
        };
        let seq = run(1);
        for workers in [2usize, 4, 8] {
            assert_eq!(seq, run(workers), "rho=0.7 csi={csi} workers={workers}");
        }
    }
}

/// End-to-end: two trainers with the same seed produce identical grad-norm
/// trajectories and participation series for both fading variants.
#[test]
fn fading_training_deterministic_given_seed() {
    for scheme in [Scheme::FadingADsgd, Scheme::BlindADsgd] {
        let cfg = RunConfig {
            scheme,
            iterations: 5,
            eval_every: 2,
            latency_mean_secs: 0.004,
            deadline_secs: 0.02,
            ..presets::smoke()
        };
        let run = || {
            let log = Trainer::new(cfg.clone()).expect("trainer").run();
            log.records
                .iter()
                .map(|r| (r.grad_norm, r.participation))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run(), "{scheme:?}");
    }
}
