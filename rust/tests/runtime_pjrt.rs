//! Cross-layer integration: the AOT-compiled JAX/Pallas graphs executed
//! through PJRT must agree numerically with the pure-rust reference
//! implementations on identical inputs.
//!
//! These tests need `artifacts/` (run `make artifacts`); when absent they
//! skip with a notice rather than fail, so `cargo test` works on a fresh
//! checkout. CI (`make test`) always builds artifacts first.

use ota_dsgd::analog::Projection;
use ota_dsgd::coordinator::{GradientBackend, RustBackend};
use ota_dsgd::data::{partition, synthetic};
use ota_dsgd::model::PARAM_DIM;
use ota_dsgd::runtime::pjrt::InputF32;
use ota_dsgd::runtime::{Manifest, PjrtBackend, PjrtRuntime};
use ota_dsgd::util::rng::Pcg64;

fn manifest_or_skip() -> Option<(PjrtRuntime, Manifest)> {
    let manifest = match Manifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            return None;
        }
    };
    let runtime = PjrtRuntime::cpu().expect("PJRT CPU client");
    Some((runtime, manifest))
}

#[test]
fn pjrt_gradients_match_rust_backend() {
    let Some((runtime, manifest)) = manifest_or_skip() else {
        return;
    };
    let (m, b) = (5usize, 120usize);
    let mut pjrt = match PjrtBackend::from_manifest(&runtime, &manifest, m, b) {
        Ok(be) => be,
        Err(e) => {
            eprintln!("SKIP (no grad artifact for {m}x{b}): {e}");
            return;
        }
    };
    let mut rust = RustBackend::new();

    let corpus = synthetic::generate(1000, 42, 0);
    let mut rng = Pcg64::new(7);
    let shards = partition::iid(&corpus, m, b, &mut rng);
    let mut params = vec![0f32; PARAM_DIM];
    let mut prng = Pcg64::new(3);
    for p in params.iter_mut() {
        *p = prng.normal_ms(0.0, 0.05) as f32;
    }

    let g_pjrt = pjrt.per_device_gradients(&params, &corpus, &shards);
    let g_rust = rust.per_device_gradients(&params, &corpus, &shards);
    assert_eq!(g_pjrt.rows, m);
    assert_eq!(g_pjrt.cols, PARAM_DIM);

    let mut max_abs = 0f64;
    let mut max_err = 0f64;
    for (a, b) in g_pjrt.data.iter().zip(&g_rust.data) {
        max_abs = max_abs.max((*b as f64).abs());
        max_err = max_err.max(((a - b) as f64).abs());
    }
    assert!(
        max_err < 1e-4 + 1e-3 * max_abs,
        "PJRT vs rust gradient mismatch: max_err={max_err}, max_abs={max_abs}"
    );
}

#[test]
fn pjrt_projection_matches_rust_apply() {
    let Some((runtime, manifest)) = manifest_or_skip() else {
        return;
    };
    let Some(art) = manifest.find_kind("projection") else {
        eprintln!("SKIP: no projection artifact");
        return;
    };
    let s_tilde = art.meta_usize("s_tilde").unwrap();
    let d = art.meta_usize("dim").unwrap();
    let exe = runtime.load_hlo(&art.file).expect("compile projection HLO");

    let proj = Projection::generate(s_tilde, d, 99);
    let mut rng = Pcg64::new(11);
    let g: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let expect = proj.apply_dense(&g);

    let out = exe
        .run_f32(&[
            InputF32 {
                data: &proj.matrix.data,
                dims: &[s_tilde as i64, d as i64],
            },
            InputF32 {
                data: &g,
                dims: &[d as i64],
            },
        ])
        .expect("execute projection");
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), s_tilde);
    let mut max_err = 0f64;
    for (a, b) in out[0].iter().zip(&expect) {
        max_err = max_err.max(((a - b) as f64).abs());
    }
    assert!(max_err < 1e-3, "projection mismatch: {max_err}");
}

#[test]
fn pjrt_amp_step_matches_rust_iteration() {
    let Some((runtime, manifest)) = manifest_or_skip() else {
        return;
    };
    let Some(art) = manifest.find_kind("amp_step") else {
        eprintln!("SKIP: no amp_step artifact");
        return;
    };
    let s_tilde = art.meta_usize("s_tilde").unwrap();
    let d = art.meta_usize("dim").unwrap();
    let exe = runtime.load_hlo(&art.file).expect("compile amp_step HLO");

    // Build a synthetic AMP state and compute one iteration in rust
    // (replicating amp::recover's loop body) and via the artifact.
    let proj = Projection::generate(s_tilde, d, 5);
    let mut rng = Pcg64::new(13);
    let mut x_true = vec![0f32; d];
    for i in rng.sample_indices(d, 40) {
        x_true[i] = rng.normal() as f32;
    }
    let y = proj.apply_dense(&x_true);
    let x0 = vec![0f32; d];
    let r0 = y.clone();

    // rust single iteration:
    let sigma = ota_dsgd::tensor::norm(&r0) / (s_tilde as f64).sqrt();
    let tau = 1.1f32 * sigma as f32;
    let mut pseudo = vec![0f32; d];
    ota_dsgd::tensor::gemv_t(&proj.matrix, &r0, &mut pseudo);
    for (p, &xi) in pseudo.iter_mut().zip(&x0) {
        *p += xi;
    }
    let mut x1 = pseudo;
    ota_dsgd::tensor::soft_threshold(&mut x1, tau);
    let nnz = x1.iter().filter(|&&v| v != 0.0).count();
    let b = nnz as f32 / s_tilde as f32;
    let ax = proj.apply_dense(&x1);
    let r1: Vec<f32> = y
        .iter()
        .zip(ax.iter().zip(&r0))
        .map(|(&yi, (&axi, &ri))| yi - axi + b * ri)
        .collect();

    let out = exe
        .run_f32(&[
            InputF32 {
                data: &proj.matrix.data,
                dims: &[s_tilde as i64, d as i64],
            },
            InputF32 {
                data: &y,
                dims: &[s_tilde as i64],
            },
            InputF32 {
                data: &x0,
                dims: &[d as i64],
            },
            InputF32 {
                data: &r0,
                dims: &[s_tilde as i64],
            },
        ])
        .expect("execute amp_step");
    assert_eq!(out.len(), 3, "amp_step returns (x', r', tau)");
    let (xj, rj) = (&out[0], &out[1]);
    let scale = ota_dsgd::tensor::norm(&x1).max(1.0) as f32;
    let mut max_err = 0f64;
    for (a, b) in xj.iter().zip(&x1) {
        max_err = max_err.max((((a - b) / scale) as f64).abs());
    }
    assert!(max_err < 1e-4, "amp_step x mismatch: {max_err}");
    let mut max_err_r = 0f64;
    for (a, b) in rj.iter().zip(&r1) {
        max_err_r = max_err_r.max(((a - b) as f64).abs());
    }
    assert!(max_err_r < 1e-2, "amp_step r mismatch: {max_err_r}");
}
