//! Golden scheme-equivalence: the refactored `LinkScheme` pipeline must
//! reproduce, round for round, the grad-norm trajectory of the seed
//! trainer's monolithic loop, reimplemented below exactly as the
//! pre-refactor `Trainer::run` dispatched it. Note the reference is built
//! from the same live components (compressors, AMP, MAC, Adam) the
//! pipeline uses — what this freezes is the *orchestration wiring*: scheme
//! dispatch, RNG stream constants, per-device seeding, encode/aggregate
//! order, and the mean-removal phase transition. A regression inside a
//! shared component moves both sides equally and is covered by that
//! component's own tests, not this file. One table entry per scheme; any
//! wiring drift fails the corresponding row.

use ota_dsgd::amp::AmpConfig;
use ota_dsgd::analog::{AnalogDevice, AnalogPs, Projection};
use ota_dsgd::channel::{GaussianMac, PowerAllocator};
use ota_dsgd::compress::DigitalPayload;
use ota_dsgd::config::{
    presets, FadingDist, GraphFamily, LinkKind, ParticipationPolicy, RunConfig, Scheme,
    TopologyConfig,
};
use ota_dsgd::coordinator::{GradientBackend, RustBackend, Trainer};
use ota_dsgd::digital::{aggregate, capacity_bits, DigitalDevice};
use ota_dsgd::model::PARAM_DIM;
use ota_dsgd::optim::{Adam, Optimizer};
use ota_dsgd::tensor;

fn golden_cfg(scheme: Scheme) -> RunConfig {
    RunConfig {
        scheme,
        iterations: 6,
        eval_every: 2,
        ..presets::smoke()
    }
}

/// The seed trainer's round loop, scheme dispatch and all, exactly as it
/// stood before the `LinkScheme` extraction. Returns the per-round ‖ĝ‖.
fn seed_reference_trajectory(cfg: &RunConfig) -> Vec<f64> {
    // Same corpus/shard plumbing as the trainer under test.
    let tr = Trainer::new(cfg.clone()).expect("reference trainer");
    let corpus = tr.corpus();
    let shards = tr.shards();
    let d = PARAM_DIM;
    let m = cfg.devices;

    let mut params = vec![0f32; d];
    let mut optimizer = Adam::new(d, cfg.lr as f32);
    let power = PowerAllocator::new(cfg.power, cfg.pbar, cfg.iterations);
    let mut backend = RustBackend::new();

    // Device state, seeded per device exactly as the seed did.
    let mut analog_devices: Vec<AnalogDevice> = Vec::new();
    let mut digital_devices: Vec<DigitalDevice> = Vec::new();
    match cfg.scheme.kind() {
        LinkKind::Analog => {
            analog_devices = (0..m).map(|_| AnalogDevice::new(d, cfg.sparsity)).collect();
        }
        LinkKind::Digital => {
            digital_devices = (0..m)
                .map(|i| {
                    DigitalDevice::new(
                        cfg.scheme,
                        d,
                        cfg.qsgd_levels,
                        cfg.seed.wrapping_add(i as u64),
                    )
                })
                .collect();
        }
        LinkKind::Passthrough => {}
        // The fading and D2D schemes postdate the seed trainer; their
        // goldens are the degeneracies against the static A-DSGD
        // trajectory below (h ≡ 1, fully-connected graph).
        LinkKind::Fading | LinkKind::D2d => {
            panic!("no seed reference for fading/d2d schemes")
        }
    }

    // Channel + analog decoders (seed RNG-stream constants).
    let mut mac = GaussianMac::new(cfg.channel_uses, m, cfg.noise_var, cfg.seed ^ 0xC4A);
    let amp_cfg = AmpConfig {
        max_iters: cfg.amp_iters,
        tol: cfg.amp_tol,
        threshold_mult: cfg.amp_threshold_mult as f32,
    };
    let (mut ps_std, mut ps_mr): (Option<AnalogPs>, Option<AnalogPs>) = (None, None);
    if cfg.scheme == Scheme::ADsgd {
        ps_std = Some(AnalogPs::new(
            Projection::generate(cfg.channel_uses - 1, d, cfg.seed ^ 0xA57D),
            amp_cfg,
        ));
        if cfg.mean_removal_rounds > 0 {
            ps_mr = Some(AnalogPs::new(
                Projection::generate(cfg.channel_uses - 2, d, cfg.seed ^ 0xA57E),
                amp_cfg,
            ));
        }
    }

    let mut trajectory = Vec::with_capacity(cfg.iterations);
    for t in 0..cfg.iterations {
        let p_t = power.p(t);
        let grads = backend.per_device_gradients(&params, &corpus.train, shards);

        let ghat: Vec<f32> = match cfg.scheme {
            Scheme::FadingADsgd | Scheme::BlindADsgd | Scheme::D2dADsgd => {
                panic!("no seed reference for fading/d2d schemes")
            }
            Scheme::ErrorFree => {
                let mut avg = vec![0f32; d];
                for dev in 0..m {
                    tensor::axpy(1.0 / m as f32, grads.row(dev), &mut avg);
                }
                avg
            }
            Scheme::DDsgd | Scheme::SignSgd | Scheme::Qsgd => {
                let budget = capacity_bits(cfg.channel_uses, m, p_t, cfg.noise_var);
                let payloads: Vec<DigitalPayload> = digital_devices
                    .iter_mut()
                    .enumerate()
                    .map(|(dev, state)| state.transmit(grads.row(dev), budget))
                    .collect();
                aggregate(&payloads, d)
            }
            Scheme::ADsgd => {
                let mean_removal = t < cfg.mean_removal_rounds;
                let (frames, decoder): (Vec<Vec<f32>>, &AnalogPs) = if mean_removal {
                    let ps = ps_mr.as_ref().expect("mean-removal decoder");
                    let proj = ps.projection();
                    let frames = analog_devices
                        .iter_mut()
                        .enumerate()
                        .map(|(dev, state)| {
                            state
                                .transmit_mean_removed(
                                    grads.row(dev),
                                    proj,
                                    p_t,
                                    cfg.channel_uses,
                                )
                                .x
                        })
                        .collect();
                    (frames, ps)
                } else {
                    let ps = ps_std.as_ref().expect("analog decoder");
                    let proj = ps.projection();
                    let frames = analog_devices
                        .iter_mut()
                        .enumerate()
                        .map(|(dev, state)| state.transmit(grads.row(dev), proj, p_t).x)
                        .collect();
                    (frames, ps)
                };
                let y = mac.transmit(&frames);
                let (ghat, _trace) = if mean_removal {
                    decoder.decode_mean_removed(&y)
                } else {
                    decoder.decode(&y)
                };
                if !mean_removal && ps_mr.is_some() {
                    ps_mr = None;
                }
                ghat
            }
        };

        optimizer.step(&mut params, &ghat);
        trajectory.push(tensor::norm(&ghat));
    }
    trajectory
}

/// Per-scheme golden table: refactored pipeline == seed loop, bit for bit.
#[test]
fn link_schemes_reproduce_seed_trainer() {
    for scheme in [
        Scheme::ErrorFree,
        Scheme::ADsgd,
        Scheme::DDsgd,
        Scheme::SignSgd,
        Scheme::Qsgd,
    ] {
        let cfg = golden_cfg(scheme);
        let golden = seed_reference_trajectory(&cfg);
        let got: Vec<f64> = Trainer::new(cfg)
            .expect("trainer")
            .run()
            .records
            .iter()
            .map(|r| r.grad_norm)
            .collect();
        assert_eq!(got, golden, "{scheme:?} diverged from the seed trainer");
    }
}

fn trajectory(cfg: RunConfig) -> Vec<f64> {
    Trainer::new(cfg)
        .expect("trainer")
        .run()
        .records
        .iter()
        .map(|r| r.grad_norm)
        .collect()
}

/// Degeneracy golden: with h_m(t) ≡ 1 and full participation, both fading
/// variants (CSI truncated inversion and blind) collapse to the static
/// Gaussian MAC — the grad-norm trajectory must equal `AnalogLink`'s bit
/// for bit. Every scaling the fading path adds is a multiplication by
/// `1.0f32` (exact) and the projection/MAC/noise streams share the static
/// link's seeds, so *any* drift here is a wiring regression.
#[test]
fn fading_unit_gain_reproduces_static_adsgd() {
    let golden = trajectory(golden_cfg(Scheme::ADsgd));
    for scheme in [Scheme::FadingADsgd, Scheme::BlindADsgd] {
        let cfg = RunConfig {
            scheme,
            fading: FadingDist::Constant(1.0),
            csi_threshold: 0.5,
            participation: ParticipationPolicy::Full,
            ..golden_cfg(Scheme::ADsgd)
        };
        assert_eq!(
            trajectory(cfg),
            golden,
            "{scheme:?} with h ≡ 1 diverged from the static A-DSGD trainer"
        );
    }
}

/// Degeneracy golden: uniform-K participation with K = M schedules every
/// device every round — bit-identical to the no-selector (Full) path, even
/// under real Rayleigh fading.
#[test]
fn uniform_k_equals_m_matches_full_participation() {
    let base = RunConfig {
        scheme: Scheme::FadingADsgd,
        fading: FadingDist::Rayleigh,
        csi_threshold: 0.2,
        ..golden_cfg(Scheme::ADsgd)
    };
    let m = base.devices;
    let full = trajectory(RunConfig {
        participation: ParticipationPolicy::Full,
        ..base.clone()
    });
    let k_eq_m = trajectory(RunConfig {
        participation: ParticipationPolicy::UniformK(m),
        ..base
    });
    assert_eq!(full, k_eq_m, "K = M must match the no-selector path");
}

/// The long-horizon variant of the degeneracy goldens for the nightly
/// `cargo test --release -- --ignored` CI job: more devices, more rounds,
/// both fading variants, plus the K = M equivalence, all in one pass.
#[test]
#[ignore = "slow golden trajectory; run via `cargo test --release -- --ignored`"]
fn fading_degeneracy_goldens_long() {
    let base = RunConfig {
        iterations: 12,
        eval_every: 4,
        devices: 12,
        local_samples: 80,
        ..presets::smoke()
    };
    let golden = trajectory(RunConfig {
        scheme: Scheme::ADsgd,
        ..base.clone()
    });
    assert_eq!(golden.len(), 12);
    for scheme in [Scheme::FadingADsgd, Scheme::BlindADsgd] {
        let cfg = RunConfig {
            scheme,
            fading: FadingDist::Constant(1.0),
            csi_threshold: 0.5,
            participation: ParticipationPolicy::Full,
            ..base.clone()
        };
        assert_eq!(trajectory(cfg), golden, "{scheme:?} long-horizon degeneracy");
    }
    let rayleigh = RunConfig {
        scheme: Scheme::FadingADsgd,
        ..base
    };
    let full = trajectory(RunConfig {
        participation: ParticipationPolicy::Full,
        ..rayleigh.clone()
    });
    let k_eq_m = trajectory(RunConfig {
        participation: ParticipationPolicy::UniformK(12),
        ..rayleigh
    });
    assert_eq!(full, k_eq_m);
}

/// Degeneracy golden: fully-connected uniform-weight D2D collapses to star
/// A-DSGD bit-for-bit. On the complete graph Metropolis weights are the
/// uniform 1/M matrix, every receiver's closed neighborhood is the whole
/// fleet, the shared broadcast noise draw rides the star MAC's RNG stream,
/// and the deviation-form mixing is a bit-exact no-op on lockstep replicas
/// — so each replica's Adam trajectory equals the PS's, and the reported
/// grad-norm series must match exactly. Consensus distance must pin to an
/// exact 0.0 every round.
#[test]
fn d2d_full_graph_reproduces_star_adsgd() {
    let golden = trajectory(golden_cfg(Scheme::ADsgd));
    let cfg = RunConfig {
        scheme: Scheme::D2dADsgd,
        fading: FadingDist::Constant(1.0),
        topology: TopologyConfig {
            family: GraphFamily::Full,
            ..TopologyConfig::default()
        },
        ..golden_cfg(Scheme::ADsgd)
    };
    let log = Trainer::new(cfg).expect("trainer").run();
    let got: Vec<f64> = log.records.iter().map(|r| r.grad_norm).collect();
    assert_eq!(
        got, golden,
        "fully-connected D2D diverged from the star A-DSGD trainer"
    );
    for r in &log.records {
        assert_eq!(
            r.consensus_distance,
            Some(0.0),
            "t={}: complete-graph replicas must stay in exact consensus",
            r.iter
        );
    }
    assert!(log.power_constraint_ok(1e-6), "{:?}", log.measured_avg_power);
}

/// Degeneracy golden: uniform-K participation with K = M on the *digital*
/// link is bit-identical to the always-on path (the selector satellite
/// must not perturb the scheduled-everyone case), and a real K < M run
/// reports Option-typed participation counts.
#[test]
fn digital_uniform_k_equals_m_matches_full_participation() {
    let base = golden_cfg(Scheme::DDsgd);
    let m = base.devices;
    let full = trajectory(RunConfig {
        participation: ParticipationPolicy::Full,
        ..base.clone()
    });
    let k_eq_m = trajectory(RunConfig {
        participation: ParticipationPolicy::UniformK(m),
        ..base.clone()
    });
    assert_eq!(full, k_eq_m, "digital K = M must match the no-selector path");
    // K < M: counts partition the fleet and the Full path stays None.
    let log = Trainer::new(RunConfig {
        participation: ParticipationPolicy::UniformK(m / 2),
        ..base.clone()
    })
    .expect("trainer")
    .run();
    for r in &log.records {
        let p = r.participation.expect("partial digital reports stats");
        assert_eq!(p.transmitting, m / 2, "t={}", r.iter);
        assert_eq!(p.total(), m, "t={}", r.iter);
    }
    assert!(log.power_constraint_ok(1e-6));
    let log_full = Trainer::new(base).expect("trainer").run();
    assert!(log_full.records.iter().all(|r| r.participation.is_none()));
}

/// The digital arm's bits telemetry: actual payload bits, within budget.
#[test]
fn digital_bits_telemetry_is_actual_and_bounded() {
    let cfg = golden_cfg(Scheme::DDsgd);
    let log = Trainer::new(cfg.clone()).expect("trainer").run();
    for r in &log.records {
        let budget = capacity_bits(cfg.channel_uses, cfg.devices, r.p_t, cfg.noise_var);
        assert!(
            r.bits_per_device <= budget,
            "t={}: reported {} bits > budget {}",
            r.iter,
            r.bits_per_device,
            budget
        );
        assert!(r.bits_per_device > 0.0, "t={}: smoke budget admits bits", r.iter);
    }
}
