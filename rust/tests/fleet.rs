//! Fleet semantics, end to end:
//!
//! * a 4-worker fleet produces `summary.csv` byte-identical to the
//!   single-process runner, and the cache-fronted `repro fig` path over
//!   the fleet's store regenerates even the per-run CSVs byte-identically
//!   (wall-clock columns included — they come from the stored result);
//! * a worker SIGKILL'd mid-run leaves a stale lease that a surviving
//!   worker reclaims, resuming from the latest snapshot rather than
//!   recomputing, with final output byte-identical to the uninterrupted
//!   golden (this test is the CI fleet-smoke step).

use std::path::{Path, PathBuf};
use std::time::Duration;

use ota_dsgd::campaign::{manifest::RunStatus, scheduler, CampaignReport, RunManifest, RunStore};
use ota_dsgd::config::{presets, CampaignConfig, FleetConfig, RunConfig, Scheme};
use ota_dsgd::experiments::runner::{self, ExperimentSpec};
use ota_dsgd::fleet;
use ota_dsgd::model::PARAM_DIM;

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn read(path: &Path) -> Vec<u8> {
    std::fs::read(path).unwrap_or_else(|e| panic!("read {path:?}: {e}"))
}

fn lean(scheme: Scheme) -> RunConfig {
    RunConfig {
        scheme,
        iterations: 4,
        eval_every: 2,
        channel_uses: PARAM_DIM / 8,
        sparsity: PARAM_DIM / 16,
        ..presets::smoke()
    }
}

fn spec() -> ExperimentSpec {
    ExperimentSpec {
        id: "tfleet".into(),
        title: "fleet vs single-process".into(),
        runs: vec![
            ("error-free".into(), lean(Scheme::ErrorFree)),
            ("signsgd".into(), lean(Scheme::SignSgd)),
            ("qsgd".into(), lean(Scheme::Qsgd)),
        ],
    }
}

fn campaign_for(store_dir: &str) -> CampaignConfig {
    CampaignConfig {
        snapshot_every: 1,
        store_dir: store_dir.to_string(),
        ..CampaignConfig::default()
    }
}

/// Compare two per-run CSVs cell by cell, ignoring the wall-clock
/// `round_secs` column (independent executions time differently; byte
/// identity across executions is asserted separately via the cache path).
fn assert_csv_equal_modulo_timing(a: &Path, b: &Path, label: &str) {
    let ra = ota_dsgd::util::csv::read_csv(a).expect("csv a");
    let rb = ota_dsgd::util::csv::read_csv(b).expect("csv b");
    assert_eq!(ra.len(), rb.len(), "{label}: row count");
    let t_col = ra[0]
        .iter()
        .position(|h| h == "round_secs")
        .expect("round_secs column");
    for (i, (rowa, rowb)) in ra.iter().zip(&rb).enumerate() {
        for (c, (va, vb)) in rowa.iter().zip(rowb).enumerate() {
            if c != t_col {
                assert_eq!(va, vb, "{label}: row {i} col {c}");
            }
        }
    }
}

/// The acceptance gate: 4 in-process workers over one store ≡ 1 worker
/// over another store ≡ the plain single-process runner, and `repro fig`'s
/// cache path over the fleet store regenerates per-run CSVs byte-for-byte.
#[test]
fn fleet_of_four_matches_single_process_byte_identical() {
    let base = fresh_dir("ota_fleet_identity_test");
    // Reference: the plain single-process runner, no store at all.
    let out_ref = base.join("ref");
    runner::run_experiment(&spec(), out_ref.to_str().unwrap(), false);

    // Fleet A: 4 concurrent workers sharing one store.
    let store4 = base.join("store4").to_str().unwrap().to_string();
    {
        let store = RunStore::open(&store4).unwrap();
        fleet::enqueue_specs(&store, &[spec()]).unwrap();
    }
    let campaign = campaign_for(&store4);
    let fleet_cfg = FleetConfig::default();
    let reports: Vec<fleet::WorkerReport> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let store4 = &store4;
                let campaign = &campaign;
                let fleet_cfg = &fleet_cfg;
                scope.spawn(move || {
                    fleet::run_worker(store4, fleet_cfg, campaign, &format!("w{i}"), false)
                        .unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let executed: usize = reports.iter().map(|r| r.executed + r.resumed).sum();
    assert_eq!(executed, 3, "every run executed exactly once across the fleet: {reports:?}");
    let out4 = base.join("out4");
    {
        let store = RunStore::open(&store4).unwrap();
        fleet::collect_outputs(&store, &[spec()], out4.to_str().unwrap()).unwrap();
    }

    // Fleet B: a single worker in a fresh store.
    let store1 = base.join("store1").to_str().unwrap().to_string();
    {
        let store = RunStore::open(&store1).unwrap();
        fleet::enqueue_specs(&store, &[spec()]).unwrap();
    }
    fleet::run_worker(&store1, &fleet_cfg, &campaign_for(&store1), "solo", false).unwrap();
    let out1 = base.join("out1");
    {
        let store = RunStore::open(&store1).unwrap();
        fleet::collect_outputs(&store, &[spec()], out1.to_str().unwrap()).unwrap();
    }

    // summary.csv is fully deterministic: byte-identical across the plain
    // runner, the 1-worker fleet and the 4-worker fleet.
    let summary_ref = read(&out_ref.join("tfleet/summary.csv"));
    assert_eq!(
        summary_ref,
        read(&out4.join("tfleet/summary.csv")),
        "4-worker fleet summary must be byte-identical to single-process"
    );
    assert_eq!(
        summary_ref,
        read(&out1.join("tfleet/summary.csv")),
        "1-worker fleet summary must be byte-identical to single-process"
    );
    // Per-run CSVs: identical numbers, timing column aside.
    for label in ["error-free", "signsgd", "qsgd"] {
        assert_csv_equal_modulo_timing(
            &out_ref.join(format!("tfleet/{label}.csv")),
            &out4.join(format!("tfleet/{label}.csv")),
            label,
        );
    }

    // `repro fig` over the fleet's store is a pure cache load and its
    // per-run CSVs are byte-identical to the fleet's — wall clock
    // included, because both regenerate from the same stored result.
    let out_fig = base.join("out_fig");
    let (_, report) = scheduler::run_experiment_cached(
        &spec(),
        out_fig.to_str().unwrap(),
        false,
        &campaign,
    );
    assert_eq!(
        report,
        CampaignReport { executed: 0, resumed: 0, cached: 3 },
        "the figure path must serve entirely from the fleet's store"
    );
    for file in ["summary.csv", "error-free.csv", "signsgd.csv", "qsgd.csv"] {
        assert_eq!(
            read(&out4.join(format!("tfleet/{file}"))),
            read(&out_fig.join(format!("tfleet/{file}"))),
            "{file} must be byte-identical between fleet output and cached repro fig"
        );
    }
    std::fs::remove_dir_all(&base).ok();
}

/// The CI fleet-smoke: enqueue a campaign, attach a real `repro worker`
/// process, SIGKILL it mid-run, and verify a second worker reclaims the
/// stale lease, resumes from the snapshot (not from scratch), and the
/// resume/cache path completes with output byte-identical to the
/// uninterrupted golden.
#[test]
fn sigkill_worker_reclaim_resumes_to_identical_output() {
    let base = fresh_dir("ota_fleet_sigkill_test");
    // One long run so the kill reliably lands mid-execution: error-free
    // rounds are milliseconds, snapshots land every round.
    let cfg = RunConfig {
        iterations: 400,
        eval_every: 100,
        ..lean(Scheme::ErrorFree)
    };
    let spec = || ExperimentSpec {
        id: "tkill".into(),
        title: "sigkill reclaim".into(),
        runs: vec![("error-free".into(), cfg.clone())],
    };
    // Golden: the uninterrupted single-process trajectory.
    let out_ref = base.join("ref");
    let golden = runner::run_experiment(&spec(), out_ref.to_str().unwrap(), false);

    let store_dir = base.join("store").to_str().unwrap().to_string();
    let store = RunStore::open(&store_dir).unwrap();
    let items = fleet::enqueue_specs(&store, &[spec()]).unwrap();
    let key = items[0].key.clone();

    // A real worker process, snapshotting every round, heartbeating fast.
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["worker", "--store-dir", store_dir.as_str()])
        .args(["--lease-secs", "2", "--heartbeat-secs", "0.5"])
        .args(["--snapshot-every", "1", "--worker-id", "victim"])
        .arg("--quiet")
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn repro worker");

    // Wait until it has made mid-run progress (a partial manifest with a
    // few snapshot rounds), then SIGKILL it — no cleanup, no release.
    let manifest_path = store.root().join(&key).join("manifest.toml");
    let mut progressed = false;
    for _ in 0..3000 {
        if let Ok(m) = RunManifest::read(&manifest_path) {
            if m.status == RunStatus::Partial && m.snapshot_round >= 3 {
                progressed = true;
                break;
            }
            if m.status == RunStatus::Complete {
                break;
            }
        }
        if child.try_wait().expect("try_wait").is_some() {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    child.kill().ok();
    child.wait().ok();
    assert!(
        progressed,
        "worker must reach a mid-run snapshot before the kill (machine too slow or worker died early?)"
    );
    let partial_round = RunManifest::read(&manifest_path).unwrap().snapshot_round;
    assert!(store.load_result(&cfg).is_none(), "the kill must land mid-run");

    // A surviving worker reclaims the stale lease (TTL 2s) and resumes
    // from the snapshot rather than recomputing from round 0.
    let fleet_cfg = FleetConfig {
        workers: 1,
        lease_secs: 2.0,
        heartbeat_secs: 0.5,
    };
    let campaign = campaign_for(&store_dir);
    let report = fleet::run_worker(&store_dir, &fleet_cfg, &campaign, "survivor", false).unwrap();
    assert_eq!(
        (report.executed, report.resumed),
        (0, 1),
        "the survivor must resume the dead worker's run from its snapshot, not restart it"
    );
    let finished = RunManifest::read(&manifest_path).unwrap();
    assert_eq!(finished.status, RunStatus::Complete);
    assert!(
        partial_round >= 3,
        "resume started from round {partial_round}, so at least that much work was salvaged"
    );

    // The kill can at worst tear the victim's *own* trailing event line;
    // the log as a whole must stay readable, and replaying it must show
    // the lease steal exactly once (the reclaim callback fires only in
    // the winning rename branch).
    let ev_report = fleet::read_events(store.root());
    assert_eq!(
        ev_report.unreadable_files, 0,
        "every event segment must still open after a SIGKILL"
    );
    let ev_metrics = fleet::reduce_report(&ev_report);
    assert_eq!(
        ev_metrics.reclaims, 1,
        "the stale lease must be reclaimed exactly once"
    );
    assert!(
        ev_metrics.resumed.contains(&key),
        "the event log must record the survivor's resume of {key}"
    );
    assert!(
        ev_metrics.completed.contains(&key),
        "the event log must record the run completing"
    );

    // The resumed trajectory is the golden one, bit for bit…
    let result = store.load_result(&cfg).expect("completed result");
    let bits = |log: &ota_dsgd::coordinator::TrainLog| {
        log.records.iter().map(|r| r.grad_norm.to_bits()).collect::<Vec<_>>()
    };
    assert_eq!(bits(&golden[0]), bits(&result));

    // …and `repro resume`'s machinery over this store completes as a pure
    // cache load with summary.csv byte-identical to the golden.
    let out_resume = base.join("out_resume");
    let (_, rep) = scheduler::run_experiment_cached(
        &spec(),
        out_resume.to_str().unwrap(),
        false,
        &campaign,
    );
    assert_eq!(rep, CampaignReport { executed: 0, resumed: 0, cached: 1 });
    assert_eq!(
        read(&out_ref.join("tkill/summary.csv")),
        read(&out_resume.join("tkill/summary.csv")),
        "post-kill resume output must match the uninterrupted golden byte-for-byte"
    );
    std::fs::remove_dir_all(&base).ok();
}
