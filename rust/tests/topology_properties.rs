//! Property layer over the topology subsystem: every graph family, for
//! random seeds and sizes, must yield a connected graph whose mixing
//! matrix (either rule) is symmetric, doubly stochastic with rows summing
//! to 1 ± 1e-12, non-negative, and has a strictly positive spectral gap —
//! the invariants the decentralized consensus update needs to preserve the
//! replica average and contract disagreement. Plus the D2D link-level
//! contract: consensus distance telemetry present every round, the Eq. 6
//! audit intact, and thread-pool-size invariance of the full D2D round.

use ota_dsgd::config::{
    presets, FadingDist, GraphFamily, MixingRule, RunConfig, Scheme, TopologyConfig,
};
use ota_dsgd::coordinator::link::{D2dAnalogLink, LinkScheme, RoundCtx};
use ota_dsgd::tensor::Matf;
use ota_dsgd::topology::{Graph, MixingMatrix};
use ota_dsgd::util::proptest::{run_property_noshrink, Check, PropConfig};
use ota_dsgd::util::rng::Pcg64;

const FAMILIES: [GraphFamily; 5] = [
    GraphFamily::Full,
    GraphFamily::Ring,
    GraphFamily::Torus,
    GraphFamily::ErdosRenyi,
    GraphFamily::Star,
];

/// Connected + symmetric + doubly stochastic (1 ± 1e-12) + non-negative +
/// positive spectral gap, for every family × rule over random seeds/sizes.
#[test]
fn prop_every_family_yields_valid_mixing() {
    run_property_noshrink(
        "topology-mixing-invariants",
        PropConfig {
            cases: 12,
            ..Default::default()
        },
        |rng| {
            let m = 2 + rng.below(23) as usize;
            let degree = 1 + rng.below(((m - 1).max(1)) as u64) as usize;
            let p = 0.15 + 0.8 * rng.f64();
            let seed = rng.next_u64();
            (m, degree, p, seed)
        },
        |&(m, degree, p, seed)| {
            for family in FAMILIES {
                let topo = TopologyConfig {
                    family,
                    degree,
                    p,
                    mixing: MixingRule::Metropolis,
                    seed,
                };
                let graph = Graph::build(&topo, m, seed ^ 0xABC);
                if !graph.is_connected() {
                    return Check::Fail(format!("{family:?} M={m} seed={seed}: disconnected"));
                }
                if graph.devices() != m {
                    return Check::Fail(format!("{family:?}: device count"));
                }
                for rule in [MixingRule::Metropolis, MixingRule::MaxDegree] {
                    let w = MixingMatrix::build(&graph, rule);
                    if w.max_symmetry_error() != 0.0 {
                        return Check::Fail(format!(
                            "{family:?}/{rule:?} M={m}: asymmetry {}",
                            w.max_symmetry_error()
                        ));
                    }
                    if w.max_row_sum_error() > 1e-12 {
                        return Check::Fail(format!(
                            "{family:?}/{rule:?} M={m}: row sum error {}",
                            w.max_row_sum_error()
                        ));
                    }
                    if w.min_weight() < 0.0 {
                        return Check::Fail(format!(
                            "{family:?}/{rule:?} M={m}: negative weight {}",
                            w.min_weight()
                        ));
                    }
                    let gap = w.spectral_gap();
                    if !(gap > 0.0 && gap <= 1.0 + 1e-9) {
                        return Check::Fail(format!(
                            "{family:?}/{rule:?} M={m}: spectral gap {gap}"
                        ));
                    }
                }
            }
            Check::Pass
        },
    );
}

/// Mixing weights live only on graph edges (plus the diagonal): W must be
/// implementable by neighbor-local communication.
#[test]
fn prop_weights_supported_on_edges() {
    run_property_noshrink(
        "topology-weight-support",
        PropConfig {
            cases: 8,
            ..Default::default()
        },
        |rng| (3 + rng.below(15) as usize, rng.next_u64()),
        |&(m, seed)| {
            for family in FAMILIES {
                let topo = TopologyConfig {
                    family,
                    seed,
                    ..TopologyConfig::default()
                };
                let graph = Graph::build(&topo, m, seed);
                let w = MixingMatrix::metropolis(&graph);
                for i in 0..m {
                    for j in 0..m {
                        let is_edge = graph.neighbors(i).contains(&j);
                        let wij = w.weight(i, j);
                        if i != j && !is_edge && wij != 0.0 {
                            return Check::Fail(format!(
                                "{family:?} M={m}: weight {wij} off the edge set at ({i},{j})"
                            ));
                        }
                        if i != j && is_edge && wij <= 0.0 {
                            return Check::Fail(format!(
                                "{family:?} M={m}: non-positive edge weight at ({i},{j})"
                            ));
                        }
                    }
                }
            }
            Check::Pass
        },
    );
}

/// The consensus operator in deviation form preserves the replica average
/// (doubly stochastic W) and contracts disagreement by at least the
/// spectral-gap rate on a random replica matrix.
#[test]
fn prop_mixing_preserves_average_and_contracts() {
    run_property_noshrink(
        "topology-mixing-contraction",
        PropConfig {
            cases: 8,
            ..Default::default()
        },
        |rng| (4 + rng.below(12) as usize, rng.next_u64()),
        |&(m, seed)| {
            let topo = TopologyConfig {
                family: GraphFamily::ErdosRenyi,
                p: 0.5,
                seed,
                ..TopologyConfig::default()
            };
            let graph = Graph::build(&topo, m, seed);
            let w = MixingMatrix::metropolis(&graph);
            let d = 24usize;
            let mut rng = Pcg64::new(seed ^ 0x5EED);
            let theta: Vec<Vec<f64>> = (0..m)
                .map(|_| (0..d).map(|_| rng.normal()).collect())
                .collect();
            // θ̃_i = θ_i + Σ_j W_ij (θ_j − θ_i)
            let mixed: Vec<Vec<f64>> = (0..m)
                .map(|i| {
                    (0..d)
                        .map(|c| {
                            let acc: f64 = graph
                                .neighbors(i)
                                .iter()
                                .map(|&j| w.weight(i, j) * (theta[j][c] - theta[i][c]))
                                .sum();
                            theta[i][c] + acc
                        })
                        .collect()
                })
                .collect();
            let mean = |ths: &[Vec<f64>]| -> Vec<f64> {
                let mut mu = vec![0.0; d];
                for th in ths {
                    for (a, &v) in mu.iter_mut().zip(th) {
                        *a += v / m as f64;
                    }
                }
                mu
            };
            let disagreement = |ths: &[Vec<f64>], mu: &[f64]| -> f64 {
                ths.iter()
                    .map(|th| {
                        th.iter()
                            .zip(mu)
                            .map(|(&v, &u)| (v - u) * (v - u))
                            .sum::<f64>()
                    })
                    .sum::<f64>()
                    .sqrt()
            };
            let mu_before = mean(&theta);
            let mu_after = mean(&mixed);
            for (a, b) in mu_before.iter().zip(&mu_after) {
                if (a - b).abs() > 1e-9 {
                    return Check::Fail(format!(
                        "average not preserved: {a} vs {b} (M={m} seed={seed})"
                    ));
                }
            }
            let before = disagreement(&theta, &mu_before);
            let after = disagreement(&mixed, &mu_after);
            // Small slack: the gap is a power-iteration estimate, so the
            // implied ρ can sit marginally below the true contraction
            // factor when trailing eigenvalues are nearly degenerate.
            let rho = 1.0 - w.spectral_gap();
            if after > before * (rho + 1e-3) + 1e-9 {
                return Check::Fail(format!(
                    "disagreement {before} -> {after} exceeds spectral bound ρ={rho} \
                     (M={m} seed={seed})"
                ));
            }
            Check::Pass
        },
    );
}

fn d2d_cfg(family: GraphFamily, m: usize, seed: u64) -> RunConfig {
    RunConfig {
        scheme: Scheme::D2dADsgd,
        devices: m,
        channel_uses: 101,
        sparsity: 25,
        mean_removal_rounds: 1,
        amp_iters: 15,
        seed,
        fading: FadingDist::Constant(1.0),
        topology: TopologyConfig {
            family,
            seed: 0,
            ..TopologyConfig::default()
        },
        ..presets::smoke()
    }
}

fn grads(m: usize, d: usize, seed: u64) -> Matf {
    let mut rng = Pcg64::new(seed);
    Matf::from_vec(
        m,
        d,
        (0..m * d).map(|_| rng.normal_ms(0.0, 0.2) as f32).collect(),
    )
}

/// Link-level D2D contract over random families: consensus distance
/// reported and finite every round, Eq. 6 power audit intact, ĝ shaped.
#[test]
fn prop_d2d_link_contract() {
    run_property_noshrink(
        "d2d-link-contract",
        PropConfig {
            cases: 6,
            ..Default::default()
        },
        |rng| {
            let family = FAMILIES[rng.below(5) as usize];
            let m = 4 + rng.below(5) as usize;
            (family, m, rng.next_u64())
        },
        |&(family, m, seed)| {
            let d = 300;
            let cfg = d2d_cfg(family, m, seed);
            let mut link = D2dAnalogLink::new(&cfg, d);
            let g = grads(m, d, seed ^ 1);
            for t in 0..3 {
                let out = link.round(
                    &RoundCtx {
                        t,
                        p_t: cfg.pbar,
                        deadline: None,
                    },
                    &g,
                );
                if out.ghat.len() != d {
                    return Check::Fail(format!("{family:?}: ghat len {}", out.ghat.len()));
                }
                let Some(dist) = out.telemetry.consensus_distance else {
                    return Check::Fail(format!("{family:?}: missing consensus distance"));
                };
                if !dist.is_finite() {
                    return Check::Fail(format!("{family:?}: consensus distance {dist}"));
                }
            }
            let powers = link.measured_avg_power();
            if powers.len() != m {
                return Check::Fail(format!("{family:?}: power report len {}", powers.len()));
            }
            for (dev, &p) in powers.iter().enumerate() {
                if p > cfg.pbar * (1.0 + 1e-4) {
                    return Check::Fail(format!(
                        "{family:?}: device {dev} avg power {p} > P̄ {}",
                        cfg.pbar
                    ));
                }
            }
            Check::Pass
        },
    );
}

/// The full D2D round — graph, per-edge gains, shared noise, per-receiver
/// AMP, mixing, local Adam steps — is bit-identical whether the device
/// encode fan-out runs sequentially or on a multi-worker pool.
#[test]
fn d2d_round_invariant_to_thread_pool_size() {
    let d = 300;
    let cfg = d2d_cfg(GraphFamily::Torus, 6, 33);
    let g = grads(6, d, 44);
    let run = |workers: usize| {
        let mut link = D2dAnalogLink::with_workers(&cfg, d, workers);
        let mut out = Vec::new();
        for t in 0..3 {
            let round = link.round(
                &RoundCtx {
                    t,
                    p_t: cfg.pbar,
                    deadline: None,
                },
                &g,
            );
            out.push((round.ghat, round.telemetry.consensus_distance));
        }
        (out, link.measured_avg_power())
    };
    let seq = run(1);
    for workers in [2usize, 4, 8] {
        assert_eq!(seq, run(workers), "workers={workers}");
    }
}

/// End-to-end D2D training through the scheme-agnostic trainer: consensus
/// distance lands in the round records (monotone coverage: every round
/// reports), the replica-average model's accuracy is evaluated, and the
/// same seed reproduces the same trajectory.
#[test]
fn d2d_trainer_end_to_end_deterministic() {
    let mut cfg = presets::d2d_smoke();
    cfg.iterations = 4;
    cfg.eval_every = 2;
    cfg.mean_removal_rounds = 1;
    let run = || {
        let log = ota_dsgd::coordinator::Trainer::new(cfg.clone())
            .expect("trainer")
            .run();
        assert_eq!(log.records.len(), 4);
        for r in &log.records {
            let dist = r.consensus_distance.expect("every D2D round reports consensus");
            assert!(dist.is_finite() && dist >= 0.0);
        }
        assert!(log.power_constraint_ok(1e-6), "{:?}", log.measured_avg_power);
        assert!(log.final_accuracy >= 0.0);
        log.records
            .iter()
            .map(|r| (r.grad_norm, r.consensus_distance))
            .collect::<Vec<_>>()
    };
    assert_eq!(run(), run(), "same seed must reproduce the D2D trajectory");
}
