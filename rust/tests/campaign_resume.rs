//! Golden resume equivalence: snapshot-at-round-t → restore → run the
//! rest must be **bit-identical** to the uninterrupted run, for every
//! factory scheme — grad-norm trajectory, evaluated accuracies, telemetry,
//! final weights, and the Eq. 6 power audit. The scenario table includes
//! the fading CSI/blind variants with Rayleigh gains and stragglers, the
//! AR(1) time-correlated gains, and D2D consensus with per-edge Rayleigh
//! gains (per-receiver decodes + the shared broadcast-noise RNG).
//!
//! Snapshots round-trip through their binary encoding on the way back in,
//! so the codec is part of what these tests pin. A second test proves the
//! link-level state blob is thread-pool-size invariant: a snapshot taken
//! from a sequential link restores into a 4-worker link (and vice versa)
//! without perturbing a single bit.

use ota_dsgd::campaign::snapshot::{SnapshotReader, SnapshotWriter, TrainerSnapshot};
use ota_dsgd::config::{presets, FadingDist, ParticipationPolicy, RunConfig, Scheme};
use ota_dsgd::coordinator::{
    D2dAnalogLink, FadingAnalogLink, LinkScheme, RoundCtx, TrainLog, Trainer,
};
use ota_dsgd::model::PARAM_DIM;
use ota_dsgd::tensor::Matf;
use ota_dsgd::util::rng::Pcg64;

/// A fast config: smoke fleet at a quarter of the smoke projection.
fn lean(scheme: Scheme) -> RunConfig {
    RunConfig {
        scheme,
        iterations: 6,
        eval_every: 2,
        channel_uses: PARAM_DIM / 8,
        sparsity: PARAM_DIM / 16,
        ..presets::smoke()
    }
}

/// Every factory scheme, plus the scenario variants the acceptance
/// criteria call out (AR(1) fading, D2D, stragglers, participation).
fn scenario_table() -> Vec<(&'static str, RunConfig)> {
    let fading = RunConfig {
        fading: FadingDist::Rayleigh,
        csi_threshold: 0.2,
        latency_mean_secs: 0.005,
        deadline_secs: 0.02,
        ..lean(Scheme::FadingADsgd)
    };
    vec![
        ("error-free", lean(Scheme::ErrorFree)),
        ("adsgd", lean(Scheme::ADsgd)),
        ("ddsgd", lean(Scheme::DDsgd)),
        (
            "ddsgd-uniform2",
            RunConfig {
                participation: ParticipationPolicy::UniformK(2),
                ..lean(Scheme::DDsgd)
            },
        ),
        ("signsgd", lean(Scheme::SignSgd)),
        ("qsgd", lean(Scheme::Qsgd)),
        ("fading-csi", fading.clone()),
        (
            "fading-blind",
            RunConfig {
                scheme: Scheme::BlindADsgd,
                ..fading.clone()
            },
        ),
        (
            "fading-ar1",
            RunConfig {
                fading_rho: 0.6,
                ..fading
            },
        ),
        (
            "d2d-ring-rayleigh",
            RunConfig {
                iterations: 6,
                eval_every: 2,
                fading: FadingDist::Rayleigh,
                ..presets::d2d_smoke()
            },
        ),
    ]
}

/// Everything in a record except the wall clock must match bit-for-bit.
fn assert_records_identical(a: &TrainLog, b: &TrainLog, name: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{name}: record count");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.iter, rb.iter, "{name} t={}", ra.iter);
        assert_eq!(
            ra.grad_norm.to_bits(),
            rb.grad_norm.to_bits(),
            "{name} t={}: grad norm",
            ra.iter
        );
        assert_eq!(
            ra.test_accuracy.to_bits(),
            rb.test_accuracy.to_bits(),
            "{name} t={}: accuracy",
            ra.iter
        );
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "{name} t={}: loss",
            ra.iter
        );
        assert_eq!(
            ra.accumulator_norm.to_bits(),
            rb.accumulator_norm.to_bits(),
            "{name} t={}: accumulator norm",
            ra.iter
        );
        assert_eq!(
            ra.bits_per_device.to_bits(),
            rb.bits_per_device.to_bits(),
            "{name} t={}: bits",
            ra.iter
        );
        assert_eq!(ra.amp_iterations, rb.amp_iterations, "{name} t={}: amp", ra.iter);
        assert_eq!(
            ra.participation, rb.participation,
            "{name} t={}: participation",
            ra.iter
        );
        assert_eq!(
            ra.consensus_distance.map(f64::to_bits),
            rb.consensus_distance.map(f64::to_bits),
            "{name} t={}: consensus",
            ra.iter
        );
    }
    assert_eq!(
        a.final_accuracy.to_bits(),
        b.final_accuracy.to_bits(),
        "{name}: final accuracy"
    );
    assert_eq!(a.measured_avg_power, b.measured_avg_power, "{name}: Eq. 6 audit");
}

/// The CI resume-smoke gate: snapshot at round 2 (inside the mean-removal
/// phase for the analog family) → resume ≡ six straight rounds, for every
/// scheme in the table.
#[test]
fn resume_equals_uninterrupted() {
    for (name, cfg) in scenario_table() {
        // Uninterrupted run, snapshotting every 2 rounds (snapshots land
        // after rounds 2, 4 and the final 6).
        let mut full_snaps: Vec<TrainerSnapshot> = Vec::new();
        let full_log = Trainer::new(cfg.clone())
            .unwrap()
            .run_with_snapshots(None, 2, &mut |s| full_snaps.push(s.clone()));
        assert_eq!(full_snaps.len(), 3, "{name}: snapshot cadence");
        assert_eq!(full_snaps[0].next_round, 2, "{name}");
        assert_eq!(full_snaps[2].next_round, cfg.iterations, "{name}");

        // Resume from the *encoded* round-2 snapshot (codec under test).
        let restored =
            TrainerSnapshot::decode(&full_snaps[0].encode()).expect("snapshot decode");
        let mut resumed_snaps: Vec<TrainerSnapshot> = Vec::new();
        let resumed_log = Trainer::new(cfg.clone())
            .unwrap()
            .run_with_snapshots(Some(&restored), 2, &mut |s| resumed_snaps.push(s.clone()));

        assert_records_identical(&full_log, &resumed_log, name);
        // Final weights bit-for-bit (via the end-of-run snapshots).
        let final_resumed = resumed_snaps.last().expect("final snapshot");
        assert_eq!(
            full_snaps[2].params, final_resumed.params,
            "{name}: final weights must be bit-identical"
        );
        assert_eq!(full_snaps[2].optim_t, final_resumed.optim_t, "{name}");
        assert_eq!(full_snaps[2].link, final_resumed.link, "{name}: link state");
    }
}

fn grads(m: usize, d: usize, seed: u64) -> Matf {
    let mut rng = Pcg64::new(seed);
    Matf::from_vec(
        m,
        d,
        (0..m * d).map(|_| rng.normal_ms(0.0, 0.1) as f32).collect(),
    )
}

fn ctx(t: usize) -> RoundCtx {
    RoundCtx {
        t,
        p_t: 500.0,
        deadline: None,
    }
}

/// The link-state blob must not depend on the encode fan-out's worker
/// count, and restoring across different pool sizes must stay bit-exact —
/// a snapshot from a laptop resumes on a 64-core box unchanged.
#[test]
fn link_snapshots_are_thread_pool_invariant() {
    let d = 600;
    let m = 6;
    let g = grads(m, d, 11);

    // Fading CSI link over Rayleigh gains.
    let fad_cfg = RunConfig {
        scheme: Scheme::FadingADsgd,
        devices: m,
        channel_uses: 101,
        sparsity: 25,
        mean_removal_rounds: 2,
        amp_iters: 20,
        fading: FadingDist::Rayleigh,
        csi_threshold: 0.2,
        ..presets::smoke()
    };
    let reference: Vec<Vec<f32>> = {
        let mut link = FadingAnalogLink::with_workers(&fad_cfg, d, true, 1);
        (0..6).map(|t| link.round(&ctx(t), &g).ghat).collect()
    };
    for (w_before, w_after) in [(1usize, 4usize), (4, 1)] {
        let mut first = FadingAnalogLink::with_workers(&fad_cfg, d, true, w_before);
        for t in 0..3 {
            assert_eq!(first.round(&ctx(t), &g).ghat, reference[t], "pre t={t}");
        }
        let mut w = SnapshotWriter::new();
        LinkScheme::snapshot(&first, &mut w);
        let blob = w.into_bytes();
        let mut second = FadingAnalogLink::with_workers(&fad_cfg, d, true, w_after);
        second
            .restore(&mut SnapshotReader::new(&blob))
            .expect("fading link restore");
        for t in 3..6 {
            assert_eq!(
                second.round(&ctx(t), &g).ghat,
                reference[t],
                "fading {w_before}→{w_after} t={t}"
            );
        }
    }

    // D2D ring with Rayleigh edge gains (per-replica optimizers + shared
    // broadcast-noise stream ride along in the blob).
    let d2d_cfg = RunConfig {
        scheme: Scheme::D2dADsgd,
        devices: m,
        channel_uses: 101,
        sparsity: 25,
        mean_removal_rounds: 2,
        amp_iters: 15,
        fading: FadingDist::Rayleigh,
        ..presets::smoke()
    };
    let reference: Vec<Vec<f32>> = {
        let mut link = D2dAnalogLink::with_workers(&d2d_cfg, d, 1);
        (0..6).map(|t| link.round(&ctx(t), &g).ghat).collect()
    };
    let mut first = D2dAnalogLink::with_workers(&d2d_cfg, d, 1);
    for t in 0..3 {
        first.round(&ctx(t), &g);
    }
    let mut w = SnapshotWriter::new();
    LinkScheme::snapshot(&first, &mut w);
    let blob = w.into_bytes();
    let mut second = D2dAnalogLink::with_workers(&d2d_cfg, d, 4);
    second
        .restore(&mut SnapshotReader::new(&blob))
        .expect("d2d link restore");
    for t in 3..6 {
        assert_eq!(second.round(&ctx(t), &g).ghat, reference[t], "d2d t={t}");
    }
    // The restored link carries the replicas too, not just ĝ.
    assert_eq!(
        second.replica_average(),
        {
            let mut straight = D2dAnalogLink::with_workers(&d2d_cfg, d, 1);
            for t in 0..6 {
                straight.round(&ctx(t), &g);
            }
            straight.replica_average()
        },
        "replica average after resume"
    );
}

/// Restoring under the wrong config must refuse loudly, not corrupt.
#[test]
#[should_panic(expected = "different RunConfig")]
fn resume_under_a_different_config_is_refused() {
    let cfg = lean(Scheme::ErrorFree);
    let mut snaps = Vec::new();
    Trainer::new(cfg.clone())
        .unwrap()
        .run_with_snapshots(None, 3, &mut |s| snaps.push(s.clone()));
    let other = RunConfig {
        seed: cfg.seed + 1,
        ..cfg
    };
    let _ = Trainer::new(other).unwrap().resume(&snaps[0]);
}
