//! Contract test for the Prometheus text exposition produced by
//! `repro metrics` ([`Metrics::to_prometheus`]), checked with a
//! minimal hand-rolled parser of the text format:
//!
//! * every non-comment line parses as `name{labels} value`;
//! * label values survive the escape round-trip (`\\`, `\"`, `\n`);
//! * no duplicate `(name, label-set)` series in one exposition;
//! * every sample's metric family is declared (`# HELP` + `# TYPE`)
//!   before its first sample, histogram suffixes included;
//! * counter-typed series are monotone under incremental log replay
//!   (reducing ever-longer prefixes of one event stream never makes a
//!   counter go down — the reducer is a pure, deduplicating fold);
//! * histogram buckets are cumulative and consistent with `_count`.
//!
//! The exporter never needs to *emit* escapes — label values are run
//! cache keys (hex) and sanitized worker ids — but the parser handles
//! them so the contract stays honest if that ever changes.

use std::collections::{BTreeMap, BTreeSet};

use ota_dsgd::fleet::events::{Event, EventKind};
use ota_dsgd::fleet::reduce;

/// One parsed sample line.
#[derive(Debug, Clone, PartialEq)]
struct Sample {
    name: String,
    /// Sorted by label name for set comparison.
    labels: Vec<(String, String)>,
    value: f64,
}

fn is_name_char(c: char, first: bool) -> bool {
    c.is_ascii_alphabetic() || c == '_' || c == ':' || (!first && c.is_ascii_digit())
}

/// Unescape a Prometheus label value body (between the quotes).
fn unescape(raw: &str) -> Result<String, String> {
    let mut out = String::with_capacity(raw.len());
    let mut chars = raw.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('\\') => out.push('\\'),
            Some('"') => out.push('"'),
            Some('n') => out.push('\n'),
            other => return Err(format!("bad escape \\{other:?} in label value")),
        }
    }
    Ok(out)
}

/// Re-escape, for the round-trip check.
fn escape(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Parse one sample line: `name` + optional `{k="v",...}` + ` value`.
fn parse_sample(line: &str) -> Result<Sample, String> {
    let err = |msg: &str| format!("{msg}: {line:?}");
    let name_end = line
        .char_indices()
        .find(|&(i, c)| !is_name_char(c, i == 0))
        .map(|(i, _)| i)
        .unwrap_or(line.len());
    if name_end == 0 {
        return Err(err("no metric name"));
    }
    let name = line[..name_end].to_string();
    let rest = &line[name_end..];
    let (labels, rest) = if let Some(body) = rest.strip_prefix('{') {
        let close = body.find('}').ok_or_else(|| err("unclosed label set"))?;
        let mut labels = Vec::new();
        let body_str = &body[..close];
        let mut cursor = body_str;
        while !cursor.is_empty() {
            let eq = cursor.find('=').ok_or_else(|| err("label without ="))?;
            let lname = &cursor[..eq];
            if lname.is_empty() || !lname.chars().enumerate().all(|(i, c)| is_name_char(c, i == 0) && c != ':')
            {
                return Err(err("bad label name"));
            }
            let after = &cursor[eq + 1..];
            let q = after.strip_prefix('"').ok_or_else(|| err("label value not quoted"))?;
            // Find the closing quote, skipping escaped characters.
            let mut end = None;
            let mut chars = q.char_indices();
            while let Some((i, c)) = chars.next() {
                match c {
                    '\\' => {
                        chars.next();
                    }
                    '"' => {
                        end = Some(i);
                        break;
                    }
                    _ => {}
                }
            }
            let end = end.ok_or_else(|| err("unterminated label value"))?;
            labels.push((lname.to_string(), unescape(&q[..end])?));
            cursor = &q[end + 1..];
            cursor = cursor.strip_prefix(',').unwrap_or(cursor);
        }
        labels.sort();
        (labels, &body[close + 1..])
    } else {
        (Vec::new(), rest)
    };
    let value: f64 = rest
        .trim()
        .parse()
        .map_err(|_| err("unparseable sample value"))?;
    Ok(Sample { name, labels, value })
}

/// Parse a whole exposition; returns samples in order plus the
/// `# TYPE` declarations (family name -> type) in declaration order.
fn parse_exposition(text: &str) -> (Vec<Sample>, Vec<(String, String)>) {
    let mut samples = Vec::new();
    let mut types = Vec::new();
    let mut helped: BTreeSet<String> = BTreeSet::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().unwrap_or("").to_string();
            assert!(!name.is_empty(), "HELP without a metric name: {line:?}");
            helped.insert(name);
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().expect("TYPE without name").to_string();
            let ty = it.next().expect("TYPE without kind").to_string();
            assert!(
                ["counter", "gauge", "histogram", "summary", "untyped"].contains(&ty.as_str()),
                "unknown TYPE {ty:?}"
            );
            assert!(
                helped.contains(&name),
                "# TYPE {name} not preceded by its # HELP"
            );
            types.push((name, ty));
            continue;
        }
        assert!(!line.starts_with('#'), "unknown comment form: {line:?}");
        samples.push(parse_sample(line).unwrap_or_else(|e| panic!("{e}")));
    }
    (samples, types)
}

/// The metric *family* a sample belongs to: histogram samples use the
/// `_bucket` / `_sum` / `_count` suffix convention.
fn family<'a>(sample: &'a Sample, types: &'a [(String, String)]) -> &'a str {
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = sample.name.strip_suffix(suffix) {
            if types.iter().any(|(n, t)| n == base && t == "histogram") {
                return base;
            }
        }
    }
    &sample.name
}

/// A synthetic but realistic event stream: two runs (one with link
/// diagnostics), two workers, a reclaim, duplicate rounds from the
/// steal, and device probes.
fn stream() -> Vec<Event> {
    fn ev(
        kind: EventKind,
        key: &str,
        worker: &str,
        round: Option<u64>,
        data: &[(&str, f64)],
    ) -> Event {
        Event {
            kind,
            key: key.into(),
            label: String::new(),
            worker: worker.into(),
            round,
            unix_ms: 0,
            data: data.iter().map(|&(k, v)| (k.into(), v)).collect(),
        }
    }
    let mut s = vec![
        ev(EventKind::Enqueued, "k1", "coord", None, &[("iterations", 4.0)]),
        ev(EventKind::Enqueued, "k2", "coord", None, &[("iterations", 2.0)]),
        ev(EventKind::Claimed, "k1", "w0", None, &[]),
        ev(EventKind::Executed, "k1", "w0", None, &[]),
    ];
    for t in 0..4u64 {
        s.push(ev(
            EventKind::Round,
            "k1",
            "w0",
            Some(t),
            &[
                ("grad_norm", 4.0 - t as f64),
                ("snr_db", 8.0 + t as f64),
                ("power_headroom", 0.5),
                ("participating", 10.0),
                ("consensus_distance", 1.0 / (t + 1) as f64),
            ],
        ));
        s.push(ev(
            EventKind::Device,
            "k1",
            "w0",
            Some(t),
            &[("device", 0.0), ("outcome", 0.0), ("tx_energy", 500.0)],
        ));
    }
    s.extend([
        ev(EventKind::Heartbeat, "k1", "w0", None, &[]),
        ev(EventKind::Snapshot, "k1", "w0", Some(2), &[]),
        // w1 steals the stale lease and re-emits a round + device point.
        ev(EventKind::Reclaimed, "k1", "w1", None, &[]),
        ev(EventKind::Round, "k1", "w1", Some(3), &[("grad_norm", 1.0), ("snr_db", 11.0)]),
        ev(
            EventKind::Device,
            "k1",
            "w1",
            Some(3),
            &[("device", 0.0), ("outcome", 0.0), ("tx_energy", 500.0)],
        ),
        ev(EventKind::Completed, "k1", "w1", None, &[
            ("final_accuracy", 0.9),
            ("pbar", 4.0),
            ("max_avg_power", 3.0),
        ]),
        // k2 never probes: exercises the mixed probe/no-probe export.
        ev(EventKind::Claimed, "k2", "w1", None, &[]),
        ev(EventKind::Executed, "k2", "w1", None, &[]),
        ev(EventKind::Round, "k2", "w1", Some(0), &[("grad_norm", 2.0)]),
        ev(EventKind::Round, "k2", "w1", Some(1), &[("grad_norm", 1.8)]),
        ev(EventKind::Completed, "k2", "w1", None, &[("final_accuracy", 0.7)]),
    ]);
    s
}

/// Every line of the exposition parses; no duplicate series; every
/// sample's family is declared before its first sample.
#[test]
fn prom_text_parses_with_no_duplicate_series() {
    let text = reduce(&stream()).to_prometheus();
    let (samples, types) = parse_exposition(&text);
    assert!(
        samples.iter().any(|s| s.name == "ota_link_snr_db_bucket"),
        "stream with probes must export the SNR histogram"
    );

    // Unique (name, labelset).
    let mut seen: BTreeSet<(String, Vec<(String, String)>)> = BTreeSet::new();
    for s in &samples {
        assert!(
            seen.insert((s.name.clone(), s.labels.clone())),
            "duplicate series {} {:?}",
            s.name,
            s.labels
        );
    }

    // Families declared exactly once, before first use.
    let mut declared: BTreeSet<&str> = BTreeSet::new();
    for (n, _) in &types {
        assert!(declared.insert(n), "family {n} declared twice");
    }
    let order: Vec<&str> = types.iter().map(|(n, _)| n.as_str()).collect();
    let mut first_sample: BTreeMap<&str, usize> = BTreeMap::new();
    for (i, s) in samples.iter().enumerate() {
        first_sample.entry(family(s, &types)).or_insert(i);
    }
    for (fam, _) in &first_sample {
        assert!(
            order.contains(fam),
            "sample family {fam} has no # TYPE declaration"
        );
    }

    // Values are finite numbers (no NaN/Inf leaks into the export).
    for s in &samples {
        assert!(s.value.is_finite(), "non-finite value in {}", s.name);
    }
}

/// Label values round-trip the escape rules, and the parser itself
/// handles escaped values the exporter does not currently need.
#[test]
fn prom_label_values_escape_roundtrip() {
    let text = reduce(&stream()).to_prometheus();
    let (samples, _) = parse_exposition(&text);
    let mut labeled = 0;
    for s in &samples {
        for (k, v) in &s.labels {
            labeled += 1;
            // Round-trip: re-escaping the parsed value reproduces a
            // valid body, and the raw text contained that body.
            assert!(text.contains(&format!("{k}=\"{}\"", escape(v))));
            assert!(!v.contains('\n'), "raw newline in label value");
        }
    }
    assert!(labeled > 0, "exposition must carry labeled series");

    // The parser handles escapes (future-proofing the contract).
    let s = parse_sample(r#"x_total{a="q\"uo\\te",b="line\nbreak"} 7"#).unwrap();
    assert_eq!(s.labels[0].1, "q\"uo\\te");
    assert_eq!(s.labels[1].1, "line\nbreak");
    assert_eq!(s.value, 7.0);
    // And rejects malformed lines rather than guessing.
    assert!(parse_sample("x_total{a=unquoted} 1").is_err());
    assert!(parse_sample("x_total{a=\"open} 1").is_err());
    assert!(parse_sample("{} 1").is_err());
    assert!(parse_sample("x_total nope").is_err());
}

/// Counters never decrease as the event log grows: reduce every
/// prefix of one stream and compare counter samples pairwise.
#[test]
fn prom_counters_monotone_under_replay() {
    let events = stream();
    let mut prev: BTreeMap<(String, Vec<(String, String)>), f64> = BTreeMap::new();
    for n in 0..=events.len() {
        let text = reduce(&events[..n]).to_prometheus();
        let (samples, types) = parse_exposition(&text);
        let counters: BTreeSet<&str> = types
            .iter()
            .filter(|(_, t)| t == "counter")
            .map(|(n, _)| n.as_str())
            .collect();
        let mut cur = BTreeMap::new();
        for s in &samples {
            if !counters.contains(s.name.as_str()) {
                continue;
            }
            if let Some(&old) = prev.get(&(s.name.clone(), s.labels.clone())) {
                assert!(
                    s.value >= old,
                    "counter {} {:?} went backwards: {} -> {} at prefix {}",
                    s.name,
                    s.labels,
                    old,
                    s.value,
                    n
                );
            }
            cur.insert((s.name.clone(), s.labels.clone()), s.value);
        }
        // A counter series, once exported, never disappears.
        for key in prev.keys() {
            assert!(cur.contains_key(key), "counter series {key:?} vanished at prefix {n}");
        }
        prev = cur;
    }
    assert!(
        prev.keys().any(|(n, _)| n == "ota_link_device_events_total"),
        "full stream must export the device-event counter"
    );
}

/// Histogram samples are internally consistent: buckets cumulative in
/// `le`, `+Inf` bucket equals `_count`, `_sum` matches the series.
#[test]
fn prom_histogram_buckets_are_cumulative() {
    let text = reduce(&stream()).to_prometheus();
    let (samples, _) = parse_exposition(&text);
    let key_of = |s: &Sample| {
        s.labels
            .iter()
            .find(|(k, _)| k == "key")
            .map(|(_, v)| v.clone())
            .expect("histogram sample without key label")
    };
    let mut buckets: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
    let mut counts: BTreeMap<String, f64> = BTreeMap::new();
    let mut sums: BTreeMap<String, f64> = BTreeMap::new();
    for s in &samples {
        match s.name.as_str() {
            "ota_link_snr_db_bucket" => {
                let le = s
                    .labels
                    .iter()
                    .find(|(k, _)| k == "le")
                    .map(|(_, v)| v.clone())
                    .expect("bucket without le");
                let le = if le == "+Inf" { f64::INFINITY } else { le.parse().unwrap() };
                buckets.entry(key_of(s)).or_default().push((le, s.value));
            }
            "ota_link_snr_db_count" => {
                counts.insert(key_of(s), s.value);
            }
            "ota_link_snr_db_sum" => {
                sums.insert(key_of(s), s.value);
            }
            _ => {}
        }
    }
    assert!(!buckets.is_empty(), "probed stream must export SNR buckets");
    for (key, mut series) in buckets {
        series.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for pair in series.windows(2) {
            assert!(
                pair[1].1 >= pair[0].1,
                "buckets not cumulative for {key}: {pair:?}"
            );
        }
        let (last_le, last_n) = *series.last().unwrap();
        assert_eq!(last_le, f64::INFINITY, "missing +Inf bucket for {key}");
        assert_eq!(Some(&last_n), counts.get(&key), "+Inf bucket != _count for {key}");
        assert!(sums.contains_key(&key), "histogram {key} missing _sum");
    }
    // The stream's k1 saw SNR 8,9,10,11 dB over 4 probed rounds.
    assert_eq!(counts.values().copied().sum::<f64>(), 4.0);
}
