//! End-to-end training assertions: small-scale versions of the paper's
//! headline qualitative claims. These are the repo's regression net for
//! "does the reproduction still reproduce".

use ota_dsgd::config::{presets, DatasetSpec, RunConfig, Scheme};
use ota_dsgd::coordinator::Trainer;

fn e2e_cfg(scheme: Scheme) -> RunConfig {
    RunConfig {
        scheme,
        devices: 8,
        local_samples: 150,
        channel_uses: presets::MODEL_DIM / 4,
        sparsity: presets::MODEL_DIM / 10,
        pbar: 500.0,
        iterations: 16,
        eval_every: 4,
        mean_removal_rounds: 3,
        dataset: DatasetSpec::Synthetic {
            train: 1_500,
            test: 800,
        },
        ..RunConfig::default()
    }
}

fn best(scheme: Scheme) -> f64 {
    Trainer::new(e2e_cfg(scheme)).unwrap().run().best_accuracy()
}

/// Everyone learns: all five schemes end well above chance on the smoke
/// workload.
#[test]
fn all_schemes_beat_chance() {
    for scheme in [
        Scheme::ErrorFree,
        Scheme::ADsgd,
        Scheme::DDsgd,
        Scheme::SignSgd,
        Scheme::Qsgd,
    ] {
        let acc = best(scheme);
        assert!(acc > 0.3, "{scheme:?}: accuracy {acc}");
    }
}

/// Paper headline (Fig. 2): the error-free bound dominates, and A-DSGD
/// tracks it. At this smoke scale the first rounds are dominated by the
/// sparsification loss on dense early gradients (top-k of a dense vector
/// keeps ≈ √(k/d) of the energy); error accumulation recovers the rest
/// over iterations — so we check the gap at a horizon long enough for the
/// mechanism to engage, not at t=0.
#[test]
fn adsgd_close_to_error_free() {
    let mut ef_cfg = e2e_cfg(Scheme::ErrorFree);
    ef_cfg.iterations = 30;
    let mut a_cfg = e2e_cfg(Scheme::ADsgd);
    a_cfg.iterations = 30;
    let ef = Trainer::new(ef_cfg).unwrap().run().best_accuracy();
    let analog = Trainer::new(a_cfg).unwrap().run().best_accuracy();
    assert!(ef >= analog - 0.05, "error-free {ef} vs A-DSGD {analog}");
    assert!(
        analog > 0.55 && analog > ef - 0.4,
        "A-DSGD should track the error-free bound: {analog} vs {ef}"
    );
}

/// Paper headline (Fig. 6): at P̄ = 1 the digital budget is zero bits —
/// D-DSGD cannot transmit anything and stays at chance, while A-DSGD still
/// learns.
#[test]
fn low_power_kills_digital_but_not_analog() {
    let mut d_cfg = e2e_cfg(Scheme::DDsgd);
    d_cfg.pbar = 1.0;
    let d_log = Trainer::new(d_cfg).unwrap().run();
    // Budget of R_t bits must not admit even one SBC entry.
    assert!(
        d_log.records.iter().all(|r| r.bits_per_device
            < ota_dsgd::compress::sbc::SbcCompressor::bit_cost(presets::MODEL_DIM, 1)),
        "digital should be silent at P̄=1"
    );
    assert!(
        d_log.best_accuracy() < 0.3,
        "D-DSGD at P̄=1 should stay near chance, got {}",
        d_log.best_accuracy()
    );

    let mut a_cfg = e2e_cfg(Scheme::ADsgd);
    a_cfg.pbar = 1.0;
    a_cfg.mean_removal_rounds = 0;
    a_cfg.iterations = 24;
    let a_acc = Trainer::new(a_cfg).unwrap().run().best_accuracy();
    assert!(
        a_acc > 0.3,
        "A-DSGD should still learn at P̄=1 (got {a_acc})"
    );
}

/// Paper claim (§VI): A-DSGD is robust to non-IID bias — its degradation is
/// bounded — while digital compression suffers more.
#[test]
fn noniid_degradation_bounded_for_analog() {
    let iid = best(Scheme::ADsgd);
    let mut cfg = e2e_cfg(Scheme::ADsgd);
    cfg.noniid = true;
    let biased = Trainer::new(cfg).unwrap().run().best_accuracy();
    assert!(
        biased > iid - 0.2,
        "A-DSGD non-IID degradation too large: {iid} → {biased}"
    );
    assert!(biased > 0.3, "A-DSGD non-IID should still learn: {biased}");
}

/// Eq. 6 audit holds for every scheme end to end.
#[test]
fn power_constraint_all_schemes() {
    for scheme in [Scheme::ADsgd, Scheme::DDsgd, Scheme::SignSgd, Scheme::Qsgd] {
        let log = Trainer::new(e2e_cfg(scheme)).unwrap().run();
        assert!(
            log.power_constraint_ok(1e-6),
            "{scheme:?}: {:?} vs P̄ {}",
            log.measured_avg_power,
            log.pbar
        );
    }
}
