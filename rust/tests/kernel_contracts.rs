//! Kernel exactness contracts (see PERF.md §Kernel table).
//!
//! Every hot-path kernel is either **bit-identical** to its seed
//! formulation (same floating-point operation order, so golden
//! trajectories and campaign-resume snapshots are byte-stable) or
//! **tolerance-gated** against an f64 oracle (f32 reductions whose
//! rounding is documented, not accidental). This suite pins each kernel to
//! its contract at tiny shapes (tails, block boundaries) and at the
//! paper's d = 7850.
//!
//! Bit-identical: topk/sparsify, soft_threshold(+count), transpose, axpy,
//! axpy4 (≡ 4 sequential axpys), projection generate (any worker count),
//! apply_sparse, A-DSGD transmit, AMP recover, minibatch gradient.
//! Tolerance-gated vs f64: dot, gemv, gemv_t, gemm, norm.

use ota_dsgd::amp::{self, AmpConfig};
use ota_dsgd::analog::projection::{transpose_with_workers, Projection};
use ota_dsgd::analog::AnalogDevice;
use ota_dsgd::data::synthetic;
use ota_dsgd::model;
use ota_dsgd::tensor::{self, reference, Matf};
use ota_dsgd::util::rng::Pcg64;

/// Paper dimension d = 7850; s̃ is cut from 3924 to keep the debug-mode
/// test budget sane while still exercising paper-length rows.
const PAPER_D: usize = model::PARAM_DIM;
const PAPER_S: usize = 491;

fn randv(n: usize, rng: &mut Pcg64) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// Cheap deterministic fill for paper-shaped matrices (no Box–Muller —
/// 30M normals in debug mode would dominate the suite's runtime).
fn patterned(n: usize, salt: u32) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let h = (i as u32).wrapping_add(salt).wrapping_mul(2_654_435_761);
            (h % 2000) as f32 * 1e-3 - 1.0
        })
        .collect()
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}: element {i} ({g} vs {w})");
    }
}

// ---------------------------------------------------------------------------
// Bit-identical kernels
// ---------------------------------------------------------------------------

#[test]
fn bit_exact_topk_and_sparsify_vs_sort() {
    let mut rng = Pcg64::new(1);
    for &n in &[1usize, 7, 64, 501] {
        let x = randv(n, &mut rng);
        for &k in &[0usize, 1, n / 3, n] {
            let got = tensor::topk_indices(&x, k);
            let want = reference::topk_indices_sort(&x, k);
            assert_eq!(got, want, "topk n={n} k={k}");
            let sp = tensor::sparsify_topk(&x, k);
            for (i, &v) in sp.iter().enumerate() {
                let expect = if want.contains(&i) { x[i] } else { 0.0 };
                assert_eq!(v.to_bits(), expect.to_bits(), "sparsify n={n} k={k} i={i}");
            }
        }
    }
    // Duplicate magnitudes: ties must resolve to the lowest indices.
    let dup = vec![2.0f32; 9];
    assert_eq!(tensor::topk_indices(&dup, 4), vec![0, 1, 2, 3]);
}

#[test]
fn bit_exact_soft_threshold_including_zero_sign() {
    let mut rng = Pcg64::new(2);
    for &n in &[5usize, 80, PAPER_D] {
        let mut x = randv(n, &mut rng);
        x[0] = 0.0;
        if n > 1 {
            x[1] = -0.0;
        }
        let mut a = x.clone();
        let mut b = x.clone();
        let tau = 0.6f32;
        tensor::soft_threshold(&mut a, tau);
        let nnz = tensor::soft_threshold_count(&mut b, tau);
        // Reference: the seed expression, element by element.
        let mut want = x;
        for v in want.iter_mut() {
            let m = v.abs() - tau;
            *v = if m > 0.0 { m * v.signum() } else { 0.0 };
        }
        assert_bits_eq(&a, &want, "soft_threshold");
        assert_bits_eq(&b, &want, "soft_threshold_count values");
        assert_eq!(nnz, want.iter().filter(|&&v| v != 0.0).count());
    }
}

#[test]
fn bit_exact_axpy_family() {
    let mut rng = Pcg64::new(3);
    for &n in &[1usize, 8, 13, 784, PAPER_D] {
        let xs: Vec<Vec<f32>> = (0..4).map(|_| randv(n, &mut rng)).collect();
        let y0 = randv(n, &mut rng);
        let a = [0.75f32, -0.3, 1.5, -2.25];
        // axpy == scalar seed loop.
        let mut got = y0.clone();
        tensor::axpy(a[0], &xs[0], &mut got);
        let mut want = y0.clone();
        reference::axpy_scalar(a[0], &xs[0], &mut want);
        assert_bits_eq(&got, &want, "axpy");
        // axpy4 == four sequential axpys.
        let mut fused = y0.clone();
        tensor::axpy4(a, &xs[0], &xs[1], &xs[2], &xs[3], &mut fused);
        let mut seq = y0.clone();
        for l in 0..4 {
            reference::axpy_scalar(a[l], &xs[l], &mut seq);
        }
        assert_bits_eq(&fused, &seq, "axpy4");
    }
}

#[test]
fn bit_exact_transpose_any_workers() {
    let mut rng = Pcg64::new(4);
    for &(r, c) in &[(1usize, 1usize), (5, 3), (64, 65), (129, 64), (200, 131)] {
        let a = Matf::from_vec(r, c, randv(r * c, &mut rng));
        let naive = reference::transpose_naive(&a);
        for workers in [1usize, 2, 5] {
            let t = transpose_with_workers(&a, workers);
            assert_eq!((t.rows, t.cols), (c, r));
            assert_bits_eq(&t.data, &naive.data, "transpose");
        }
    }
}

#[test]
fn bit_exact_projection_generate_worker_invariant() {
    let seq = Projection::generate_with_workers(37, 120, 5, 1);
    for workers in [2usize, 4, 9] {
        let par = Projection::generate_with_workers(37, 120, 5, workers);
        assert_bits_eq(&par.matrix.data, &seq.matrix.data, "generate matrix");
        assert_bits_eq(&par.matrix_t.data, &seq.matrix_t.data, "generate matrix_t");
    }
}

#[test]
fn bit_exact_apply_sparse_vs_sequential_axpys() {
    let proj = Projection::generate(53, PAPER_D, 7);
    let mut rng = Pcg64::new(5);
    let g = randv(PAPER_D, &mut rng);
    for &k in &[1usize, 4, 7, 32, 101] {
        let mut g_sp = g.clone();
        let support = tensor::sparsify_topk_inplace(&mut g_sp, k);
        let got = proj.apply_sparse(&g_sp, &support);
        let mut want = vec![0f32; proj.s_tilde()];
        for &j in &support {
            reference::axpy_scalar(g_sp[j], proj.matrix_t.row(j), &mut want);
        }
        assert_bits_eq(&got, &want, &format!("apply_sparse k={k}"));
    }
}

#[test]
fn bit_exact_transmit_fused_vs_reference() {
    // Two fresh devices (each transmit mutates the error accumulator) fed
    // identical gradients over several rounds: frames must match bitwise,
    // and so must the carried accumulator state.
    let (d, k, s_tilde) = (900, 120, 449);
    let proj = Projection::generate(s_tilde, d, 11);
    let mut dev_fused = AnalogDevice::new(d, k);
    let mut dev_ref = AnalogDevice::new(d, k);
    let mut rng = Pcg64::new(6);
    for round in 0..3 {
        let g = randv(d, &mut rng);
        let f = dev_fused.transmit(&g, &proj, 500.0);
        let r = dev_ref.transmit_reference(&g, &proj, 500.0);
        assert_eq!(f.x.len(), r.x.len());
        assert_bits_eq(&f.x, &r.x, "transmit frame");
        assert_eq!(
            f.sqrt_alpha.to_bits(),
            r.sqrt_alpha.to_bits(),
            "sqrt_alpha round {round}"
        );
        assert_bits_eq(
            dev_fused.accumulator(),
            dev_ref.accumulator(),
            "error accumulator",
        );
    }
}

#[test]
fn bit_exact_amp_recover_fused_vs_reference() {
    let (s, d, k) = (201, 403, 30);
    let a = amp::measurement_matrix(s, d, 13);
    let at = transpose_with_workers(&a, 2);
    let mut rng = Pcg64::new(7);
    let mut x = vec![0f32; d];
    for i in rng.sample_indices(d, k) {
        x[i] = rng.normal() as f32;
    }
    let mut y = vec![0f32; s];
    tensor::gemv(&a, &x, &mut y);
    for v in y.iter_mut() {
        *v += rng.normal_ms(0.0, 0.03) as f32;
    }
    for cfg in [
        AmpConfig::default(),
        AmpConfig {
            max_iters: 50,
            tol: 1e-8,
            threshold_mult: 1.2,
        },
    ] {
        let (xf, tf) = amp::recover_with(&a, Some(&at), &y, &cfg);
        let (xr, tr) = amp::recover_with_reference(&a, Some(&at), &y, &cfg);
        assert_bits_eq(&xf, &xr, "amp x");
        assert_eq!(tf.iterations, tr.iterations);
        assert_eq!(tf.converged, tr.converged);
        assert_eq!(tf.tau.len(), tr.tau.len());
        for (f, r) in tf.tau.iter().zip(&tr.tau) {
            assert_eq!(f.to_bits(), r.to_bits(), "amp tau");
        }
    }
}

#[test]
fn bit_exact_minibatch_gradient_tiled_vs_reference() {
    let ds = synthetic::generate(100, 15, 0);
    let mut rng = Pcg64::new(8);
    let params: Vec<f32> = (0..model::PARAM_DIM)
        .map(|_| rng.normal() as f32 * 0.01)
        .collect();
    for &n in &[1usize, 31, 32, 33, 100] {
        let idx: Vec<usize> = (0..n).collect();
        let mut gt = vec![0f32; model::PARAM_DIM];
        let mut gr = vec![0f32; model::PARAM_DIM];
        let lt = model::gradient(&params, &ds, &idx, &mut gt);
        let lr = model::gradient_reference(&params, &ds, &idx, &mut gr);
        assert_eq!(lt.to_bits(), lr.to_bits(), "loss at B={n}");
        assert_bits_eq(&gt, &gr, "gradient");
    }
}

// ---------------------------------------------------------------------------
// Tolerance-gated kernels (f32 reductions vs f64 oracles)
// ---------------------------------------------------------------------------

/// Relative bound for an n-term f32 reduction: c·n·ε with headroom.
fn red_tol(n: usize) -> f64 {
    8.0 * n as f64 * f32::EPSILON as f64
}

#[test]
fn tolerance_dot_vs_f64_tiny_and_paper() {
    let mut rng = Pcg64::new(9);
    for &n in &[1usize, 9, 100, PAPER_D] {
        let x = randv(n, &mut rng);
        let y = randv(n, &mut rng);
        let got = tensor::dot(&x, &y) as f64;
        let want = reference::dot_f64(&x, &y);
        let mag = reference::abs_dot_f64(&x, &y).max(1e-12);
        assert!(
            (got - want).abs() <= red_tol(n) * mag,
            "dot n={n}: {got} vs {want}"
        );
    }
}

#[test]
fn tolerance_gemv_pair_vs_f64_paper_shape() {
    let a = Matf::from_vec(PAPER_S, PAPER_D, patterned(PAPER_S * PAPER_D, 1));
    let mut rng = Pcg64::new(10);
    let x = randv(PAPER_D, &mut rng);
    let mut out = vec![0f32; PAPER_S];
    tensor::gemv(&a, &x, &mut out);
    let want = reference::gemv_f64(&a, &x);
    for (r, (&g, &w)) in out.iter().zip(&want).enumerate() {
        assert!(
            (g as f64 - w).abs() <= red_tol(PAPER_D) * w.abs().max(1.0),
            "gemv row {r}: {g} vs {w}"
        );
    }
    let r_in = randv(PAPER_S, &mut rng);
    let mut out_t = vec![0f32; PAPER_D];
    tensor::gemv_t(&a, &r_in, &mut out_t);
    let want_t = reference::gemv_t_f64(&a, &r_in);
    for (c, (&g, &w)) in out_t.iter().zip(&want_t).enumerate() {
        assert!(
            (g as f64 - w).abs() <= red_tol(PAPER_S) * w.abs().max(1.0),
            "gemv_t col {c}: {g} vs {w}"
        );
    }
}

#[test]
fn tolerance_gemm_vs_f64() {
    let mut rng = Pcg64::new(11);
    let (m, kk, n) = (17, 130, 9);
    let a = Matf::from_vec(m, kk, randv(m * kk, &mut rng));
    let b = Matf::from_vec(kk, n, randv(kk * n, &mut rng));
    let c = tensor::gemm(&a, &b);
    let want = reference::gemm_f64(&a, &b);
    for i in 0..c.data.len() {
        assert!(
            (c.data[i] as f64 - want[i]).abs() <= red_tol(kk) * want[i].abs().max(1.0),
            "gemm idx {i}: {} vs {}",
            c.data[i],
            want[i]
        );
    }
}

#[test]
fn tolerance_norm_vs_f64() {
    let mut rng = Pcg64::new(12);
    for &n in &[3usize, 100, PAPER_D] {
        let x = randv(n, &mut rng);
        let got = tensor::norm_sq(&x);
        let want: f64 = x.iter().map(|&v| (v as f64) * (v as f64)).sum();
        // norm_sq accumulates in f64 already; only f32→f64 squaring order
        // could differ, and it doesn't — this pins the f64 contract.
        assert_eq!(got.to_bits(), want.to_bits(), "norm_sq n={n}");
    }
}
