//! Fleet tracing, end to end (the CI trace-smoke suite — every test is
//! `trace_`-prefixed so the main Test step skips it):
//!
//! * a SIGKILL'd worker's already-flushed spans survive and merge — the
//!   kill can at worst tear the victim's *own* trailing line, which
//!   readers skip (counted, never fatal);
//! * a 4-worker fleet's merged trace reconstructs every run's
//!   claim → execute → complete chain exactly once;
//! * `summary.csv` is byte-identical with tracing on vs off (spans are
//!   pure wall-clock, outside the deterministic core);
//! * `repro trace --connect` renders byte-identically to the local
//!   store read — same spans, same report, same Chrome JSON.

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::time::Duration;

use ota_dsgd::campaign::{manifest::RunStatus, RunManifest, RunStore};
use ota_dsgd::config::{presets, CampaignConfig, FleetConfig, RunConfig, Scheme};
use ota_dsgd::experiments::runner::ExperimentSpec;
use ota_dsgd::fleet;
use ota_dsgd::model::PARAM_DIM;

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn read(path: &Path) -> Vec<u8> {
    std::fs::read(path).unwrap_or_else(|e| panic!("read {path:?}: {e}"))
}

fn lean(scheme: Scheme) -> RunConfig {
    RunConfig {
        scheme,
        iterations: 4,
        eval_every: 2,
        channel_uses: PARAM_DIM / 8,
        sparsity: PARAM_DIM / 16,
        ..presets::smoke()
    }
}

fn spec(id: &str) -> ExperimentSpec {
    ExperimentSpec {
        id: id.into(),
        title: format!("fleet tracing {id}"),
        runs: vec![
            ("error-free".into(), lean(Scheme::ErrorFree)),
            ("signsgd".into(), lean(Scheme::SignSgd)),
            ("qsgd".into(), lean(Scheme::Qsgd)),
        ],
    }
}

fn traced_campaign(store_dir: &str) -> CampaignConfig {
    let mut c = CampaignConfig {
        snapshot_every: 1,
        store_dir: store_dir.to_string(),
        ..CampaignConfig::default()
    };
    c.telemetry.trace = true;
    c
}

/// Spans for `key` named `name`, in merge order.
fn of<'a>(spans: &'a [fleet::Span], key: &str, name: &str) -> Vec<&'a fleet::Span> {
    spans.iter().filter(|s| s.key == key && s.name == name).collect()
}

/// Enqueue with a trace attached (so `enqueue` marks anchor queue-wait),
/// returning the run keys.
fn enqueue_traced(store_dir: &str, sp: &ExperimentSpec) -> Vec<String> {
    let store = RunStore::open(store_dir).unwrap();
    let log = fleet::TraceLog::open(store.root(), "enqueuer").unwrap();
    store.attach_trace(log);
    fleet::enqueue_specs(&store, std::slice::from_ref(sp))
        .unwrap()
        .into_iter()
        .map(|i| i.key)
        .collect()
}

/// The acceptance gate for crash safety: SIGKILL a real `repro worker
/// --trace` mid-run. Its flushed spans must survive and merge; the
/// survivor's resume completes the chain; an injected torn tail is
/// skipped, not fatal.
#[test]
fn trace_sigkill_worker_spans_survive_and_merge() {
    let base = fresh_dir("ota_trace_sigkill_test");
    let cfg = RunConfig {
        iterations: 400,
        eval_every: 100,
        ..lean(Scheme::ErrorFree)
    };
    let sp = ExperimentSpec {
        id: "tkill".into(),
        title: "trace sigkill".into(),
        runs: vec![("error-free".into(), cfg.clone())],
    };
    let store_dir = base.join("store").to_str().unwrap().to_string();
    let keys = enqueue_traced(&store_dir, &sp);
    let key = keys[0].clone();
    let store = RunStore::open(&store_dir).unwrap();

    // A real worker process with tracing on, snapshotting every round.
    // (`--trace` sits directly before another `--` token: the CLI parser
    // would otherwise consume a following bare word as its value.)
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["worker", "--store-dir", store_dir.as_str()])
        .args(["--lease-secs", "2", "--heartbeat-secs", "0.5"])
        .args(["--snapshot-every", "1", "--worker-id", "victim"])
        .args(["--trace", "--quiet"])
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn repro worker");

    let manifest_path = store.root().join(&key).join("manifest.toml");
    let mut progressed = false;
    for _ in 0..3000 {
        if let Ok(m) = RunManifest::read(&manifest_path) {
            if m.status == RunStatus::Partial && m.snapshot_round >= 3 {
                progressed = true;
                break;
            }
            if m.status == RunStatus::Complete {
                break;
            }
        }
        if child.try_wait().expect("try_wait").is_some() {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    child.kill().ok();
    child.wait().ok();
    assert!(
        progressed,
        "worker must reach a mid-run snapshot before the kill (machine too slow or worker died early?)"
    );

    // The victim's flushed spans are already durable: its lease_acquire
    // and per-round snapshot_save scopes landed line-by-line. The kill
    // can at worst tear its own trailing line (counted, not fatal).
    let rep = fleet::read_spans(store.root());
    assert_eq!(
        rep.unreadable_files, 0,
        "every span segment must still open after a SIGKILL"
    );
    let victim: Vec<_> = rep.spans.iter().filter(|s| s.worker == "victim").collect();
    assert!(
        victim.iter().any(|s| s.name == "lease_acquire" && s.key == key),
        "the victim's lease_acquire span must have been flushed: {victim:?}"
    );
    assert!(
        victim.iter().any(|s| s.name == "snapshot_save" && s.key == key),
        "at least one snapshot_save span must have been flushed before the kill"
    );
    assert!(
        !rep.spans.iter().any(|s| s.name == "execute" && s.worker == "victim"),
        "the victim died inside its execute scope, so that span never flushed"
    );

    // A surviving in-process worker (tracing on) reclaims and resumes.
    let fleet_cfg = FleetConfig {
        workers: 1,
        lease_secs: 2.0,
        heartbeat_secs: 0.5,
    };
    let campaign = traced_campaign(&store_dir);
    let report = fleet::run_worker(&store_dir, &fleet_cfg, &campaign, "survivor", false).unwrap();
    assert_eq!((report.executed, report.resumed), (0, 1));

    // The merged trace now completes the chain: the survivor's resume
    // marker, execute span and complete marker all carry the same key.
    let rep = fleet::read_spans(store.root());
    assert_eq!(rep.unreadable_files, 0);
    let resumes = of(&rep.spans, &key, "resume");
    assert_eq!(resumes.len(), 1, "exactly one resume marker");
    assert_eq!(resumes[0].worker, "survivor");
    assert!(
        resumes[0].round.is_some_and(|r| r >= 3),
        "the resume marker must carry the snapshot round it restored: {resumes:?}"
    );
    let execs = of(&rep.spans, &key, "execute");
    assert_eq!(execs.len(), 1, "exactly one completed execute span");
    assert_eq!(execs[0].worker, "survivor");
    assert!(execs[0].dur_us > 0);
    assert_eq!(of(&rep.spans, &key, "complete").len(), 1);
    let parsed_before = rep.spans.len();
    let skipped_before = rep.skipped_lines;

    // Inject a garbage line plus a torn tail into the victim's segment:
    // the reader must skip both (counted), keep every parsed span, and
    // never flag the file unreadable. (`>` not an exact count: if the
    // SIGKILL itself tore the victim's last line, the injected garbage
    // concatenates onto it and the two merge into one skipped line.)
    let segment = fleet::trace_dir(store.root()).join("victim.jsonl");
    let mut fh = std::fs::OpenOptions::new().append(true).open(&segment).unwrap();
    fh.write_all(b"this is not a span\n").unwrap();
    fh.write_all(b"{\"v\":1,\"name\":\"execute\",\"us\":12,\"dur").unwrap();
    drop(fh);
    let rep = fleet::read_spans(store.root());
    assert_eq!(rep.unreadable_files, 0, "a torn tail is not an unreadable file");
    assert_eq!(rep.spans.len(), parsed_before, "torn tail must not drop parsed spans");
    assert!(
        rep.skipped_lines > skipped_before,
        "garbage + torn tail must be counted as skipped ({} -> {})",
        skipped_before,
        rep.skipped_lines
    );
    std::fs::remove_dir_all(&base).ok();
}

/// A 4-worker fleet's merged trace reconstructs every run's lifecycle
/// chain exactly once: enqueue → lease_acquire → execute → complete,
/// causally ordered on the shared unix-microsecond axis.
#[test]
fn trace_fleet_reconstructs_lifecycle_chains_exactly_once() {
    let base = fresh_dir("ota_trace_chains_test");
    let store_dir = base.join("store").to_str().unwrap().to_string();
    let keys = enqueue_traced(&store_dir, &spec("tchain"));
    assert_eq!(keys.len(), 3);

    let campaign = traced_campaign(&store_dir);
    let fleet_cfg = FleetConfig::default();
    let reports: Vec<fleet::WorkerReport> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let store_dir = &store_dir;
                let campaign = &campaign;
                let fleet_cfg = &fleet_cfg;
                scope.spawn(move || {
                    fleet::run_worker(store_dir, fleet_cfg, campaign, &format!("w{i}"), false)
                        .unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let executed: usize = reports.iter().map(|r| r.executed + r.resumed).sum();
    assert_eq!(executed, 3, "every run executed exactly once: {reports:?}");

    let store = RunStore::open(&store_dir).unwrap();
    let rep = fleet::read_spans(store.root());
    assert_eq!(rep.unreadable_files, 0);
    assert_eq!(rep.skipped_lines, 0, "a clean fleet writes no torn lines");
    let workers: Vec<&str> = ["w0", "w1", "w2", "w3"].to_vec();
    for key in &keys {
        let enq = of(&rep.spans, key, "enqueue");
        assert_eq!(enq.len(), 1, "{key}: exactly one enqueue marker");
        assert_eq!(enq[0].worker, "enqueuer");
        assert_eq!(enq[0].campaign, "tchain", "the enqueue marker carries the spec id");
        let execs = of(&rep.spans, key, "execute");
        assert_eq!(execs.len(), 1, "{key}: exactly one execute span across 4 workers");
        let exec = execs[0];
        assert!(exec.dur_us > 0, "{key}: execute must be a timed span");
        assert!(
            workers.contains(&exec.worker.as_str()),
            "{key}: execute ran on a fleet worker, got {:?}",
            exec.worker
        );
        let acquires = of(&rep.spans, key, "lease_acquire");
        assert!(
            !acquires.is_empty(),
            "{key}: the winning claim's lease_acquire span must be recorded"
        );
        assert!(
            acquires.iter().any(|a| a.worker == exec.worker),
            "{key}: the executing worker must hold a lease_acquire span"
        );
        let completes = of(&rep.spans, key, "complete");
        assert_eq!(completes.len(), 1, "{key}: exactly one complete marker");
        assert_eq!(completes[0].worker, exec.worker);
        // Causal order on the shared clock: enqueue ≤ acquire ≤ execute
        // start, and complete lands inside execute (1 ms slack — the
        // marker is SystemTime-stamped, the span end is start +
        // Instant-elapsed, and the two clocks may micro-drift).
        let acq = acquires.iter().find(|a| a.worker == exec.worker).unwrap();
        assert!(enq[0].start_us <= acq.start_us, "{key}: enqueue before acquire");
        assert!(acq.start_us <= exec.start_us, "{key}: acquire before execute");
        assert!(
            completes[0].start_us >= exec.start_us
                && completes[0].start_us <= exec.end_us() + 1_000,
            "{key}: the complete marker lands within the execute span"
        );
    }

    // The rendered report contains a critical-path row for every run
    // and a utilization line for every lane that emitted spans.
    let mut spans = rep.spans.clone();
    fleet::sort_spans(&mut spans);
    let report = fleet::render_trace_report(&spans, 0, 0, 0);
    assert!(report.contains("critical path per run"));
    for key in &keys {
        assert!(report.contains(key.as_str()), "report must list {key}");
    }
    assert!(report.contains("worker utilization"));
    assert!(report.contains("straggler:"), "multi-lane traces rank the straggler");
    std::fs::remove_dir_all(&base).ok();
}

/// Tracing is observe-only: the same campaign with tracing off and on
/// produces byte-identical `summary.csv` (and identical stored
/// trajectories), because spans are pure wall-clock — no RNG draws, no
/// f32 op-order changes.
#[test]
fn trace_on_off_byte_identical_outputs() {
    let base = fresh_dir("ota_trace_identity_test");
    let fleet_cfg = FleetConfig::default();
    let mut outs: Vec<PathBuf> = Vec::new();
    for (tag, traced) in [("off", false), ("on", true)] {
        let store_dir = base.join(format!("store_{tag}")).to_str().unwrap().to_string();
        {
            let store = RunStore::open(&store_dir).unwrap();
            fleet::enqueue_specs(&store, &[spec("tident")]).unwrap();
        }
        let mut campaign = traced_campaign(&store_dir);
        campaign.telemetry.trace = traced;
        fleet::run_worker(&store_dir, &fleet_cfg, &campaign, "w0", false).unwrap();
        let out = base.join(format!("out_{tag}"));
        let store = RunStore::open(&store_dir).unwrap();
        fleet::collect_outputs(&store, &[spec("tident")], out.to_str().unwrap()).unwrap();
        let spans = fleet::read_spans(store.root());
        if traced {
            assert!(!spans.spans.is_empty(), "traced store must hold spans");
        } else {
            assert!(
                spans.spans.is_empty(),
                "untraced store must hold no spans: {:?}",
                spans.spans
            );
        }
        outs.push(out);
    }
    assert_eq!(
        read(&outs[0].join("tident/summary.csv")),
        read(&outs[1].join("tident/summary.csv")),
        "summary.csv must be byte-identical with tracing off vs on"
    );
    std::fs::remove_dir_all(&base).ok();
}

/// `repro trace --connect` ≡ local: the server's `/trace` cursor read
/// and the local store read return the same spans and render the same
/// report and Chrome JSON, byte for byte — including the fail-soft
/// accounting around injected garbage and a torn tail.
#[test]
fn trace_connect_output_byte_identical_to_local() {
    let base = fresh_dir("ota_trace_connect_test");
    let store_dir = base.join("store").to_str().unwrap().to_string();
    enqueue_traced(&store_dir, &spec("twire"));
    let campaign = traced_campaign(&store_dir);
    fleet::run_worker(&store_dir, &FleetConfig::default(), &campaign, "w0", false).unwrap();
    let store = RunStore::open(&store_dir).unwrap();

    // Garbage + torn tail exercise the skipped/pending split both
    // sides must account identically.
    let segment = fleet::trace_dir(store.root()).join("w0.jsonl");
    let mut fh = std::fs::OpenOptions::new().append(true).open(&segment).unwrap();
    fh.write_all(b"this is not a span\n").unwrap();
    fh.write_all(b"{\"v\":1,\"name\":\"torn-mid-wri").unwrap();
    drop(fh);

    let server =
        fleet::Server::bind(&store_dir, "127.0.0.1:0", fleet::ServeOptions::default()).unwrap();
    let addr = server.addr().to_string();

    let local = fleet::read_spans_from(store.root(), &fleet::Cursor::default());
    let remote = fleet::fetch_spans(&addr, &fleet::Cursor::default()).unwrap();
    assert!(!local.spans.is_empty(), "the traced run must produce spans");
    assert_eq!(local.spans, remote.spans, "span sets must match over the wire");
    assert_eq!(local.consumed_skipped, remote.consumed_skipped);
    assert_eq!(local.pending_tails, remote.pending_tails);
    assert_eq!(local.unreadable_files, remote.unreadable_files);
    assert_eq!(local.consumed_skipped, 1, "the garbage line is consumed-skipped");
    assert_eq!(local.pending_tails, 1, "the torn tail is pending, not consumed");

    // The exact `repro trace` rendering pipeline, both sides.
    let render = |tail: &fleet::SpanTailReport| {
        let mut spans = tail.spans.clone();
        fleet::sort_spans(&mut spans);
        (
            fleet::render_trace_report(
                &spans,
                tail.consumed_skipped,
                tail.pending_tails,
                tail.unreadable_files,
            ),
            fleet::chrome_trace(&spans),
        )
    };
    let (local_report, local_chrome) = render(&local);
    let (remote_report, remote_chrome) = render(&remote);
    assert_eq!(
        local_report, remote_report,
        "`repro trace --connect` report must be byte-identical to local"
    );
    assert!(local_report.contains("fail-soft: 1 skipped line(s) · 1 pending tail(s)"));
    assert_eq!(
        local_chrome, remote_chrome,
        "the merged Chrome trace must be byte-identical over the wire"
    );

    // Cursor chaining: a second read from the returned cursor is empty
    // (the torn tail stays pending; nothing is consumed twice).
    let next = fleet::fetch_spans(&addr, &remote.cursor).unwrap();
    assert!(next.spans.is_empty(), "no new spans after the first read");
    assert_eq!(next.consumed_skipped, 0, "garbage must not be re-consumed");
    assert_eq!(next.pending_tails, 1, "the torn tail is still pending");
    drop(server);
    std::fs::remove_dir_all(&base).ok();
}
