//! Property-based tests over the paper's invariants, driven by the
//! hand-rolled harness in `util::proptest` (no proptest crate offline —
//! same methodology: random generation + shrinking).

use ota_dsgd::amp::{self, AmpConfig};
use ota_dsgd::analog::{AnalogDevice, Projection};
use ota_dsgd::channel::PowerAllocator;
use ota_dsgd::compress::bits::{capacity_bits, max_q_within_budget, position_bits};
use ota_dsgd::compress::sbc::SbcCompressor;
use ota_dsgd::compress::signsgd::SignSgdCompressor;
use ota_dsgd::compress::{DigitalCompressor, ErrorAccumulator};
use ota_dsgd::config::PowerSchedule;
use ota_dsgd::tensor;
use ota_dsgd::util::proptest::{
    run_property, run_property_noshrink, shrink_vec_f32, Check, PropConfig,
};
use ota_dsgd::util::rng::Pcg64;

fn gen_vec(rng: &mut Pcg64, max_len: usize) -> Vec<f32> {
    let n = 1 + rng.below(max_len as u64) as usize;
    (0..n).map(|_| rng.normal_ms(0.0, 2.0) as f32).collect()
}

/// Corollary 1: ‖x − sp_k(x)‖ ≤ √((d−k)/d)·‖x‖ for every x and k.
#[test]
fn prop_sparsification_error_bound() {
    run_property(
        "corollary1",
        PropConfig {
            cases: 128,
            ..Default::default()
        },
        |rng| {
            let x = gen_vec(rng, 400);
            let k = 1 + rng.below(x.len() as u64) as usize;
            (x, k)
        },
        |(x, k)| {
            let k = (*k).min(x.len());
            let sp = tensor::sparsify_topk(x, k);
            let err: f64 = x
                .iter()
                .zip(&sp)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            let d = x.len() as f64;
            let bound = ((d - k as f64) / d).sqrt() * tensor::norm(x) + 1e-5;
            Check::from_bool(err <= bound, &format!("err {err} > bound {bound}"))
        },
        |(x, k)| {
            shrink_vec_f32(x)
                .into_iter()
                .map(|v| {
                    let kk = (*k).min(v.len().max(1));
                    (v, kk)
                })
                .collect()
        },
    );
}

/// The A-DSGD frame always has ‖x‖² = P_t exactly (Eq. 12), for any
/// gradient, any k, any power.
#[test]
fn prop_analog_frame_power_exact() {
    run_property_noshrink(
        "eq12-frame-power",
        PropConfig {
            cases: 64,
            ..Default::default()
        },
        |rng| {
            let d = 20 + rng.below(300) as usize;
            let g: Vec<f32> = (0..d).map(|_| rng.normal_ms(0.0, 1.5) as f32).collect();
            let s_tilde = 4 + rng.below((d / 2) as u64) as usize;
            let k = 1 + rng.below(s_tilde.min(d) as u64) as usize;
            let p_t = 0.5 + rng.f64() * 800.0;
            let seed = rng.next_u64();
            (g, s_tilde, k, p_t, seed)
        },
        |(g, s_tilde, k, p_t, seed)| {
            let proj = Projection::generate(*s_tilde, g.len(), *seed);
            let mut dev = AnalogDevice::new(g.len(), *k);
            let frame = dev.transmit(g, &proj, *p_t);
            let power = tensor::norm_sq(&frame.x);
            Check::from_bool(
                (power - p_t).abs() <= 1e-3 * p_t.max(1.0),
                &format!("power {power} vs P_t {p_t}"),
            )
        },
    );
}

/// Error accumulation conserves mass: Δ(t+1) + transmitted = g + Δ(t).
#[test]
fn prop_error_accumulator_conservation() {
    run_property_noshrink(
        "error-accum-conservation",
        PropConfig::default(),
        |rng| {
            let g = gen_vec(rng, 300);
            let k = 1 + rng.below(g.len() as u64) as usize;
            (g, k)
        },
        |(g, k)| {
            let mut acc = ErrorAccumulator::new(g.len());
            let g_ec = acc.compensate(g);
            let sent = tensor::sparsify_topk(&g_ec, (*k).min(g.len()));
            acc.update(&g_ec, &sent);
            let recon: Vec<f32> = acc
                .as_slice()
                .iter()
                .zip(&sent)
                .map(|(d, s)| d + s)
                .collect();
            let diff: f64 = recon
                .iter()
                .zip(g)
                .map(|(a, b)| ((a - b) as f64).abs())
                .fold(0.0, f64::max);
            Check::from_bool(diff < 1e-5, &format!("mass not conserved: {diff}"))
        },
    );
}

/// Capacity (Eq. 8) is monotone in P and s, and the budget search always
/// returns the maximal feasible q.
#[test]
fn prop_capacity_and_budget_search() {
    run_property_noshrink(
        "capacity-monotone-budget-max",
        PropConfig {
            cases: 96,
            ..Default::default()
        },
        |rng| {
            let s = 10 + rng.below(4000) as usize;
            let m = 1 + rng.below(50) as usize;
            let p = rng.f64() * 1000.0;
            let d = 100 + rng.below(8000) as usize;
            (s, m, p, d)
        },
        |&(s, m, p, d)| {
            let r = capacity_bits(s, m, p, 1.0);
            let r_more_power = capacity_bits(s, m, p + 50.0, 1.0);
            let r_more_bw = capacity_bits(s + 100, m, p, 1.0);
            if r_more_power < r || r_more_bw < r {
                return Check::Fail(format!("capacity not monotone at s={s} m={m} p={p}"));
            }
            let q = max_q_within_budget(d / 2, r, |q| position_bits(d, q) + 33.0);
            if q > 0 && position_bits(d, q) + 33.0 > r {
                return Check::Fail(format!("q={q} exceeds budget"));
            }
            if q < d / 2 && position_bits(d, q + 1) + 33.0 <= r {
                return Check::Fail(format!("q={q} not maximal"));
            }
            Check::Pass
        },
    );
}

/// Every power schedule meets Eq. 7 for any (P̄, T).
#[test]
fn prop_power_schedules_satisfy_average() {
    run_property_noshrink(
        "eq7-average-power",
        PropConfig::default(),
        |rng| {
            let pbar = 0.1 + rng.f64() * 1000.0;
            let t = 1 + rng.below(600) as usize;
            let kind = match rng.below(4) {
                0 => PowerSchedule::Constant,
                1 => PowerSchedule::LhStair,
                2 => PowerSchedule::Lh,
                _ => PowerSchedule::Hl,
            };
            (pbar, t, kind)
        },
        |&(pbar, t, kind)| {
            let alloc = PowerAllocator::new(kind, pbar, t);
            Check::from_bool(
                alloc.satisfies_average(1e-9) && alloc.schedule.iter().all(|&p| p > 0.0),
                &format!("{kind:?} T={t} P̄={pbar}"),
            )
        },
    );
}

/// Digital payloads always fit the budget and reconstruct with the correct
/// support size.
#[test]
fn prop_digital_payloads_fit_budget() {
    run_property_noshrink(
        "digital-fits-budget",
        PropConfig {
            cases: 48,
            ..Default::default()
        },
        |rng| {
            let g = gen_vec(rng, 500);
            let budget = rng.f64() * 500.0;
            let which = rng.below(2);
            (g, budget, which)
        },
        |(g, budget, which)| {
            let payload = if *which == 0 {
                SbcCompressor::new().encode(g, *budget)
            } else {
                SignSgdCompressor::new().encode(g, *budget)
            };
            if payload.bits > *budget && payload.bits != 0.0 {
                return Check::Fail(format!("bits {} > budget {budget}", payload.bits));
            }
            let nnz = payload.reconstruction.iter().filter(|&&v| v != 0.0).count();
            Check::from_bool(
                nnz == payload.nnz,
                &format!("nnz mismatch: {} vs {}", nnz, payload.nnz),
            )
        },
    );
}

/// AMP on a noiseless well-conditioned instance recovers the signal
/// (Lemma 1 regime: k < s/4, s = d/2).
#[test]
fn prop_amp_recovery_in_lemma1_regime() {
    run_property_noshrink(
        "amp-recovery",
        PropConfig {
            cases: 16,
            ..Default::default()
        },
        |rng| {
            let d = 200 + rng.below(200) as usize;
            let s = d / 2;
            let k = 1 + rng.below((s / 4) as u64) as usize;
            let seed = rng.next_u64();
            let mut x = vec![0f32; d];
            let idx = rng.sample_indices(d, k);
            for i in idx {
                x[i] = rng.normal_ms(0.0, 1.0) as f32;
            }
            (x, s, seed)
        },
        |(x, s, seed)| {
            let a = amp::measurement_matrix(*s, x.len(), *seed);
            let mut y = vec![0f32; *s];
            tensor::gemv(&a, x, &mut y);
            let (xhat, _) = amp::recover(
                &a,
                &y,
                &AmpConfig {
                    max_iters: 60,
                    tol: 1e-7,
                    threshold_mult: 1.1,
                },
            );
            let err: f64 = x
                .iter()
                .zip(&xhat)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
                / tensor::norm(x).max(1e-9);
            Check::from_bool(err < 0.1, &format!("relative error {err}"))
        },
    );
}

/// QSGD stochastic quantization is unbiased for any input (statistical
/// property over repeated encodes).
#[test]
fn prop_qsgd_unbiased() {
    run_property_noshrink(
        "qsgd-unbiased",
        PropConfig {
            cases: 8,
            ..Default::default()
        },
        |rng| {
            let n = 3 + rng.below(12) as usize;
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let seed = rng.next_u64();
            (g, seed)
        },
        |(g, seed)| {
            use ota_dsgd::compress::qsgd::QsgdCompressor;
            let budget = QsgdCompressor::bit_cost(g.len(), g.len(), 2) + 1.0;
            let mut enc = QsgdCompressor::new(2, *seed);
            let trials = 4000;
            let mut sums = vec![0f64; g.len()];
            for _ in 0..trials {
                let p = enc.encode(g, budget);
                for (s, &r) in sums.iter_mut().zip(&p.reconstruction) {
                    *s += r as f64;
                }
            }
            let norm: f64 = tensor::norm(g);
            for (i, s) in sums.iter().enumerate() {
                let mean = s / trials as f64;
                if (mean - g[i] as f64).abs() > 0.05 * norm.max(0.2) {
                    return Check::Fail(format!(
                        "coord {i}: E[Q] = {mean} vs {} (norm {norm})",
                        g[i]
                    ));
                }
            }
            Check::Pass
        },
    );
}
