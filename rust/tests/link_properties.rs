//! Property layer over the `LinkScheme` contract: every scheme the factory
//! can build — static analog, fading CSI/blind, the three digital arms, and
//! the error-free benchmark — must honor the encode/aggregate/audit
//! invariants across seeded random configurations:
//!
//! * **Eq. 6 power audit**: `measured_avg_power()` stays within the P̄
//!   budget (within tolerance) for every device.
//! * **Shape**: `ghat.len() == d` every round.
//! * **Telemetry honesty**: digital ⇒ `bits_per_device ≤ R_t`, with
//!   participation counts present exactly when a non-Full policy is
//!   configured; analog ⇒ AMP actually ran on rounds with a non-empty
//!   transmitting set; fading ⇒ participation counts present and
//!   partitioning the fleet; D2D ⇒ consensus distance present and finite;
//!   everything else ⇒ `participation == None` (absent, not zero), and
//!   `consensus_distance == None` for every PS-centric link.

use ota_dsgd::config::{
    presets, FadingDist, LinkKind, ParticipationPolicy, RunConfig, Scheme,
};
use ota_dsgd::coordinator::link::{self, RoundCtx};
use ota_dsgd::digital::capacity_bits;
use ota_dsgd::tensor::Matf;
use ota_dsgd::util::proptest::{run_property_noshrink, Check, PropConfig};
use ota_dsgd::util::rng::Pcg64;

const ALL_SCHEMES: [Scheme; 8] = [
    Scheme::ErrorFree,
    Scheme::ADsgd,
    Scheme::FadingADsgd,
    Scheme::BlindADsgd,
    Scheme::D2dADsgd,
    Scheme::DDsgd,
    Scheme::SignSgd,
    Scheme::Qsgd,
];

/// A random but *valid* link-level configuration, small enough that the
/// analog projection matrices stay cheap.
fn random_cfg(rng: &mut Pcg64) -> (RunConfig, usize) {
    let d = 120 + rng.below(280) as usize;
    let s = 16 + rng.below((d / 2 - 16) as u64) as usize;
    let k = 1 + rng.below((s.min(d) - 4) as u64) as usize;
    let devices = 2 + rng.below(7) as usize;
    let fading = match rng.below(3) {
        0 => FadingDist::Rayleigh,
        1 => FadingDist::Constant(0.4 + rng.f64()),
        _ => FadingDist::Uniform(0.1, 0.1 + 1.5 * rng.f64() + 1e-3),
    };
    let participation = match rng.below(3) {
        0 => ParticipationPolicy::Full,
        1 => ParticipationPolicy::UniformK(1 + rng.below(devices as u64) as usize),
        _ => ParticipationPolicy::GainThreshold(0.1 * rng.f64()),
    };
    let cfg = RunConfig {
        devices,
        channel_uses: s,
        sparsity: k,
        pbar: 50.0 + rng.f64() * 800.0,
        noise_var: 0.25 + rng.f64() * 2.0,
        mean_removal_rounds: rng.below(3) as usize,
        seed: rng.next_u64(),
        amp_iters: 15,
        fading,
        csi_threshold: 0.05 * rng.f64(),
        participation,
        latency_mean_secs: 0.0,
        deadline_secs: 0.0,
        ..presets::smoke()
    };
    (cfg, d)
}

fn random_grads(rng: &mut Pcg64, m: usize, d: usize) -> Matf {
    Matf::from_vec(
        m,
        d,
        (0..m * d).map(|_| rng.normal_ms(0.0, 0.5) as f32).collect(),
    )
}

/// The cross-scheme contract, one random config per case, all schemes.
#[test]
fn prop_every_scheme_honors_link_contract() {
    run_property_noshrink(
        "link-contract-all-schemes",
        PropConfig {
            cases: 10,
            ..Default::default()
        },
        |rng| {
            let (cfg, d) = random_cfg(rng);
            let seed = rng.next_u64();
            (cfg, d, seed)
        },
        |(cfg, d, seed)| {
            let d = *d;
            let mut rng = Pcg64::new(*seed);
            for scheme in ALL_SCHEMES {
                let cfg = RunConfig {
                    scheme,
                    ..cfg.clone()
                };
                let mut link = link::for_config(&cfg, d);
                let grads = random_grads(&mut rng, cfg.devices, d);
                let rounds = 3usize;
                let mut amp_ran = false;
                let mut had_transmitters = false;
                for t in 0..rounds {
                    let out = link.round(
                        &RoundCtx {
                            t,
                            p_t: cfg.pbar,
                            deadline: None,
                        },
                        &grads,
                    );
                    // Shape invariant.
                    if out.ghat.len() != d {
                        return Check::Fail(format!(
                            "{scheme:?}: ghat.len() {} != d {d}",
                            out.ghat.len()
                        ));
                    }
                    // PS-centric links never measure replica disagreement.
                    if cfg.scheme.kind() != LinkKind::D2d
                        && out.telemetry.consensus_distance.is_some()
                    {
                        return Check::Fail(format!(
                            "{scheme:?}: PS-centric link must not report consensus distance"
                        ));
                    }
                    // Telemetry invariants per family.
                    match cfg.scheme.kind() {
                        LinkKind::Digital => {
                            let budget =
                                capacity_bits(cfg.channel_uses, cfg.devices, cfg.pbar, cfg.noise_var);
                            if out.telemetry.bits_per_device > budget + 1e-9 {
                                return Check::Fail(format!(
                                    "{scheme:?}: bits {} > budget {budget}",
                                    out.telemetry.bits_per_device
                                ));
                            }
                            // Participation is reported exactly when a
                            // non-Full policy is configured (None ≠ 0).
                            match out.telemetry.participation {
                                Some(stats) => {
                                    if cfg.participation == ParticipationPolicy::Full {
                                        return Check::Fail(format!(
                                            "{scheme:?}: always-on digital link must not \
                                             report participation"
                                        ));
                                    }
                                    if stats.total() != cfg.devices
                                        || stats.silenced_low_gain != 0
                                        || stats.dropped_stragglers != 0
                                    {
                                        return Check::Fail(format!(
                                            "{scheme:?}: digital stats {stats:?} vs M={}",
                                            cfg.devices
                                        ));
                                    }
                                }
                                None => {
                                    if cfg.participation != ParticipationPolicy::Full {
                                        return Check::Fail(format!(
                                            "{scheme:?}: scheduled digital link must report \
                                             participation ({:?})",
                                            cfg.participation
                                        ));
                                    }
                                }
                            }
                        }
                        LinkKind::Analog | LinkKind::Passthrough => {
                            if out.telemetry.participation.is_some() {
                                return Check::Fail(format!(
                                    "{scheme:?}: static link must not report participation"
                                ));
                            }
                            if cfg.scheme.kind() == LinkKind::Analog {
                                amp_ran |= out.telemetry.amp_iterations > 0;
                                had_transmitters = true;
                            }
                        }
                        LinkKind::D2d => {
                            let Some(dist) = out.telemetry.consensus_distance else {
                                return Check::Fail(format!(
                                    "{scheme:?}: D2D link must report consensus distance"
                                ));
                            };
                            if !dist.is_finite() || dist < 0.0 {
                                return Check::Fail(format!(
                                    "{scheme:?}: consensus distance {dist} not a finite \
                                     non-negative number"
                                ));
                            }
                            if out.telemetry.participation.is_some() {
                                return Check::Fail(format!(
                                    "{scheme:?}: D2D (all devices broadcast) must not \
                                     report participation"
                                ));
                            }
                            had_transmitters = true;
                            amp_ran |= out.telemetry.amp_iterations > 0;
                        }
                        LinkKind::Fading => {
                            let Some(stats) = out.telemetry.participation else {
                                return Check::Fail(format!(
                                    "{scheme:?}: fading link must report participation"
                                ));
                            };
                            if stats.total() != cfg.devices {
                                return Check::Fail(format!(
                                    "{scheme:?}: stats {stats:?} don't partition M={}",
                                    cfg.devices
                                ));
                            }
                            if stats.transmitting > 0 {
                                had_transmitters = true;
                                amp_ran |= out.telemetry.amp_iterations > 0;
                            } else if out.telemetry.amp_iterations != 0 {
                                return Check::Fail(format!(
                                    "{scheme:?}: AMP ran with nobody transmitting"
                                ));
                            }
                        }
                    }
                }
                // Eq. 6 audit across the rounds driven (P_t = P̄ here).
                let powers = link.measured_avg_power();
                if powers.len() != cfg.devices {
                    return Check::Fail(format!(
                        "{scheme:?}: power report covers {} devices, M={}",
                        powers.len(),
                        cfg.devices
                    ));
                }
                // 1e-4 relative slack: the analog frame hits ‖x‖² = P_t up
                // to f32 rounding of the α scaling.
                for (m, &p) in powers.iter().enumerate() {
                    if p > cfg.pbar * (1.0 + 1e-4) {
                        return Check::Fail(format!(
                            "{scheme:?}: device {m} avg power {p} > P̄ {}",
                            cfg.pbar
                        ));
                    }
                }
                // Analog-family links must have exercised AMP whenever
                // anyone transmitted.
                if had_transmitters && !amp_ran {
                    return Check::Fail(format!(
                        "{scheme:?}: no AMP iterations across {rounds} rounds"
                    ));
                }
            }
            Check::Pass
        },
    );
}

/// Satellite regression: the telemetry default is honest — participation
/// is `None` (absent), never a fake measured zero.
#[test]
fn telemetry_default_participation_is_absent_not_zero() {
    let telemetry = ota_dsgd::coordinator::link::RoundTelemetry::default();
    assert!(telemetry.participation.is_none());
    assert!(telemetry.consensus_distance.is_none());
    assert_eq!(telemetry.bits_per_device, 0.0);
    assert_eq!(telemetry.amp_iterations, 0);
}

/// Straggler invariant under random deadlines: dropped devices spend no
/// energy, counts stay a partition, and an all-dropped round yields ĝ = 0.
#[test]
fn prop_straggler_deadlines_respected() {
    run_property_noshrink(
        "straggler-deadlines",
        PropConfig {
            cases: 8,
            ..Default::default()
        },
        |rng| {
            let (mut cfg, d) = random_cfg(rng);
            cfg.scheme = Scheme::FadingADsgd;
            cfg.participation = ParticipationPolicy::Full;
            cfg.latency_mean_secs = 0.002 + 0.02 * rng.f64();
            let deadline = 0.0005 + 0.03 * rng.f64();
            let seed = rng.next_u64();
            (cfg, d, deadline, seed)
        },
        |(cfg, d, deadline, seed)| {
            let d = *d;
            let mut rng = Pcg64::new(*seed);
            let mut link = link::for_config(cfg, d);
            let grads = random_grads(&mut rng, cfg.devices, d);
            for t in 0..3 {
                let out = link.round(
                    &RoundCtx {
                        t,
                        p_t: cfg.pbar,
                        deadline: Some(*deadline),
                    },
                    &grads,
                );
                let stats = out.telemetry.participation.expect("fading stats");
                if stats.total() != cfg.devices {
                    return Check::Fail(format!("stats {stats:?} vs M={}", cfg.devices));
                }
                if stats.transmitting == 0 && out.ghat.iter().any(|&v| v != 0.0) {
                    return Check::Fail("empty round must return ĝ = 0".into());
                }
            }
            for &p in &link.measured_avg_power() {
                if p > cfg.pbar * (1.0 + 1e-4) {
                    return Check::Fail(format!("power {p} > P̄ {}", cfg.pbar));
                }
            }
            Check::Pass
        },
    );
}
