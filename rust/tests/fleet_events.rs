//! Event-sourced observability, end to end:
//!
//! * the event logs of a 4-worker fleet and a 1-worker fleet executing
//!   the same campaign reduce — after deterministic sorting and
//!   wall-clock masking — to bit-identical deterministic cores (who ran
//!   what, in how many pieces, is operational noise, not signal);
//! * a 2-worker drain's replayed metrics agree with the sum of the
//!   workers' own reports, and a subsequent `repro fig`-style scheduler
//!   pass records exactly the cache hits its `CampaignReport` claims;
//! * garbage and torn trailing lines injected into a segment are
//!   skipped and counted, and the replayed metrics are unchanged.

use std::io::Write;
use std::path::{Path, PathBuf};

use ota_dsgd::campaign::{scheduler, CampaignReport, RunStore};
use ota_dsgd::config::{presets, CampaignConfig, FleetConfig, RunConfig, Scheme};
use ota_dsgd::experiments::runner::ExperimentSpec;
use ota_dsgd::fleet;
use ota_dsgd::model::PARAM_DIM;

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn lean(scheme: Scheme) -> RunConfig {
    RunConfig {
        scheme,
        iterations: 4,
        eval_every: 2,
        channel_uses: PARAM_DIM / 8,
        sparsity: PARAM_DIM / 16,
        ..presets::smoke()
    }
}

fn spec() -> ExperimentSpec {
    ExperimentSpec {
        id: "tevents".into(),
        title: "event log determinism".into(),
        runs: vec![
            ("error-free".into(), lean(Scheme::ErrorFree)),
            ("signsgd".into(), lean(Scheme::SignSgd)),
            ("qsgd".into(), lean(Scheme::Qsgd)),
        ],
    }
}

fn campaign_for(store_dir: &str) -> CampaignConfig {
    CampaignConfig {
        snapshot_every: 1,
        store_dir: store_dir.to_string(),
        ..CampaignConfig::default()
    }
}

/// Enqueue the spec into a fresh store under `base/name` and drain it
/// with `n` in-process workers; returns the store dir and their reports.
fn drain(base: &Path, name: &str, n: usize) -> (String, Vec<fleet::WorkerReport>) {
    let store_dir = base.join(name).to_str().unwrap().to_string();
    {
        let store = RunStore::open(&store_dir).unwrap();
        fleet::enqueue_specs(&store, &[spec()]).unwrap();
    }
    let campaign = campaign_for(&store_dir);
    let fleet_cfg = FleetConfig::default();
    let reports = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let store_dir = &store_dir;
                let campaign = &campaign;
                let fleet_cfg = &fleet_cfg;
                scope.spawn(move || {
                    fleet::run_worker(store_dir, fleet_cfg, campaign, &format!("w{i}"), false)
                        .unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    (store_dir, reports)
}

/// Read a store's event log, assert it is clean, and reduce it to the
/// canonical deterministic-core rendering after seq-sort + masking.
fn clean_core(store_dir: &str) -> String {
    let store = RunStore::open(store_dir).unwrap();
    let mut report = fleet::read_events(store.root());
    assert_eq!(report.unreadable_files, 0, "no segment may be unreadable");
    assert_eq!(report.skipped_lines, 0, "a clean shutdown tears no lines");
    fleet::sort_events(&mut report.events);
    fleet::mask_wallclock(&mut report.events);
    fleet::reduce(&report.events).deterministic_core()
}

/// The replay determinism contract: fleet shape must not leak into the
/// deterministic core. 4 workers racing over the queue and 1 worker
/// draining it serially produce bit-identical cores (same key sets,
/// same per-round gauge bit patterns, same final metrics).
#[test]
fn fleet_shapes_reduce_to_identical_deterministic_core() {
    let base = fresh_dir("ota_fleet_events_determinism_test");
    let (store4, reports4) = drain(&base, "store4", 4);
    let (store1, reports1) = drain(&base, "store1", 1);
    let done = |rs: &[fleet::WorkerReport]| -> usize {
        rs.iter().map(|r| r.executed + r.resumed).sum()
    };
    assert_eq!(done(&reports4), 3, "4-worker fleet executes every run once");
    assert_eq!(done(&reports1), 3, "solo worker executes every run once");

    let core4 = clean_core(&store4);
    let core1 = clean_core(&store1);
    assert_eq!(
        core4, core1,
        "deterministic core must be identical for 4-worker and 1-worker fleets"
    );
    // And it is not trivially identical-because-empty: all three runs
    // show up enqueued, executed, completed, with per-round series.
    assert!(core4.contains("queue_depth=0"), "drained queue:\n{core4}");
    for needle in ["executed=[", "completed=[", "run["] {
        assert!(core4.contains(needle), "core must mention {needle}:\n{core4}");
    }
    assert_eq!(core4.matches("run[").count(), 3, "one series per run:\n{core4}");
    std::fs::remove_dir_all(&base).ok();
}

/// The observability smoke (the CI step's in-process twin): replayed
/// metrics must agree with what the workers and the scheduler say
/// happened — executed/resumed from `WorkerReport`s, cached from
/// `CampaignReport`.
#[test]
fn two_worker_drain_metrics_match_worker_and_campaign_reports() {
    let base = fresh_dir("ota_fleet_events_smoke_test");
    let (store_dir, reports) = drain(&base, "store2", 2);
    let executed: usize = reports.iter().map(|r| r.executed + r.resumed).sum();
    assert_eq!(executed, 3, "both workers together drain all 3 runs: {reports:?}");

    let store = RunStore::open(&store_dir).unwrap();
    let m = fleet::reduce_report(&fleet::read_events(store.root()));
    assert_eq!(m.enqueued.len(), 3, "3 runs enqueued");
    assert_eq!(
        m.executed.len() + m.resumed.len(),
        executed,
        "replayed executed+resumed must match the workers' own accounting"
    );
    assert_eq!(m.completed.len(), 3, "all runs completed");
    assert_eq!(m.cached.len(), 0, "nothing served from cache yet");
    assert_eq!(m.queue_depth(), 0, "queue drained");
    // Telemetry default is every round: 4 rounds x 3 runs, (key, round)-deduped.
    assert_eq!(m.rounds_total(), 12, "per-round telemetry for every round");
    let prom = m.to_prometheus();
    for needle in [
        "ota_runs_executed_total 3",
        "ota_runs_completed_total 3",
        "ota_rounds_total 12",
        "ota_queue_depth 0",
    ] {
        assert!(prom.contains(needle), "missing `{needle}` in:\n{prom}");
    }

    // A figure regeneration over the same store is a pure cache load,
    // and the event log must record exactly those cache hits.
    let out_fig = base.join("out_fig");
    let campaign = campaign_for(&store_dir);
    let (_, rep) =
        scheduler::run_experiment_cached(&spec(), out_fig.to_str().unwrap(), false, &campaign);
    assert_eq!(rep, CampaignReport { executed: 0, resumed: 0, cached: 3 });
    let m2 = fleet::reduce_report(&fleet::read_events(store.root()));
    assert_eq!(
        m2.cached.len(),
        rep.cached,
        "replayed cache hits must match the scheduler's CampaignReport"
    );
    std::fs::remove_dir_all(&base).ok();
}

/// Reader robustness at the integration level: inject a garbage line
/// and a torn (unterminated) trailing record into a real segment. Both
/// are skipped and counted; the replayed metrics are unchanged.
#[test]
fn torn_and_garbage_event_lines_are_skipped_not_fatal() {
    let base = fresh_dir("ota_fleet_events_torn_test");
    let (store_dir, _) = drain(&base, "store", 1);
    let store = RunStore::open(&store_dir).unwrap();
    let before = fleet::reduce_report(&fleet::read_events(store.root()));
    assert!(before.events_total > 0, "the drain must have logged events");

    let dir = fleet::events_dir(store.root());
    let segment = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok().map(|e| e.path()))
        .find(|p| p.extension().and_then(|e| e.to_str()) == Some("jsonl"))
        .expect("at least one event segment");
    let mut fh = std::fs::OpenOptions::new()
        .append(true)
        .open(&segment)
        .unwrap();
    fh.write_all(b"this is not json\n").unwrap();
    fh.write_all(b"{\"v\":1,\"kind\":\"round\",\"key\":\"torn-mid-wri").unwrap();
    drop(fh);

    let report = fleet::read_events(store.root());
    assert_eq!(report.unreadable_files, 0, "the segment still opens");
    assert_eq!(
        report.skipped_lines, 2,
        "the garbage line and the torn trailing line are counted, not fatal"
    );
    let after = fleet::reduce_report(&report);
    assert_eq!(
        before.deterministic_core(),
        after.deterministic_core(),
        "skipped lines must not change the replayed metrics"
    );
    assert_eq!(after.skipped_lines, 2, "the reducer surfaces the skip count");
    std::fs::remove_dir_all(&base).ok();
}
