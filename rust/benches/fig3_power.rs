//! Fig. 3 bench: D-DSGD under the four power-allocation schedules
//! (Eq. 45a–c) + the analog/error-free anchors, at P̄ = 200.

#[path = "common.rs"]
mod common;

use ota_dsgd::experiments::figures;

fn main() {
    common::print_header("fig3", "power-allocation schedules (P̄=200)");
    let spec = figures::fig3(false);
    for (label, cfg) in spec.runs {
        common::bench_rounds(&label, cfg, 2);
    }
}
