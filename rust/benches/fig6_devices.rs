//! Fig. 6 bench: device-count scaling (M,B) ∈ {(10,2000),(20,1000)} with
//! MB fixed — round cost vs fleet size, including the P̄=1 regime where
//! D-DSGD's budget is zero bits.

#[path = "common.rs"]
mod common;

use ota_dsgd::experiments::figures;

fn main() {
    common::print_header("fig6", "device scaling, MB fixed (s=d/4)");
    let spec = figures::fig6(false);
    for (label, cfg) in spec.runs {
        common::bench_rounds(&label, cfg, 2);
    }
}
