//! Component microbenchmarks: every hot-path primitive of the stack at the
//! paper's shapes (d = 7850, s = d/2, k = s/2, M = 25). These are the
//! numbers EXPERIMENTS.md §Perf tracks before/after optimization.

use ota_dsgd::amp::{self, AmpConfig};
use ota_dsgd::analog::{AnalogDevice, Projection};
use ota_dsgd::channel::GaussianMac;
use ota_dsgd::compress::qsgd::QsgdCompressor;
use ota_dsgd::compress::sbc::SbcCompressor;
use ota_dsgd::compress::signsgd::SignSgdCompressor;
use ota_dsgd::compress::DigitalCompressor;
use ota_dsgd::coordinator::{DeviceSet, GradientBackend, RustBackend};
use ota_dsgd::data::{partition, synthetic};
use ota_dsgd::model::PARAM_DIM;
use ota_dsgd::tensor;
use ota_dsgd::util::bench::{black_box, group, Bench};
use ota_dsgd::util::rng::Pcg64;
use std::time::Duration;

const D: usize = PARAM_DIM;

fn random_grad(rng: &mut Pcg64) -> Vec<f32> {
    (0..D).map(|_| rng.normal_ms(0.0, 0.02) as f32).collect()
}

fn main() {
    let s = D / 2;
    let s_tilde = s - 1;
    let k = s / 2;
    let mut rng = Pcg64::new(1);

    group("selection / sparsification (d = 7850)");
    let g = random_grad(&mut rng);
    Bench::new(format!("topk_indices k={k}"))
        .throughput(D as u64)
        .run(|| black_box(tensor::topk_indices(&g, k)));
    Bench::new("sparsify_topk k=s/2")
        .throughput(D as u64)
        .run(|| black_box(tensor::sparsify_topk(&g, k)));

    group("digital codecs (budget = R_t at P=500, s=d/2, M=25)");
    let budget = ota_dsgd::digital::capacity_bits(s, 25, 500.0, 1.0);
    println!("(R_t = {budget:.1} bits)");
    let mut sbc = SbcCompressor::new();
    Bench::new("SBC encode (D-DSGD)").run(|| black_box(sbc.encode(&g, budget)));
    let mut sign = SignSgdCompressor::new();
    Bench::new("SignSGD encode").run(|| black_box(sign.encode(&g, budget)));
    let mut qsgd = QsgdCompressor::new(2, 7);
    Bench::new("QSGD encode").run(|| black_box(qsgd.encode(&g, budget)));
    Bench::new("q_t budget search (SBC)")
        .run(|| black_box(SbcCompressor::pick_q(D, black_box(budget))));

    group("analog pipeline (s̃ = d/2 − 1)");
    let t0 = std::time::Instant::now();
    let proj = Projection::generate(s_tilde, D, 3);
    println!("(projection generate: {:.2}s for {}x{})", t0.elapsed().as_secs_f64(), s_tilde, D);
    let mut dev = AnalogDevice::new(D, k);
    Bench::new("A-DSGD device transmit (sparsify+project+scale)")
        .iters(3, 20)
        .target_time(Duration::from_secs(3))
        .run(|| black_box(dev.transmit(&g, &proj, 500.0)));
    let g_sp = tensor::sparsify_topk(&g, k);
    let support = tensor::topk_indices(&g, k);
    Bench::new("projection apply_sparse (s̃·k MACs)")
        .iters(3, 20)
        .throughput((s_tilde * k) as u64)
        .run(|| black_box(proj.apply_sparse(&g_sp, &support)));
    Bench::new("projection apply_dense (s̃·d MACs)")
        .iters(3, 10)
        .throughput((s_tilde * D) as u64)
        .run(|| black_box(proj.apply_dense(&g_sp)));

    group("AMP recovery at paper scale");
    let y = proj.apply_dense(&g_sp);
    for iters in [5usize, 15, 30] {
        Bench::new(format!("amp::recover max_iters={iters} (row-major only)"))
            .iters(2, 6)
            .target_time(Duration::from_secs(4))
            .run(|| {
                black_box(amp::recover(
                    &proj.matrix,
                    &y,
                    &AmpConfig {
                        max_iters: iters,
                        tol: 0.0,
                        threshold_mult: 1.1,
                    },
                ))
            });
        Bench::new(format!("amp::recover_with Aᵀ max_iters={iters} (production)"))
            .iters(2, 6)
            .target_time(Duration::from_secs(4))
            .run(|| {
                black_box(amp::recover_with(
                    &proj.matrix,
                    Some(&proj.matrix_t),
                    &y,
                    &AmpConfig {
                        max_iters: iters,
                        tol: 0.0,
                        threshold_mult: 1.1,
                    },
                ))
            });
    }

    group("device encode fan-out (M=25, DeviceSet::encode)");
    for workers in [1usize, 4] {
        let grads25: Vec<Vec<f32>> = {
            let mut r = Pcg64::new(21);
            (0..25).map(|_| (0..D).map(|_| r.normal_ms(0.0, 0.02) as f32).collect()).collect()
        };
        let states: Vec<AnalogDevice> = (0..25).map(|_| AnalogDevice::new(D, k)).collect();
        let mut set = DeviceSet::with_workers(states, workers);
        Bench::new(format!("A-DSGD encode M=25 workers={workers}"))
            .iters(2, 6)
            .target_time(Duration::from_secs(4))
            .throughput(25)
            .run(|| black_box(set.encode(|dev, st| st.transmit(&grads25[dev], &proj, 500.0).x)));
    }

    group("channel");
    let mut mac = GaussianMac::new(s, 25, 1.0, 5);
    let frames: Vec<Vec<f32>> = (0..25)
        .map(|i| (0..s).map(|j| ((i + j) % 7) as f32 * 0.1).collect())
        .collect();
    Bench::new("GaussianMac transmit (M=25, s=d/2)")
        .throughput((25 * s) as u64)
        .run(|| black_box(mac.transmit(&frames)));

    group("gradient backend (rust reference)");
    let corpus = synthetic::generate(25 * 200, 9, 0);
    let mut prng = Pcg64::new(11);
    let shards = partition::iid(&corpus, 25, 200, &mut prng);
    let params = vec![0.01f32; D];
    let mut backend = RustBackend::new();
    Bench::new("per_device_gradients M=25 B=200")
        .iters(2, 8)
        .target_time(Duration::from_secs(4))
        .throughput((25 * 200) as u64)
        .run(|| black_box(backend.per_device_gradients(&params, &corpus, &shards)));

    group("linalg primitives");
    let x: Vec<f32> = (0..D).map(|i| (i % 13) as f32 * 0.1).collect();
    let yv: Vec<f32> = (0..D).map(|i| (i % 7) as f32 * 0.2).collect();
    Bench::new("dot d=7850")
        .throughput(D as u64)
        .run(|| black_box(tensor::dot(&x, &yv)));
    let mut out = vec![0f32; D];
    Bench::new("gemv_t (s̃×d)ᵀ·r")
        .iters(3, 15)
        .throughput((s_tilde * D) as u64)
        .run(|| {
            tensor::gemv_t(&proj.matrix, &y, &mut out);
            black_box(out[0])
        });
}
