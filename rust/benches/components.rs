//! Component microbenchmarks: every hot-path primitive of the stack at the
//! paper's shapes (d = 7850, s = d/2, k = s/2, M = 25). These are the
//! numbers PERF.md tracks before/after optimization.
//!
//! Every result is collected into a [`BenchSuite`] and written as
//! `BENCH_components.json` at the repo root (override with
//! `OTA_BENCH_JSON=<path>`). The seed formulations are benched alongside
//! the optimized kernels under "… reference …" names, so a single run
//! records an honest before/after pair on the same host and build;
//! `scripts/bench_compare.py` gates CI on >2× regressions of any entry vs
//! the committed snapshot.

use ota_dsgd::amp::{self, AmpConfig};
use ota_dsgd::analog::{AnalogDevice, Projection};
use ota_dsgd::channel::GaussianMac;
use ota_dsgd::compress::qsgd::QsgdCompressor;
use ota_dsgd::compress::sbc::SbcCompressor;
use ota_dsgd::compress::signsgd::SignSgdCompressor;
use ota_dsgd::compress::DigitalCompressor;
use ota_dsgd::coordinator::{DeviceSet, GradientBackend, RustBackend};
use ota_dsgd::data::{partition, synthetic};
use ota_dsgd::model::{self, PARAM_DIM};
use ota_dsgd::tensor;
use ota_dsgd::util::bench::{black_box, group, Bench, BenchSuite};
use ota_dsgd::util::rng::Pcg64;
use std::time::Duration;

const D: usize = PARAM_DIM;

fn random_grad(rng: &mut Pcg64) -> Vec<f32> {
    (0..D).map(|_| rng.normal_ms(0.0, 0.02) as f32).collect()
}

fn main() {
    let s = D / 2;
    let s_tilde = s - 1;
    let k = s / 2;
    let mut rng = Pcg64::new(1);
    let mut suite = BenchSuite::new("components");

    group("selection / sparsification (d = 7850)");
    let g = random_grad(&mut rng);
    suite.record(
        Bench::new(format!("topk_indices k={k}"))
            .throughput(D as u64)
            .run(|| black_box(tensor::topk_indices(&g, k))),
    );
    suite.record(
        Bench::new("sparsify_topk k=s/2")
            .throughput(D as u64)
            .run(|| black_box(tensor::sparsify_topk(&g, k))),
    );

    group("digital codecs (budget = R_t at P=500, s=d/2, M=25)");
    let budget = ota_dsgd::digital::capacity_bits(s, 25, 500.0, 1.0);
    println!("(R_t = {budget:.1} bits)");
    let mut sbc = SbcCompressor::new();
    suite.record(Bench::new("SBC encode (D-DSGD)").run(|| black_box(sbc.encode(&g, budget))));
    let mut sign = SignSgdCompressor::new();
    suite.record(Bench::new("SignSGD encode").run(|| black_box(sign.encode(&g, budget))));
    let mut qsgd = QsgdCompressor::new(2, 7);
    suite.record(Bench::new("QSGD encode").run(|| black_box(qsgd.encode(&g, budget))));
    suite.record(
        Bench::new("q_t budget search (SBC)")
            .run(|| black_box(SbcCompressor::pick_q(D, black_box(budget)))),
    );

    group("projection generation (s̃ = d/2 − 1)");
    suite.record(
        Bench::new("projection generate s̃×d (parallel)")
            .warmup(0)
            .iters(1, 3)
            .target_time(Duration::from_secs(4))
            .throughput((s_tilde * D) as u64)
            .run(|| black_box(Projection::generate(s_tilde, D, 3)).matrix.data[0]),
    );
    suite.record(
        Bench::new("projection generate s̃×d (workers=1 reference)")
            .warmup(0)
            .iters(1, 2)
            .target_time(Duration::from_secs(2))
            .throughput((s_tilde * D) as u64)
            .run(|| {
                black_box(Projection::generate_with_workers(s_tilde, D, 3, 1))
                    .matrix
                    .data[0]
            }),
    );

    group("analog pipeline (s̃ = d/2 − 1)");
    let proj = Projection::generate(s_tilde, D, 3);
    let mut dev = AnalogDevice::new(D, k);
    suite.record(
        Bench::new("A-DSGD device transmit (sparsify+project+scale)")
            .iters(3, 20)
            .target_time(Duration::from_secs(3))
            .run(|| black_box(dev.transmit(&g, &proj, 500.0))),
    );
    let mut dev_ref = AnalogDevice::new(D, k);
    suite.record(
        Bench::new("A-DSGD device transmit (reference unfused)")
            .iters(3, 20)
            .target_time(Duration::from_secs(3))
            .run(|| black_box(dev_ref.transmit_reference(&g, &proj, 500.0))),
    );
    let g_sp = tensor::sparsify_topk(&g, k);
    let support = tensor::topk_indices(&g, k);
    suite.record(
        Bench::new("projection apply_sparse (s̃·k MACs)")
            .iters(3, 20)
            .throughput((s_tilde * k) as u64)
            .run(|| black_box(proj.apply_sparse(&g_sp, &support))),
    );
    suite.record(
        Bench::new("projection apply_dense (s̃·d MACs)")
            .iters(3, 10)
            .throughput((s_tilde * D) as u64)
            .run(|| black_box(proj.apply_dense(&g_sp))),
    );

    group("AMP recovery at paper scale");
    let y = proj.apply_dense(&g_sp);
    for iters in [5usize, 15, 30] {
        let cfg = AmpConfig {
            max_iters: iters,
            tol: 0.0,
            threshold_mult: 1.1,
        };
        suite.record(
            Bench::new(format!("amp::recover max_iters={iters} (row-major only)"))
                .iters(2, 6)
                .target_time(Duration::from_secs(4))
                .run(|| black_box(amp::recover(&proj.matrix, &y, &cfg))),
        );
        suite.record(
            Bench::new(format!("amp::recover_with Aᵀ max_iters={iters} (production)"))
                .iters(2, 6)
                .target_time(Duration::from_secs(4))
                .run(|| black_box(amp::recover_with(&proj.matrix, Some(&proj.matrix_t), &y, &cfg))),
        );
    }
    {
        let cfg = AmpConfig {
            max_iters: 15,
            tol: 0.0,
            threshold_mult: 1.1,
        };
        suite.record(
            Bench::new("amp::recover_with Aᵀ max_iters=15 (reference unfused)")
                .iters(2, 6)
                .target_time(Duration::from_secs(4))
                .run(|| {
                    black_box(amp::recover_with_reference(
                        &proj.matrix,
                        Some(&proj.matrix_t),
                        &y,
                        &cfg,
                    ))
                }),
        );
    }

    group("device encode fan-out (M=25, DeviceSet::encode)");
    for workers in [1usize, 4] {
        let grads25: Vec<Vec<f32>> = {
            let mut r = Pcg64::new(21);
            (0..25)
                .map(|_| (0..D).map(|_| r.normal_ms(0.0, 0.02) as f32).collect())
                .collect()
        };
        let states: Vec<AnalogDevice> = (0..25).map(|_| AnalogDevice::new(D, k)).collect();
        let mut set = DeviceSet::with_workers(states, workers);
        suite.record(
            Bench::new(format!("A-DSGD encode M=25 workers={workers}"))
                .iters(2, 6)
                .target_time(Duration::from_secs(4))
                .throughput(25)
                .run(|| {
                    black_box(set.encode(|dev, st| st.transmit(&grads25[dev], &proj, 500.0).x))
                }),
        );
    }

    group("channel");
    let mut mac = GaussianMac::new(s, 25, 1.0, 5);
    let frames: Vec<Vec<f32>> = (0..25)
        .map(|i| (0..s).map(|j| ((i + j) % 7) as f32 * 0.1).collect())
        .collect();
    suite.record(
        Bench::new("GaussianMac transmit (M=25, s=d/2)")
            .throughput((25 * s) as u64)
            .run(|| black_box(mac.transmit(&frames))),
    );

    group("gradient backend (rust reference)");
    let corpus = synthetic::generate(25 * 200, 9, 0);
    let mut prng = Pcg64::new(11);
    let shards = partition::iid(&corpus, 25, 200, &mut prng);
    let params = vec![0.01f32; D];
    let mut backend = RustBackend::new();
    suite.record(
        Bench::new("per_device_gradients M=25 B=200")
            .iters(2, 8)
            .target_time(Duration::from_secs(4))
            .throughput((25 * 200) as u64)
            .run(|| black_box(backend.per_device_gradients(&params, &corpus, &shards))),
    );
    let mut gbuf = vec![0f32; D];
    suite.record(
        Bench::new("minibatch gradient B=200 (tiled)")
            .iters(3, 30)
            .target_time(Duration::from_secs(2))
            .throughput(200)
            .run(|| black_box(model::gradient(&params, &corpus, &shards[0], &mut gbuf))),
    );
    suite.record(
        Bench::new("minibatch gradient B=200 (reference per-sample)")
            .iters(3, 30)
            .target_time(Duration::from_secs(2))
            .throughput(200)
            .run(|| black_box(model::gradient_reference(&params, &corpus, &shards[0], &mut gbuf))),
    );

    group("linalg primitives");
    let x: Vec<f32> = (0..D).map(|i| (i % 13) as f32 * 0.1).collect();
    let yv: Vec<f32> = (0..D).map(|i| (i % 7) as f32 * 0.2).collect();
    suite.record(
        Bench::new("dot d=7850")
            .throughput(D as u64)
            .run(|| black_box(tensor::dot(&x, &yv))),
    );
    suite.record(
        Bench::new("dot d=7850 (reference scalar)")
            .throughput(D as u64)
            .run(|| black_box(tensor::reference::dot_scalar(&x, &yv))),
    );
    let mut out = vec![0f32; D];
    suite.record(
        Bench::new("gemv_t (s̃×d)ᵀ·r")
            .iters(3, 15)
            .throughput((s_tilde * D) as u64)
            .run(|| {
                tensor::gemv_t(&proj.matrix, &y, &mut out);
                black_box(out[0])
            }),
    );

    let path = BenchSuite::output_path("BENCH_components.json");
    match suite.write_json(&path) {
        Ok(()) => println!("\nwrote {} results to {}", suite.results().len(), path.display()),
        Err(e) => {
            eprintln!("failed to write {}: {e}", path.display());
            std::process::exit(1);
        }
    }
}
