//! Shared helpers for the bench targets (no criterion offline — the
//! harness lives in `ota_dsgd::util::bench`).

use ota_dsgd::config::{DatasetSpec, RunConfig};
use ota_dsgd::coordinator::Trainer;
use ota_dsgd::util::bench::{Bench, BenchResult};
use std::time::Duration;

/// Shrink a figure preset's *runtime* knobs so a bench round is fast, while
/// keeping the channel dimensions (s, k, d, M, P̄) paper-exact — those are
/// what the round cost depends on.
pub fn benchify(mut cfg: RunConfig, rounds: usize) -> RunConfig {
    cfg.local_samples = cfg.local_samples.min(200);
    cfg.iterations = rounds;
    cfg.eval_every = usize::MAX / 2; // no eval inside the timed region
    cfg.dataset = DatasetSpec::Synthetic {
        train: cfg.devices * cfg.local_samples,
        test: 64,
    };
    cfg
}

/// Time `rounds` synchronous rounds of the given config (setup excluded
/// from the timed region). Reports seconds *per round* via the throughput
/// field (rounds/sec).
pub fn bench_rounds(name: &str, cfg: RunConfig, rounds: usize) -> BenchResult {
    let cfg = benchify(cfg, rounds);
    // Corpus load/partition happens once, outside the timed region; each
    // timed call is a full T=`rounds` job (device transmit, MAC, decode,
    // optimizer) including per-run state init.
    let mut tr = Trainer::new(cfg).expect("trainer");
    Bench::new(name)
        .warmup(0)
        .iters(2, 5)
        .target_time(Duration::from_secs(4))
        .throughput(rounds as u64)
        .run(move || tr.run().records.len())
}

/// Entry-point boilerplate shared by the per-figure bench mains.
pub fn print_header(fig: &str, what: &str) {
    println!("=== bench {fig}: {what} ===");
    println!("(throughput column = DSGD rounds/sec incl. setup; lower-level component timings live in the `components` bench)");
}
