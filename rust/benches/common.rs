//! Shared helpers for the bench targets (no criterion offline — the
//! harness lives in `ota_dsgd::util::bench`).

use ota_dsgd::config::{DatasetSpec, RunConfig};
use ota_dsgd::coordinator::Trainer;
use ota_dsgd::util::bench::{Bench, BenchResult};
use std::time::Duration;

/// Shrink a figure preset's *runtime* knobs so a bench round is fast, while
/// keeping the channel dimensions (s, k, d, M, P̄) paper-exact — those are
/// what the round cost depends on.
pub fn benchify(mut cfg: RunConfig, rounds: usize) -> RunConfig {
    cfg.local_samples = cfg.local_samples.min(200);
    cfg.iterations = rounds;
    cfg.eval_every = usize::MAX / 2; // no eval inside the timed region
    cfg.dataset = DatasetSpec::Synthetic {
        train: cfg.devices * cfg.local_samples,
        test: 64,
    };
    cfg
}

/// Time `rounds` synchronous rounds of the given config (setup excluded
/// from the timed region). Reports seconds *per round* via the throughput
/// field (rounds/sec).
pub fn bench_rounds(name: &str, cfg: RunConfig, rounds: usize) -> BenchResult {
    let cfg = benchify(cfg, rounds);
    // Corpus load/partition happens once, outside the timed region; each
    // timed call is a full T=`rounds` job (device transmit, MAC, decode,
    // optimizer) including per-run state init.
    let mut tr = Trainer::new(cfg).expect("trainer");
    Bench::new(name)
        .warmup(0)
        .iters(2, 5)
        .target_time(Duration::from_secs(4))
        .throughput(rounds as u64)
        .run(move || tr.run().records.len())
}

/// Results directory for bench artifacts: `--out-dir <dir>` (or
/// `--out-dir=<dir>`) from the bench's argv, then the `OTA_OUT_DIR`
/// environment variable, then `results` — the same default the `repro`
/// CLI uses, so campaigns and CI stop hard-coding `results/`.
#[allow(dead_code)]
pub fn out_dir() -> String {
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        if arg == "--out-dir" {
            if let Some(v) = args.next() {
                return v;
            }
        } else if let Some(v) = arg.strip_prefix("--out-dir=") {
            return v.to_string();
        }
    }
    std::env::var("OTA_OUT_DIR").unwrap_or_else(|_| "results".into())
}

/// Entry-point boilerplate shared by the per-figure bench mains.
pub fn print_header(fig: &str, what: &str) {
    println!("=== bench {fig}: {what} ===");
    println!("(throughput column = DSGD rounds/sec incl. setup; lower-level component timings live in the `components` bench)");
}

// Not every bench binary includes a JSON-dumping sweep, so these helpers
// are dead code in the figure benches (each bench compiles its own copy
// of this module).
#[allow(dead_code)]
fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Dump a sweep's results as a JSON array (the artifact CI uploads).
#[allow(dead_code)]
pub fn write_json(path: &str, results: &[BenchResult]) -> std::io::Result<()> {
    use std::io::Write as _;
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "[")?;
    for (i, r) in results.iter().enumerate() {
        let comma = if i + 1 < results.len() { "," } else { "" };
        writeln!(
            f,
            "  {{\"name\": \"{}\", \"iters\": {}, \"mean_secs\": {:.9}, \"p50_secs\": {:.9}, \"p95_secs\": {:.9}, \"min_secs\": {:.9}, \"rounds_per_sec\": {}}}{comma}",
            json_escape(&r.name),
            r.iters,
            r.mean.as_secs_f64(),
            r.p50.as_secs_f64(),
            r.p95.as_secs_f64(),
            r.min.as_secs_f64(),
            r.throughput
                .map(|t| format!("{t:.4}"))
                .unwrap_or_else(|| "null".into()),
        )?;
    }
    writeln!(f, "]")?;
    Ok(())
}
