//! Fading-MAC sweep bench: time one round of every run in the fading
//! experiment spec (CSI thresholds, blind, partial participation,
//! stragglers, plus the static anchors) and dump the results as JSON —
//! `results/fading_sweep.json` — which CI uploads as an artifact so
//! per-round fading cost is tracked across commits.

#[path = "common.rs"]
mod common;

use ota_dsgd::experiments::figures;

fn main() {
    common::print_header("fading", "fading MAC: CSI thresholds, blind, participation, stragglers");
    let spec = figures::fading(false);
    let mut results = Vec::new();
    for (label, cfg) in spec.runs {
        results.push(common::bench_rounds(&label, cfg, 2));
    }
    let path = format!("{}/fading_sweep.json", common::out_dir());
    common::write_json(&path, &results).expect("write bench json");
    println!("json → {path}");
}
