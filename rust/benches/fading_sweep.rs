//! Fading-MAC sweep bench: time one round of every run in the fading
//! experiment spec (CSI thresholds, blind, partial participation,
//! stragglers, plus the static anchors) and dump the results as JSON —
//! `results/fading_sweep.json` — which CI uploads as an artifact so
//! per-round fading cost is tracked across commits.

#[path = "common.rs"]
mod common;

use std::io::Write as _;

use ota_dsgd::experiments::figures;
use ota_dsgd::util::bench::BenchResult;

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn write_json(path: &str, results: &[BenchResult]) -> std::io::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "[")?;
    for (i, r) in results.iter().enumerate() {
        let comma = if i + 1 < results.len() { "," } else { "" };
        writeln!(
            f,
            "  {{\"name\": \"{}\", \"iters\": {}, \"mean_secs\": {:.9}, \"p50_secs\": {:.9}, \"p95_secs\": {:.9}, \"min_secs\": {:.9}, \"rounds_per_sec\": {}}}{comma}",
            json_escape(&r.name),
            r.iters,
            r.mean.as_secs_f64(),
            r.p50.as_secs_f64(),
            r.p95.as_secs_f64(),
            r.min.as_secs_f64(),
            r.throughput
                .map(|t| format!("{t:.4}"))
                .unwrap_or_else(|| "null".into()),
        )?;
    }
    writeln!(f, "]")?;
    Ok(())
}

fn main() {
    common::print_header("fading", "fading MAC: CSI thresholds, blind, participation, stragglers");
    let spec = figures::fading(false);
    let mut results = Vec::new();
    for (label, cfg) in spec.runs {
        results.push(common::bench_rounds(&label, cfg, 2));
    }
    let path = "results/fading_sweep.json";
    write_json(path, &results).expect("write bench json");
    println!("json → {path}");
}
