//! D2D topology sweep bench: time one round of every run in the D2D
//! experiment spec (the star anchor plus fully-connected / ring / torus /
//! Erdős–Rényi consensus) and dump the results as JSON —
//! `results/d2d_sweep.json` — which CI uploads as an artifact so the
//! per-round cost of decentralized rounds (one AMP decode per distinct
//! neighborhood) is tracked across commits.

#[path = "common.rs"]
mod common;

use ota_dsgd::experiments::figures;

fn main() {
    common::print_header(
        "d2d",
        "D2D over-the-air consensus: graph families vs the star anchor",
    );
    let spec = figures::d2d(false);
    let mut results = Vec::new();
    for (label, cfg) in spec.runs {
        results.push(common::bench_rounds(&label, cfg, 2));
    }
    let path = format!("{}/d2d_sweep.json", common::out_dir());
    common::write_json(&path, &results).expect("write bench json");
    println!("json → {path}");
}
