//! Fig. 4 bench: A-DSGD vs D-DSGD round cost across P̄ ∈ {200, 1000}.

#[path = "common.rs"]
mod common;

use ota_dsgd::experiments::figures;

fn main() {
    common::print_header("fig4", "average-power sweep");
    let spec = figures::fig4(false);
    for (label, cfg) in spec.runs {
        common::bench_rounds(&label, cfg, 2);
    }
}
