//! Fig. 5 bench: round cost across channel bandwidths s ∈ {d/2, 3d/10}
//! (the AMP/projection cost scales with s — this is where bandwidth hits
//! compute).

#[path = "common.rs"]
mod common;

use ota_dsgd::experiments::figures;

fn main() {
    common::print_header("fig5", "channel-bandwidth sweep (M=20)");
    let spec = figures::fig5(false);
    for (label, cfg) in spec.runs {
        common::bench_rounds(&label, cfg, 2);
    }
}
