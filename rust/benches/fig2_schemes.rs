//! Fig. 2 bench: one-round cost of every scheme at the figure's channel
//! shape (M=25, s=d/2, k=s/2, P̄=500), IID and non-IID.

#[path = "common.rs"]
mod common;

use ota_dsgd::experiments::figures;

fn main() {
    common::print_header("fig2", "scheme shoot-out (IID + non-IID)");
    for noniid in [false, true] {
        let spec = figures::fig2(noniid, false);
        for (label, cfg) in spec.runs {
            let tag = if noniid { "non-IID" } else { "IID" };
            common::bench_rounds(&format!("{label} [{tag}]"), cfg, 2);
        }
    }
}
