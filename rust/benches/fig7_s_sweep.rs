//! Fig. 7 bench: A-DSGD round cost across s ∈ {d/10, d/5, d/2} with
//! k = 4s/5 — the bandwidth/latency trade-off's compute side: smaller s
//! means cheaper rounds (Fig. 7b's x-axis is t·s).

#[path = "common.rs"]
mod common;

use ota_dsgd::experiments::figures;

fn main() {
    common::print_header("fig7", "A-DSGD bandwidth/latency sweep (P̄=50)");
    let spec = figures::fig7(false);
    for (label, cfg) in spec.runs {
        common::bench_rounds(&label, cfg, 2);
    }
}
