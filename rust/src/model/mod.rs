//! The learning model: the paper's single-layer network for 10-class MNIST
//! classification, d = 784·10 + 10 = 7850 parameters, softmax cross-entropy
//! loss (§VI trains it with ADAM at the PS).
//!
//! This pure-rust implementation is the reference path and the test oracle
//! for the L2 JAX graph (`python/compile/model.py`); the coordinator can
//! compute gradients with either backend (`grad` module in `coordinator`).

use crate::data::{Dataset, IMG_PIXELS, NUM_CLASSES};
use crate::tensor::{softmax, Matf};

/// Total parameter count d = 7850.
pub const PARAM_DIM: usize = IMG_PIXELS * NUM_CLASSES + NUM_CLASSES;

/// Flat parameter layout: `[W row-major (10×784) | b (10)]`.
#[inline]
pub fn w_slice(params: &[f32]) -> &[f32] {
    &params[..IMG_PIXELS * NUM_CLASSES]
}

#[inline]
pub fn b_slice(params: &[f32]) -> &[f32] {
    &params[IMG_PIXELS * NUM_CLASSES..]
}

/// Compute logits for one image: logits[c] = W_c · x + b_c.
pub fn logits(params: &[f32], image: &[f32], out: &mut [f32; NUM_CLASSES]) {
    debug_assert_eq!(params.len(), PARAM_DIM);
    debug_assert_eq!(image.len(), IMG_PIXELS);
    let w = w_slice(params);
    let b = b_slice(params);
    for c in 0..NUM_CLASSES {
        out[c] = crate::tensor::dot(&w[c * IMG_PIXELS..(c + 1) * IMG_PIXELS], image) + b[c];
    }
}

/// Average softmax cross-entropy loss over a dataset shard.
pub fn loss(params: &[f32], data: &Dataset, idx: &[usize]) -> f64 {
    let mut lg = [0f32; NUM_CLASSES];
    let mut probs = [0f32; NUM_CLASSES];
    let mut total = 0f64;
    for &i in idx {
        logits(params, data.image(i), &mut lg);
        softmax(&lg, &mut probs);
        let p = probs[data.label(i)].max(1e-12);
        total -= (p as f64).ln();
    }
    total / idx.len().max(1) as f64
}

/// Gradient of the average loss over `idx`, written into `grad` (len d).
/// Returns the loss as a by-product.
pub fn gradient(params: &[f32], data: &Dataset, idx: &[usize], grad: &mut [f32]) -> f64 {
    assert_eq!(params.len(), PARAM_DIM);
    assert_eq!(grad.len(), PARAM_DIM);
    grad.fill(0.0);
    let inv_n = 1.0 / idx.len().max(1) as f32;
    let mut lg = [0f32; NUM_CLASSES];
    let mut probs = [0f32; NUM_CLASSES];
    let mut total_loss = 0f64;
    let (gw, gb) = grad.split_at_mut(IMG_PIXELS * NUM_CLASSES);
    for &i in idx {
        let x = data.image(i);
        logits(params, x, &mut lg);
        softmax(&lg, &mut probs);
        let y = data.label(i);
        total_loss -= (probs[y].max(1e-12) as f64).ln();
        for c in 0..NUM_CLASSES {
            // dL/dlogit_c = p_c − 1{c==y}
            let err = (probs[c] - if c == y { 1.0 } else { 0.0 }) * inv_n;
            if err != 0.0 {
                crate::tensor::axpy(err, x, &mut gw[c * IMG_PIXELS..(c + 1) * IMG_PIXELS]);
                gb[c] += err;
            }
        }
    }
    total_loss / idx.len().max(1) as f64
}

/// Classification accuracy over a dataset (all rows).
pub fn accuracy(params: &[f32], data: &Dataset) -> f64 {
    let mut lg = [0f32; NUM_CLASSES];
    let mut correct = 0usize;
    for i in 0..data.len() {
        logits(params, data.image(i), &mut lg);
        let pred = lg
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred == data.label(i) {
            correct += 1;
        }
    }
    correct as f64 / data.len().max(1) as f64
}

/// Finite-difference gradient check helper (tests + python cross-check).
pub fn numeric_gradient(
    params: &[f32],
    data: &Dataset,
    idx: &[usize],
    coords: &[usize],
    eps: f32,
) -> Vec<f32> {
    let mut p = params.to_vec();
    let mut out = Vec::with_capacity(coords.len());
    for &c in coords {
        let orig = p[c];
        p[c] = orig + eps;
        let lp = loss(&p, data, idx);
        p[c] = orig - eps;
        let lm = loss(&p, data, idx);
        p[c] = orig;
        out.push(((lp - lm) / (2.0 * eps as f64)) as f32);
    }
    out
}

/// Batched per-device gradients: one row per device shard. This is the
/// rust mirror of the L2 JAX graph's `[M, B, 784] → [M, d]` signature.
pub fn per_device_gradients(
    params: &[f32],
    data: &Dataset,
    shards: &[Vec<usize>],
    workers: usize,
) -> Matf {
    let m = shards.len();
    let rows = crate::util::threadpool::par_map(m, workers, |dev| {
        let mut g = vec![0f32; PARAM_DIM];
        gradient(params, data, &shards[dev], &mut g);
        g
    });
    let mut out = Matf::zeros(m, PARAM_DIM);
    for (r, row) in rows.into_iter().enumerate() {
        out.row_mut(r).copy_from_slice(&row);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::rng::Pcg64;

    fn random_params(rng: &mut Pcg64) -> Vec<f32> {
        (0..PARAM_DIM).map(|_| rng.normal() as f32 * 0.01).collect()
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let ds = synthetic::generate(20, 1, 0);
        let idx: Vec<usize> = (0..20).collect();
        let mut rng = Pcg64::new(2);
        let params = random_params(&mut rng);
        let mut grad = vec![0f32; PARAM_DIM];
        gradient(&params, &ds, &idx, &mut grad);
        // Check a scatter of coordinates incl. weights and biases.
        let coords = vec![0, 5, 783, 784, 4000, 7839, 7840, 7845, 7849];
        let num = numeric_gradient(&params, &ds, &idx, &coords, 1e-3);
        for (j, &c) in coords.iter().enumerate() {
            let a = grad[c];
            let n = num[j];
            assert!(
                (a - n).abs() < 2e-3 + 0.05 * n.abs(),
                "coord {c}: analytic {a} vs numeric {n}"
            );
        }
    }

    #[test]
    fn loss_decreases_under_gd() {
        let ds = synthetic::generate(100, 3, 0);
        let idx: Vec<usize> = (0..100).collect();
        let mut params = vec![0f32; PARAM_DIM];
        let mut grad = vec![0f32; PARAM_DIM];
        let l0 = gradient(&params, &ds, &idx, &mut grad);
        for _ in 0..20 {
            let g = grad.clone();
            for (p, gi) in params.iter_mut().zip(&g) {
                *p -= 0.5 * gi;
            }
            gradient(&params, &ds, &idx, &mut grad);
        }
        let l1 = loss(&params, &ds, &idx);
        assert!(l1 < l0, "loss {l0} -> {l1} should decrease");
    }

    #[test]
    fn zero_params_loss_is_ln10() {
        let ds = synthetic::generate(50, 4, 0);
        let idx: Vec<usize> = (0..50).collect();
        let params = vec![0f32; PARAM_DIM];
        let l = loss(&params, &ds, &idx);
        assert!((l - (10f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn accuracy_improves_with_training() {
        let ds = synthetic::generate(400, 5, 0);
        let test = synthetic::generate(200, 5, 1);
        let idx: Vec<usize> = (0..400).collect();
        let mut params = vec![0f32; PARAM_DIM];
        let acc0 = accuracy(&params, &test);
        let mut grad = vec![0f32; PARAM_DIM];
        for _ in 0..60 {
            gradient(&params, &ds, &idx, &mut grad);
            for (p, g) in params.iter_mut().zip(&grad) {
                *p -= 1.0 * g;
            }
        }
        let acc1 = accuracy(&params, &test);
        assert!(
            acc1 > acc0 + 0.3,
            "training should lift accuracy well above chance: {acc0} -> {acc1}"
        );
    }

    #[test]
    fn per_device_rows_match_sequential() {
        let ds = synthetic::generate(60, 6, 0);
        let shards = vec![(0..30).collect::<Vec<_>>(), (30..60).collect::<Vec<_>>()];
        let mut rng = Pcg64::new(7);
        let params = random_params(&mut rng);
        let batched = per_device_gradients(&params, &ds, &shards, 2);
        for (d, shard) in shards.iter().enumerate() {
            let mut g = vec![0f32; PARAM_DIM];
            gradient(&params, &ds, shard, &mut g);
            assert_eq!(batched.row(d), &g[..]);
        }
    }
}
