//! The learning model: the paper's single-layer network for 10-class MNIST
//! classification, d = 784·10 + 10 = 7850 parameters, softmax cross-entropy
//! loss (§VI trains it with ADAM at the PS).
//!
//! This pure-rust implementation is the reference path and the test oracle
//! for the L2 JAX graph (`python/compile/model.py`); the coordinator can
//! compute gradients with either backend (`grad` module in `coordinator`).
//!
//! # Perf (see PERF.md)
//!
//! [`gradient`] is the blocked formulation: samples are processed in tiles
//! of [`GRAD_TILE`]; the forward pass (logits → softmax → error) runs
//! sample-major exactly as before, then the backward rank-k update runs
//! class-major over the tile with 4-sample fused [`crate::tensor::axpy4`]
//! blocks, so each 784-float gradient row is loaded/stored once per 4
//! samples instead of once per sample. Both [`logits`] (4 classes share one
//! pass over the image via `dot4`) and the backward pass preserve the
//! seed's per-destination floating-point add order, so [`gradient`] is
//! **bit-identical** to [`gradient_reference`] — enforced by tests here and
//! in `rust/tests/kernel_contracts.rs`, and what keeps the golden
//! trajectories and campaign-resume suites byte-stable.

use crate::data::{Dataset, IMG_PIXELS, NUM_CLASSES};
use crate::tensor::{softmax, Matf};

/// Total parameter count d = 7850.
pub const PARAM_DIM: usize = IMG_PIXELS * NUM_CLASSES + NUM_CLASSES;

/// Sample-tile size for the blocked gradient: 32 error rows (1.3 KB) plus
/// 32 cached 784-float images (~100 KB) stay L2-resident while the 31 KB
/// weight gradient streams through L1. Mirrors the BLOCK_M row-tiling in
/// `python/compile/kernels/matmul.py`.
pub const GRAD_TILE: usize = 32;

/// Flat parameter layout: `[W row-major (10×784) | b (10)]`.
#[inline]
pub fn w_slice(params: &[f32]) -> &[f32] {
    &params[..IMG_PIXELS * NUM_CLASSES]
}

#[inline]
pub fn b_slice(params: &[f32]) -> &[f32] {
    &params[IMG_PIXELS * NUM_CLASSES..]
}

/// Compute logits for one image: logits[c] = W_c · x + b_c.
///
/// Four weight rows share each streaming pass over the image via
/// [`crate::tensor::dot4`]; every logit is bit-identical to the per-class
/// `dot(W_c, x) + b_c` formulation.
pub fn logits(params: &[f32], image: &[f32], out: &mut [f32; NUM_CLASSES]) {
    debug_assert_eq!(params.len(), PARAM_DIM);
    debug_assert_eq!(image.len(), IMG_PIXELS);
    let w = w_slice(params);
    let b = b_slice(params);
    let row = |c: usize| &w[c * IMG_PIXELS..(c + 1) * IMG_PIXELS];
    let mut c = 0usize;
    while c + 4 <= NUM_CLASSES {
        let d4 = crate::tensor::dot4(row(c), row(c + 1), row(c + 2), row(c + 3), image);
        out[c] = d4[0] + b[c];
        out[c + 1] = d4[1] + b[c + 1];
        out[c + 2] = d4[2] + b[c + 2];
        out[c + 3] = d4[3] + b[c + 3];
        c += 4;
    }
    while c < NUM_CLASSES {
        out[c] = crate::tensor::dot(row(c), image) + b[c];
        c += 1;
    }
}

/// Average softmax cross-entropy loss over a dataset shard.
pub fn loss(params: &[f32], data: &Dataset, idx: &[usize]) -> f64 {
    let mut lg = [0f32; NUM_CLASSES];
    let mut probs = [0f32; NUM_CLASSES];
    let mut total = 0f64;
    for &i in idx {
        logits(params, data.image(i), &mut lg);
        softmax(&lg, &mut probs);
        let p = probs[data.label(i)].max(1e-12);
        total -= (p as f64).ln();
    }
    total / idx.len().max(1) as f64
}

/// Gradient of the average loss over `idx`, written into `grad` (len d).
/// Returns the loss as a by-product.
///
/// Blocked formulation: per [`GRAD_TILE`]-sample tile, the forward pass
/// fills an error matrix sample-major (identical order to the seed), then
/// the backward pass accumulates each class's weight-gradient row over the
/// tile's samples in ascending order with fused 4-sample
/// [`crate::tensor::axpy4`] updates. Since f32 adds into each destination
/// happen in the seed's exact order (samples ascending per class row, the
/// zero-error skip preserved), the result is bit-identical to
/// [`gradient_reference`].
pub fn gradient(params: &[f32], data: &Dataset, idx: &[usize], grad: &mut [f32]) -> f64 {
    assert_eq!(params.len(), PARAM_DIM);
    assert_eq!(grad.len(), PARAM_DIM);
    grad.fill(0.0);
    let inv_n = 1.0 / idx.len().max(1) as f32;
    let mut lg = [0f32; NUM_CLASSES];
    let mut probs = [0f32; NUM_CLASSES];
    let mut err = [[0f32; NUM_CLASSES]; GRAD_TILE];
    let mut total_loss = 0f64;
    let (gw, gb) = grad.split_at_mut(IMG_PIXELS * NUM_CLASSES);
    for tile in idx.chunks(GRAD_TILE) {
        // Forward: logits → softmax → scaled error rows, sample-major.
        for (t, &i) in tile.iter().enumerate() {
            logits(params, data.image(i), &mut lg);
            softmax(&lg, &mut probs);
            let y = data.label(i);
            total_loss -= (probs[y].max(1e-12) as f64).ln();
            for c in 0..NUM_CLASSES {
                // dL/dlogit_c = p_c − 1{c==y}
                let e = (probs[c] - if c == y { 1.0 } else { 0.0 }) * inv_n;
                err[t][c] = e;
                if e != 0.0 {
                    gb[c] += e;
                }
            }
        }
        // Backward: rank-|tile| update, class-major so each gradient row
        // stays hot across the whole tile.
        for c in 0..NUM_CLASSES {
            let gwc = &mut gw[c * IMG_PIXELS..(c + 1) * IMG_PIXELS];
            let mut t = 0usize;
            while t + 4 <= tile.len() {
                let co = [err[t][c], err[t + 1][c], err[t + 2][c], err[t + 3][c]];
                if co[0] != 0.0 && co[1] != 0.0 && co[2] != 0.0 && co[3] != 0.0 {
                    crate::tensor::axpy4(
                        co,
                        data.image(tile[t]),
                        data.image(tile[t + 1]),
                        data.image(tile[t + 2]),
                        data.image(tile[t + 3]),
                        gwc,
                    );
                } else {
                    for (j, &cj) in co.iter().enumerate() {
                        if cj != 0.0 {
                            crate::tensor::axpy(cj, data.image(tile[t + j]), gwc);
                        }
                    }
                }
                t += 4;
            }
            while t < tile.len() {
                let e = err[t][c];
                if e != 0.0 {
                    crate::tensor::axpy(e, data.image(tile[t]), gwc);
                }
                t += 1;
            }
        }
    }
    total_loss / idx.len().max(1) as f64
}

/// The seed's per-sample gradient formulation (one dot+axpy pass per
/// sample and class), kept verbatim as the bit-identity oracle for
/// [`gradient`] and as the "before" timing in the components bench. Not
/// used by any training path.
pub fn gradient_reference(params: &[f32], data: &Dataset, idx: &[usize], grad: &mut [f32]) -> f64 {
    assert_eq!(params.len(), PARAM_DIM);
    assert_eq!(grad.len(), PARAM_DIM);
    grad.fill(0.0);
    let inv_n = 1.0 / idx.len().max(1) as f32;
    let mut lg = [0f32; NUM_CLASSES];
    let mut probs = [0f32; NUM_CLASSES];
    let mut total_loss = 0f64;
    let (gw, gb) = grad.split_at_mut(IMG_PIXELS * NUM_CLASSES);
    for &i in idx {
        let x = data.image(i);
        logits(params, x, &mut lg);
        softmax(&lg, &mut probs);
        let y = data.label(i);
        total_loss -= (probs[y].max(1e-12) as f64).ln();
        for c in 0..NUM_CLASSES {
            let err = (probs[c] - if c == y { 1.0 } else { 0.0 }) * inv_n;
            if err != 0.0 {
                crate::tensor::axpy(err, x, &mut gw[c * IMG_PIXELS..(c + 1) * IMG_PIXELS]);
                gb[c] += err;
            }
        }
    }
    total_loss / idx.len().max(1) as f64
}

/// Classification accuracy over a dataset (all rows).
pub fn accuracy(params: &[f32], data: &Dataset) -> f64 {
    let mut lg = [0f32; NUM_CLASSES];
    let mut correct = 0usize;
    for i in 0..data.len() {
        logits(params, data.image(i), &mut lg);
        let pred = lg
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred == data.label(i) {
            correct += 1;
        }
    }
    correct as f64 / data.len().max(1) as f64
}

/// Finite-difference gradient check helper (tests + python cross-check).
pub fn numeric_gradient(
    params: &[f32],
    data: &Dataset,
    idx: &[usize],
    coords: &[usize],
    eps: f32,
) -> Vec<f32> {
    let mut p = params.to_vec();
    let mut out = Vec::with_capacity(coords.len());
    for &c in coords {
        let orig = p[c];
        p[c] = orig + eps;
        let lp = loss(&p, data, idx);
        p[c] = orig - eps;
        let lm = loss(&p, data, idx);
        p[c] = orig;
        out.push(((lp - lm) / (2.0 * eps as f64)) as f32);
    }
    out
}

/// Batched per-device gradients: one row per device shard. This is the
/// rust mirror of the L2 JAX graph's `[M, B, 784] → [M, d]` signature.
pub fn per_device_gradients(
    params: &[f32],
    data: &Dataset,
    shards: &[Vec<usize>],
    workers: usize,
) -> Matf {
    let m = shards.len();
    let rows = crate::util::threadpool::par_map(m, workers, |dev| {
        let mut g = vec![0f32; PARAM_DIM];
        gradient(params, data, &shards[dev], &mut g);
        g
    });
    let mut out = Matf::zeros(m, PARAM_DIM);
    for (r, row) in rows.into_iter().enumerate() {
        out.row_mut(r).copy_from_slice(&row);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::rng::Pcg64;

    fn random_params(rng: &mut Pcg64) -> Vec<f32> {
        (0..PARAM_DIM).map(|_| rng.normal() as f32 * 0.01).collect()
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let ds = synthetic::generate(20, 1, 0);
        let idx: Vec<usize> = (0..20).collect();
        let mut rng = Pcg64::new(2);
        let params = random_params(&mut rng);
        let mut grad = vec![0f32; PARAM_DIM];
        gradient(&params, &ds, &idx, &mut grad);
        // Check a scatter of coordinates incl. weights and biases.
        let coords = vec![0, 5, 783, 784, 4000, 7839, 7840, 7845, 7849];
        let num = numeric_gradient(&params, &ds, &idx, &coords, 1e-3);
        for (j, &c) in coords.iter().enumerate() {
            let a = grad[c];
            let n = num[j];
            assert!(
                (a - n).abs() < 2e-3 + 0.05 * n.abs(),
                "coord {c}: analytic {a} vs numeric {n}"
            );
        }
    }

    #[test]
    fn gradient_tiled_matches_reference_bitwise() {
        // Batch sizes straddling the tile: below, at, above, and with a
        // ragged tail — every one must be bit-identical to the seed
        // formulation (loss included).
        let ds = synthetic::generate(3 * GRAD_TILE, 8, 0);
        let mut rng = Pcg64::new(21);
        let params = random_params(&mut rng);
        for &n in &[1usize, 5, GRAD_TILE - 1, GRAD_TILE, GRAD_TILE + 3, 3 * GRAD_TILE] {
            let idx: Vec<usize> = (0..n).collect();
            let mut g_tiled = vec![0f32; PARAM_DIM];
            let mut g_ref = vec![0f32; PARAM_DIM];
            let l_tiled = gradient(&params, &ds, &idx, &mut g_tiled);
            let l_ref = gradient_reference(&params, &ds, &idx, &mut g_ref);
            assert_eq!(l_tiled.to_bits(), l_ref.to_bits(), "loss differs at n={n}");
            for (j, (a, b)) in g_tiled.iter().zip(&g_ref).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "coord {j} differs at n={n}");
            }
        }
    }

    #[test]
    fn loss_decreases_under_gd() {
        let ds = synthetic::generate(100, 3, 0);
        let idx: Vec<usize> = (0..100).collect();
        let mut params = vec![0f32; PARAM_DIM];
        let mut grad = vec![0f32; PARAM_DIM];
        let l0 = gradient(&params, &ds, &idx, &mut grad);
        for _ in 0..20 {
            let g = grad.clone();
            for (p, gi) in params.iter_mut().zip(&g) {
                *p -= 0.5 * gi;
            }
            gradient(&params, &ds, &idx, &mut grad);
        }
        let l1 = loss(&params, &ds, &idx);
        assert!(l1 < l0, "loss {l0} -> {l1} should decrease");
    }

    #[test]
    fn zero_params_loss_is_ln10() {
        let ds = synthetic::generate(50, 4, 0);
        let idx: Vec<usize> = (0..50).collect();
        let params = vec![0f32; PARAM_DIM];
        let l = loss(&params, &ds, &idx);
        assert!((l - (10f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn accuracy_improves_with_training() {
        let ds = synthetic::generate(400, 5, 0);
        let test = synthetic::generate(200, 5, 1);
        let idx: Vec<usize> = (0..400).collect();
        let mut params = vec![0f32; PARAM_DIM];
        let acc0 = accuracy(&params, &test);
        let mut grad = vec![0f32; PARAM_DIM];
        for _ in 0..60 {
            gradient(&params, &ds, &idx, &mut grad);
            for (p, g) in params.iter_mut().zip(&grad) {
                *p -= 1.0 * g;
            }
        }
        let acc1 = accuracy(&params, &test);
        assert!(
            acc1 > acc0 + 0.3,
            "training should lift accuracy well above chance: {acc0} -> {acc1}"
        );
    }

    #[test]
    fn per_device_rows_match_sequential() {
        let ds = synthetic::generate(60, 6, 0);
        let shards = vec![(0..30).collect::<Vec<_>>(), (30..60).collect::<Vec<_>>()];
        let mut rng = Pcg64::new(7);
        let params = random_params(&mut rng);
        let batched = per_device_gradients(&params, &ds, &shards, 2);
        for (d, shard) in shards.iter().enumerate() {
            let mut g = vec![0f32; PARAM_DIM];
            gradient(&params, &ds, shard, &mut g);
            assert_eq!(batched.row(d), &g[..]);
        }
    }
}
