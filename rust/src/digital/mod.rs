//! Digital DSGD (§III): separation-based computation + communication.
//!
//! Per iteration t each device gets the capacity budget
//! `R_t = s/(2M)·log2(1 + M·P_t/(sσ²))` (Eq. 8, [`crate::compress::bits`]),
//! compresses its (error-compensated, for D-DSGD) gradient within that
//! budget, and — because the paper assumes capacity-achieving codes — the
//! payload arrives error-free whenever it fits. The device spends
//! `‖x_m(t)‖² = P_t` of energy regardless, which the coordinator meters
//! against Eq. 6.

use crate::compress::qsgd::QsgdCompressor;
use crate::compress::sbc::SbcCompressor;
use crate::compress::signsgd::SignSgdCompressor;
use crate::compress::{DigitalCompressor, DigitalPayload, ErrorAccumulator};
use crate::config::Scheme;

pub use crate::compress::bits::capacity_bits;

/// Device-side state for one digital participant.
pub struct DigitalDevice {
    compressor: Box<dyn DigitalCompressor>,
    /// D-DSGD carries local error accumulation (§III); the SignSGD/QSGD
    /// baselines follow their source papers and do not.
    accum: Option<ErrorAccumulator>,
}

impl DigitalDevice {
    /// Build the device pipeline for a digital scheme. `dim` is d.
    pub fn new(scheme: Scheme, dim: usize, qsgd_levels: u32, seed: u64) -> DigitalDevice {
        let (compressor, use_accum): (Box<dyn DigitalCompressor>, bool) = match scheme {
            Scheme::DDsgd => (Box::new(SbcCompressor::new()), true),
            Scheme::SignSgd => (Box::new(SignSgdCompressor::new()), false),
            Scheme::Qsgd => (Box::new(QsgdCompressor::new(qsgd_levels, seed)), false),
            other => panic!("{other:?} is not a digital scheme"),
        };
        DigitalDevice {
            compressor,
            accum: use_accum.then(|| ErrorAccumulator::new(dim)),
        }
    }

    /// One iteration: compress the local gradient within `budget_bits`.
    pub fn transmit(&mut self, g: &[f32], budget_bits: f64) -> DigitalPayload {
        match &mut self.accum {
            Some(acc) => {
                let g_ec = acc.compensate(g);
                let payload = self.compressor.encode(&g_ec, budget_bits);
                acc.update(&g_ec, &payload.reconstruction);
                payload
            }
            None => self.compressor.encode(g, budget_bits),
        }
    }

    /// A round in which this device is not scheduled: nothing is
    /// transmitted, so D-DSGD banks the whole gradient in its error
    /// accumulator — Δ(t+1) = g + Δ(t) — and delivers it once scheduled
    /// again. The SignSGD/QSGD baselines carry no accumulator (faithful to
    /// their source papers), so a silent round genuinely loses their
    /// gradient.
    pub fn absorb(&mut self, g: &[f32]) {
        if let Some(acc) = &mut self.accum {
            acc.bank(g);
        }
    }

    pub fn accumulator_norm(&self) -> f64 {
        self.accum.as_ref().map(|a| a.norm()).unwrap_or(0.0)
    }

    /// Error residual Δ for checkpointing (`None` for the accumulator-free
    /// baselines — absent state, not an all-zero vector).
    pub fn accumulator(&self) -> Option<&[f32]> {
        self.accum.as_ref().map(|a| a.as_slice())
    }

    /// Restore a residual captured by [`DigitalDevice::accumulator`].
    /// No-op for baselines without an accumulator.
    pub fn load_accumulator(&mut self, delta: &[f32]) {
        if let Some(acc) = &mut self.accum {
            acc.load(delta);
        }
    }

    /// Compressor RNG position for checkpointing (QSGD's stochastic
    /// rounding stream; `None` for deterministic compressors).
    pub fn rng_state(&self) -> Option<(u64, u64, Option<f64>)> {
        self.compressor.rng_state()
    }

    /// Restore a position captured by [`DigitalDevice::rng_state`].
    pub fn restore_rng(&mut self, state: (u64, u64, Option<f64>)) {
        self.compressor.restore_rng(state);
    }

    pub fn compressor_name(&self) -> &'static str {
        self.compressor.name()
    }
}

/// PS-side aggregation of digital payloads: the average of the decoded
/// per-device reconstructions (Eq. 4's inner sum).
pub fn aggregate(payloads: &[DigitalPayload], dim: usize) -> Vec<f32> {
    let mut out = vec![0f32; dim];
    if payloads.is_empty() {
        return out;
    }
    for p in payloads {
        debug_assert_eq!(p.reconstruction.len(), dim);
        for (o, &r) in out.iter_mut().zip(&p.reconstruction) {
            *o += r;
        }
    }
    let inv = 1.0 / payloads.len() as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ddsgd_uses_error_accumulation() {
        let mut dev = DigitalDevice::new(Scheme::DDsgd, 64, 2, 1);
        let g: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) / 10.0).collect();
        // Tight budget → much left behind → accumulator non-zero.
        let budget = SbcCompressor::bit_cost(64, 2) + 0.5;
        let p = dev.transmit(&g, budget);
        assert!(p.bits <= budget);
        assert!(dev.accumulator_norm() > 0.0);
    }

    #[test]
    fn baselines_do_not_accumulate() {
        for scheme in [Scheme::SignSgd, Scheme::Qsgd] {
            let mut dev = DigitalDevice::new(scheme, 32, 2, 1);
            let g = vec![1.0f32; 32];
            let _ = dev.transmit(&g, 100.0);
            assert_eq!(dev.accumulator_norm(), 0.0, "{scheme:?}");
        }
    }

    #[test]
    fn ddsgd_residual_flushes_over_rounds() {
        // With zero new gradient after round 1, repeated D-DSGD rounds must
        // drain what the first compression left behind.
        let dim = 32;
        let mut dev = DigitalDevice::new(Scheme::DDsgd, dim, 2, 1);
        let g0: Vec<f32> = (0..dim).map(|i| 1.0 + (i as f32) * 0.1).collect();
        let budget = SbcCompressor::bit_cost(dim, 4) + 0.5;
        let mut recovered = vec![0f32; dim];
        let zero = vec![0f32; dim];
        let p = dev.transmit(&g0, budget);
        for (r, v) in recovered.iter_mut().zip(&p.reconstruction) {
            *r += v;
        }
        for _ in 0..20 {
            let p = dev.transmit(&zero, budget);
            for (r, v) in recovered.iter_mut().zip(&p.reconstruction) {
                *r += v;
            }
        }
        // Total recovered ≈ g0 in l2 (the SBC means redistribute mass, so
        // compare norms rather than coordinates).
        let err = recovered
            .iter()
            .zip(&g0)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
            / crate::tensor::norm(&g0);
        assert!(err < 0.35, "relative residual {err}");
        assert!(dev.accumulator_norm() < 0.6 * crate::tensor::norm(&g0));
    }

    #[test]
    fn aggregate_averages() {
        let p1 = DigitalPayload {
            reconstruction: vec![2.0, 0.0],
            nnz: 1,
            bits: 10.0,
        };
        let p2 = DigitalPayload {
            reconstruction: vec![0.0, 4.0],
            nnz: 1,
            bits: 10.0,
        };
        assert_eq!(aggregate(&[p1, p2], 2), vec![1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "not a digital scheme")]
    fn analog_scheme_rejected() {
        let _ = DigitalDevice::new(Scheme::ADsgd, 8, 2, 1);
    }
}
