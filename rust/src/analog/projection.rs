//! The shared pseudo-random projection (§IV): `A_s̃ ∈ R^{s̃×d}` with i.i.d.
//! N(0, 1/s̃) entries generated from a seed shared between the PS and all
//! devices before training starts. Devices compute `g̃ = A·g^sp`; the PS
//! uses the same matrix inside AMP.

use crate::amp::measurement_matrix;
use crate::tensor::Matf;

/// A cached projection matrix tied to its (s̃, d, seed) identity.
///
/// Both layouts are kept: `matrix` (s̃×d, row-major) for the PS-side AMP
/// pseudo-data pass, and `matrix_t` (d×s̃) so that sparse applies
/// `A·g^sp = Σ_{j∈supp} g_j·col_j(A)` become *contiguous* axpys over rows
/// of Aᵀ — the §Perf optimization that took the device transmit path from
/// 17 ms to ~4 ms and AMP's A·x̂ pass off the strided-gather cliff (see
/// EXPERIMENTS.md §Perf). Costs one extra s̃·d·4-byte buffer.
#[derive(Clone, Debug)]
pub struct Projection {
    pub matrix: Matf,
    /// Aᵀ (d × s̃), derived from `matrix`.
    pub matrix_t: Matf,
    pub seed: u64,
}

impl Projection {
    /// Generate (deterministically) the shared matrix.
    pub fn generate(s_tilde: usize, d: usize, seed: u64) -> Projection {
        assert!(s_tilde > 0 && d > 0);
        let matrix = measurement_matrix(s_tilde, d, seed);
        let matrix_t = transpose(&matrix);
        Projection {
            matrix,
            matrix_t,
            seed,
        }
    }

    #[inline]
    pub fn s_tilde(&self) -> usize {
        self.matrix.rows
    }

    #[inline]
    pub fn d(&self) -> usize {
        self.matrix.cols
    }

    /// Apply to a k-sparse vector given its support: cost s̃·k, contiguous
    /// (axpy over rows of Aᵀ). This is the device-side hot path (Alg. 1
    /// line 8).
    pub fn apply_sparse(&self, g_sp: &[f32], support: &[usize]) -> Vec<f32> {
        assert_eq!(g_sp.len(), self.d());
        let mut out = vec![0f32; self.s_tilde()];
        for &j in support {
            crate::tensor::axpy(g_sp[j], self.matrix_t.row(j), &mut out);
        }
        out
    }

    /// Dense apply (tests / reference).
    pub fn apply_dense(&self, g: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; self.s_tilde()];
        crate::tensor::gemv(&self.matrix, g, &mut out);
        out
    }
}

/// Blocked transpose (cache-tiled).
pub fn transpose(a: &Matf) -> Matf {
    let mut t = Matf::zeros(a.cols, a.rows);
    const B: usize = 64;
    for r0 in (0..a.rows).step_by(B) {
        let r1 = (r0 + B).min(a.rows);
        for c0 in (0..a.cols).step_by(B) {
            let c1 = (c0 + B).min(a.cols);
            for r in r0..r1 {
                let row = a.row(r);
                for c in c0..c1 {
                    t.data[c * a.rows + r] = row[c];
                }
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::sparsify_topk_inplace;
    use crate::util::rng::Pcg64;

    #[test]
    fn sparse_apply_matches_dense() {
        let proj = Projection::generate(40, 120, 3);
        let mut rng = Pcg64::new(1);
        let mut g: Vec<f32> = (0..120).map(|_| rng.normal() as f32).collect();
        let support = sparsify_topk_inplace(&mut g, 10);
        let sparse = proj.apply_sparse(&g, &support);
        let dense = proj.apply_dense(&g);
        for (a, b) in sparse.iter().zip(&dense) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn shared_seed_identical_across_parties() {
        let device_side = Projection::generate(64, 256, 99);
        let ps_side = Projection::generate(64, 256, 99);
        assert_eq!(device_side.matrix.data, ps_side.matrix.data);
    }

    #[test]
    fn projection_roughly_preserves_norm() {
        // E‖A x‖² = ‖x‖² for N(0, 1/s̃) entries — check concentration.
        let proj = Projection::generate(500, 1000, 5);
        let mut rng = Pcg64::new(2);
        let mut g = vec![0f32; 1000];
        let support = {
            let idx = rng.sample_indices(1000, 50);
            for &i in &idx {
                g[i] = rng.normal() as f32;
            }
            let mut s = idx;
            s.sort_unstable();
            s
        };
        let proj_g = proj.apply_sparse(&g, &support);
        let ratio = crate::tensor::norm_sq(&proj_g) / crate::tensor::norm_sq(&g);
        assert!((0.7..1.3).contains(&ratio), "ratio={ratio}");
    }
}
