//! The shared pseudo-random projection (§IV): `A_s̃ ∈ R^{s̃×d}` with i.i.d.
//! N(0, 1/s̃) entries generated from a seed shared between the PS and all
//! devices before training starts. Devices compute `g̃ = A·g^sp`; the PS
//! uses the same matrix inside AMP.

use crate::amp::{measurement_matrix, measurement_matrix_with_workers};
use crate::tensor::Matf;

/// A cached projection matrix tied to its (s̃, d, seed) identity.
///
/// Both layouts are kept: `matrix` (s̃×d, row-major) for the PS-side AMP
/// pseudo-data pass, and `matrix_t` (d×s̃) so that sparse applies
/// `A·g^sp = Σ_{j∈supp} g_j·col_j(A)` become *contiguous* axpys over rows
/// of Aᵀ — the optimization that takes the device transmit path and AMP's
/// A·x̂ pass off the strided-gather cliff (see PERF.md §Kernel table).
/// Costs one extra s̃·d·4-byte buffer.
#[derive(Clone, Debug)]
pub struct Projection {
    pub matrix: Matf,
    /// Aᵀ (d × s̃), derived from `matrix`.
    pub matrix_t: Matf,
    pub seed: u64,
}

impl Projection {
    /// Generate (deterministically) the shared matrix. Row generation and
    /// the transpose both run on the thread pool; the result is
    /// bit-identical for any worker count (counter-based per-row RNG
    /// streams — see [`measurement_matrix_with_workers`]).
    pub fn generate(s_tilde: usize, d: usize, seed: u64) -> Projection {
        assert!(s_tilde > 0 && d > 0);
        let matrix = measurement_matrix(s_tilde, d, seed);
        let matrix_t = transpose(&matrix);
        Projection {
            matrix,
            matrix_t,
            seed,
        }
    }

    /// [`Projection::generate`] with an explicit worker count for both the
    /// row fill and the transpose (tests assert workers = 1 ≡ workers = N
    /// bitwise).
    pub fn generate_with_workers(
        s_tilde: usize,
        d: usize,
        seed: u64,
        workers: usize,
    ) -> Projection {
        assert!(s_tilde > 0 && d > 0);
        let matrix = measurement_matrix_with_workers(s_tilde, d, seed, workers);
        let matrix_t = transpose_with_workers(&matrix, workers);
        Projection {
            matrix,
            matrix_t,
            seed,
        }
    }

    #[inline]
    pub fn s_tilde(&self) -> usize {
        self.matrix.rows
    }

    #[inline]
    pub fn d(&self) -> usize {
        self.matrix.cols
    }

    /// Apply to a k-sparse vector given its support: cost s̃·k, contiguous
    /// (axpy over rows of Aᵀ). This is the device-side hot path (Alg. 1
    /// line 8).
    pub fn apply_sparse(&self, g_sp: &[f32], support: &[usize]) -> Vec<f32> {
        let mut out = vec![0f32; self.s_tilde()];
        self.apply_sparse_into(g_sp, support, &mut out);
        out
    }

    /// [`Projection::apply_sparse`] writing into a caller buffer, with the
    /// support consumed four entries at a time via fused
    /// [`crate::tensor::axpy4`] (each s̃-float accumulator block is
    /// loaded/stored once per 4 support entries instead of once per entry).
    /// Bit-identical to sequential axpys over the support in order.
    pub fn apply_sparse_into(&self, g_sp: &[f32], support: &[usize], out: &mut [f32]) {
        assert_eq!(g_sp.len(), self.d());
        assert_eq!(out.len(), self.s_tilde());
        out.fill(0.0);
        let t = &self.matrix_t;
        let mut i = 0usize;
        while i + 4 <= support.len() {
            let (j0, j1, j2, j3) = (support[i], support[i + 1], support[i + 2], support[i + 3]);
            crate::tensor::axpy4(
                [g_sp[j0], g_sp[j1], g_sp[j2], g_sp[j3]],
                t.row(j0),
                t.row(j1),
                t.row(j2),
                t.row(j3),
                out,
            );
            i += 4;
        }
        while i < support.len() {
            let j = support[i];
            crate::tensor::axpy(g_sp[j], t.row(j), out);
            i += 1;
        }
    }

    /// Dense apply (tests / reference).
    pub fn apply_dense(&self, g: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; self.s_tilde()];
        crate::tensor::gemv(&self.matrix, g, &mut out);
        out
    }
}

/// Blocked transpose (cache-tiled), parallelized over 64-row output strips.
pub fn transpose(a: &Matf) -> Matf {
    let workers = crate::util::threadpool::default_workers(a.cols / TRANSPOSE_BLOCK + 1);
    transpose_with_workers(a, workers)
}

const TRANSPOSE_BLOCK: usize = 64;

/// [`transpose`] with an explicit worker count. Each worker fills a
/// disjoint strip of output rows (= input columns); the copy is exact, so
/// the result is bit-identical for any worker count.
pub fn transpose_with_workers(a: &Matf, workers: usize) -> Matf {
    let mut t = Matf::zeros(a.cols, a.rows);
    const B: usize = TRANSPOSE_BLOCK;
    let rows = a.rows;
    crate::util::threadpool::par_chunks_mut(&mut t.data, B * rows, workers, |blk, chunk| {
        // This chunk holds output rows [c0, c1) == input columns [c0, c1).
        let c0 = blk * B;
        let c1 = (c0 + B).min(a.cols);
        for r0 in (0..rows).step_by(B) {
            let r1 = (r0 + B).min(rows);
            for r in r0..r1 {
                let row = a.row(r);
                for c in c0..c1 {
                    chunk[(c - c0) * rows + r] = row[c];
                }
            }
        }
    });
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::sparsify_topk_inplace;
    use crate::util::rng::Pcg64;

    #[test]
    fn sparse_apply_matches_dense() {
        let proj = Projection::generate(40, 120, 3);
        let mut rng = Pcg64::new(1);
        let mut g: Vec<f32> = (0..120).map(|_| rng.normal() as f32).collect();
        let support = sparsify_topk_inplace(&mut g, 10);
        let sparse = proj.apply_sparse(&g, &support);
        let dense = proj.apply_dense(&g);
        for (a, b) in sparse.iter().zip(&dense) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn apply_sparse_blocked_matches_sequential_axpys_bitwise() {
        // Support sizes around the 4-entry block boundary; the fused path
        // must equal sequential axpys over the support, bit for bit.
        let proj = Projection::generate(37, 90, 5);
        let mut rng = Pcg64::new(6);
        for &k in &[1usize, 3, 4, 5, 8, 11] {
            let mut g: Vec<f32> = (0..90).map(|_| rng.normal() as f32).collect();
            let support = sparsify_topk_inplace(&mut g, k);
            let got = proj.apply_sparse(&g, &support);
            let mut want = vec![0f32; proj.s_tilde()];
            for &j in &support {
                crate::tensor::reference::axpy_scalar(g[j], proj.matrix_t.row(j), &mut want);
            }
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "k={k}");
            }
        }
    }

    #[test]
    fn shared_seed_identical_across_parties() {
        let device_side = Projection::generate(64, 256, 99);
        let ps_side = Projection::generate(64, 256, 99);
        assert_eq!(device_side.matrix.data, ps_side.matrix.data);
    }

    #[test]
    fn generate_worker_invariant_bitwise() {
        // Satellite contract: parallel generation (rows + transpose) is
        // bit-identical to sequential for any worker count.
        let seq = Projection::generate_with_workers(65, 130, 12, 1);
        for workers in [2usize, 3, 8] {
            let par = Projection::generate_with_workers(65, 130, 12, workers);
            assert_eq!(seq.matrix.data, par.matrix.data, "workers={workers}");
            assert_eq!(seq.matrix_t.data, par.matrix_t.data, "workers={workers}");
        }
        // And the default entry point agrees with the sequential result.
        let default = Projection::generate(65, 130, 12);
        assert_eq!(seq.matrix.data, default.matrix.data);
        assert_eq!(seq.matrix_t.data, default.matrix_t.data);
    }

    #[test]
    fn transpose_matches_naive_bitwise() {
        let mut rng = Pcg64::new(9);
        // Shapes straddling the 64-wide block in both dimensions.
        for &(r, c) in &[(3usize, 5usize), (64, 64), (65, 130), (130, 65)] {
            let a = Matf::from_vec(r, c, (0..r * c).map(|_| rng.normal() as f32).collect());
            let naive = crate::tensor::reference::transpose_naive(&a);
            for workers in [1usize, 4] {
                let t = transpose_with_workers(&a, workers);
                assert_eq!((t.rows, t.cols), (c, r));
                assert_eq!(t.data, naive.data, "{r}x{c} workers={workers}");
            }
        }
    }

    #[test]
    fn projection_roughly_preserves_norm() {
        // E‖A x‖² = ‖x‖² for N(0, 1/s̃) entries — check concentration.
        let proj = Projection::generate(500, 1000, 5);
        let mut rng = Pcg64::new(2);
        let mut g = vec![0f32; 1000];
        let support = {
            let idx = rng.sample_indices(1000, 50);
            for &i in &idx {
                g[i] = rng.normal() as f32;
            }
            let mut s = idx;
            s.sort_unstable();
            s
        };
        let proj_g = proj.apply_sparse(&g, &support);
        let ratio = crate::tensor::norm_sq(&proj_g) / crate::tensor::norm_sq(&g);
        assert!((0.7..1.3).contains(&ratio), "ratio={ratio}");
    }
}
