//! A-DSGD: the paper's analog over-the-air scheme (§IV, Algorithm 1).

pub mod adsgd;
pub mod projection;

pub use adsgd::{AnalogDevice, AnalogPs};
pub use projection::Projection;
