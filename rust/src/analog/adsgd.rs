//! A-DSGD device and PS pipelines (Algorithm 1 + §IV-A mean removal).
//!
//! Device side per iteration t (lines 4–9):
//!   1. error-compensate:  g_ec = g + Δ(t)
//!   2. sparsify:          g_sp = sp_k(g_ec);  Δ(t+1) = g_ec − g_sp
//!   3. project:           g̃ = A_s̃ · g_sp
//!   4. scale & frame:     x = [√α·g̃ᵀ, √α]ᵀ with α = P_t/(‖g̃‖²+1)
//!      (mean removal:     x = [√α·(g̃−μ1)ᵀ, √α·μ, √α]ᵀ, Eq. 20–22)
//!
//! PS side (lines 11–12): normalize the projected block by the received
//! Σ√α (last channel use), run AMP with the shared matrix, update θ.

use crate::amp::{self, AmpConfig};
use crate::compress::ErrorAccumulator;
use crate::tensor::sparsify_topk_inplace;

use super::projection::Projection;

/// Per-device analog state.
pub struct AnalogDevice {
    accum: ErrorAccumulator,
    /// Sparsification level k.
    pub k: usize,
}

/// What a device emits in one round.
#[derive(Clone, Debug)]
pub struct AnalogFrame {
    /// The length-s channel input x_m(t).
    pub x: Vec<f32>,
    /// √α_m(t) (diagnostic; also the last entry of x).
    pub sqrt_alpha: f64,
}

impl AnalogDevice {
    pub fn new(dim: usize, k: usize) -> AnalogDevice {
        assert!(k >= 1 && k <= dim);
        AnalogDevice {
            accum: ErrorAccumulator::new(dim),
            k,
        }
    }

    /// Standard framing (s̃ = s−1): Alg. 1 lines 4–9.
    ///
    /// Fused hot path (PERF.md): the projection lands directly in the
    /// frame buffer via [`Projection::apply_sparse_into`] (4-way blocked
    /// axpys, no intermediate `g̃` allocation) and the power scaling runs
    /// in place. Bit-identical to [`AnalogDevice::transmit_reference`].
    pub fn transmit(&mut self, g: &[f32], proj: &Projection, p_t: f64) -> AnalogFrame {
        let (g_sp, support) = self.sparsify_step(g);
        let s_tilde = proj.s_tilde();
        let mut x = vec![0f32; s_tilde + 1];
        {
            let _sp = crate::util::prof::span("project");
            proj.apply_sparse_into(&g_sp, &support, &mut x[..s_tilde]);
        }
        // Eq. 13: α = P_t / (‖g̃‖² + 1)
        let alpha = p_t / (crate::tensor::norm_sq(&x[..s_tilde]) + 1.0);
        let sa = alpha.sqrt();
        crate::tensor::scale(&mut x[..s_tilde], sa as f32);
        x[s_tilde] = sa as f32;
        AnalogFrame { x, sqrt_alpha: sa }
    }

    /// The seed's unfused transmit (separate projection allocation, then a
    /// scaled copy into the frame), kept verbatim as the bit-identity
    /// oracle for [`AnalogDevice::transmit`] and the "before" timing in
    /// the components bench. Identical error-accumulator semantics.
    pub fn transmit_reference(&mut self, g: &[f32], proj: &Projection, p_t: f64) -> AnalogFrame {
        let (g_sp, support) = self.sparsify_step(g);
        let g_tilde = proj.apply_sparse(&g_sp, &support);
        // Eq. 13: α = P_t / (‖g̃‖² + 1)
        let alpha = p_t / (crate::tensor::norm_sq(&g_tilde) + 1.0);
        let sa = alpha.sqrt();
        let mut x = Vec::with_capacity(g_tilde.len() + 1);
        x.extend(g_tilde.iter().map(|&v| (sa as f32) * v));
        x.push(sa as f32);
        AnalogFrame { x, sqrt_alpha: sa }
    }

    /// Mean-removal framing (s̃ = s−2): §IV-A, Eq. 19–22. Fused like
    /// [`AnalogDevice::transmit`]; the mean-removal scaling
    /// `√α·(g̃_i − μ)` keeps the seed's exact expression per element.
    pub fn transmit_mean_removed(
        &mut self,
        g: &[f32],
        proj: &Projection,
        p_t: f64,
        s: usize,
    ) -> AnalogFrame {
        assert_eq!(proj.s_tilde(), s - 2, "mean removal uses s̃ = s − 2");
        let (g_sp, support) = self.sparsify_step(g);
        let s_tilde = proj.s_tilde();
        let mut x = vec![0f32; s_tilde + 2];
        {
            let _sp = crate::util::prof::span("project");
            proj.apply_sparse_into(&g_sp, &support, &mut x[..s_tilde]);
        }
        let mu = crate::tensor::mean(&x[..s_tilde]) as f64;
        // Eq. 22: α = P_t / (‖g̃‖² − (s−3)μ² + 1).
        // ‖g̃ − μ1‖² = ‖g̃‖² − s̃μ², and the μ side-channel adds μ² back,
        // hence the (s̃ − 1) = (s − 3) in the denominator.
        let denom = crate::tensor::norm_sq(&x[..s_tilde]) - (s as f64 - 3.0) * mu * mu + 1.0;
        let alpha = p_t / denom.max(1e-12);
        let sa = alpha.sqrt();
        let sa_f = sa as f32;
        let mu_f = mu as f32;
        for v in x[..s_tilde].iter_mut() {
            *v = sa_f * (*v - mu_f);
        }
        x[s_tilde] = (sa * mu) as f32;
        x[s_tilde + 1] = sa as f32;
        AnalogFrame { x, sqrt_alpha: sa }
    }

    /// A round in which this device stays silent (not scheduled, silenced
    /// by the CSI gain threshold, or dropped as a straggler): nothing is
    /// transmitted, so the *whole* error-compensated gradient becomes the
    /// new residual — Δ(t+1) = g + Δ(t) — and is delivered in a later round
    /// (the fading companion papers' error-accumulation semantics).
    pub fn absorb(&mut self, g: &[f32]) {
        self.accum.bank(g);
    }

    /// Lines 4–7: compensate, sparsify, update Δ. Returns (g_sp, support).
    fn sparsify_step(&mut self, g: &[f32]) -> (Vec<f32>, Vec<usize>) {
        let g_ec = self.accum.compensate(g);
        let mut g_sp = g_ec.clone();
        let support = sparsify_topk_inplace(&mut g_sp, self.k);
        self.accum.update(&g_ec, &g_sp);
        (g_sp, support)
    }

    pub fn accumulator_norm(&self) -> f64 {
        self.accum.norm()
    }

    /// The current error residual Δ (checkpointing accessor — the device's
    /// only mutable state; k and the projection are config-derived).
    pub fn accumulator(&self) -> &[f32] {
        self.accum.as_slice()
    }

    /// Restore a residual captured by [`AnalogDevice::accumulator`].
    pub fn load_accumulator(&mut self, delta: &[f32]) {
        self.accum.load(delta);
    }
}

/// PS-side decoder.
pub struct AnalogPs {
    proj: Projection,
    pub amp_cfg: AmpConfig,
}

impl AnalogPs {
    pub fn new(proj: Projection, amp_cfg: AmpConfig) -> AnalogPs {
        AnalogPs { proj, amp_cfg }
    }

    pub fn projection(&self) -> &Projection {
        &self.proj
    }

    /// Decode the standard framing: y = [y^{s−1}; y_s] (Eq. 17–18).
    /// Returns ĝ ≈ (1/M)Σ g^sp plus the AMP trace.
    pub fn decode(&self, y: &[f32]) -> (Vec<f32>, amp::AmpTrace) {
        let s = y.len();
        assert_eq!(s - 1, self.proj.s_tilde());
        let y_s = y[s - 1];
        let scale = if y_s.abs() < 1e-12 { 1e-12 } else { y_s };
        let v: Vec<f32> = y[..s - 1].iter().map(|&yi| yi / scale).collect();
        amp::recover_with(
            &self.proj.matrix,
            Some(&self.proj.matrix_t),
            &v,
            &self.amp_cfg,
        )
    }

    /// Decode the mean-removal framing (Eq. 23–25):
    /// AMP over (y^{s−2} + y_{s−1}·1)/y_s.
    pub fn decode_mean_removed(&self, y: &[f32]) -> (Vec<f32>, amp::AmpTrace) {
        let s = y.len();
        assert_eq!(s - 2, self.proj.s_tilde());
        let y_s = y[s - 1];
        let y_mu = y[s - 2];
        let scale = if y_s.abs() < 1e-12 { 1e-12 } else { y_s };
        let v: Vec<f32> = y[..s - 2].iter().map(|&yi| (yi + y_mu) / scale).collect();
        amp::recover_with(
            &self.proj.matrix,
            Some(&self.proj.matrix_t),
            &v,
            &self.amp_cfg,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::GaussianMac;
    use crate::util::rng::Pcg64;

    fn rel_err(x: &[f32], y: &[f32]) -> f64 {
        let num: f64 = x
            .iter()
            .zip(y)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        num / crate::tensor::norm(x).max(1e-12)
    }

    /// Full round-trip: M devices, shared-seed projection, MAC, AMP.
    fn round_trip(mean_removal: bool, noise_var: f64, pbar: f64) -> f64 {
        let (d, s, k, m_devices) = (600, 301, 40, 8);
        let s_tilde = if mean_removal { s - 2 } else { s - 1 };
        let proj = Projection::generate(s_tilde, d, 77);
        let mut rng = Pcg64::new(4);
        let mut mac = GaussianMac::new(s, m_devices, noise_var, 5);

        // Devices share a common sparse "direction" plus small private
        // noise so the superposed supports stay recoverable (mirrors
        // aligned gradients early in training).
        let mut base = vec![0f32; d];
        for i in rng.sample_indices(d, k / 2) {
            base[i] = rng.normal_ms(0.0, 1.0) as f32;
        }
        let mut devices: Vec<AnalogDevice> =
            (0..m_devices).map(|_| AnalogDevice::new(d, k)).collect();
        let mut truth_sum = vec![0f32; d];
        let mut frames = Vec::new();
        for dev in devices.iter_mut() {
            let g: Vec<f32> = base
                .iter()
                .map(|&b| b + rng.normal_ms(0.0, 0.02) as f32)
                .collect();
            // Track the true average of the *sparsified* vectors.
            let g_sp = crate::tensor::sparsify_topk(&g, k);
            for (t, v) in truth_sum.iter_mut().zip(&g_sp) {
                *t += v;
            }
            let frame = if mean_removal {
                dev.transmit_mean_removed(&g, &proj, pbar, s)
            } else {
                dev.transmit(&g, &proj, pbar)
            };
            assert_eq!(frame.x.len(), s);
            frames.push(frame.x);
        }
        let y = mac.transmit(&frames);
        let ps = AnalogPs::new(proj, AmpConfig {
            max_iters: 60,
            tol: 1e-6,
            threshold_mult: 1.1,
        });
        let (ghat, _) = if mean_removal {
            ps.decode_mean_removed(&y)
        } else {
            ps.decode(&y)
        };
        let truth_avg: Vec<f32> = truth_sum.iter().map(|&v| v / m_devices as f32).collect();
        rel_err(&truth_avg, &ghat)
    }

    #[test]
    fn frame_power_equals_pt() {
        // Eq. 12: ‖x_m(t)‖² = P_t exactly (standard framing).
        let d = 200;
        let proj = Projection::generate(49, d, 1);
        let mut dev = AnalogDevice::new(d, 10);
        let mut rng = Pcg64::new(2);
        let g: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        for &p_t in &[1.0, 50.0, 500.0] {
            let frame = dev.transmit(&g, &proj, p_t);
            let power = crate::tensor::norm_sq(&frame.x);
            assert!(
                (power - p_t).abs() < 1e-3 * p_t.max(1.0),
                "power {power} != P_t {p_t}"
            );
        }
    }

    #[test]
    fn mean_removed_frame_power_equals_pt() {
        let d = 200;
        let s = 50;
        let proj = Projection::generate(s - 2, d, 1);
        let mut dev = AnalogDevice::new(d, 10);
        let mut rng = Pcg64::new(3);
        let g: Vec<f32> = (0..d).map(|_| rng.normal() as f32 + 0.5).collect();
        let frame = dev.transmit_mean_removed(&g, &proj, 100.0, s);
        let power = crate::tensor::norm_sq(&frame.x);
        assert!((power - 100.0).abs() < 1e-2, "power {power}");
    }

    #[test]
    fn mean_removal_never_costs_more_power_per_signal() {
        // Eq. 21 argument: for the same P_t, mean removal spends
        // α·(s−3)·μ² less on the mean, i.e. the scaling α_az ≥ α when μ≠0 —
        // more of the budget goes to the informative signal.
        let d = 300;
        let s = 62;
        let proj_std = Projection::generate(s - 1, d, 9);
        let proj_mr = Projection::generate(s - 2, d, 9);
        let mut dev1 = AnalogDevice::new(d, 15);
        let mut dev2 = AnalogDevice::new(d, 15);
        let mut rng = Pcg64::new(5);
        // Gradient with a strong common offset → large projected mean.
        let g: Vec<f32> = (0..d).map(|_| 1.0 + rng.normal_ms(0.0, 0.05) as f32).collect();
        let f_std = dev1.transmit(&g, &proj_std, 100.0);
        let f_mr = dev2.transmit_mean_removed(&g, &proj_mr, 100.0, s);
        assert!(
            f_mr.sqrt_alpha >= f_std.sqrt_alpha * 0.99,
            "α_az {} < α {}",
            f_mr.sqrt_alpha,
            f_std.sqrt_alpha
        );
    }

    #[test]
    fn end_to_end_recovery_standard() {
        let err = round_trip(false, 1.0, 500.0);
        assert!(err < 0.25, "relative error {err}");
    }

    #[test]
    fn end_to_end_recovery_mean_removed() {
        let err = round_trip(true, 1.0, 500.0);
        assert!(err < 0.25, "relative error {err}");
    }

    #[test]
    fn more_power_helps() {
        let hi = round_trip(false, 1.0, 500.0);
        let lo = round_trip(false, 1.0, 0.05);
        assert!(
            hi < lo,
            "recovery should improve with power: hi-P err {hi}, lo-P err {lo}"
        );
    }

    #[test]
    fn absorb_banks_the_whole_gradient() {
        let d = 50;
        let proj = Projection::generate(9, d, 8);
        let mut dev = AnalogDevice::new(d, 5);
        let g: Vec<f32> = (0..d).map(|i| i as f32 * 0.1).collect();
        dev.absorb(&g);
        // Δ = g exactly after a silent first round.
        assert!((dev.accumulator_norm() - crate::tensor::norm(&g)).abs() < 1e-6);
        // A later transmitting round drains the banked residual as usual.
        let zero = vec![0.0f32; d];
        let frame = dev.transmit(&zero, &proj, 10.0);
        assert_eq!(frame.x.len(), 10);
        assert!(dev.accumulator_norm() < crate::tensor::norm(&g));
    }

    #[test]
    fn error_accumulates_what_sparsification_drops() {
        let d = 100;
        let proj = Projection::generate(19, d, 3);
        let mut dev = AnalogDevice::new(d, 5);
        let g: Vec<f32> = (0..d).map(|i| (i as f32) / d as f32).collect();
        let norm_before = crate::tensor::norm(&g);
        let _ = dev.transmit(&g, &proj, 10.0);
        let lam = (((d - 5) as f64) / d as f64).sqrt();
        assert!(dev.accumulator_norm() > 0.0);
        assert!(dev.accumulator_norm() <= lam * norm_before + 1e-6);
    }
}
