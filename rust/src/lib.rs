//! # ota-dsgd — Over-the-Air Distributed SGD at the Wireless Edge
//!
//! Production-quality reproduction of Amiri & Gündüz, *"Machine Learning at
//! the Wireless Edge: Distributed Stochastic Gradient Descent Over-the-Air"*
//! (IEEE TSP 2020): federated SGD where `M` power/bandwidth-limited devices
//! send gradient information to a parameter server over `s` uses of a
//! Gaussian multiple-access channel.
//!
//! The crate implements:
//!
//! * **A-DSGD** (analog over-the-air, Algorithm 1): error accumulation →
//!   top-k sparsification → shared pseudo-random projection → power-scaled
//!   uncoded superposition → AMP recovery at the PS ([`analog`], [`amp`]).
//! * **D-DSGD** (digital, Section III): per-iteration MAC capacity budget,
//!   SBC-style quantization with error accumulation, enumerative position
//!   coding ([`digital`], [`compress`]).
//! * Digital baselines **SignSGD** and **QSGD** through the same capacity
//!   pipe, and the noiseless **error-free shared link** benchmark.
//! * The **Gaussian MAC** simulator with per-device power metering
//!   ([`channel`]) and the paper's power-allocation schedules (Eq. 45a–c).
//! * **Decentralized D2D consensus** ([`topology`],
//!   `coordinator::link::d2d`): no parameter server — per-device model
//!   replicas over seeded graph families (ring/torus/Erdős–Rényi/full/
//!   star) with Metropolis mixing, over-the-air neighborhood gradient
//!   averaging, and consensus-distance telemetry (Xing, Simeone & Bi
//!   2021).
//! * A synchronous **coordinator** (leader/worker over std threads) driving
//!   rounds end-to-end ([`coordinator`]): a scheme-agnostic trainer loop
//!   over pluggable transmission pipelines ([`coordinator::link`]), with
//!   device-side encoding fanned out across worker threads and gradients
//!   computed either by the pure-rust model ([`model`]) or by AOT-compiled
//!   JAX/Pallas graphs executed through PJRT ([`runtime`], behind the
//!   `xla` feature).
//! * Every figure of the paper's evaluation as a runnable experiment
//!   ([`experiments`]), plus the Theorem-1 convergence bound.
//! * **Campaign orchestration** ([`campaign`]): versioned binary snapshots
//!   of the complete trainer state with bit-identical resume, and a
//!   content-addressed run cache so re-invoking a figure executes only the
//!   delta (`repro resume`, `repro status`).
//! * **Worker-fleet execution** ([`fleet`]): the campaign store as a
//!   shared work queue — crash-safe filesystem leases with heartbeats and
//!   expiry-based reclaim, shortest-remaining-work-first ordering, and
//!   multi-process workers (`repro fleet --workers N`, `repro worker`)
//!   whose collective output is byte-identical to the single-process
//!   path.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod amp;
pub mod analog;
pub mod campaign;
pub mod channel;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod digital;
pub mod experiments;
pub mod fleet;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod tensor;
pub mod topology;
pub mod util;

/// Crate version string (matches Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
