//! `repro` — the launcher for the over-the-air DSGD reproduction.
//!
//! Subcommands:
//!   train        one training job from a preset/TOML/CLI overrides
//!                (checkpointed through the campaign store by default)
//!   fig N        regenerate the series of paper figure N (2..=7)
//!   all          every figure back to back
//!   fleet        run a figure campaign with N worker processes over the
//!                shared store (lease-based claims, crash reclaim)
//!   worker       attach one worker to a store's fleet queue
//!   fleet-status live queue/lease/progress view of a fleet store
//!                (`--connect host:port` renders from a remote server)
//!   metrics      replay the store's event log into Prometheus text
//!                (`--connect host:port` streams events from a server —
//!                byte-identical output by construction)
//!   watch        live terminal dashboard over the store's event log,
//!                incremental (each frame folds only appended bytes);
//!                `--connect host:port` watches a remote store
//!   serve        telemetry server over a store: /metrics /status
//!                /events /trace /health on a plain HTTP/1.1 listener
//!   trace        merge the store's fleet trace spans into a critical-path
//!                + utilization report (`--connect host:port` renders from
//!                a remote server — byte-identical by construction)
//!   resume       re-run a figure campaign through the run cache (forced on)
//!   status       list the campaign store's cached/partial runs
//!   gc           prune snapshot history + strays per the retention policy
//!   theory       Theorem-1 convergence-bound curves
//!   info         environment + artifact status
//!
//! Figure campaigns run through the content-addressed run cache by default
//! (`campaign::scheduler`): completed runs load from the store, partial
//! runs resume from their latest snapshot, only the delta executes.
//! `--no-cache` bypasses the store entirely.

use ota_dsgd::campaign::{scheduler, RunDisposition, RunStore};
use ota_dsgd::config::{
    presets, Backend, CampaignConfig, FleetConfig, GraphFamily, PowerSchedule, RunConfig, Scheme,
    ServeConfig,
};
use ota_dsgd::coordinator::{RustBackend, TrainLog, Trainer};
use ota_dsgd::experiments::{figures, runner, theory};
use ota_dsgd::fleet;
use ota_dsgd::model::PARAM_DIM;
use ota_dsgd::runtime::{Manifest, PjrtBackend, PjrtRuntime};
use ota_dsgd::util::cli::{Args, Usage};
use ota_dsgd::util::logging;

fn usage() -> Usage {
    Usage {
        program: "repro",
        about: "Over-the-air distributed SGD at the wireless edge (A-DSGD / D-DSGD)",
        subcommands: &[
            ("train", "run one training job (see options)"),
            ("fig <2|3|4|5|6|7|fading|d2d>", "regenerate a paper figure's series"),
            ("all", "regenerate every figure"),
            ("fleet <fig|all>", "run a figure campaign with a worker fleet over the store"),
            ("worker", "attach one worker to a store's fleet queue (--follow to stand by)"),
            ("fleet-status", "live fleet queue/lease/progress view (--connect for remote)"),
            ("metrics", "fold the store's event log into Prometheus text (--connect for remote)"),
            ("watch", "live dashboard over the store's event log (--once for one frame)"),
            ("serve", "telemetry server over a store: /metrics /status /events /trace /health"),
            ("trace", "merged fleet trace: critical path + utilization (--connect for remote)"),
            ("resume <fig|all>", "re-run a figure campaign through the run cache"),
            ("status", "campaign store status (cached/partial runs)"),
            ("gc", "prune snapshot history and stray files from the store"),
            ("ablate [name]", "ablations: mean-removal | sparsity | amp-threshold | analog-power"),
            ("theory", "Theorem-1 convergence-bound curves"),
            ("info", "platform, artifacts, configuration echo"),
        ],
        options: &[
            ("--scheme <name>", "adsgd|fading|blind|d2d|ddsgd|signsgd|qsgd|error-free (train)"),
            ("--topology <family>", "full|ring|torus|er|star D2D graph (train)"),
            ("--devices <M>", "number of devices"),
            ("--local-samples <B>", "samples per device"),
            ("--channel-uses <s>", "channel uses per iteration"),
            ("--sparsity <k>", "A-DSGD sparsification level"),
            ("--pbar <P>", "average power constraint"),
            ("--iterations <T>", "DSGD iterations"),
            ("--power <sched>", "const|lh-stair|lh|hl"),
            ("--noniid", "biased (2-class) device data"),
            ("--seed <u64>", "rng seed"),
            ("--backend <rust|pjrt>", "gradient backend (train)"),
            ("--config <file.toml>", "TOML config: [run] for train, [campaign] for figs"),
            ("--full", "paper-scale horizon (figs; slower)"),
            ("--out-dir <dir>", "results directory (default results; --out is an alias)"),
            ("--no-cache", "bypass the campaign run cache (figs)"),
            ("--store-dir <dir>", "campaign store (default <out-dir>/.campaign)"),
            ("--snapshot-every <N>", "trainer snapshot cadence in rounds (default 20)"),
            ("--keep-last-n <N>", "snapshot rounds retained per store entry (default 2)"),
            ("--workers <N>", "worker processes for `fleet` (default 4)"),
            ("--lease-secs <s>", "fleet lease TTL before reclaim (default 30)"),
            ("--heartbeat-secs <s>", "fleet lease refresh cadence (default 5)"),
            ("--worker-id <id>", "worker identity in lease records (worker)"),
            ("--follow", "keep the worker standing by for later campaigns (worker)"),
            ("--listen <host:port>", "telemetry server bind address (serve; default 127.0.0.1:7878)"),
            ("--connect <host:port>", "read from a `repro serve` server (watch/metrics/fleet-status)"),
            ("--no-telemetry", "disable the store's fleet event log"),
            ("--telemetry-every <N>", "round-event cadence in rounds (default 1)"),
            ("--no-diagnostics", "disable link diagnostics probes (device events, SNR)"),
            ("--profile-out <file>", "write a Chrome trace of pipeline spans (train)"),
            ("--trace", "record fleet trace spans to the store ([telemetry] trace)"),
            ("--trace-out <file>", "write the merged Chrome trace JSON (trace)"),
            ("--once", "render a single dashboard frame and exit (watch)"),
            ("--interval-secs <s>", "dashboard refresh cadence (watch; default 2)"),
            ("--quiet", "suppress per-round progress"),
        ],
    }
}

fn main() {
    logging::init_from_env();
    let args = Args::from_env();
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "train" => cmd_train(&args),
        "fig" => cmd_fig(&args, false),
        "all" => cmd_all(&args, false),
        "fleet" => cmd_fleet(&args),
        "worker" => cmd_worker(&args),
        "fleet-status" => cmd_fleet_status(&args),
        "metrics" => cmd_metrics(&args),
        "watch" => cmd_watch(&args),
        "serve" => cmd_serve(&args),
        "trace" => cmd_trace(&args),
        "resume" => cmd_fig(&args, true),
        "status" => cmd_status(&args),
        "gc" => cmd_gc(&args),
        "ablate" => cmd_ablate(&args),
        "theory" => cmd_theory(&args),
        "info" => cmd_info(),
        _ => {
            print!("{}", usage().render());
        }
    }
}

/// Results directory: `--out-dir` with `--out` kept as the legacy alias.
fn out_dir(args: &Args) -> String {
    args.get("out-dir")
        .or_else(|| args.get("out"))
        .unwrap_or("results")
        .to_string()
}

/// Campaign policy for figure runs: `[campaign]` table from `--config` if
/// given, CLI overrides on top. `None` = cache bypassed (`--no-cache` or
/// `enabled = false`), unless `force_resume` pins it on (`repro resume`).
fn campaign_from_args(args: &Args, force_resume: bool) -> Option<CampaignConfig> {
    if args.flag("no-cache") && !force_resume {
        return None;
    }
    let mut c = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .unwrap_or_else(|e| panic!("cannot read {path}: {e}"));
            CampaignConfig::from_toml(&text).unwrap_or_else(|e| panic!("{e}"))
        }
        None => CampaignConfig::default(),
    };
    if let Some(dir) = args.get("store-dir") {
        c.store_dir = dir.to_string();
    }
    c.snapshot_every = args.usize("snapshot-every", c.snapshot_every);
    c.keep_last_n = args.usize("keep-last-n", c.keep_last_n);
    if args.flag("no-telemetry") {
        c.telemetry.enabled = false;
    }
    if args.flag("no-diagnostics") {
        c.telemetry.diagnostics = false;
    }
    if args.flag("trace") {
        c.telemetry.trace = true;
    }
    c.telemetry.every = args.usize("telemetry-every", c.telemetry.every).max(1);
    if force_resume {
        c.enabled = true;
        c.resume = true;
    }
    if !c.enabled {
        return None;
    }
    Some(c)
}

/// Fleet policy: `[fleet]` table from `--config` if given, CLI overrides
/// on top, validated.
fn fleet_from_args(args: &Args) -> FleetConfig {
    let mut f = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .unwrap_or_else(|e| panic!("cannot read {path}: {e}"));
            FleetConfig::from_toml(&text).unwrap_or_else(|e| panic!("{e}"))
        }
        None => FleetConfig::default(),
    };
    f.workers = args.usize("workers", f.workers);
    f.lease_secs = args.f64("lease-secs", f.lease_secs);
    f.heartbeat_secs = args.f64("heartbeat-secs", f.heartbeat_secs);
    f.validate().unwrap_or_else(|e| panic!("{e}"));
    f
}

/// The figure specs a selector names: `2..7`, `fading`, `d2d`, or `all`
/// (shared by `fig`, `resume` and `fleet`). `fig 2` without `--noniid`
/// runs both panels, as the paper does.
fn specs_for(which: &str, args: &Args) -> Vec<runner::ExperimentSpec> {
    let full = args.flag("full");
    match which {
        "fading" => vec![figures::fading(full)],
        "d2d" => vec![figures::d2d(full)],
        "all" => vec![
            figures::fig2(false, full),
            figures::fig2(true, full),
            figures::fig3(full),
            figures::fig4(full),
            figures::fig5(full),
            figures::fig6(full),
            figures::fading(full),
            figures::d2d(full),
            figures::fig7(full),
        ],
        n => match n.parse::<usize>() {
            Ok(2) => {
                if args.flag("noniid") {
                    vec![figures::fig2(true, full)]
                } else {
                    vec![figures::fig2(false, full), figures::fig2(true, full)]
                }
            }
            Ok(3) => vec![figures::fig3(full)],
            Ok(4) => vec![figures::fig4(full)],
            Ok(5) => vec![figures::fig5(full)],
            Ok(6) => vec![figures::fig6(full)],
            Ok(7) => vec![figures::fig7(full)],
            _ => panic!("no figure {n:?}; valid: 2..=7, `fading`, `d2d` or `all`"),
        },
    }
}

/// Run one spec through the cache-aware scheduler (or the plain runner
/// when the cache is bypassed).
fn run_spec(
    spec: &runner::ExperimentSpec,
    out: &str,
    verbose: bool,
    campaign: Option<&CampaignConfig>,
) -> Vec<TrainLog> {
    match campaign {
        Some(c) => scheduler::run_experiment_cached(spec, out, verbose, c).0,
        None => runner::run_experiment(spec, out, verbose),
    }
}

/// Build a RunConfig from `--config` + CLI overrides on top of the smoke
/// preset (train subcommand).
fn config_from_args(args: &Args) -> RunConfig {
    let mut cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .unwrap_or_else(|e| panic!("cannot read {path}: {e}"));
            RunConfig::from_toml(&text).unwrap_or_else(|e| panic!("{e}"))
        }
        None => presets::smoke(),
    };
    if let Some(s) = args.get("scheme") {
        cfg.scheme = Scheme::parse(s).unwrap_or_else(|| panic!("unknown scheme {s}"));
    }
    if let Some(p) = args.get("power") {
        cfg.power = PowerSchedule::parse(p).unwrap_or_else(|| panic!("unknown schedule {p}"));
    }
    if let Some(f) = args.get("topology") {
        cfg.topology.family =
            GraphFamily::parse(f).unwrap_or_else(|| panic!("unknown graph family {f}"));
    }
    cfg.devices = args.usize("devices", cfg.devices);
    cfg.local_samples = args.usize("local-samples", cfg.local_samples);
    cfg.channel_uses = args.usize("channel-uses", cfg.channel_uses);
    cfg.sparsity = args.usize("sparsity", cfg.sparsity);
    cfg.pbar = args.f64("pbar", cfg.pbar);
    cfg.iterations = args.usize("iterations", cfg.iterations);
    cfg.seed = args.u64("seed", cfg.seed);
    cfg.eval_every = args.usize("eval-every", cfg.eval_every);
    if args.flag("noniid") {
        cfg.noniid = true;
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = Backend::parse(b).unwrap_or_else(|| panic!("unknown backend {b}"));
    }
    cfg
}

fn cmd_train(args: &Args) {
    let cfg = config_from_args(args);
    cfg.validate(PARAM_DIM).unwrap_or_else(|e| panic!("{e}"));
    println!("training: {}", cfg.summary());
    let out = out_dir(args);
    let verbose = !args.flag("quiet");
    let campaign = campaign_from_args(args, false);
    let profile_out = args.get("profile-out").map(str::to_string);
    if profile_out.is_some() {
        ota_dsgd::util::prof::enable();
    }
    // Single runs checkpoint through the same campaign store the figure
    // sweeps use: an interrupted `repro train` resumes from its latest
    // snapshot, and re-running a finished config is a pure cache load
    // (`--no-cache` opts out). The PJRT backend stays on the direct path —
    // its gradient executor is built per-invocation, not per-config.
    let log = match (cfg.backend, &campaign) {
        (Backend::Rust, Some(campaign)) => {
            let (log, disposition) =
                scheduler::run_single_cached(cfg.scheme.name(), &cfg, &out, verbose, campaign);
            match disposition {
                RunDisposition::Cached => println!(
                    "served from campaign store {} (use --no-cache to re-execute)",
                    campaign.store_dir_or(&out)
                ),
                RunDisposition::Resumed(round) => {
                    println!("resumed from snapshot at round {round}/{}", cfg.iterations)
                }
                RunDisposition::Executed => {}
            }
            log
        }
        _ => {
            let mut trainer = match cfg.backend {
                Backend::Rust => {
                    Trainer::with_backend(cfg.clone(), Box::new(RustBackend::new()))
                }
                Backend::Pjrt => {
                    let runtime = PjrtRuntime::cpu().expect("PJRT client");
                    let manifest = Manifest::load_default().expect("artifact manifest");
                    let backend = PjrtBackend::from_manifest(
                        &runtime,
                        &manifest,
                        cfg.devices,
                        cfg.local_samples,
                    )
                    .expect("PJRT gradient backend");
                    Trainer::with_backend(cfg.clone(), Box::new(backend))
                }
            }
            .expect("trainer");
            trainer.verbose = verbose;
            trainer.run()
        }
    };
    if let Some(path) = &profile_out {
        ota_dsgd::util::prof::disable();
        let spans = ota_dsgd::util::prof::take();
        std::fs::write(path, ota_dsgd::util::prof::chrome_trace_json(&spans))
            .unwrap_or_else(|e| panic!("cannot write {path}: {e}"));
        print!(
            "{}",
            ota_dsgd::util::prof::render_summary(&ota_dsgd::util::prof::summarize(&spans))
        );
        println!("trace ({} spans) → {path}  [open in chrome://tracing or Perfetto]", spans.len());
    }
    println!(
        "done: final accuracy {:.4} (best {:.4}) in {:.1}s; power ok: {}",
        log.final_accuracy,
        log.best_accuracy(),
        log.total_secs,
        log.power_constraint_ok(1e-6)
    );
    let path = format!("{out}/train/{}.csv", cfg.scheme.name().replace(' ', "_"));
    log.write_csv(&path).expect("write csv");
    println!("series → {path}");
}

/// `repro fig <which>` and (with `force_resume`) `repro resume <which>`.
fn cmd_fig(args: &Args, force_resume: bool) {
    let which = args
        .positional
        .first()
        .unwrap_or_else(|| panic!("usage: repro fig <2..7|fading|d2d>"))
        .clone();
    if which == "all" {
        cmd_all(args, force_resume);
        return;
    }
    let out = out_dir(args);
    let verbose = !args.flag("quiet");
    let campaign = campaign_from_args(args, force_resume);
    for spec in specs_for(&which, args) {
        let logs = run_spec(&spec, &out, verbose, campaign.as_ref());
        if spec.id == "fig7" {
            figures::print_fig7b(&logs, &spec.runs);
        }
    }
}

fn cmd_all(args: &Args, force_resume: bool) {
    let out = out_dir(args);
    let verbose = !args.flag("quiet");
    let campaign = campaign_from_args(args, force_resume);
    for spec in specs_for("all", args) {
        let logs = run_spec(&spec, &out, verbose, campaign.as_ref());
        if spec.id == "fig7" {
            figures::print_fig7b(&logs, &spec.runs);
        }
    }
    theory::run(&theory::TheoryParams::default(), &out);
}

/// `repro fleet <which>`: enumerate the campaign into the store's queue,
/// spawn the worker processes, wait for the queue to drain, then
/// regenerate the figure outputs from the store — byte-identical to the
/// single-process path, whoever executed what.
fn cmd_fleet(args: &Args) {
    let which = args
        .positional
        .first()
        .unwrap_or_else(|| panic!("usage: repro fleet <2..7|fading|d2d|all> [--workers N]"))
        .clone();
    let out = out_dir(args);
    // The fleet *is* the campaign store — `--no-cache` has nothing to
    // bypass here, so the store is forced on like `repro resume`.
    let campaign = campaign_from_args(args, true)
        .expect("resume-forced campaign config is always present");
    let fleet_cfg = fleet_from_args(args);
    let specs = specs_for(&which, args);
    let store_dir = campaign.store_dir_or(&out);
    let store = RunStore::open(&store_dir).expect("open campaign run store");
    let items = fleet::enqueue_specs(&store, &specs).expect("enqueue fleet work items");
    let total_rounds: usize = items.iter().map(|i| i.cfg.iterations).sum();
    println!(
        "fleet: {} spec(s), {} run(s), {total_rounds} total rounds → store {store_dir}",
        specs.len(),
        items.len()
    );
    println!(
        "spawning {} worker(s) [lease {}s, heartbeat {}s, snapshot every {}]",
        fleet_cfg.workers, fleet_cfg.lease_secs, fleet_cfg.heartbeat_secs, campaign.snapshot_every
    );
    let exe = std::env::current_exe().expect("current executable path");
    let mut children = Vec::new();
    for i in 0..fleet_cfg.workers {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("worker")
            .args(["--store-dir", store_dir.as_str()])
            .args(["--lease-secs", fleet_cfg.lease_secs.to_string().as_str()])
            .args(["--heartbeat-secs", fleet_cfg.heartbeat_secs.to_string().as_str()])
            .args(["--snapshot-every", campaign.snapshot_every.to_string().as_str()])
            .args(["--keep-last-n", campaign.keep_last_n.to_string().as_str()])
            .args(["--telemetry-every", campaign.telemetry.every.to_string().as_str()])
            .args(["--worker-id", format!("w{i}").as_str()])
            .arg("--quiet");
        if !campaign.telemetry.enabled {
            cmd.arg("--no-telemetry");
        }
        if !campaign.telemetry.diagnostics {
            cmd.arg("--no-diagnostics");
        }
        if campaign.telemetry.trace {
            cmd.arg("--trace");
        }
        let child = cmd
            .spawn()
            .unwrap_or_else(|e| panic!("spawn worker {i}: {e}"));
        children.push(child);
    }
    for (i, mut child) in children.into_iter().enumerate() {
        match child.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => eprintln!("warning: worker w{i} exited with {status}"),
            Err(e) => eprintln!("warning: waiting on worker w{i}: {e}"),
        }
    }
    // Belt and braces: if workers died (OOM kill, …) the queue may not be
    // drained — finish the remainder here rather than leaving the
    // campaign hanging (their leases have expired by now or will).
    let report = fleet::run_worker(&store_dir, &fleet_cfg, &campaign, "coordinator", false)
        .expect("final in-process drain");
    if report.executed + report.resumed > 0 {
        println!(
            "coordinator finished {} leftover run(s)",
            report.executed + report.resumed
        );
    }
    let all_logs = fleet::collect_outputs(&store, &specs, &out)
        .unwrap_or_else(|e| panic!("collect fleet outputs: {e}"));
    for (spec, logs) in specs.iter().zip(&all_logs) {
        if spec.id == "fig7" {
            figures::print_fig7b(logs, &spec.runs);
        }
    }
    if which == "all" {
        theory::run(&theory::TheoryParams::default(), &out);
    }
}

/// `repro worker`: attach one worker to a store's fleet queue and drain it.
fn cmd_worker(args: &Args) {
    let out = out_dir(args);
    let mut campaign = campaign_from_args(args, true)
        .expect("resume-forced campaign config is always present");
    let store_dir = campaign.store_dir_or(&out);
    campaign.store_dir = store_dir.clone();
    let fleet_cfg = fleet_from_args(args);
    let worker_id = args
        .get("worker-id")
        .map(str::to_string)
        .unwrap_or_else(|| format!("pid{}", std::process::id()));
    let verbose = !args.flag("quiet");
    // `--follow` turns this into a standing worker: it outlives queue
    // drains, picks up later campaigns, and exits on SIGTERM/SIGINT.
    let follow = args.flag("follow");
    let stop = follow.then(fleet::install_stop_signals);
    let report =
        fleet::run_worker_ctl(&store_dir, &fleet_cfg, &campaign, &worker_id, verbose, follow, stop)
            .unwrap_or_else(|e| panic!("worker loop: {e}"));
    println!(
        "[{worker_id}] done: {} executed, {} resumed, {} already complete",
        report.executed, report.resumed, report.already_done
    );
}

/// Resolve the fleet store for read-only views: `--store-dir` directly,
/// else the campaign config's derivation against the results directory.
fn open_store_for_view(args: &Args) -> Option<(RunStore, String)> {
    let out = out_dir(args);
    let store_dir = match args.get("store-dir") {
        Some(dir) => dir.to_string(),
        None => campaign_from_args(args, true)
            .expect("resume-forced campaign config is always present")
            .store_dir_or(&out),
    };
    match RunStore::open(&store_dir) {
        Ok(s) => Some((s, store_dir)),
        Err(e) => {
            println!("campaign store {store_dir}: unavailable ({e})");
            None
        }
    }
}

/// `repro fleet-status`: live view of the queue, leases and progress.
/// Fail-soft end to end — torn queue items and mid-write lease records
/// are skipped and surfaced as `unreadable: N`, never an abort.
fn cmd_fleet_status(args: &Args) {
    if let Some(addr) = args.get("connect") {
        // Render from a remote server's `/status`. The fail-soft
        // `unreadable: N` accounting rides the JSON untouched.
        let (store_dir, status) = fleet::fetch_status(addr)
            .unwrap_or_else(|e| panic!("repro fleet-status --connect {addr}: {e}"));
        print!("{}", fleet::render_status(&store_dir, &status));
        return;
    }
    let Some((store, store_dir)) = open_store_for_view(args) else {
        return;
    };
    let fleet_cfg = fleet_from_args(args);
    let ttl = std::time::Duration::from_secs_f64(fleet_cfg.lease_secs);
    let status = fleet::collect_status(&store, ttl);
    print!("{}", fleet::render_status(&store_dir, &status));
}

/// `repro metrics`: replay the store's event log through the
/// deterministic reducer and dump Prometheus exposition text.
fn cmd_metrics(args: &Args) {
    if let Some(addr) = args.get("connect") {
        // Stream `/events` and fold them through the same reducer the
        // local path uses — the output is byte-identical to running
        // `repro metrics` on the server's own store, by construction.
        let metrics = fleet::remote_metrics(addr)
            .unwrap_or_else(|e| panic!("repro metrics --connect {addr}: {e}"));
        print!("{}", metrics.to_prometheus());
        return;
    }
    let Some((store, store_dir)) = open_store_for_view(args) else {
        return;
    };
    let report = fleet::read_events(store.root());
    if report.events.is_empty() && report.skipped_lines == 0 && report.unreadable_files == 0 {
        eprintln!("note: no events recorded under {store_dir} (telemetry off or nothing run)");
    }
    let metrics = fleet::reduce_report(&report);
    print!("{}", metrics.to_prometheus());
}

/// Per-frame dashboard state shared by the local and remote watch
/// paths: a cursor chain + incremental reducer (each frame folds only
/// the bytes appended since the last one — incremental == batch is
/// pinned in `rust/tests/remote_observability.rs`) and a stall tracker
/// whose poll cadence is the refresh cadence.
struct WatchState {
    cursor: fleet::Cursor,
    reducer: fleet::Reducer,
    tracker: fleet::HealthTracker,
    policy: fleet::HealthPolicy,
    /// Cursor chain over the trace segments (the utilization pane's
    /// feed) and the spans accumulated so far. Both stay empty when
    /// tracing is off — the pane fails soft to absent.
    trace_cursor: fleet::Cursor,
    spans: Vec<fleet::Span>,
}

impl WatchState {
    fn new() -> WatchState {
        WatchState {
            cursor: fleet::Cursor::default(),
            reducer: fleet::Reducer::default(),
            tracker: fleet::HealthTracker::default(),
            policy: fleet::HealthPolicy::default(),
            trace_cursor: fleet::Cursor::default(),
            spans: Vec::new(),
        }
    }

    /// Fold one frame's tails and render them against `status`.
    /// `span_tail` is `None` when the trace feed is unavailable (old
    /// server, tracing off) — the dashboard renders without the pane.
    fn frame(
        &mut self,
        store_dir: &str,
        status: &fleet::FleetStatus,
        tail: &fleet::TailReport,
        span_tail: Option<fleet::SpanTailReport>,
    ) -> String {
        self.cursor = tail.cursor.clone();
        self.reducer.absorb_tail(tail);
        if let Some(st) = span_tail {
            self.trace_cursor = st.cursor.clone();
            self.spans.extend(st.spans);
        }
        let metrics = self.reducer.metrics();
        self.tracker.observe(&metrics);
        let mut findings = fleet::evaluate(&metrics, &self.policy);
        findings.extend(self.tracker.stalled(&self.policy));
        let util = fleet::utilization(&self.spans);
        fleet::render_dashboard(store_dir, status, &metrics, &findings, &util)
    }
}

/// `repro watch`: live terminal dashboard over the queue and event log.
/// `--once` renders a single frame (scripting/CI); otherwise refreshes
/// every `--interval-secs` until interrupted. With `--connect` the
/// frames render from a `repro serve` server's `/status` + `/events`
/// instead of the local filesystem — through the same reducer.
fn cmd_watch(args: &Args) {
    let once = args.flag("once");
    let interval = std::time::Duration::from_secs_f64(args.f64("interval-secs", 2.0).max(0.1));
    let mut state = WatchState::new();
    if let Some(addr) = args.get("connect") {
        loop {
            let (store_dir, status) = fleet::fetch_status(addr)
                .unwrap_or_else(|e| panic!("repro watch --connect {addr}: {e}"));
            let tail = fleet::fetch_events(addr, &state.cursor)
                .unwrap_or_else(|e| panic!("repro watch --connect {addr}: {e}"));
            // The trace feed is best-effort: a server predating /trace
            // (or a store with tracing off) just means no pane.
            let span_tail = fleet::fetch_spans(addr, &state.trace_cursor).ok();
            let frame = state.frame(&format!("{store_dir} @ {addr}"), &status, &tail, span_tail);
            if emit_frame(&frame, once, interval) {
                return;
            }
        }
    }
    let Some((store, store_dir)) = open_store_for_view(args) else {
        return;
    };
    let fleet_cfg = fleet_from_args(args);
    let ttl = std::time::Duration::from_secs_f64(fleet_cfg.lease_secs);
    loop {
        let status = fleet::collect_status(&store, ttl);
        let tail = fleet::read_events_from(store.root(), &state.cursor);
        let span_tail = Some(fleet::read_spans_from(store.root(), &state.trace_cursor));
        let frame = state.frame(&store_dir, &status, &tail, span_tail);
        if emit_frame(&frame, once, interval) {
            return;
        }
    }
}

/// Print one dashboard frame; returns true when the loop should end.
fn emit_frame(frame: &str, once: bool, interval: std::time::Duration) -> bool {
    if once {
        print!("{frame}");
        return true;
    }
    // ANSI clear + home keeps the frame flicker-free on any terminal
    // the repo targets; plain output still renders under `--once`.
    print!("\x1b[2J\x1b[H{frame}");
    use std::io::Write as _;
    let _ = std::io::stdout().flush();
    std::thread::sleep(interval);
    false
}

/// `repro serve`: bind the telemetry server over a store and block.
/// `[serve]` table from `--config`, `--listen` on top.
fn cmd_serve(args: &Args) {
    let out = out_dir(args);
    let store_dir = match args.get("store-dir") {
        Some(dir) => dir.to_string(),
        None => campaign_from_args(args, true)
            .expect("resume-forced campaign config is always present")
            .store_dir_or(&out),
    };
    let mut serve_cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .unwrap_or_else(|e| panic!("cannot read {path}: {e}"));
            ServeConfig::from_toml(&text).unwrap_or_else(|e| panic!("{e}"))
        }
        None => ServeConfig::default(),
    };
    if let Some(listen) = args.get("listen") {
        serve_cfg.listen = listen.to_string();
    }
    serve_cfg.validate().unwrap_or_else(|e| panic!("{e}"));
    let fleet_cfg = fleet_from_args(args);
    let opts = fleet::ServeOptions {
        lease_secs: fleet_cfg.lease_secs,
        policy: fleet::HealthPolicy::default(),
    };
    let server = fleet::Server::bind(&store_dir, &serve_cfg.listen, opts)
        .unwrap_or_else(|e| panic!("repro serve: cannot bind {}: {e}", serve_cfg.listen));
    let addr = server.addr();
    println!("serving campaign store {store_dir} on http://{addr}");
    println!("  GET /metrics                Prometheus text (== `repro metrics`)");
    println!("  GET /status                 fleet queue/lease status as JSON");
    println!("  GET /events?after=<cursor>  incremental event tail (whole lines only)");
    println!("  GET /trace?after=<cursor>   incremental span tail (same cursor scheme)");
    println!("  GET /health                 health findings as JSON (one poll per scrape)");
    server.join();
}

/// `repro trace`: merge every worker's span segments into one timeline
/// and render the critical-path / utilization report. With `--connect`
/// the spans stream from a `repro serve` server's `/trace` and pass
/// through the same sort + render pipeline, so the two outputs are
/// byte-identical by construction. `--trace-out file.json` additionally
/// writes the merged Chrome trace (per-worker process lanes).
fn cmd_trace(args: &Args) {
    let (mut spans, skipped, pending, unreadable) = if let Some(addr) = args.get("connect") {
        let tail = fleet::fetch_spans(addr, &fleet::Cursor::default())
            .unwrap_or_else(|e| panic!("repro trace --connect {addr}: {e}"));
        (tail.spans, tail.consumed_skipped, tail.pending_tails, tail.unreadable_files)
    } else {
        let Some((store, store_dir)) = open_store_for_view(args) else {
            return;
        };
        // Zero-cursor incremental read — the exact computation the
        // server performs for `/trace?after=`, including the
        // skipped/pending split, keeping local and remote reports
        // byte-identical even around torn tails.
        let tail = fleet::read_spans_from(store.root(), &fleet::Cursor::default());
        if tail.spans.is_empty() && tail.consumed_skipped == 0 && tail.unreadable_files == 0 {
            eprintln!(
                "note: no trace spans under {store_dir} (record them with --trace on \
                 train/fleet/worker)"
            );
        }
        (tail.spans, tail.consumed_skipped, tail.pending_tails, tail.unreadable_files)
    };
    fleet::sort_spans(&mut spans);
    print!("{}", fleet::render_trace_report(&spans, skipped, pending, unreadable));
    if let Some(path) = args.get("trace-out") {
        std::fs::write(path, fleet::chrome_trace(&spans))
            .unwrap_or_else(|e| panic!("cannot write {path}: {e}"));
        eprintln!(
            "chrome trace ({} spans) → {path}  [open in chrome://tracing or Perfetto]",
            spans.len()
        );
    }
}

/// `repro gc`: prune the store per the retention policy.
fn cmd_gc(args: &Args) {
    let out = out_dir(args);
    let campaign = campaign_from_args(args, true)
        .expect("resume-forced campaign config is always present");
    let store_dir = campaign.store_dir_or(&out);
    let store = match RunStore::open(&store_dir) {
        Ok(s) => s,
        Err(e) => {
            println!("campaign store {store_dir}: unavailable ({e})");
            return;
        }
    };
    let report = store
        .gc(campaign.keep_last_n)
        .unwrap_or_else(|e| panic!("gc {store_dir}: {e}"));
    println!(
        "gc {store_dir}: {} entr{} scanned, {} file(s) removed, {} byte(s) reclaimed \
         (keep_last_n = {})",
        report.entries,
        if report.entries == 1 { "y" } else { "ies" },
        report.files_removed,
        report.bytes_reclaimed,
        campaign.keep_last_n
    );
}

/// `repro status`: list the campaign store's entries.
fn cmd_status(args: &Args) {
    let out = out_dir(args);
    let store_dir = match args.get("store-dir") {
        Some(dir) => dir.to_string(),
        None => campaign_from_args(args, true)
            .expect("resume-forced campaign config is always present")
            .store_dir_or(&out),
    };
    let store = match RunStore::open(&store_dir) {
        Ok(s) => s,
        Err(e) => {
            println!("campaign store {store_dir}: unavailable ({e})");
            return;
        }
    };
    let entries = store.list();
    if entries.is_empty() {
        println!("campaign store {store_dir}: empty");
        return;
    }
    println!("campaign store {store_dir}: {} run(s)", entries.len());
    println!("{:<16} {:<8} {:>11}  {}", "key", "status", "round", "run");
    for m in entries {
        println!(
            "{:<16} {:<8} {:>5}/{:<5}  `{}` — {}",
            m.key,
            m.status.name(),
            m.snapshot_round,
            m.iterations,
            m.label,
            m.summary
        );
    }
}

fn cmd_ablate(args: &Args) {
    use ota_dsgd::experiments::ablations;
    let full = args.flag("full");
    let out = out_dir(args);
    let verbose = !args.flag("quiet");
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let specs = match which {
        "mean-removal" => vec![ablations::mean_removal(full)],
        "sparsity" => vec![ablations::sparsity(full)],
        "amp-threshold" => vec![ablations::amp_threshold(full)],
        "analog-power" => vec![ablations::analog_power(full)],
        "all" => ablations::all(full),
        other => panic!("unknown ablation {other:?}"),
    };
    for spec in specs {
        runner::run_experiment(&spec, &out, verbose);
    }
}

fn cmd_theory(args: &Args) {
    let out = out_dir(args);
    let mut p = theory::TheoryParams::default();
    p.pbar = args.f64("pbar", p.pbar);
    p.devices = args.usize("devices", p.devices);
    p.grad_bound = args.f64("grad-bound", p.grad_bound);
    p.convexity = args.f64("convexity", p.convexity);
    theory::run(&p, &out);
}

fn cmd_info() {
    println!("ota-dsgd v{}", ota_dsgd::VERSION);
    println!("model dim d = {PARAM_DIM}");
    match PjrtRuntime::cpu() {
        Ok(rt) => println!("PJRT platform: {}", rt.platform()),
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    match Manifest::load_default() {
        Ok(m) => {
            println!("artifacts ({}):", m.artifacts.len());
            for a in &m.artifacts {
                println!("  {} kind={} file={:?} meta={:?}", a.name, a.kind, a.file, a.meta);
            }
        }
        Err(e) => println!("artifacts: {e}"),
    }
}
