//! `repro` — the launcher for the over-the-air DSGD reproduction.
//!
//! Subcommands:
//!   train     one training job from a preset/TOML/CLI overrides
//!   fig N     regenerate the series of paper figure N (2..=7)
//!   all       every figure back to back
//!   theory    Theorem-1 convergence-bound curves
//!   info      environment + artifact status

use ota_dsgd::config::{presets, Backend, GraphFamily, PowerSchedule, RunConfig, Scheme};
use ota_dsgd::coordinator::{RustBackend, Trainer};
use ota_dsgd::experiments::{figures, runner, theory};
use ota_dsgd::model::PARAM_DIM;
use ota_dsgd::runtime::{Manifest, PjrtBackend, PjrtRuntime};
use ota_dsgd::util::cli::{Args, Usage};
use ota_dsgd::util::logging;

fn usage() -> Usage {
    Usage {
        program: "repro",
        about: "Over-the-air distributed SGD at the wireless edge (A-DSGD / D-DSGD)",
        subcommands: &[
            ("train", "run one training job (see options)"),
            ("fig <2|3|4|5|6|7|fading|d2d>", "regenerate a paper figure's series"),
            ("all", "regenerate every figure"),
            ("ablate [name]", "ablations: mean-removal | sparsity | amp-threshold | analog-power"),
            ("theory", "Theorem-1 convergence-bound curves"),
            ("info", "platform, artifacts, configuration echo"),
        ],
        options: &[
            ("--scheme <name>", "adsgd|fading|blind|d2d|ddsgd|signsgd|qsgd|error-free (train)"),
            ("--topology <family>", "full|ring|torus|er|star D2D graph (train)"),
            ("--devices <M>", "number of devices"),
            ("--local-samples <B>", "samples per device"),
            ("--channel-uses <s>", "channel uses per iteration"),
            ("--sparsity <k>", "A-DSGD sparsification level"),
            ("--pbar <P>", "average power constraint"),
            ("--iterations <T>", "DSGD iterations"),
            ("--power <sched>", "const|lh-stair|lh|hl"),
            ("--noniid", "biased (2-class) device data"),
            ("--seed <u64>", "rng seed"),
            ("--backend <rust|pjrt>", "gradient backend (train)"),
            ("--config <file.toml>", "load a TOML run config (train)"),
            ("--full", "paper-scale horizon (figs; slower)"),
            ("--out <dir>", "results directory (default results)"),
            ("--quiet", "suppress per-round progress"),
        ],
    }
}

fn main() {
    logging::init_from_env();
    let args = Args::from_env();
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "train" => cmd_train(&args),
        "fig" => cmd_fig(&args),
        "all" => cmd_all(&args),
        "ablate" => cmd_ablate(&args),
        "theory" => cmd_theory(&args),
        "info" => cmd_info(),
        _ => {
            print!("{}", usage().render());
        }
    }
}

/// Build a RunConfig from `--config` + CLI overrides on top of the smoke
/// preset (train subcommand).
fn config_from_args(args: &Args) -> RunConfig {
    let mut cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .unwrap_or_else(|e| panic!("cannot read {path}: {e}"));
            RunConfig::from_toml(&text).unwrap_or_else(|e| panic!("{e}"))
        }
        None => presets::smoke(),
    };
    if let Some(s) = args.get("scheme") {
        cfg.scheme = Scheme::parse(s).unwrap_or_else(|| panic!("unknown scheme {s}"));
    }
    if let Some(p) = args.get("power") {
        cfg.power = PowerSchedule::parse(p).unwrap_or_else(|| panic!("unknown schedule {p}"));
    }
    if let Some(f) = args.get("topology") {
        cfg.topology.family =
            GraphFamily::parse(f).unwrap_or_else(|| panic!("unknown graph family {f}"));
    }
    cfg.devices = args.usize("devices", cfg.devices);
    cfg.local_samples = args.usize("local-samples", cfg.local_samples);
    cfg.channel_uses = args.usize("channel-uses", cfg.channel_uses);
    cfg.sparsity = args.usize("sparsity", cfg.sparsity);
    cfg.pbar = args.f64("pbar", cfg.pbar);
    cfg.iterations = args.usize("iterations", cfg.iterations);
    cfg.seed = args.u64("seed", cfg.seed);
    cfg.eval_every = args.usize("eval-every", cfg.eval_every);
    if args.flag("noniid") {
        cfg.noniid = true;
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = Backend::parse(b).unwrap_or_else(|| panic!("unknown backend {b}"));
    }
    cfg
}

fn cmd_train(args: &Args) {
    let cfg = config_from_args(args);
    cfg.validate(PARAM_DIM).unwrap_or_else(|e| panic!("{e}"));
    println!("training: {}", cfg.summary());
    let mut trainer = match cfg.backend {
        Backend::Rust => Trainer::with_backend(cfg.clone(), Box::new(RustBackend::new())),
        Backend::Pjrt => {
            let runtime = PjrtRuntime::cpu().expect("PJRT client");
            let manifest = Manifest::load_default().expect("artifact manifest");
            let backend =
                PjrtBackend::from_manifest(&runtime, &manifest, cfg.devices, cfg.local_samples)
                    .expect("PJRT gradient backend");
            Trainer::with_backend(cfg.clone(), Box::new(backend))
        }
    }
    .expect("trainer");
    trainer.verbose = !args.flag("quiet");
    let log = trainer.run();
    println!(
        "done: final accuracy {:.4} (best {:.4}) in {:.1}s; power ok: {}",
        log.final_accuracy,
        log.best_accuracy(),
        log.total_secs,
        log.power_constraint_ok(1e-6)
    );
    let out = args.get_or("out", "results");
    let path = format!("{out}/train/{}.csv", cfg.scheme.name().replace(' ', "_"));
    log.write_csv(&path).expect("write csv");
    println!("series → {path}");
}

fn cmd_fig(args: &Args) {
    let which = args
        .positional
        .first()
        .unwrap_or_else(|| panic!("usage: repro fig <2..7|fading>"))
        .clone();
    let full = args.flag("full");
    let out = args.get_or("out", "results");
    let verbose = !args.flag("quiet");
    if which == "fading" {
        runner::run_experiment(&figures::fading(full), out, verbose);
        return;
    }
    if which == "d2d" {
        runner::run_experiment(&figures::d2d(full), out, verbose);
        return;
    }
    let n: usize = which.parse().expect("figure number, `fading` or `d2d`");
    match n {
        2 => {
            let spec = figures::fig2(args.flag("noniid"), full);
            runner::run_experiment(&spec, out, verbose);
            if !args.flag("noniid") {
                let spec_b = figures::fig2(true, full);
                runner::run_experiment(&spec_b, out, verbose);
            }
        }
        3 => {
            runner::run_experiment(&figures::fig3(full), out, verbose);
        }
        4 => {
            runner::run_experiment(&figures::fig4(full), out, verbose);
        }
        5 => {
            runner::run_experiment(&figures::fig5(full), out, verbose);
        }
        6 => {
            runner::run_experiment(&figures::fig6(full), out, verbose);
        }
        7 => {
            let spec = figures::fig7(full);
            let logs = runner::run_experiment(&spec, out, verbose);
            figures::print_fig7b(&logs, &spec.runs);
        }
        other => panic!("no figure {other}; valid: 2..=7, `fading` or `d2d`"),
    }
}

fn cmd_all(args: &Args) {
    let full = args.flag("full");
    let out = args.get_or("out", "results");
    let verbose = !args.flag("quiet");
    for spec in [
        figures::fig2(false, full),
        figures::fig2(true, full),
        figures::fig3(full),
        figures::fig4(full),
        figures::fig5(full),
        figures::fig6(full),
        figures::fading(full),
        figures::d2d(full),
    ] {
        runner::run_experiment(&spec, out, verbose);
    }
    let spec7 = figures::fig7(full);
    let logs = runner::run_experiment(&spec7, out, verbose);
    figures::print_fig7b(&logs, &spec7.runs);
    theory::run(&theory::TheoryParams::default(), out);
}

fn cmd_ablate(args: &Args) {
    use ota_dsgd::experiments::ablations;
    let full = args.flag("full");
    let out = args.get_or("out", "results");
    let verbose = !args.flag("quiet");
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let specs = match which {
        "mean-removal" => vec![ablations::mean_removal(full)],
        "sparsity" => vec![ablations::sparsity(full)],
        "amp-threshold" => vec![ablations::amp_threshold(full)],
        "analog-power" => vec![ablations::analog_power(full)],
        "all" => ablations::all(full),
        other => panic!("unknown ablation {other:?}"),
    };
    for spec in specs {
        runner::run_experiment(&spec, out, verbose);
    }
}

fn cmd_theory(args: &Args) {
    let out = args.get_or("out", "results");
    let mut p = theory::TheoryParams::default();
    p.pbar = args.f64("pbar", p.pbar);
    p.devices = args.usize("devices", p.devices);
    p.grad_bound = args.f64("grad-bound", p.grad_bound);
    p.convexity = args.f64("convexity", p.convexity);
    theory::run(&p, out);
}

fn cmd_info() {
    println!("ota-dsgd v{}", ota_dsgd::VERSION);
    println!("model dim d = {PARAM_DIM}");
    match PjrtRuntime::cpu() {
        Ok(rt) => println!("PJRT platform: {}", rt.platform()),
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    match Manifest::load_default() {
        Ok(m) => {
            println!("artifacts ({}):", m.artifacts.len());
            for a in &m.artifacts {
                println!("  {} kind={} file={:?} meta={:?}", a.name, a.kind, a.file, a.meta);
            }
        }
        Err(e) => println!("artifacts: {e}"),
    }
}
