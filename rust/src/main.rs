//! `repro` — the launcher for the over-the-air DSGD reproduction.
//!
//! Subcommands:
//!   train     one training job from a preset/TOML/CLI overrides
//!   fig N     regenerate the series of paper figure N (2..=7)
//!   all       every figure back to back
//!   resume    re-run a figure campaign through the run cache (forced on)
//!   status    list the campaign store's cached/partial runs
//!   theory    Theorem-1 convergence-bound curves
//!   info      environment + artifact status
//!
//! Figure campaigns run through the content-addressed run cache by default
//! (`campaign::scheduler`): completed runs load from the store, partial
//! runs resume from their latest snapshot, only the delta executes.
//! `--no-cache` bypasses the store entirely.

use ota_dsgd::campaign::{scheduler, RunStore};
use ota_dsgd::config::{
    presets, Backend, CampaignConfig, GraphFamily, PowerSchedule, RunConfig, Scheme,
};
use ota_dsgd::coordinator::{RustBackend, TrainLog, Trainer};
use ota_dsgd::experiments::{figures, runner, theory};
use ota_dsgd::model::PARAM_DIM;
use ota_dsgd::runtime::{Manifest, PjrtBackend, PjrtRuntime};
use ota_dsgd::util::cli::{Args, Usage};
use ota_dsgd::util::logging;

fn usage() -> Usage {
    Usage {
        program: "repro",
        about: "Over-the-air distributed SGD at the wireless edge (A-DSGD / D-DSGD)",
        subcommands: &[
            ("train", "run one training job (see options)"),
            ("fig <2|3|4|5|6|7|fading|d2d>", "regenerate a paper figure's series"),
            ("all", "regenerate every figure"),
            ("resume <fig|all>", "re-run a figure campaign through the run cache"),
            ("status", "campaign store status (cached/partial runs)"),
            ("ablate [name]", "ablations: mean-removal | sparsity | amp-threshold | analog-power"),
            ("theory", "Theorem-1 convergence-bound curves"),
            ("info", "platform, artifacts, configuration echo"),
        ],
        options: &[
            ("--scheme <name>", "adsgd|fading|blind|d2d|ddsgd|signsgd|qsgd|error-free (train)"),
            ("--topology <family>", "full|ring|torus|er|star D2D graph (train)"),
            ("--devices <M>", "number of devices"),
            ("--local-samples <B>", "samples per device"),
            ("--channel-uses <s>", "channel uses per iteration"),
            ("--sparsity <k>", "A-DSGD sparsification level"),
            ("--pbar <P>", "average power constraint"),
            ("--iterations <T>", "DSGD iterations"),
            ("--power <sched>", "const|lh-stair|lh|hl"),
            ("--noniid", "biased (2-class) device data"),
            ("--seed <u64>", "rng seed"),
            ("--backend <rust|pjrt>", "gradient backend (train)"),
            ("--config <file.toml>", "TOML config: [run] for train, [campaign] for figs"),
            ("--full", "paper-scale horizon (figs; slower)"),
            ("--out-dir <dir>", "results directory (default results; --out is an alias)"),
            ("--no-cache", "bypass the campaign run cache (figs)"),
            ("--store-dir <dir>", "campaign store (default <out-dir>/.campaign)"),
            ("--snapshot-every <N>", "trainer snapshot cadence in rounds (default 20)"),
            ("--quiet", "suppress per-round progress"),
        ],
    }
}

fn main() {
    logging::init_from_env();
    let args = Args::from_env();
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "train" => cmd_train(&args),
        "fig" => cmd_fig(&args, false),
        "all" => cmd_all(&args, false),
        "resume" => cmd_fig(&args, true),
        "status" => cmd_status(&args),
        "ablate" => cmd_ablate(&args),
        "theory" => cmd_theory(&args),
        "info" => cmd_info(),
        _ => {
            print!("{}", usage().render());
        }
    }
}

/// Results directory: `--out-dir` with `--out` kept as the legacy alias.
fn out_dir(args: &Args) -> String {
    args.get("out-dir")
        .or_else(|| args.get("out"))
        .unwrap_or("results")
        .to_string()
}

/// Campaign policy for figure runs: `[campaign]` table from `--config` if
/// given, CLI overrides on top. `None` = cache bypassed (`--no-cache` or
/// `enabled = false`), unless `force_resume` pins it on (`repro resume`).
fn campaign_from_args(args: &Args, force_resume: bool) -> Option<CampaignConfig> {
    if args.flag("no-cache") && !force_resume {
        return None;
    }
    let mut c = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .unwrap_or_else(|e| panic!("cannot read {path}: {e}"));
            CampaignConfig::from_toml(&text).unwrap_or_else(|e| panic!("{e}"))
        }
        None => CampaignConfig::default(),
    };
    if let Some(dir) = args.get("store-dir") {
        c.store_dir = dir.to_string();
    }
    c.snapshot_every = args.usize("snapshot-every", c.snapshot_every);
    if force_resume {
        c.enabled = true;
        c.resume = true;
    }
    if !c.enabled {
        return None;
    }
    Some(c)
}

/// Run one spec through the cache-aware scheduler (or the plain runner
/// when the cache is bypassed).
fn run_spec(
    spec: &runner::ExperimentSpec,
    out: &str,
    verbose: bool,
    campaign: Option<&CampaignConfig>,
) -> Vec<TrainLog> {
    match campaign {
        Some(c) => scheduler::run_experiment_cached(spec, out, verbose, c).0,
        None => runner::run_experiment(spec, out, verbose),
    }
}

/// Build a RunConfig from `--config` + CLI overrides on top of the smoke
/// preset (train subcommand).
fn config_from_args(args: &Args) -> RunConfig {
    let mut cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .unwrap_or_else(|e| panic!("cannot read {path}: {e}"));
            RunConfig::from_toml(&text).unwrap_or_else(|e| panic!("{e}"))
        }
        None => presets::smoke(),
    };
    if let Some(s) = args.get("scheme") {
        cfg.scheme = Scheme::parse(s).unwrap_or_else(|| panic!("unknown scheme {s}"));
    }
    if let Some(p) = args.get("power") {
        cfg.power = PowerSchedule::parse(p).unwrap_or_else(|| panic!("unknown schedule {p}"));
    }
    if let Some(f) = args.get("topology") {
        cfg.topology.family =
            GraphFamily::parse(f).unwrap_or_else(|| panic!("unknown graph family {f}"));
    }
    cfg.devices = args.usize("devices", cfg.devices);
    cfg.local_samples = args.usize("local-samples", cfg.local_samples);
    cfg.channel_uses = args.usize("channel-uses", cfg.channel_uses);
    cfg.sparsity = args.usize("sparsity", cfg.sparsity);
    cfg.pbar = args.f64("pbar", cfg.pbar);
    cfg.iterations = args.usize("iterations", cfg.iterations);
    cfg.seed = args.u64("seed", cfg.seed);
    cfg.eval_every = args.usize("eval-every", cfg.eval_every);
    if args.flag("noniid") {
        cfg.noniid = true;
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = Backend::parse(b).unwrap_or_else(|| panic!("unknown backend {b}"));
    }
    cfg
}

fn cmd_train(args: &Args) {
    let cfg = config_from_args(args);
    cfg.validate(PARAM_DIM).unwrap_or_else(|e| panic!("{e}"));
    println!("training: {}", cfg.summary());
    let mut trainer = match cfg.backend {
        Backend::Rust => Trainer::with_backend(cfg.clone(), Box::new(RustBackend::new())),
        Backend::Pjrt => {
            let runtime = PjrtRuntime::cpu().expect("PJRT client");
            let manifest = Manifest::load_default().expect("artifact manifest");
            let backend =
                PjrtBackend::from_manifest(&runtime, &manifest, cfg.devices, cfg.local_samples)
                    .expect("PJRT gradient backend");
            Trainer::with_backend(cfg.clone(), Box::new(backend))
        }
    }
    .expect("trainer");
    trainer.verbose = !args.flag("quiet");
    let log = trainer.run();
    println!(
        "done: final accuracy {:.4} (best {:.4}) in {:.1}s; power ok: {}",
        log.final_accuracy,
        log.best_accuracy(),
        log.total_secs,
        log.power_constraint_ok(1e-6)
    );
    let out = out_dir(args);
    let path = format!("{out}/train/{}.csv", cfg.scheme.name().replace(' ', "_"));
    log.write_csv(&path).expect("write csv");
    println!("series → {path}");
}

/// `repro fig <which>` and (with `force_resume`) `repro resume <which>`.
fn cmd_fig(args: &Args, force_resume: bool) {
    let which = args
        .positional
        .first()
        .unwrap_or_else(|| panic!("usage: repro fig <2..7|fading|d2d>"))
        .clone();
    if force_resume && which == "all" {
        cmd_all(args, true);
        return;
    }
    let full = args.flag("full");
    let out = out_dir(args);
    let verbose = !args.flag("quiet");
    let campaign = campaign_from_args(args, force_resume);
    let run = |spec: &runner::ExperimentSpec| run_spec(spec, &out, verbose, campaign.as_ref());
    if which == "fading" {
        run(&figures::fading(full));
        return;
    }
    if which == "d2d" {
        run(&figures::d2d(full));
        return;
    }
    let n: usize = which.parse().expect("figure number, `fading` or `d2d`");
    match n {
        2 => {
            run(&figures::fig2(args.flag("noniid"), full));
            if !args.flag("noniid") {
                run(&figures::fig2(true, full));
            }
        }
        3 => {
            run(&figures::fig3(full));
        }
        4 => {
            run(&figures::fig4(full));
        }
        5 => {
            run(&figures::fig5(full));
        }
        6 => {
            run(&figures::fig6(full));
        }
        7 => {
            let spec = figures::fig7(full);
            let logs = run(&spec);
            figures::print_fig7b(&logs, &spec.runs);
        }
        other => panic!("no figure {other}; valid: 2..=7, `fading` or `d2d`"),
    }
}

fn cmd_all(args: &Args, force_resume: bool) {
    let full = args.flag("full");
    let out = out_dir(args);
    let verbose = !args.flag("quiet");
    let campaign = campaign_from_args(args, force_resume);
    for spec in [
        figures::fig2(false, full),
        figures::fig2(true, full),
        figures::fig3(full),
        figures::fig4(full),
        figures::fig5(full),
        figures::fig6(full),
        figures::fading(full),
        figures::d2d(full),
    ] {
        run_spec(&spec, &out, verbose, campaign.as_ref());
    }
    let spec7 = figures::fig7(full);
    let logs = run_spec(&spec7, &out, verbose, campaign.as_ref());
    figures::print_fig7b(&logs, &spec7.runs);
    theory::run(&theory::TheoryParams::default(), &out);
}

/// `repro status`: list the campaign store's entries.
fn cmd_status(args: &Args) {
    let out = out_dir(args);
    let store_dir = match args.get("store-dir") {
        Some(dir) => dir.to_string(),
        None => campaign_from_args(args, true)
            .expect("resume-forced campaign config is always present")
            .store_dir_or(&out),
    };
    let store = match RunStore::open(&store_dir) {
        Ok(s) => s,
        Err(e) => {
            println!("campaign store {store_dir}: unavailable ({e})");
            return;
        }
    };
    let entries = store.list();
    if entries.is_empty() {
        println!("campaign store {store_dir}: empty");
        return;
    }
    println!("campaign store {store_dir}: {} run(s)", entries.len());
    println!("{:<16} {:<8} {:>11}  {}", "key", "status", "round", "run");
    for m in entries {
        println!(
            "{:<16} {:<8} {:>5}/{:<5}  `{}` — {}",
            m.key,
            m.status.name(),
            m.snapshot_round,
            m.iterations,
            m.label,
            m.summary
        );
    }
}

fn cmd_ablate(args: &Args) {
    use ota_dsgd::experiments::ablations;
    let full = args.flag("full");
    let out = out_dir(args);
    let verbose = !args.flag("quiet");
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let specs = match which {
        "mean-removal" => vec![ablations::mean_removal(full)],
        "sparsity" => vec![ablations::sparsity(full)],
        "amp-threshold" => vec![ablations::amp_threshold(full)],
        "analog-power" => vec![ablations::analog_power(full)],
        "all" => ablations::all(full),
        other => panic!("unknown ablation {other:?}"),
    };
    for spec in specs {
        runner::run_experiment(&spec, &out, verbose);
    }
}

fn cmd_theory(args: &Args) {
    let out = out_dir(args);
    let mut p = theory::TheoryParams::default();
    p.pbar = args.f64("pbar", p.pbar);
    p.devices = args.usize("devices", p.devices);
    p.grad_bound = args.f64("grad-bound", p.grad_bound);
    p.convexity = args.f64("convexity", p.convexity);
    theory::run(&p, &out);
}

fn cmd_info() {
    println!("ota-dsgd v{}", ota_dsgd::VERSION);
    println!("model dim d = {PARAM_DIM}");
    match PjrtRuntime::cpu() {
        Ok(rt) => println!("PJRT platform: {}", rt.platform()),
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    match Manifest::load_default() {
        Ok(m) => {
            println!("artifacts ({}):", m.artifacts.len());
            for a in &m.artifacts {
                println!("  {} kind={} file={:?} meta={:?}", a.name, a.kind, a.file, a.meta);
            }
        }
        Err(e) => println!("artifacts: {e}"),
    }
}
