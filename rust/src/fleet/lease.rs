//! Crash-safe filesystem run leases — the mutual-exclusion primitive that
//! lets many worker processes share one campaign store.
//!
//! # Protocol
//!
//! One lease file per run key under `<store>/fleet/leases/<key>.lease`.
//!
//! * **Acquire** — the claimant writes its record to a private temp file
//!   in the same directory, then `hard_link`s it to the lease path.
//!   `link(2)` fails atomically when the target exists, which is exactly
//!   the test-and-set a lock needs (a plain `rename` would silently
//!   replace a rival's live lease). The temp file is removed either way.
//! * **Heartbeat** — the holder verifies the record is still its own,
//!   then refreshes the file's mtime on the open handle. Lease content is
//!   never rewritten after acquire, so a heartbeat can never clobber a
//!   rival's record; the file never disappears during a refresh, so a
//!   concurrent observer always sees a complete record with either the
//!   old or the new mtime.
//! * **Expiry / reclaim** — a lease whose mtime is older than the TTL
//!   belongs to a worker that died (SIGKILL leaves no chance to clean
//!   up). A claimant *steals* it by renaming it to a unique grave name:
//!   the rename succeeds for exactly one stealer, the losers fall through
//!   to a normal acquire attempt. The reclaimed run then resumes from its
//!   latest store snapshot — never from scratch.
//! * **Release** — the holder removes the file, but only after verifying
//!   the record is still its own: if the lease was stolen while the
//!   holder stalled past the TTL, removing it would free a *rival's*
//!   lease.
//!
//! # Failure model
//!
//! The lease is an efficiency device, not a correctness boundary. If a
//! stalled worker loses its lease and both it and the thief finish the
//! run, both write the *same bytes* (runs are deterministic) through
//! atomic temp-file + rename writes — last writer wins with an identical
//! blob. Heartbeat cadence is validated well under the TTL
//! ([`crate::config::FleetConfig::validate`]) precisely so that duplicated
//! work stays a freak event rather than a steady state.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, SystemTime};

/// Per-process sequence for unique temp/grave names (shared-store safe:
/// names also embed the pid).
fn seq() -> u64 {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    SEQ.fetch_add(1, Ordering::Relaxed)
}

/// The lease directory for a store root.
pub fn lease_dir(store_root: &Path) -> PathBuf {
    store_root.join("fleet").join("leases")
}

/// A held run lease. Dropping it without [`Lease::release`] performs a
/// best-effort conditional release (a crash between acquire and drop is
/// what expiry-based reclaim is for).
pub struct Lease {
    path: PathBuf,
    record: String,
    released: bool,
}

fn is_stale(meta: &fs::Metadata, ttl: Duration) -> bool {
    match meta.modified().map(|m| SystemTime::now().duration_since(m)) {
        // An unreadable or future mtime counts as fresh — reclaiming on
        // bad evidence risks a live double-claim, waiting risks nothing.
        Ok(Ok(age)) => age > ttl,
        _ => false,
    }
}

/// Observed state of a key's lease — for status displays; advisory only
/// (the state can change the instant after it is read).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LeaseState {
    /// No lease file.
    Free,
    /// Held and fresh; carries the record's owner id when readable.
    Held(String),
    /// Present but older than the TTL — reclaimable.
    Stale,
}

/// Inspect the lease for `key` without touching it.
pub fn lease_state(dir: &Path, key: &str, ttl: Duration) -> LeaseState {
    let path = dir.join(format!("{key}.lease"));
    let Ok(meta) = fs::metadata(&path) else {
        return LeaseState::Free;
    };
    if is_stale(&meta, ttl) {
        return LeaseState::Stale;
    }
    let owner = fs::read_to_string(&path)
        .ok()
        .and_then(|s| {
            s.lines()
                .next()
                .and_then(|l| l.strip_prefix("owner = "))
                .map(|o| o.trim_matches('"').to_string())
        })
        .unwrap_or_else(|| "?".into());
    LeaseState::Held(owner)
}

/// Try to claim the lease for `key`. Returns `Ok(None)` when another
/// worker holds a fresh lease. A stale lease (mtime older than `ttl`) is
/// stolen first, then acquired through the normal path — exactly one of
/// any number of concurrent claimants wins.
pub fn try_acquire(
    dir: &Path,
    key: &str,
    owner: &str,
    ttl: Duration,
) -> io::Result<Option<Lease>> {
    try_acquire_with(dir, key, owner, ttl, &mut || {})
}

/// [`try_acquire`] with a reclaim observer: `on_reclaim` fires exactly
/// when this claimant wins the stale-steal rename of a genuinely dead
/// lease — the rename succeeds for exactly one stealer, so across the
/// whole fleet the callback fires **exactly once per reclaimed lease**
/// (the hook the event log's `reclaimed` kind relies on).
pub fn try_acquire_with(
    dir: &Path,
    key: &str,
    owner: &str,
    ttl: Duration,
    on_reclaim: &mut dyn FnMut(),
) -> io::Result<Option<Lease>> {
    fs::create_dir_all(dir)?;
    let path = dir.join(format!("{key}.lease"));
    if let Ok(meta) = fs::metadata(&path) {
        if !is_stale(&meta, ttl) {
            return Ok(None);
        }
        // Steal the stale lease: one rename wins, losers fall through and
        // contend on the hard_link below like everyone else.
        let grave = dir.join(format!("{key}.stale.{}.{}", std::process::id(), seq()));
        if fs::rename(&path, &grave).is_ok() {
            // TOCTOU guard: between our staleness read and the rename, the
            // slot may have been reclaimed and re-leased by a rival (or
            // refreshed by a holder that woke up) — in which case we just
            // renamed away a LIVE lease. rename preserves mtime, so
            // re-check on the grave and put a live lease back (the
            // hard_link only lands if nobody re-acquired meanwhile).
            let still_stale = fs::metadata(&grave)
                .map(|m| is_stale(&m, ttl))
                .unwrap_or(true);
            if !still_stale {
                let relinked = fs::hard_link(&grave, &path);
                let _ = fs::remove_file(&grave);
                if relinked.is_ok() {
                    return Ok(None);
                }
                // A third claimant took the slot inside the window; the
                // displaced live holder will observe the loss on its next
                // heartbeat (results stay correct — see the failure
                // model). Fall through and contend normally.
            } else {
                let _ = fs::remove_file(&grave);
                on_reclaim();
            }
        }
    }
    // The record doubles as an ownership token: pid + per-process seq +
    // wall-clock nanos make it unique across the fleet.
    let nonce = SystemTime::now()
        .duration_since(SystemTime::UNIX_EPOCH)
        .map(|d| d.as_nanos())
        .unwrap_or(0);
    let record = format!(
        "owner = \"{owner}\"\npid = {}\nnonce = {}.{nonce}\n",
        std::process::id(),
        seq(),
    );
    let tmp = dir.join(format!("{key}.tmp.{}.{}", std::process::id(), seq()));
    fs::write(&tmp, &record)?;
    let linked = fs::hard_link(&tmp, &path);
    let _ = fs::remove_file(&tmp);
    match linked {
        Ok(()) => Ok(Some(Lease {
            path,
            record,
            released: false,
        })),
        Err(e) if e.kind() == io::ErrorKind::AlreadyExists => Ok(None),
        Err(e) => Err(e),
    }
}

impl Lease {
    /// Refresh the lease mtime. Returns `Ok(false)` when the lease no
    /// longer belongs to this holder (it expired and was stolen) — the
    /// holder should finish its current run (results are deterministic
    /// and writes atomic, so a duplicate finish is harmless) but must not
    /// claim further work on this lease.
    ///
    /// The refresh touches the mtime of the *open handle* after verifying
    /// the record is still ours; lease content is never rewritten after
    /// acquire, so a rival's freshly installed record can never be
    /// clobbered. The residual race (the lease is stolen between the
    /// verify and the touch) at worst refreshes the *thief's* mtime —
    /// which only extends a live rival's lease slightly, never corrupts
    /// ownership.
    pub fn heartbeat(&self) -> io::Result<bool> {
        use std::io::Read as _;
        let mut f = match fs::OpenOptions::new().read(true).write(true).open(&self.path) {
            Ok(f) => f,
            Err(_) => return Ok(false),
        };
        let mut cur = String::new();
        if f.read_to_string(&mut cur).is_err() || cur != self.record {
            return Ok(false);
        }
        f.set_modified(SystemTime::now())?;
        Ok(true)
    }

    /// Release the lease if it is still ours.
    pub fn release(mut self) {
        self.release_inner();
    }

    fn release_inner(&mut self) {
        if self.released {
            return;
        }
        self.released = true;
        if let Ok(cur) = fs::read_to_string(&self.path) {
            if cur == self.record {
                let _ = fs::remove_file(&self.path);
            }
        }
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        self.release_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("ota_lease_{name}"));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn acquire_is_exclusive_and_release_frees() {
        let dir = tmp_dir("excl");
        let ttl = Duration::from_secs(60);
        let a = try_acquire(&dir, "k1", "a", ttl).unwrap();
        assert!(a.is_some());
        assert!(try_acquire(&dir, "k1", "b", ttl).unwrap().is_none());
        // A different key is independent.
        assert!(try_acquire(&dir, "k2", "b", ttl).unwrap().is_some());
        a.unwrap().release();
        assert!(try_acquire(&dir, "k1", "b", ttl).unwrap().is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    /// The race the fleet depends on: any number of concurrent claimants,
    /// exactly one winner, every round.
    #[test]
    fn concurrent_claimants_one_winner() {
        let dir = tmp_dir("race");
        let ttl = Duration::from_secs(60);
        for round in 0..25 {
            let key = format!("key{round}");
            let winners: Vec<Option<Lease>> = std::thread::scope(|scope| {
                let handles: Vec<_> = (0..8)
                    .map(|i| {
                        let dir = &dir;
                        let key = &key;
                        scope.spawn(move || {
                            try_acquire(dir, key, &format!("w{i}"), ttl).unwrap()
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            let won = winners.iter().filter(|w| w.is_some()).count();
            assert_eq!(won, 1, "round {round}: exactly one claimant must win");
        }
        let _ = fs::remove_dir_all(&dir);
    }

    /// A killed worker's lease goes stale and is reclaimed; a heartbeating
    /// worker's is not.
    #[test]
    fn stale_lease_reclaimed_fresh_lease_respected() {
        let dir = tmp_dir("stale");
        let ttl = Duration::from_millis(400);
        let held = try_acquire(&dir, "k", "dead", ttl).unwrap().unwrap();
        // Forget instead of releasing — the SIGKILL'd-worker shape.
        std::mem::forget(held);
        assert!(try_acquire(&dir, "k", "b", ttl).unwrap().is_none());
        std::thread::sleep(Duration::from_millis(900));
        let reclaimed = try_acquire(&dir, "k", "b", ttl).unwrap();
        assert!(reclaimed.is_some(), "stale lease must be reclaimable");

        // A live holder heartbeats and survives a wait past the original
        // acquire time (TTL sized generously against coarse-mtime
        // filesystems — staleness only ever *overestimates* there).
        let ttl_live = Duration::from_secs(2);
        let live = try_acquire(&dir, "k2", "alive", ttl_live).unwrap().unwrap();
        // Total wait (2.5s) exceeds the TTL, so only the heartbeats keep
        // the lease alive.
        for _ in 0..10 {
            std::thread::sleep(Duration::from_millis(250));
            assert!(live.heartbeat().unwrap(), "holder must keep its own lease");
        }
        assert!(
            try_acquire(&dir, "k2", "b", ttl_live).unwrap().is_none(),
            "heartbeats must keep the lease fresh"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    /// After a steal, the original holder's heartbeat reports the loss and
    /// its release leaves the thief's lease intact.
    #[test]
    fn stolen_lease_is_not_clobbered_by_old_holder() {
        let dir = tmp_dir("steal");
        let ttl = Duration::from_millis(300);
        let old = try_acquire(&dir, "k", "old", ttl).unwrap().unwrap();
        std::thread::sleep(Duration::from_millis(800));
        let thief = try_acquire(&dir, "k", "thief", ttl).unwrap().unwrap();
        assert!(!old.heartbeat().unwrap(), "old holder must observe the loss");
        old.release();
        // The thief's lease survives the old holder's release.
        assert!(try_acquire(&dir, "k", "c", ttl).unwrap().is_none());
        assert!(thief.heartbeat().unwrap());
        thief.release();
        let _ = fs::remove_dir_all(&dir);
    }
}
