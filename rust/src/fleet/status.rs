//! Fail-soft fleet status collection and terminal rendering.
//!
//! `repro fleet-status` and `repro watch` read the queue, the lease
//! directory, and the manifests **while workers are writing them**. A
//! status reader racing a writer may see a torn queue item (a replace
//! in progress), a mid-write lease record, or a manifest in flight —
//! none of which may abort the view: every unreadable artifact is
//! skipped and *counted*, and the render surfaces the count as
//! `unreadable: N`. [`collect_status`] therefore returns a plain value,
//! never an error.
//!
//! The live dashboard ([`render_dashboard`]) joins this queue/lease
//! view with the replayed event log ([`super::metrics`]): progress bars
//! per run, grad-norm / accuracy sparklines from the per-round
//! telemetry, and per-worker throughput.

use std::fmt::Write as _;
use std::time::Duration;

use crate::campaign::RunStore;

use super::events::json_escape;
use super::health::Finding;
use super::metrics::Metrics;
use super::trace::WorkerUtil;
use super::{lease, queue};

/// One queue item's observed state.
#[derive(Clone, Debug)]
pub struct ItemStatus {
    pub seq: usize,
    pub key: String,
    pub label: String,
    pub spec_id: String,
    /// `complete`, `run:<owner>`, `stale-lease`, or `queued`.
    pub state: String,
    pub rounds_done: usize,
    pub rounds_total: usize,
}

/// A point-in-time, fail-soft view of a fleet store.
#[derive(Clone, Debug, Default)]
pub struct FleetStatus {
    pub items: Vec<ItemStatus>,
    /// Queue item files skipped as torn/unparseable, plus a whole
    /// unreadable queue directory counted as one.
    pub unreadable: usize,
    pub complete: usize,
    pub running: usize,
    pub stale: usize,
    pub rounds_done: usize,
    pub rounds_total: usize,
}

/// Collect the queue/lease/progress view. Never fails: torn queue
/// items and unreadable lease records are skipped and counted (see the
/// module docs), and an unreadable queue directory yields an empty view
/// with `unreadable >= 1`.
pub fn collect_status(store: &RunStore, ttl: Duration) -> FleetStatus {
    let mut st = FleetStatus::default();
    let (items, skipped) = match queue::load_queue_counted(store) {
        Ok(pair) => pair,
        Err(_) => (Vec::new(), 1),
    };
    st.unreadable = skipped;
    let ldir = lease::lease_dir(store.root());
    for item in &items {
        let remaining = queue::remaining_rounds(store, item);
        let done = item.cfg.iterations.saturating_sub(remaining);
        st.rounds_done += done;
        st.rounds_total += item.cfg.iterations;
        let state = if remaining == 0 {
            st.complete += 1;
            "complete".to_string()
        } else {
            // `lease_state` is itself fail-soft: a mid-write or
            // garbage lease record reads as `Held("?")`, a missing
            // file as `Free` — never an error.
            match lease::lease_state(&ldir, &item.key, ttl) {
                lease::LeaseState::Held(owner) => {
                    st.running += 1;
                    format!("run:{owner}")
                }
                lease::LeaseState::Stale => {
                    st.stale += 1;
                    "stale-lease".to_string()
                }
                lease::LeaseState::Free => "queued".to_string(),
            }
        };
        st.items.push(ItemStatus {
            seq: item.seq,
            key: item.key.clone(),
            label: item.label.clone(),
            spec_id: item.spec_id.clone(),
            state,
            rounds_done: done,
            rounds_total: item.cfg.iterations,
        });
    }
    st
}

/// Render a [`FleetStatus`] as the `/status` JSON document served by
/// `fleet::serve` (and parsed back by `fleet::client::parse_status`,
/// which pins the round-trip). `store_dir` names the store on the
/// *server* machine — informational for the remote viewer.
pub fn status_to_json(store_dir: &str, st: &FleetStatus) -> String {
    let mut s = String::with_capacity(256);
    let _ = write!(
        s,
        "{{\"store_dir\":\"{}\",\"unreadable\":{},\"complete\":{},\"running\":{},\"stale\":{},\"rounds_done\":{},\"rounds_total\":{},\"items\":[",
        json_escape(store_dir),
        st.unreadable,
        st.complete,
        st.running,
        st.stale,
        st.rounds_done,
        st.rounds_total
    );
    for (i, it) in st.items.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(
            s,
            "{{\"seq\":{},\"key\":\"{}\",\"label\":\"{}\",\"spec_id\":\"{}\",\"state\":\"{}\",\"rounds_done\":{},\"rounds_total\":{}}}",
            it.seq,
            json_escape(&it.key),
            json_escape(&it.label),
            json_escape(&it.spec_id),
            json_escape(&it.state),
            it.rounds_done,
            it.rounds_total
        );
    }
    s.push_str("]}");
    s
}

/// The classic `repro fleet-status` table.
pub fn render_status(store_dir: &str, st: &FleetStatus) -> String {
    let mut s = String::new();
    if st.items.is_empty() {
        let _ = writeln!(
            s,
            "fleet queue at {store_dir}: empty (run `repro fleet` to enqueue)"
        );
        if st.unreadable > 0 {
            let _ = writeln!(s, "unreadable: {} queue item(s) skipped", st.unreadable);
        }
        return s;
    }
    let _ = writeln!(s, "fleet store {store_dir}: {} queued run(s)", st.items.len());
    let _ = writeln!(s, "{:<4} {:<16} {:<14} {:>11}  {}", "seq", "key", "state", "round", "run");
    for it in &st.items {
        let _ = writeln!(
            s,
            "{:<4} {:<16} {:<14} {:>5}/{:<5}  `{}` ({})",
            it.seq, it.key, it.state, it.rounds_done, it.rounds_total, it.label, it.spec_id
        );
    }
    let _ = writeln!(
        s,
        "\n{}/{} run(s) complete, {} running, {} stale lease(s); {}/{} rounds done",
        st.complete,
        st.items.len(),
        st.running,
        st.stale,
        st.rounds_done,
        st.rounds_total
    );
    if st.unreadable > 0 {
        let _ = writeln!(s, "unreadable: {} queue item(s) skipped", st.unreadable);
    }
    s
}

/// `[####....]`-style progress bar.
fn progress_bar(done: usize, total: usize, width: usize) -> String {
    let filled = if total == 0 {
        0
    } else {
        (done * width + total / 2) / total
    }
    .min(width);
    let mut bar = String::with_capacity(width + 2);
    bar.push('[');
    for i in 0..width {
        bar.push(if i < filled { '#' } else { '.' });
    }
    bar.push(']');
    bar
}

/// Unicode sparkline over the last `width` finite values.
fn sparkline(values: impl Iterator<Item = f64>, width: usize) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let vals: Vec<f64> = values.filter(|v| v.is_finite()).collect();
    let tail = &vals[vals.len().saturating_sub(width)..];
    if tail.is_empty() {
        return String::new();
    }
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &v in tail {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    tail.iter()
        .map(|&v| {
            let idx = if hi > lo {
                (((v - lo) / (hi - lo)) * 7.0).round() as usize
            } else {
                3
            };
            BARS[idx.min(7)]
        })
        .collect()
}

/// The `repro watch` dashboard: the queue/lease view joined with the
/// replayed event-log metrics, the active health findings (the alerts
/// pane; pass `&[]` when health is not being tracked), and the
/// trace-fed worker-utilization pane (pass `&[]` when tracing is off
/// or the store has no spans — the pane fails soft to absent).
pub fn render_dashboard(
    store_dir: &str,
    st: &FleetStatus,
    m: &Metrics,
    findings: &[Finding],
    util: &[WorkerUtil],
) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "fleet store {store_dir} — {} queued, depth {}, {} event(s), {} round(s) trained",
        st.items.len(),
        m.queue_depth(),
        m.events_total,
        m.rounds_total(),
    );
    let _ = writeln!(
        s,
        "runs: {} complete, {} running, {} stale lease(s); reclaims {}, claim races {}",
        st.complete, st.running, st.stale, m.reclaims, m.already_done
    );
    if st.unreadable > 0 || m.skipped_lines > 0 || m.unreadable_files > 0 {
        let _ = writeln!(
            s,
            "unreadable: {} queue item(s), {} log line(s), {} log file(s) skipped",
            st.unreadable, m.skipped_lines, m.unreadable_files
        );
    }
    if !findings.is_empty() {
        let _ = writeln!(s, "alerts:");
        for f in findings {
            let _ = writeln!(s, "  !! {:<16} {}", f.kind.name(), f.detail);
        }
    }
    let _ = writeln!(s);
    for it in &st.items {
        let pct = if it.rounds_total == 0 {
            0.0
        } else {
            100.0 * it.rounds_done as f64 / it.rounds_total as f64
        };
        let _ = writeln!(
            s,
            "{} {:>5.1}%  {:<14} `{}` ({}) {}/{}",
            progress_bar(it.rounds_done, it.rounds_total, 20),
            pct,
            it.state,
            it.label,
            it.spec_id,
            it.rounds_done,
            it.rounds_total
        );
        if let Some(run) = m.runs.get(&it.key) {
            let gauge = |v: Option<(u64, f64)>| {
                v.map_or("-".to_string(), |(_, x)| format!("{x:.4}"))
            };
            let grad = sparkline(run.grad_norm.values().copied(), 32);
            let acc = sparkline(run.accuracy.values().copied(), 32);
            if !grad.is_empty() || !acc.is_empty() {
                let _ = writeln!(
                    s,
                    "  ‖ĝ‖ {} {}   acc {} {}",
                    grad,
                    gauge(run.last_grad_norm()),
                    acc,
                    gauge(run.last_accuracy()),
                );
            }
            // Link-diagnostics pane: only runs whose probes were enabled
            // carry these series.
            if !run.snr_db.is_empty() || !run.participating.is_empty() {
                let snr = sparkline(run.snr_db.values().copied(), 32);
                let _ = writeln!(
                    s,
                    "  SNR {} {} dB   tx {}/dev   headroom {}",
                    snr,
                    gauge(run.last_snr_db()),
                    run.last_participating()
                        .map_or("-".to_string(), |(_, v)| format!("{v:.0}")),
                    gauge(run.last_link_headroom()),
                );
            }
            if !run.consensus.is_empty() {
                let cons = sparkline(run.consensus.values().copied(), 32);
                let _ = writeln!(
                    s,
                    "  consensus {} {}",
                    cons,
                    gauge(run.last_consensus()),
                );
            }
        }
    }
    if !m.workers.is_empty() {
        let _ = writeln!(s);
        let _ = writeln!(s, "workers:");
        for (w, ws) in &m.workers {
            let rate = ws.rounds_per_sec();
            let mut line = format!(
                "  {w:<12} claims={} rounds={} heartbeats={}",
                ws.claims, ws.rounds, ws.heartbeats
            );
            if ws.reclaims > 0 {
                line.push_str(&format!(" reclaims={}", ws.reclaims));
            }
            if rate > 0.0 {
                line.push_str(&format!(" {rate:.2} r/s"));
            }
            let _ = writeln!(s, "{line}");
        }
    }
    if !util.is_empty() {
        let _ = writeln!(s);
        let _ = writeln!(s, "utilization (from trace spans):");
        for u in util {
            let busy = 100.0 * u.busy_frac();
            let mut line = format!(
                "  {:<12} busy {:>5.1}%  idle {:>5.1}%  phase {}",
                u.worker,
                busy,
                100.0 - busy,
                u.last_phase
            );
            if let Some(ws) = m.workers.get(&u.worker) {
                let rate = ws.rounds_per_sec();
                if rate > 0.0 {
                    line.push_str(&format!("  {rate:.2} r/s"));
                }
            }
            let _ = writeln!(s, "{line}");
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, RunConfig, Scheme};
    use crate::experiments::runner::ExperimentSpec;
    use std::fs;
    use std::io::Write as _;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("ota_status_{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn spec() -> ExperimentSpec {
        let mut cfg = presets::smoke();
        cfg.iterations = 4;
        cfg.eval_every = 2;
        ExperimentSpec {
            id: "tstat".into(),
            title: "status".into(),
            runs: vec![
                ("error-free".into(), RunConfig { scheme: Scheme::ErrorFree, ..cfg.clone() }),
                ("signsgd".into(), RunConfig { scheme: Scheme::SignSgd, ..cfg }),
            ],
        }
    }

    /// The satellite-1 regression: a queue item truncated mid-byte and a
    /// lease record torn mid-write must degrade to a skip-and-count,
    /// never an abort.
    #[test]
    fn torn_queue_item_and_lease_are_skipped_not_fatal() {
        let dir = tmp("torn");
        let store = RunStore::open(dir.to_str().unwrap()).unwrap();
        let items = queue::enqueue_specs(&store, &[spec()]).unwrap();
        assert_eq!(items.len(), 2);

        // Truncate the first item file mid-byte — the shape a status
        // reader sees while `enqueue_specs` replaces the queue.
        let qdir = queue::queue_dir(store.root());
        let victim = fs::read_dir(&qdir)
            .unwrap()
            .filter_map(|e| e.ok().map(|e| e.path()))
            .find(|p| p.extension().and_then(|e| e.to_str()) == Some("toml"))
            .unwrap();
        let bytes = fs::read(&victim).unwrap();
        fs::write(&victim, &bytes[..bytes.len() / 2]).unwrap();

        // Tear the surviving item's lease record mid-write.
        let ldir = lease::lease_dir(store.root());
        fs::create_dir_all(&ldir).unwrap();
        let survivor = items
            .iter()
            .find(|i| !victim.to_string_lossy().contains(&i.key))
            .unwrap();
        let mut f = fs::File::create(ldir.join(format!("{}.lease", survivor.key))).unwrap();
        f.write_all(b"owner = \"w").unwrap(); // cut inside the value
        drop(f);

        let st = collect_status(&store, Duration::from_secs(60));
        assert_eq!(st.unreadable, 1, "the torn item is counted, not fatal");
        assert_eq!(st.items.len(), 1, "the readable item survives");
        assert!(
            st.items[0].state.starts_with("run:"),
            "a torn-but-fresh lease reads as held-by-unknown, got {:?}",
            st.items[0].state
        );
        let rendered = render_status(dir.to_str().unwrap(), &st);
        assert!(rendered.contains("unreadable: 1"), "{rendered}");
        let _ = fs::remove_dir_all(&dir);
    }

    /// A missing store directory is an empty view, not a crash.
    #[test]
    fn missing_queue_is_empty_view() {
        let dir = tmp("empty");
        let store = RunStore::open(dir.to_str().unwrap()).unwrap();
        let st = collect_status(&store, Duration::from_secs(30));
        assert!(st.items.is_empty());
        assert_eq!(st.unreadable, 0);
        let rendered = render_status(dir.to_str().unwrap(), &st);
        assert!(rendered.contains("empty"));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn progress_bar_and_sparkline_render() {
        assert_eq!(progress_bar(0, 4, 8), "[........]");
        assert_eq!(progress_bar(2, 4, 8), "[####....]");
        assert_eq!(progress_bar(4, 4, 8), "[########]");
        assert_eq!(progress_bar(9, 4, 8), "[########]", "overshoot clamps");
        assert_eq!(progress_bar(0, 0, 4), "[....]", "0/0 does not divide by zero");
        let line = sparkline([1.0, 2.0, 3.0, f64::NAN, 4.0].into_iter(), 32);
        assert_eq!(line.chars().count(), 4, "NaN dropped");
        assert_eq!(line.chars().next(), Some('▁'));
        assert_eq!(line.chars().last(), Some('█'));
        assert_eq!(sparkline([2.0, 2.0].into_iter(), 8).chars().count(), 2);
        assert_eq!(sparkline(std::iter::empty(), 8), "");
    }

    /// The dashboard joins the queue view with replayed metrics.
    #[test]
    fn dashboard_shows_progress_and_series() {
        use super::super::events::{Event, EventKind};
        let dir = tmp("dash");
        let store = RunStore::open(dir.to_str().unwrap()).unwrap();
        let items = queue::enqueue_specs(&store, &[spec()]).unwrap();
        let key = items[0].key.clone();
        let mk = |kind, round, data: &[(&str, f64)]| Event {
            kind,
            key: key.clone(),
            label: String::new(),
            worker: "w0".into(),
            round,
            unix_ms: 0,
            data: data.iter().map(|&(k, v)| (k.into(), v)).collect(),
        };
        let m = super::super::metrics::reduce(&[
            mk(EventKind::Executed, None, &[]),
            mk(EventKind::Round, Some(0), &[("grad_norm", 2.0), ("test_accuracy", 0.3)]),
            mk(EventKind::Round, Some(1), &[("grad_norm", 1.0), ("test_accuracy", 0.5)]),
        ]);
        let st = collect_status(&store, Duration::from_secs(30));
        let dash = render_dashboard(dir.to_str().unwrap(), &st, &m, &[], &[]);
        assert!(dash.contains("‖ĝ‖"), "{dash}");
        assert!(dash.contains("workers:"), "{dash}");
        assert!(dash.contains("[...................."), "fresh runs are empty bars:\n{dash}");
        assert!(!dash.contains("SNR"), "no probes, no link pane:\n{dash}");
        assert!(!dash.contains("alerts:"), "no findings, no pane:\n{dash}");
        assert!(!dash.contains("utilization"), "no spans, no pane:\n{dash}");

        // Health findings render as the alerts pane.
        let finding = crate::fleet::health::Finding {
            kind: crate::fleet::health::HealthKind::LeaseChurn,
            key: key.clone(),
            value: 4.0,
            detail: format!("run {key} reclaimed 4×"),
        };
        let dash = render_dashboard(dir.to_str().unwrap(), &st, &m, &[finding], &[]);
        assert!(dash.contains("alerts:"), "{dash}");
        assert!(dash.contains("!! lease_churn"), "{dash}");

        // Trace-fed utilization renders its own pane, joined with the
        // event-fed per-worker rate where both views know the worker.
        let util = vec![WorkerUtil {
            worker: "w0".into(),
            busy_us: 750_000,
            window_us: 1_000_000,
            spans: 12,
            last_phase: "execute".into(),
            last_end_us: 1_000_000,
        }];
        let dash = render_dashboard(dir.to_str().unwrap(), &st, &m, &[], &util);
        assert!(dash.contains("utilization (from trace spans):"), "{dash}");
        assert!(dash.contains("busy  75.0%"), "{dash}");
        assert!(dash.contains("phase execute"), "{dash}");

        // With link payloads the SNR/participation/headroom pane and the
        // consensus sparkline appear.
        let m = super::super::metrics::reduce(&[
            mk(EventKind::Executed, None, &[]),
            mk(
                EventKind::Round,
                Some(0),
                &[
                    ("grad_norm", 2.0),
                    ("snr_db", 11.0),
                    ("participating", 9.0),
                    ("power_headroom", 0.02),
                    ("consensus_distance", 0.3),
                ],
            ),
            mk(
                EventKind::Round,
                Some(1),
                &[
                    ("grad_norm", 1.0),
                    ("snr_db", 12.0),
                    ("participating", 10.0),
                    ("power_headroom", 0.01),
                    ("consensus_distance", 0.2),
                ],
            ),
        ]);
        let dash = render_dashboard(dir.to_str().unwrap(), &st, &m, &[], &[]);
        assert!(dash.contains("SNR"), "{dash}");
        assert!(dash.contains("tx 10/dev"), "{dash}");
        assert!(dash.contains("consensus"), "{dash}");
        let _ = fs::remove_dir_all(&dir);
    }

    /// The `/status` JSON carries every field the table renderer uses,
    /// escaped; the parse side lives in `fleet::client` and the full
    /// round-trip is pinned in `rust/tests/remote_observability.rs`.
    #[test]
    fn status_json_renders_items_and_counts() {
        let st = FleetStatus {
            items: vec![ItemStatus {
                seq: 0,
                key: "abc123".into(),
                label: "A-DSGD \"quoted\"".into(),
                spec_id: "fig2".into(),
                state: "run:w0".into(),
                rounds_done: 3,
                rounds_total: 8,
            }],
            unreadable: 2,
            complete: 0,
            running: 1,
            stale: 0,
            rounds_done: 3,
            rounds_total: 8,
        };
        let json = status_to_json("/data/store", &st);
        assert!(json.contains("\"unreadable\":2"), "{json}");
        assert!(json.contains("\"key\":\"abc123\""), "{json}");
        assert!(json.contains("\\\"quoted\\\""), "labels are escaped: {json}");
        assert!(json.contains("\"state\":\"run:w0\""), "{json}");
    }
}
