//! Worker-fleet execution: lease-based distributed campaigns over the
//! content-addressed [`campaign`](crate::campaign) store.
//!
//! The paper's headline figures are sweeps of schemes × power × bandwidth
//! × fleet sizes — hundreds of independent multi-thousand-round runs. The
//! campaign subsystem (PR 4) made each run cacheable and resumable; this
//! subsystem turns the store into a **shared work queue** so any number
//! of unreliable worker processes can execute a campaign together, the
//! same way production OTA-FL systems coordinate many faulty trainers
//! with round deadlines and checkpoint hand-off.
//!
//! * [`queue`] — the coordinator enumerates every run of every figure
//!   spec into one persisted item per run (`RunConfig::to_toml` is an
//!   exact round-trip, so a worker attached from another process — e.g.
//!   `repro worker --store-dir …` on a second machine sharing the
//!   filesystem — reconstructs the identical content-address). Claim
//!   order is budget-aware: **shortest remaining work first**, measured
//!   in manifest `snapshot_round`s, ties broken by enqueue order.
//! * [`lease`] — crash-safe filesystem leases: temp-file + `hard_link`
//!   acquire (atomic test-and-set), mtime-refresh heartbeats, and
//!   expiry-based reclaim where exactly one rival steals a dead worker's
//!   lease via rename. See the module docs for the full protocol and
//!   failure model.
//! * [`worker`] — the claim-execute loop (`repro worker`, and what
//!   `repro fleet --workers N` spawns N of): claim the first available
//!   incomplete run, heartbeat while the trainer executes, snapshot every
//!   `snapshot_every` rounds, write the result, release, repeat; exit
//!   when the queue is drained.
//! * [`events`] — the observability layer's source of truth: an
//!   append-only, crash-safe JSONL event log (one segment per writer)
//!   that lease, queue, worker, and scheduler layers emit typed lifecycle
//!   and per-round telemetry events into. A SIGKILL'd writer can at worst
//!   leave one torn *trailing* line in its own segment, which readers
//!   skip and count — the log never poisons.
//! * [`metrics`] — the deterministic replay reducer: folds an event
//!   stream into Prometheus-style counters and gauges (`repro metrics`)
//!   and per-run series for the live dashboard (`repro watch`). The
//!   deterministic core of the reduction is identical for any fleet shape
//!   executing the same campaign.
//! * [`status`] — fail-soft queue/lease status collection
//!   (`repro fleet-status`) and the terminal dashboard renderer: a torn
//!   or mid-write queue item or lease record is skipped and *counted*,
//!   never fatal — status must stay readable while writers are live.
//! * [`health`] — fleet health findings derived from the replayed
//!   metrics (lease churn, Eq. 6 power-headroom violations, diverging
//!   loss) plus a poll-history stall tracker. The deterministic kinds
//!   are embedded in the Prometheus exposition; stall findings — which
//!   depend on *when* you looked — appear only in `/health` JSON and
//!   the `repro watch` alerts pane.
//! * [`trace`] — fleet-wide distributed tracing: per-writer span
//!   segments in the store (same crash-safe append/torn-tail rules as
//!   [`events`]) capturing the worker loop and the trainer's phase
//!   spans, merged by `repro trace` into a per-worker-lane Chrome
//!   trace plus a critical-path / utilization report. Spans are pure
//!   wall-clock and live outside the deterministic core.
//! * [`serve`] — the network-native observability plane
//!   (`repro serve`): a dependency-free HTTP/1.1 server over the
//!   event log exposing `/metrics`, `/status`, `/events` (cursor-based
//!   incremental tail), `/trace` and `/health`.
//! * [`client`] — the `--connect` side: remote watch/metrics/status
//!   clients that stream `/events` (and `/trace`) and fold them
//!   through the *same* reducer as the local path, so remote output is
//!   byte-identical to local output by construction.
//!
//! # Why a fleet changes nothing about the numbers
//!
//! Every run's trajectory is a pure function of its `RunConfig` (all
//! randomness is seeded, counter-based, or an explicitly checkpointed RNG
//! position), and snapshot resume is bit-identical to never having
//! stopped. So *who* executes a run, in *how many* pieces, after *how
//! many* crashes — none of it can change a byte of the result, and a
//! 4-worker fleet's `summary.csv` is byte-identical to the single-process
//! path (`rust/tests/fleet.rs` pins this, and the kill-a-worker smoke in
//! CI pins the reclaim path). Duplicated execution after a lease expires
//! is likewise harmless: both writers produce identical blobs through
//! atomic renames.

pub mod client;
pub mod events;
pub mod health;
pub mod lease;
pub mod metrics;
pub mod queue;
pub mod serve;
pub mod status;
pub mod trace;
pub mod worker;

pub use client::{
    fetch_events, fetch_spans, fetch_status, http_get, parse_status, remote_metrics, Response,
};
pub use events::{
    events_dir, mask_wallclock, read_events, read_events_from, sort_events, Cursor, Event,
    EventKind, EventLog, ReadReport, TailReport,
};
pub use health::{evaluate, Finding, HealthKind, HealthPolicy, HealthTracker};
pub use lease::{lease_dir, lease_state, try_acquire, try_acquire_with, Lease, LeaseState};
pub use metrics::{reduce, reduce_report, Metrics, Reducer, RunSeries, WorkerStats};
pub use queue::{
    claim_order, collect_outputs, enqueue_specs, list_item_names, load_queue, load_queue_counted,
    order_by_remaining, queue_dir, remaining_rounds, WorkItem,
};
pub use serve::{Server, ServeOptions};
pub use status::{
    collect_status, render_dashboard, render_status, status_to_json, FleetStatus, ItemStatus,
};
pub use trace::{
    chrome_trace, read_spans, read_spans_from, render_report as render_trace_report, sort_spans,
    trace_dir, utilization, Span, SpanReadReport, SpanTailReport, TraceLog, WorkerUtil,
};
pub use worker::{install_stop_signals, run_worker, run_worker_ctl, WorkerReport};
