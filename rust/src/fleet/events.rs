//! Append-only, crash-safe JSONL event log for fleet observability.
//!
//! Every layer that touches a run — queue, lease, worker, scheduler,
//! trainer callback, blob store — emits typed [`Event`]s into
//! `<store>/fleet/events/`. The log is the source of truth for
//! [`super::metrics`]: nothing is aggregated at write time; readers
//! replay the log with a deterministic reducer.
//!
//! # Event schema (v1)
//!
//! One JSON object per line, flat, with a fixed field order:
//!
//! ```text
//! {"v":1,"kind":"round","key":"06e71b1ab9b1e1b7","worker":"w0",
//!  "round":3,"ms":1754650000123,"grad_norm":1.25,"test_accuracy":0.41}
//! ```
//!
//! * `v` — schema version, **per kind**: the twelve v1 kinds still
//!   write `"v":1` byte-for-byte (a v1 reader replays any log written
//!   by this build minus the kinds it doesn't know), while the
//!   `device` kind added for link diagnostics writes `"v":2`. Readers
//!   built from this source accept both and skip anything newer.
//! * `kind` — one of the [`EventKind`] names (lifecycle order:
//!   `enqueued`, `claimed`, `reclaimed`, `heartbeat`, `executed`,
//!   `resumed`, `cached`, `already_done`, `snapshot`, `device`,
//!   `round`, `completed`, `quarantined`). `device` carries one
//!   transmitter's link diagnostics for one round (its `device` /
//!   `outcome` / norm / energy payload fields — see
//!   `OBSERVABILITY.md`) and sorts immediately before the round's
//!   summarizing `round` event.
//! * `key` — the run's content-addressed cache key (store directory
//!   name); empty for events not tied to a run.
//! * `label` — optional human-readable run label (carried by
//!   `enqueued` so dashboards can name runs without parsing configs).
//! * `worker` — the emitting writer id (worker id, or a scheduler /
//!   coordinator writer name).
//! * `round` — optional 0-based round index (`round` / `snapshot`).
//! * `ms` — wall-clock unix milliseconds. This is the **only**
//!   wall-clock field: the determinism contract masks it (see
//!   [`mask_wallclock`]) and everything else must replay identically
//!   across fleet shapes.
//! * any further numeric fields are the event's payload `data`
//!   (non-finite values are dropped at emit time, so NaN never
//!   reaches the wire).
//!
//! # Append / torn-record rules
//!
//! * **One file per writer** (`<writer>.jsonl`): concurrent workers
//!   never interleave bytes within a file, so a reader can only ever
//!   observe a *trailing* partial line per file, never a corrupted
//!   middle.
//! * **One `write(2)` per event** on an `O_APPEND` handle: a line is
//!   either fully in the file or not at all on every local
//!   filesystem's crash model that matters here; a SIGKILL mid-call
//!   leaves at most one unterminated tail line.
//! * **Readers are fail-soft**: a line that is unterminated,
//!   unparseable, or of an unknown schema version is skipped and
//!   counted ([`ReadReport::skipped_lines`]); an unreadable file is
//!   skipped and counted ([`ReadReport::unreadable_files`]). A torn
//!   record can therefore never poison a reader.
//! * Emission itself is fail-soft too: telemetry must never take down
//!   a run, so append errors are reported once to stderr and dropped.
//!
//! # Replay contract
//!
//! [`super::metrics::reduce`] folds events with commutative,
//! key-deduplicated operations, so the deterministic core of the
//! metrics (which runs executed / resumed / cached / completed, which
//! rounds were trained, final gauges) is identical for a 1-worker and
//! a 4-worker fleet over the same campaign once events are ordered by
//! [`sort_events`] and wall clocks are zeroed by [`mask_wallclock`].
//! Per-worker throughput and reclaim counts are intentionally
//! *outside* that core — they describe the fleet, not the campaign.

use std::collections::BTreeMap;
use std::fs::{self, OpenOptions};
use std::io::{self, Read as _, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// Base schema version: every kind that existed before link
/// diagnostics still writes (and parses as) version 1.
pub const EVENT_VERSION: u64 = 1;

/// Highest schema version this build understands; readers skip
/// anything newer, per the fail-soft contract.
pub const MAX_EVENT_VERSION: u64 = 2;

/// Typed event kinds, declared in lifecycle order (the declaration
/// order is also the deterministic sort order within a run+round).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    /// A run was placed on the fleet queue.
    Enqueued,
    /// A worker acquired the run's lease.
    Claimed,
    /// A stale lease was stolen from a dead owner (exactly once per
    /// steal — emitted by the winner of the reclaim rename).
    Reclaimed,
    /// A lease heartbeat landed.
    Heartbeat,
    /// A run started from round 0.
    Executed,
    /// A run resumed from a snapshot.
    Resumed,
    /// A finished result was served from the run cache.
    Cached,
    /// A worker claimed a run whose result had just landed (claim
    /// race) — operational, not part of the deterministic core.
    AlreadyDone,
    /// A snapshot was persisted at `round`.
    Snapshot,
    /// One device's link diagnostics for one round (schema v2; emitted
    /// only when diagnostics are enabled). Sorts before the round's
    /// `round` summary, mirroring the trainer's observer order.
    Device,
    /// Per-round telemetry from the trainer callback.
    Round,
    /// A run finished and its result was persisted.
    Completed,
    /// A corrupt blob was quarantined by the store.
    Quarantined,
}

impl EventKind {
    /// All kinds, in lifecycle (= sort) order.
    pub const ALL: [EventKind; 13] = [
        EventKind::Enqueued,
        EventKind::Claimed,
        EventKind::Reclaimed,
        EventKind::Heartbeat,
        EventKind::Executed,
        EventKind::Resumed,
        EventKind::Cached,
        EventKind::AlreadyDone,
        EventKind::Snapshot,
        EventKind::Device,
        EventKind::Round,
        EventKind::Completed,
        EventKind::Quarantined,
    ];

    /// Wire name of the kind.
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Enqueued => "enqueued",
            EventKind::Claimed => "claimed",
            EventKind::Reclaimed => "reclaimed",
            EventKind::Heartbeat => "heartbeat",
            EventKind::Executed => "executed",
            EventKind::Resumed => "resumed",
            EventKind::Cached => "cached",
            EventKind::AlreadyDone => "already_done",
            EventKind::Snapshot => "snapshot",
            EventKind::Device => "device",
            EventKind::Round => "round",
            EventKind::Completed => "completed",
            EventKind::Quarantined => "quarantined",
        }
    }

    /// Inverse of [`EventKind::name`].
    pub fn parse(s: &str) -> Option<EventKind> {
        EventKind::ALL.iter().copied().find(|k| k.name() == s)
    }

    /// The schema version this kind is written with. Versioning is
    /// per kind so that pre-diagnostics readers replay everything
    /// they already understood byte-for-byte: only the new `device`
    /// kind advances past [`EVENT_VERSION`].
    pub fn wire_version(self) -> u64 {
        match self {
            EventKind::Device => 2,
            _ => EVENT_VERSION,
        }
    }
}

/// One log record. See the module docs for the wire schema.
#[derive(Clone, Debug, PartialEq)]
pub struct Event {
    pub kind: EventKind,
    /// Run cache key (store directory name); empty if not run-scoped.
    pub key: String,
    /// Optional human label (carried by `enqueued`).
    pub label: String,
    /// Writer id (worker name / scheduler writer).
    pub worker: String,
    /// 0-based round, for `round` / `snapshot` events.
    pub round: Option<u64>,
    /// Wall-clock unix milliseconds — the only nondeterministic field.
    pub unix_ms: u64,
    /// Numeric payload, sorted by field name at emit time.
    pub data: Vec<(String, f64)>,
}

impl Event {
    /// Payload field lookup.
    pub fn field(&self, name: &str) -> Option<f64> {
        self.data
            .iter()
            .find(|(k, _)| k == name)
            .map(|&(_, v)| v)
    }

    /// Serialize to one JSON line (no trailing newline).
    pub fn to_line(&self) -> String {
        let mut s = String::with_capacity(96);
        s.push_str("{\"v\":");
        s.push_str(&self.kind.wire_version().to_string());
        s.push_str(",\"kind\":\"");
        s.push_str(self.kind.name());
        s.push('"');
        if !self.key.is_empty() {
            s.push_str(",\"key\":\"");
            s.push_str(&json_escape(&self.key));
            s.push('"');
        }
        if !self.label.is_empty() {
            s.push_str(",\"label\":\"");
            s.push_str(&json_escape(&self.label));
            s.push('"');
        }
        if !self.worker.is_empty() {
            s.push_str(",\"worker\":\"");
            s.push_str(&json_escape(&self.worker));
            s.push('"');
        }
        if let Some(r) = self.round {
            s.push_str(",\"round\":");
            s.push_str(&r.to_string());
        }
        s.push_str(",\"ms\":");
        s.push_str(&self.unix_ms.to_string());
        for (k, v) in &self.data {
            if !v.is_finite() {
                continue;
            }
            s.push_str(",\"");
            s.push_str(&json_escape(k));
            s.push_str("\":");
            // `{}` on f64 is the shortest exact round-trip form.
            s.push_str(&format!("{v}"));
        }
        s.push('}');
        s
    }

    /// Parse one line. `Err` carries a short reason; callers count it
    /// as a skipped line rather than aborting.
    pub fn parse(line: &str) -> Result<Event, String> {
        let mut p = JsonParser::new(line);
        p.expect(b'{')?;
        let mut ev = Event {
            kind: EventKind::Round,
            key: String::new(),
            label: String::new(),
            worker: String::new(),
            round: None,
            unix_ms: 0,
            data: Vec::new(),
        };
        let mut saw_kind = false;
        let mut version = 0u64;
        loop {
            p.skip_ws();
            if p.eat(b'}') {
                break;
            }
            let field = p.string()?;
            p.skip_ws();
            p.expect(b':')?;
            p.skip_ws();
            match field.as_str() {
                "v" => version = p.number()? as u64,
                "kind" => {
                    let name = p.string()?;
                    ev.kind = EventKind::parse(&name)
                        .ok_or_else(|| format!("unknown kind `{name}`"))?;
                    saw_kind = true;
                }
                "key" => ev.key = p.string()?,
                "label" => ev.label = p.string()?,
                "worker" => ev.worker = p.string()?,
                "round" => ev.round = Some(p.number()? as u64),
                "ms" => ev.unix_ms = p.number()? as u64,
                _ => {
                    // Any other field is numeric payload; tolerate (and
                    // drop) nulls so forward-compat additions parse.
                    if !p.eat_literal("null") {
                        let v = p.number()?;
                        ev.data.push((field, v));
                    }
                }
            }
            p.skip_ws();
            if !p.eat(b',') {
                p.expect(b'}')?;
                break;
            }
        }
        if version == 0 || version > MAX_EVENT_VERSION {
            return Err(format!("unsupported event version {version}"));
        }
        if !saw_kind {
            return Err("missing `kind`".into());
        }
        Ok(ev)
    }
}

/// Directory holding the per-writer event segments.
pub fn events_dir(store_root: &Path) -> PathBuf {
    store_root.join("fleet").join("events")
}

static EMIT_FAILED: AtomicBool = AtomicBool::new(false);

/// Handle for appending events as one writer. Cloning is cheap; all
/// clones append to the same per-writer segment file.
#[derive(Clone, Debug)]
pub struct EventLog {
    path: PathBuf,
    writer: String,
}

impl EventLog {
    /// Open (creating directories as needed) the segment for `writer`
    /// under `store_root`. Writer ids are sanitized to
    /// `[A-Za-z0-9._-]` so they are always valid file names.
    pub fn open(store_root: &Path, writer: &str) -> io::Result<EventLog> {
        let dir = events_dir(store_root);
        fs::create_dir_all(&dir)?;
        let writer: String = writer
            .chars()
            .map(|c| {
                if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') {
                    c
                } else {
                    '-'
                }
            })
            .collect();
        let writer = if writer.is_empty() { "anon".to_string() } else { writer };
        let path = dir.join(format!("{writer}.jsonl"));
        Ok(EventLog { path, writer })
    }

    /// The sanitized writer id this log appends as.
    pub fn writer(&self) -> &str {
        &self.writer
    }

    /// Emit an event with no label. Never fails (see module docs).
    pub fn emit(&self, kind: EventKind, key: &str, round: Option<u64>, data: &[(&str, f64)]) {
        self.emit_labeled(kind, key, "", round, data)
    }

    /// Emit an event carrying a human label (used by `enqueued`).
    pub fn emit_labeled(
        &self,
        kind: EventKind,
        key: &str,
        label: &str,
        round: Option<u64>,
        data: &[(&str, f64)],
    ) {
        let mut payload: Vec<(String, f64)> = data
            .iter()
            .filter(|(_, v)| v.is_finite())
            .map(|&(k, v)| (k.to_string(), v))
            .collect();
        payload.sort_by(|a, b| a.0.cmp(&b.0));
        let ev = Event {
            kind,
            key: key.to_string(),
            label: label.to_string(),
            worker: self.writer.clone(),
            round,
            unix_ms: unix_ms_now(),
            data: payload,
        };
        let mut line = ev.to_line();
        line.push('\n');
        // Single append-mode write per line: the crash-safety invariant.
        let res = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .and_then(|mut f| f.write_all(line.as_bytes()));
        if let Err(e) = res {
            if !EMIT_FAILED.swap(true, Ordering::Relaxed) {
                eprintln!(
                    "warning: telemetry append failed ({}): {e} — further failures are silent",
                    self.path.display()
                );
            }
        }
    }
}

pub(crate) fn unix_ms_now() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// The result of replaying a store's event directory.
#[derive(Clone, Debug, Default)]
pub struct ReadReport {
    /// Parsed events, in per-file order (not globally ordered — see
    /// [`sort_events`]).
    pub events: Vec<Event>,
    /// Lines skipped: torn tails, parse failures, unknown versions.
    pub skipped_lines: usize,
    /// Segment files that could not be read at all.
    pub unreadable_files: usize,
}

/// Read every `*.jsonl` segment under the store's event directory.
/// Fail-soft: a missing directory yields an empty report; torn or
/// unparseable lines and unreadable files are counted, never fatal.
///
/// Equivalent to [`read_events_from`] with an empty [`Cursor`]: the
/// batch read is literally the from-zero special case of the
/// incremental tail, so the two accountings can never drift apart.
pub fn read_events(store_root: &Path) -> ReadReport {
    let tail = read_events_from(store_root, &Cursor::default());
    ReadReport {
        events: tail.events,
        skipped_lines: tail.consumed_skipped + tail.pending_tails,
        unreadable_files: tail.unreadable_files,
    }
}

/// A reader's position in the store's event log: one consumed-byte
/// offset per writer segment, keyed by the sanitized writer id (the
/// segment's file stem). An absent writer reads from offset 0, so a
/// default cursor replays the whole log and segments that appear later
/// (new workers joining the fleet) are picked up automatically.
///
/// The wire form is `writer:offset` pairs joined by commas
/// (`w0:1024,w1:768`, empty string for the zero cursor) — unambiguous
/// because writer ids are sanitized to `[A-Za-z0-9._-]` at
/// [`EventLog::open`], which admits neither `:` nor `,`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Cursor {
    offsets: BTreeMap<String, u64>,
}

impl Cursor {
    /// Consumed-byte offset for one writer segment (0 if never seen).
    pub fn offset(&self, writer: &str) -> u64 {
        self.offsets.get(writer).copied().unwrap_or(0)
    }

    /// The writers this cursor has consumed bytes from.
    pub fn writers(&self) -> impl Iterator<Item = (&str, u64)> {
        self.offsets.iter().map(|(w, &o)| (w.as_str(), o))
    }

    pub(crate) fn advance(&mut self, writer: &str, offset: u64) {
        if offset > 0 {
            self.offsets.insert(writer.to_string(), offset);
        }
    }

    /// Wire form: `w0:1024,w1:768` (empty for the zero cursor).
    pub fn render(&self) -> String {
        self.offsets
            .iter()
            .map(|(w, o)| format!("{w}:{o}"))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Inverse of [`Cursor::render`]. `Err` carries a short reason.
    pub fn parse(s: &str) -> Result<Cursor, String> {
        let mut c = Cursor::default();
        for pair in s.split(',') {
            if pair.is_empty() {
                continue;
            }
            let (writer, off) = pair
                .rsplit_once(':')
                .ok_or_else(|| format!("cursor pair `{pair}` has no `:`"))?;
            if writer.is_empty()
                || !writer
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-'))
            {
                return Err(format!("bad writer id `{writer}` in cursor"));
            }
            let off: u64 = off
                .parse()
                .map_err(|_| format!("bad offset `{off}` in cursor"))?;
            c.offsets.insert(writer.to_string(), off);
        }
        Ok(c)
    }
}

/// One incremental read of the log: everything appended past a
/// [`Cursor`], plus the advanced cursor and the reader's fail-soft
/// accounting. See [`read_events_from`].
#[derive(Clone, Debug, Default)]
pub struct TailReport {
    /// Newly parsed events, in per-file order.
    pub events: Vec<Event>,
    /// The cursor after this read; feed it back to resume.
    pub cursor: Cursor,
    /// Garbage *terminated* lines consumed (and permanently skipped)
    /// by this read. Cumulative across a cursor chain: a consumed line
    /// is never revisited, so a resumed reader adds these up.
    pub consumed_skipped: usize,
    /// Segments currently ending in a torn, unterminated line. The
    /// cursor does **not** advance past a torn tail — the writer may
    /// still be mid-append — so this is a point-in-time count, not a
    /// cumulative one: the same tail reports 1 on every read until the
    /// writer terminates it (then it parses) or appends past it (then
    /// it is consumed as garbage and moves into `consumed_skipped`).
    pub pending_tails: usize,
    /// Segments unreadable at this read (open/read failure, or a
    /// segment shorter than the cursor claims was consumed — an
    /// append-only file must never shrink). Point-in-time, like
    /// `pending_tails`; the cursor is left untouched for retry.
    pub unreadable_files: usize,
}

/// Incrementally read every `*.jsonl` segment past `cursor`, never
/// consuming a partial line: a torn tail is left unconsumed (and
/// counted in [`TailReport::pending_tails`]) so the next read resumes
/// exactly at the line boundary. Fail-soft like [`read_events`], and
/// equivalent to it from the zero cursor:
/// `consumed_skipped + pending_tails` is then exactly the batch
/// reader's `skipped_lines`.
pub fn read_events_from(store_root: &Path, cursor: &Cursor) -> TailReport {
    let seg = tail_segments(&events_dir(store_root), cursor);
    let mut tail = TailReport {
        cursor: seg.cursor,
        pending_tails: seg.pending_tails,
        unreadable_files: seg.unreadable_files,
        ..TailReport::default()
    };
    for line in &seg.lines {
        match Event::parse(line) {
            Ok(ev) => tail.events.push(ev),
            Err(_) => tail.consumed_skipped += 1,
        }
    }
    tail
}

/// One incremental pass over a directory of per-writer `*.jsonl`
/// segments: every whole line past `cursor` (torn tails left
/// unconsumed), the advanced cursor, and the fail-soft accounting.
/// Shared by the event log and [`super::trace`] so both speak exactly
/// the same append/torn-tail discipline.
#[derive(Clone, Debug, Default)]
pub(crate) struct SegmentTail {
    pub(crate) lines: Vec<String>,
    pub(crate) cursor: Cursor,
    pub(crate) pending_tails: usize,
    pub(crate) unreadable_files: usize,
}

pub(crate) fn tail_segments(dir: &Path, cursor: &Cursor) -> SegmentTail {
    let mut tail = SegmentTail { cursor: cursor.clone(), ..SegmentTail::default() };
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return tail,
    };
    let mut files: Vec<PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("jsonl"))
        .collect();
    files.sort();
    for path in files {
        let Some(writer) = path.file_stem().and_then(|s| s.to_str()).map(str::to_string)
        else {
            continue;
        };
        let offset = cursor.offset(&writer);
        let bytes = match read_segment_from(&path, offset) {
            Ok(b) => b,
            Err(_) => {
                tail.unreadable_files += 1;
                continue;
            }
        };
        // Only whole lines are consumed: split at the final newline and
        // leave anything after it (a torn or in-flight append) for the
        // next read.
        let consumed_len = match bytes.iter().rposition(|&b| b == b'\n') {
            Some(last_nl) => last_nl + 1,
            None => 0,
        };
        for line in bytes[..consumed_len].split(|&b| b == b'\n') {
            let line = String::from_utf8_lossy(line);
            let line = line.trim_end_matches('\r');
            if line.trim().is_empty() {
                continue;
            }
            tail.lines.push(line.to_string());
        }
        if bytes[consumed_len..].iter().any(|b| !b.is_ascii_whitespace()) {
            tail.pending_tails += 1;
        }
        tail.cursor.advance(&writer, offset + consumed_len as u64);
    }
    tail
}

/// Read one segment from `offset` to EOF. `Err` on open/seek/read
/// failure or if the file is shorter than `offset` (an append-only
/// segment must never shrink — a shorter file means the cursor belongs
/// to a different incarnation of the store).
fn read_segment_from(path: &Path, offset: u64) -> io::Result<Vec<u8>> {
    let mut f = fs::File::open(path)?;
    let len = f.metadata()?.len();
    if len < offset {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "segment shrank below the cursor offset",
        ));
    }
    if offset > 0 {
        f.seek(SeekFrom::Start(offset))?;
    }
    let mut buf = Vec::with_capacity((len - offset) as usize);
    f.read_to_end(&mut buf)?;
    Ok(buf)
}

/// Zero the wall-clock field of every event (the determinism mask).
pub fn mask_wallclock(events: &mut [Event]) {
    for ev in events {
        ev.unix_ms = 0;
    }
}

/// Deterministic order: by run key, then round (lifecycle events
/// first), then kind lifecycle rank, then worker, then payload. After
/// [`mask_wallclock`], two fleets of different shapes sort identical
/// deterministic-core events into the same sequence.
pub fn sort_events(events: &mut [Event]) {
    events.sort_by(|a, b| {
        (&a.key, a.round, a.kind, &a.worker, a.unix_ms)
            .cmp(&(&b.key, b.round, b.kind, &b.worker, b.unix_ms))
            .then_with(|| {
                a.data
                    .iter()
                    .map(|(k, v)| (k, v.to_bits()))
                    .cmp(b.data.iter().map(|(k, v)| (k, v.to_bits())))
            })
    });
}

pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Minimal flat-JSON tokenizer for the line schema above (strings,
/// numbers, `null`; no nesting). Hand-rolled because the crate has no
/// JSON dependency by design. Shared with [`super::trace`], whose span
/// lines use the same flat shape.
pub(crate) struct JsonParser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> JsonParser<'a> {
    pub(crate) fn new(s: &'a str) -> Self {
        JsonParser { bytes: s.as_bytes(), pos: 0 }
    }

    pub(crate) fn skip_ws(&mut self) {
        while self
            .bytes
            .get(self.pos)
            .is_some_and(|b| b.is_ascii_whitespace())
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    pub(crate) fn eat(&mut self, b: u8) -> bool {
        if self.peek() == Some(b) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    pub(crate) fn eat_literal(&mut self, lit: &str) -> bool {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            true
        } else {
            false
        }
    }

    pub(crate) fn expect(&mut self, b: u8) -> Result<(), String> {
        self.skip_ws();
        if self.eat(b) {
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", b as char, self.pos))
        }
    }

    pub(crate) fn string(&mut self) -> Result<String, String> {
        self.skip_ws();
        if !self.eat(b'"') {
            return Err(format!("expected string at byte {}", self.pos));
        }
        let mut out = String::new();
        loop {
            let b = self
                .peek()
                .ok_or_else(|| "unterminated string".to_string())?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self
                        .peek()
                        .ok_or_else(|| "dangling escape".to_string())?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("unknown escape \\{}", e as char)),
                    }
                }
                _ => {
                    // Re-sync to char boundary for multi-byte UTF-8.
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    while end < self.bytes.len() && (self.bytes[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| "invalid utf-8 in string".to_string())?;
                    out.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    pub(crate) fn number(&mut self) -> Result<f64, String> {
        self.skip_ws();
        let start = self.pos;
        while self.peek().is_some_and(|b| {
            b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E')
        }) {
            self.pos += 1;
        }
        if start == self.pos {
            return Err(format!("expected number at byte {start}"));
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("ota_events_{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn event_roundtrips_through_json() {
        let ev = Event {
            kind: EventKind::Round,
            key: "0123456789abcdef".into(),
            label: "A-DSGD \"quoted\" λ".into(),
            worker: "w0".into(),
            round: Some(7),
            unix_ms: 1_754_650_000_123,
            data: vec![
                ("grad_norm".into(), 1.25),
                ("test_accuracy".into(), 0.30000000000000004),
            ],
        };
        let parsed = Event::parse(&ev.to_line()).unwrap();
        assert_eq!(parsed, ev);
    }

    #[test]
    fn nonfinite_payload_fields_are_dropped_at_emit() {
        let root = tmp("nan");
        let log = EventLog::open(&root, "w0").unwrap();
        log.emit(
            EventKind::Round,
            "k",
            Some(0),
            &[("grad_norm", 2.0), ("test_accuracy", f64::NAN)],
        );
        let report = read_events(&root);
        assert_eq!(report.events.len(), 1);
        assert_eq!(report.events[0].field("grad_norm"), Some(2.0));
        assert_eq!(report.events[0].field("test_accuracy"), None);
        fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn kind_names_roundtrip() {
        for k in EventKind::ALL {
            assert_eq!(EventKind::parse(k.name()), Some(k));
        }
        assert_eq!(EventKind::parse("nope"), None);
    }

    #[test]
    fn per_kind_versioning_keeps_v1_lines_byte_identical() {
        // Every pre-diagnostics kind still writes "v":1 — a v1 reader
        // replays logs from this build minus only the kinds it never
        // knew about.
        for k in EventKind::ALL {
            let ev = Event {
                kind: k,
                key: "k".into(),
                label: String::new(),
                worker: "w0".into(),
                round: None,
                unix_ms: 5,
                data: vec![],
            };
            let line = ev.to_line();
            let expect = if k == EventKind::Device { 2 } else { 1 };
            assert!(
                line.starts_with(&format!("{{\"v\":{expect},")),
                "{k:?}: {line}"
            );
            assert_eq!(Event::parse(&line).unwrap(), ev, "{k:?}");
        }
    }

    #[test]
    fn device_event_roundtrips_at_v2() {
        let ev = Event {
            kind: EventKind::Device,
            key: "0123456789abcdef".into(),
            label: String::new(),
            worker: "w1".into(),
            round: Some(4),
            unix_ms: 77,
            data: vec![
                ("device".into(), 3.0),
                ("outcome".into(), 2.0),
                ("pre_sparsify_norm".into(), 1.5),
                ("tx_energy".into(), 500.0),
            ],
        };
        let line = ev.to_line();
        assert!(line.starts_with("{\"v\":2,\"kind\":\"device\""), "{line}");
        assert_eq!(Event::parse(&line).unwrap(), ev);
        // Versions beyond MAX are still skipped (fail-soft forward
        // compatibility), and v0 was never valid.
        let future = line.replacen("{\"v\":2,", "{\"v\":3,", 1);
        assert!(Event::parse(&future).is_err());
        assert!(Event::parse(&line.replacen("{\"v\":2,", "{\"v\":0,", 1)).is_err());
    }

    #[test]
    fn torn_tail_and_garbage_lines_are_skipped_not_fatal() {
        let root = tmp("torn");
        let log = EventLog::open(&root, "w0").unwrap();
        log.emit(EventKind::Claimed, "k1", None, &[]);
        log.emit(EventKind::Completed, "k1", None, &[("final_accuracy", 0.9)]);
        // Garbage in the middle (e.g. a cosmic-ray flip) and a torn,
        // unterminated tail (a SIGKILL mid-append).
        let path = events_dir(&root).join("w0.jsonl");
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"not json at all\n").unwrap();
        f.write_all(b"{\"v\":1,\"kind\":\"claimed\",\"key\":\"k2").unwrap();
        drop(f);
        let report = read_events(&root);
        assert_eq!(report.events.len(), 2, "good lines still parse");
        assert_eq!(report.skipped_lines, 2, "garbage + torn tail counted");
        assert_eq!(report.unreadable_files, 0);
        fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn unknown_version_is_skipped() {
        let root = tmp("ver");
        fs::create_dir_all(events_dir(&root)).unwrap();
        fs::write(
            events_dir(&root).join("w0.jsonl"),
            "{\"v\":99,\"kind\":\"claimed\",\"key\":\"k\",\"ms\":0}\n",
        )
        .unwrap();
        let report = read_events(&root);
        assert!(report.events.is_empty());
        assert_eq!(report.skipped_lines, 1);
        fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn cursor_renders_and_parses_roundtrip() {
        let mut c = Cursor::default();
        assert_eq!(c.render(), "");
        assert_eq!(Cursor::parse("").unwrap(), c);
        c.advance("w0", 1024);
        c.advance("sched-123", 77);
        assert_eq!(c.render(), "sched-123:77,w0:1024");
        assert_eq!(Cursor::parse(&c.render()).unwrap(), c);
        assert_eq!(c.offset("w0"), 1024);
        assert_eq!(c.offset("nope"), 0);
        assert!(Cursor::parse("w0").is_err(), "missing `:`");
        assert!(Cursor::parse("w0:abc").is_err(), "bad offset");
        assert!(Cursor::parse("w:0/evil:1").is_err(), "bad writer chars");
    }

    #[test]
    fn incremental_tail_never_consumes_a_torn_line() {
        let root = tmp("tail");
        let log = EventLog::open(&root, "w0").unwrap();
        log.emit(EventKind::Claimed, "k1", None, &[]);
        let first = read_events_from(&root, &Cursor::default());
        assert_eq!(first.events.len(), 1);
        assert_eq!((first.consumed_skipped, first.pending_tails), (0, 0));

        // A torn append: the cursor must not move past it, and it is
        // reported as a pending tail on every read until resolved.
        let path = events_dir(&root).join("w0.jsonl");
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"v\":1,\"kind\":\"comp").unwrap();
        drop(f);
        let torn = read_events_from(&root, &first.cursor);
        assert!(torn.events.is_empty());
        assert_eq!(torn.pending_tails, 1);
        assert_eq!(torn.cursor, first.cursor, "cursor parked before the tear");

        // The writer finishes the line: the next read parses it whole.
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"leted\",\"key\":\"k1\",\"ms\":0}\n").unwrap();
        drop(f);
        let healed = read_events_from(&root, &torn.cursor);
        assert_eq!(healed.events.len(), 1);
        assert_eq!(healed.events[0].kind, EventKind::Completed);
        assert_eq!((healed.consumed_skipped, healed.pending_tails), (0, 0));

        // A new writer segment appears: picked up from offset 0.
        let log2 = EventLog::open(&root, "w1").unwrap();
        log2.emit(EventKind::Heartbeat, "k1", None, &[]);
        let grown = read_events_from(&root, &healed.cursor);
        assert_eq!(grown.events.len(), 1);
        assert_eq!(grown.events[0].worker, "w1");
        assert!(grown.cursor.offset("w1") > 0);
        fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn batch_read_is_the_zero_cursor_special_case() {
        let root = tmp("batchzero");
        let log = EventLog::open(&root, "w0").unwrap();
        log.emit(EventKind::Claimed, "k1", None, &[]);
        let path = events_dir(&root).join("w0.jsonl");
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"garbage line\n").unwrap();
        f.write_all(b"{\"v\":1,\"kind\":\"torn").unwrap();
        drop(f);
        let batch = read_events(&root);
        let tail = read_events_from(&root, &Cursor::default());
        assert_eq!(batch.events, tail.events);
        assert_eq!(
            batch.skipped_lines,
            tail.consumed_skipped + tail.pending_tails,
            "batch skip accounting == consumed garbage + pending tails"
        );
        assert_eq!(batch.unreadable_files, tail.unreadable_files);
        fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn shrunk_segment_reads_as_unreadable_not_corrupt() {
        let root = tmp("shrunk");
        let log = EventLog::open(&root, "w0").unwrap();
        log.emit(EventKind::Claimed, "k1", None, &[]);
        let tail = read_events_from(&root, &Cursor::default());
        fs::write(events_dir(&root).join("w0.jsonl"), b"{}").unwrap();
        let after = read_events_from(&root, &tail.cursor);
        assert!(after.events.is_empty());
        assert_eq!(after.unreadable_files, 1);
        assert_eq!(after.cursor, tail.cursor, "cursor untouched for retry");
        fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn sort_is_stable_across_writer_interleavings() {
        let mk = |key: &str, kind, round, worker: &str| Event {
            kind,
            key: key.into(),
            label: String::new(),
            worker: worker.into(),
            round,
            unix_ms: 0,
            data: vec![],
        };
        let mut a = vec![
            mk("k2", EventKind::Round, Some(1), "w1"),
            mk("k1", EventKind::Completed, None, "w0"),
            mk("k1", EventKind::Round, Some(0), "w0"),
            mk("k1", EventKind::Claimed, None, "w0"),
        ];
        let mut b = a.clone();
        b.reverse();
        sort_events(&mut a);
        sort_events(&mut b);
        assert_eq!(a, b);
        // Lifecycle events (round None) sort before any round event.
        assert_eq!(a[0].kind, EventKind::Claimed);
        assert_eq!(a[1].kind, EventKind::Completed);
    }
}
