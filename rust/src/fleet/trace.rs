//! Fleet-wide distributed tracing: crash-safe span persistence in the
//! run store, merging N workers' timelines into one trace.
//!
//! [`util::prof`](crate::util::prof) sees one process and dies with it.
//! This layer promotes those spans — plus new worker-loop spans (claim
//! scan, lease acquire, heartbeat, execute, snapshot save/load, resume,
//! collect) — into per-writer JSONL segments under
//! `<store>/fleet/trace/`, written with **exactly** the append /
//! torn-tail / fail-soft discipline of [`super::events`] (one file per
//! writer, one `write(2)` per span, readers skip+count torn or unknown
//! lines, emission never fails a run).
//!
//! # Span schema (v1)
//!
//! One flat JSON object per line, fixed field order:
//!
//! ```text
//! {"v":1,"name":"execute","key":"06e71b1ab9b1e1b7","campaign":"fig1",
//!  "worker":"w0","tid":0,"round":3,"us":1754650000123456,"dur":45678}
//! ```
//!
//! * `v` — span schema version; readers skip anything newer than
//!   [`MAX_TRACE_VERSION`].
//! * `name` — the phase: trainer phases (`encode`, `project`,
//!   `transmit`, `decode_amp`, `gradient`, `consensus`, `eval`) or
//!   worker-loop phases (`enqueue`, `claim_scan`, `lease_acquire`,
//!   `heartbeat`, `snapshot_load`, `resume`, `execute`,
//!   `snapshot_save`, `complete`, `collect`).
//! * causal context, outermost first: `campaign` (figure/spec id,
//!   stamped where known — e.g. at enqueue) → `key` (run
//!   content-hash) → `round` → `name` (phase). Joining on `key` links
//!   a span to every event, snapshot, and result for that run.
//! * `worker` — the writer id (worker id / scheduler / coordinator),
//!   which is also the segment file stem.
//! * `tid` — the emitting thread's profiler ordinal
//!   ([`crate::util::prof::current_tid`]), so in-process parallelism
//!   gets its own lanes under the worker's process lane.
//! * `us` / `dur` — start (unix microseconds) and duration
//!   (microseconds). Spans are pure wall-clock and live strictly
//!   outside the deterministic core: no RNG draws, no f32 op-order
//!   change, nothing fed back into training state or content
//!   addresses. Goldens and `summary.csv` are byte-identical with
//!   tracing on or off.
//!
//! # Reading
//!
//! [`read_spans_from`] reuses the event log's segment tailer (same
//! [`Cursor`], same accounting), so `GET /trace` serves spans with the
//! exact cursor semantics `/events` already has and
//! `repro trace --connect` is byte-identical to a local read.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::{Instant, SystemTime, UNIX_EPOCH};
use std::{fs, io};

use super::events::{json_escape, tail_segments, Cursor, JsonParser};
use crate::util::prof;

/// Span schema version written by this build.
pub const TRACE_VERSION: u64 = 1;

/// Highest span schema version this build understands.
pub const MAX_TRACE_VERSION: u64 = 1;

/// One timed (or instantaneous, `dur_us == 0`) phase on some worker's
/// timeline. See the module docs for the wire schema.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Span {
    /// Phase name.
    pub name: String,
    /// Run content-hash; empty if not run-scoped.
    pub key: String,
    /// Campaign / figure spec id; empty where the emitter doesn't know it.
    pub campaign: String,
    /// Writer id (segment file stem).
    pub worker: String,
    /// Per-thread lane ordinal within the writer's process.
    pub tid: u64,
    /// 0-based round for per-round phases.
    pub round: Option<u64>,
    /// Start, unix microseconds.
    pub start_us: u64,
    /// Duration, microseconds (0 for instantaneous markers).
    pub dur_us: u64,
}

impl Span {
    /// End of the span on the unix-microsecond axis.
    pub fn end_us(&self) -> u64 {
        self.start_us.saturating_add(self.dur_us)
    }

    /// Serialize to one JSON line (no trailing newline).
    pub fn to_line(&self) -> String {
        let mut s = String::with_capacity(96);
        s.push_str("{\"v\":");
        s.push_str(&TRACE_VERSION.to_string());
        s.push_str(",\"name\":\"");
        s.push_str(&json_escape(&self.name));
        s.push('"');
        if !self.key.is_empty() {
            s.push_str(",\"key\":\"");
            s.push_str(&json_escape(&self.key));
            s.push('"');
        }
        if !self.campaign.is_empty() {
            s.push_str(",\"campaign\":\"");
            s.push_str(&json_escape(&self.campaign));
            s.push('"');
        }
        if !self.worker.is_empty() {
            s.push_str(",\"worker\":\"");
            s.push_str(&json_escape(&self.worker));
            s.push('"');
        }
        s.push_str(",\"tid\":");
        s.push_str(&self.tid.to_string());
        if let Some(r) = self.round {
            s.push_str(",\"round\":");
            s.push_str(&r.to_string());
        }
        s.push_str(",\"us\":");
        s.push_str(&self.start_us.to_string());
        s.push_str(",\"dur\":");
        s.push_str(&self.dur_us.to_string());
        s.push('}');
        s
    }

    /// Parse one line. `Err` carries a short reason; callers count it
    /// as a skipped line rather than aborting (fail-soft contract).
    pub fn parse(line: &str) -> Result<Span, String> {
        let mut p = JsonParser::new(line);
        p.expect(b'{')?;
        let mut sp = Span {
            name: String::new(),
            key: String::new(),
            campaign: String::new(),
            worker: String::new(),
            tid: 0,
            round: None,
            start_us: 0,
            dur_us: 0,
        };
        let mut version = 0u64;
        loop {
            p.skip_ws();
            if p.eat(b'}') {
                break;
            }
            let field = p.string()?;
            p.skip_ws();
            p.expect(b':')?;
            p.skip_ws();
            match field.as_str() {
                "v" => version = p.number()? as u64,
                "name" => sp.name = p.string()?,
                "key" => sp.key = p.string()?,
                "campaign" => sp.campaign = p.string()?,
                "worker" => sp.worker = p.string()?,
                "tid" => sp.tid = p.number()? as u64,
                "round" => sp.round = Some(p.number()? as u64),
                "us" => sp.start_us = p.number()? as u64,
                "dur" => sp.dur_us = p.number()? as u64,
                _ => {
                    // Forward compat: unknown numeric or null fields are
                    // tolerated and dropped, like the event parser.
                    if !p.eat_literal("null") {
                        p.number()?;
                    }
                }
            }
            p.skip_ws();
            if !p.eat(b',') {
                p.expect(b'}')?;
                break;
            }
        }
        if version == 0 || version > MAX_TRACE_VERSION {
            return Err(format!("unsupported span version {version}"));
        }
        if sp.name.is_empty() {
            return Err("missing `name`".into());
        }
        Ok(sp)
    }
}

/// Directory holding the per-writer span segments.
pub fn trace_dir(store_root: &Path) -> PathBuf {
    store_root.join("fleet").join("trace")
}

pub(crate) fn unix_us_now() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

static TRACE_EMIT_FAILED: AtomicBool = AtomicBool::new(false);

/// Handle for appending spans as one writer. Cloning is cheap; all
/// clones append to the same per-writer segment file, one `write(2)`
/// per span (the crash-safety invariant, same as [`super::events`]).
#[derive(Clone, Debug)]
pub struct TraceLog {
    path: PathBuf,
    writer: String,
}

impl TraceLog {
    /// Open (creating directories as needed) the span segment for
    /// `writer`. Writer ids are sanitized to `[A-Za-z0-9._-]` exactly
    /// like [`super::events::EventLog::open`], so the shared [`Cursor`]
    /// wire form stays unambiguous.
    pub fn open(store_root: &Path, writer: &str) -> io::Result<TraceLog> {
        let dir = trace_dir(store_root);
        fs::create_dir_all(&dir)?;
        let writer: String = writer
            .chars()
            .map(|c| {
                if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') {
                    c
                } else {
                    '-'
                }
            })
            .collect();
        let writer = if writer.is_empty() { "anon".to_string() } else { writer };
        let path = dir.join(format!("{writer}.jsonl"));
        Ok(TraceLog { path, writer })
    }

    /// The sanitized writer id this log appends as.
    pub fn writer(&self) -> &str {
        &self.writer
    }

    /// Emit one span. Never fails: tracing must never take down a run,
    /// so append errors are reported once to stderr and dropped.
    pub fn emit(
        &self,
        name: &str,
        key: &str,
        campaign: &str,
        round: Option<u64>,
        start_us: u64,
        dur_us: u64,
    ) {
        self.append(&Span {
            name: name.to_string(),
            key: key.to_string(),
            campaign: campaign.to_string(),
            worker: self.writer.clone(),
            tid: prof::current_tid(),
            round,
            start_us,
            dur_us,
        })
    }

    /// Emit an instantaneous marker span (`dur == 0`) stamped now.
    pub fn mark(&self, name: &str, key: &str, campaign: &str, round: Option<u64>) {
        self.emit(name, key, campaign, round, unix_us_now(), 0)
    }

    /// Open an RAII scope: the span is emitted when the guard drops,
    /// covering the wall-clock between the two points.
    pub fn scope(&self, name: &'static str, key: &str, round: Option<u64>) -> SpanScope {
        SpanScope {
            log: self.clone(),
            name,
            key: key.to_string(),
            round,
            started: Instant::now(),
            start_us: unix_us_now(),
        }
    }

    fn append(&self, span: &Span) {
        let mut line = span.to_line();
        line.push('\n');
        let res = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .and_then(|mut f| io::Write::write_all(&mut f, line.as_bytes()));
        if let Err(e) = res {
            if !TRACE_EMIT_FAILED.swap(true, Ordering::Relaxed) {
                eprintln!(
                    "warning: trace append failed ({}): {e} — further failures are silent",
                    self.path.display()
                );
            }
        }
    }
}

/// RAII span guard from [`TraceLog::scope`]: emits on drop.
pub struct SpanScope {
    log: TraceLog,
    name: &'static str,
    key: String,
    round: Option<u64>,
    started: Instant,
    start_us: u64,
}

impl Drop for SpanScope {
    fn drop(&mut self) {
        let dur_us = self.started.elapsed().as_micros() as u64;
        self.log
            .emit(self.name, &self.key, "", self.round, self.start_us, dur_us);
    }
}

// ---------------------------------------------------------------------------
// Bridging util::prof phase spans into the fleet trace.

static PROF_DRAIN_CLAIMED: AtomicBool = AtomicBool::new(false);
static ACTIVE_TRACED_RUNS: AtomicUsize = AtomicUsize::new(0);

/// RAII marker: one traced run is executing in this process. Used to
/// detect in-process run concurrency (`par_map` campaigns), where
/// drained phase spans cannot be attributed to a single run.
pub struct RunToken(());

impl RunToken {
    pub fn new() -> RunToken {
        ACTIVE_TRACED_RUNS.fetch_add(1, Ordering::SeqCst);
        RunToken(())
    }
}

impl Default for RunToken {
    fn default() -> Self {
        RunToken::new()
    }
}

impl Drop for RunToken {
    fn drop(&mut self) {
        ACTIVE_TRACED_RUNS.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Claims the process-global [`prof`] buffer for one run and drains it
/// into the fleet trace every round, stamping each phase span with the
/// run key and round. Exactly one run per process may hold the claim;
/// `--profile-out` (which enabled prof first) always wins, so the two
/// consumers never steal each other's records.
pub struct ProfDrain {
    log: TraceLog,
    key: String,
}

impl ProfDrain {
    /// Try to claim phase-span capture for the run `key`. `None` if the
    /// profiler is already enabled externally or another run holds the
    /// claim — the run still traces its worker-level spans, it just
    /// skips per-phase detail.
    pub fn claim(log: TraceLog, key: &str) -> Option<ProfDrain> {
        if prof::is_enabled() {
            return None;
        }
        if PROF_DRAIN_CLAIMED.swap(true, Ordering::SeqCst) {
            return None;
        }
        prof::enable();
        let _ = prof::take(); // drop stale records from any previous owner
        Some(ProfDrain { log, key: key.to_string() })
    }

    /// Drain accumulated phase spans, attributing them to `round`.
    /// If another traced run started concurrently in this process the
    /// records can't be attributed to one run, so they are discarded
    /// (fail-soft: observability loses detail, never invents it).
    pub fn drain(&self, round: Option<u64>) {
        let spans = prof::take();
        if ACTIVE_TRACED_RUNS.load(Ordering::SeqCst) > 1 {
            return;
        }
        let base = prof::epoch_unix_us();
        for s in &spans {
            self.log.append(&Span {
                name: s.name.to_string(),
                key: self.key.clone(),
                campaign: String::new(),
                worker: self.log.writer.clone(),
                tid: s.tid,
                round,
                start_us: base.saturating_add(s.start_us),
                dur_us: s.dur_us,
            });
        }
    }
}

impl Drop for ProfDrain {
    fn drop(&mut self) {
        self.drain(None);
        prof::disable();
        let _ = prof::take();
        PROF_DRAIN_CLAIMED.store(false, Ordering::SeqCst);
    }
}

// ---------------------------------------------------------------------------
// Reading.

/// Batch read of a store's whole trace directory (fail-soft).
#[derive(Clone, Debug, Default)]
pub struct SpanReadReport {
    /// Parsed spans, in per-file order (see [`sort_spans`]).
    pub spans: Vec<Span>,
    /// Lines skipped: torn tails, parse failures, unknown versions.
    pub skipped_lines: usize,
    /// Segment files that could not be read at all.
    pub unreadable_files: usize,
}

/// One incremental read of the trace past a [`Cursor`] — the same
/// accounting contract as [`super::events::TailReport`].
#[derive(Clone, Debug, Default)]
pub struct SpanTailReport {
    /// Newly parsed spans, in per-file order.
    pub spans: Vec<Span>,
    /// The cursor after this read; feed it back to resume.
    pub cursor: Cursor,
    /// Garbage terminated lines consumed (cumulative across a chain).
    pub consumed_skipped: usize,
    /// Segments currently ending in a torn line (point-in-time).
    pub pending_tails: usize,
    /// Segments unreadable at this read (point-in-time).
    pub unreadable_files: usize,
}

/// Read every span segment under the store's trace directory.
/// Equivalent to [`read_spans_from`] with the zero cursor.
pub fn read_spans(store_root: &Path) -> SpanReadReport {
    let tail = read_spans_from(store_root, &Cursor::default());
    SpanReadReport {
        spans: tail.spans,
        skipped_lines: tail.consumed_skipped + tail.pending_tails,
        unreadable_files: tail.unreadable_files,
    }
}

/// Incrementally read every span segment past `cursor`, never
/// consuming a partial line — the trace analogue of
/// [`super::events::read_events_from`], built on the same segment
/// tailer so the two can never drift in torn-tail semantics.
pub fn read_spans_from(store_root: &Path, cursor: &Cursor) -> SpanTailReport {
    let seg = tail_segments(&trace_dir(store_root), cursor);
    let mut tail = SpanTailReport {
        cursor: seg.cursor,
        pending_tails: seg.pending_tails,
        unreadable_files: seg.unreadable_files,
        ..SpanTailReport::default()
    };
    for line in &seg.lines {
        match Span::parse(line) {
            Ok(sp) => tail.spans.push(sp),
            Err(_) => tail.consumed_skipped += 1,
        }
    }
    tail
}

/// Deterministic merge order for rendering: by start time, then
/// writer, lane, name, duration, key, round. Local and `--connect`
/// readers sort the same spans into the same sequence, which is what
/// makes `repro trace --connect` byte-identical to local.
pub fn sort_spans(spans: &mut [Span]) {
    spans.sort_by(|a, b| {
        (a.start_us, &a.worker, a.tid, &a.name, a.dur_us, &a.key, a.round)
            .cmp(&(b.start_us, &b.worker, b.tid, &b.name, b.dur_us, &b.key, b.round))
    });
}

// ---------------------------------------------------------------------------
// Analysis: utilization, critical path, Chrome export.

/// One worker lane's busy/idle accounting over the fleet window.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerUtil {
    pub worker: String,
    /// Microseconds covered by at least one span (interval union, so
    /// nested phase spans don't double-count).
    pub busy_us: u64,
    /// The fleet window (earliest span start → latest span end),
    /// shared by every worker so fractions are comparable.
    pub window_us: u64,
    /// Number of spans on this lane.
    pub spans: usize,
    /// Name of the latest-ending span (the lane's current phase).
    pub last_phase: String,
    /// When that span ended, unix microseconds.
    pub last_end_us: u64,
}

impl WorkerUtil {
    pub fn busy_frac(&self) -> f64 {
        if self.window_us == 0 {
            0.0
        } else {
            (self.busy_us as f64 / self.window_us as f64).min(1.0)
        }
    }
}

/// Fold spans into per-worker utilization, sorted by worker name.
/// Empty input yields an empty vec (the fail-soft "no pane" signal).
pub fn utilization(spans: &[Span]) -> Vec<WorkerUtil> {
    if spans.is_empty() {
        return Vec::new();
    }
    let t0 = spans.iter().map(|s| s.start_us).min().unwrap_or(0);
    let t1 = spans.iter().map(Span::end_us).max().unwrap_or(0);
    let window_us = t1.saturating_sub(t0);
    let mut workers: Vec<&str> = spans.iter().map(|s| s.worker.as_str()).collect();
    workers.sort_unstable();
    workers.dedup();
    workers
        .into_iter()
        .map(|w| {
            let mut ivals: Vec<(u64, u64)> = spans
                .iter()
                .filter(|s| s.worker == w)
                .map(|s| (s.start_us, s.end_us()))
                .collect();
            ivals.sort_unstable();
            let mut busy_us = 0u64;
            let mut cur: Option<(u64, u64)> = None;
            for (a, b) in ivals {
                match cur {
                    Some((ca, cb)) if a <= cb => cur = Some((ca, cb.max(b))),
                    Some((ca, cb)) => {
                        busy_us += cb - ca;
                        cur = Some((a, b));
                    }
                    None => cur = Some((a, b)),
                }
            }
            if let Some((ca, cb)) = cur {
                busy_us += cb - ca;
            }
            let last = spans
                .iter()
                .filter(|s| s.worker == w)
                .max_by(|x, y| {
                    (x.end_us(), x.start_us, &x.name).cmp(&(y.end_us(), y.start_us, &y.name))
                })
                .expect("worker has at least one span");
            WorkerUtil {
                worker: w.to_string(),
                busy_us,
                window_us,
                spans: spans.iter().filter(|s| s.worker == w).count(),
                last_phase: last.name.clone(),
                last_end_us: last.end_us(),
            }
        })
        .collect()
}

fn ms(us: u64) -> f64 {
    us as f64 / 1000.0
}

/// Render the merged-trace text report: header, per-run critical-path
/// table (queue-wait vs execute vs snapshot overhead), and per-worker
/// utilization with straggler ranking. Pure function of its inputs, so
/// local and `--connect` renderings are byte-identical by
/// construction. `spans` must already be ordered by [`sort_spans`].
pub fn render_report(
    spans: &[Span],
    consumed_skipped: usize,
    pending_tails: usize,
    unreadable_files: usize,
) -> String {
    let mut out = String::new();
    let util = utilization(spans);
    let window_us = util.first().map(|u| u.window_us).unwrap_or(0);
    out.push_str(&format!(
        "fleet trace: {} span(s) · {} worker lane(s) · makespan {:.3} ms\n",
        spans.len(),
        util.len(),
        ms(window_us)
    ));
    if consumed_skipped + pending_tails + unreadable_files > 0 {
        out.push_str(&format!(
            "fail-soft: {consumed_skipped} skipped line(s) · {pending_tails} pending tail(s) · {unreadable_files} unreadable file(s)\n"
        ));
    }

    // Per-run critical path: queue-wait (enqueue → first execute start),
    // execute, snapshot overhead (save + load), per key.
    let mut keys: Vec<&str> = spans
        .iter()
        .filter(|s| !s.key.is_empty())
        .map(|s| s.key.as_str())
        .collect();
    keys.sort_unstable();
    keys.dedup();
    out.push_str("\ncritical path per run (queue-wait → execute → snapshot):\n");
    if keys.is_empty() {
        out.push_str("  (no run-scoped spans)\n");
    } else {
        let mut rows: Vec<(String, String, Option<u64>, u64, u64, usize)> = keys
            .iter()
            .map(|&key| {
                let of = |name: &str| spans.iter().filter(move |s| s.key == key && s.name == name);
                let enq = of("enqueue").map(|s| s.start_us).min();
                let exec_start = of("execute").map(|s| s.start_us).min();
                let queue_wait = match (enq, exec_start) {
                    (Some(e), Some(x)) => Some(x.saturating_sub(e)),
                    _ => None,
                };
                let exec_us: u64 = of("execute").map(|s| s.dur_us).sum();
                let snap_us: u64 = of("snapshot_save")
                    .chain(of("snapshot_load"))
                    .map(|s| s.dur_us)
                    .sum();
                let mut execers: Vec<&str> =
                    of("execute").map(|s| s.worker.as_str()).collect();
                execers.sort_unstable();
                execers.dedup();
                let who = if execers.is_empty() { "-".to_string() } else { execers.join("+") };
                let rounds = spans
                    .iter()
                    .filter(|s| s.key == key)
                    .filter_map(|s| s.round)
                    .collect::<std::collections::BTreeSet<u64>>()
                    .len();
                (key.to_string(), who, queue_wait, exec_us, snap_us, rounds)
            })
            .collect();
        // Longest execute first: the top row is the campaign's critical run.
        rows.sort_by(|a, b| b.3.cmp(&a.3).then(a.0.cmp(&b.0)));
        out.push_str(&format!(
            "  {:<18} {:<12} {:>13} {:>12} {:>12} {:>7}\n",
            "key", "worker", "queue-wait ms", "execute ms", "snapshot ms", "rounds"
        ));
        for (key, who, queue_wait, exec_us, snap_us, rounds) in rows {
            let qw = match queue_wait {
                Some(us) => format!("{:.3}", ms(us)),
                None => "-".to_string(),
            };
            out.push_str(&format!(
                "  {:<18} {:<12} {:>13} {:>12.3} {:>12.3} {:>7}\n",
                key,
                who,
                qw,
                ms(exec_us),
                ms(snap_us),
                rounds
            ));
        }
    }

    // Per-worker utilization, busiest first; straggler = latest finisher.
    out.push_str("\nworker utilization (busy/idle over the fleet window):\n");
    if util.is_empty() {
        out.push_str("  (no spans)\n");
    } else {
        let mut by_busy = util.clone();
        by_busy.sort_by(|a, b| {
            b.busy_us.cmp(&a.busy_us).then(a.worker.cmp(&b.worker))
        });
        out.push_str(&format!(
            "  {:<12} {:>7} {:>7} {:>7}  {}\n",
            "worker", "busy %", "idle %", "spans", "last phase"
        ));
        for u in &by_busy {
            let busy = 100.0 * u.busy_frac();
            out.push_str(&format!(
                "  {:<12} {:>7.1} {:>7.1} {:>7}  {}\n",
                u.worker,
                busy,
                100.0 - busy,
                u.spans,
                u.last_phase
            ));
        }
        if util.len() > 1 {
            let straggler = util
                .iter()
                .max_by(|a, b| {
                    (a.last_end_us, &a.worker).cmp(&(b.last_end_us, &b.worker))
                })
                .expect("non-empty");
            let first_done = util.iter().map(|u| u.last_end_us).min().unwrap_or(0);
            out.push_str(&format!(
                "  straggler: {} (finished {:.3} ms after the first idle lane)\n",
                straggler.worker,
                ms(straggler.last_end_us.saturating_sub(first_done))
            ));
        }
    }
    out
}

/// Merged Chrome trace-event JSON: one process (`pid`) lane per
/// worker, one thread row per `(worker, tid)`, with "M" metadata
/// events naming both. Timestamps are rebased to the earliest span so
/// viewers open at t≈0. `spans` must already be ordered by
/// [`sort_spans`] for deterministic output.
pub fn chrome_trace(spans: &[Span]) -> String {
    let mut workers: Vec<&str> = spans.iter().map(|s| s.worker.as_str()).collect();
    workers.sort_unstable();
    workers.dedup();
    let pid_of = |w: &str| workers.iter().position(|x| *x == w).unwrap_or(0) as u64 + 1;
    let t0 = spans.iter().map(|s| s.start_us).min().unwrap_or(0);
    let mut events: Vec<String> = Vec::with_capacity(spans.len() + 2 * workers.len());
    for w in &workers {
        events.push(format!(
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{},\"args\":{{\"name\":\"{}\"}}}}",
            pid_of(w),
            json_escape(w)
        ));
    }
    let mut lanes: Vec<(&str, u64)> = spans.iter().map(|s| (s.worker.as_str(), s.tid)).collect();
    lanes.sort_unstable();
    lanes.dedup();
    for (w, tid) in lanes {
        events.push(format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{},\"tid\":{tid},\"args\":{{\"name\":\"lane-{tid}\"}}}}",
            pid_of(w)
        ));
    }
    for s in spans {
        let mut args = String::new();
        if !s.key.is_empty() {
            args.push_str(&format!(",\"key\":\"{}\"", json_escape(&s.key)));
        }
        if !s.campaign.is_empty() {
            args.push_str(&format!(",\"campaign\":\"{}\"", json_escape(&s.campaign)));
        }
        if let Some(r) = s.round {
            args.push_str(&format!(",\"round\":{r}"));
        }
        let args = if args.is_empty() {
            String::new()
        } else {
            format!(",\"args\":{{{}}}", &args[1..])
        };
        events.push(format!(
            "{{\"name\":\"{}\",\"cat\":\"fleet\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":{},\"tid\":{}{}}}",
            json_escape(&s.name),
            s.start_us - t0,
            s.dur_us,
            pid_of(&s.worker),
            s.tid,
            args
        ));
    }
    format!(
        "{{\"traceEvents\":[{}],\"displayTimeUnit\":\"ms\"}}",
        events.join(",")
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn tmp(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("ota_tracemod_{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn mk(name: &str, key: &str, worker: &str, start: u64, dur: u64) -> Span {
        Span {
            name: name.into(),
            key: key.into(),
            campaign: String::new(),
            worker: worker.into(),
            tid: 0,
            round: None,
            start_us: start,
            dur_us: dur,
        }
    }

    #[test]
    fn span_line_roundtrips_with_hostile_strings() {
        let sp = Span {
            name: "lease \"acquire\"\\".into(),
            key: "0123456789abcdef".into(),
            campaign: "fig-λ\n".into(),
            worker: "w0".into(),
            tid: 3,
            round: Some(7),
            start_us: 1_754_650_000_123_456,
            dur_us: 42,
        };
        assert_eq!(Span::parse(&sp.to_line()).unwrap(), sp);
        let bare = mk("execute", "", "w1", 5, 0);
        assert_eq!(Span::parse(&bare.to_line()).unwrap(), bare);
    }

    #[test]
    fn unknown_span_versions_and_garbage_are_skipped() {
        assert!(Span::parse("{\"v\":99,\"name\":\"x\",\"tid\":0,\"us\":0,\"dur\":0}").is_err());
        assert!(Span::parse("{\"v\":1,\"tid\":0,\"us\":0,\"dur\":0}").is_err(), "missing name");
        assert!(Span::parse("not json").is_err());
        // Unknown numeric / null fields are tolerated (forward compat).
        let sp = Span::parse("{\"v\":1,\"name\":\"x\",\"tid\":1,\"us\":9,\"dur\":2,\"future\":3,\"gone\":null}")
            .unwrap();
        assert_eq!((sp.name.as_str(), sp.start_us, sp.dur_us), ("x", 9, 2));
    }

    #[test]
    fn log_appends_and_tail_skips_torn_lines() {
        let root = tmp("torn");
        let log = TraceLog::open(&root, "w0/evil").unwrap();
        assert_eq!(log.writer(), "w0-evil", "writer sanitized");
        log.emit("lease_acquire", "k1", "", None, 10, 5);
        log.mark("enqueue", "k1", "fig1", None);
        let first = read_spans_from(&root, &Cursor::default());
        assert_eq!(first.spans.len(), 2);
        assert_eq!((first.consumed_skipped, first.pending_tails), (0, 0));

        // Torn tail: cursor parks, pending counted, nothing fatal.
        let path = trace_dir(&root).join("w0-evil.jsonl");
        let mut f = fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"v\":1,\"name\":\"exec").unwrap();
        drop(f);
        let torn = read_spans_from(&root, &first.cursor);
        assert!(torn.spans.is_empty());
        assert_eq!(torn.pending_tails, 1);
        assert_eq!(torn.cursor, first.cursor);

        // Writer completes the line: parses whole on the next read.
        let mut f = fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"ute\",\"tid\":0,\"us\":20,\"dur\":7}\n").unwrap();
        drop(f);
        let healed = read_spans_from(&root, &torn.cursor);
        assert_eq!(healed.spans.len(), 1);
        assert_eq!(healed.spans[0].name, "execute");
        fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn batch_read_is_zero_cursor_special_case() {
        let root = tmp("batch");
        let log = TraceLog::open(&root, "w0").unwrap();
        log.emit("execute", "k", "", None, 0, 3);
        let path = trace_dir(&root).join("w0.jsonl");
        let mut f = fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"garbage\n{\"v\":1,\"name\":\"to").unwrap();
        drop(f);
        let batch = read_spans(&root);
        let tail = read_spans_from(&root, &Cursor::default());
        assert_eq!(batch.spans, tail.spans);
        assert_eq!(batch.skipped_lines, tail.consumed_skipped + tail.pending_tails);
        assert_eq!(batch.skipped_lines, 2);
        fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn utilization_unions_nested_spans_and_ranks_stragglers() {
        let spans = vec![
            // w0 busy [0, 100) with a nested phase inside — no double count.
            mk("execute", "k1", "w0", 0, 100),
            mk("gradient", "k1", "w0", 10, 20),
            // w1 busy [0, 50) ∪ [150, 200): two disjoint intervals.
            mk("execute", "k2", "w1", 0, 50),
            mk("snapshot_save", "k2", "w1", 150, 50),
        ];
        let util = utilization(&spans);
        assert_eq!(util.len(), 2);
        let w0 = &util[0];
        let w1 = &util[1];
        assert_eq!((w0.worker.as_str(), w0.busy_us, w0.window_us), ("w0", 100, 200));
        assert_eq!((w1.worker.as_str(), w1.busy_us), ("w1", 100));
        assert_eq!(w1.last_phase, "snapshot_save");
        assert!(w1.last_end_us > w0.last_end_us, "w1 is the straggler");
        assert!(utilization(&[]).is_empty(), "fail-soft on no spans");
    }

    #[test]
    fn report_and_chrome_export_are_deterministic() {
        let mut spans = vec![
            mk("enqueue", "k1", "coordinator", 0, 0),
            mk("execute", "k1", "w0", 40, 100),
            mk("snapshot_save", "k1", "w0", 90, 10),
            mk("execute", "k2", "w1", 10, 300),
        ];
        let mut rev: Vec<Span> = spans.iter().rev().cloned().collect();
        sort_spans(&mut spans);
        sort_spans(&mut rev);
        assert_eq!(spans, rev, "sort is order-insensitive");
        let report = render_report(&spans, 1, 0, 0);
        assert!(report.contains("critical path per run"), "{report}");
        // k2 has the longest execute → ranked first.
        let k1_at = report.find("k1").unwrap();
        let k2_at = report.find("k2").unwrap();
        assert!(k2_at < k1_at, "{report}");
        // Queue wait for k1 = execute start (40µs) − enqueue (0µs).
        assert!(report.contains("0.040"), "{report}");
        assert!(report.contains("straggler"), "{report}");
        assert_eq!(report, render_report(&spans, 1, 0, 0));

        let json = chrome_trace(&spans);
        let doc = crate::fleet::client::Json::parse(&json).expect("valid JSON");
        let events = doc.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        // 3 worker lanes → 3 process_name + 3 thread_name metas + 4 spans.
        assert_eq!(events.len(), 10, "{json}");
        let pids: std::collections::BTreeSet<u64> = events
            .iter()
            .filter_map(|e| e.get("pid").and_then(|p| p.as_f64()))
            .map(|p| p as u64)
            .collect();
        assert_eq!(pids.len(), 3, "one pid lane per worker");
        assert_eq!(json, chrome_trace(&spans));
    }
}
