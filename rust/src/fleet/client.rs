//! Remote observability clients: the `--connect` side of
//! [`super::serve`].
//!
//! `repro metrics --connect` and `repro watch --connect` do **not**
//! trust the server to aggregate: they stream raw events from
//! `/events` and fold them through the same [`Reducer`] the local CLI
//! uses, so the remote view is the same *computation* as the local
//! one, merely fed over TCP. That is what makes the over-the-wire
//! determinism contract checkable: remote Prometheus text ==
//! local `repro metrics` byte-for-byte, remote
//! `Metrics::deterministic_core()` == local bit-for-bit.
//!
//! Everything here is hand-rolled on `std::net` + a minimal JSON
//! value parser (the crate has no HTTP or JSON dependency by design)
//! and speaks exactly the responder subset `fleet::serve` emits:
//! `HTTP/1.x`, `Connection: close`, EOF-delimited bodies.

use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use super::events::{Cursor, Event, TailReport};
use super::metrics::{Metrics, Reducer};
use super::status::{FleetStatus, ItemStatus};
use super::trace::{Span, SpanTailReport};

const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// One parsed HTTP response.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    /// Header names lowercased.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }
}

/// `GET http://{addr}{path}` with `Connection: close`; the body is
/// read to EOF. `addr` is `host:port`.
pub fn http_get(addr: &str, path: &str) -> io::Result<Response> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let req = format!("GET {path} HTTP/1.1\r\nhost: {addr}\r\nconnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?;
    parse_response(&raw)
}

fn bad(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

fn parse_response(raw: &[u8]) -> io::Result<Response> {
    let head_end = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .map(|p| (p, p + 4))
        .or_else(|| raw.windows(2).position(|w| w == b"\n\n").map(|p| (p, p + 2)))
        .ok_or_else(|| bad("response has no header/body separator"))?;
    let head = String::from_utf8_lossy(&raw[..head_end.0]);
    let mut lines = head.lines();
    let status_line = lines.next().ok_or_else(|| bad("empty response"))?;
    let mut parts = status_line.split_whitespace();
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        return Err(bad(format!("not an HTTP/1.x response: {status_line:?}")));
    }
    let status: u16 = parts
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| bad(format!("bad status line: {status_line:?}")))?;
    let headers = lines
        .filter_map(|l| {
            l.split_once(':')
                .map(|(k, v)| (k.trim().to_ascii_lowercase(), v.trim().to_string()))
        })
        .collect();
    Ok(Response { status, headers, body: raw[head_end.1..].to_vec() })
}

/// Fetch `/events?after=<cursor>` and reassemble the server's
/// [`TailReport`]: whole event lines from the body, advanced cursor
/// and skip accounting from the `x-ota-*` headers. Like the local
/// reader it is fail-soft on content: a body line that does not parse
/// is counted as skipped, never fatal.
pub fn fetch_events(addr: &str, cursor: &Cursor) -> io::Result<TailReport> {
    let path = format!("/events?after={}", cursor.render());
    let resp = http_get(addr, &path)?;
    if resp.status != 200 {
        return Err(bad(format!("GET /events: HTTP {}", resp.status)));
    }
    let next = resp
        .header("x-ota-cursor")
        .ok_or_else(|| bad("missing x-ota-cursor header"))?;
    let mut tail = TailReport {
        cursor: Cursor::parse(next).map_err(bad)?,
        consumed_skipped: header_count(&resp, "x-ota-skipped")?,
        pending_tails: header_count(&resp, "x-ota-pending")?,
        unreadable_files: header_count(&resp, "x-ota-unreadable")?,
        ..TailReport::default()
    };
    for line in String::from_utf8_lossy(&resp.body).lines() {
        if line.trim().is_empty() {
            continue;
        }
        match Event::parse(line) {
            Ok(ev) => tail.events.push(ev),
            Err(_) => tail.consumed_skipped += 1,
        }
    }
    Ok(tail)
}

/// Fetch `/trace?after=<cursor>` and reassemble the server's
/// [`SpanTailReport`] — the span-segment twin of [`fetch_events`],
/// sharing the cursor wire form and the x-ota accounting headers.
/// `repro trace --connect` feeds this into the same sort/render
/// pipeline as a local read, which is what makes the two outputs
/// byte-identical.
pub fn fetch_spans(addr: &str, cursor: &Cursor) -> io::Result<SpanTailReport> {
    let path = format!("/trace?after={}", cursor.render());
    let resp = http_get(addr, &path)?;
    if resp.status != 200 {
        return Err(bad(format!("GET /trace: HTTP {}", resp.status)));
    }
    let next = resp
        .header("x-ota-cursor")
        .ok_or_else(|| bad("missing x-ota-cursor header"))?;
    let mut tail = SpanTailReport {
        cursor: Cursor::parse(next).map_err(bad)?,
        consumed_skipped: header_count(&resp, "x-ota-skipped")?,
        pending_tails: header_count(&resp, "x-ota-pending")?,
        unreadable_files: header_count(&resp, "x-ota-unreadable")?,
        ..SpanTailReport::default()
    };
    for line in String::from_utf8_lossy(&resp.body).lines() {
        if line.trim().is_empty() {
            continue;
        }
        match Span::parse(line) {
            Ok(sp) => tail.spans.push(sp),
            Err(_) => tail.consumed_skipped += 1,
        }
    }
    Ok(tail)
}

fn header_count(resp: &Response, name: &str) -> io::Result<usize> {
    resp.header(name)
        .unwrap_or("0")
        .parse()
        .map_err(|_| bad(format!("bad {name} header")))
}

/// One-shot remote reduction: stream the whole log from the zero
/// cursor and fold it through the same [`Reducer`] as the local path.
/// `repro metrics --connect` prints `.to_prometheus()` of this.
pub fn remote_metrics(addr: &str) -> io::Result<Metrics> {
    let tail = fetch_events(addr, &Cursor::default())?;
    let mut r = Reducer::default();
    r.absorb_tail(&tail);
    Ok(r.metrics())
}

/// Fetch `/status` and parse it back into the server's
/// [`FleetStatus`] (plus the server-side store path, informational).
/// The fail-soft `unreadable` count rides along untouched, so
/// `repro fleet-status --connect` keeps the skip-and-count contract
/// end to end.
pub fn fetch_status(addr: &str) -> io::Result<(String, FleetStatus)> {
    let resp = http_get(addr, "/status")?;
    if resp.status != 200 {
        return Err(bad(format!("GET /status: HTTP {}", resp.status)));
    }
    parse_status(&String::from_utf8_lossy(&resp.body))
}

/// Parse the `/status` JSON document (the inverse of
/// `status::status_to_json`; the round-trip is pinned in
/// `rust/tests/remote_observability.rs`).
pub fn parse_status(text: &str) -> io::Result<(String, FleetStatus)> {
    let doc = Json::parse(text).map_err(bad)?;
    let obj = doc.as_obj().ok_or_else(|| bad("/status: not an object"))?;
    let field = |name: &str| {
        obj.iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v)
            .ok_or_else(|| bad(format!("/status: missing `{name}`")))
    };
    let count = |name: &str| -> io::Result<usize> {
        field(name)?
            .as_f64()
            .map(|v| v as usize)
            .ok_or_else(|| bad(format!("/status: `{name}` is not a number")))
    };
    let store_dir = field("store_dir")?
        .as_str()
        .ok_or_else(|| bad("/status: `store_dir` is not a string"))?
        .to_string();
    let mut st = FleetStatus {
        unreadable: count("unreadable")?,
        complete: count("complete")?,
        running: count("running")?,
        stale: count("stale")?,
        rounds_done: count("rounds_done")?,
        rounds_total: count("rounds_total")?,
        ..FleetStatus::default()
    };
    let items = field("items")?
        .as_arr()
        .ok_or_else(|| bad("/status: `items` is not an array"))?;
    for item in items {
        let obj = item.as_obj().ok_or_else(|| bad("/status: item is not an object"))?;
        let get = |name: &str| {
            obj.iter()
                .find(|(k, _)| k == name)
                .map(|(_, v)| v)
                .ok_or_else(|| bad(format!("/status item: missing `{name}`")))
        };
        let s = |name: &str| -> io::Result<String> {
            get(name)?
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| bad(format!("/status item: `{name}` is not a string")))
        };
        let n = |name: &str| -> io::Result<usize> {
            get(name)?
                .as_f64()
                .map(|v| v as usize)
                .ok_or_else(|| bad(format!("/status item: `{name}` is not a number")))
        };
        st.items.push(ItemStatus {
            seq: n("seq")?,
            key: s("key")?,
            label: s("label")?,
            spec_id: s("spec_id")?,
            state: s("state")?,
            rounds_done: n("rounds_done")?,
            rounds_total: n("rounds_total")?,
        });
    }
    Ok((store_dir, st))
}

/// Minimal recursive JSON value — just enough to parse the structured
/// documents `fleet::serve` emits (`/status`, `/health`). The event
/// wire format stays on the flat parser in [`super::events`].
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing bytes at {}", p.pos));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, name: &str) -> Option<&Json> {
        self.as_obj()?
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.bytes.get(self.pos).is_some_and(|b| b.is_ascii_whitespace()) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> bool {
        if self.peek() == Some(b) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn lit(&mut self, lit: &str) -> bool {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            true
        } else {
            false
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string().map(Json::Str),
            Some(b't') if self.lit("true") => Ok(Json::Bool(true)),
            Some(b'f') if self.lit("false") => Ok(Json::Bool(false)),
            Some(b'n') if self.lit("null") => Ok(Json::Null),
            Some(_) => self.number().map(Json::Num),
            None => Err("unexpected end of document".into()),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.pos += 1; // {
        let mut out = Vec::new();
        self.skip_ws();
        if self.eat(b'}') {
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            if !self.eat(b':') {
                return Err(format!("expected `:` at byte {}", self.pos));
            }
            out.push((key, self.value()?));
            self.skip_ws();
            if self.eat(b',') {
                continue;
            }
            if self.eat(b'}') {
                return Ok(Json::Obj(out));
            }
            return Err(format!("expected `,` or `}}` at byte {}", self.pos));
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.pos += 1; // [
        let mut out = Vec::new();
        self.skip_ws();
        if self.eat(b']') {
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            if self.eat(b',') {
                continue;
            }
            if self.eat(b']') {
                return Ok(Json::Arr(out));
            }
            return Err(format!("expected `,` or `]` at byte {}", self.pos));
        }
    }

    fn string(&mut self) -> Result<String, String> {
        if !self.eat(b'"') {
            return Err(format!("expected string at byte {}", self.pos));
        }
        let mut out = String::new();
        loop {
            let b = self.peek().ok_or("unterminated string")?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or("dangling escape")?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("unknown escape \\{}", e as char)),
                    }
                }
                _ => {
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    while end < self.bytes.len() && (self.bytes[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| "invalid utf-8 in string".to_string())?;
                    out.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<f64, String> {
        let start = self.pos;
        while self.peek().is_some_and(|b| {
            b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E')
        }) {
            self.pos += 1;
        }
        if start == self.pos {
            return Err(format!("expected a value at byte {start}"));
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_parses_nested_documents() {
        let doc = Json::parse(
            "{\"a\":[1,2.5,-3e2],\"b\":{\"c\":\"x\\ny\",\"d\":true},\"e\":null}",
        )
        .unwrap();
        assert_eq!(doc.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(doc.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(doc.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
        assert_eq!(doc.get("b").unwrap().get("d").unwrap(), &Json::Bool(true));
        assert_eq!(doc.get("e").unwrap(), &Json::Null);
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("[1,2}").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    /// Deterministic pseudo-random string: the property-test driver for
    /// the serializer/parser round trips below. A seeded LCG keeps the
    /// cases reproducible (no RNG dependency, no flaky shrinking).
    fn lcg_string(seed: &mut u64, max_len: usize) -> String {
        let mut next = || {
            *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (*seed >> 33) as u32
        };
        let len = next() as usize % (max_len + 1);
        (0..len)
            .map(|_| {
                // Bias toward hostile characters: quotes, backslashes,
                // control bytes, multi-byte unicode, and plain ASCII.
                match next() % 8 {
                    0 => '"',
                    1 => '\\',
                    2 => char::from_u32(next() % 0x20).unwrap(),
                    3 => '\u{2603}',   // ☃ (3-byte UTF-8)
                    4 => '\u{1F600}',  // 😀 (4-byte UTF-8, surrogate pair in JSON)
                    5 => '/',
                    _ => char::from_u32(0x20 + next() % 0x5f).unwrap(),
                }
            })
            .collect()
    }

    /// Property: any string escaped by the `fleet::events` serializer
    /// parses back to itself through this module's `Json` parser — the
    /// two hand-rolled halves of the wire format agree on escaping.
    #[test]
    fn escaped_strings_round_trip_against_events_serializer() {
        let mut seed = 0x07A5_EEDu64 ^ 42;
        for case in 0..200 {
            let original = lcg_string(&mut seed, 24);
            let doc = format!("\"{}\"", crate::fleet::events::json_escape(&original));
            let parsed = Json::parse(&doc)
                .unwrap_or_else(|e| panic!("case {case}: {doc:?} failed to parse: {e}"));
            assert_eq!(parsed.as_str(), Some(original.as_str()), "case {case}: {doc:?}");
        }
    }

    /// Property: escaped strings survive nesting inside arrays and
    /// objects of pseudo-random shape.
    #[test]
    fn nested_documents_round_trip_escaped_strings() {
        let mut seed = 7;
        for case in 0..50 {
            let key = lcg_string(&mut seed, 8);
            let val = lcg_string(&mut seed, 16);
            let deep = lcg_string(&mut seed, 16);
            let esc = crate::fleet::events::json_escape;
            // The fixed field name is longer than `lcg_string`'s max
            // length, so a generated key can never shadow it.
            let doc = format!(
                "{{\"{}\":[\"{}\",{{\"inner\":[[\"{}\"],null,true]}}],\"numeric-edge\":-0.5e3}}",
                esc(&key),
                esc(&val),
                esc(&deep)
            );
            let parsed = Json::parse(&doc)
                .unwrap_or_else(|e| panic!("case {case}: {doc:?} failed to parse: {e}"));
            let arr = parsed.get(&key).and_then(Json::as_arr).unwrap();
            assert_eq!(arr[0].as_str(), Some(val.as_str()), "case {case}");
            let inner = arr[1].get("inner").and_then(Json::as_arr).unwrap();
            assert_eq!(inner[0].as_arr().unwrap()[0].as_str(), Some(deep.as_str()));
            assert_eq!(inner[1], Json::Null);
            assert_eq!(inner[2], Json::Bool(true));
            assert_eq!(parsed.get("numeric-edge").unwrap().as_f64(), Some(-500.0));
        }
    }

    #[test]
    fn unicode_escapes_parse_including_raw_codepoints() {
        // \uXXXX escapes decode; unpaired surrogates degrade to U+FFFD
        // instead of panicking or corrupting the rest of the string.
        let doc = Json::parse("\"snow \\u2603 man\"").unwrap();
        assert_eq!(doc.as_str(), Some("snow \u{2603} man"));
        let doc = Json::parse("\"bad \\ud800 half\"").unwrap();
        assert_eq!(doc.as_str(), Some("bad \u{fffd} half"));
        // Raw multi-byte UTF-8 passes through untouched.
        let doc = Json::parse("\"emoji 😀 λ\"").unwrap();
        assert_eq!(doc.as_str(), Some("emoji 😀 λ"));
        assert!(Json::parse("\"truncated \\u26").is_err());
        assert!(Json::parse("\"dangling \\").is_err());
    }

    #[test]
    fn numeric_edge_cases_parse_like_rust_floats() {
        for (text, want) in [
            ("0", 0.0),
            ("-0", -0.0),
            ("1e-12", 1e-12),
            ("-2.5E+4", -25000.0),
            ("9007199254740993", 9007199254740993.0), // > 2^53: f64-rounded, not an error
            ("0.1", 0.1),
        ] {
            let v = Json::parse(text).unwrap().as_f64().unwrap();
            assert_eq!(v, want, "{text}");
        }
        assert_eq!(Json::parse("-0").unwrap().as_f64().map(f64::is_sign_negative), Some(true));
        assert!(Json::parse("1.2.3").is_err());
        assert!(Json::parse("--1").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn http_response_parses_status_headers_body() {
        let raw = b"HTTP/1.1 200 OK\r\nContent-Type: text/plain\r\nX-OTA-Cursor: w0:12\r\n\r\nbody bytes";
        let resp = parse_response(raw).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("x-ota-cursor"), Some("w0:12"));
        assert_eq!(resp.header("content-type"), Some("text/plain"));
        assert_eq!(resp.body, b"body bytes");
        assert!(parse_response(b"junk with no separator").is_err());
    }
}
