//! `repro serve` — the store's observability surfaces over the wire.
//!
//! A dependency-free telemetry server on `std::net` with a
//! hand-rolled minimal HTTP/1.1 responder (GET-only, `Connection:
//! close`, one response per connection), exposing exactly what the
//! local CLI reads from a shared filesystem — so a fleet can be
//! watched from a machine that mounts nothing:
//!
//! | endpoint | body |
//! |---|---|
//! | `GET /metrics` | the Prometheus text of `repro metrics`, byte-identical |
//! | `GET /status`  | [`super::status::collect_status`] as JSON |
//! | `GET /events?after=<cursor>` | incremental JSONL event tail (see below) |
//! | `GET /trace?after=<cursor>`  | incremental JSONL span tail, same cursor scheme |
//! | `GET /health`  | active health findings as JSON (observes one poll) |
//!
//! `/events` is the primitive the remote clients build on: the query
//! carries a [`Cursor`] (`w0:1024,w1:768` per-segment byte offsets),
//! the body carries only **whole** re-serialized event lines past it
//! (a torn tail is never shipped — [`read_events_from`] parks the
//! cursor before it), and the response headers return the advanced
//! cursor plus the reader's fail-soft accounting:
//!
//! ```text
//! x-ota-cursor:     <cursor to pass as ?after= next time>
//! x-ota-skipped:    garbage lines consumed by this read
//! x-ota-pending:    segments currently ending in a torn tail
//! x-ota-unreadable: segments unreadable at this read
//! ```
//!
//! The determinism contract extends over the wire: a client folding
//! the streamed events through the same [`Reducer`] reaches the same
//! `Metrics` — bit-identical `deterministic_core()`, byte-identical
//! Prometheus text — as a local reduction of the store (pinned in
//! `rust/tests/remote_observability.rs`). The server is observe-only
//! by construction: it shares the read-side code paths and never
//! touches run content-addresses, blobs, or goldens.
//!
//! Robustness at the socket: request lines over 8 KiB → `431`,
//! malformed request lines → `400`, non-GET methods → `405`, unknown
//! paths → `404`, and a slow or stalled client is cut off by a read
//! timeout. Each connection gets its own thread; the incremental
//! reducer state is behind one mutex, so concurrent scrapes serialize
//! on the fold but never observe a partial line.

use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::campaign::RunStore;

use super::events::{read_events_from, Cursor};
use super::health::{self, HealthPolicy, HealthTracker};
use super::metrics::Reducer;
use super::status::{collect_status, status_to_json};
use super::trace;

/// Cap on the request head (request line + headers) we will buffer.
const MAX_REQUEST_HEAD: usize = 8 * 1024;
/// Per-connection socket timeout: a stalled client cannot pin a
/// handler thread forever.
const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// Server policy knobs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Lease TTL used by the `/status` view (matches `fleet-status`).
    pub lease_secs: f64,
    /// Health thresholds for `/health` findings.
    pub policy: HealthPolicy,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            lease_secs: crate::config::FleetConfig::default().lease_secs,
            policy: HealthPolicy::default(),
        }
    }
}

/// Incremental state shared by `/metrics` and `/health`: one cursor
/// chain and reducer per server, so each scrape folds only the bytes
/// appended since the previous one.
struct ServerState {
    cursor: Cursor,
    reducer: Reducer,
    tracker: HealthTracker,
}

struct Shared {
    store: RunStore,
    store_dir: String,
    opts: ServeOptions,
    state: Mutex<ServerState>,
    stop: AtomicBool,
}

/// A running telemetry server. Binding spawns the accept loop on a
/// background thread; [`Server::join`] blocks until [`Server::stop`]
/// (tests) or forever (the CLI).
pub struct Server {
    addr: SocketAddr,
    shared: Arc<Shared>,
    handle: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind `listen` (e.g. `127.0.0.1:7878`; port 0 picks a free port)
    /// and start serving `store_dir`'s observability surfaces.
    pub fn bind(store_dir: &str, listen: &str, opts: ServeOptions) -> io::Result<Server> {
        let store = RunStore::open(store_dir)?;
        let listener = TcpListener::bind(listen)?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            store,
            store_dir: store_dir.to_string(),
            opts,
            state: Mutex::new(ServerState {
                cursor: Cursor::default(),
                reducer: Reducer::default(),
                tracker: HealthTracker::default(),
            }),
            stop: AtomicBool::new(false),
        });
        let accept_shared = Arc::clone(&shared);
        let handle = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if accept_shared.stop.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let conn_shared = Arc::clone(&accept_shared);
                std::thread::spawn(move || handle_connection(stream, &conn_shared));
            }
        });
        Ok(Server { addr, shared, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 for tests).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block on the accept loop (the CLI's foreground mode).
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    /// Ask the accept loop to exit and unblock it with one dummy
    /// connection (tests; idempotent).
    pub fn stop(&self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(self.addr);
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One parsed request target, already routed past method checks.
struct Request {
    path: String,
    query: String,
}

fn handle_connection(stream: TcpStream, shared: &Shared) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let mut stream = stream;
    let req = match read_request(&mut stream) {
        Ok(req) => req,
        Err((code, reason)) => {
            respond(&mut stream, code, reason, "text/plain", &[], reason.as_bytes());
            return;
        }
    };
    match req.path.as_str() {
        "/metrics" => {
            let body = {
                let mut st = shared.state.lock().unwrap();
                let tail = read_events_from(shared.store.root(), &st.cursor);
                st.cursor = tail.cursor.clone();
                st.reducer.absorb_tail(&tail);
                st.reducer.metrics().to_prometheus()
            };
            respond(
                &mut stream,
                200,
                "OK",
                "text/plain; version=0.0.4",
                &[],
                body.as_bytes(),
            );
        }
        "/status" => {
            let ttl = Duration::from_secs_f64(shared.opts.lease_secs);
            let status = collect_status(&shared.store, ttl);
            let body = status_to_json(&shared.store_dir, &status);
            respond(&mut stream, 200, "OK", "application/json", &[], body.as_bytes());
        }
        "/events" => {
            let after = req
                .query
                .split('&')
                .find_map(|kv| kv.strip_prefix("after="))
                .unwrap_or("");
            let cursor = match Cursor::parse(after) {
                Ok(c) => c,
                Err(e) => {
                    let msg = format!("bad cursor: {e}");
                    respond(&mut stream, 400, "Bad Request", "text/plain", &[], msg.as_bytes());
                    return;
                }
            };
            // Stateless by design: the *client* owns this cursor chain,
            // so any number of independent tailing clients can follow
            // one server without sharing positions.
            let tail = read_events_from(shared.store.root(), &cursor);
            let mut body = String::with_capacity(tail.events.len() * 96);
            for ev in &tail.events {
                body.push_str(&ev.to_line());
                body.push('\n');
            }
            let headers = [
                ("x-ota-cursor".to_string(), tail.cursor.render()),
                ("x-ota-skipped".to_string(), tail.consumed_skipped.to_string()),
                ("x-ota-pending".to_string(), tail.pending_tails.to_string()),
                ("x-ota-unreadable".to_string(), tail.unreadable_files.to_string()),
            ];
            respond(&mut stream, 200, "OK", "application/x-ndjson", &headers, body.as_bytes());
        }
        "/trace" => {
            let after = req
                .query
                .split('&')
                .find_map(|kv| kv.strip_prefix("after="))
                .unwrap_or("");
            let cursor = match Cursor::parse(after) {
                Ok(c) => c,
                Err(e) => {
                    let msg = format!("bad cursor: {e}");
                    respond(&mut stream, 400, "Bad Request", "text/plain", &[], msg.as_bytes());
                    return;
                }
            };
            // Same stateless cursor contract as `/events`, over the span
            // segments: whole re-serialized lines only, torn tails held
            // back, accounting in the same x-ota headers — which is what
            // makes `repro trace --connect` byte-identical to local.
            let tail = trace::read_spans_from(shared.store.root(), &cursor);
            let mut body = String::with_capacity(tail.spans.len() * 96);
            for sp in &tail.spans {
                body.push_str(&sp.to_line());
                body.push('\n');
            }
            let headers = [
                ("x-ota-cursor".to_string(), tail.cursor.render()),
                ("x-ota-skipped".to_string(), tail.consumed_skipped.to_string()),
                ("x-ota-pending".to_string(), tail.pending_tails.to_string()),
                ("x-ota-unreadable".to_string(), tail.unreadable_files.to_string()),
            ];
            respond(&mut stream, 200, "OK", "application/x-ndjson", &headers, body.as_bytes());
        }
        "/health" => {
            let body = {
                let mut st = shared.state.lock().unwrap();
                let tail = read_events_from(shared.store.root(), &st.cursor);
                st.cursor = tail.cursor.clone();
                st.reducer.absorb_tail(&tail);
                let m = st.reducer.metrics();
                // Stall detection is keyed on elapsed wall-clock, not
                // request count: any number of concurrent scrapers share
                // this tracker, and N monitors must not divide the stall
                // window by N (`HealthPolicy::stall_poll_secs`).
                st.tracker.observe_at(&m, super::events::unix_ms_now(), &shared.opts.policy);
                let mut findings = health::evaluate(&m, &shared.opts.policy);
                findings.extend(st.tracker.stalled(&shared.opts.policy));
                health_json(st.tracker.polls(), &findings)
            };
            respond(&mut stream, 200, "OK", "application/json", &[], body.as_bytes());
        }
        _ => {
            respond(&mut stream, 404, "Not Found", "text/plain", &[], b"not found");
        }
    }
}

/// `/health` response body.
fn health_json(polls: u64, findings: &[health::Finding]) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(64);
    let _ = write!(s, "{{\"polls\":{polls},\"findings\":[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(
            s,
            "{{\"kind\":\"{}\",\"key\":\"{}\",\"value\":{},\"detail\":\"{}\"}}",
            f.kind.name(),
            super::events::json_escape(&f.key),
            f.value,
            super::events::json_escape(&f.detail),
        );
    }
    s.push_str("]}");
    s
}

/// Read and parse the request head. Tolerates the head arriving in any
/// number of TCP segments; rejects oversized heads (`431`), malformed
/// request lines (`400`), non-GET methods (`405`), and HTTP versions
/// this responder does not speak (`505`).
fn read_request(stream: &mut TcpStream) -> Result<Request, (u16, &'static str)> {
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 1024];
    while !head_complete(&buf) {
        if buf.len() > MAX_REQUEST_HEAD {
            return Err((431, "Request Header Fields Too Large"));
        }
        match stream.read(&mut chunk) {
            Ok(0) => break, // EOF: parse whatever arrived
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(_) => break, // timeout/reset: same
        }
    }
    if buf.len() > MAX_REQUEST_HEAD {
        return Err((431, "Request Header Fields Too Large"));
    }
    let head = String::from_utf8_lossy(&buf);
    let line = head.lines().next().unwrap_or("");
    let mut parts = line.split_whitespace();
    let (Some(method), Some(target), Some(version)) =
        (parts.next(), parts.next(), parts.next())
    else {
        return Err((400, "Bad Request"));
    };
    if parts.next().is_some() || !target.starts_with('/') {
        return Err((400, "Bad Request"));
    }
    if !version.starts_with("HTTP/1.") {
        return Err((505, "HTTP Version Not Supported"));
    }
    if method != "GET" {
        return Err((405, "Method Not Allowed"));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };
    Ok(Request { path, query })
}

/// The head is complete once the blank line after the headers arrives.
fn head_complete(buf: &[u8]) -> bool {
    buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.windows(2).any(|w| w == b"\n\n")
}

fn respond(
    stream: &mut TcpStream,
    code: u16,
    reason: &str,
    content_type: &str,
    extra: &[(String, String)],
    body: &[u8],
) {
    let mut head = format!(
        "HTTP/1.1 {code} {reason}\r\ncontent-type: {content_type}\r\ncontent-length: {}\r\nconnection: close\r\n",
        body.len()
    );
    for (k, v) in extra {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    let _ = stream
        .write_all(head.as_bytes())
        .and_then(|()| stream.write_all(body))
        .and_then(|()| stream.flush());
    let _ = stream.shutdown(Shutdown::Write);
    // Lingering close: drain whatever the client is still sending (an
    // oversized head, a request body we never read) before the socket
    // drops. Closing with unread bytes queued makes the kernel send
    // RST instead of FIN, which can destroy the response in flight —
    // the client would see a connection reset instead of our 431/400.
    let mut scratch = [0u8; 1024];
    let mut drained = 0usize;
    while drained < 64 * 1024 {
        match stream.read(&mut scratch) {
            Ok(0) | Err(_) => break,
            Ok(n) => drained += n,
        }
    }
}
