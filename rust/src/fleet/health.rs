//! Typed fleet-health findings derived from the replayed metrics.
//!
//! Observability so far *shows* the fleet; this layer *judges* it:
//! a small catalog of conditions that mean "a human should look",
//! each one a typed [`Finding`] rather than a log line, so the same
//! judgement renders as the `/health` JSON endpoint, the
//! `ota_health_*` Prometheus family, and the alerts pane of
//! `repro watch`.
//!
//! The catalog splits along the same determinism seam as the metrics:
//!
//! * **Deterministic findings** ([`evaluate`]) are pure functions of
//!   [`Metrics`] — lease churn (repeated reclaims of one key), Eq. 6
//!   power-headroom violation (the budget audit of arXiv 1901.00844's
//!   power constraint), diverging training loss. Because they depend
//!   on nothing but the reduced log, a remote client evaluating its
//!   streamed copy of the events reaches byte-identical findings, and
//!   they are safe to embed in the Prometheus text without breaking
//!   the local/remote byte-identity contract.
//! * **Stall findings** ([`HealthTracker`]) need *poll history* —
//!   "rounds not advancing" is only meaningful across successive
//!   observations — so they are inherently observer-local: they
//!   surface in `/health` JSON and the watch alerts pane, never in
//!   the Prometheus exposition.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::metrics::Metrics;

/// The health-finding catalog.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum HealthKind {
    /// An active (executed/resumed, not completed) run whose
    /// deduplicated round count did not advance across N polls.
    StalledRun,
    /// One run key reclaimed repeatedly — workers keep dying on it or
    /// the lease TTL is mis-tuned.
    LeaseChurn,
    /// Eq. 6 power budget violated: the completed-run audit shows
    /// `max_avg_power > pbar`, or a per-round link probe reported
    /// negative headroom.
    PowerViolation,
    /// Training loss rising well above its own minimum — the run is
    /// diverging, not converging.
    DivergingLoss,
}

impl HealthKind {
    /// Deterministic kinds, in render order (stalls are excluded: they
    /// are poll-history dependent and never enter the Prometheus text).
    pub const DETERMINISTIC: [HealthKind; 3] = [
        HealthKind::LeaseChurn,
        HealthKind::PowerViolation,
        HealthKind::DivergingLoss,
    ];

    /// Wire/label name of the kind.
    pub fn name(self) -> &'static str {
        match self {
            HealthKind::StalledRun => "stalled_run",
            HealthKind::LeaseChurn => "lease_churn",
            HealthKind::PowerViolation => "power_violation",
            HealthKind::DivergingLoss => "diverging_loss",
        }
    }
}

/// One active health finding.
#[derive(Clone, Debug, PartialEq)]
pub struct Finding {
    pub kind: HealthKind,
    /// Run key the finding is about (empty for fleet-wide findings).
    pub key: String,
    /// Magnitude: reclaim count, negative headroom, loss ratio,
    /// stalled-poll count — whatever quantifies `kind`.
    pub value: f64,
    /// Human-readable one-liner for dashboards.
    pub detail: String,
}

/// Thresholds for the catalog. Defaults are deliberately conservative:
/// a finding should mean "look at this", not "a counter moved".
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HealthPolicy {
    /// Reclaims of one key at or above this is lease churn.
    pub churn_reclaims: u64,
    /// Latest train loss above `factor ×` its own minimum is diverging…
    pub divergence_factor: f64,
    /// …once the run has at least this many loss points (young runs
    /// fluctuate legitimately).
    pub divergence_min_rounds: usize,
    /// Consecutive polls without round progress before a run stalls.
    pub stall_polls: u32,
    /// Minimum wall-clock between *counted* stall polls for
    /// [`HealthTracker::observe_at`]. Keying the poll history on
    /// elapsed time instead of call count keeps the effective stall
    /// window (`stall_polls × stall_poll_secs`) independent of how
    /// many clients happen to be scraping `/health` concurrently —
    /// two monitors must not halve it. `0.0` restores the legacy
    /// every-call advance.
    pub stall_poll_secs: f64,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy {
            churn_reclaims: 3,
            divergence_factor: 2.0,
            divergence_min_rounds: 8,
            stall_polls: 3,
            stall_poll_secs: 2.0,
        }
    }
}

/// Evaluate the deterministic catalog over reduced metrics. Pure: the
/// same `Metrics` (local batch reduce, incremental reducer, or a
/// remote client's streamed copy) always yields the same findings, in
/// the same order (by kind, then key).
pub fn evaluate(m: &Metrics, policy: &HealthPolicy) -> Vec<Finding> {
    let mut out = Vec::new();
    for (key, &n) in &m.reclaims_by_key {
        if n >= policy.churn_reclaims {
            out.push(Finding {
                kind: HealthKind::LeaseChurn,
                key: key.clone(),
                value: n as f64,
                detail: format!(
                    "run {key} reclaimed {n}× — workers keep dying on it or the lease TTL is too short"
                ),
            });
        }
    }
    for (key, run) in &m.runs {
        // Eq. 6 audit from `completed` (fraction of budget), or the
        // per-round probe gauge (absolute energy): either going
        // negative means a device exceeded its average power budget.
        let audit = run.power_headroom.filter(|&h| h < 0.0);
        let probe = run.last_link_headroom().map(|(_, v)| v).filter(|&h| h < 0.0);
        if let Some(h) = audit.or(probe) {
            out.push(Finding {
                kind: HealthKind::PowerViolation,
                key: key.clone(),
                value: h,
                detail: format!(
                    "run {key} violates the Eq. 6 power budget (headroom {h:.3e})"
                ),
            });
        }
        if run.train_loss.len() >= policy.divergence_min_rounds {
            let min = run.train_loss.values().cloned().fold(f64::INFINITY, f64::min);
            let last = run.last_train_loss().map(|(_, v)| v).unwrap_or(min);
            if min.is_finite() && min > 0.0 && last > policy.divergence_factor * min {
                out.push(Finding {
                    kind: HealthKind::DivergingLoss,
                    key: key.clone(),
                    value: last / min,
                    detail: format!(
                        "run {key} train loss {last:.4} is {:.1}× its minimum {min:.4} — diverging",
                        last / min
                    ),
                });
            }
        }
    }
    out.sort_by(|a, b| (a.kind, &a.key).cmp(&(b.kind, &b.key)));
    out
}

/// Poll-history stall detector for watch loops and the telemetry
/// server: feed it one [`Metrics`] snapshot per poll and it reports
/// active runs whose deduplicated round count has not advanced for
/// [`HealthPolicy::stall_polls`] consecutive polls.
#[derive(Clone, Debug, Default)]
pub struct HealthTracker {
    /// Per-key (last observed round count, polls without progress).
    seen: BTreeMap<String, (usize, u32)>,
    polls: u64,
    /// When the stall counters last advanced (unix ms), for
    /// [`observe_at`](HealthTracker::observe_at)'s rate limiting.
    last_advance_ms: Option<u64>,
}

impl HealthTracker {
    /// Observe one poll, advancing the stall counters unconditionally.
    /// Right for a *single* caller with its own cadence (the
    /// `repro watch` loop); a shared tracker behind an endpoint must
    /// use [`observe_at`](HealthTracker::observe_at) instead, or N
    /// concurrent scrapers divide the stall window by N. Only *active*
    /// runs are tracked: started (executed or resumed) and not yet
    /// completed. Completed or unseen runs are dropped so a finished
    /// store never alarms.
    pub fn observe(&mut self, m: &Metrics) {
        self.update(m, true);
    }

    /// Observe one poll at wall-clock `now_ms`, advancing the stall
    /// counters only when at least [`HealthPolicy::stall_poll_secs`]
    /// has elapsed since they last advanced. Interleaved scrapers all
    /// refresh the round counts (progress is never missed — a run that
    /// advanced resets its counter on *any* observation) but the
    /// no-progress clock ticks on elapsed time, not on request rate.
    pub fn observe_at(&mut self, m: &Metrics, now_ms: u64, policy: &HealthPolicy) {
        let interval_ms = (policy.stall_poll_secs.max(0.0) * 1000.0) as u64;
        let advance = match self.last_advance_ms {
            Some(prev) => now_ms.saturating_sub(prev) >= interval_ms,
            None => true,
        };
        if advance {
            self.last_advance_ms = Some(now_ms);
        }
        self.update(m, advance);
    }

    fn update(&mut self, m: &Metrics, advance: bool) {
        self.polls += 1;
        let mut next = BTreeMap::new();
        for key in m.executed.union(&m.resumed) {
            if m.completed.contains(key) {
                continue;
            }
            let rounds = m.runs.get(key).map_or(0, |r| r.rounds.len());
            let stalls = match self.seen.get(key) {
                Some(&(prev, stalls)) if rounds <= prev => stalls + u32::from(advance),
                _ => 0,
            };
            next.insert(key.clone(), (rounds, stalls));
        }
        self.seen = next;
    }

    /// Polls observed so far.
    pub fn polls(&self) -> u64 {
        self.polls
    }

    /// Stall findings as of the latest poll.
    pub fn stalled(&self, policy: &HealthPolicy) -> Vec<Finding> {
        self.seen
            .iter()
            .filter(|(_, &(_, stalls))| stalls >= policy.stall_polls)
            .map(|(key, &(rounds, stalls))| Finding {
                kind: HealthKind::StalledRun,
                key: key.clone(),
                value: stalls as f64,
                detail: format!(
                    "run {key} stuck at {rounds} round(s) for {stalls} poll(s)"
                ),
            })
            .collect()
    }
}

/// The `ota_health_*` Prometheus family over the deterministic
/// findings: one gauge per catalog kind (always all three, so the
/// text shape is stable) plus a `{kind,key}` detail gauge per active
/// finding. Callers pass [`evaluate`]'s output — never stall findings,
/// which would break local/remote byte-identity.
pub fn render_prometheus(findings: &[Finding]) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "# HELP ota_health_findings Active deterministic health findings by kind."
    );
    let _ = writeln!(s, "# TYPE ota_health_findings gauge");
    for kind in HealthKind::DETERMINISTIC {
        let n = findings.iter().filter(|f| f.kind == kind).count();
        let _ = writeln!(s, "ota_health_findings{{kind=\"{}\"}} {n}", kind.name());
    }
    if !findings.is_empty() {
        let _ = writeln!(
            s,
            "# HELP ota_health_finding_value Magnitude of each active finding."
        );
        let _ = writeln!(s, "# TYPE ota_health_finding_value gauge");
        for f in findings {
            let _ = writeln!(
                s,
                "ota_health_finding_value{{kind=\"{}\",key=\"{}\"}} {}",
                f.kind.name(),
                f.key,
                f.value
            );
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::events::{Event, EventKind};
    use crate::fleet::metrics::reduce;

    fn ev(kind: EventKind, key: &str, round: Option<u64>, data: &[(&str, f64)]) -> Event {
        Event {
            kind,
            key: key.into(),
            label: String::new(),
            worker: "w0".into(),
            round,
            unix_ms: 0,
            data: data.iter().map(|&(k, v)| (k.into(), v)).collect(),
        }
    }

    #[test]
    fn lease_churn_fires_at_threshold() {
        let events: Vec<Event> =
            (0..3).map(|_| ev(EventKind::Reclaimed, "k1", None, &[])).collect();
        let m = reduce(&events);
        let f = evaluate(&m, &HealthPolicy::default());
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].kind, HealthKind::LeaseChurn);
        assert_eq!(f[0].value, 3.0);
        // Two reclaims is below the default threshold.
        let m = reduce(&events[..2]);
        assert!(evaluate(&m, &HealthPolicy::default()).is_empty());
    }

    #[test]
    fn power_violation_from_audit_or_probe() {
        // Completed-run audit: max_avg_power > pbar.
        let m = reduce(&[ev(
            EventKind::Completed,
            "k1",
            None,
            &[("pbar", 1.0), ("max_avg_power", 1.5)],
        )]);
        let f = evaluate(&m, &HealthPolicy::default());
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].kind, HealthKind::PowerViolation);
        assert!(f[0].value < 0.0);
        // Per-round probe headroom going negative also fires.
        let m = reduce(&[ev(
            EventKind::Round,
            "k1",
            Some(0),
            &[("power_headroom", -0.25)],
        )]);
        assert_eq!(evaluate(&m, &HealthPolicy::default()).len(), 1);
        // Healthy headroom on both counts: silent.
        let m = reduce(&[
            ev(EventKind::Completed, "k1", None, &[("pbar", 1.0), ("max_avg_power", 0.5)]),
            ev(EventKind::Round, "k1", Some(0), &[("power_headroom", 0.25)]),
        ]);
        assert!(evaluate(&m, &HealthPolicy::default()).is_empty());
    }

    #[test]
    fn diverging_loss_needs_history_and_ratio() {
        let rising: Vec<Event> = (0..8)
            .map(|r| {
                ev(
                    EventKind::Round,
                    "k1",
                    Some(r),
                    &[("train_loss", 0.5 + 0.25 * r as f64)],
                )
            })
            .collect();
        let m = reduce(&rising);
        let f = evaluate(&m, &HealthPolicy::default());
        assert_eq!(f.len(), 1, "2.25/0.5 = 4.5× the minimum");
        assert_eq!(f[0].kind, HealthKind::DivergingLoss);
        // Short history never alarms, whatever the ratio.
        let m = reduce(&rising[..4]);
        assert!(evaluate(&m, &HealthPolicy::default()).is_empty());
        // A converging run never alarms.
        let falling: Vec<Event> = (0..8)
            .map(|r| {
                ev(
                    EventKind::Round,
                    "k1",
                    Some(r),
                    &[("train_loss", 2.0 / (1.0 + r as f64))],
                )
            })
            .collect();
        assert!(evaluate(&reduce(&falling), &HealthPolicy::default()).is_empty());
    }

    #[test]
    fn stall_tracker_needs_consecutive_flat_polls() {
        let active = reduce(&[
            ev(EventKind::Executed, "k1", None, &[]),
            ev(EventKind::Round, "k1", Some(0), &[]),
        ]);
        let policy = HealthPolicy::default();
        let mut t = HealthTracker::default();
        t.observe(&active);
        assert!(t.stalled(&policy).is_empty(), "first sighting is progress");
        t.observe(&active);
        t.observe(&active);
        assert!(t.stalled(&policy).is_empty(), "2 flat polls < threshold");
        t.observe(&active);
        let f = t.stalled(&policy);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].kind, HealthKind::StalledRun);
        // Progress resets the counter…
        let progressed = reduce(&[
            ev(EventKind::Executed, "k1", None, &[]),
            ev(EventKind::Round, "k1", Some(0), &[]),
            ev(EventKind::Round, "k1", Some(1), &[]),
        ]);
        t.observe(&progressed);
        assert!(t.stalled(&policy).is_empty());
        // …and completion retires the run entirely.
        let done = reduce(&[
            ev(EventKind::Executed, "k1", None, &[]),
            ev(EventKind::Round, "k1", Some(0), &[]),
            ev(EventKind::Round, "k1", Some(1), &[]),
            ev(EventKind::Completed, "k1", None, &[]),
        ]);
        for _ in 0..5 {
            t.observe(&done);
        }
        assert!(t.stalled(&policy).is_empty());
    }

    /// Two monitors scraping the same endpoint must not halve the
    /// stall window: with `observe_at`, interleaved scrapes inside one
    /// `stall_poll_secs` window advance the no-progress clock once.
    #[test]
    fn interleaved_scrapers_advance_stall_clock_once_per_window() {
        let active = reduce(&[
            ev(EventKind::Executed, "k1", None, &[]),
            ev(EventKind::Round, "k1", Some(0), &[]),
        ]);
        let policy = HealthPolicy { stall_poll_secs: 2.0, ..HealthPolicy::default() };
        let mut t = HealthTracker::default();
        // Two scrapers, each polling every 2s, phase-shifted by 100ms:
        // 8 seconds of wall clock = 4 windows = at most 4 counted polls
        // (first sighting is progress), not 8 — the counter must stay
        // below the 3-poll threshold until 3 *windows* elapse.
        let mut counted = 0u32;
        for window in 0u64..4 {
            let base = 1_000_000 + window * 2_000;
            t.observe_at(&active, base, &policy); // scraper A
            t.observe_at(&active, base + 100, &policy); // scraper B
            if window > 0 {
                counted += 1;
            }
            let stalled = !t.stalled(&policy).is_empty();
            assert_eq!(
                stalled,
                counted >= policy.stall_polls,
                "window {window}: {counted} counted poll(s)"
            );
        }
        assert_eq!(t.polls(), 8, "every scrape is still a poll");
        // Legacy mode: stall_poll_secs = 0 restores per-call advance.
        let legacy = HealthPolicy { stall_poll_secs: 0.0, ..policy };
        let mut t = HealthTracker::default();
        for i in 0..4 {
            t.observe_at(&active, 5_000_000 + i, &legacy);
        }
        assert_eq!(t.stalled(&legacy).len(), 1, "3 flat polls after first sighting");
        // Progress observed by either scraper resets the counter even
        // mid-window.
        let progressed = reduce(&[
            ev(EventKind::Executed, "k1", None, &[]),
            ev(EventKind::Round, "k1", Some(0), &[]),
            ev(EventKind::Round, "k1", Some(1), &[]),
        ]);
        let mut t = HealthTracker::default();
        t.observe_at(&active, 0, &policy);
        t.observe_at(&active, 2_000, &policy);
        t.observe_at(&active, 4_000, &policy);
        t.observe_at(&active, 6_000, &policy);
        assert_eq!(t.stalled(&policy).len(), 1);
        t.observe_at(&progressed, 6_050, &policy); // off-window scrape sees progress
        assert!(t.stalled(&policy).is_empty(), "progress resets regardless of window");
    }

    #[test]
    fn prometheus_family_is_stable_and_labeled() {
        let text = render_prometheus(&[]);
        assert!(text.contains("ota_health_findings{kind=\"lease_churn\"} 0"));
        assert!(text.contains("ota_health_findings{kind=\"power_violation\"} 0"));
        assert!(text.contains("ota_health_findings{kind=\"diverging_loss\"} 0"));
        assert!(!text.contains("stalled_run"), "stalls never enter the exposition");
        let f = Finding {
            kind: HealthKind::LeaseChurn,
            key: "k1".into(),
            value: 4.0,
            detail: String::new(),
        };
        let text = render_prometheus(&[f]);
        assert!(text.contains("ota_health_findings{kind=\"lease_churn\"} 1"));
        assert!(text.contains("ota_health_finding_value{kind=\"lease_churn\",key=\"k1\"} 4"));
    }
}
