//! The fleet's persistent global work queue over a campaign store.
//!
//! The coordinator enumerates every run of every figure spec into one
//! item file per run under `<store>/fleet/queue/`; workers — including
//! ones attached later from other processes, knowing nothing but the
//! store directory — read the queue back and reconstruct each
//! [`RunConfig`] from its TOML rendering ([`RunConfig::to_toml`] is
//! exact, so the worker addresses the same content-addressed store entry
//! the coordinator did).
//!
//! # Ordering policy
//!
//! Claim order is **shortest-remaining-work-first**: remaining rounds per
//! item come from the store manifest's `snapshot_round` (complete → 0,
//! partial → `iterations − snapshot_round`, absent → `iterations`), ties
//! broken by enqueue sequence, so every worker derives the same order
//! from the same store state. Budget-wise this drains near-finished
//! (e.g. reclaimed) runs first and converts partial work into cacheable
//! results as early as possible.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use crate::campaign::manifest::{RunManifest, RunStatus};
use crate::campaign::store::{self, RunStore};
use crate::config::RunConfig;
use crate::coordinator::TrainLog;
use crate::experiments::runner::{self, ExperimentSpec};

/// One enqueued run.
#[derive(Clone, Debug)]
pub struct WorkItem {
    /// Enqueue sequence — the deterministic tie-breaker.
    pub seq: usize,
    /// Figure spec the run belongs to (results directory name).
    pub spec_id: String,
    /// Run label inside the spec (display metadata).
    pub label: String,
    /// Content-address of the run in the store.
    pub key: String,
    pub cfg: RunConfig,
}

/// The queue directory for a store root.
pub fn queue_dir(store_root: &Path) -> PathBuf {
    store_root.join("fleet").join("queue")
}

/// Item `spec_id`/`label` are display metadata (the coordinator keeps the
/// originals for output files), sanitized lossily via the shared rule.
/// The embedded `RunConfig` — the identity-bearing part — goes through
/// `RunConfig::to_toml`, which rejects unescapable strings instead.
fn clean(s: &str) -> String {
    crate::config::parser::sanitize_display(s)
}

/// Enumerate every run of every spec into the store's queue, **replacing**
/// whatever campaign was queued before: the queue always describes the
/// most recent `repro fleet` invocation, so leftover items from an
/// abandoned earlier campaign cannot silently block or pollute a new one
/// (their store entries stay cached/resumable — only the queue view is
/// replaced). Re-enqueueing the same specs is idempotent. A worker that
/// loaded the old queue mid-pass finishes its current claim into the
/// store harmlessly and picks up the new view on its next pass. Returns
/// the enqueued items in sequence order.
pub fn enqueue_specs(
    store: &RunStore,
    specs: &[ExperimentSpec],
) -> io::Result<Vec<WorkItem>> {
    let dir = queue_dir(store.root());
    fs::create_dir_all(&dir)?;
    if let Ok(old) = fs::read_dir(&dir) {
        for entry in old.flatten() {
            let name = entry.file_name().to_string_lossy().into_owned();
            if name.ends_with(".toml") {
                let _ = fs::remove_file(entry.path());
            }
        }
    }
    let mut items = Vec::new();
    let mut seq = 0usize;
    for spec in specs {
        for (label, cfg) in &spec.runs {
            let key = store::cache_key(cfg);
            let body = format!(
                "[item]\nseq = {seq}\nspec_id = \"{}\"\nlabel = \"{}\"\nkey = \"{key}\"\n\n{}",
                clean(&spec.id),
                clean(label),
                cfg.to_toml(),
            );
            store::write_atomic(&dir.join(format!("{seq:06}_{key}.toml")), body.as_bytes())?;
            if let Some(log) = store.event_log() {
                log.emit_labeled(
                    super::events::EventKind::Enqueued,
                    &key,
                    &clean(label),
                    None,
                    &[("seq", seq as f64), ("iterations", cfg.iterations as f64)],
                );
            }
            // Enqueue instants anchor per-run queue-wait in the trace
            // report: queue-wait = first `execute` start − `enqueue`.
            if let Some(tl) = store.trace_log() {
                tl.mark("enqueue", &key, &clean(&spec.id), None);
            }
            items.push(WorkItem {
                seq,
                spec_id: spec.id.clone(),
                label: label.clone(),
                key,
                cfg: cfg.clone(),
            });
            seq += 1;
        }
    }
    Ok(items)
}

/// The sorted item filenames currently in the queue — one `read_dir`, no
/// file contents. Workers poll this per pass to detect a queue
/// replacement cheaply and re-parse item files only when the name set
/// changes (names embed `seq` and the content-address, so a different
/// campaign always changes the set; an in-place edit of an item file
/// without renaming it is not detected until the set changes).
pub fn list_item_names(store: &RunStore) -> io::Result<Vec<String>> {
    let dir = queue_dir(store.root());
    let mut names = Vec::new();
    let entries = match fs::read_dir(&dir) {
        Ok(e) => e,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(names),
        Err(e) => return Err(e),
    };
    for entry in entries.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        if name.ends_with(".toml") {
            names.push(name);
        }
    }
    names.sort();
    Ok(names)
}

/// Read the queue back, sequence order. Unparseable item files are
/// reported and skipped — one hand-mangled file must not take the fleet
/// down.
pub fn load_queue(store: &RunStore) -> io::Result<Vec<WorkItem>> {
    load_queue_counted(store).map(|(items, _)| items)
}

/// [`load_queue`] plus the number of item files that were skipped as
/// unreadable (torn mid-write, hand-mangled, …). Status readers racing
/// a writer surface this as `unreadable: N` instead of a confusing
/// warning-only partial view.
pub fn load_queue_counted(store: &RunStore) -> io::Result<(Vec<WorkItem>, usize)> {
    let dir = queue_dir(store.root());
    let mut items = Vec::new();
    let mut unreadable = 0usize;
    let entries = match fs::read_dir(&dir) {
        Ok(e) => e,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok((items, 0)),
        Err(e) => return Err(e),
    };
    for entry in entries.flatten() {
        let path = entry.path();
        let name = entry.file_name().to_string_lossy().into_owned();
        if !name.ends_with(".toml") {
            continue;
        }
        match parse_item(&path) {
            Ok(item) => items.push(item),
            Err(e) => {
                unreadable += 1;
                eprintln!("warning: skipping queue item {}: {e}", path.display());
            }
        }
    }
    items.sort_by_key(|i| (i.seq, i.key.clone()));
    Ok((items, unreadable))
}

fn parse_item(path: &Path) -> Result<WorkItem, String> {
    let text = fs::read_to_string(path).map_err(|e| e.to_string())?;
    let doc = crate::config::schema::load_document(&text).map_err(|e| e.to_string())?;
    let section = doc.get("item").ok_or("missing [item] section")?;
    let get_str = |k: &str| -> Result<String, String> {
        section
            .get(k)
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .ok_or_else(|| format!("missing or non-string [item] key {k:?}"))
    };
    let seq = section
        .get("seq")
        .and_then(|v| v.as_usize())
        .ok_or("missing or non-integer [item] key \"seq\"")?;
    let cfg = RunConfig::from_toml(&text).map_err(|e| e.to_string())?;
    // A parseable but semantically invalid config (e.g. a hand-edited
    // `devices = 0`) would otherwise panic inside every worker's
    // `execute_run` — validate here so the item is skipped with a
    // warning like any other unreadable file.
    cfg.validate(crate::model::PARAM_DIM)
        .map_err(|e| format!("invalid run config: {e}"))?;
    // The config is authoritative for the address; a recorded key that
    // disagrees (hand-edited file) is corrected, not trusted.
    let key = store::cache_key(&cfg);
    if get_str("key")? != key {
        eprintln!(
            "warning: queue item {} records a stale key; using {key} derived from its config",
            path.display()
        );
    }
    Ok(WorkItem {
        seq,
        spec_id: get_str("spec_id")?,
        label: get_str("label")?,
        key,
        cfg,
    })
}

/// Rounds still to execute for an item, per the store's manifest.
pub fn remaining_rounds(store: &RunStore, item: &WorkItem) -> usize {
    let path = store.root().join(&item.key).join("manifest.toml");
    match RunManifest::read(&path) {
        Ok(m) if m.status == RunStatus::Complete => 0,
        Ok(m) => item.cfg.iterations.saturating_sub(m.snapshot_round),
        Err(_) => item.cfg.iterations,
    }
}

/// Order `subset` (indices into `items`) by the claim policy: shortest
/// remaining work first, enqueue sequence as the tie-breaker. The worker
/// loop passes only its pending tail so manifest reads scale with what is
/// left, not with the whole campaign.
pub fn order_by_remaining(
    items: &[WorkItem],
    subset: Vec<usize>,
    store: &RunStore,
) -> Vec<usize> {
    let mut order: Vec<(usize, usize)> = subset
        .into_iter()
        .map(|i| (remaining_rounds(store, &items[i]), i))
        .collect();
    order.sort_by_key(|&(remaining, i)| (remaining, items[i].seq, i));
    order.into_iter().map(|(_, i)| i).collect()
}

/// Indices of all of `items` in claim order (see [`order_by_remaining`]).
pub fn claim_order(items: &[WorkItem], store: &RunStore) -> Vec<usize> {
    order_by_remaining(items, (0..items.len()).collect(), store)
}

/// Regenerate every spec's output files from the store once the fleet has
/// drained the queue. Goes through [`runner::write_outputs`], the same
/// code path as single-process campaigns — which is what makes a fleet's
/// `summary.csv` and per-run CSVs byte-identical to them.
pub fn collect_outputs(
    store: &RunStore,
    specs: &[ExperimentSpec],
    out_dir: &str,
) -> Result<Vec<Vec<TrainLog>>, String> {
    let _sp = store.trace_log().map(|t| t.scope("collect", "", None));
    let mut all = Vec::new();
    for spec in specs {
        let logs: Vec<TrainLog> = spec
            .runs
            .iter()
            .map(|(label, cfg)| {
                store
                    .load_result(cfg)
                    .map(|mut log| {
                        log.label = label.clone();
                        log
                    })
                    .ok_or_else(|| {
                        format!("run `{label}` of spec `{}` has no cached result", spec.id)
                    })
            })
            .collect::<Result<_, String>>()?;
        runner::write_outputs(spec, &logs, out_dir);
        all.push(logs);
    }
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::TrainerSnapshot;
    use crate::config::{presets, CampaignConfig, Scheme};

    fn tmp_store(name: &str) -> (RunStore, PathBuf) {
        let dir = std::env::temp_dir().join(format!("ota_queue_{name}"));
        let _ = fs::remove_dir_all(&dir);
        let store = RunStore::open(dir.to_str().unwrap()).unwrap();
        (store, dir)
    }

    fn spec() -> ExperimentSpec {
        let mut cfg = presets::smoke();
        cfg.iterations = 8;
        ExperimentSpec {
            id: "tq".into(),
            title: "queue".into(),
            runs: vec![
                ("error-free".into(), RunConfig { scheme: Scheme::ErrorFree, ..cfg.clone() }),
                ("signsgd".into(), RunConfig { scheme: Scheme::SignSgd, ..cfg.clone() }),
                ("qsgd".into(), RunConfig { scheme: Scheme::Qsgd, ..cfg }),
            ],
        }
    }

    #[test]
    fn enqueue_load_round_trip() {
        let (store, dir) = tmp_store("roundtrip");
        let enqueued = enqueue_specs(&store, &[spec()]).unwrap();
        assert_eq!(enqueued.len(), 3);
        let loaded = load_queue(&store).unwrap();
        assert_eq!(loaded.len(), 3);
        for (a, b) in enqueued.iter().zip(&loaded) {
            assert_eq!(a.seq, b.seq);
            assert_eq!(a.spec_id, b.spec_id);
            assert_eq!(a.label, b.label);
            assert_eq!(a.key, b.key);
            assert_eq!(a.cfg, b.cfg, "config must round-trip exactly through the queue");
        }
        // Idempotent: re-enqueueing the same specs changes nothing — the
        // name set (the workers' cheap replacement probe) included.
        let names = list_item_names(&store).unwrap();
        assert_eq!(names.len(), 3);
        enqueue_specs(&store, &[spec()]).unwrap();
        assert_eq!(load_queue(&store).unwrap().len(), 3);
        assert_eq!(list_item_names(&store).unwrap(), names);
        let _ = fs::remove_dir_all(&dir);
    }

    /// Enqueueing a new campaign replaces the previous queue view — stale
    /// items from an abandoned campaign must not block the new one.
    #[test]
    fn enqueue_replaces_previous_campaign() {
        let (store, dir) = tmp_store("replace");
        enqueue_specs(&store, &[spec()]).unwrap();
        assert_eq!(load_queue(&store).unwrap().len(), 3);
        let mut cfg = presets::smoke();
        cfg.iterations = 5;
        let next = ExperimentSpec {
            id: "tq2".into(),
            title: "second campaign".into(),
            runs: vec![("error-free".into(), RunConfig { scheme: Scheme::ErrorFree, ..cfg })],
        };
        let before = list_item_names(&store).unwrap();
        enqueue_specs(&store, &[next]).unwrap();
        let items = load_queue(&store).unwrap();
        assert_eq!(items.len(), 1, "old campaign's items must be gone");
        assert_eq!(items[0].spec_id, "tq2");
        assert_ne!(
            list_item_names(&store).unwrap(),
            before,
            "a replacement must change the name set workers poll"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn claim_order_is_shortest_remaining_first() {
        let (store, dir) = tmp_store("order");
        let items = enqueue_specs(&store, &[spec()]).unwrap();
        // No store state: everything ties at full horizon → enqueue order.
        assert_eq!(claim_order(&items, &store), vec![0, 1, 2]);
        assert_eq!(remaining_rounds(&store, &items[0]), 8);

        // A partial snapshot at round 5 pulls item 1 to the front…
        let snap = TrainerSnapshot {
            config_hash: store::config_hash(&items[1].cfg),
            next_round: 5,
            params: vec![0.0; 4],
            optim_m: vec![0.0; 4],
            optim_v: vec![0.0; 4],
            optim_t: 5,
            link: vec![],
            records: vec![],
            final_accuracy: 0.0,
        };
        store.save_snapshot(&items[1].cfg, "signsgd", &snap).unwrap();
        assert_eq!(remaining_rounds(&store, &items[1]), 3);
        assert_eq!(claim_order(&items, &store), vec![1, 0, 2]);

        // …and a complete result sorts first of all (remaining 0).
        let log = TrainLog {
            label: "raw".into(),
            records: vec![],
            measured_avg_power: vec![],
            pbar: 500.0,
            final_accuracy: 0.5,
            total_secs: 1.0,
        };
        store.save_result(&items[2].cfg, "qsgd", &log).unwrap();
        assert_eq!(remaining_rounds(&store, &items[2]), 0);
        assert_eq!(claim_order(&items, &store), vec![2, 1, 0]);
        let _ = fs::remove_dir_all(&dir);
    }

    /// `collect_outputs` refuses to write figures from an undrained queue.
    #[test]
    fn collect_outputs_requires_complete_runs() {
        let (store, dir) = tmp_store("collect");
        let s = spec();
        enqueue_specs(&store, &[s]).unwrap();
        let out = dir.join("out");
        let err = collect_outputs(&store, &[spec()], out.to_str().unwrap()).unwrap_err();
        assert!(err.contains("no cached result"), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn campaign_defaults_still_apply() {
        // The queue lives inside the store dir the campaign config names;
        // nothing here invents a second location.
        let c = CampaignConfig::default();
        assert_eq!(
            queue_dir(Path::new(&c.store_dir_or("results"))),
            Path::new("results/.campaign/fleet/queue")
        );
    }
}
