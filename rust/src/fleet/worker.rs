//! The worker claim-execute loop: what `repro worker` runs, and what
//! `repro fleet` spawns N of.
//!
//! A worker knows nothing but the store directory. Each pass it loads the
//! queue, walks it in shortest-remaining-work-first order, and claims the
//! first incomplete run whose lease it can take. While a claimed run
//! executes, a sidecar thread heartbeats the lease every
//! `heartbeat_secs`, and the trainer's snapshot sink persists progress
//! every `snapshot_every` rounds — so when a worker is SIGKILL'd, its
//! lease goes stale, a surviving worker reclaims the run, and execution
//! resumes from the latest snapshot (bit-identical to never having
//! stopped; see `rust/tests/campaign_resume.rs`). The worker exits when
//! every queued run has a cached result.
//!
//! With `--follow` the worker becomes a **standing** worker: instead of
//! exiting on a drained (or empty) queue it keeps polling for items a
//! later campaign may enqueue, sleeping in short heartbeat-friendly
//! ticks between passes, and exits cleanly when its stop flag is set
//! (SIGTERM/SIGINT via [`install_stop_signals`], or an in-process
//! `AtomicBool` in tests).

use std::io;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use crate::campaign::scheduler;
use crate::campaign::RunStore;
use crate::config::{CampaignConfig, FleetConfig};

use super::events::{EventKind, EventLog};
use super::lease::{self, Lease};
use super::queue::{self, WorkItem};
use super::trace::{self, TraceLog};

/// What one worker did over its lifetime.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkerReport {
    /// Runs executed from round 0.
    pub executed: usize,
    /// Runs resumed from a snapshot (its own earlier progress or a dead
    /// worker's reclaimed run).
    pub resumed: usize,
    /// Claims that turned out to be already complete (a rival finished
    /// between the scan and the lease).
    pub already_done: usize,
}

/// Drain the store's queue. Returns when every item has a cached result.
/// `worker_id` appears in lease records and progress lines.
pub fn run_worker(
    store_dir: &str,
    fleet: &FleetConfig,
    campaign: &CampaignConfig,
    worker_id: &str,
    verbose: bool,
) -> io::Result<WorkerReport> {
    run_worker_ctl(store_dir, fleet, campaign, worker_id, verbose, false, None)
}

/// Install SIGTERM/SIGINT handlers that set (and return) a process-wide
/// stop flag, for `repro worker --follow`. The handler only stores an
/// `AtomicBool` (async-signal-safe); the worker loop notices it at the
/// next idle tick and exits cleanly. On non-unix targets the flag is
/// returned un-wired.
pub fn install_stop_signals() -> &'static AtomicBool {
    static STOP: AtomicBool = AtomicBool::new(false);
    #[cfg(unix)]
    {
        extern "C" fn on_signal(_signum: i32) {
            STOP.store(true, Ordering::Relaxed);
        }
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGTERM, on_signal as usize);
            signal(SIGINT, on_signal as usize);
        }
    }
    &STOP
}

/// Sleep `total` in short ticks, returning early when `stop` is set.
fn idle_sleep(total: Duration, stop: Option<&AtomicBool>) {
    let tick = Duration::from_millis(25).min(total);
    let mut slept = Duration::ZERO;
    while slept < total {
        if stop.is_some_and(|s| s.load(Ordering::Relaxed)) {
            return;
        }
        std::thread::sleep(tick);
        slept += tick;
    }
}

/// [`run_worker`] with lifecycle control: `follow` keeps the worker
/// standing after the queue drains (polling for a later campaign's
/// items), and `stop` — checked between claims and during idle sleeps,
/// never mid-run — requests a clean exit.
pub fn run_worker_ctl(
    store_dir: &str,
    fleet: &FleetConfig,
    campaign: &CampaignConfig,
    worker_id: &str,
    verbose: bool,
    follow: bool,
    stop: Option<&AtomicBool>,
) -> io::Result<WorkerReport> {
    fleet
        .validate()
        .unwrap_or_else(|e| panic!("invalid fleet config: {e}"));
    let store = RunStore::open(store_dir)?;
    // Telemetry: this worker appends to its own event segment; the store
    // attachment also routes scheduler + quarantine events through it.
    if campaign.telemetry.enabled {
        if let Ok(log) = EventLog::open(store.root(), worker_id) {
            store.attach_events(log);
        }
        // Tracing rides on telemetry: this worker's spans go to its own
        // segment under <store>/fleet/trace/, and the store attachment
        // routes scheduler spans (execute, snapshot_save, phases)
        // through the same writer.
        if campaign.telemetry.trace {
            if let Ok(log) = TraceLog::open(store.root(), worker_id) {
                store.attach_trace(log);
            }
        }
    }
    let events = store.event_log();
    let traces = store.trace_log();
    let mut report = WorkerReport::default();
    let ttl = Duration::from_secs_f64(fleet.lease_secs);
    let ldir = lease::lease_dir(store.root());
    // Poll cadence while every pending run is leased elsewhere: fast
    // enough to pick freed work up promptly, slow enough not to churn
    // the store.
    let poll = Duration::from_secs_f64(fleet.heartbeat_secs.clamp(0.05, 0.5));
    // Consecutive drained-but-unverifiable passes (a corrupt result blob
    // that cannot be quarantined); bounded so a read-only store cannot
    // spin the worker forever.
    let mut bad_drains = 0u32;
    // Consecutive passes that saw an empty queue: a single empty read may
    // be the delete-then-write window of a queue replacement in progress,
    // so only a *stable* empty queue ends the worker.
    let mut empty_passes = 0u32;
    // Parsed queue, cached on the item-name set: detecting a replacement
    // costs one read_dir per pass; item files are re-parsed only when the
    // set actually changes.
    let mut cached_names: Vec<String> = Vec::new();
    let mut items: Vec<queue::WorkItem> = Vec::new();
    // Follow mode: the queue generation (item-name set) whose drained
    // results were already decode-verified, so a standing worker does
    // not re-decode every result blob on every idle pass.
    let mut verified_names: Option<Vec<String>> = None;
    loop {
        if stop.is_some_and(|s| s.load(Ordering::Relaxed)) {
            println!("[{worker_id}] stop requested — exiting cleanly");
            break;
        }
        // `repro fleet` may *replace* the queue with a new campaign while
        // this worker is attached (`enqueue_specs` semantics) — an
        // attached worker must not keep grinding an abandoned campaign's
        // items, so re-check the name set every pass.
        let names = queue::list_item_names(&store)?;
        if names != cached_names {
            items = queue::load_queue(&store)?;
            cached_names = names;
        }
        if items.is_empty() {
            if follow {
                // A standing worker outlives campaigns: an empty queue
                // just means the next one has not been enqueued yet.
                idle_sleep(poll, stop);
                continue;
            }
            empty_passes += 1;
            if empty_passes > 3 {
                println!("[{worker_id}] queue at {store_dir} is empty — nothing to do");
                break;
            }
            std::thread::sleep(poll);
            continue;
        }
        empty_passes = 0;
        // Cheap scan: one stat per item. Manifests are read only for the
        // pending tail below, and result blobs are never decoded here —
        // this runs on every pass including 0.5s idle polls.
        let pending: Vec<usize> = (0..items.len())
            .filter(|&i| !store.has_result(&items[i].cfg))
            .collect();
        if pending.is_empty() {
            if follow && verified_names.as_ref() == Some(&cached_names) {
                // This campaign already drained and verified; wait for
                // the next one without re-decoding its results.
                idle_sleep(poll, stop);
                continue;
            }
            // A stat cannot see corruption. Before declaring the queue
            // drained, verify every result decodes: a corrupt blob is
            // quarantined by `load_result` (reads as a miss), the next
            // pass recomputes it, and the campaign completes — instead of
            // aborting downstream in `collect_outputs`.
            if items.iter().all(|item| store.load_result(&item.cfg).is_some()) {
                if follow {
                    verified_names = Some(cached_names.clone());
                    println!(
                        "[{worker_id}] queue drained — standing by for the next campaign"
                    );
                    idle_sleep(poll, stop);
                    continue;
                }
                break;
            }
            bad_drains += 1;
            if bad_drains > 5 {
                return Err(io::Error::new(
                    io::ErrorKind::Other,
                    "a corrupt result blob could not be quarantined for recompute \
                     (store read-only?) — aborting",
                ));
            }
            std::thread::sleep(poll);
            continue;
        }
        bad_drains = 0;
        // Shortest-remaining-work-first over the pending tail (manifest
        // reads scale with what is left, not with the whole campaign).
        // The whole scan-and-acquire pass is one `claim_scan` span; a
        // successful acquisition additionally gets a `lease_acquire`
        // span carrying the run key and campaign id.
        let mut claimed: Option<(usize, Lease)> = None;
        {
            let _scan = traces.as_ref().map(|t| t.scope("claim_scan", "", None));
            for idx in queue::order_by_remaining(&items, pending, &store) {
                let key = items[idx].key.clone();
                let mut on_reclaim = || {
                    if let Some(ev) = &events {
                        ev.emit(EventKind::Reclaimed, &key, None, &[]);
                    }
                };
                let acquire_started = (std::time::Instant::now(), trace::unix_us_now());
                if let Some(l) = lease::try_acquire_with(
                    &ldir,
                    &items[idx].key,
                    worker_id,
                    ttl,
                    &mut on_reclaim,
                )? {
                    if let Some(ev) = &events {
                        ev.emit(EventKind::Claimed, &items[idx].key, None, &[]);
                    }
                    if let Some(t) = &traces {
                        t.emit(
                            "lease_acquire",
                            &items[idx].key,
                            &items[idx].spec_id,
                            None,
                            acquire_started.1,
                            acquire_started.0.elapsed().as_micros() as u64,
                        );
                    }
                    claimed = Some((idx, l));
                    break;
                }
            }
        }
        match claimed {
            Some((idx, l)) => {
                let outcome = execute_item(
                    &store, &items[idx], fleet, campaign, &l, worker_id, verbose, &mut report,
                );
                l.release();
                outcome?;
            }
            // Everything pending is leased by live rivals — wait for
            // either a result to land or a lease to expire.
            None => idle_sleep(poll, stop),
        }
    }
    Ok(report)
}

/// Execute one claimed run under a heartbeating lease. Errors when the
/// run executed but its result did not land in the store — retrying would
/// re-execute the identical run forever (disk full, store unwritable), so
/// the worker aborts loudly instead.
#[allow(clippy::too_many_arguments)]
fn execute_item(
    store: &RunStore,
    item: &WorkItem,
    fleet: &FleetConfig,
    campaign: &CampaignConfig,
    l: &Lease,
    worker_id: &str,
    verbose: bool,
    report: &mut WorkerReport,
) -> io::Result<()> {
    // Between the scan and the lease a rival may have finished the run.
    if store.load_result(&item.cfg).is_some() {
        report.already_done += 1;
        if let Some(ev) = store.event_log() {
            ev.emit(EventKind::AlreadyDone, &item.key, None, &[]);
        }
        return Ok(());
    }
    let traces = store.trace_log();
    let resume = {
        let _sp = traces.as_ref().map(|t| t.scope("snapshot_load", &item.key, None));
        store
            .load_best_snapshot(&item.cfg)
            .filter(|snap| scheduler::snapshot_restorable(&item.cfg, snap))
    };
    match &resume {
        Some(snap) => {
            report.resumed += 1;
            println!(
                "[{worker_id}] resuming `{}` ({}/{}) at round {}/{}",
                item.label, item.spec_id, item.key, snap.next_round, item.cfg.iterations
            );
        }
        None => {
            report.executed += 1;
            println!(
                "[{worker_id}] executing `{}` ({}/{}) from round 0",
                item.label, item.spec_id, item.key
            );
        }
    }
    let stop = AtomicBool::new(false);
    // Set the stop flag even if the trainer panics: without this the
    // heartbeat thread would spin forever and `thread::scope` would never
    // join — a deadlocked worker whose *still-refreshing* lease blocks the
    // whole fleet from ever reclaiming the run.
    struct StopGuard<'a>(&'a AtomicBool);
    impl Drop for StopGuard<'_> {
        fn drop(&mut self) {
            self.0.store(true, Ordering::Relaxed);
        }
    }
    let events = store.event_log();
    std::thread::scope(|scope| {
        scope.spawn(|| {
            let tick = Duration::from_millis(25);
            let interval = Duration::from_secs_f64(fleet.heartbeat_secs);
            let mut since_beat = Duration::ZERO;
            let mut lost_logged = false;
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(tick);
                since_beat += tick;
                if since_beat >= interval {
                    since_beat = Duration::ZERO;
                    let beat_started = (std::time::Instant::now(), trace::unix_us_now());
                    match l.heartbeat() {
                        Ok(true) => {
                            if let Some(ev) = &events {
                                ev.emit(EventKind::Heartbeat, &item.key, None, &[]);
                            }
                            if let Some(t) = &traces {
                                t.emit(
                                    "heartbeat",
                                    &item.key,
                                    "",
                                    None,
                                    beat_started.1,
                                    beat_started.0.elapsed().as_micros() as u64,
                                );
                            }
                        }
                        // Lease lost (we stalled past the TTL) or the
                        // refresh failed: finish the run anyway — the
                        // result is deterministic and its write atomic,
                        // so a duplicated finish is byte-identical.
                        Ok(false) | Err(_) => {
                            if !lost_logged {
                                lost_logged = true;
                                eprintln!(
                                    "[{worker_id}] warning: lease for `{}` was reclaimed; \
                                     finishing the run anyway (writes are idempotent)",
                                    item.label
                                );
                            }
                        }
                    }
                }
            }
        });
        let _stop_on_exit = StopGuard(&stop);
        scheduler::execute_run(store, &item.label, &item.cfg, resume.as_ref(), campaign, verbose);
    });
    // execute_run only warns when the result write fails; for the worker
    // loop that would mean claim → execute → miss → claim again, forever.
    if store.load_result(&item.cfg).is_none() {
        return Err(io::Error::new(
            io::ErrorKind::Other,
            format!(
                "run `{}` executed but its result did not land in store entry {} \
                 (disk full or store unwritable?) — aborting instead of re-executing forever",
                item.label, item.key
            ),
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, RunConfig, Scheme};
    use crate::experiments::runner::ExperimentSpec;

    /// One in-process worker drains a three-run queue; a second worker
    /// finds nothing to do.
    #[test]
    fn worker_drains_queue_then_idles() {
        let base = std::env::temp_dir().join("ota_worker_drain_test");
        let _ = std::fs::remove_dir_all(&base);
        let store_dir = base.join("store").to_str().unwrap().to_string();
        let store = RunStore::open(&store_dir).unwrap();
        let mut cfg = presets::smoke();
        cfg.iterations = 3;
        cfg.eval_every = 1;
        let spec = ExperimentSpec {
            id: "tw".into(),
            title: "worker drain".into(),
            runs: vec![
                ("error-free".into(), RunConfig { scheme: Scheme::ErrorFree, ..cfg.clone() }),
                ("signsgd".into(), RunConfig { scheme: Scheme::SignSgd, ..cfg }),
            ],
        };
        queue::enqueue_specs(&store, &[spec]).unwrap();
        let fleet = FleetConfig::default();
        let campaign = CampaignConfig {
            snapshot_every: 1,
            store_dir: store_dir.clone(),
            ..CampaignConfig::default()
        };
        let report = run_worker(&store_dir, &fleet, &campaign, "w0", false).unwrap();
        assert_eq!(report.executed, 2);
        assert_eq!(report.resumed, 0);
        // Every item now has a result; a late-attached worker exits clean.
        let report2 = run_worker(&store_dir, &fleet, &campaign, "w1", false).unwrap();
        assert_eq!(report2, WorkerReport::default());
        let _ = std::fs::remove_dir_all(&base);
    }

    /// A `--follow` worker outlives the drain, picks up a campaign
    /// enqueued *after* it went idle, and exits when its stop flag is
    /// set.
    #[test]
    fn follow_worker_picks_up_later_campaign_and_stops() {
        let base = std::env::temp_dir().join("ota_worker_follow_test");
        let _ = std::fs::remove_dir_all(&base);
        let store_dir = base.join("store").to_str().unwrap().to_string();
        let store = RunStore::open(&store_dir).unwrap();
        let mut cfg = presets::smoke();
        cfg.iterations = 2;
        cfg.eval_every = 1;
        let spec = |id: &str, scheme: Scheme| ExperimentSpec {
            id: id.into(),
            title: id.into(),
            runs: vec![(id.into(), RunConfig { scheme, ..cfg.clone() })],
        };
        queue::enqueue_specs(&store, &[spec("tf1", Scheme::ErrorFree)]).unwrap();
        let fleet = FleetConfig::default();
        let campaign = CampaignConfig {
            snapshot_every: 1,
            store_dir: store_dir.clone(),
            ..CampaignConfig::default()
        };
        let stop = AtomicBool::new(false);
        let report = std::thread::scope(|scope| {
            let handle = scope.spawn(|| {
                run_worker_ctl(&store_dir, &fleet, &campaign, "wf", false, true, Some(&stop))
            });
            // First campaign drains; the standing worker must still be
            // alive to claim the second one.
            let second = spec("tf2", Scheme::SignSgd);
            let deadline = std::time::Instant::now() + Duration::from_secs(60);
            let mut enqueued = false;
            loop {
                let drained_first = queue::load_queue(&store)
                    .map(|items| !items.is_empty() && items.iter().all(|i| store.has_result(&i.cfg)))
                    .unwrap_or(false);
                if drained_first && !enqueued {
                    queue::enqueue_specs(&store, std::slice::from_ref(&second)).unwrap();
                    enqueued = true;
                }
                if enqueued && store.has_result(&second.runs[0].1) {
                    break;
                }
                assert!(std::time::Instant::now() < deadline, "follow worker stalled");
                std::thread::sleep(Duration::from_millis(25));
            }
            stop.store(true, Ordering::Relaxed);
            handle.join().unwrap().unwrap()
        });
        // One run per campaign, both executed by the same standing worker.
        assert_eq!(report.executed, 2);
        let _ = std::fs::remove_dir_all(&base);
    }
}
