//! Deterministic replay reducer: fold the event log into
//! Prometheus-style counters and gauges.
//!
//! [`reduce`] is a pure fold over [`super::events::Event`]s built
//! entirely from commutative, deduplicating operations — key sets for
//! run lifecycle, `(key, round)` sets for training progress, and
//! latest-round gauges. That makes the **deterministic core**
//! ([`Metrics::deterministic_core`]) independent of event order,
//! worker count, and wall clock: a 1-worker and a 4-worker fleet over
//! the same campaign reduce to the same core (the contract pinned by
//! `rust/tests/fleet_events.rs`).
//!
//! Everything describing the *fleet* rather than the *campaign* —
//! per-worker claim/heartbeat/round counts and rounds/sec, lease
//! reclaims, claim races, skipped log lines — is kept in an
//! operational section that is exported by [`Metrics::to_prometheus`]
//! but excluded from the core.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;

use super::events::{Event, EventKind, ReadReport, TailReport};

/// Per-run telemetry folded from `round` / `completed` / `enqueued`.
#[derive(Clone, Debug, Default)]
pub struct RunSeries {
    /// Human label, if an `enqueued` event carried one.
    pub label: String,
    /// Total planned rounds (`iterations` payload on `enqueued`).
    pub planned_rounds: Option<u64>,
    /// Deduplicated set of trained rounds.
    pub rounds: BTreeSet<u64>,
    /// grad-norm by round (first write wins; identical by determinism).
    pub grad_norm: BTreeMap<u64, f64>,
    /// training loss by round (feeds the diverging-loss health check).
    pub train_loss: BTreeMap<u64, f64>,
    /// test accuracy by round (only rounds that evaluated).
    pub accuracy: BTreeMap<u64, f64>,
    /// Final accuracy from `completed`.
    pub final_accuracy: Option<f64>,
    /// Eq. 6 power-audit headroom from `completed`:
    /// `1 - max_avg_power / pbar` (fraction of budget left unused).
    pub power_headroom: Option<f64>,
    // --- link diagnostics (absent unless probes were enabled) ---------
    /// Effective receive SNR (dB) by round.
    pub snr_db: BTreeMap<u64, f64>,
    /// Per-round Eq. 6 headroom gauge `P_t − max‖x_m‖²` from the link
    /// probe (absolute energy units, unlike the completed-run audit).
    pub link_headroom: BTreeMap<u64, f64>,
    /// Devices that actually transmitted, by round.
    pub participating: BTreeMap<u64, f64>,
    /// RMS consensus distance by round (decentralized runs only).
    pub consensus: BTreeMap<u64, f64>,
    /// Deduplicated `(round, device)` diagnostics points seen.
    pub device_points: BTreeSet<(u64, u64)>,
}

impl RunSeries {
    /// Latest `(round, grad_norm)` gauge.
    pub fn last_grad_norm(&self) -> Option<(u64, f64)> {
        self.grad_norm.iter().next_back().map(|(&r, &v)| (r, v))
    }

    /// Latest `(round, accuracy)` gauge.
    pub fn last_accuracy(&self) -> Option<(u64, f64)> {
        self.accuracy.iter().next_back().map(|(&r, &v)| (r, v))
    }

    /// Latest `(round, train loss)` gauge.
    pub fn last_train_loss(&self) -> Option<(u64, f64)> {
        Self::last_of(&self.train_loss)
    }

    /// Latest `(round, value)` of a per-round link series.
    fn last_of(series: &BTreeMap<u64, f64>) -> Option<(u64, f64)> {
        series.iter().next_back().map(|(&r, &v)| (r, v))
    }

    /// Latest `(round, SNR dB)` gauge.
    pub fn last_snr_db(&self) -> Option<(u64, f64)> {
        Self::last_of(&self.snr_db)
    }

    /// Latest `(round, headroom)` gauge from the link probe.
    pub fn last_link_headroom(&self) -> Option<(u64, f64)> {
        Self::last_of(&self.link_headroom)
    }

    /// Latest `(round, transmitting-device count)` gauge.
    pub fn last_participating(&self) -> Option<(u64, f64)> {
        Self::last_of(&self.participating)
    }

    /// Latest `(round, consensus distance)` gauge.
    pub fn last_consensus(&self) -> Option<(u64, f64)> {
        Self::last_of(&self.consensus)
    }

    /// Completed fraction in `[0, 1]`, when the plan is known.
    pub fn progress(&self) -> Option<f64> {
        let planned = self.planned_rounds?;
        if planned == 0 {
            return None;
        }
        Some(self.rounds.len() as f64 / planned as f64)
    }
}

/// Per-worker operational stats (excluded from the deterministic core).
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub claims: u64,
    pub heartbeats: u64,
    /// Round events emitted by this worker (duplicates included — it
    /// measures work done, not campaign progress).
    pub rounds: u64,
    pub reclaims: u64,
    first_ms: Option<u64>,
    last_ms: Option<u64>,
}

impl WorkerStats {
    fn observe_ms(&mut self, ms: u64) {
        if ms == 0 {
            return; // masked or clock-less — leave rates undefined
        }
        self.first_ms = Some(self.first_ms.map_or(ms, |f| f.min(ms)));
        self.last_ms = Some(self.last_ms.map_or(ms, |l| l.max(ms)));
    }

    /// Observed throughput over this worker's active window; `0.0`
    /// when the window is empty or wall clocks were masked.
    pub fn rounds_per_sec(&self) -> f64 {
        match (self.first_ms, self.last_ms) {
            (Some(first), Some(last)) if last > first => {
                self.rounds as f64 / ((last - first) as f64 / 1000.0)
            }
            _ => 0.0,
        }
    }
}

/// The folded metrics. See the module docs for the core/operational
/// split.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    // --- deterministic core -------------------------------------------
    /// Runs ever enqueued (by cache key).
    pub enqueued: BTreeSet<String>,
    /// Runs started from round 0.
    pub executed: BTreeSet<String>,
    /// Runs resumed from a snapshot.
    pub resumed: BTreeSet<String>,
    /// Runs served from the run cache.
    pub cached: BTreeSet<String>,
    /// Runs whose result was persisted.
    pub completed: BTreeSet<String>,
    /// Store entries that quarantined a corrupt blob.
    pub quarantined: BTreeSet<String>,
    /// Per-run training telemetry.
    pub runs: BTreeMap<String, RunSeries>,
    // --- operational (fleet-shape dependent) --------------------------
    /// Stale-lease steals (exactly one event per steal).
    pub reclaims: u64,
    /// Reclaims per run key — repeated steals of one key are the
    /// lease-churn health signal (see [`super::health`]).
    pub reclaims_by_key: BTreeMap<String, u64>,
    /// Claim races that found the result already landed.
    pub already_done: u64,
    /// Snapshot events (resumes re-snapshot, so this may exceed the
    /// per-run snapshot cadence).
    pub snapshots: u64,
    /// Total heartbeat events.
    pub heartbeats: u64,
    /// Per-worker stats.
    pub workers: BTreeMap<String, WorkerStats>,
    /// Log lines skipped by the reader (torn tails, parse failures).
    pub skipped_lines: usize,
    /// Log segment files that could not be read.
    pub unreadable_files: usize,
    /// Total events folded.
    pub events_total: u64,
}

impl Metrics {
    /// Enqueued-but-never-completed runs across the log's history.
    pub fn queue_depth(&self) -> usize {
        self.enqueued.difference(&self.completed).count()
    }

    /// Deduplicated `(run, round)` count across the campaign.
    pub fn rounds_total(&self) -> u64 {
        self.runs.values().map(|r| r.rounds.len() as u64).sum()
    }

    /// Canonical rendering of everything that must replay identically
    /// across fleet shapes. Float gauges are rendered as exact bit
    /// patterns so "identical" means bit-identical, not approximately
    /// equal. Worker stats, reclaim/race counts, and reader-skip
    /// counts are deliberately absent.
    pub fn deterministic_core(&self) -> String {
        let mut s = String::new();
        let keyset = |s: &mut String, name: &str, set: &BTreeSet<String>| {
            let _ = writeln!(
                s,
                "{name}=[{}]",
                set.iter().cloned().collect::<Vec<_>>().join(",")
            );
        };
        keyset(&mut s, "enqueued", &self.enqueued);
        keyset(&mut s, "executed", &self.executed);
        keyset(&mut s, "resumed", &self.resumed);
        keyset(&mut s, "cached", &self.cached);
        keyset(&mut s, "completed", &self.completed);
        keyset(&mut s, "quarantined", &self.quarantined);
        let _ = writeln!(s, "queue_depth={}", self.queue_depth());
        for (key, run) in &self.runs {
            let bits = |v: Option<f64>| match v {
                Some(v) => format!("{:016x}", v.to_bits()),
                None => "-".to_string(),
            };
            let _ = writeln!(
                s,
                "run[{key}] label={} planned={} rounds={} grad_last={} loss_last={} acc_last={} final_acc={} headroom={} snr_last={} link_headroom_last={} participating_last={} consensus_last={} device_points={}",
                run.label,
                run.planned_rounds.map_or("-".into(), |p| p.to_string()),
                run.rounds.len(),
                bits(run.last_grad_norm().map(|(_, v)| v)),
                bits(run.last_train_loss().map(|(_, v)| v)),
                bits(run.last_accuracy().map(|(_, v)| v)),
                bits(run.final_accuracy),
                bits(run.power_headroom),
                bits(run.last_snr_db().map(|(_, v)| v)),
                bits(run.last_link_headroom().map(|(_, v)| v)),
                bits(run.last_participating().map(|(_, v)| v)),
                bits(run.last_consensus().map(|(_, v)| v)),
                run.device_points.len(),
            );
        }
        s
    }

    /// Prometheus text exposition (the `repro metrics` output).
    pub fn to_prometheus(&self) -> String {
        let mut s = String::new();
        let mut counter = |name: &str, help: &str, v: f64| {
            let _ = writeln!(s, "# HELP {name} {help}");
            let _ = writeln!(s, "# TYPE {name} counter");
            let _ = writeln!(s, "{name} {v}");
        };
        counter("ota_events_total", "Events folded from the store log.", self.events_total as f64);
        counter(
            "ota_queue_enqueued_total",
            "Distinct runs ever enqueued.",
            self.enqueued.len() as f64,
        );
        counter(
            "ota_runs_executed_total",
            "Distinct runs started from round 0.",
            self.executed.len() as f64,
        );
        counter(
            "ota_runs_resumed_total",
            "Distinct runs resumed from a snapshot.",
            self.resumed.len() as f64,
        );
        counter(
            "ota_runs_cached_total",
            "Distinct runs served from the run cache.",
            self.cached.len() as f64,
        );
        counter(
            "ota_runs_completed_total",
            "Distinct runs whose result was persisted.",
            self.completed.len() as f64,
        );
        counter(
            "ota_runs_quarantined_total",
            "Store entries that quarantined a corrupt blob.",
            self.quarantined.len() as f64,
        );
        counter(
            "ota_rounds_total",
            "Deduplicated (run, round) pairs trained.",
            self.rounds_total() as f64,
        );
        counter(
            "ota_lease_reclaims_total",
            "Stale leases stolen from dead owners.",
            self.reclaims as f64,
        );
        counter(
            "ota_claim_races_total",
            "Claims that found the result already landed.",
            self.already_done as f64,
        );
        counter("ota_snapshots_total", "Snapshots persisted.", self.snapshots as f64);
        counter("ota_heartbeats_total", "Lease heartbeats.", self.heartbeats as f64);
        counter(
            "ota_log_skipped_lines",
            "Event-log lines skipped by the reader (torn/unparseable).",
            self.skipped_lines as f64,
        );
        counter(
            "ota_log_unreadable_files",
            "Event-log segment files the reader could not open.",
            self.unreadable_files as f64,
        );
        let _ = writeln!(s, "# HELP ota_queue_depth Enqueued runs not yet completed.");
        let _ = writeln!(s, "# TYPE ota_queue_depth gauge");
        let _ = writeln!(s, "ota_queue_depth {}", self.queue_depth());

        if !self.workers.is_empty() {
            let _ = writeln!(s, "# HELP ota_worker_claims_total Lease claims per worker.");
            let _ = writeln!(s, "# TYPE ota_worker_claims_total counter");
            for (w, st) in &self.workers {
                let _ = writeln!(s, "ota_worker_claims_total{{worker=\"{w}\"}} {}", st.claims);
            }
            let _ = writeln!(s, "# HELP ota_worker_rounds_total Rounds processed per worker.");
            let _ = writeln!(s, "# TYPE ota_worker_rounds_total counter");
            for (w, st) in &self.workers {
                let _ = writeln!(s, "ota_worker_rounds_total{{worker=\"{w}\"}} {}", st.rounds);
            }
            let _ = writeln!(
                s,
                "# HELP ota_worker_rounds_per_sec Observed rounds/sec over the worker's active window."
            );
            let _ = writeln!(s, "# TYPE ota_worker_rounds_per_sec gauge");
            for (w, st) in &self.workers {
                let _ = writeln!(
                    s,
                    "ota_worker_rounds_per_sec{{worker=\"{w}\"}} {:.3}",
                    st.rounds_per_sec()
                );
            }
        }

        if !self.runs.is_empty() {
            let _ = writeln!(s, "# HELP ota_run_rounds_total Deduplicated rounds per run.");
            let _ = writeln!(s, "# TYPE ota_run_rounds_total counter");
            for (k, run) in &self.runs {
                let _ = writeln!(s, "ota_run_rounds_total{{key=\"{k}\"}} {}", run.rounds.len());
            }
            let _ = writeln!(s, "# HELP ota_run_last_grad_norm Latest gradient norm per run.");
            let _ = writeln!(s, "# TYPE ota_run_last_grad_norm gauge");
            for (k, run) in &self.runs {
                if let Some((_, v)) = run.last_grad_norm() {
                    let _ = writeln!(s, "ota_run_last_grad_norm{{key=\"{k}\"}} {v}");
                }
            }
            let _ = writeln!(s, "# HELP ota_run_last_accuracy Latest test accuracy per run.");
            let _ = writeln!(s, "# TYPE ota_run_last_accuracy gauge");
            for (k, run) in &self.runs {
                if let Some((_, v)) = run.last_accuracy() {
                    let _ = writeln!(s, "ota_run_last_accuracy{{key=\"{k}\"}} {v}");
                }
            }
            let _ = writeln!(
                s,
                "# HELP ota_run_power_headroom Eq. 6 audit headroom (1 - max avg power / pbar)."
            );
            let _ = writeln!(s, "# TYPE ota_run_power_headroom gauge");
            for (k, run) in &self.runs {
                if let Some(h) = run.power_headroom {
                    let _ = writeln!(s, "ota_run_power_headroom{{key=\"{k}\"}} {h}");
                }
            }
        }

        // Link diagnostics: only rendered when at least one run carried
        // probe payloads, so probe-less stores export byte-identical
        // text to pre-diagnostics builds.
        let has_link = self.runs.values().any(|r| {
            !r.snr_db.is_empty()
                || !r.link_headroom.is_empty()
                || !r.participating.is_empty()
                || !r.consensus.is_empty()
                || !r.device_points.is_empty()
        });
        if has_link {
            let gauge = |s: &mut String, name: &str, help: &str, f: &dyn Fn(&RunSeries) -> Option<f64>| {
                let _ = writeln!(s, "# HELP {name} {help}");
                let _ = writeln!(s, "# TYPE {name} gauge");
                for (k, run) in &self.runs {
                    if let Some(v) = f(run) {
                        let _ = writeln!(s, "{name}{{key=\"{k}\"}} {v}");
                    }
                }
            };
            gauge(
                &mut s,
                "ota_link_last_snr_db",
                "Latest effective receive SNR per run (dB).",
                &|r| r.last_snr_db().map(|(_, v)| v),
            );
            gauge(
                &mut s,
                "ota_link_power_headroom",
                "Latest per-round Eq. 6 headroom P_t - max tx energy.",
                &|r| r.last_link_headroom().map(|(_, v)| v),
            );
            gauge(
                &mut s,
                "ota_link_participating",
                "Latest transmitting-device count per run.",
                &|r| r.last_participating().map(|(_, v)| v),
            );
            gauge(
                &mut s,
                "ota_link_consensus_distance",
                "Latest RMS replica disagreement per run (D2D).",
                &|r| r.last_consensus().map(|(_, v)| v),
            );
            let _ = writeln!(
                s,
                "# HELP ota_link_device_events_total Deduplicated (round, device) diagnostics points."
            );
            let _ = writeln!(s, "# TYPE ota_link_device_events_total counter");
            for (k, run) in &self.runs {
                if !run.device_points.is_empty() {
                    let _ = writeln!(
                        s,
                        "ota_link_device_events_total{{key=\"{k}\"}} {}",
                        run.device_points.len()
                    );
                }
            }
            // Fixed-bucket SNR histogram over every probed round.
            const SNR_BUCKETS: [f64; 9] = [-10.0, -5.0, 0.0, 5.0, 10.0, 15.0, 20.0, 25.0, 30.0];
            let _ = writeln!(
                s,
                "# HELP ota_link_snr_db SNR distribution across probed rounds (dB)."
            );
            let _ = writeln!(s, "# TYPE ota_link_snr_db histogram");
            for (k, run) in &self.runs {
                if run.snr_db.is_empty() {
                    continue;
                }
                let mut sum = 0.0f64;
                for le in SNR_BUCKETS {
                    let n = run.snr_db.values().filter(|&&v| v <= le).count();
                    let _ = writeln!(s, "ota_link_snr_db_bucket{{key=\"{k}\",le=\"{le}\"}} {n}");
                }
                let _ = writeln!(
                    s,
                    "ota_link_snr_db_bucket{{key=\"{k}\",le=\"+Inf\"}} {}",
                    run.snr_db.len()
                );
                for v in run.snr_db.values() {
                    sum += v;
                }
                let _ = writeln!(s, "ota_link_snr_db_sum{{key=\"{k}\"}} {sum}");
                let _ = writeln!(s, "ota_link_snr_db_count{{key=\"{k}\"}} {}", run.snr_db.len());
            }
        }
        // Health: the deterministic findings catalog is a pure function
        // of `self`, so embedding it here keeps every rendering path —
        // local CLI, telemetry server, remote client — byte-identical.
        s.push_str(&super::health::render_prometheus(&super::health::evaluate(
            self,
            &super::health::HealthPolicy::default(),
        )));
        s
    }
}

/// Fold events into [`Metrics`]. Order-insensitive by construction,
/// and literally the from-empty special case of [`Reducer`] — batch
/// and incremental reduction share one fold, so they cannot drift.
pub fn reduce(events: &[Event]) -> Metrics {
    let mut r = Reducer::default();
    r.fold(events);
    r.into_metrics()
}

/// Incremental reducer: the same pure fold as [`reduce`], kept alive
/// across reads so a dashboard frame or a telemetry server only folds
/// the bytes appended since the last poll ([`TailReport`]s from
/// [`super::events::read_events_from`]).
///
/// Skip accounting is two-tier, mirroring the tail reader: garbage
/// lines *consumed* by some read are gone forever and accumulate,
/// while torn tails and unreadable segments are point-in-time
/// observations refreshed by each read. [`Reducer::metrics`] renders
/// `skipped_lines = consumed + pending`, which makes the incremental
/// view byte-identical to [`reduce_report`] over a from-scratch batch
/// read of the same log.
#[derive(Clone, Debug, Default)]
pub struct Reducer {
    m: Metrics,
    /// Garbage lines permanently consumed across the cursor chain.
    consumed_skipped: usize,
    /// Latest read's torn-tail count (point-in-time).
    pending_tails: usize,
    /// Latest read's unreadable-segment count (point-in-time).
    unreadable_files: usize,
}

impl Reducer {
    /// Fold a batch of events into the running state.
    pub fn fold(&mut self, events: &[Event]) {
        for ev in events {
            fold_event(&mut self.m, ev);
        }
    }

    /// Fold one incremental read: its events plus its skip accounting.
    pub fn absorb_tail(&mut self, tail: &TailReport) {
        self.absorb(
            &tail.events,
            tail.consumed_skipped,
            tail.pending_tails,
            tail.unreadable_files,
        );
    }

    /// [`Reducer::absorb_tail`] with the accounting passed explicitly —
    /// the remote client path, where the counts arrive as response
    /// headers rather than a local [`TailReport`].
    pub fn absorb(
        &mut self,
        events: &[Event],
        consumed_skipped: usize,
        pending_tails: usize,
        unreadable_files: usize,
    ) {
        self.fold(events);
        self.consumed_skipped += consumed_skipped;
        self.pending_tails = pending_tails;
        self.unreadable_files = unreadable_files;
    }

    /// The current metrics view (cloned; reducers outlive frames).
    pub fn metrics(&self) -> Metrics {
        let mut m = self.m.clone();
        m.skipped_lines = self.consumed_skipped + self.pending_tails;
        m.unreadable_files = self.unreadable_files;
        m
    }

    /// Consume the reducer (the batch [`reduce`] path).
    fn into_metrics(mut self) -> Metrics {
        self.m.skipped_lines = self.consumed_skipped + self.pending_tails;
        self.m.unreadable_files = self.unreadable_files;
        self.m
    }
}

/// Fold one event — the single definition both [`reduce`] and
/// [`Reducer`] replay.
fn fold_event(m: &mut Metrics, ev: &Event) {
    {
        m.events_total += 1;
        let worker = || ev.worker.clone();
        match ev.kind {
            EventKind::Enqueued => {
                m.enqueued.insert(ev.key.clone());
                let run = m.runs.entry(ev.key.clone()).or_default();
                if run.label.is_empty() && !ev.label.is_empty() {
                    run.label = ev.label.clone();
                }
                if let Some(planned) = ev.field("iterations") {
                    run.planned_rounds = Some(planned as u64);
                }
            }
            EventKind::Claimed => {
                let st = m.workers.entry(worker()).or_default();
                st.claims += 1;
                st.observe_ms(ev.unix_ms);
            }
            EventKind::Reclaimed => {
                m.reclaims += 1;
                if !ev.key.is_empty() {
                    *m.reclaims_by_key.entry(ev.key.clone()).or_default() += 1;
                }
                m.workers.entry(worker()).or_default().reclaims += 1;
            }
            EventKind::Heartbeat => {
                m.heartbeats += 1;
                let st = m.workers.entry(worker()).or_default();
                st.heartbeats += 1;
                st.observe_ms(ev.unix_ms);
            }
            EventKind::Executed => {
                m.executed.insert(ev.key.clone());
            }
            EventKind::Resumed => {
                m.resumed.insert(ev.key.clone());
            }
            EventKind::Cached => {
                m.cached.insert(ev.key.clone());
            }
            EventKind::AlreadyDone => m.already_done += 1,
            EventKind::Snapshot => m.snapshots += 1,
            EventKind::Device => {
                // One transmitter's diagnostics: deduplicated on
                // (round, device) like everything else in the core.
                let (Some(round), Some(dev)) = (ev.round, ev.field("device")) else {
                    return;
                };
                let run = m.runs.entry(ev.key.clone()).or_default();
                run.device_points.insert((round, dev as u64));
            }
            EventKind::Round => {
                let Some(round) = ev.round else { return };
                let run = m.runs.entry(ev.key.clone()).or_default();
                run.rounds.insert(round);
                if let Some(g) = ev.field("grad_norm") {
                    run.grad_norm.entry(round).or_insert(g);
                }
                if let Some(l) = ev.field("train_loss") {
                    run.train_loss.entry(round).or_insert(l);
                }
                if let Some(a) = ev.field("test_accuracy") {
                    run.accuracy.entry(round).or_insert(a);
                }
                // Link-diagnostics payload (absent when probes are off;
                // first write wins, identical by determinism).
                if let Some(v) = ev.field("snr_db") {
                    run.snr_db.entry(round).or_insert(v);
                }
                if let Some(v) = ev.field("power_headroom") {
                    run.link_headroom.entry(round).or_insert(v);
                }
                if let Some(v) = ev.field("participating") {
                    run.participating.entry(round).or_insert(v);
                }
                if let Some(v) = ev.field("consensus_distance") {
                    run.consensus.entry(round).or_insert(v);
                }
                let st = m.workers.entry(worker()).or_default();
                st.rounds += 1;
                st.observe_ms(ev.unix_ms);
            }
            EventKind::Completed => {
                m.completed.insert(ev.key.clone());
                let run = m.runs.entry(ev.key.clone()).or_default();
                if let Some(acc) = ev.field("final_accuracy") {
                    run.final_accuracy = Some(acc);
                }
                if let (Some(pbar), Some(max_p)) =
                    (ev.field("pbar"), ev.field("max_avg_power"))
                {
                    if pbar > 0.0 {
                        run.power_headroom = Some(1.0 - max_p / pbar);
                    }
                }
                if let Some(planned) = ev.field("rounds") {
                    run.planned_rounds.get_or_insert(planned as u64);
                }
            }
            EventKind::Quarantined => {
                m.quarantined.insert(ev.key.clone());
            }
        }
    }
}

/// [`reduce`] plus the reader's skip counters.
pub fn reduce_report(report: &ReadReport) -> Metrics {
    let mut m = reduce(&report.events);
    m.skipped_lines = report.skipped_lines;
    m.unreadable_files = report.unreadable_files;
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: EventKind, key: &str, worker: &str, round: Option<u64>, data: &[(&str, f64)]) -> Event {
        Event {
            kind,
            key: key.into(),
            label: String::new(),
            worker: worker.into(),
            round,
            unix_ms: 0,
            data: data.iter().map(|&(k, v)| (k.into(), v)).collect(),
        }
    }

    #[test]
    fn reduce_is_order_insensitive_and_dedups() {
        let mut events = vec![
            ev(EventKind::Enqueued, "k1", "coord", None, &[("iterations", 4.0)]),
            ev(EventKind::Claimed, "k1", "w0", None, &[]),
            ev(EventKind::Executed, "k1", "w0", None, &[]),
            ev(EventKind::Round, "k1", "w0", Some(0), &[("grad_norm", 2.0)]),
            ev(EventKind::Round, "k1", "w0", Some(1), &[("grad_norm", 1.5)]),
            // Duplicate round from a second worker after a steal: must
            // not double-count campaign progress.
            ev(EventKind::Round, "k1", "w1", Some(1), &[("grad_norm", 1.5)]),
            ev(
                EventKind::Completed,
                "k1",
                "w1",
                None,
                &[("final_accuracy", 0.8), ("pbar", 4.0), ("max_avg_power", 3.0)],
            ),
        ];
        let fwd = reduce(&events);
        events.reverse();
        let rev = reduce(&events);
        assert_eq!(fwd.deterministic_core(), rev.deterministic_core());
        assert_eq!(fwd.rounds_total(), 2, "(key, round) deduplicated");
        assert_eq!(fwd.queue_depth(), 0);
        let run = &fwd.runs["k1"];
        assert_eq!(run.last_grad_norm(), Some((1, 1.5)));
        assert_eq!(run.final_accuracy, Some(0.8));
        assert_eq!(run.power_headroom, Some(0.25));
        assert_eq!(run.progress(), Some(0.5));
        // Worker stats are operational: present, but outside the core.
        assert_eq!(fwd.workers["w0"].rounds, 2);
        assert!(!fwd.deterministic_core().contains("w0"));
    }

    #[test]
    fn queue_depth_counts_incomplete_runs() {
        let events = vec![
            ev(EventKind::Enqueued, "k1", "c", None, &[]),
            ev(EventKind::Enqueued, "k2", "c", None, &[]),
            ev(EventKind::Completed, "k1", "w0", None, &[]),
        ];
        let m = reduce(&events);
        assert_eq!(m.queue_depth(), 1);
        assert!(m.to_prometheus().contains("ota_queue_depth 1"));
    }

    #[test]
    fn link_diagnostics_fold_dedup_and_export() {
        let mut events = vec![
            ev(
                EventKind::Round,
                "k1",
                "w0",
                Some(0),
                &[
                    ("grad_norm", 2.0),
                    ("snr_db", 12.5),
                    ("power_headroom", 0.01),
                    ("participating", 8.0),
                    ("consensus_distance", 0.2),
                ],
            ),
            ev(
                EventKind::Round,
                "k1",
                "w0",
                Some(1),
                &[("grad_norm", 1.5), ("snr_db", 9.0), ("participating", 10.0)],
            ),
            ev(EventKind::Device, "k1", "w0", Some(0), &[("device", 0.0), ("outcome", 0.0)]),
            ev(EventKind::Device, "k1", "w0", Some(0), &[("device", 1.0), ("outcome", 2.0)]),
            // Duplicate device point from a second worker: deduplicated.
            ev(EventKind::Device, "k1", "w1", Some(0), &[("device", 1.0), ("outcome", 2.0)]),
        ];
        let fwd = reduce(&events);
        events.reverse();
        let rev = reduce(&events);
        assert_eq!(fwd.deterministic_core(), rev.deterministic_core());
        let run = &fwd.runs["k1"];
        assert_eq!(run.last_snr_db(), Some((1, 9.0)));
        assert_eq!(run.last_participating(), Some((1, 10.0)));
        assert_eq!(run.last_consensus(), Some((0, 0.2)));
        assert_eq!(run.device_points.len(), 2, "(round, device) deduplicated");
        let text = fwd.to_prometheus();
        assert!(text.contains("ota_link_last_snr_db{key=\"k1\"} 9"));
        assert!(text.contains("ota_link_participating{key=\"k1\"} 10"));
        assert!(text.contains("ota_link_device_events_total{key=\"k1\"} 2"));
        assert!(text.contains("ota_link_snr_db_bucket{key=\"k1\",le=\"10\"} 1"));
        assert!(text.contains("ota_link_snr_db_bucket{key=\"k1\",le=\"+Inf\"} 2"));
        assert!(text.contains("ota_link_snr_db_count{key=\"k1\"} 2"));
        // A store without probes exports no ota_link_* series at all.
        let plain = reduce(&[ev(EventKind::Round, "k", "w", Some(0), &[("grad_norm", 1.0)])]);
        assert!(!plain.to_prometheus().contains("ota_link_"));
    }

    #[test]
    fn incremental_reducer_matches_batch_reduce() {
        let events = vec![
            ev(EventKind::Enqueued, "k1", "coord", None, &[("iterations", 4.0)]),
            ev(EventKind::Executed, "k1", "w0", None, &[]),
            ev(EventKind::Round, "k1", "w0", Some(0), &[("grad_norm", 2.0), ("train_loss", 1.0)]),
            ev(EventKind::Reclaimed, "k1", "w1", None, &[]),
            ev(EventKind::Round, "k1", "w1", Some(1), &[("grad_norm", 1.5), ("train_loss", 0.8)]),
            ev(EventKind::Completed, "k1", "w1", None, &[("final_accuracy", 0.8)]),
        ];
        let batch = reduce(&events);
        let mut r = Reducer::default();
        for chunk in events.chunks(2) {
            r.fold(chunk);
        }
        let inc = r.metrics();
        assert_eq!(inc.deterministic_core(), batch.deterministic_core());
        assert_eq!(inc.to_prometheus(), batch.to_prometheus());
        assert_eq!(inc.reclaims_by_key.get("k1"), Some(&1));
        assert_eq!(inc.runs["k1"].last_train_loss(), Some((1, 0.8)));

        // Skip accounting: consumed garbage accumulates across tails,
        // pending tails / unreadable files are snapshots of the latest.
        let mut r = Reducer::default();
        r.absorb(&events[..3], 1, 1, 0);
        r.absorb(&events[3..], 2, 1, 1);
        let m = r.metrics();
        assert_eq!(m.skipped_lines, 1 + 2 + 1, "consumed accumulates + latest pending");
        assert_eq!(m.unreadable_files, 1, "latest snapshot, not a sum");
        assert_eq!(m.deterministic_core(), batch.deterministic_core());
    }

    #[test]
    fn prometheus_dump_has_core_counters() {
        let m = reduce(&[ev(EventKind::Executed, "k", "w", None, &[])]);
        let text = m.to_prometheus();
        assert!(text.contains("ota_runs_executed_total 1"));
        assert!(text.contains("# TYPE ota_runs_executed_total counter"));
        assert!(text.contains("ota_events_total 1"));
    }
}
