//! Vector/matrix primitives (row-major, f32).

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matf {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matf {
    pub fn zeros(rows: usize, cols: usize) -> Matf {
        Matf {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matf {
        assert_eq!(data.len(), rows * cols);
        Matf { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }
}

/// y += a * x
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Dot product with 4-lane unrolling (autovectorizes well at opt-level 3).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    let (mut s4, mut s5, mut s6, mut s7) = (0f32, 0f32, 0f32, 0f32);
    for i in 0..chunks {
        let b = i * 8;
        s0 += x[b] * y[b];
        s1 += x[b + 1] * y[b + 1];
        s2 += x[b + 2] * y[b + 2];
        s3 += x[b + 3] * y[b + 3];
        s4 += x[b + 4] * y[b + 4];
        s5 += x[b + 5] * y[b + 5];
        s6 += x[b + 6] * y[b + 6];
        s7 += x[b + 7] * y[b + 7];
    }
    let mut tail = 0f32;
    for i in chunks * 8..n {
        tail += x[i] * y[i];
    }
    (s0 + s1) + (s2 + s3) + ((s4 + s5) + (s6 + s7)) + tail
}

/// ‖x‖₂²
#[inline]
pub fn norm_sq(x: &[f32]) -> f64 {
    // f64 accumulator: d = 7850 partial sums in f32 lose ~3 digits.
    x.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

/// ‖x‖₂
#[inline]
pub fn norm(x: &[f32]) -> f64 {
    norm_sq(x).sqrt()
}

/// Scale in place.
#[inline]
pub fn scale(x: &mut [f32], a: f32) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// out = A · x  (A: m×n row-major, x: n, out: m)
pub fn gemv(a: &Matf, x: &[f32], out: &mut [f32]) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, out.len());
    for (r, o) in out.iter_mut().enumerate() {
        *o = dot(a.row(r), x);
    }
}

/// out = Aᵀ · x  (A: m×n row-major, x: m, out: n) — traverses rows to stay
/// cache-friendly on the row-major layout (axpy per row).
pub fn gemv_t(a: &Matf, x: &[f32], out: &mut [f32]) {
    assert_eq!(a.rows, x.len());
    assert_eq!(a.cols, out.len());
    out.fill(0.0);
    for (r, &xr) in x.iter().enumerate() {
        if xr != 0.0 {
            axpy(xr, a.row(r), out);
        }
    }
}

/// C = A · B (naive-blocked; only used for small model shapes and tests).
pub fn gemm(a: &Matf, b: &Matf) -> Matf {
    assert_eq!(a.cols, b.rows);
    let mut c = Matf::zeros(a.rows, b.cols);
    const BK: usize = 64;
    for k0 in (0..a.cols).step_by(BK) {
        let kmax = (k0 + BK).min(a.cols);
        for i in 0..a.rows {
            let arow = a.row(i);
            let crow = c.row_mut(i);
            for k in k0..kmax {
                let aik = arow[k];
                if aik != 0.0 {
                    axpy(aik, b.row(k), crow);
                }
            }
        }
    }
    c
}

/// Numerically-stable softmax over `x`, written into `out`.
pub fn softmax(x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    let max = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0f32;
    for (o, &v) in out.iter_mut().zip(x) {
        let e = (v - max).exp();
        *o = e;
        sum += e;
    }
    let inv = 1.0 / sum;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

/// Elementwise soft-threshold (the AMP denoiser): sign(x)·max(|x|−τ, 0).
#[inline]
pub fn soft_threshold(x: &mut [f32], tau: f32) {
    for v in x.iter_mut() {
        let a = v.abs() - tau;
        *v = if a > 0.0 { a * v.signum() } else { 0.0 };
    }
}

/// Mean of a slice.
#[inline]
pub fn mean(x: &[f32]) -> f32 {
    if x.is_empty() {
        return 0.0;
    }
    (x.iter().map(|&v| v as f64).sum::<f64>() / x.len() as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f32> = (0..100).map(|i| i as f32 * 0.1).collect();
        let y: Vec<f32> = (0..100).map(|i| (100 - i) as f32 * 0.05).collect();
        let naive: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-2);
    }

    #[test]
    fn gemv_identity() {
        let mut a = Matf::zeros(3, 3);
        for i in 0..3 {
            *a.at_mut(i, i) = 1.0;
        }
        let x = [1.0, 2.0, 3.0];
        let mut out = [0.0; 3];
        gemv(&a, &x, &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn gemv_t_matches_transpose() {
        let a = Matf::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let x = [10.0, 20.0];
        let mut out = [0.0; 3];
        gemv_t(&a, &x, &mut out);
        // Aᵀ x = [1*10+4*20, 2*10+5*20, 3*10+6*20]
        assert_eq!(out, [90.0, 120.0, 150.0]);
    }

    #[test]
    fn gemm_small() {
        let a = Matf::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Matf::from_vec(2, 2, vec![5., 6., 7., 8.]);
        let c = gemm(&a, &b);
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn softmax_sums_to_one_and_stable() {
        let x = [1000.0, 1000.0, 1000.0];
        let mut out = [0.0; 3];
        softmax(&x, &mut out);
        let sum: f32 = out.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        for &p in &out {
            assert!((p - 1.0 / 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn soft_threshold_behaviour() {
        let mut x = [3.0, -3.0, 0.5, -0.5, 0.0];
        soft_threshold(&mut x, 1.0);
        assert_eq!(x, [2.0, -2.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn norm_accumulates_in_f64() {
        let x = vec![1e-3f32; 1_000_000];
        // Σ x² = 1e6 · 1e-6 = 1.0
        assert!((norm_sq(&x) - 1.0).abs() < 1e-3);
    }
}
