//! Vector/matrix primitives (row-major, f32).
//!
//! The elementwise/reduction kernels (`dot`, `axpy`, `scale`,
//! `soft_threshold`, …) live in [`super::simd`]; this module keeps the
//! matrix container and the blocked matrix kernels built on top of them.
//!
//! Blocking mirrors the Pallas tiling sketched in
//! `python/compile/kernels/{matmul,projection}.py`: row-strip matvec
//! (4 rows share one load of `x`), k-blocked GEMM (`BK = 64` keeps the
//! active B-panel in L1 while C rows stream).

use super::simd;

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matf {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matf {
    pub fn zeros(rows: usize, cols: usize) -> Matf {
        Matf {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matf {
        assert_eq!(data.len(), rows * cols);
        Matf { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }
}

/// ‖x‖₂²
#[inline]
pub fn norm_sq(x: &[f32]) -> f64 {
    // f64 accumulator: d = 7850 partial sums in f32 lose ~3 digits.
    // Sequential on purpose — the f64 sum order is part of the golden
    // trajectories (alpha in Eq. 21 depends on it), so this kernel is
    // deliberately NOT lane-blocked.
    x.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

/// ‖x‖₂
#[inline]
pub fn norm(x: &[f32]) -> f64 {
    norm_sq(x).sqrt()
}

/// out = A · x  (A: m×n row-major, x: n, out: m). Row-strip blocked: four
/// rows share one streaming pass over `x` via [`simd::dot4`]; each output
/// element is bit-identical to `simd::dot(a.row(r), x)`.
pub fn gemv(a: &Matf, x: &[f32], out: &mut [f32]) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, out.len());
    let mut r = 0usize;
    while r + 4 <= a.rows {
        let d4 = simd::dot4(a.row(r), a.row(r + 1), a.row(r + 2), a.row(r + 3), x);
        out[r..r + 4].copy_from_slice(&d4);
        r += 4;
    }
    while r < a.rows {
        out[r] = simd::dot(a.row(r), x);
        r += 1;
    }
}

/// out = Aᵀ · x  (A: m×n row-major, x: m, out: n) — traverses rows to stay
/// cache-friendly on the row-major layout. Rows are consumed four at a time
/// via [`simd::axpy4`] when all four coefficients are nonzero; the seed's
/// zero-skip semantics and per-destination add order are preserved exactly,
/// so results are bit-identical to the sequential axpy-per-row version.
pub fn gemv_t(a: &Matf, x: &[f32], out: &mut [f32]) {
    assert_eq!(a.rows, x.len());
    assert_eq!(a.cols, out.len());
    out.fill(0.0);
    let mut r = 0usize;
    while r + 4 <= a.rows {
        let c = [x[r], x[r + 1], x[r + 2], x[r + 3]];
        if c[0] != 0.0 && c[1] != 0.0 && c[2] != 0.0 && c[3] != 0.0 {
            simd::axpy4(c, a.row(r), a.row(r + 1), a.row(r + 2), a.row(r + 3), out);
        } else {
            for (j, &cj) in c.iter().enumerate() {
                if cj != 0.0 {
                    simd::axpy(cj, a.row(r + j), out);
                }
            }
        }
        r += 4;
    }
    while r < a.rows {
        if x[r] != 0.0 {
            simd::axpy(x[r], a.row(r), out);
        }
        r += 1;
    }
}

/// C = A · B (k-blocked with 4-way fused row updates; used for small model
/// shapes and tests). Per C-row the adds happen in ascending-k order with
/// the seed's `a[i,k] == 0` skip, so results are bit-identical to the
/// axpy-per-k version.
pub fn gemm(a: &Matf, b: &Matf) -> Matf {
    assert_eq!(a.cols, b.rows);
    let mut c = Matf::zeros(a.rows, b.cols);
    const BK: usize = 64;
    for k0 in (0..a.cols).step_by(BK) {
        let kmax = (k0 + BK).min(a.cols);
        for i in 0..a.rows {
            let arow = a.row(i);
            let crow = c.row_mut(i);
            let mut k = k0;
            while k + 4 <= kmax {
                let co = [arow[k], arow[k + 1], arow[k + 2], arow[k + 3]];
                if co[0] != 0.0 && co[1] != 0.0 && co[2] != 0.0 && co[3] != 0.0 {
                    simd::axpy4(co, b.row(k), b.row(k + 1), b.row(k + 2), b.row(k + 3), crow);
                } else {
                    for (j, &cj) in co.iter().enumerate() {
                        if cj != 0.0 {
                            simd::axpy(cj, b.row(k + j), crow);
                        }
                    }
                }
                k += 4;
            }
            while k < kmax {
                let aik = arow[k];
                if aik != 0.0 {
                    simd::axpy(aik, b.row(k), crow);
                }
                k += 1;
            }
        }
    }
    c
}

/// Numerically-stable softmax over `x`, written into `out`.
pub fn softmax(x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    let max = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0f32;
    for (o, &v) in out.iter_mut().zip(x) {
        let e = (v - max).exp();
        *o = e;
        sum += e;
    }
    let inv = 1.0 / sum;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

/// Mean of a slice.
#[inline]
pub fn mean(x: &[f32]) -> f32 {
    if x.is_empty() {
        return 0.0;
    }
    (x.iter().map(|&v| v as f64).sum::<f64>() / x.len() as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::reference;
    use crate::util::rng::Pcg64;

    #[test]
    fn gemv_identity() {
        let mut a = Matf::zeros(3, 3);
        for i in 0..3 {
            *a.at_mut(i, i) = 1.0;
        }
        let x = [1.0, 2.0, 3.0];
        let mut out = [0.0; 3];
        gemv(&a, &x, &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn gemv_t_matches_transpose() {
        let a = Matf::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let x = [10.0, 20.0];
        let mut out = [0.0; 3];
        gemv_t(&a, &x, &mut out);
        // Aᵀ x = [1*10+4*20, 2*10+5*20, 3*10+6*20]
        assert_eq!(out, [90.0, 120.0, 150.0]);
    }

    #[test]
    fn gemv_t_blocked_matches_sequential_axpys_bitwise() {
        // Mixed zero/nonzero coefficients hit both the fused and the
        // fallback branch; compare against the seed formulation.
        let mut rng = Pcg64::new(11);
        let rows = 13;
        let cols = 37;
        let a = Matf::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| rng.normal() as f32).collect(),
        );
        let x: Vec<f32> = (0..rows)
            .map(|i| if i % 3 == 0 { 0.0 } else { rng.normal() as f32 })
            .collect();
        let mut got = vec![0f32; cols];
        gemv_t(&a, &x, &mut got);
        let mut want = vec![0f32; cols];
        for (r, &xr) in x.iter().enumerate() {
            if xr != 0.0 {
                reference::axpy_scalar(xr, a.row(r), &mut want);
            }
        }
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn gemm_small() {
        let a = Matf::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Matf::from_vec(2, 2, vec![5., 6., 7., 8.]);
        let c = gemm(&a, &b);
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn gemm_matches_f64_reference() {
        let mut rng = Pcg64::new(12);
        let a = Matf::from_vec(9, 70, (0..9 * 70).map(|_| rng.normal() as f32).collect());
        let b = Matf::from_vec(70, 11, (0..70 * 11).map(|_| rng.normal() as f32).collect());
        let c = gemm(&a, &b);
        let want = reference::gemm_f64(&a, &b);
        for i in 0..c.data.len() {
            let w = want[i];
            assert!(
                (c.data[i] as f64 - w).abs() <= 1e-4 * w.abs().max(1.0),
                "idx {i}: {} vs {w}",
                c.data[i]
            );
        }
    }

    #[test]
    fn softmax_sums_to_one_and_stable() {
        let x = [1000.0, 1000.0, 1000.0];
        let mut out = [0.0; 3];
        softmax(&x, &mut out);
        let sum: f32 = out.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        for &p in &out {
            assert!((p - 1.0 / 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn norm_accumulates_in_f64() {
        let x = vec![1e-3f32; 1_000_000];
        // Σ x² = 1e6 · 1e-6 = 1.0
        assert!((norm_sq(&x) - 1.0).abs() < 1e-3);
    }
}
