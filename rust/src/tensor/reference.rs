//! Naive reference oracles for the optimized kernels.
//!
//! Two uses, both deliberate:
//!
//! 1. **Contract tests** (`rust/tests/kernel_contracts.rs` and module
//!    tests) check the optimized kernels against these at tiny and paper
//!    shapes — f64 oracles with relative bounds for f32 reductions,
//!    bit-for-bit for the kernels whose contract is exactness.
//! 2. **The components bench** times the scalar formulations alongside the
//!    optimized ones, so one `cargo bench --bench components` run records
//!    an honest before/after pair in `BENCH_components.json` on the same
//!    host, same build, same inputs.
//!
//! Nothing in the library hot paths calls into this module.

use super::Matf;

/// Sequential f64 dot product — the accuracy oracle for [`super::dot`].
pub fn dot_f64(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(&a, &b)| a as f64 * b as f64)
        .sum()
}

/// Σ|xᵢ·yᵢ| in f64 — the magnitude scale for relative error bounds on dot
/// products (a near-cancelling dot can have a tiny value but large terms).
pub fn abs_dot_f64(x: &[f32], y: &[f32]) -> f64 {
    x.iter()
        .zip(y)
        .map(|(&a, &b)| (a as f64 * b as f64).abs())
        .sum()
}

/// Sequential f32 dot (single accumulator) — the scalar formulation the
/// bench uses as the "before" timing for `dot`.
pub fn dot_scalar(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut s = 0f32;
    for (a, b) in x.iter().zip(y) {
        s += a * b;
    }
    s
}

/// The seed's elementwise axpy — bit-identity oracle for [`super::axpy`].
pub fn axpy_scalar(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// out = A·x in f64 — accuracy oracle for [`super::gemv`].
pub fn gemv_f64(a: &Matf, x: &[f32]) -> Vec<f64> {
    assert_eq!(a.cols, x.len());
    (0..a.rows).map(|r| dot_f64(a.row(r), x)).collect()
}

/// out = Aᵀ·x in f64 — accuracy oracle for [`super::gemv_t`].
pub fn gemv_t_f64(a: &Matf, x: &[f32]) -> Vec<f64> {
    assert_eq!(a.rows, x.len());
    let mut out = vec![0f64; a.cols];
    for (r, &xr) in x.iter().enumerate() {
        let row = a.row(r);
        for (o, &v) in out.iter_mut().zip(row) {
            *o += xr as f64 * v as f64;
        }
    }
    out
}

/// C = A·B with per-element f64 accumulation — accuracy oracle for
/// [`super::gemm`].
pub fn gemm_f64(a: &Matf, b: &Matf) -> Vec<f64> {
    assert_eq!(a.cols, b.rows);
    let mut c = vec![0f64; a.rows * b.cols];
    for i in 0..a.rows {
        for k in 0..a.cols {
            let aik = a.at(i, k) as f64;
            if aik != 0.0 {
                let brow = b.row(k);
                let crow = &mut c[i * b.cols..(i + 1) * b.cols];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += aik * bv as f64;
                }
            }
        }
    }
    c
}

/// Naive double-loop transpose — bit-identity oracle for the blocked
/// (and now parallel) transpose in `analog::projection`.
pub fn transpose_naive(a: &Matf) -> Matf {
    let mut t = Matf::zeros(a.cols, a.rows);
    for r in 0..a.rows {
        for c in 0..a.cols {
            *t.at_mut(c, r) = a.at(r, c);
        }
    }
    t
}

/// Top-k indices by |v| via full sort (stable tie-break: lowest index
/// first, matching the quickselect contract in `tensor::select`).
pub fn topk_indices_sort(v: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..v.len()).collect();
    idx.sort_by(|&a, &b| {
        v[b].abs()
            .partial_cmp(&v[a].abs())
            .unwrap()
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    idx.sort_unstable();
    idx
}
