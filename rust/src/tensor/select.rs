//! Selection primitives: top-k by magnitude (the paper's `sp_k` operator and
//! the D-DSGD top-2q selection both reduce to this), via introselect-style
//! quickselect — O(d) expected, no full sort.

use crate::util::rng::Pcg64;

/// Return the k-th largest magnitude (1-indexed: k=1 → max |x|).
/// `k` must satisfy 1 <= k <= x.len().
pub fn kth_largest_magnitude(x: &[f32], k: usize) -> f32 {
    assert!(k >= 1 && k <= x.len(), "k={k} len={}", x.len());
    let mut mags: Vec<f32> = x.iter().map(|v| v.abs()).collect();
    let idx = mags.len() - k; // k-th largest == (n-k)-th smallest (0-indexed)
    quickselect(&mut mags, idx);
    mags[idx]
}

/// In-place quickselect: after return, `xs[idx]` holds the idx-th smallest
/// element and elements left/right of it partition around it.
fn quickselect(xs: &mut [f32], idx: usize) {
    let mut lo = 0usize;
    let mut hi = xs.len();
    let mut rng = Pcg64::new(0x5E1E_C7);
    loop {
        if hi - lo <= 16 {
            xs[lo..hi].sort_by(|a, b| a.partial_cmp(b).unwrap());
            return;
        }
        // Median-of-3 with a random middle to defeat adversarial patterns.
        let mid = lo + rng.below((hi - lo) as u64) as usize;
        let pivot = median3(xs[lo], xs[mid], xs[hi - 1]);
        // 3-way partition (Dutch national flag) — robust to duplicates.
        let (mut i, mut j, mut n) = (lo, lo, hi);
        while j < n {
            if xs[j] < pivot {
                xs.swap(i, j);
                i += 1;
                j += 1;
            } else if xs[j] > pivot {
                n -= 1;
                xs.swap(j, n);
            } else {
                j += 1;
            }
        }
        if idx < i {
            hi = i;
        } else if idx >= n {
            lo = n;
        } else {
            return; // idx lands inside the == pivot band
        }
    }
}

fn median3(a: f32, b: f32, c: f32) -> f32 {
    a.max(b).min(a.min(b).max(c))
}

/// Indices of the k largest-magnitude entries (ties broken by lower index).
/// Returned indices are sorted ascending.
pub fn topk_indices(x: &[f32], k: usize) -> Vec<usize> {
    assert!(k <= x.len());
    if k == 0 {
        return Vec::new();
    }
    if k == x.len() {
        return (0..x.len()).collect();
    }
    let thresh = kth_largest_magnitude(x, k);
    // First pass: all strictly above the threshold.
    let mut idx: Vec<usize> = Vec::with_capacity(k);
    let mut at_thresh: Vec<usize> = Vec::new();
    for (i, v) in x.iter().enumerate() {
        let a = v.abs();
        if a > thresh {
            idx.push(i);
        } else if a == thresh {
            at_thresh.push(i);
        }
    }
    // Fill remaining slots from the threshold band, lowest index first.
    for i in at_thresh {
        if idx.len() == k {
            break;
        }
        idx.push(i);
    }
    idx.sort_unstable();
    debug_assert_eq!(idx.len(), k);
    idx
}

/// The paper's sp_k operator: keep the k largest-magnitude entries of `x`,
/// zero the rest. Returns the sparse result (dense representation).
pub fn sparsify_topk(x: &[f32], k: usize) -> Vec<f32> {
    let idx = topk_indices(x, k);
    let mut out = vec![0.0f32; x.len()];
    for i in idx {
        out[i] = x[i];
    }
    out
}

/// Apply sp_k in place, returning the support indices.
pub fn sparsify_topk_inplace(x: &mut [f32], k: usize) -> Vec<usize> {
    let idx = topk_indices(x, k);
    let mut keep = vec![false; x.len()];
    for &i in &idx {
        keep[i] = true;
    }
    for (i, v) in x.iter_mut().enumerate() {
        if !keep[i] {
            *v = 0.0;
        }
    }
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn kth_matches_sort() {
        let mut rng = Pcg64::new(1);
        for _ in 0..20 {
            let n = 1 + rng.below(200) as usize;
            let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let k = 1 + rng.below(n as u64) as usize;
            let mut mags: Vec<f32> = x.iter().map(|v| v.abs()).collect();
            mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
            assert_eq!(kth_largest_magnitude(&x, k), mags[k - 1]);
        }
    }

    #[test]
    fn topk_picks_largest() {
        let x = [0.1, -5.0, 3.0, -0.2, 4.0];
        assert_eq!(topk_indices(&x, 2), vec![1, 4]);
        assert_eq!(topk_indices(&x, 3), vec![1, 2, 4]);
    }

    #[test]
    fn topk_handles_duplicates() {
        let x = [1.0f32; 10];
        let idx = topk_indices(&x, 4);
        assert_eq!(idx, vec![0, 1, 2, 3]); // lowest indices win ties
    }

    #[test]
    fn sparsify_preserves_selected_and_zeros_rest() {
        let x = [0.5, -2.0, 1.5, 0.1];
        let s = sparsify_topk(&x, 2);
        assert_eq!(s, vec![0.0, -2.0, 1.5, 0.0]);
    }

    #[test]
    fn sparsify_error_bound_corollary1() {
        // Corollary 1: ‖x − sp_k(x)‖ ≤ sqrt((d−k)/d)·‖x‖
        let mut rng = Pcg64::new(7);
        for _ in 0..10 {
            let d = 500;
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            for &k in &[1usize, 50, 250, 499, 500] {
                let s = sparsify_topk(&x, k);
                let err: f64 = x
                    .iter()
                    .zip(&s)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt();
                let bound =
                    (((d - k) as f64) / d as f64).sqrt() * crate::tensor::norm(&x) + 1e-6;
                assert!(err <= bound, "k={k} err={err} bound={bound}");
            }
        }
    }

    #[test]
    fn topk_edge_cases() {
        let x = [1.0, 2.0];
        assert!(topk_indices(&x, 0).is_empty());
        assert_eq!(topk_indices(&x, 2), vec![0, 1]);
        let mut y = [3.0, -1.0, 2.0];
        let idx = sparsify_topk_inplace(&mut y, 1);
        assert_eq!(idx, vec![0]);
        assert_eq!(y, [3.0, 0.0, 0.0]);
    }
}
