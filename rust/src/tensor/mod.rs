//! Dense f32 linear algebra substrate.
//!
//! Small, allocation-conscious routines sized for this paper's shapes
//! (d = 7850, s up to d/2, M up to 50). The hot paths — `gemv`, the
//! sparse-aware projection in `analog::projection`, and AMP's `gemv_t` —
//! are written to autovectorize; see EXPERIMENTS.md §Perf.

mod dense;
mod select;

pub use dense::*;
pub use select::*;
