//! Dense f32 linear algebra substrate.
//!
//! Small, allocation-conscious routines sized for this paper's shapes
//! (d = 7850, s up to d/2, M up to 50). Layout:
//!
//! - [`simd`] — portable 8-wide f32 lane kernels (`dot`, `axpy`, the
//!   4-row blocked `dot4`/`axpy4`, fused `axpy_scaled_add` /
//!   `residual_update` / `soft_threshold_count`). All re-exported here;
//!   every hot path in `model`, `analog`, and `amp` runs on these.
//! - `dense` — the [`Matf`] container plus blocked matrix kernels
//!   ([`gemv`], [`gemv_t`], [`gemm`]) built on the simd layer.
//! - `select` — top-k / sparsify (quickselect, bit-exact).
//! - [`reference`] — naive scalar/f64 oracles used by the contract tests
//!   and the components bench (never by library hot paths).
//!
//! Exactness contracts per kernel are tabulated in PERF.md and enforced by
//! `rust/tests/kernel_contracts.rs`.

mod dense;
pub mod reference;
mod select;
pub mod simd;

pub use dense::*;
pub use select::*;
pub use simd::{
    add_assign, axpy, axpy4, axpy_scaled_add, dot, dot4, residual_update, scale, scale_into,
    soft_threshold, soft_threshold_count, F32x8, LANES,
};
