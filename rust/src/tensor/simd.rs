//! Portable 8-wide f32 lane kernels — the SIMD substrate of every hot path.
//!
//! Stable Rust, no intrinsics, no new dependencies: [`F32x8`] is a plain
//! `[f32; 8]` wrapper whose lanewise ops compile to straight-line vector
//! code under `opt-level = 3` on any target (SSE2 pairs on baseline x86-64,
//! NEON quads on aarch64). The win over the seed's scalar loops is not the
//! vector ISA alone — it is the *fixed lane structure* these kernels give
//! LLVM (reductions become 8 independent accumulator chains it is allowed
//! to vectorize) plus the 4-row blocked variants ([`dot4`], [`axpy4`]) that
//! quarter the load/store traffic on the shared operand.
//!
//! # Exactness contract (see PERF.md §Kernel table)
//!
//! Every kernel here is **deterministic and machine-portable**: no
//! `mul_add`/FMA (Rust never contracts `a * b + c` on its own), no
//! worker-count-dependent reduction trees. Beyond that, two classes:
//!
//! - **Bit-identical to the seed kernels**: `axpy`, `axpy4` (≡ four
//!   sequential `axpy` passes), `axpy_scaled_add`, `scale`, `scale_into`,
//!   `add_assign`, `soft_threshold`, `soft_threshold_count`,
//!   `residual_update` are elementwise with the seed's expression order,
//!   and `dot`/`dot4` reproduce the seed `dot`'s exact reduction tree
//!   (8 lane accumulators, pairwise combine, scalar tail) — so every
//!   golden trajectory recorded before this layer landed still holds
//!   bit-for-bit.
//! - **Tolerance-gated vs an f64 oracle**: `dot` (and everything built on
//!   it: `gemv`, `gemm`, logits) is an f32 reduction, so it carries the
//!   usual ~n·ε relative error against [`super::reference::dot_f64`];
//!   `rust/tests/kernel_contracts.rs` pins the bound at both tiny and
//!   paper (d = 7850) shapes.

/// Lane width of the portable vector type.
pub const LANES: usize = 8;

/// Portable 8-lane f32 vector: lanewise ops over a fixed-size array that
/// LLVM unrolls and vectorizes. 32-byte alignment matches one AVX register
/// (two SSE/NEON registers) so spills stay aligned.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
#[repr(C, align(32))]
pub struct F32x8(pub [f32; 8]);

impl F32x8 {
    pub const ZERO: F32x8 = F32x8([0.0; 8]);

    #[inline(always)]
    pub fn splat(v: f32) -> F32x8 {
        F32x8([v; 8])
    }

    /// Load the first 8 elements of `src` (must have len >= 8).
    #[inline(always)]
    pub fn load(src: &[f32]) -> F32x8 {
        let mut a = [0f32; 8];
        a.copy_from_slice(&src[..8]);
        F32x8(a)
    }

    /// Store into the first 8 elements of `dst` (must have len >= 8).
    #[inline(always)]
    pub fn store(self, dst: &mut [f32]) {
        dst[..8].copy_from_slice(&self.0);
    }

    #[inline(always)]
    pub fn add(self, o: F32x8) -> F32x8 {
        let mut r = [0f32; 8];
        for i in 0..8 {
            r[i] = self.0[i] + o.0[i];
        }
        F32x8(r)
    }

    #[inline(always)]
    pub fn sub(self, o: F32x8) -> F32x8 {
        let mut r = [0f32; 8];
        for i in 0..8 {
            r[i] = self.0[i] - o.0[i];
        }
        F32x8(r)
    }

    #[inline(always)]
    pub fn mul(self, o: F32x8) -> F32x8 {
        let mut r = [0f32; 8];
        for i in 0..8 {
            r[i] = self.0[i] * o.0[i];
        }
        F32x8(r)
    }

    /// Horizontal sum with a *fixed* pairwise tree:
    /// `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))` — exactly the combine order
    /// of the seed `dot`'s eight scalar accumulators.
    #[inline(always)]
    pub fn hsum(self) -> f32 {
        let v = self.0;
        ((v[0] + v[1]) + (v[2] + v[3])) + ((v[4] + v[5]) + (v[6] + v[7]))
    }
}

/// Dot product: one 8-lane accumulator, pairwise horizontal combine, scalar
/// tail — the seed kernel's exact reduction tree, so the result is
/// bit-identical to the pre-SIMD `dot` (and tolerance-gated only against
/// the f64 oracle, like any f32 reduction).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / LANES;
    let mut acc = F32x8::ZERO;
    for c in 0..chunks {
        let b = c * LANES;
        acc = acc.add(F32x8::load(&x[b..]).mul(F32x8::load(&y[b..])));
    }
    let mut tail = 0f32;
    for i in chunks * LANES..n {
        tail += x[i] * y[i];
    }
    acc.hsum() + tail
}

/// Four dot products against a shared right-hand side, computed in one
/// pass: `x` is loaded once per 8 lanes instead of four times, and the four
/// independent accumulator chains give the ILP a single running sum cannot.
/// Each returned lane is bit-identical to `dot(r_i, x)`.
#[inline]
pub fn dot4(r0: &[f32], r1: &[f32], r2: &[f32], r3: &[f32], x: &[f32]) -> [f32; 4] {
    let n = x.len();
    debug_assert_eq!(r0.len(), n);
    debug_assert_eq!(r1.len(), n);
    debug_assert_eq!(r2.len(), n);
    debug_assert_eq!(r3.len(), n);
    let chunks = n / LANES;
    let mut a0 = F32x8::ZERO;
    let mut a1 = F32x8::ZERO;
    let mut a2 = F32x8::ZERO;
    let mut a3 = F32x8::ZERO;
    for c in 0..chunks {
        let b = c * LANES;
        let xv = F32x8::load(&x[b..]);
        a0 = a0.add(F32x8::load(&r0[b..]).mul(xv));
        a1 = a1.add(F32x8::load(&r1[b..]).mul(xv));
        a2 = a2.add(F32x8::load(&r2[b..]).mul(xv));
        a3 = a3.add(F32x8::load(&r3[b..]).mul(xv));
    }
    let (mut t0, mut t1, mut t2, mut t3) = (0f32, 0f32, 0f32, 0f32);
    for i in chunks * LANES..n {
        t0 += r0[i] * x[i];
        t1 += r1[i] * x[i];
        t2 += r2[i] * x[i];
        t3 += r3[i] * x[i];
    }
    [
        a0.hsum() + t0,
        a1.hsum() + t1,
        a2.hsum() + t2,
        a3.hsum() + t3,
    ]
}

/// y += a * x (elementwise; bit-identical to the seed kernel).
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = y.len();
    let chunks = n / LANES;
    let av = F32x8::splat(a);
    for c in 0..chunks {
        let b = c * LANES;
        let r = F32x8::load(&y[b..]).add(F32x8::load(&x[b..]).mul(av));
        r.store(&mut y[b..]);
    }
    for i in chunks * LANES..n {
        y[i] += a * x[i];
    }
}

/// Four fused axpy passes: `y = (((y + a0·x0) + a1·x1) + a2·x2) + a3·x3`
/// per element — bit-identical to four sequential [`axpy`] calls in that
/// order, but `y` is loaded and stored once per block instead of four
/// times. This is the workhorse of the device transmit path, `gemv_t`,
/// `gemm`, the blocked backward pass, and AMP's fused A·x̂ accumulation.
#[inline]
pub fn axpy4(a: [f32; 4], x0: &[f32], x1: &[f32], x2: &[f32], x3: &[f32], y: &mut [f32]) {
    let n = y.len();
    debug_assert_eq!(x0.len(), n);
    debug_assert_eq!(x1.len(), n);
    debug_assert_eq!(x2.len(), n);
    debug_assert_eq!(x3.len(), n);
    let chunks = n / LANES;
    let a0 = F32x8::splat(a[0]);
    let a1 = F32x8::splat(a[1]);
    let a2 = F32x8::splat(a[2]);
    let a3 = F32x8::splat(a[3]);
    for c in 0..chunks {
        let b = c * LANES;
        let mut acc = F32x8::load(&y[b..]);
        acc = acc.add(F32x8::load(&x0[b..]).mul(a0));
        acc = acc.add(F32x8::load(&x1[b..]).mul(a1));
        acc = acc.add(F32x8::load(&x2[b..]).mul(a2));
        acc = acc.add(F32x8::load(&x3[b..]).mul(a3));
        acc.store(&mut y[b..]);
    }
    for i in chunks * LANES..n {
        y[i] = (((y[i] + a[0] * x0[i]) + a[1] * x1[i]) + a[2] * x2[i]) + a[3] * x3[i];
    }
}

/// Fused scaled update: y = a·x + b·y per element (one pass instead of a
/// `scale` pass followed by an `axpy` pass).
#[inline]
pub fn axpy_scaled_add(a: f32, x: &[f32], b: f32, y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = y.len();
    let chunks = n / LANES;
    let av = F32x8::splat(a);
    let bv = F32x8::splat(b);
    for c in 0..chunks {
        let o = c * LANES;
        let r = F32x8::load(&x[o..])
            .mul(av)
            .add(F32x8::load(&y[o..]).mul(bv));
        r.store(&mut y[o..]);
    }
    for i in chunks * LANES..n {
        y[i] = a * x[i] + b * y[i];
    }
}

/// AMP residual update, fused: r = (y − ax) + b·r per element — the seed's
/// exact expression order, one pass instead of three.
#[inline]
pub fn residual_update(r: &mut [f32], y: &[f32], ax: &[f32], b: f32) {
    debug_assert_eq!(r.len(), y.len());
    debug_assert_eq!(r.len(), ax.len());
    for i in 0..r.len() {
        r[i] = y[i] - ax[i] + b * r[i];
    }
}

/// Scale in place (bit-identical to the seed kernel).
#[inline]
pub fn scale(x: &mut [f32], a: f32) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// out = a·x (fused scale-into-destination, no read of `out`).
#[inline]
pub fn scale_into(out: &mut [f32], x: &[f32], a: f32) {
    debug_assert_eq!(out.len(), x.len());
    for (o, &v) in out.iter_mut().zip(x) {
        *o = a * v;
    }
}

/// y += x.
#[inline]
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += xi;
    }
}

/// Elementwise soft-threshold (the AMP denoiser): sign(x)·max(|x|−τ, 0).
/// Bit-identical to the seed kernel (compare + select per lane).
#[inline]
pub fn soft_threshold(x: &mut [f32], tau: f32) {
    for v in x.iter_mut() {
        let a = v.abs() - tau;
        *v = if a > 0.0 { a * v.signum() } else { 0.0 };
    }
}

/// Fused soft-threshold + support count: same elementwise results as
/// [`soft_threshold`], and returns ‖x‖₀ from the same pass (AMP needs the
/// count for its Onsager term and previously re-scanned the vector).
#[inline]
pub fn soft_threshold_count(x: &mut [f32], tau: f32) -> usize {
    let mut nnz = 0usize;
    for v in x.iter_mut() {
        let a = v.abs() - tau;
        if a > 0.0 {
            *v = a * v.signum();
            nnz += 1;
        } else {
            *v = 0.0;
        }
    }
    nnz
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::reference;
    use crate::util::rng::Pcg64;

    fn random_vec(n: usize, rng: &mut Pcg64) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn dot_matches_f64_reference_relative() {
        // The seed test compared against an f32 naive sum with a loose 1e-2
        // absolute bound; the honest oracle is f64 with a relative bound.
        let mut rng = Pcg64::new(1);
        for &n in &[100usize, 7850] {
            let x = random_vec(n, &mut rng);
            let y = random_vec(n, &mut rng);
            let got = dot(&x, &y) as f64;
            let want = reference::dot_f64(&x, &y);
            let mag = reference::abs_dot_f64(&x, &y).max(1e-12);
            assert!(
                (got - want).abs() <= 1e-5 * mag,
                "n={n}: dot {got} vs f64 {want} (mag {mag})"
            );
        }
    }

    #[test]
    fn dot_property_random_lengths_exercise_tail() {
        // Random lengths, including n % 8 != 0, so the scalar tail path is
        // genuinely exercised (the seed test only ever used n = 100).
        let mut rng = Pcg64::new(2);
        let mut saw_tail = false;
        for _ in 0..60 {
            let n = 1 + rng.below(97) as usize;
            if n % LANES != 0 {
                saw_tail = true;
            }
            let x = random_vec(n, &mut rng);
            let y = random_vec(n, &mut rng);
            let got = dot(&x, &y) as f64;
            let want = reference::dot_f64(&x, &y);
            let mag = reference::abs_dot_f64(&x, &y).max(1e-12);
            assert!(
                (got - want).abs() <= 1e-5 * mag,
                "n={n}: dot {got} vs f64 {want}"
            );
        }
        assert!(saw_tail, "random lengths never hit the tail path");
    }

    #[test]
    fn dot4_lanes_bit_identical_to_dot() {
        let mut rng = Pcg64::new(3);
        for &n in &[8usize, 15, 64, 103] {
            let rows: Vec<Vec<f32>> = (0..4).map(|_| random_vec(n, &mut rng)).collect();
            let x = random_vec(n, &mut rng);
            let got = dot4(&rows[0], &rows[1], &rows[2], &rows[3], &x);
            for l in 0..4 {
                assert_eq!(
                    got[l].to_bits(),
                    dot(&rows[l], &x).to_bits(),
                    "lane {l}, n={n}"
                );
            }
        }
    }

    #[test]
    fn axpy4_bit_identical_to_sequential_axpys() {
        let mut rng = Pcg64::new(4);
        for &n in &[8usize, 23, 96] {
            let xs: Vec<Vec<f32>> = (0..4).map(|_| random_vec(n, &mut rng)).collect();
            let a = [0.5f32, -1.25, 0.03125, 2.0];
            let y0 = random_vec(n, &mut rng);
            let mut fused = y0.clone();
            axpy4(a, &xs[0], &xs[1], &xs[2], &xs[3], &mut fused);
            let mut seq = y0;
            for l in 0..4 {
                axpy(a[l], &xs[l], &mut seq);
            }
            for (f, s) in fused.iter().zip(&seq) {
                assert_eq!(f.to_bits(), s.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn axpy_matches_scalar_reference_bitwise() {
        let mut rng = Pcg64::new(5);
        for &n in &[1usize, 8, 13, 40] {
            let x = random_vec(n, &mut rng);
            let mut y = random_vec(n, &mut rng);
            let mut want = y.clone();
            reference::axpy_scalar(0.75, &x, &mut want);
            axpy(0.75, &x, &mut y);
            for (g, w) in y.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn axpy_scaled_add_matches_expression() {
        let mut rng = Pcg64::new(6);
        let x = random_vec(21, &mut rng);
        let y0 = random_vec(21, &mut rng);
        let mut y = y0.clone();
        axpy_scaled_add(1.5, &x, -0.5, &mut y);
        for i in 0..21 {
            let want = 1.5f32 * x[i] + (-0.5f32) * y0[i];
            assert_eq!(y[i].to_bits(), want.to_bits());
        }
    }

    #[test]
    fn residual_update_matches_expression() {
        let mut rng = Pcg64::new(7);
        let y = random_vec(17, &mut rng);
        let ax = random_vec(17, &mut rng);
        let r0 = random_vec(17, &mut rng);
        let mut r = r0.clone();
        residual_update(&mut r, &y, &ax, 0.3);
        for i in 0..17 {
            let want = y[i] - ax[i] + 0.3f32 * r0[i];
            assert_eq!(r[i].to_bits(), want.to_bits());
        }
    }

    #[test]
    fn soft_threshold_count_matches_plain() {
        let mut rng = Pcg64::new(8);
        let x0 = random_vec(100, &mut rng);
        let mut a = x0.clone();
        let mut b = x0;
        soft_threshold(&mut a, 0.8);
        let nnz = soft_threshold_count(&mut b, 0.8);
        assert_eq!(a, b);
        assert_eq!(nnz, a.iter().filter(|&&v| v != 0.0).count());
    }

    #[test]
    fn soft_threshold_behaviour() {
        let mut x = [3.0, -3.0, 0.5, -0.5, 0.0];
        soft_threshold(&mut x, 1.0);
        assert_eq!(x, [2.0, -2.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn scale_into_and_add_assign() {
        let x = [1.0f32, -2.0, 3.0];
        let mut out = [0f32; 3];
        scale_into(&mut out, &x, 2.0);
        assert_eq!(out, [2.0, -4.0, 6.0]);
        let mut y = [1.0f32, 1.0, 1.0];
        add_assign(&mut y, &x);
        assert_eq!(y, [2.0, -1.0, 4.0]);
    }
}
