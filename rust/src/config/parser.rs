//! TOML-subset parser for experiment configuration files.
//!
//! Supported: `[section]` headers, `key = value` pairs, comments (`#`),
//! values: string (quoted), bool, integer, float, and flat arrays of those.
//! This covers every config the launcher consumes; no serde in the offline
//! vendor set.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }
}

pub type Section = BTreeMap<String, Value>;
pub type Document = BTreeMap<String, Section>;

#[derive(Debug)]
pub enum ParseError {
    Syntax { line: usize, msg: String },
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParseError::Syntax { line, msg } => write!(f, "line {line}: {msg}"),
        }
    }
}

impl std::error::Error for ParseError {}

/// Parse a TOML-subset document. Keys before any `[section]` land in the
/// section named "" (root).
pub fn parse(text: &str) -> Result<Document, ParseError> {
    let mut doc = Document::new();
    let mut current = String::new();
    doc.entry(current.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(body) = line.strip_prefix('[') {
            let name = body.strip_suffix(']').ok_or_else(|| ParseError::Syntax {
                line: lineno + 1,
                msg: "unterminated section header".into(),
            })?;
            current = name.trim().to_string();
            doc.entry(current.clone()).or_default();
        } else if let Some((k, v)) = line.split_once('=') {
            let key = k.trim().to_string();
            let value = parse_value(v.trim()).map_err(|msg| ParseError::Syntax {
                line: lineno + 1,
                msg,
            })?;
            doc.get_mut(&current).unwrap().insert(key, value);
        } else {
            return Err(ParseError::Syntax {
                line: lineno + 1,
                msg: format!("expected key = value, got {line:?}"),
            });
        }
    }
    Ok(doc)
}

/// Sanitize display metadata for embedding in this TOML subset: quoted
/// strings are kept verbatim (no escape sequences), so embedded double
/// quotes and newlines cannot round-trip — swap them for near-lookalikes.
/// Only for display-only fields (run labels, summaries, spec ids);
/// identity-bearing strings must be *rejected* instead of rewritten (see
/// `RunConfig::to_toml`), because a silent rewrite changes the content
/// address on the reader's side.
pub fn sanitize_display(s: &str) -> String {
    s.replace('"', "'").replace('\n', " ")
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside quotes starts a comment.
    let mut in_quotes = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_quotes = !in_quotes,
            '#' if !in_quotes => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = s.strip_prefix('"') {
        let inner = body
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let inner = body
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in trimmed.split(',') {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            r#"
# experiment
title = "fig2"
[run]
devices = 25
pbar = 500.0
noniid = false
powers = [100, 200, 300]
"#,
        )
        .unwrap();
        assert_eq!(doc[""]["title"].as_str(), Some("fig2"));
        assert_eq!(doc["run"]["devices"].as_usize(), Some(25));
        assert_eq!(doc["run"]["pbar"].as_f64(), Some(500.0));
        assert_eq!(doc["run"]["noniid"].as_bool(), Some(false));
        match &doc["run"]["powers"] {
            Value::Array(v) => assert_eq!(v.len(), 3),
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let doc = parse("a = 1 # trailing\n\n# full line\nb = \"x # not comment\"\n").unwrap();
        assert_eq!(doc[""]["a"].as_i64(), Some(1));
        assert_eq!(doc[""]["b"].as_str(), Some("x # not comment"));
    }

    #[test]
    fn int_coerces_to_f64() {
        let doc = parse("p = 500\n").unwrap();
        assert_eq!(doc[""]["p"].as_f64(), Some(500.0));
    }

    #[test]
    fn error_reports_line() {
        let err = parse("good = 1\nnot a kv\n").unwrap_err();
        assert!(err.to_string().contains("line 2"));
    }

    #[test]
    fn empty_array() {
        let doc = parse("xs = []\n").unwrap();
        assert_eq!(doc[""]["xs"], Value::Array(vec![]));
    }
}
