//! Paper-exact experiment presets, one per figure (Section VI).
//!
//! Each preset fixes M, B, s, k, P̄, σ² and the power schedule to the values
//! the figure caption states. `full = false` shrinks only the *runtime*
//! knobs (iterations T, corpus size, eval cadence) so the qualitative series
//! regenerate in minutes on the 1-core CI box; `full = true` is the paper's
//! exact T = 300-ish horizon.

use super::schema::{
    DatasetSpec, FadingDist, GraphFamily, MixingRule, ParticipationPolicy, PowerSchedule,
    RunConfig, Scheme, TopologyConfig,
};

/// Model dimension for the paper's single-layer MNIST network:
/// d = 784·10 + 10 = 7850.
pub const MODEL_DIM: usize = 7850;

fn base(full: bool) -> RunConfig {
    RunConfig {
        iterations: if full { 300 } else { 60 },
        eval_every: if full { 5 } else { 2 },
        dataset: DatasetSpec::Synthetic {
            train: 60_000,
            test: if full { 10_000 } else { 2_000 },
        },
        ..RunConfig::default()
    }
}

/// Fig. 2: scheme shoot-out, IID and non-IID.
/// M=25, B=1000, P̄=500, s=d/2, k=⌊s/2⌋, P_t = P̄.
pub fn fig2(scheme: Scheme, noniid: bool, full: bool) -> RunConfig {
    let s = MODEL_DIM / 2;
    RunConfig {
        scheme,
        devices: 25,
        local_samples: 1000,
        channel_uses: s,
        sparsity: s / 2,
        pbar: 500.0,
        noniid,
        power: PowerSchedule::Constant,
        ..base(full)
    }
}

/// Fig. 3: D-DSGD power allocation schedules at P̄=200 (T=300 in the paper).
pub fn fig3(scheme: Scheme, power: PowerSchedule, full: bool) -> RunConfig {
    let s = MODEL_DIM / 2;
    RunConfig {
        scheme,
        devices: 25,
        local_samples: 1000,
        channel_uses: s,
        sparsity: s / 2,
        pbar: 200.0,
        power,
        ..base(full)
    }
}

/// Fig. 4: average power sweep P̄ ∈ {200, 1000}.
pub fn fig4(scheme: Scheme, pbar: f64, full: bool) -> RunConfig {
    let s = MODEL_DIM / 2;
    RunConfig {
        scheme,
        devices: 25,
        local_samples: 1000,
        channel_uses: s,
        sparsity: s / 2,
        pbar,
        ..base(full)
    }
}

/// Fig. 5: bandwidth sweep s ∈ {d/2, 3d/10}, M=20, P̄=500.
pub fn fig5(scheme: Scheme, s: usize, full: bool) -> RunConfig {
    RunConfig {
        scheme,
        devices: 20,
        local_samples: 1000,
        channel_uses: s,
        sparsity: s / 2,
        pbar: 500.0,
        ..base(full)
    }
}

/// Fig. 6: device scaling (M,B) ∈ {(10,2000),(20,1000)}, P̄ ∈ {1,500},
/// s = ⌊d/4⌋.
pub fn fig6(scheme: Scheme, devices: usize, local: usize, pbar: f64, full: bool) -> RunConfig {
    let s = MODEL_DIM / 4;
    RunConfig {
        scheme,
        devices,
        local_samples: local,
        channel_uses: s,
        sparsity: s / 2,
        pbar,
        ..base(full)
    }
}

/// Fig. 7: A-DSGD bandwidth/latency trade-off,
/// s ∈ {d/10, d/5, d/2}, k=⌊4s/5⌋, M=25, B=1000, P̄=50.
pub fn fig7(s: usize, full: bool) -> RunConfig {
    RunConfig {
        scheme: Scheme::ADsgd,
        devices: 25,
        local_samples: 1000,
        channel_uses: s,
        sparsity: 4 * s / 5,
        pbar: 50.0,
        ..base(full)
    }
}

/// The small config used by quickstart/example smoke paths and tests:
/// the same pipeline at a scale that runs in seconds.
pub fn smoke() -> RunConfig {
    RunConfig {
        scheme: Scheme::ADsgd,
        // Enough devices that the coherent over-the-air sum clears the
        // noise floor (Remark 4); k = s/2 as in the paper's figures —
        // empirically the partial-AMP + error-accumulation combination
        // beats conservatively small k (see EXPERIMENTS.md).
        devices: 10,
        local_samples: 100,
        channel_uses: MODEL_DIM / 4,
        sparsity: MODEL_DIM / 8,
        pbar: 500.0,
        iterations: 10,
        eval_every: 2,
        mean_removal_rounds: 3,
        dataset: DatasetSpec::Synthetic {
            train: 1_000,
            test: 400,
        },
        amp_iters: 20,
        ..RunConfig::default()
    }
}

/// Fading-MAC sweep (companion papers, Amiri & Gündüz 2019): the same fleet
/// as the figures but over per-device Rayleigh gains, at dimensions chosen
/// so a sweep run (CSI thresholds × participation × stragglers) stays
/// tractable. `scheme` picks CSI vs blind vs the static/error-free anchors.
pub fn fading_sweep(scheme: Scheme, full: bool) -> RunConfig {
    let s = MODEL_DIM / 4;
    RunConfig {
        scheme,
        devices: 20,
        local_samples: 1000,
        channel_uses: s,
        sparsity: s / 2,
        pbar: 500.0,
        fading: FadingDist::Rayleigh,
        csi_threshold: 0.2,
        participation: ParticipationPolicy::Full,
        ..base(full)
    }
}

/// Decentralized D2D sweep: the same fleet over every graph family at
/// matched power/bandwidth (`repro fig d2d`). Dimensions are chosen so the
/// per-receiver AMP decodes (one per distinct neighborhood per round) stay
/// tractable: M = 9 gives a 3×3 torus, and s = d/8 keeps one decode under
/// half a second. Per-edge gains default to h ≡ 1 so the comparison
/// isolates the topology (set `fading`/`fading_rho` for fading edges).
pub fn d2d_sweep(family: GraphFamily, full: bool) -> RunConfig {
    let s = MODEL_DIM / 8;
    RunConfig {
        scheme: Scheme::D2dADsgd,
        devices: 9,
        local_samples: 1000,
        channel_uses: s,
        sparsity: s / 2,
        pbar: 500.0,
        fading: FadingDist::Constant(1.0),
        amp_iters: 15,
        topology: TopologyConfig {
            family,
            degree: 1,
            p: 0.45,
            mixing: MixingRule::Metropolis,
            seed: 0,
        },
        ..base(full)
    }
}

/// The matched star anchor for the D2D sweep: classic PS-based A-DSGD at
/// the d2d_sweep dimensions (same M, s, k, P̄), so the figure isolates
/// "decentralize the aggregation" as the only change.
pub fn d2d_star_anchor(full: bool) -> RunConfig {
    RunConfig {
        scheme: Scheme::ADsgd,
        ..d2d_sweep(GraphFamily::Full, full)
    }
}

/// The D2D analogue of [`smoke`]: ring consensus at a scale that runs in
/// seconds (per-receiver decodes make D2D ~M× a star round, so the smoke
/// preset halves the projection relative to [`smoke`]).
pub fn d2d_smoke() -> RunConfig {
    let s = MODEL_DIM / 8;
    RunConfig {
        scheme: Scheme::D2dADsgd,
        devices: 6,
        channel_uses: s,
        sparsity: s / 2,
        amp_iters: 15,
        fading: FadingDist::Constant(1.0),
        topology: TopologyConfig {
            family: GraphFamily::Ring,
            degree: 1,
            p: 0.5,
            mixing: MixingRule::Metropolis,
            seed: 0,
        },
        ..smoke()
    }
}

/// The fading analogue of [`smoke`]: the full fading pipeline — Rayleigh
/// gains, CSI truncation, stragglers — at a scale that runs in seconds.
pub fn fading_smoke() -> RunConfig {
    RunConfig {
        scheme: Scheme::FadingADsgd,
        fading: FadingDist::Rayleigh,
        csi_threshold: 0.2,
        participation: ParticipationPolicy::Full,
        latency_mean_secs: 0.005,
        deadline_secs: 0.02,
        ..smoke()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_validate() {
        for full in [false, true] {
            fig2(Scheme::ADsgd, false, full).validate(MODEL_DIM).unwrap();
            fig2(Scheme::DDsgd, true, full).validate(MODEL_DIM).unwrap();
            fig3(Scheme::DDsgd, PowerSchedule::LhStair, full)
                .validate(MODEL_DIM)
                .unwrap();
            fig4(Scheme::ADsgd, 200.0, full).validate(MODEL_DIM).unwrap();
            fig5(Scheme::DDsgd, 3 * MODEL_DIM / 10, full)
                .validate(MODEL_DIM)
                .unwrap();
            fig6(Scheme::ADsgd, 10, 2000, 1.0, full)
                .validate(MODEL_DIM)
                .unwrap();
            fig7(MODEL_DIM / 10, full).validate(MODEL_DIM).unwrap();
            fading_sweep(Scheme::FadingADsgd, full)
                .validate(MODEL_DIM)
                .unwrap();
            fading_sweep(Scheme::BlindADsgd, full)
                .validate(MODEL_DIM)
                .unwrap();
            for family in [
                GraphFamily::Full,
                GraphFamily::Ring,
                GraphFamily::Torus,
                GraphFamily::ErdosRenyi,
                GraphFamily::Star,
            ] {
                d2d_sweep(family, full).validate(MODEL_DIM).unwrap();
            }
            d2d_star_anchor(full).validate(MODEL_DIM).unwrap();
        }
        smoke().validate(MODEL_DIM).unwrap();
        fading_smoke().validate(MODEL_DIM).unwrap();
        d2d_smoke().validate(MODEL_DIM).unwrap();
    }

    #[test]
    fn d2d_anchor_matches_sweep_dimensions() {
        let d2d = d2d_sweep(GraphFamily::Ring, false);
        let star = d2d_star_anchor(false);
        assert_eq!(star.scheme, Scheme::ADsgd);
        assert_eq!(d2d.scheme, Scheme::D2dADsgd);
        assert_eq!(star.devices, d2d.devices);
        assert_eq!(star.channel_uses, d2d.channel_uses);
        assert_eq!(star.sparsity, d2d.sparsity);
        assert_eq!(star.pbar, d2d.pbar);
    }

    #[test]
    fn fading_smoke_models_stragglers() {
        let c = fading_smoke();
        assert_eq!(c.scheme, Scheme::FadingADsgd);
        assert!(c.deadline().is_some());
        assert!(c.latency_mean_secs > 0.0);
    }

    #[test]
    fn fig2_matches_caption() {
        let c = fig2(Scheme::ADsgd, false, true);
        assert_eq!(c.devices, 25);
        assert_eq!(c.local_samples, 1000);
        assert_eq!(c.channel_uses, MODEL_DIM / 2);
        assert_eq!(c.sparsity, MODEL_DIM / 4);
        assert_eq!(c.pbar, 500.0);
    }

    #[test]
    fn fig7_sparsity_is_4s_over_5() {
        let s = MODEL_DIM / 5;
        let c = fig7(s, false);
        assert_eq!(c.sparsity, 4 * s / 5);
        assert_eq!(c.pbar, 50.0);
    }

    #[test]
    fn fig6_pbar_one_is_valid() {
        // The P̄ = 1 regime is the one where D-DSGD sends zero bits.
        fig6(Scheme::DDsgd, 20, 1000, 1.0, false)
            .validate(MODEL_DIM)
            .unwrap();
    }
}
