//! Experiment configuration schema + validation.
//!
//! A `RunConfig` fully determines one training run: scheme, channel, power,
//! data distribution, optimizer, and backend. Configs are constructed from
//! presets (`config::presets`), from TOML files (`RunConfig::from_toml`), or
//! from CLI overrides (`apply_overrides`).

use super::parser::{self, Document, Value};

/// Which transmission scheme the run uses (Section III / IV of the paper).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// Analog over-the-air DSGD (Algorithm 1).
    ADsgd,
    /// Digital DSGD: SBC-style quantizer + capacity bit budget (Section III).
    DDsgd,
    /// SignSGD baseline through the same capacity pipe (Eq. 43).
    SignSgd,
    /// QSGD baseline through the same capacity pipe (Eq. 44).
    Qsgd,
    /// Noiseless benchmark: PS receives the exact average gradient.
    ErrorFree,
}

impl Scheme {
    pub fn parse(s: &str) -> Option<Scheme> {
        Some(match s.to_ascii_lowercase().as_str() {
            "adsgd" | "a-dsgd" | "analog" => Scheme::ADsgd,
            "ddsgd" | "d-dsgd" | "digital" => Scheme::DDsgd,
            "signsgd" | "s-dsgd" | "sign" => Scheme::SignSgd,
            "qsgd" | "q-dsgd" => Scheme::Qsgd,
            "errorfree" | "error-free" | "shared-link" => Scheme::ErrorFree,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::ADsgd => "A-DSGD",
            Scheme::DDsgd => "D-DSGD",
            Scheme::SignSgd => "SignSGD",
            Scheme::Qsgd => "QSGD",
            Scheme::ErrorFree => "error-free",
        }
    }

    /// Which transmission-pipeline family serves this scheme. The trainer
    /// never branches on `Scheme` directly — it builds the matching
    /// [`crate::coordinator::link::LinkScheme`] implementation and drives
    /// that; this classification is the config-side half of that factory.
    pub fn kind(&self) -> LinkKind {
        match self {
            Scheme::ADsgd => LinkKind::Analog,
            Scheme::DDsgd | Scheme::SignSgd | Scheme::Qsgd => LinkKind::Digital,
            Scheme::ErrorFree => LinkKind::Passthrough,
        }
    }
}

/// The three transmission-pipeline families (III/IV of the paper): uncoded
/// analog superposition, separation-based digital, and the noiseless
/// benchmark that bypasses the channel entirely.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkKind {
    /// Device gradients bypass the channel (error-free shared link).
    Passthrough,
    /// Capacity-budgeted digital payloads (D-DSGD, SignSGD, QSGD).
    Digital,
    /// Uncoded analog superposition over the Gaussian MAC (A-DSGD).
    Analog,
}

impl LinkKind {
    pub fn name(&self) -> &'static str {
        match self {
            LinkKind::Passthrough => "passthrough",
            LinkKind::Digital => "digital",
            LinkKind::Analog => "analog",
        }
    }
}

/// Power allocation across iterations (Fig. 3, Eq. 45a–c). The schedule is
/// normalized so that (1/T)Σ P_t = P̄ holds for every variant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PowerSchedule {
    /// P_t = P̄ for all t.
    Constant,
    /// Eq. 45a: linear ramp 0.5·P̄ → 1.5·P̄ ("LH, stair").
    LhStair,
    /// Eq. 45b: three equal blocks 0.5/1.0/1.5 × P̄ (low→high).
    Lh,
    /// Eq. 45c: three equal blocks 1.5/1.0/0.5 × P̄ (high→low).
    Hl,
}

impl PowerSchedule {
    pub fn parse(s: &str) -> Option<PowerSchedule> {
        Some(match s.to_ascii_lowercase().as_str() {
            "const" | "constant" => PowerSchedule::Constant,
            "lhstair" | "lh-stair" | "stair" => PowerSchedule::LhStair,
            "lh" => PowerSchedule::Lh,
            "hl" => PowerSchedule::Hl,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PowerSchedule::Constant => "const",
            PowerSchedule::LhStair => "LH-stair",
            PowerSchedule::Lh => "LH",
            PowerSchedule::Hl => "HL",
        }
    }
}

/// Gradient/compute backend: pure rust reference, or the AOT-compiled JAX
/// graphs executed through PJRT (`runtime::pjrt`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Rust,
    Pjrt,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        Some(match s.to_ascii_lowercase().as_str() {
            "rust" => Backend::Rust,
            "pjrt" | "xla" => Backend::Pjrt,
            _ => return None,
        })
    }
}

/// Where training data comes from.
#[derive(Clone, Debug, PartialEq)]
pub enum DatasetSpec {
    /// Deterministic MNIST-like synthetic corpus (see `data::synthetic`).
    Synthetic { train: usize, test: usize },
    /// Real MNIST IDX files under the given directory (auto-detected).
    MnistIdx { dir: String },
}

/// Full specification of one training run.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    pub scheme: Scheme,
    /// Number of devices M.
    pub devices: usize,
    /// Local dataset size B per device (batch = full local set, as in §VI).
    pub local_samples: usize,
    /// Channel uses per iteration, s.
    pub channel_uses: usize,
    /// A-DSGD sparsification level k.
    pub sparsity: usize,
    /// Average power constraint P̄ (per device, per iteration, Eq. 6).
    pub pbar: f64,
    /// Channel noise variance σ².
    pub noise_var: f64,
    /// Number of DSGD iterations T.
    pub iterations: usize,
    pub power: PowerSchedule,
    /// Adam step size at the PS.
    pub lr: f64,
    /// Non-IID data split (two classes per device) vs IID.
    pub noniid: bool,
    pub seed: u64,
    /// Use the §IV-A mean-removal variant for the first N iterations.
    pub mean_removal_rounds: usize,
    /// QSGD quantization bits l_Q (paper uses l_Q = 2).
    pub qsgd_levels: u32,
    pub backend: Backend,
    pub dataset: DatasetSpec,
    /// Evaluate test accuracy every `eval_every` iterations.
    pub eval_every: usize,
    /// AMP decoder iteration cap / tolerance / denoiser threshold α
    /// (τ = α‖r‖/√s).
    pub amp_iters: usize,
    pub amp_tol: f64,
    pub amp_threshold_mult: f64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            scheme: Scheme::ADsgd,
            devices: 25,
            local_samples: 1000,
            channel_uses: 3925, // d/2 for d = 7850
            sparsity: 1962,     // s/2
            pbar: 500.0,
            noise_var: 1.0,
            iterations: 100,
            power: PowerSchedule::Constant,
            lr: 1e-3,
            noniid: false,
            seed: 1,
            mean_removal_rounds: 20,
            qsgd_levels: 2,
            backend: Backend::Rust,
            dataset: DatasetSpec::Synthetic {
                train: 25_000,
                test: 2_000,
            },
            eval_every: 5,
            amp_iters: 30,
            amp_tol: 1e-4,
            amp_threshold_mult: 1.1,
        }
    }
}

#[derive(Debug)]
pub enum ConfigError {
    Parse(parser::ParseError),
    Invalid(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Parse(e) => write!(f, "config parse error: {e}"),
            ConfigError::Invalid(msg) => write!(f, "invalid config: {msg}"),
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConfigError::Parse(e) => Some(e),
            ConfigError::Invalid(_) => None,
        }
    }
}

impl From<parser::ParseError> for ConfigError {
    fn from(e: parser::ParseError) -> ConfigError {
        ConfigError::Parse(e)
    }
}

impl RunConfig {
    /// Validate the cross-field constraints the schemes rely on.
    pub fn validate(&self, model_dim: usize) -> Result<(), ConfigError> {
        let fail = |msg: String| Err(ConfigError::Invalid(msg));
        if self.devices == 0 {
            return fail("devices must be >= 1".into());
        }
        if self.local_samples == 0 {
            return fail("local_samples must be >= 1".into());
        }
        if self.iterations == 0 {
            return fail("iterations must be >= 1".into());
        }
        if self.pbar <= 0.0 {
            return fail("pbar must be > 0".into());
        }
        if self.noise_var <= 0.0 {
            return fail("noise_var must be > 0".into());
        }
        if self.scheme == Scheme::ADsgd {
            // A-DSGD needs s >= 2 (s̃ = s−1 plus the scaling channel use);
            // mean removal needs s >= 3 (§IV-A).
            let min_s = if self.mean_removal_rounds > 0 { 3 } else { 2 };
            if self.channel_uses < min_s {
                return fail(format!(
                    "A-DSGD requires s >= {min_s}, got {}",
                    self.channel_uses
                ));
            }
            if self.sparsity == 0 || self.sparsity > model_dim {
                return fail(format!(
                    "sparsity k={} out of range (1..={model_dim})",
                    self.sparsity
                ));
            }
            if self.sparsity >= self.channel_uses {
                // Assumption 3 / Lemma 1 need k < s̃ for AMP recovery.
                return fail(format!(
                    "A-DSGD requires k < s (k={}, s={})",
                    self.sparsity, self.channel_uses
                ));
            }
        }
        if self.channel_uses > model_dim {
            return fail(format!(
                "s={} exceeds model dimension d={model_dim}; uncoded transmission would \
                 not need compression",
                self.channel_uses
            ));
        }
        match &self.dataset {
            DatasetSpec::Synthetic { train, test } => {
                if self.devices * self.local_samples > *train {
                    return fail(format!(
                        "M*B = {} exceeds synthetic train size {train}",
                        self.devices * self.local_samples
                    ));
                }
                if *test == 0 {
                    return fail("test set must be non-empty".into());
                }
            }
            DatasetSpec::MnistIdx { dir } => {
                if dir.is_empty() {
                    return fail("mnist dir must be non-empty".into());
                }
            }
        }
        Ok(())
    }

    /// Load from a TOML-subset file (single `[run]` section or root keys).
    pub fn from_toml(text: &str) -> Result<RunConfig, ConfigError> {
        let doc = parser::parse(text)?;
        let mut cfg = RunConfig::default();
        let section = doc
            .get("run")
            .filter(|s| !s.is_empty())
            .or_else(|| doc.get(""))
            .cloned()
            .unwrap_or_default();
        cfg.apply_section(&section)?;
        // Allow a separate [dataset] section.
        if let Some(ds) = doc.get("dataset") {
            cfg.apply_dataset(ds)?;
        }
        Ok(cfg)
    }

    fn apply_section(
        &mut self,
        s: &std::collections::BTreeMap<String, Value>,
    ) -> Result<(), ConfigError> {
        let bad = |k: &str, v: &Value| {
            ConfigError::Invalid(format!("key {k:?}: unexpected value {v:?}"))
        };
        for (k, v) in s {
            match k.as_str() {
                "scheme" => {
                    let name = v.as_str().ok_or_else(|| bad(k, v))?;
                    self.scheme =
                        Scheme::parse(name).ok_or_else(|| {
                            ConfigError::Invalid(format!("unknown scheme {name:?}"))
                        })?;
                }
                "devices" => self.devices = v.as_usize().ok_or_else(|| bad(k, v))?,
                "local_samples" => {
                    self.local_samples = v.as_usize().ok_or_else(|| bad(k, v))?
                }
                "channel_uses" => {
                    self.channel_uses = v.as_usize().ok_or_else(|| bad(k, v))?
                }
                "sparsity" => self.sparsity = v.as_usize().ok_or_else(|| bad(k, v))?,
                "pbar" => self.pbar = v.as_f64().ok_or_else(|| bad(k, v))?,
                "noise_var" => self.noise_var = v.as_f64().ok_or_else(|| bad(k, v))?,
                "iterations" => self.iterations = v.as_usize().ok_or_else(|| bad(k, v))?,
                "power" => {
                    let name = v.as_str().ok_or_else(|| bad(k, v))?;
                    self.power = PowerSchedule::parse(name).ok_or_else(|| {
                        ConfigError::Invalid(format!("unknown power schedule {name:?}"))
                    })?;
                }
                "lr" => self.lr = v.as_f64().ok_or_else(|| bad(k, v))?,
                "noniid" => self.noniid = v.as_bool().ok_or_else(|| bad(k, v))?,
                "seed" => self.seed = v.as_i64().ok_or_else(|| bad(k, v))? as u64,
                "mean_removal_rounds" => {
                    self.mean_removal_rounds = v.as_usize().ok_or_else(|| bad(k, v))?
                }
                "qsgd_levels" => {
                    self.qsgd_levels = v.as_usize().ok_or_else(|| bad(k, v))? as u32
                }
                "backend" => {
                    let name = v.as_str().ok_or_else(|| bad(k, v))?;
                    self.backend = Backend::parse(name).ok_or_else(|| {
                        ConfigError::Invalid(format!("unknown backend {name:?}"))
                    })?;
                }
                "eval_every" => self.eval_every = v.as_usize().ok_or_else(|| bad(k, v))?,
                "amp_iters" => self.amp_iters = v.as_usize().ok_or_else(|| bad(k, v))?,
                "amp_tol" => self.amp_tol = v.as_f64().ok_or_else(|| bad(k, v))?,
                "amp_threshold_mult" => {
                    self.amp_threshold_mult = v.as_f64().ok_or_else(|| bad(k, v))?
                }
                other => {
                    return Err(ConfigError::Invalid(format!("unknown key {other:?}")));
                }
            }
        }
        Ok(())
    }

    fn apply_dataset(
        &mut self,
        s: &std::collections::BTreeMap<String, Value>,
    ) -> Result<(), ConfigError> {
        let kind = s
            .get("kind")
            .and_then(|v| v.as_str())
            .unwrap_or("synthetic");
        match kind {
            "synthetic" => {
                let train = s
                    .get("train")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(25_000);
                let test = s.get("test").and_then(|v| v.as_usize()).unwrap_or(2_000);
                self.dataset = DatasetSpec::Synthetic { train, test };
            }
            "mnist" => {
                let dir = s
                    .get("dir")
                    .and_then(|v| v.as_str())
                    .unwrap_or("data/mnist")
                    .to_string();
                self.dataset = DatasetSpec::MnistIdx { dir };
            }
            other => {
                return Err(ConfigError::Invalid(format!("unknown dataset {other:?}")));
            }
        }
        Ok(())
    }

    /// Single-line summary, echoed into logs and CSV headers.
    pub fn summary(&self) -> String {
        format!(
            "{} M={} B={} s={} k={} P̄={} σ²={} T={} power={} noniid={} seed={}",
            self.scheme.name(),
            self.devices,
            self.local_samples,
            self.channel_uses,
            self.sparsity,
            self.pbar,
            self.noise_var,
            self.iterations,
            self.power.name(),
            self.noniid,
            self.seed
        )
    }
}

/// Parse helper used by the launcher: read a whole document and report
/// unknown sections.
pub fn load_document(text: &str) -> Result<Document, ConfigError> {
    Ok(parser::parse(text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        RunConfig::default().validate(7850).unwrap();
    }

    #[test]
    fn toml_roundtrip_overrides() {
        let cfg = RunConfig::from_toml(
            r#"
[run]
scheme = "ddsgd"
devices = 10
local_samples = 2000
pbar = 200.0
power = "hl"
noniid = true
[dataset]
kind = "synthetic"
train = 20000
test = 1000
"#,
        )
        .unwrap();
        assert_eq!(cfg.scheme, Scheme::DDsgd);
        assert_eq!(cfg.devices, 10);
        assert_eq!(cfg.local_samples, 2000);
        assert_eq!(cfg.power, PowerSchedule::Hl);
        assert!(cfg.noniid);
        assert_eq!(
            cfg.dataset,
            DatasetSpec::Synthetic {
                train: 20000,
                test: 1000
            }
        );
        cfg.validate(7850).unwrap();
    }

    #[test]
    fn unknown_key_rejected() {
        let err = RunConfig::from_toml("bogus_key = 1\n").unwrap_err();
        assert!(err.to_string().contains("bogus_key"));
    }

    #[test]
    fn adsgd_requires_k_below_s() {
        let cfg = RunConfig {
            sparsity: 4000,
            channel_uses: 3925,
            ..RunConfig::default()
        };
        assert!(cfg.validate(7850).is_err());
    }

    #[test]
    fn mean_removal_needs_three_uses() {
        let cfg = RunConfig {
            channel_uses: 2,
            sparsity: 1,
            mean_removal_rounds: 5,
            ..RunConfig::default()
        };
        assert!(cfg.validate(7850).is_err());
        let cfg2 = RunConfig {
            channel_uses: 2,
            sparsity: 1,
            mean_removal_rounds: 0,
            ..cfg
        };
        cfg2.validate(7850).unwrap();
    }

    #[test]
    fn scheme_kind_classification() {
        assert_eq!(Scheme::ADsgd.kind(), LinkKind::Analog);
        assert_eq!(Scheme::DDsgd.kind(), LinkKind::Digital);
        assert_eq!(Scheme::SignSgd.kind(), LinkKind::Digital);
        assert_eq!(Scheme::Qsgd.kind(), LinkKind::Digital);
        assert_eq!(Scheme::ErrorFree.kind(), LinkKind::Passthrough);
        assert_eq!(LinkKind::Analog.name(), "analog");
    }

    #[test]
    fn scheme_and_power_parsing() {
        assert_eq!(Scheme::parse("A-DSGD"), Some(Scheme::ADsgd));
        assert_eq!(Scheme::parse("qsgd"), Some(Scheme::Qsgd));
        assert_eq!(Scheme::parse("nope"), None);
        assert_eq!(PowerSchedule::parse("LH-stair"), Some(PowerSchedule::LhStair));
    }

    #[test]
    fn mb_must_fit_in_corpus() {
        let cfg = RunConfig {
            devices: 100,
            local_samples: 1000,
            dataset: DatasetSpec::Synthetic {
                train: 25_000,
                test: 1000,
            },
            ..RunConfig::default()
        };
        assert!(cfg.validate(7850).is_err());
    }
}
