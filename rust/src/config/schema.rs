//! Experiment configuration schema + validation.
//!
//! A `RunConfig` fully determines one training run: scheme, channel, power,
//! data distribution, optimizer, and backend. Configs are constructed from
//! presets (`config::presets`), from TOML files (`RunConfig::from_toml`), or
//! from CLI overrides (`apply_overrides`).

use super::parser::{self, Document, Value};

/// Which transmission scheme the run uses (Section III / IV of the paper,
/// plus the fading-MAC extensions of the companion works).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// Analog over-the-air DSGD (Algorithm 1).
    ADsgd,
    /// A-DSGD over a fading MAC with CSI at the transmitters: truncated
    /// channel inversion, devices below the gain threshold stay silent
    /// (Amiri & Gündüz 2019, "Federated Learning over Wireless Fading
    /// Channels").
    FadingADsgd,
    /// A-DSGD over a fading MAC with *no* CSI at the transmitters: devices
    /// transmit blindly at full power and the gains average out across the
    /// fleet (Amiri, Duman & Gündüz 2019).
    BlindADsgd,
    /// Decentralized over-the-air DSGD: no parameter server — each device
    /// keeps its own model replica and averages with its graph neighbors
    /// via analog superposition (Xing, Simeone & Bi 2021, "Federated
    /// Learning over Wireless Device-to-Device Networks").
    D2dADsgd,
    /// Digital DSGD: SBC-style quantizer + capacity bit budget (Section III).
    DDsgd,
    /// SignSGD baseline through the same capacity pipe (Eq. 43).
    SignSgd,
    /// QSGD baseline through the same capacity pipe (Eq. 44).
    Qsgd,
    /// Noiseless benchmark: PS receives the exact average gradient.
    ErrorFree,
}

impl Scheme {
    pub fn parse(s: &str) -> Option<Scheme> {
        Some(match s.to_ascii_lowercase().as_str() {
            "adsgd" | "a-dsgd" | "analog" => Scheme::ADsgd,
            "fading" | "fading-adsgd" | "fading-csi" | "csi" => Scheme::FadingADsgd,
            "blind" | "blind-adsgd" | "no-csi" => Scheme::BlindADsgd,
            "d2d" | "d2d-adsgd" | "decentralized" | "consensus" => Scheme::D2dADsgd,
            "ddsgd" | "d-dsgd" | "digital" => Scheme::DDsgd,
            "signsgd" | "s-dsgd" | "sign" => Scheme::SignSgd,
            "qsgd" | "q-dsgd" => Scheme::Qsgd,
            "errorfree" | "error-free" | "shared-link" => Scheme::ErrorFree,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::ADsgd => "A-DSGD",
            Scheme::FadingADsgd => "A-DSGD-fading",
            Scheme::BlindADsgd => "A-DSGD-blind",
            Scheme::D2dADsgd => "D2D-A-DSGD",
            Scheme::DDsgd => "D-DSGD",
            Scheme::SignSgd => "SignSGD",
            Scheme::Qsgd => "QSGD",
            Scheme::ErrorFree => "error-free",
        }
    }

    /// Canonical lower-case spelling accepted by [`Scheme::parse`] — the
    /// form [`RunConfig::to_toml`] emits. [`Scheme::name`] is display
    /// metadata and not always parseable (`"A-DSGD-fading"` has no parse
    /// alias), so round-tripping configs through TOML must go through this
    /// spelling instead.
    pub fn config_name(&self) -> &'static str {
        match self {
            Scheme::ADsgd => "adsgd",
            Scheme::FadingADsgd => "fading-adsgd",
            Scheme::BlindADsgd => "blind-adsgd",
            Scheme::D2dADsgd => "d2d",
            Scheme::DDsgd => "ddsgd",
            Scheme::SignSgd => "signsgd",
            Scheme::Qsgd => "qsgd",
            Scheme::ErrorFree => "error-free",
        }
    }

    /// Which transmission-pipeline family serves this scheme. The trainer
    /// never branches on `Scheme` directly — it builds the matching
    /// [`crate::coordinator::link::LinkScheme`] implementation and drives
    /// that; this classification is the config-side half of that factory.
    pub fn kind(&self) -> LinkKind {
        match self {
            Scheme::ADsgd => LinkKind::Analog,
            Scheme::FadingADsgd | Scheme::BlindADsgd => LinkKind::Fading,
            Scheme::D2dADsgd => LinkKind::D2d,
            Scheme::DDsgd | Scheme::SignSgd | Scheme::Qsgd => LinkKind::Digital,
            Scheme::ErrorFree => LinkKind::Passthrough,
        }
    }
}

/// The transmission-pipeline families: uncoded analog superposition,
/// analog superposition under per-device fading gains, separation-based
/// digital, and the noiseless benchmark that bypasses the channel entirely.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkKind {
    /// Device gradients bypass the channel (error-free shared link).
    Passthrough,
    /// Capacity-budgeted digital payloads (D-DSGD, SignSGD, QSGD).
    Digital,
    /// Uncoded analog superposition over the static Gaussian MAC (A-DSGD).
    Analog,
    /// Analog superposition over a fading MAC with per-device, per-round
    /// gains h_m(t), partial participation and straggler deadlines.
    Fading,
    /// Decentralized device-to-device consensus: no PS, per-device model
    /// replicas, neighborhood superposition over per-edge Gaussian MACs
    /// plus a Metropolis mixing step on a [`TopologyConfig`] graph.
    D2d,
}

impl LinkKind {
    pub fn name(&self) -> &'static str {
        match self {
            LinkKind::Passthrough => "passthrough",
            LinkKind::Digital => "digital",
            LinkKind::Analog => "analog",
            LinkKind::Fading => "fading",
            LinkKind::D2d => "d2d",
        }
    }
}

/// Graph family for the device-to-device topology (see [`crate::topology`]).
/// Every family is built deterministically from the `[topology]` seed, so
/// two runs with the same config see the same graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GraphFamily {
    /// Every pair of devices is connected. Metropolis weights degenerate to
    /// the uniform 1/M matrix, which collapses D2D consensus to the star
    /// A-DSGD average (pinned bit-for-bit by the degeneracy golden in
    /// `rust/tests/golden_schemes.rs`).
    Full,
    /// Cycle with `degree` neighbors on each side (degree 1 = plain ring).
    Ring,
    /// 2-D torus on the most-square `r × c` factorization of M (wrap-around
    /// grid; degenerates to a ring when M is prime).
    Torus,
    /// Erdős–Rényi G(M, p), deterministically resampled (and, as a last
    /// resort, minimally augmented) until connected.
    ErdosRenyi,
    /// Hub-and-spoke: device 0 is the hub. The D2D analogue of the PS star.
    Star,
}

impl GraphFamily {
    pub fn parse(s: &str) -> Option<GraphFamily> {
        Some(match s.to_ascii_lowercase().as_str() {
            "full" | "complete" | "fully-connected" => GraphFamily::Full,
            "ring" | "cycle" => GraphFamily::Ring,
            "torus" | "grid" => GraphFamily::Torus,
            "er" | "erdos-renyi" | "erdos" => GraphFamily::ErdosRenyi,
            "star" | "hub" => GraphFamily::Star,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            GraphFamily::Full => "full",
            GraphFamily::Ring => "ring",
            GraphFamily::Torus => "torus",
            GraphFamily::ErdosRenyi => "er",
            GraphFamily::Star => "star",
        }
    }
}

/// How mixing weights are derived from the graph. Both rules produce a
/// symmetric doubly-stochastic matrix on any connected graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MixingRule {
    /// Metropolis–Hastings: W_ij = 1/(1 + max(deg_i, deg_j)) on edges,
    /// diagonal takes the remainder. Needs only local degree knowledge.
    Metropolis,
    /// Max-degree weights: W_ij = 1/(1 + Δ) on edges with Δ the global
    /// maximum degree; slower mixing but a single global constant.
    MaxDegree,
}

impl MixingRule {
    pub fn parse(s: &str) -> Option<MixingRule> {
        Some(match s.to_ascii_lowercase().as_str() {
            "metropolis" | "metropolis-hastings" | "mh" => MixingRule::Metropolis,
            "max-degree" | "maxdeg" | "uniform" => MixingRule::MaxDegree,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            MixingRule::Metropolis => "metropolis",
            MixingRule::MaxDegree => "max-degree",
        }
    }
}

/// The `[topology]` table: which D2D communication graph the decentralized
/// schemes run over, and how its mixing weights are built.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TopologyConfig {
    pub family: GraphFamily,
    /// Ring half-degree (neighbors on each side). Ignored by other families.
    pub degree: usize,
    /// Erdős–Rényi edge probability. Ignored by other families.
    pub p: f64,
    pub mixing: MixingRule,
    /// Graph seed; 0 derives one from the run seed.
    pub seed: u64,
}

impl Default for TopologyConfig {
    fn default() -> Self {
        TopologyConfig {
            family: GraphFamily::Ring,
            degree: 1,
            p: 0.5,
            mixing: MixingRule::Metropolis,
            seed: 0,
        }
    }
}

impl TopologyConfig {
    /// Single-line summary echoed into run logs.
    pub fn describe(&self) -> String {
        let mut s = format!("{}", self.family.name());
        match self.family {
            GraphFamily::Ring => s.push_str(&format!(":deg{}", self.degree)),
            GraphFamily::ErdosRenyi => s.push_str(&format!(":p{}", self.p)),
            _ => {}
        }
        s.push_str(&format!("/{}", self.mixing.name()));
        s
    }

    pub fn validate(&self, devices: usize) -> Result<(), String> {
        if devices < 2 {
            return Err(format!("D2D topology needs M >= 2 devices, got {devices}"));
        }
        if self.family == GraphFamily::Ring && (self.degree == 0 || self.degree >= devices) {
            return Err(format!(
                "ring degree must satisfy 1 <= degree < M, got degree={} M={devices}",
                self.degree
            ));
        }
        if self.family == GraphFamily::ErdosRenyi && !(self.p > 0.0 && self.p <= 1.0) {
            return Err(format!("Erdős–Rényi p must be in (0, 1], got {}", self.p));
        }
        Ok(())
    }
}

/// Distribution of the per-device, per-round channel-gain magnitude h_m(t).
/// Every variant is normalized so unit-mean-square (`E[h²] = 1`) is the
/// natural default: a fading run then has the same *average* received power
/// as the static MAC.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FadingDist {
    /// Rayleigh magnitude with E[h²] = 1 (i.i.d. complex-Gaussian taps).
    Rayleigh,
    /// Fixed gain h ≡ v. `Constant(1.0)` degrades the fading link to the
    /// static MAC exactly (the degeneracy golden in
    /// `rust/tests/golden_schemes.rs` pins this bit-for-bit).
    Constant(f64),
    /// Uniform magnitude on [lo, hi).
    Uniform(f64, f64),
}

impl FadingDist {
    /// Parse `"rayleigh"`, `"constant:<v>"` or `"uniform:<lo>:<hi>"`.
    pub fn parse(s: &str) -> Option<FadingDist> {
        let lower = s.to_ascii_lowercase();
        let mut parts = lower.split(':');
        let head = parts.next()?;
        match head {
            "rayleigh" => Some(FadingDist::Rayleigh),
            "constant" | "const" => {
                let v: f64 = parts.next()?.parse().ok()?;
                Some(FadingDist::Constant(v))
            }
            "uniform" => {
                let lo: f64 = parts.next()?.parse().ok()?;
                let hi: f64 = parts.next()?.parse().ok()?;
                Some(FadingDist::Uniform(lo, hi))
            }
            _ => None,
        }
    }

    /// Canonical string form (round-trips through [`FadingDist::parse`]).
    pub fn describe(&self) -> String {
        match self {
            FadingDist::Rayleigh => "rayleigh".into(),
            FadingDist::Constant(v) => format!("constant:{v}"),
            FadingDist::Uniform(lo, hi) => format!("uniform:{lo}:{hi}"),
        }
    }

    /// Gain values must be non-negative magnitudes.
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            FadingDist::Rayleigh => Ok(()),
            FadingDist::Constant(v) if v > 0.0 && v.is_finite() => Ok(()),
            FadingDist::Constant(v) => Err(format!("constant gain must be > 0, got {v}")),
            FadingDist::Uniform(lo, hi) if 0.0 <= lo && lo < hi && hi.is_finite() => Ok(()),
            FadingDist::Uniform(lo, hi) => {
                Err(format!("uniform gain needs 0 <= lo < hi, got [{lo}, {hi})"))
            }
        }
    }
}

/// Round-level device-subset selection applied in front of
/// `DeviceSet::encode` (partial participation).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ParticipationPolicy {
    /// Every device transmits every round.
    Full,
    /// A uniformly random K-subset per round (PS-scheduled). `K = M` is
    /// bit-identical to `Full` (pinned by the degeneracy golden).
    UniformK(usize),
    /// Only devices whose current gain h_m(t) clears the threshold are
    /// scheduled (opportunistic, needs CSI at the scheduler).
    GainThreshold(f64),
}

impl ParticipationPolicy {
    /// Parse `"full"`, `"uniform:<K>"` or `"gain:<threshold>"`.
    pub fn parse(s: &str) -> Option<ParticipationPolicy> {
        let lower = s.to_ascii_lowercase();
        let mut parts = lower.split(':');
        match parts.next()? {
            "full" | "all" => Some(ParticipationPolicy::Full),
            "uniform" | "uniform-k" => {
                let k: usize = parts.next()?.parse().ok()?;
                Some(ParticipationPolicy::UniformK(k))
            }
            "gain" | "gain-threshold" => {
                let th: f64 = parts.next()?.parse().ok()?;
                Some(ParticipationPolicy::GainThreshold(th))
            }
            _ => None,
        }
    }

    /// Canonical string form (round-trips through `parse`).
    pub fn describe(&self) -> String {
        match self {
            ParticipationPolicy::Full => "full".into(),
            ParticipationPolicy::UniformK(k) => format!("uniform:{k}"),
            ParticipationPolicy::GainThreshold(th) => format!("gain:{th}"),
        }
    }
}

/// Power allocation across iterations (Fig. 3, Eq. 45a–c). The schedule is
/// normalized so that (1/T)Σ P_t = P̄ holds for every variant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PowerSchedule {
    /// P_t = P̄ for all t.
    Constant,
    /// Eq. 45a: linear ramp 0.5·P̄ → 1.5·P̄ ("LH, stair").
    LhStair,
    /// Eq. 45b: three equal blocks 0.5/1.0/1.5 × P̄ (low→high).
    Lh,
    /// Eq. 45c: three equal blocks 1.5/1.0/0.5 × P̄ (high→low).
    Hl,
}

impl PowerSchedule {
    pub fn parse(s: &str) -> Option<PowerSchedule> {
        Some(match s.to_ascii_lowercase().as_str() {
            "const" | "constant" => PowerSchedule::Constant,
            "lhstair" | "lh-stair" | "stair" => PowerSchedule::LhStair,
            "lh" => PowerSchedule::Lh,
            "hl" => PowerSchedule::Hl,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PowerSchedule::Constant => "const",
            PowerSchedule::LhStair => "LH-stair",
            PowerSchedule::Lh => "LH",
            PowerSchedule::Hl => "HL",
        }
    }
}

/// Gradient/compute backend: pure rust reference, or the AOT-compiled JAX
/// graphs executed through PJRT (`runtime::pjrt`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Rust,
    Pjrt,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        Some(match s.to_ascii_lowercase().as_str() {
            "rust" => Backend::Rust,
            "pjrt" | "xla" => Backend::Pjrt,
            _ => return None,
        })
    }
}

/// Where training data comes from.
#[derive(Clone, Debug, PartialEq)]
pub enum DatasetSpec {
    /// Deterministic MNIST-like synthetic corpus (see `data::synthetic`).
    Synthetic { train: usize, test: usize },
    /// Real MNIST IDX files under the given directory (auto-detected).
    MnistIdx { dir: String },
}

/// Full specification of one training run.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    pub scheme: Scheme,
    /// Number of devices M.
    pub devices: usize,
    /// Local dataset size B per device (batch = full local set, as in §VI).
    pub local_samples: usize,
    /// Channel uses per iteration, s.
    pub channel_uses: usize,
    /// A-DSGD sparsification level k.
    pub sparsity: usize,
    /// Average power constraint P̄ (per device, per iteration, Eq. 6).
    pub pbar: f64,
    /// Channel noise variance σ².
    pub noise_var: f64,
    /// Number of DSGD iterations T.
    pub iterations: usize,
    pub power: PowerSchedule,
    /// Adam step size at the PS.
    pub lr: f64,
    /// Non-IID data split (two classes per device) vs IID.
    pub noniid: bool,
    pub seed: u64,
    /// Use the §IV-A mean-removal variant for the first N iterations.
    pub mean_removal_rounds: usize,
    /// QSGD quantization bits l_Q (paper uses l_Q = 2).
    pub qsgd_levels: u32,
    pub backend: Backend,
    pub dataset: DatasetSpec,
    /// Evaluate test accuracy every `eval_every` iterations.
    pub eval_every: usize,
    /// AMP decoder iteration cap / tolerance / denoiser threshold α
    /// (τ = α‖r‖/√s).
    pub amp_iters: usize,
    pub amp_tol: f64,
    pub amp_threshold_mult: f64,
    /// Channel-gain distribution for the fading schemes (ignored by the
    /// static-MAC schemes).
    pub fading: FadingDist,
    /// Truncated channel inversion: a CSI device with h_m(t) at or below
    /// this gain stays silent for the round (`<=`, so h = 0 can never be
    /// inverted). Ignored by the blind variant.
    pub csi_threshold: f64,
    /// Round-level device-subset selection (fading schemes).
    pub participation: ParticipationPolicy,
    /// Round deadline in (simulated) seconds; devices whose modeled encode
    /// latency exceeds it are dropped from aggregation. `<= 0` disables
    /// straggler dropping.
    pub deadline_secs: f64,
    /// Mean of the per-device encode-latency model (simulated seconds).
    /// `<= 0` disables the latency model (no device ever straggles).
    pub latency_mean_secs: f64,
    /// Gauss–Markov (AR(1)) time correlation of the fading gains: 0 keeps
    /// the i.i.d. per-round draws bit-for-bit; rho ∈ (0, 1) correlates
    /// h_m(t) with h_m(t−1) through an AR(1) chain on the underlying
    /// Gaussian state (see `channel::fading`).
    pub fading_rho: f64,
    /// D2D communication graph for the decentralized schemes (ignored by
    /// the PS-centric schemes).
    pub topology: TopologyConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            scheme: Scheme::ADsgd,
            devices: 25,
            local_samples: 1000,
            channel_uses: 3925, // d/2 for d = 7850
            sparsity: 1962,     // s/2
            pbar: 500.0,
            noise_var: 1.0,
            iterations: 100,
            power: PowerSchedule::Constant,
            lr: 1e-3,
            noniid: false,
            seed: 1,
            mean_removal_rounds: 20,
            qsgd_levels: 2,
            backend: Backend::Rust,
            dataset: DatasetSpec::Synthetic {
                train: 25_000,
                test: 2_000,
            },
            eval_every: 5,
            amp_iters: 30,
            amp_tol: 1e-4,
            amp_threshold_mult: 1.1,
            fading: FadingDist::Rayleigh,
            csi_threshold: 0.2,
            participation: ParticipationPolicy::Full,
            deadline_secs: 0.0,
            latency_mean_secs: 0.0,
            fading_rho: 0.0,
            topology: TopologyConfig::default(),
        }
    }
}

#[derive(Debug)]
pub enum ConfigError {
    Parse(parser::ParseError),
    Invalid(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Parse(e) => write!(f, "config parse error: {e}"),
            ConfigError::Invalid(msg) => write!(f, "invalid config: {msg}"),
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConfigError::Parse(e) => Some(e),
            ConfigError::Invalid(_) => None,
        }
    }
}

impl From<parser::ParseError> for ConfigError {
    fn from(e: parser::ParseError) -> ConfigError {
        ConfigError::Parse(e)
    }
}

impl RunConfig {
    /// Validate the cross-field constraints the schemes rely on.
    pub fn validate(&self, model_dim: usize) -> Result<(), ConfigError> {
        let fail = |msg: String| Err(ConfigError::Invalid(msg));
        if self.devices == 0 {
            return fail("devices must be >= 1".into());
        }
        if self.local_samples == 0 {
            return fail("local_samples must be >= 1".into());
        }
        if self.iterations == 0 {
            return fail("iterations must be >= 1".into());
        }
        if self.pbar <= 0.0 {
            return fail("pbar must be > 0".into());
        }
        if self.noise_var <= 0.0 {
            return fail("noise_var must be > 0".into());
        }
        if matches!(
            self.scheme.kind(),
            LinkKind::Analog | LinkKind::Fading | LinkKind::D2d
        ) {
            // A-DSGD needs s >= 2 (s̃ = s−1 plus the scaling channel use);
            // mean removal needs s >= 3 (§IV-A). The fading and D2D
            // variants reuse the same framing, so the same floor applies.
            let min_s = if self.mean_removal_rounds > 0 { 3 } else { 2 };
            if self.channel_uses < min_s {
                return fail(format!(
                    "A-DSGD requires s >= {min_s}, got {}",
                    self.channel_uses
                ));
            }
            if self.sparsity == 0 || self.sparsity > model_dim {
                return fail(format!(
                    "sparsity k={} out of range (1..={model_dim})",
                    self.sparsity
                ));
            }
            if self.sparsity >= self.channel_uses {
                // Assumption 3 / Lemma 1 need k < s̃ for AMP recovery.
                return fail(format!(
                    "A-DSGD requires k < s (k={}, s={})",
                    self.sparsity, self.channel_uses
                ));
            }
        }
        if self.channel_uses > model_dim {
            return fail(format!(
                "s={} exceeds model dimension d={model_dim}; uncoded transmission would \
                 not need compression",
                self.channel_uses
            ));
        }
        if matches!(self.scheme.kind(), LinkKind::Fading | LinkKind::D2d) {
            if let Err(msg) = self.fading.validate() {
                return fail(format!("fading distribution: {msg}"));
            }
            if !(self.fading_rho >= 0.0 && self.fading_rho < 1.0) {
                return fail(format!(
                    "fading rho must be in [0, 1), got {}",
                    self.fading_rho
                ));
            }
        }
        // Partial participation serves the fading analog family and the
        // digital family (silent digital devices bank via error
        // accumulation); validate the policy for both.
        if matches!(self.scheme.kind(), LinkKind::Fading | LinkKind::Digital) {
            match self.participation {
                ParticipationPolicy::UniformK(k) if k == 0 || k > self.devices => {
                    return fail(format!(
                        "uniform-K participation needs 1 <= K <= M, got K={k}, M={}",
                        self.devices
                    ));
                }
                ParticipationPolicy::GainThreshold(th) if !(th >= 0.0 && th.is_finite()) => {
                    return fail(format!(
                        "gain-threshold participation needs a finite threshold >= 0, got {th}"
                    ));
                }
                _ => {}
            }
        }
        if self.scheme.kind() == LinkKind::Fading {
            if !(self.csi_threshold >= 0.0 && self.csi_threshold.is_finite()) {
                return fail(format!(
                    "csi_threshold must be finite and >= 0, got {}",
                    self.csi_threshold
                ));
            }
            if self.deadline_secs > 0.0 && self.latency_mean_secs <= 0.0 {
                return fail(
                    "deadline_secs is set but latency_mean_secs <= 0: no device would \
                     ever straggle — set a latency model or drop the deadline"
                        .into(),
                );
            }
        }
        if self.scheme.kind() == LinkKind::D2d {
            if let Err(msg) = self.topology.validate(self.devices) {
                return fail(format!("topology: {msg}"));
            }
        }
        match &self.dataset {
            DatasetSpec::Synthetic { train, test } => {
                if self.devices * self.local_samples > *train {
                    return fail(format!(
                        "M*B = {} exceeds synthetic train size {train}",
                        self.devices * self.local_samples
                    ));
                }
                if *test == 0 {
                    return fail("test set must be non-empty".into());
                }
            }
            DatasetSpec::MnistIdx { dir } => {
                if dir.is_empty() {
                    return fail("mnist dir must be non-empty".into());
                }
            }
        }
        Ok(())
    }

    /// Load from a TOML-subset file (single `[run]` section or root keys).
    pub fn from_toml(text: &str) -> Result<RunConfig, ConfigError> {
        let doc = parser::parse(text)?;
        let mut cfg = RunConfig::default();
        let section = doc
            .get("run")
            .filter(|s| !s.is_empty())
            .or_else(|| doc.get(""))
            .cloned()
            .unwrap_or_default();
        cfg.apply_section(&section)?;
        // Allow a separate [dataset] section.
        if let Some(ds) = doc.get("dataset") {
            cfg.apply_dataset(ds)?;
        }
        // Optional [fading] table: dist + AR(1) time-correlation knob.
        if let Some(fd) = doc.get("fading") {
            cfg.apply_fading(fd)?;
        }
        // Optional [topology] table for the D2D schemes.
        if let Some(topo) = doc.get("topology") {
            cfg.apply_topology(topo)?;
        }
        Ok(cfg)
    }

    fn apply_section(
        &mut self,
        s: &std::collections::BTreeMap<String, Value>,
    ) -> Result<(), ConfigError> {
        let bad = |k: &str, v: &Value| {
            ConfigError::Invalid(format!("key {k:?}: unexpected value {v:?}"))
        };
        for (k, v) in s {
            match k.as_str() {
                "scheme" => {
                    let name = v.as_str().ok_or_else(|| bad(k, v))?;
                    self.scheme =
                        Scheme::parse(name).ok_or_else(|| {
                            ConfigError::Invalid(format!("unknown scheme {name:?}"))
                        })?;
                }
                "devices" => self.devices = v.as_usize().ok_or_else(|| bad(k, v))?,
                "local_samples" => {
                    self.local_samples = v.as_usize().ok_or_else(|| bad(k, v))?
                }
                "channel_uses" => {
                    self.channel_uses = v.as_usize().ok_or_else(|| bad(k, v))?
                }
                "sparsity" => self.sparsity = v.as_usize().ok_or_else(|| bad(k, v))?,
                "pbar" => self.pbar = v.as_f64().ok_or_else(|| bad(k, v))?,
                "noise_var" => self.noise_var = v.as_f64().ok_or_else(|| bad(k, v))?,
                "iterations" => self.iterations = v.as_usize().ok_or_else(|| bad(k, v))?,
                "power" => {
                    let name = v.as_str().ok_or_else(|| bad(k, v))?;
                    self.power = PowerSchedule::parse(name).ok_or_else(|| {
                        ConfigError::Invalid(format!("unknown power schedule {name:?}"))
                    })?;
                }
                "lr" => self.lr = v.as_f64().ok_or_else(|| bad(k, v))?,
                "noniid" => self.noniid = v.as_bool().ok_or_else(|| bad(k, v))?,
                "seed" => self.seed = v.as_i64().ok_or_else(|| bad(k, v))? as u64,
                "mean_removal_rounds" => {
                    self.mean_removal_rounds = v.as_usize().ok_or_else(|| bad(k, v))?
                }
                "qsgd_levels" => {
                    self.qsgd_levels = v.as_usize().ok_or_else(|| bad(k, v))? as u32
                }
                "backend" => {
                    let name = v.as_str().ok_or_else(|| bad(k, v))?;
                    self.backend = Backend::parse(name).ok_or_else(|| {
                        ConfigError::Invalid(format!("unknown backend {name:?}"))
                    })?;
                }
                "eval_every" => self.eval_every = v.as_usize().ok_or_else(|| bad(k, v))?,
                "amp_iters" => self.amp_iters = v.as_usize().ok_or_else(|| bad(k, v))?,
                "amp_tol" => self.amp_tol = v.as_f64().ok_or_else(|| bad(k, v))?,
                "amp_threshold_mult" => {
                    self.amp_threshold_mult = v.as_f64().ok_or_else(|| bad(k, v))?
                }
                "fading" => {
                    let name = v.as_str().ok_or_else(|| bad(k, v))?;
                    self.fading = FadingDist::parse(name).ok_or_else(|| {
                        ConfigError::Invalid(format!("unknown fading distribution {name:?}"))
                    })?;
                }
                "csi_threshold" => {
                    self.csi_threshold = v.as_f64().ok_or_else(|| bad(k, v))?
                }
                "participation" => {
                    let name = v.as_str().ok_or_else(|| bad(k, v))?;
                    self.participation = ParticipationPolicy::parse(name).ok_or_else(|| {
                        ConfigError::Invalid(format!("unknown participation policy {name:?}"))
                    })?;
                }
                "deadline_secs" => {
                    self.deadline_secs = v.as_f64().ok_or_else(|| bad(k, v))?
                }
                "latency_mean_secs" => {
                    self.latency_mean_secs = v.as_f64().ok_or_else(|| bad(k, v))?
                }
                "fading_rho" => {
                    self.fading_rho = v.as_f64().ok_or_else(|| bad(k, v))?
                }
                other => {
                    return Err(ConfigError::Invalid(format!("unknown key {other:?}")));
                }
            }
        }
        Ok(())
    }

    fn apply_dataset(
        &mut self,
        s: &std::collections::BTreeMap<String, Value>,
    ) -> Result<(), ConfigError> {
        let kind = s
            .get("kind")
            .and_then(|v| v.as_str())
            .unwrap_or("synthetic");
        match kind {
            "synthetic" => {
                let train = s
                    .get("train")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(25_000);
                let test = s.get("test").and_then(|v| v.as_usize()).unwrap_or(2_000);
                self.dataset = DatasetSpec::Synthetic { train, test };
            }
            "mnist" => {
                let dir = s
                    .get("dir")
                    .and_then(|v| v.as_str())
                    .unwrap_or("data/mnist")
                    .to_string();
                self.dataset = DatasetSpec::MnistIdx { dir };
            }
            other => {
                return Err(ConfigError::Invalid(format!("unknown dataset {other:?}")));
            }
        }
        Ok(())
    }

    fn apply_fading(
        &mut self,
        s: &std::collections::BTreeMap<String, Value>,
    ) -> Result<(), ConfigError> {
        let bad = |k: &str, v: &Value| {
            ConfigError::Invalid(format!("[fading] key {k:?}: unexpected value {v:?}"))
        };
        for (k, v) in s {
            match k.as_str() {
                "dist" => {
                    let name = v.as_str().ok_or_else(|| bad(k, v))?;
                    self.fading = FadingDist::parse(name).ok_or_else(|| {
                        ConfigError::Invalid(format!("unknown fading distribution {name:?}"))
                    })?;
                }
                "rho" => self.fading_rho = v.as_f64().ok_or_else(|| bad(k, v))?,
                other => {
                    return Err(ConfigError::Invalid(format!(
                        "unknown [fading] key {other:?}"
                    )));
                }
            }
        }
        Ok(())
    }

    fn apply_topology(
        &mut self,
        s: &std::collections::BTreeMap<String, Value>,
    ) -> Result<(), ConfigError> {
        let bad = |k: &str, v: &Value| {
            ConfigError::Invalid(format!("[topology] key {k:?}: unexpected value {v:?}"))
        };
        for (k, v) in s {
            match k.as_str() {
                "family" => {
                    let name = v.as_str().ok_or_else(|| bad(k, v))?;
                    self.topology.family = GraphFamily::parse(name).ok_or_else(|| {
                        ConfigError::Invalid(format!("unknown graph family {name:?}"))
                    })?;
                }
                "degree" => self.topology.degree = v.as_usize().ok_or_else(|| bad(k, v))?,
                "p" => self.topology.p = v.as_f64().ok_or_else(|| bad(k, v))?,
                "mixing" => {
                    let name = v.as_str().ok_or_else(|| bad(k, v))?;
                    self.topology.mixing = MixingRule::parse(name).ok_or_else(|| {
                        ConfigError::Invalid(format!("unknown mixing rule {name:?}"))
                    })?;
                }
                "seed" => self.topology.seed = v.as_i64().ok_or_else(|| bad(k, v))? as u64,
                other => {
                    return Err(ConfigError::Invalid(format!(
                        "unknown [topology] key {other:?}"
                    )));
                }
            }
        }
        Ok(())
    }

    /// The round deadline as an `Option` (`None` when disabled): the form
    /// the link layer consumes via `RoundCtx::deadline`.
    pub fn deadline(&self) -> Option<f64> {
        (self.deadline_secs > 0.0).then_some(self.deadline_secs)
    }

    /// Single-line summary, echoed into logs and CSV headers. Fading runs
    /// append their scenario knobs — without them the fading sweep's runs
    /// (same M/B/s/k, different thresholds) would echo identical lines.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} M={} B={} s={} k={} P̄={} σ²={} T={} power={} noniid={} seed={}",
            self.scheme.name(),
            self.devices,
            self.local_samples,
            self.channel_uses,
            self.sparsity,
            self.pbar,
            self.noise_var,
            self.iterations,
            self.power.name(),
            self.noniid,
            self.seed
        );
        if self.scheme.kind() == LinkKind::D2d {
            s.push_str(&format!(
                " topo={} h={}",
                self.topology.describe(),
                self.fading.describe()
            ));
            if self.fading_rho > 0.0 {
                s.push_str(&format!(" rho={}", self.fading_rho));
            }
        }
        if self.scheme.kind() == LinkKind::Fading {
            s.push_str(&format!(
                " h={} part={}",
                self.fading.describe(),
                self.participation.describe()
            ));
            if self.fading_rho > 0.0 {
                s.push_str(&format!(" rho={}", self.fading_rho));
            }
            if self.scheme == Scheme::FadingADsgd {
                s.push_str(&format!(" h_min={}", self.csi_threshold));
            }
            if let Some(dl) = self.deadline() {
                s.push_str(&format!(
                    " deadline={dl}s latency_mean={}s",
                    self.latency_mean_secs
                ));
            }
        }
        s
    }

    /// Render this config as a TOML document [`RunConfig::from_toml`] reads
    /// back to an *equal* config (`PartialEq`, hence an identical cache
    /// key). This is how the fleet queue persists work items on disk so
    /// workers attached from other processes can reconstruct each run.
    ///
    /// Every field is emitted explicitly — like `canonical_config`, the
    /// exhaustive destructuring makes adding a `RunConfig` field without a
    /// TOML rendering a compile error rather than a silently lossy queue.
    pub fn to_toml(&self) -> String {
        // The TOML-subset parser reads integers through i64 (parser.rs
        // demotes larger literals to lossy floats), so a seed with the
        // top bit set cannot round-trip — and a silently altered seed
        // would address the wrong store entry. Fail loudly, like the
        // unescapable-string guard below.
        assert!(
            self.seed <= i64::MAX as u64 && self.topology.seed <= i64::MAX as u64,
            "seeds >= 2^63 cannot round-trip through the TOML subset (seed={}, topology.seed={})",
            self.seed,
            self.topology.seed
        );
        let RunConfig {
            scheme,
            devices,
            local_samples,
            channel_uses,
            sparsity,
            pbar,
            noise_var,
            iterations,
            power,
            lr,
            noniid,
            seed,
            mean_removal_rounds,
            qsgd_levels,
            backend,
            dataset,
            eval_every,
            amp_iters,
            amp_tol,
            amp_threshold_mult,
            fading,
            csi_threshold,
            participation,
            deadline_secs,
            latency_mean_secs,
            fading_rho,
            topology,
        } = self;
        let backend = match backend {
            Backend::Rust => "rust",
            Backend::Pjrt => "pjrt",
        };
        let mut out = format!(
            "[run]\nscheme = \"{}\"\ndevices = {devices}\nlocal_samples = {local_samples}\n\
             channel_uses = {channel_uses}\nsparsity = {sparsity}\npbar = {pbar}\n\
             noise_var = {noise_var}\niterations = {iterations}\npower = \"{}\"\nlr = {lr}\n\
             noniid = {noniid}\nseed = {seed}\nmean_removal_rounds = {mean_removal_rounds}\n\
             qsgd_levels = {qsgd_levels}\nbackend = \"{backend}\"\neval_every = {eval_every}\n\
             amp_iters = {amp_iters}\namp_tol = {amp_tol}\n\
             amp_threshold_mult = {amp_threshold_mult}\nfading = \"{}\"\n\
             csi_threshold = {csi_threshold}\nparticipation = \"{}\"\n\
             deadline_secs = {deadline_secs}\nlatency_mean_secs = {latency_mean_secs}\n\
             fading_rho = {fading_rho}\n",
            scheme.config_name(),
            power.name(),
            fading.describe(),
            participation.describe(),
        );
        match dataset {
            DatasetSpec::Synthetic { train, test } => {
                out.push_str(&format!(
                    "\n[dataset]\nkind = \"synthetic\"\ntrain = {train}\ntest = {test}\n"
                ));
            }
            DatasetSpec::MnistIdx { dir } => {
                // The config parser has no string escapes, so a dir with an
                // embedded quote or newline cannot be represented — and
                // silently rewriting it would change the config's cache key
                // on the far side of the queue (a worker would execute into
                // the wrong store entry). Identity-bearing strings fail
                // loudly; display metadata is sanitized lossily instead
                // (`parser::sanitize_display`).
                assert!(
                    !dir.contains('"') && !dir.contains('\n'),
                    "mnist dir {dir:?} contains characters the TOML subset cannot round-trip"
                );
                out.push_str(&format!("\n[dataset]\nkind = \"mnist\"\ndir = \"{dir}\"\n"));
            }
        }
        let TopologyConfig {
            family,
            degree,
            p,
            mixing,
            seed: topology_seed,
        } = topology;
        out.push_str(&format!(
            "\n[topology]\nfamily = \"{}\"\ndegree = {degree}\np = {p}\nmixing = \"{}\"\n\
             seed = {topology_seed}\n",
            family.name(),
            mixing.name(),
        ));
        out
    }
}

/// The `[campaign]` table: checkpoint/resume and run-cache policy for
/// experiment campaigns (`repro fig`, `repro all`, `repro resume`). These
/// knobs are campaign-level, not per-run — they never enter the
/// content-address of a run (see `campaign::store`), so changing the
/// snapshot cadence does not invalidate cached results.
#[derive(Clone, Debug, PartialEq)]
pub struct CampaignConfig {
    /// Snapshot the full trainer state every N rounds (plus once after the
    /// final round). 0 disables periodic snapshots — interrupted runs then
    /// restart from scratch, but finished results are still cached.
    pub snapshot_every: usize,
    /// Run-store directory. Empty (the default) derives `<out>/.campaign`
    /// from the results directory at launch time.
    pub store_dir: String,
    /// Resume partial runs from their latest snapshot instead of
    /// restarting them.
    pub resume: bool,
    /// Master switch; `false` bypasses the store entirely (the CLI's
    /// `--no-cache`).
    pub enabled: bool,
    /// Snapshot retention per store entry: how many distinct snapshot
    /// rounds to keep (latest + history). `<= 1` keeps only the latest
    /// blob (the pre-retention layout); larger values let a corrupted
    /// latest snapshot fall back to an earlier round instead of restarting
    /// the run, at the cost of `keep_last_n` blobs per partial entry.
    /// `repro gc` prunes stores down to this policy.
    pub keep_last_n: usize,
    /// Observability policy (`[telemetry]` table). Like the campaign knobs
    /// above, telemetry never enters a run's content-address — the event
    /// log is observe-only and toggling it cannot invalidate cached runs.
    pub telemetry: TelemetryConfig,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        CampaignConfig {
            snapshot_every: 20,
            store_dir: String::new(),
            resume: true,
            enabled: true,
            keep_last_n: 2,
            telemetry: TelemetryConfig::default(),
        }
    }
}

impl CampaignConfig {
    /// Read the `[campaign]` table from a parsed document (absent table =
    /// all defaults).
    pub fn from_doc(doc: &Document) -> Result<CampaignConfig, ConfigError> {
        let mut cfg = CampaignConfig::default();
        // `[telemetry]` is its own table but rides on the campaign config —
        // parse it first so a document with `[telemetry]` and no
        // `[campaign]` still takes effect.
        cfg.telemetry = TelemetryConfig::from_doc(doc)?;
        let Some(section) = doc.get("campaign") else {
            return Ok(cfg);
        };
        let bad = |k: &str, v: &Value| {
            ConfigError::Invalid(format!("[campaign] key {k:?}: unexpected value {v:?}"))
        };
        for (k, v) in section {
            match k.as_str() {
                "snapshot_every" => cfg.snapshot_every = v.as_usize().ok_or_else(|| bad(k, v))?,
                "store_dir" => {
                    cfg.store_dir = v.as_str().ok_or_else(|| bad(k, v))?.to_string()
                }
                "resume" => cfg.resume = v.as_bool().ok_or_else(|| bad(k, v))?,
                "enabled" => cfg.enabled = v.as_bool().ok_or_else(|| bad(k, v))?,
                "keep_last_n" => cfg.keep_last_n = v.as_usize().ok_or_else(|| bad(k, v))?,
                other => {
                    return Err(ConfigError::Invalid(format!(
                        "unknown [campaign] key {other:?}"
                    )));
                }
            }
        }
        Ok(cfg)
    }

    pub fn from_toml(text: &str) -> Result<CampaignConfig, ConfigError> {
        Self::from_doc(&parser::parse(text)?)
    }

    /// The store directory with the empty-means-derive default resolved
    /// against the results directory.
    pub fn store_dir_or(&self, out_dir: &str) -> String {
        if self.store_dir.is_empty() {
            format!("{out_dir}/.campaign")
        } else {
            self.store_dir.clone()
        }
    }
}

/// The `[telemetry]` table: event-sourced observability policy for
/// campaign stores (see `fleet::events`). Telemetry is observe-only — it
/// never enters a run's content-address and never perturbs a trajectory —
/// so it defaults to on.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TelemetryConfig {
    /// Master switch; `false` means no event log is attached and nothing
    /// is emitted (the CLI's `--no-telemetry`).
    pub enabled: bool,
    /// Emit a `round` telemetry event every N trainer rounds (the final
    /// round is always emitted). Must be >= 1; raise it for very long runs
    /// to bound event-log growth.
    pub every: usize,
    /// Link diagnostics probes: per-device `device` events and the
    /// `snr_db`/`power_headroom`/`participating` round payload (the
    /// CLI's `--no-diagnostics`). Probes are read-only, so this only
    /// trades event-log volume for visibility; `enabled = false`
    /// implies no diagnostics regardless of this flag.
    pub diagnostics: bool,
    /// Fleet-wide span tracing (`fleet::trace`, the CLI's `--trace`):
    /// persist worker-loop and trainer phase spans to the store for
    /// `repro trace`. Spans are pure wall-clock and cannot perturb a
    /// trajectory, but per-round phase spans are high-volume, so this
    /// defaults to off; `enabled = false` implies no tracing.
    pub trace: bool,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig { enabled: true, every: 1, diagnostics: true, trace: false }
    }
}

impl TelemetryConfig {
    /// Read the `[telemetry]` table from a parsed document (absent table =
    /// all defaults).
    pub fn from_doc(doc: &Document) -> Result<TelemetryConfig, ConfigError> {
        let mut cfg = TelemetryConfig::default();
        let Some(section) = doc.get("telemetry") else {
            return Ok(cfg);
        };
        let bad = |k: &str, v: &Value| {
            ConfigError::Invalid(format!("[telemetry] key {k:?}: unexpected value {v:?}"))
        };
        for (k, v) in section {
            match k.as_str() {
                "enabled" => cfg.enabled = v.as_bool().ok_or_else(|| bad(k, v))?,
                "every" => cfg.every = v.as_usize().ok_or_else(|| bad(k, v))?,
                "diagnostics" => cfg.diagnostics = v.as_bool().ok_or_else(|| bad(k, v))?,
                "trace" => cfg.trace = v.as_bool().ok_or_else(|| bad(k, v))?,
                other => {
                    return Err(ConfigError::Invalid(format!(
                        "unknown [telemetry] key {other:?}"
                    )));
                }
            }
        }
        if cfg.every == 0 {
            return Err(ConfigError::Invalid(
                "telemetry every must be >= 1".into(),
            ));
        }
        Ok(cfg)
    }

    pub fn from_toml(text: &str) -> Result<TelemetryConfig, ConfigError> {
        Self::from_doc(&parser::parse(text)?)
    }
}

/// The `[fleet]` table: multi-process worker execution policy for campaign
/// stores (`repro fleet`, `repro worker`). Like `[campaign]`, these knobs
/// are execution policy, not run identity — they never enter a run's
/// content-address, so the same store serves any fleet shape.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetConfig {
    /// Worker processes `repro fleet` spawns.
    pub workers: usize,
    /// Lease time-to-live: a run lease whose heartbeat is older than this
    /// is considered abandoned and may be reclaimed by another worker.
    pub lease_secs: f64,
    /// How often an executing worker refreshes its lease. Must be well
    /// under `lease_secs` or healthy workers would lose their runs.
    pub heartbeat_secs: f64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            workers: 4,
            lease_secs: 30.0,
            heartbeat_secs: 5.0,
        }
    }
}

impl FleetConfig {
    /// Read the `[fleet]` table from a parsed document (absent table = all
    /// defaults).
    pub fn from_doc(doc: &Document) -> Result<FleetConfig, ConfigError> {
        let mut cfg = FleetConfig::default();
        let Some(section) = doc.get("fleet") else {
            return Ok(cfg);
        };
        let bad = |k: &str, v: &Value| {
            ConfigError::Invalid(format!("[fleet] key {k:?}: unexpected value {v:?}"))
        };
        for (k, v) in section {
            match k.as_str() {
                "workers" => cfg.workers = v.as_usize().ok_or_else(|| bad(k, v))?,
                "lease_secs" => cfg.lease_secs = v.as_f64().ok_or_else(|| bad(k, v))?,
                "heartbeat_secs" => {
                    cfg.heartbeat_secs = v.as_f64().ok_or_else(|| bad(k, v))?
                }
                other => {
                    return Err(ConfigError::Invalid(format!(
                        "unknown [fleet] key {other:?}"
                    )));
                }
            }
        }
        Ok(cfg)
    }

    pub fn from_toml(text: &str) -> Result<FleetConfig, ConfigError> {
        Self::from_doc(&parser::parse(text)?)
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        let fail = |msg: String| Err(ConfigError::Invalid(msg));
        if self.workers == 0 {
            return fail("fleet workers must be >= 1".into());
        }
        if !(self.lease_secs > 0.0 && self.lease_secs.is_finite()) {
            return fail(format!("lease_secs must be finite and > 0, got {}", self.lease_secs));
        }
        if !(self.heartbeat_secs > 0.0 && self.heartbeat_secs.is_finite()) {
            return fail(format!(
                "heartbeat_secs must be finite and > 0, got {}",
                self.heartbeat_secs
            ));
        }
        if self.heartbeat_secs * 2.0 > self.lease_secs {
            return fail(format!(
                "heartbeat_secs = {} must be at most half of lease_secs = {} — a healthy \
                 worker must refresh its lease well before rivals may reclaim it",
                self.heartbeat_secs, self.lease_secs
            ));
        }
        Ok(())
    }
}

/// The `[serve]` table: the telemetry server's listen address
/// (`repro serve`, see `fleet::serve`). Serving is observe-only like
/// telemetry itself — nothing here enters a run's content-address.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// `host:port` the HTTP server binds. Port 0 picks an ephemeral
    /// port (the chosen address is printed at startup).
    pub listen: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { listen: "127.0.0.1:7878".into() }
    }
}

impl ServeConfig {
    /// Read the `[serve]` table from a parsed document (absent table =
    /// all defaults).
    pub fn from_doc(doc: &Document) -> Result<ServeConfig, ConfigError> {
        let mut cfg = ServeConfig::default();
        let Some(section) = doc.get("serve") else {
            return Ok(cfg);
        };
        let bad = |k: &str, v: &Value| {
            ConfigError::Invalid(format!("[serve] key {k:?}: unexpected value {v:?}"))
        };
        for (k, v) in section {
            match k.as_str() {
                "listen" => cfg.listen = v.as_str().ok_or_else(|| bad(k, v))?.to_string(),
                other => {
                    return Err(ConfigError::Invalid(format!(
                        "unknown [serve] key {other:?}"
                    )));
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_toml(text: &str) -> Result<ServeConfig, ConfigError> {
        Self::from_doc(&parser::parse(text)?)
    }

    /// `listen` must look like `host:port` — the split is validated here
    /// so a typo fails at config load, not at bind time.
    pub fn validate(&self) -> Result<(), ConfigError> {
        let fail = |msg: String| Err(ConfigError::Invalid(msg));
        let Some((host, port)) = self.listen.rsplit_once(':') else {
            return fail(format!("serve listen must be host:port, got {:?}", self.listen));
        };
        if host.is_empty() {
            return fail(format!("serve listen has an empty host: {:?}", self.listen));
        }
        if port.parse::<u16>().is_err() {
            return fail(format!("serve listen has a bad port: {:?}", self.listen));
        }
        Ok(())
    }
}

/// Parse helper used by the launcher: read a whole document and report
/// unknown sections.
pub fn load_document(text: &str) -> Result<Document, ConfigError> {
    Ok(parser::parse(text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        RunConfig::default().validate(7850).unwrap();
    }

    #[test]
    fn toml_roundtrip_overrides() {
        let cfg = RunConfig::from_toml(
            r#"
[run]
scheme = "ddsgd"
devices = 10
local_samples = 2000
pbar = 200.0
power = "hl"
noniid = true
[dataset]
kind = "synthetic"
train = 20000
test = 1000
"#,
        )
        .unwrap();
        assert_eq!(cfg.scheme, Scheme::DDsgd);
        assert_eq!(cfg.devices, 10);
        assert_eq!(cfg.local_samples, 2000);
        assert_eq!(cfg.power, PowerSchedule::Hl);
        assert!(cfg.noniid);
        assert_eq!(
            cfg.dataset,
            DatasetSpec::Synthetic {
                train: 20000,
                test: 1000
            }
        );
        cfg.validate(7850).unwrap();
    }

    #[test]
    fn unknown_key_rejected() {
        let err = RunConfig::from_toml("bogus_key = 1\n").unwrap_err();
        assert!(err.to_string().contains("bogus_key"));
    }

    #[test]
    fn adsgd_requires_k_below_s() {
        let cfg = RunConfig {
            sparsity: 4000,
            channel_uses: 3925,
            ..RunConfig::default()
        };
        assert!(cfg.validate(7850).is_err());
    }

    #[test]
    fn mean_removal_needs_three_uses() {
        let cfg = RunConfig {
            channel_uses: 2,
            sparsity: 1,
            mean_removal_rounds: 5,
            ..RunConfig::default()
        };
        assert!(cfg.validate(7850).is_err());
        let cfg2 = RunConfig {
            channel_uses: 2,
            sparsity: 1,
            mean_removal_rounds: 0,
            ..cfg
        };
        cfg2.validate(7850).unwrap();
    }

    #[test]
    fn scheme_kind_classification() {
        assert_eq!(Scheme::ADsgd.kind(), LinkKind::Analog);
        assert_eq!(Scheme::FadingADsgd.kind(), LinkKind::Fading);
        assert_eq!(Scheme::BlindADsgd.kind(), LinkKind::Fading);
        assert_eq!(Scheme::DDsgd.kind(), LinkKind::Digital);
        assert_eq!(Scheme::SignSgd.kind(), LinkKind::Digital);
        assert_eq!(Scheme::Qsgd.kind(), LinkKind::Digital);
        assert_eq!(Scheme::ErrorFree.kind(), LinkKind::Passthrough);
        assert_eq!(LinkKind::Analog.name(), "analog");
        assert_eq!(LinkKind::Fading.name(), "fading");
    }

    #[test]
    fn fading_dist_parse_roundtrip() {
        for dist in [
            FadingDist::Rayleigh,
            FadingDist::Constant(1.0),
            FadingDist::Constant(0.75),
            FadingDist::Uniform(0.2, 1.8),
        ] {
            assert_eq!(FadingDist::parse(&dist.describe()), Some(dist));
            dist.validate().unwrap();
        }
        assert_eq!(FadingDist::parse("rayleigh"), Some(FadingDist::Rayleigh));
        assert_eq!(FadingDist::parse("nope"), None);
        assert_eq!(FadingDist::parse("constant"), None);
        assert_eq!(FadingDist::parse("uniform:0.5"), None);
        assert!(FadingDist::Constant(0.0).validate().is_err());
        assert!(FadingDist::Uniform(1.0, 0.5).validate().is_err());
    }

    #[test]
    fn participation_parse_roundtrip() {
        for p in [
            ParticipationPolicy::Full,
            ParticipationPolicy::UniformK(8),
            ParticipationPolicy::GainThreshold(0.5),
        ] {
            assert_eq!(ParticipationPolicy::parse(&p.describe()), Some(p));
        }
        assert_eq!(ParticipationPolicy::parse("all"), Some(ParticipationPolicy::Full));
        assert_eq!(ParticipationPolicy::parse("uniform:x"), None);
        assert_eq!(ParticipationPolicy::parse("bogus"), None);
    }

    #[test]
    fn fading_validation_rules() {
        let base = RunConfig {
            scheme: Scheme::FadingADsgd,
            ..RunConfig::default()
        };
        base.validate(7850).unwrap();
        // K out of range.
        let cfg = RunConfig {
            participation: ParticipationPolicy::UniformK(26),
            ..base.clone()
        };
        assert!(cfg.validate(7850).is_err());
        let cfg = RunConfig {
            participation: ParticipationPolicy::UniformK(0),
            ..base.clone()
        };
        assert!(cfg.validate(7850).is_err());
        // Deadline without a latency model is a silent no-op — rejected.
        let cfg = RunConfig {
            deadline_secs: 0.1,
            latency_mean_secs: 0.0,
            ..base.clone()
        };
        assert!(cfg.validate(7850).is_err());
        let cfg = RunConfig {
            deadline_secs: 0.1,
            latency_mean_secs: 0.05,
            ..base.clone()
        };
        cfg.validate(7850).unwrap();
        // Bad gain distribution.
        let cfg = RunConfig {
            fading: FadingDist::Constant(-1.0),
            ..base
        };
        assert!(cfg.validate(7850).is_err());
        // The same knobs are ignored (not validated) for static schemes.
        let cfg = RunConfig {
            scheme: Scheme::ADsgd,
            fading: FadingDist::Constant(-1.0),
            ..RunConfig::default()
        };
        cfg.validate(7850).unwrap();
    }

    #[test]
    fn summary_echoes_fading_knobs() {
        let cfg = RunConfig {
            scheme: Scheme::FadingADsgd,
            csi_threshold: 0.4,
            participation: ParticipationPolicy::UniformK(5),
            deadline_secs: 0.02,
            latency_mean_secs: 0.01,
            ..RunConfig::default()
        };
        let s = cfg.summary();
        assert!(s.contains("h=rayleigh"), "{s}");
        assert!(s.contains("part=uniform:5"), "{s}");
        assert!(s.contains("h_min=0.4"), "{s}");
        assert!(s.contains("deadline=0.02s"), "{s}");
        // Two sweep configs differing only in threshold echo differently.
        let other = RunConfig {
            csi_threshold: 0.8,
            ..cfg
        };
        assert_ne!(s, other.summary());
        // Static schemes keep the original line.
        assert!(!RunConfig::default().summary().contains("h="));
    }

    #[test]
    fn fading_toml_knobs() {
        let cfg = RunConfig::from_toml(
            r#"
[run]
scheme = "fading-adsgd"
fading = "uniform:0.3:1.7"
csi_threshold = 0.4
participation = "uniform:5"
deadline_secs = 0.02
latency_mean_secs = 0.01
"#,
        )
        .unwrap();
        assert_eq!(cfg.scheme, Scheme::FadingADsgd);
        assert_eq!(cfg.fading, FadingDist::Uniform(0.3, 1.7));
        assert_eq!(cfg.csi_threshold, 0.4);
        assert_eq!(cfg.participation, ParticipationPolicy::UniformK(5));
        assert_eq!(cfg.deadline(), Some(0.02));
        assert_eq!(cfg.latency_mean_secs, 0.01);
        let off = RunConfig::default();
        assert_eq!(off.deadline(), None);
    }

    #[test]
    fn d2d_scheme_kind_and_parsing() {
        assert_eq!(Scheme::D2dADsgd.kind(), LinkKind::D2d);
        assert_eq!(LinkKind::D2d.name(), "d2d");
        assert_eq!(Scheme::parse("d2d"), Some(Scheme::D2dADsgd));
        assert_eq!(Scheme::parse("decentralized"), Some(Scheme::D2dADsgd));
        assert_eq!(Scheme::D2dADsgd.name(), "D2D-A-DSGD");
    }

    #[test]
    fn graph_family_and_mixing_parse() {
        for family in [
            GraphFamily::Full,
            GraphFamily::Ring,
            GraphFamily::Torus,
            GraphFamily::ErdosRenyi,
            GraphFamily::Star,
        ] {
            assert_eq!(GraphFamily::parse(family.name()), Some(family));
        }
        assert_eq!(GraphFamily::parse("complete"), Some(GraphFamily::Full));
        assert_eq!(GraphFamily::parse("nope"), None);
        for rule in [MixingRule::Metropolis, MixingRule::MaxDegree] {
            assert_eq!(MixingRule::parse(rule.name()), Some(rule));
        }
        assert_eq!(MixingRule::parse("mh"), Some(MixingRule::Metropolis));
        assert_eq!(MixingRule::parse("nope"), None);
    }

    #[test]
    fn topology_toml_table() {
        let cfg = RunConfig::from_toml(
            r#"
[run]
scheme = "d2d"
devices = 12
[topology]
family = "er"
p = 0.35
mixing = "max-degree"
seed = 99
"#,
        )
        .unwrap();
        assert_eq!(cfg.scheme, Scheme::D2dADsgd);
        assert_eq!(cfg.topology.family, GraphFamily::ErdosRenyi);
        assert_eq!(cfg.topology.p, 0.35);
        assert_eq!(cfg.topology.mixing, MixingRule::MaxDegree);
        assert_eq!(cfg.topology.seed, 99);
        cfg.validate(7850).unwrap();
        // Unknown topology keys rejected.
        assert!(RunConfig::from_toml("[topology]\nbogus = 1\n").is_err());
    }

    #[test]
    fn fading_toml_table_with_rho() {
        let cfg = RunConfig::from_toml(
            r#"
[run]
scheme = "fading-adsgd"
[fading]
dist = "uniform:0.3:1.7"
rho = 0.85
"#,
        )
        .unwrap();
        assert_eq!(cfg.fading, FadingDist::Uniform(0.3, 1.7));
        assert_eq!(cfg.fading_rho, 0.85);
        cfg.validate(7850).unwrap();
        // rho outside [0, 1) rejected at validation for fading schemes.
        let bad = RunConfig {
            scheme: Scheme::FadingADsgd,
            fading_rho: 1.0,
            ..RunConfig::default()
        };
        assert!(bad.validate(7850).is_err());
        // Flat run-section key works too.
        let flat = RunConfig::from_toml("[run]\nfading_rho = 0.5\n").unwrap();
        assert_eq!(flat.fading_rho, 0.5);
    }

    #[test]
    fn d2d_validation_rules() {
        let base = RunConfig {
            scheme: Scheme::D2dADsgd,
            ..RunConfig::default()
        };
        base.validate(7850).unwrap();
        // One device cannot form a D2D graph.
        let cfg = RunConfig {
            devices: 1,
            local_samples: 100,
            ..base.clone()
        };
        assert!(cfg.validate(7850).is_err());
        // Ring degree out of range.
        let cfg = RunConfig {
            topology: TopologyConfig {
                degree: 0,
                ..base.topology
            },
            ..base.clone()
        };
        assert!(cfg.validate(7850).is_err());
        // ER probability out of range.
        let cfg = RunConfig {
            topology: TopologyConfig {
                family: GraphFamily::ErdosRenyi,
                p: 0.0,
                ..base.topology
            },
            ..base.clone()
        };
        assert!(cfg.validate(7850).is_err());
        // The same knobs are ignored for PS-centric schemes.
        let cfg = RunConfig {
            scheme: Scheme::ADsgd,
            topology: TopologyConfig {
                degree: 0,
                ..TopologyConfig::default()
            },
            ..RunConfig::default()
        };
        cfg.validate(7850).unwrap();
    }

    #[test]
    fn digital_participation_validated() {
        // The selector now serves the digital family: K out of range fails.
        let cfg = RunConfig {
            scheme: Scheme::DDsgd,
            participation: ParticipationPolicy::UniformK(26),
            ..RunConfig::default()
        };
        assert!(cfg.validate(7850).is_err());
        let cfg = RunConfig {
            scheme: Scheme::DDsgd,
            participation: ParticipationPolicy::UniformK(25),
            ..RunConfig::default()
        };
        cfg.validate(7850).unwrap();
    }

    #[test]
    fn summary_echoes_topology() {
        let cfg = RunConfig {
            scheme: Scheme::D2dADsgd,
            topology: TopologyConfig {
                family: GraphFamily::ErdosRenyi,
                p: 0.4,
                ..TopologyConfig::default()
            },
            fading_rho: 0.6,
            ..RunConfig::default()
        };
        let s = cfg.summary();
        assert!(s.contains("topo=er:p0.4/metropolis"), "{s}");
        assert!(s.contains("rho=0.6"), "{s}");
        // Ring echoes its degree; static schemes stay silent.
        let ring = RunConfig {
            topology: TopologyConfig::default(),
            ..cfg
        };
        assert!(ring.summary().contains("topo=ring:deg1/metropolis"), "{}", ring.summary());
        assert!(!RunConfig::default().summary().contains("topo="));
    }

    #[test]
    fn campaign_table_parses_and_defaults() {
        let c = CampaignConfig::from_toml(
            "[campaign]\nsnapshot_every = 50\nstore_dir = \"cache\"\nresume = false\nkeep_last_n = 5\n",
        )
        .unwrap();
        assert_eq!(c.snapshot_every, 50);
        assert_eq!(c.store_dir, "cache");
        assert!(!c.resume);
        assert!(c.enabled);
        assert_eq!(c.keep_last_n, 5);
        assert_eq!(c.store_dir_or("results"), "cache");
        // Absent table = defaults; empty store_dir derives from out dir.
        let d = CampaignConfig::from_toml("[run]\ndevices = 4\n").unwrap();
        assert_eq!(d, CampaignConfig::default());
        assert_eq!(d.store_dir_or("artifacts"), "artifacts/.campaign");
        // Unknown keys rejected.
        assert!(CampaignConfig::from_toml("[campaign]\nbogus = 1\n").is_err());
        // A [campaign] table does not disturb RunConfig parsing of the
        // same document.
        let rc =
            RunConfig::from_toml("[run]\ndevices = 4\n[campaign]\nsnapshot_every = 5\n").unwrap();
        assert_eq!(rc.devices, 4);
    }

    #[test]
    fn telemetry_table_parses_validates_and_defaults() {
        let t = TelemetryConfig::from_toml("[telemetry]\nenabled = false\nevery = 25\n").unwrap();
        assert!(!t.enabled);
        assert_eq!(t.every, 25);
        assert!(t.diagnostics, "diagnostics default on");
        assert!(!t.trace, "tracing defaults off");
        let t =
            TelemetryConfig::from_toml("[telemetry]\ndiagnostics = false\n").unwrap();
        assert!(!t.diagnostics);
        assert!(t.enabled);
        let t = TelemetryConfig::from_toml("[telemetry]\ntrace = true\n").unwrap();
        assert!(t.trace);
        assert!(TelemetryConfig::from_toml("[telemetry]\ntrace = 3\n").is_err());
        // Absent table = defaults (on, every round).
        assert_eq!(
            TelemetryConfig::from_toml("[run]\ndevices = 4\n").unwrap(),
            TelemetryConfig::default()
        );
        // A zero cadence and unknown keys are rejected.
        assert!(TelemetryConfig::from_toml("[telemetry]\nevery = 0\n").is_err());
        assert!(TelemetryConfig::from_toml("[telemetry]\nbogus = 1\n").is_err());
        // `[telemetry]` rides on CampaignConfig::from_toml, with or
        // without a [campaign] table in the same document.
        let c = CampaignConfig::from_toml("[telemetry]\nevery = 10\n").unwrap();
        assert_eq!(c.telemetry.every, 10);
        let c = CampaignConfig::from_toml(
            "[campaign]\nsnapshot_every = 5\n[telemetry]\nenabled = false\n",
        )
        .unwrap();
        assert_eq!(c.snapshot_every, 5);
        assert!(!c.telemetry.enabled);
    }

    #[test]
    fn fleet_table_parses_validates_and_defaults() {
        let f = FleetConfig::from_toml(
            "[fleet]\nworkers = 8\nlease_secs = 12.5\nheartbeat_secs = 2\n",
        )
        .unwrap();
        assert_eq!(f.workers, 8);
        assert_eq!(f.lease_secs, 12.5);
        assert_eq!(f.heartbeat_secs, 2.0);
        f.validate().unwrap();
        // Absent table = defaults, and the defaults validate.
        let d = FleetConfig::from_toml("[run]\ndevices = 4\n").unwrap();
        assert_eq!(d, FleetConfig::default());
        d.validate().unwrap();
        // Unknown keys rejected.
        assert!(FleetConfig::from_toml("[fleet]\nbogus = 1\n").is_err());
        // Validation: zero workers, non-positive times, heartbeat too close
        // to the lease TTL.
        assert!(FleetConfig { workers: 0, ..d.clone() }.validate().is_err());
        assert!(FleetConfig { lease_secs: 0.0, ..d.clone() }.validate().is_err());
        assert!(FleetConfig { heartbeat_secs: -1.0, ..d.clone() }.validate().is_err());
        assert!(FleetConfig { lease_secs: 10.0, heartbeat_secs: 6.0, ..d }
            .validate()
            .is_err());
    }

    #[test]
    fn serve_table_parses_validates_and_defaults() {
        let s = ServeConfig::from_toml("[serve]\nlisten = \"0.0.0.0:9100\"\n").unwrap();
        assert_eq!(s.listen, "0.0.0.0:9100");
        // Absent table = defaults, and the defaults validate.
        let d = ServeConfig::from_toml("[run]\ndevices = 4\n").unwrap();
        assert_eq!(d, ServeConfig::default());
        d.validate().unwrap();
        // Unknown keys and malformed addresses rejected at load time.
        assert!(ServeConfig::from_toml("[serve]\nbogus = 1\n").is_err());
        assert!(ServeConfig::from_toml("[serve]\nlisten = \"no-port\"\n").is_err());
        assert!(ServeConfig::from_toml("[serve]\nlisten = \":7878\"\n").is_err());
        assert!(ServeConfig::from_toml("[serve]\nlisten = \"host:70000\"\n").is_err());
    }

    #[test]
    fn scheme_config_name_round_trips_through_parse() {
        for scheme in [
            Scheme::ADsgd,
            Scheme::FadingADsgd,
            Scheme::BlindADsgd,
            Scheme::D2dADsgd,
            Scheme::DDsgd,
            Scheme::SignSgd,
            Scheme::Qsgd,
            Scheme::ErrorFree,
        ] {
            assert_eq!(Scheme::parse(scheme.config_name()), Some(scheme), "{scheme:?}");
        }
    }

    /// The queue-persistence contract: every config the repo can express
    /// must survive `to_toml` → `from_toml` exactly (equal config ⇒ equal
    /// cache key, which is what lets an attached worker address the same
    /// store entry as the coordinator that enqueued the run).
    #[test]
    fn run_config_toml_round_trip_is_exact() {
        let mut configs = vec![RunConfig::default()];
        for scheme in [
            Scheme::ADsgd,
            Scheme::FadingADsgd,
            Scheme::BlindADsgd,
            Scheme::D2dADsgd,
            Scheme::DDsgd,
            Scheme::SignSgd,
            Scheme::Qsgd,
            Scheme::ErrorFree,
        ] {
            configs.push(RunConfig { scheme, ..RunConfig::default() });
        }
        configs.push(RunConfig {
            scheme: Scheme::FadingADsgd,
            fading: FadingDist::Uniform(0.3, 1.7),
            csi_threshold: 0.45,
            participation: ParticipationPolicy::UniformK(7),
            deadline_secs: 0.025,
            latency_mean_secs: 0.0125,
            fading_rho: 0.875,
            power: PowerSchedule::LhStair,
            noniid: true,
            seed: 424242,
            lr: 0.00075,
            amp_tol: 0.0001,
            ..RunConfig::default()
        });
        configs.push(RunConfig {
            scheme: Scheme::D2dADsgd,
            topology: TopologyConfig {
                family: GraphFamily::ErdosRenyi,
                degree: 2,
                p: 0.35,
                mixing: MixingRule::MaxDegree,
                seed: 99,
            },
            fading: FadingDist::Constant(0.75),
            ..RunConfig::default()
        });
        configs.push(RunConfig {
            dataset: DatasetSpec::MnistIdx { dir: "data/mnist".into() },
            power: PowerSchedule::Hl,
            qsgd_levels: 4,
            ..RunConfig::default()
        });
        for cfg in &configs {
            let text = cfg.to_toml();
            let back = RunConfig::from_toml(&text)
                .unwrap_or_else(|e| panic!("round-trip parse failed: {e}\n{text}"));
            assert_eq!(&back, cfg, "lossy TOML round-trip:\n{text}");
        }
    }

    #[test]
    fn scheme_and_power_parsing() {
        assert_eq!(Scheme::parse("A-DSGD"), Some(Scheme::ADsgd));
        assert_eq!(Scheme::parse("qsgd"), Some(Scheme::Qsgd));
        assert_eq!(Scheme::parse("nope"), None);
        assert_eq!(PowerSchedule::parse("LH-stair"), Some(PowerSchedule::LhStair));
    }

    #[test]
    fn mb_must_fit_in_corpus() {
        let cfg = RunConfig {
            devices: 100,
            local_samples: 1000,
            dataset: DatasetSpec::Synthetic {
                train: 25_000,
                test: 1000,
            },
            ..RunConfig::default()
        };
        assert!(cfg.validate(7850).is_err());
    }
}
