//! Configuration system: TOML-subset parser, run schema, per-figure presets.

pub mod parser;
pub mod presets;
pub mod schema;

pub use presets::MODEL_DIM;
pub use schema::{
    Backend, CampaignConfig, ConfigError, DatasetSpec, FadingDist, FleetConfig, GraphFamily,
    LinkKind, MixingRule, ParticipationPolicy, PowerSchedule, RunConfig, Scheme, ServeConfig,
    TelemetryConfig, TopologyConfig,
};
