//! Power allocation across DSGD iterations (§III Remark 1, §VI Fig. 3).
//!
//! The average power constraint (Eq. 7) is `(1/T) Σ_t P_t ≤ P̄`. Fig. 3
//! evaluates four schedules at P̄ = 200 with T = 300 (Eq. 45a–c): constant,
//! a linear "stair" ramp, and two three-block schedules. We normalize every
//! schedule to its P̄ so the same enum generalizes beyond the figure's
//! absolute numbers: the paper's (45a) `100·(2(t−1)/299 + 1)` is exactly
//! `P̄·(t-linear ramp from 0.5 to 1.5)` at P̄ = 200, and (45b)/(45c) are the
//! 0.5/1.0/1.5·P̄ blocks.

use crate::config::PowerSchedule;

use super::gaussian_mac::PowerReport;

/// Per-device transmit-energy meter backing the Eq. 6 audit. Shared by the
/// MAC simulator (analog links meter actual frame energy) and the digital
/// link (frames never traverse the simulator — capacity-achieving codes are
/// assumed — but each device still spends ‖x‖² = P_t per round).
#[derive(Clone, Debug)]
pub struct PowerMeter {
    /// Σ_t ‖x_m(t)‖² per device.
    energy: Vec<f64>,
    rounds: usize,
}

impl PowerMeter {
    pub fn new(devices: usize) -> PowerMeter {
        assert!(devices > 0);
        PowerMeter {
            energy: vec![0.0; devices],
            rounds: 0,
        }
    }

    /// Meter one device's frame energy within the current round.
    pub fn add(&mut self, device: usize, energy: f64) {
        self.energy[device] += energy;
    }

    /// Close the current round (average power divides by rounds, not uses).
    pub fn end_round(&mut self) {
        self.rounds += 1;
    }

    /// Every device spent exactly `energy` this round, and the round ends.
    pub fn add_uniform_round(&mut self, energy: f64) {
        for e in self.energy.iter_mut() {
            *e += energy;
        }
        self.rounds += 1;
    }

    pub fn devices(&self) -> usize {
        self.energy.len()
    }

    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// Accumulated per-device energy (checkpointing accessor).
    pub fn energy(&self) -> &[f64] {
        &self.energy
    }

    /// Restore a position captured by [`PowerMeter::energy`] /
    /// [`PowerMeter::rounds`]: the Eq. 6 audit of a resumed run then
    /// averages over the *whole* trajectory, not just the resumed suffix.
    pub fn load(&mut self, energy: &[f64], rounds: usize) {
        assert_eq!(
            energy.len(),
            self.energy.len(),
            "meter restore must match the configured device count"
        );
        self.energy.copy_from_slice(energy);
        self.rounds = rounds;
    }

    /// Snapshot as a [`PowerReport`] — the single home of the Eq. 6
    /// averaging math (`uses_per_round` = s for MAC links).
    pub fn report(&self, uses_per_round: usize) -> PowerReport {
        PowerReport {
            energy: self.energy.clone(),
            uses: self.rounds * uses_per_round,
            rounds: self.rounds,
        }
    }
}

/// Resolves P_t for every iteration of a run and proves Eq. 7 holds.
#[derive(Clone, Debug)]
pub struct PowerAllocator {
    /// P_t for t = 0..T-1.
    pub schedule: Vec<f64>,
    pub pbar: f64,
}

impl PowerAllocator {
    pub fn new(kind: PowerSchedule, pbar: f64, iterations: usize) -> PowerAllocator {
        assert!(iterations > 0 && pbar > 0.0);
        let t_total = iterations;
        let schedule: Vec<f64> = match kind {
            PowerSchedule::Constant => vec![pbar; t_total],
            PowerSchedule::LhStair => {
                // Eq. 45a generalized: linear ramp 0.5·P̄ → 1.5·P̄.
                if t_total == 1 {
                    vec![pbar]
                } else {
                    (0..t_total)
                        .map(|t| {
                            let frac = t as f64 / (t_total - 1) as f64;
                            pbar * (0.5 + frac)
                        })
                        .collect()
                }
            }
            PowerSchedule::Lh => blocks(pbar, t_total, [0.5, 1.0, 1.5]),
            PowerSchedule::Hl => blocks(pbar, t_total, [1.5, 1.0, 0.5]),
        };
        let alloc = PowerAllocator { schedule, pbar };
        debug_assert!(alloc.satisfies_average(1e-9));
        alloc
    }

    /// Explicit per-iteration schedule (for custom sweeps).
    pub fn custom(schedule: Vec<f64>, pbar: f64) -> PowerAllocator {
        PowerAllocator { schedule, pbar }
    }

    #[inline]
    pub fn p(&self, t: usize) -> f64 {
        self.schedule[t.min(self.schedule.len() - 1)]
    }

    pub fn iterations(&self) -> usize {
        self.schedule.len()
    }

    /// Eq. 7: (1/T) Σ P_t ≤ P̄ (within tolerance).
    pub fn satisfies_average(&self, tol: f64) -> bool {
        let avg = self.schedule.iter().sum::<f64>() / self.schedule.len() as f64;
        avg <= self.pbar * (1.0 + tol)
    }
}

fn blocks(pbar: f64, t_total: usize, multipliers: [f64; 3]) -> Vec<f64> {
    // Three equal blocks; remainder goes to the last block. For T not
    // divisible by 3 we rescale so the average still equals P̄ exactly.
    let mut out = Vec::with_capacity(t_total);
    let block = t_total / 3;
    for t in 0..t_total {
        let idx = if block == 0 {
            2
        } else {
            (t / block).min(2)
        };
        out.push(pbar * multipliers[idx]);
    }
    let avg = out.iter().sum::<f64>() / t_total as f64;
    let fix = pbar / avg;
    for p in out.iter_mut() {
        *p *= fix;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_schedules_satisfy_eq7() {
        for kind in [
            PowerSchedule::Constant,
            PowerSchedule::LhStair,
            PowerSchedule::Lh,
            PowerSchedule::Hl,
        ] {
            for t in [1usize, 2, 10, 299, 300] {
                let a = PowerAllocator::new(kind, 200.0, t);
                assert!(
                    a.satisfies_average(1e-9),
                    "{kind:?} T={t} avg={}",
                    a.schedule.iter().sum::<f64>() / t as f64
                );
                assert!(a.schedule.iter().all(|&p| p > 0.0));
            }
        }
    }

    #[test]
    fn paper_eq45a_values() {
        // P̄=200, T=300: P_1 = 100, P_300 = 300, linear in between.
        let a = PowerAllocator::new(PowerSchedule::LhStair, 200.0, 300);
        assert!((a.p(0) - 100.0).abs() < 1e-9);
        assert!((a.p(299) - 300.0).abs() < 1e-9);
        let mid = a.p(150);
        assert!((mid - 100.0 * (2.0 / 299.0 * 150.0 + 1.0)).abs() < 1e-9);
    }

    #[test]
    fn paper_eq45b_blocks() {
        let a = PowerAllocator::new(PowerSchedule::Lh, 200.0, 300);
        assert!((a.p(0) - 100.0).abs() < 1e-9);
        assert!((a.p(150) - 200.0).abs() < 1e-9);
        assert!((a.p(299) - 300.0).abs() < 1e-9);
    }

    #[test]
    fn paper_eq45c_blocks_reversed() {
        let a = PowerAllocator::new(PowerSchedule::Hl, 200.0, 300);
        assert!((a.p(0) - 300.0).abs() < 1e-9);
        assert!((a.p(299) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn constant_is_pbar_everywhere() {
        let a = PowerAllocator::new(PowerSchedule::Constant, 500.0, 100);
        assert!(a.schedule.iter().all(|&p| (p - 500.0).abs() < 1e-12));
    }

    #[test]
    fn custom_schedule_passthrough() {
        let a = PowerAllocator::custom(vec![1.0, 2.0, 3.0], 2.0);
        assert_eq!(a.iterations(), 3);
        assert!(a.satisfies_average(1e-9));
    }

    #[test]
    fn meter_averages_per_round() {
        let mut m = PowerMeter::new(2);
        assert_eq!(m.report(1).avg_power(0), 0.0);
        m.add(0, 25.0);
        m.add(1, 9.0);
        m.end_round();
        m.add_uniform_round(5.0);
        assert_eq!(m.rounds(), 2);
        let rep = m.report(4);
        assert_eq!(rep.uses, 8);
        assert!((rep.avg_power(0) - 15.0).abs() < 1e-12);
        assert!((rep.avg_power(1) - 7.0).abs() < 1e-12);
        assert_eq!(rep.averages(), vec![15.0, 7.0]);
        assert!(rep.satisfies(15.0, 1e-9));
        assert!(!rep.satisfies(14.0, 1e-9));
    }
}
