//! Time-varying per-device channel gains h_m(t) and the straggler latency
//! model — the channel-layer half of the fading-MAC scenario subsystem.
//!
//! Both generators are **counter-based**: every draw is produced by a fresh
//! RNG derived from `(seed, device, round)`, so the value of h_m(t) does not
//! depend on how many other gains were drawn before it, in which order, or
//! on how the encode fan-out is scheduled across worker threads. Same seed ⇒
//! identical gain sequences across runs and across thread-pool sizes
//! (pinned by `rust/tests/fading_determinism.rs`).

use crate::config::FadingDist;
use crate::util::rng::counter_rng;

/// Seeded i.i.d. per-device, per-round channel-gain process h_m(t).
#[derive(Clone, Debug)]
pub struct FadingProcess {
    dist: FadingDist,
    seed: u64,
}

impl FadingProcess {
    pub fn new(dist: FadingDist, seed: u64) -> FadingProcess {
        FadingProcess { dist, seed }
    }

    pub fn dist(&self) -> FadingDist {
        self.dist
    }

    /// The gain magnitude h_m(t) for device `device` at round `t`.
    /// Pure in `(self, device, t)` — calling twice returns the same value.
    pub fn gain(&self, device: usize, t: usize) -> f64 {
        match self.dist {
            FadingDist::Constant(v) => v,
            FadingDist::Rayleigh => {
                let mut rng = counter_rng(self.seed, 0xFAD0_0001, device as u64, t as u64);
                // Rayleigh with E[h²] = 1: h = √(−ln(1 − u)), u ~ U[0,1).
                let u = rng.f64();
                (-(1.0 - u).ln()).sqrt()
            }
            FadingDist::Uniform(lo, hi) => {
                let mut rng = counter_rng(self.seed, 0xFAD0_0001, device as u64, t as u64);
                rng.range_f64(lo, hi)
            }
        }
    }

    /// All M gains for round `t`, in device order.
    pub fn gains_for_round(&self, devices: usize, t: usize) -> Vec<f64> {
        (0..devices).map(|m| self.gain(m, t)).collect()
    }
}

/// Per-device encode-latency model for straggler simulation.
///
/// Latency of device m at round t is `speed_m · mean · E` where `speed_m`
/// is a persistent per-device heterogeneity factor drawn uniformly from
/// [0.5, 1.5) (slow and fast devices exist for the whole run) and `E` is a
/// fresh Exp(1) draw per round (transient load spikes). A non-positive
/// `mean` disables the model: every latency is exactly 0.
#[derive(Clone, Debug)]
pub struct LatencyModel {
    mean_secs: f64,
    seed: u64,
}

impl LatencyModel {
    pub fn new(mean_secs: f64, seed: u64) -> LatencyModel {
        LatencyModel { mean_secs, seed }
    }

    /// The persistent speed factor of device m (uniform in [0.5, 1.5)).
    pub fn speed_factor(&self, device: usize) -> f64 {
        let mut rng = counter_rng(self.seed, 0x1A7E_0002, device as u64, 0);
        rng.range_f64(0.5, 1.5)
    }

    /// Simulated encode latency of device m at round t, in seconds.
    /// Pure in `(self, device, t)`.
    pub fn latency(&self, device: usize, t: usize) -> f64 {
        if self.mean_secs <= 0.0 {
            return 0.0;
        }
        let mut rng = counter_rng(self.seed, 0x1A7E_0003, device as u64, t as u64);
        let e = -(1.0 - rng.f64()).ln(); // Exp(1)
        self.speed_factor(device) * self.mean_secs * e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_gains_any_query_order() {
        let a = FadingProcess::new(FadingDist::Rayleigh, 42);
        let b = FadingProcess::new(FadingDist::Rayleigh, 42);
        // Forward order vs reversed order vs repeated queries.
        let fwd: Vec<f64> = (0..20).map(|m| a.gain(m, 3)).collect();
        let rev: Vec<f64> = (0..20).rev().map(|m| b.gain(m, 3)).collect();
        let rev: Vec<f64> = rev.into_iter().rev().collect();
        assert_eq!(fwd, rev);
        assert_eq!(a.gain(7, 11), a.gain(7, 11));
    }

    #[test]
    fn different_seeds_decorrelate() {
        let a = FadingProcess::new(FadingDist::Rayleigh, 1);
        let b = FadingProcess::new(FadingDist::Rayleigh, 2);
        let same = (0..64).filter(|&m| a.gain(m, 0) == b.gain(m, 0)).count();
        assert!(same < 4);
    }

    #[test]
    fn rayleigh_is_unit_mean_square() {
        let p = FadingProcess::new(FadingDist::Rayleigh, 9);
        let n = 20_000usize;
        let ms: f64 = (0..n).map(|i| p.gain(i % 50, i / 50).powi(2)).sum::<f64>() / n as f64;
        assert!((ms - 1.0).abs() < 0.05, "E[h²]={ms}");
    }

    #[test]
    fn constant_and_uniform_respect_their_ranges() {
        let c = FadingProcess::new(FadingDist::Constant(0.7), 5);
        assert_eq!(c.gain(3, 8), 0.7);
        let u = FadingProcess::new(FadingDist::Uniform(0.2, 1.8), 5);
        for t in 0..50 {
            let h = u.gain(t % 7, t);
            assert!((0.2..1.8).contains(&h), "h={h}");
        }
    }

    #[test]
    fn gains_vary_across_rounds_and_devices() {
        let p = FadingProcess::new(FadingDist::Rayleigh, 3);
        assert_ne!(p.gain(0, 0), p.gain(0, 1));
        assert_ne!(p.gain(0, 0), p.gain(1, 0));
        assert_eq!(p.gains_for_round(4, 2).len(), 4);
    }

    #[test]
    fn latency_deterministic_and_disabled_at_zero_mean() {
        let l = LatencyModel::new(0.01, 7);
        assert_eq!(l.latency(2, 5), l.latency(2, 5));
        assert!(l.latency(2, 5) >= 0.0);
        let off = LatencyModel::new(0.0, 7);
        for m in 0..10 {
            assert_eq!(off.latency(m, 0), 0.0);
        }
    }

    #[test]
    fn latency_mean_scales_with_speed_factor() {
        let l = LatencyModel::new(0.01, 11);
        for m in 0..20 {
            let f = l.speed_factor(m);
            assert!((0.5..1.5).contains(&f), "speed={f}");
        }
        // Empirical mean over many rounds ≈ speed · mean (Exp(1) has mean 1).
        let m = 4;
        let n = 8000;
        let avg: f64 = (0..n).map(|t| l.latency(m, t)).sum::<f64>() / n as f64;
        let expect = l.speed_factor(m) * 0.01;
        assert!((avg - expect).abs() < 0.15 * expect, "avg={avg} expect={expect}");
    }
}
