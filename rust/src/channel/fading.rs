//! Time-varying per-device channel gains h_m(t) and the straggler latency
//! model — the channel-layer half of the fading-MAC scenario subsystem.
//!
//! Both generators are **counter-based**: every draw is produced by a fresh
//! RNG derived from `(seed, device, round)`, so the value of h_m(t) does not
//! depend on how many other gains were drawn before it, in which order, or
//! on how the encode fan-out is scheduled across worker threads. Same seed ⇒
//! identical gain sequences across runs and across thread-pool sizes
//! (pinned by `rust/tests/fading_determinism.rs`).
//!
//! # Time-correlated (Gauss–Markov) gains
//!
//! `rho > 0` ([`FadingProcess::with_rho`]) correlates h_m(t) with h_m(t−1)
//! through an AR(1) chain on the underlying Gaussian state:
//! `u(t) = ρ·u(t−1) + √(1−ρ²)·w(t)` with every innovation `w(t)` its own
//! counter-based cell. The chain is *recomputed from t = 0 on each query*
//! rather than cached, which keeps the draw a pure function of
//! `(seed, device, t)` — O(t) per query, but order- and
//! thread-pool-invariant like the i.i.d. path (and T is a few hundred
//! here). Stationary marginals match the configured distribution:
//! Rayleigh maps two unit-variance chains through the magnitude,
//! Uniform maps one chain through the Gaussian CDF. `rho = 0` takes the
//! original i.i.d. code path bit-for-bit, so all PR 2 goldens are
//! unaffected.

use crate::config::FadingDist;
use crate::util::rng::counter_rng;

/// Seeded per-device, per-round channel-gain process h_m(t): i.i.d. across
/// rounds by default, AR(1)-correlated when built `with_rho`.
#[derive(Clone, Debug)]
pub struct FadingProcess {
    dist: FadingDist,
    seed: u64,
    /// AR(1) coefficient of the underlying Gaussian state; 0 = i.i.d.
    rho: f64,
}

impl FadingProcess {
    pub fn new(dist: FadingDist, seed: u64) -> FadingProcess {
        Self::with_rho(dist, seed, 0.0)
    }

    /// Gauss–Markov variant: `rho ∈ [0, 1)` correlates consecutive rounds.
    pub fn with_rho(dist: FadingDist, seed: u64, rho: f64) -> FadingProcess {
        assert!(
            (0.0..1.0).contains(&rho),
            "AR(1) rho must be in [0, 1), got {rho}"
        );
        FadingProcess { dist, seed, rho }
    }

    pub fn dist(&self) -> FadingDist {
        self.dist
    }

    pub fn rho(&self) -> f64 {
        self.rho
    }

    /// The gain magnitude h_m(t) for device `device` at round `t`.
    /// Pure in `(self, device, t)` — calling twice returns the same value.
    pub fn gain(&self, device: usize, t: usize) -> f64 {
        if self.rho > 0.0 {
            return self.gain_ar1(device, t);
        }
        match self.dist {
            FadingDist::Constant(v) => v,
            FadingDist::Rayleigh => {
                let mut rng = counter_rng(self.seed, 0xFAD0_0001, device as u64, t as u64);
                // Rayleigh with E[h²] = 1: h = √(−ln(1 − u)), u ~ U[0,1).
                let u = rng.f64();
                (-(1.0 - u).ln()).sqrt()
            }
            FadingDist::Uniform(lo, hi) => {
                let mut rng = counter_rng(self.seed, 0xFAD0_0001, device as u64, t as u64);
                rng.range_f64(lo, hi)
            }
        }
    }

    /// Time-correlated gain: stationary AR(1) Gaussian state(s) mapped to
    /// the configured marginal.
    fn gain_ar1(&self, device: usize, t: usize) -> f64 {
        match self.dist {
            FadingDist::Constant(v) => v,
            FadingDist::Rayleigh => {
                // Two independent unit-variance chains (I/Q taps);
                // h = √((u_I² + u_Q²)/2) keeps E[h²] = 1.
                let ui = self.ar1_state(0xFAD0_00A1, device, t);
                let uq = self.ar1_state(0xFAD0_00A2, device, t);
                ((ui * ui + uq * uq) / 2.0).sqrt()
            }
            FadingDist::Uniform(lo, hi) => {
                // Gaussian copula: Φ(u) is uniform on [0, 1) at
                // stationarity, then rescale to [lo, hi).
                let u = self.ar1_state(0xFAD0_00A3, device, t);
                lo + (hi - lo) * normal_cdf(u).clamp(1e-12, 1.0 - 1e-12)
            }
        }
    }

    /// `u(t) = ρ·u(t−1) + √(1−ρ²)·w(t)`, `u(0) = w(0)`, every `w(k)` a
    /// counter-based N(0,1) cell — recomputed from 0 so the value is pure
    /// in `(seed, salt, device, t)`.
    fn ar1_state(&self, salt: u64, device: usize, t: usize) -> f64 {
        let draw = |k: usize| counter_rng(self.seed, salt, device as u64, k as u64).normal();
        let scale = (1.0 - self.rho * self.rho).sqrt();
        let mut u = draw(0);
        for k in 1..=t {
            u = self.rho * u + scale * draw(k);
        }
        u
    }

    /// All M gains for round `t`, in device order.
    pub fn gains_for_round(&self, devices: usize, t: usize) -> Vec<f64> {
        (0..devices).map(|m| self.gain(m, t)).collect()
    }
}

/// Standard normal CDF via the Abramowitz–Stegun 7.1.26 erf polynomial
/// (|error| < 1.5e-7 — far below the gain tolerances anywhere downstream).
fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let poly = t
        * (0.254_829_592
            + t * (-0.284_496_736 + t * (1.421_413_741 + t * (-1.453_152_027 + t * 1.061_405_429))));
    sign * (1.0 - poly * (-x * x).exp())
}

/// Per-device encode-latency model for straggler simulation.
///
/// Latency of device m at round t is `speed_m · mean · E` where `speed_m`
/// is a persistent per-device heterogeneity factor drawn uniformly from
/// [0.5, 1.5) (slow and fast devices exist for the whole run) and `E` is a
/// fresh Exp(1) draw per round (transient load spikes). A non-positive
/// `mean` disables the model: every latency is exactly 0.
#[derive(Clone, Debug)]
pub struct LatencyModel {
    mean_secs: f64,
    seed: u64,
}

impl LatencyModel {
    pub fn new(mean_secs: f64, seed: u64) -> LatencyModel {
        LatencyModel { mean_secs, seed }
    }

    /// The persistent speed factor of device m (uniform in [0.5, 1.5)).
    pub fn speed_factor(&self, device: usize) -> f64 {
        let mut rng = counter_rng(self.seed, 0x1A7E_0002, device as u64, 0);
        rng.range_f64(0.5, 1.5)
    }

    /// Simulated encode latency of device m at round t, in seconds.
    /// Pure in `(self, device, t)`.
    pub fn latency(&self, device: usize, t: usize) -> f64 {
        if self.mean_secs <= 0.0 {
            return 0.0;
        }
        let mut rng = counter_rng(self.seed, 0x1A7E_0003, device as u64, t as u64);
        let e = -(1.0 - rng.f64()).ln(); // Exp(1)
        self.speed_factor(device) * self.mean_secs * e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_gains_any_query_order() {
        let a = FadingProcess::new(FadingDist::Rayleigh, 42);
        let b = FadingProcess::new(FadingDist::Rayleigh, 42);
        // Forward order vs reversed order vs repeated queries.
        let fwd: Vec<f64> = (0..20).map(|m| a.gain(m, 3)).collect();
        let rev: Vec<f64> = (0..20).rev().map(|m| b.gain(m, 3)).collect();
        let rev: Vec<f64> = rev.into_iter().rev().collect();
        assert_eq!(fwd, rev);
        assert_eq!(a.gain(7, 11), a.gain(7, 11));
    }

    #[test]
    fn different_seeds_decorrelate() {
        let a = FadingProcess::new(FadingDist::Rayleigh, 1);
        let b = FadingProcess::new(FadingDist::Rayleigh, 2);
        let same = (0..64).filter(|&m| a.gain(m, 0) == b.gain(m, 0)).count();
        assert!(same < 4);
    }

    #[test]
    fn rayleigh_is_unit_mean_square() {
        let p = FadingProcess::new(FadingDist::Rayleigh, 9);
        let n = 20_000usize;
        let ms: f64 = (0..n).map(|i| p.gain(i % 50, i / 50).powi(2)).sum::<f64>() / n as f64;
        assert!((ms - 1.0).abs() < 0.05, "E[h²]={ms}");
    }

    #[test]
    fn constant_and_uniform_respect_their_ranges() {
        let c = FadingProcess::new(FadingDist::Constant(0.7), 5);
        assert_eq!(c.gain(3, 8), 0.7);
        let u = FadingProcess::new(FadingDist::Uniform(0.2, 1.8), 5);
        for t in 0..50 {
            let h = u.gain(t % 7, t);
            assert!((0.2..1.8).contains(&h), "h={h}");
        }
    }

    #[test]
    fn gains_vary_across_rounds_and_devices() {
        let p = FadingProcess::new(FadingDist::Rayleigh, 3);
        assert_ne!(p.gain(0, 0), p.gain(0, 1));
        assert_ne!(p.gain(0, 0), p.gain(1, 0));
        assert_eq!(p.gains_for_round(4, 2).len(), 4);
    }

    #[test]
    fn ar1_rho_zero_is_bitwise_iid_path() {
        for dist in [
            FadingDist::Rayleigh,
            FadingDist::Uniform(0.2, 1.8),
            FadingDist::Constant(0.7),
        ] {
            let iid = FadingProcess::new(dist, 11);
            let ar0 = FadingProcess::with_rho(dist, 11, 0.0);
            for m in 0..6 {
                for t in 0..6 {
                    assert_eq!(iid.gain(m, t), ar0.gain(m, t), "{dist:?} m={m} t={t}");
                }
            }
        }
    }

    #[test]
    fn ar1_is_pure_in_its_cell() {
        let p = FadingProcess::with_rho(FadingDist::Rayleigh, 13, 0.8);
        assert_eq!(p.gain(3, 7), p.gain(3, 7));
        assert_ne!(p.gain(3, 7), p.gain(4, 7));
        assert_ne!(p.gain(3, 7), p.gain(3, 8));
    }

    #[test]
    fn ar1_correlates_consecutive_rounds() {
        // Lag-1 autocorrelation of the squared-gain process grows with rho;
        // compare empirical correlation of h(t), h(t+1) at rho = 0 vs 0.9.
        let corr = |rho: f64| {
            let p = FadingProcess::with_rho(FadingDist::Rayleigh, 17, rho);
            let n = 400usize;
            let xs: Vec<f64> = (0..n).map(|t| p.gain(0, t)).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
            let cov = xs
                .windows(2)
                .map(|w| (w[0] - mean) * (w[1] - mean))
                .sum::<f64>()
                / (n - 1) as f64;
            cov / var
        };
        let c_iid = corr(0.0);
        let c_ar = corr(0.9);
        assert!(c_iid.abs() < 0.2, "iid lag-1 corr {c_iid}");
        assert!(c_ar > 0.5, "AR(0.9) lag-1 corr {c_ar}");
    }

    #[test]
    fn ar1_preserves_stationary_marginals() {
        // Rayleigh: E[h²] stays 1 under correlation.
        let p = FadingProcess::with_rho(FadingDist::Rayleigh, 19, 0.7);
        let n = 10_000usize;
        let ms: f64 = (0..n).map(|i| p.gain(i % 40, i / 40).powi(2)).sum::<f64>() / n as f64;
        assert!((ms - 1.0).abs() < 0.07, "E[h²]={ms}");
        // Uniform: range respected, mean near the midpoint.
        let u = FadingProcess::with_rho(FadingDist::Uniform(0.2, 1.8), 19, 0.7);
        let mut sum = 0.0;
        for i in 0..4000 {
            let h = u.gain(i % 20, i / 20);
            assert!((0.2..1.8).contains(&h), "h={h}");
            sum += h;
        }
        let mean = sum / 4000.0;
        assert!((mean - 1.0).abs() < 0.08, "uniform AR mean {mean}");
        // Constant is rho-invariant.
        let c = FadingProcess::with_rho(FadingDist::Constant(0.6), 19, 0.9);
        assert_eq!(c.gain(2, 9), 0.6);
    }

    #[test]
    fn latency_deterministic_and_disabled_at_zero_mean() {
        let l = LatencyModel::new(0.01, 7);
        assert_eq!(l.latency(2, 5), l.latency(2, 5));
        assert!(l.latency(2, 5) >= 0.0);
        let off = LatencyModel::new(0.0, 7);
        for m in 0..10 {
            assert_eq!(off.latency(m, 0), 0.0);
        }
    }

    #[test]
    fn latency_mean_scales_with_speed_factor() {
        let l = LatencyModel::new(0.01, 11);
        for m in 0..20 {
            let f = l.speed_factor(m);
            assert!((0.5..1.5).contains(&f), "speed={f}");
        }
        // Empirical mean over many rounds ≈ speed · mean (Exp(1) has mean 1).
        let m = 4;
        let n = 8000;
        let avg: f64 = (0..n).map(|t| l.latency(m, t)).sum::<f64>() / n as f64;
        let expect = l.speed_factor(m) * 0.01;
        assert!((avg - expect).abs() < 0.15 * expect, "avg={avg} expect={expect}");
    }
}
