//! The wireless substrate: Gaussian multiple-access channel simulation and
//! power allocation across iterations.

pub mod gaussian_mac;
pub mod power;

pub use gaussian_mac::{GaussianMac, PowerReport};
pub use power::{PowerAllocator, PowerMeter};
