//! The wireless substrate: Gaussian multiple-access channel simulation
//! (static and fading), per-device gain/latency processes, and power
//! allocation across iterations.

pub mod fading;
pub mod gaussian_mac;
pub mod power;

pub use fading::{FadingProcess, LatencyModel};
pub use gaussian_mac::{GaussianMac, PowerReport};
pub use power::{PowerAllocator, PowerMeter};
