//! Gaussian MAC simulator (Eq. 5): `y(t) = Σ_m x_m(t) + z(t)` with
//! `z ~ N(0, σ² I_s)`, plus per-device transmit-power metering that enforces
//! the paper's average power constraint (Eq. 6) at the end of a run.
//!
//! The paper models the uplink as an ideal synchronous AWGN MAC — the
//! simulator *is* that model, so no fidelity is lost by simulating (see
//! DESIGN.md §3). The metering exists so tests can prove every scheme obeys
//! `(1/T) Σ_t ‖x_m(t)‖² ≤ P̄` rather than assuming it.

use crate::util::rng::Pcg64;

use super::power::PowerMeter;

/// Per-device power accounting over a run.
#[derive(Clone, Debug)]
pub struct PowerReport {
    /// Σ_t ‖x_m(t)‖² per device.
    pub energy: Vec<f64>,
    /// Number of channel uses consumed (MAC invocations × s).
    pub uses: usize,
    /// Number of MAC rounds.
    pub rounds: usize,
}

impl PowerReport {
    /// Average per-round transmit power of device m.
    pub fn avg_power(&self, m: usize) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.energy[m] / self.rounds as f64
        }
    }

    /// Average per-round transmit power for every device (Eq. 6 left side).
    pub fn averages(&self) -> Vec<f64> {
        (0..self.energy.len()).map(|m| self.avg_power(m)).collect()
    }

    /// Check Eq. 6 for every device (with a small numerical slack).
    pub fn satisfies(&self, pbar: f64, tol: f64) -> bool {
        (0..self.energy.len()).all(|m| self.avg_power(m) <= pbar * (1.0 + tol))
    }
}

/// The s-use Gaussian MAC.
pub struct GaussianMac {
    /// Channel uses per invocation (s).
    pub s: usize,
    /// Noise variance σ².
    pub noise_var: f64,
    devices: usize,
    rng: Pcg64,
    meter: PowerMeter,
}

impl GaussianMac {
    pub fn new(s: usize, devices: usize, noise_var: f64, seed: u64) -> GaussianMac {
        assert!(s > 0 && devices > 0 && noise_var >= 0.0);
        GaussianMac {
            s,
            noise_var,
            devices,
            rng: Pcg64::with_stream(seed, 0x3AC),
            meter: PowerMeter::new(devices),
        }
    }

    /// Transmit: each row of `inputs` is one device's length-s channel input
    /// x_m(t). Returns y(t) = Σ_m x_m(t) + z(t) and meters per-device energy.
    pub fn transmit(&mut self, inputs: &[Vec<f32>]) -> Vec<f32> {
        assert_eq!(inputs.len(), self.devices, "one input row per device");
        let mut y = vec![0f32; self.s];
        for (m, x) in inputs.iter().enumerate() {
            assert_eq!(x.len(), self.s, "device {m} input must be length s={}", self.s);
            self.meter.add(m, crate::tensor::norm_sq(x));
            for (yi, &xi) in y.iter_mut().zip(x) {
                *yi += xi;
            }
        }
        let sd = self.noise_var.sqrt();
        for yi in y.iter_mut() {
            *yi += (self.rng.normal() * sd) as f32;
        }
        self.meter.end_round();
        y
    }

    /// Fading transmit: `y(t) = Σ_m h_m·x_m(t) + z(t)` with per-device
    /// gains `h_m` applied by the channel. The meter records the
    /// *transmitted* energy ‖x_m‖² — the Eq. 6 power constraint binds what
    /// the device radiates, not what the PS receives — so a silent device
    /// (all-zero frame) spends nothing regardless of its gain. With
    /// `h_m ≡ 1` this is bit-identical to [`GaussianMac::transmit`]
    /// (multiplication by `1.0f32` is exact), which the fading degeneracy
    /// golden relies on.
    pub fn transmit_faded(&mut self, inputs: &[Vec<f32>], gains: &[f64]) -> Vec<f32> {
        assert_eq!(inputs.len(), self.devices, "one input row per device");
        assert_eq!(gains.len(), self.devices, "one gain per device");
        let mut y = vec![0f32; self.s];
        for (m, x) in inputs.iter().enumerate() {
            assert_eq!(x.len(), self.s, "device {m} input must be length s={}", self.s);
            self.meter.add(m, crate::tensor::norm_sq(x));
            let h = gains[m] as f32;
            for (yi, &xi) in y.iter_mut().zip(x) {
                *yi += h * xi;
            }
        }
        let sd = self.noise_var.sqrt();
        for yi in y.iter_mut() {
            *yi += (self.rng.normal() * sd) as f32;
        }
        self.meter.end_round();
        y
    }

    /// Energy metered so far (for Eq. 6 verification).
    pub fn power_report(&self) -> PowerReport {
        self.meter.report(self.s)
    }

    /// Noise-stream position for checkpointing (the per-round z(t) draws
    /// are the MAC's only advancing state besides the meter).
    pub fn rng_state(&self) -> (u64, u64, Option<f64>) {
        self.rng.raw_state()
    }

    /// Restore the noise stream at an exact position captured by
    /// [`GaussianMac::rng_state`].
    pub fn restore_rng(&mut self, st: (u64, u64, Option<f64>)) {
        self.rng = Pcg64::from_raw_state(st.0, st.1, st.2);
    }

    /// The transmit-energy meter (checkpointing accessor).
    pub fn meter(&self) -> &PowerMeter {
        &self.meter
    }

    pub fn meter_mut(&mut self) -> &mut PowerMeter {
        &mut self.meter
    }

    pub fn devices(&self) -> usize {
        self.devices
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn superposition_without_noise() {
        let mut mac = GaussianMac::new(4, 3, 0.0, 1);
        let inputs = vec![
            vec![1.0, 0.0, -1.0, 2.0],
            vec![0.5, 0.5, 0.5, 0.5],
            vec![-1.5, 1.0, 0.0, 0.0],
        ];
        let y = mac.transmit(&inputs);
        assert_eq!(y, vec![0.0, 1.5, -0.5, 2.5]);
    }

    #[test]
    fn noise_statistics() {
        let s = 20_000;
        let mut mac = GaussianMac::new(s, 1, 4.0, 2);
        let y = mac.transmit(&[vec![0.0; s]]);
        let mean = y.iter().map(|&v| v as f64).sum::<f64>() / s as f64;
        let var = y.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / s as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.2, "var={var}");
    }

    #[test]
    fn energy_metering_accumulates() {
        let mut mac = GaussianMac::new(2, 2, 0.0, 3);
        mac.transmit(&[vec![3.0, 4.0], vec![1.0, 0.0]]);
        mac.transmit(&[vec![0.0, 0.0], vec![2.0, 2.0]]);
        let rep = mac.power_report();
        assert!((rep.energy[0] - 25.0).abs() < 1e-6);
        assert!((rep.energy[1] - 9.0).abs() < 1e-6);
        assert_eq!(rep.rounds, 2);
        assert!((rep.avg_power(0) - 12.5).abs() < 1e-6);
        assert!(rep.satisfies(12.5, 1e-9));
        assert!(!rep.satisfies(12.0, 1e-9));
    }

    #[test]
    #[should_panic(expected = "length s")]
    fn wrong_length_rejected() {
        let mut mac = GaussianMac::new(3, 1, 1.0, 4);
        mac.transmit(&[vec![1.0, 2.0]]);
    }

    #[test]
    fn faded_superposition_applies_gains_meters_transmit_energy() {
        let mut mac = GaussianMac::new(2, 2, 0.0, 6);
        let y = mac.transmit_faded(
            &[vec![2.0, -1.0], vec![4.0, 0.0]],
            &[0.5, 2.0],
        );
        // y = 0.5·x₀ + 2.0·x₁.
        assert_eq!(y, vec![9.0, -0.5]);
        let rep = mac.power_report();
        // Metered pre-gain: ‖x₀‖² = 5, ‖x₁‖² = 16.
        assert!((rep.energy[0] - 5.0).abs() < 1e-6);
        assert!((rep.energy[1] - 16.0).abs() < 1e-6);
    }

    #[test]
    fn unit_gains_match_static_transmit_bit_for_bit() {
        let inputs = vec![vec![1.5f32, -0.25, 3.0], vec![0.125, 2.0, -1.0]];
        let mut a = GaussianMac::new(3, 2, 1.7, 21);
        let mut b = GaussianMac::new(3, 2, 1.7, 21);
        let ya = a.transmit(&inputs);
        let yb = b.transmit_faded(&inputs, &[1.0, 1.0]);
        assert_eq!(ya, yb);
    }

    #[test]
    fn deterministic_noise_per_seed() {
        let mut a = GaussianMac::new(8, 1, 1.0, 9);
        let mut b = GaussianMac::new(8, 1, 1.0, 9);
        assert_eq!(a.transmit(&[vec![0.0; 8]]), b.transmit(&[vec![0.0; 8]]));
    }
}
