//! Shared experiment runner: execute a set of labeled runs, write one CSV
//! per run plus a combined summary, and print the paper-style series.
//!
//! Runs within a spec are independent seeded trainers, so quiet
//! invocations fan them out across the thread pool
//! ([`crate::util::threadpool::par_map`]); every run's randomness is
//! derived from its own config, so the parallel path produces CSV and
//! summary files whose *contents* are identical to the sequential path
//! (asserted in a test — only the wall-clock `round_secs` column differs,
//! timing being timing). Verbose runs stay sequential: per-round progress
//! lines from concurrent trainers would interleave into noise.

use crate::config::RunConfig;
use crate::coordinator::{TrainLog, Trainer};
use crate::model::PARAM_DIM;
use crate::util::csv::CsvWriter;
use crate::util::threadpool::{default_workers, par_map};

/// One experiment = one figure: several labeled runs over the same axis.
pub struct ExperimentSpec {
    /// Short id, e.g. "fig2a" (becomes the results directory name).
    pub id: String,
    /// Human title printed above the series.
    pub title: String,
    pub runs: Vec<(String, RunConfig)>,
}

/// Execute a spec, writing `results/<id>/<label>.csv`. Quiet runs execute
/// in parallel across the spec's runs; verbose runs stay sequential so the
/// per-round progress stream remains readable.
pub fn run_experiment(spec: &ExperimentSpec, out_dir: &str, verbose: bool) -> Vec<TrainLog> {
    let workers = if verbose {
        1
    } else {
        default_workers(spec.runs.len())
    };
    run_experiment_with_workers(spec, out_dir, verbose, workers)
}

/// Execute a spec with an explicit run-level worker count (`1` forces the
/// sequential path; the byte-identity test compares the two).
pub fn run_experiment_with_workers(
    spec: &ExperimentSpec,
    out_dir: &str,
    verbose: bool,
    workers: usize,
) -> Vec<TrainLog> {
    println!("\n### {} — {}", spec.id, spec.title);
    let logs: Vec<TrainLog> = if workers <= 1 {
        // Sequential: header before each run so verbose progress lines
        // land under it.
        spec.runs
            .iter()
            .map(|(label, cfg)| {
                print_run_header(label, cfg);
                execute_run(label, cfg, verbose)
            })
            .collect()
    } else {
        let logs = par_map(spec.runs.len(), workers, |i| {
            let (label, cfg) = &spec.runs[i];
            execute_run(label, cfg, verbose)
        });
        for (label, cfg) in &spec.runs {
            print_run_header(label, cfg);
        }
        logs
    };
    write_outputs(spec, &logs, out_dir);
    logs
}

/// Write per-run CSVs plus the combined summary, print the paper-style
/// series, and assert the Eq. 6 power audit — for logs that were just
/// executed *or* loaded from the campaign run cache (the cache path in
/// [`crate::campaign::scheduler`] reuses this so cached and fresh
/// invocations produce byte-identical files).
pub fn write_outputs(spec: &ExperimentSpec, logs: &[TrainLog], out_dir: &str) {
    let filenames = unique_filenames(spec.runs.iter().map(|(label, _)| label.as_str()));
    for (((label, _), log), fname) in spec.runs.iter().zip(logs).zip(&filenames) {
        let path = format!("{out_dir}/{}/{fname}.csv", spec.id);
        log.write_csv(&path).expect("write csv");
        // Headroom is stdout-only telemetry: the CSV columns (and so the
        // golden summary files) are untouched by it.
        let headroom = log.power_headroom();
        let headroom = if headroom.is_nan() {
            "  --".to_string()
        } else {
            format!("{:4.1}%", 100.0 * headroom)
        };
        println!(
            "    `{label}`: final acc {:.4} (best {:.4}) in {:.1}s, power headroom {headroom} → {path}",
            log.final_accuracy,
            log.best_accuracy(),
            log.total_secs
        );
        assert!(
            log.power_constraint_ok(1e-6),
            "power constraint violated in `{label}`"
        );
    }
    write_summary(spec, logs, out_dir);
    print_series(spec, logs);
}

/// The per-run banner line, shared with the campaign scheduler so cached
/// and uncached invocations stay visually identical.
pub fn print_run_header(label: &str, cfg: &RunConfig) {
    println!(
        "--- run `{label}` [{} link]: {}",
        cfg.scheme.kind().name(),
        cfg.summary()
    );
}

fn execute_run(label: &str, cfg: &RunConfig, verbose: bool) -> TrainLog {
    cfg.validate(PARAM_DIM).expect("invalid experiment config");
    let mut trainer = Trainer::new(cfg.clone()).expect("trainer construction");
    trainer.verbose = verbose;
    let mut log = trainer.run();
    log.label = label.to_string();
    log
}

fn sanitize(label: &str) -> String {
    label
        .chars()
        .map(|c| if c.is_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
        .collect()
}

/// Per-run CSV filenames (without extension), deduplicated in spec order:
/// sanitizing is lossy (`"a b"` and `"a_b"` both map to `a_b`), and before
/// deduplication two such runs silently overwrote each other's CSVs. The
/// first claimant keeps the bare name; later collisions get `_2`, `_3`, …
/// — including collisions *with* an already-suffixed name.
pub fn unique_filenames<'a>(labels: impl Iterator<Item = &'a str>) -> Vec<String> {
    let mut used = std::collections::HashSet::new();
    labels
        .map(|label| {
            let base = sanitize(label);
            let mut name = base.clone();
            let mut n = 1usize;
            while !used.insert(name.clone()) {
                n += 1;
                name = format!("{base}_{n}");
            }
            name
        })
        .collect()
}

/// Combined summary CSV: one row per (run, evaluated iteration).
fn write_summary(spec: &ExperimentSpec, logs: &[TrainLog], out_dir: &str) {
    let path = format!("{out_dir}/{}/summary.csv", spec.id);
    let mut w = CsvWriter::create(
        &path,
        &["run", "iter", "test_accuracy", "channel_uses", "pbar", "devices"],
    )
    .expect("create summary csv");
    for ((label, cfg), log) in spec.runs.iter().zip(logs) {
        for (iter, acc) in log.accuracy_series() {
            w.write_row_str(&[
                label,
                &iter.to_string(),
                &format!("{acc}"),
                &cfg.channel_uses.to_string(),
                &format!("{}", cfg.pbar),
                &cfg.devices.to_string(),
            ])
            .expect("summary row");
        }
    }
    w.flush().ok();
}

/// Paper-style printout: accuracy series side by side.
fn print_series(spec: &ExperimentSpec, logs: &[TrainLog]) {
    println!("\n{} — test accuracy vs iteration", spec.title);
    let mut header = format!("{:>6}", "t");
    for log in logs {
        header.push_str(&format!("  {:>18}", truncate(&log.label, 18)));
    }
    println!("{header}");
    // Union of evaluated iterations (assume aligned cadence; take first log).
    let iters: Vec<usize> = logs
        .first()
        .map(|l| l.accuracy_series().iter().map(|&(t, _)| t).collect())
        .unwrap_or_default();
    for t in iters {
        let mut line = format!("{t:>6}");
        for log in logs {
            let v = log
                .accuracy_series()
                .iter()
                .find(|&&(it, _)| it == t)
                .map(|&(_, a)| a);
            match v {
                Some(a) => line.push_str(&format!("  {a:>18.4}")),
                None => line.push_str(&format!("  {:>18}", "--")),
            }
        }
        println!("{line}");
    }
    // Final standings, best-first (the paper's qualitative ordering).
    let mut order: Vec<(&str, f64)> = logs
        .iter()
        .map(|l| (l.label.as_str(), l.final_accuracy))
        .collect();
    order.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("\nfinal ranking:");
    for (label, acc) in order {
        println!("  {acc:.4}  {label}");
    }
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("{}…", &s[..n - 1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Scheme};

    #[test]
    fn runner_executes_and_writes_csv() {
        let dir = std::env::temp_dir().join("ota_runner_test");
        let out = dir.to_str().unwrap();
        let mut cfg = presets::smoke();
        cfg.iterations = 4;
        cfg.eval_every = 2;
        let spec = ExperimentSpec {
            id: "t0".into(),
            title: "smoke".into(),
            runs: vec![
                ("error-free".into(), RunConfig { scheme: Scheme::ErrorFree, ..cfg.clone() }),
                ("adsgd".into(), cfg),
            ],
        };
        let logs = run_experiment(&spec, out, false);
        assert_eq!(logs.len(), 2);
        assert!(dir.join("t0/error-free.csv").exists());
        assert!(dir.join("t0/adsgd.csv").exists());
        assert!(dir.join("t0/summary.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Regression: two labels that sanitize to the same filename used to
    /// silently overwrite each other's per-run CSVs; they must now land in
    /// distinct suffixed files.
    #[test]
    fn colliding_labels_get_unique_filenames() {
        assert_eq!(
            unique_filenames(["a b", "a_b", "a b", "c"].into_iter()),
            vec!["a_b", "a_b_2", "a_b_3", "c"]
        );
        // A label that already carries a suffix cannot be clobbered either.
        assert_eq!(
            unique_filenames(["x y", "x_y", "x_y_2"].into_iter()),
            vec!["x_y", "x_y_2", "x_y_2_2"]
        );

        // End to end: both runs' CSVs exist with full row counts.
        let dir = std::env::temp_dir().join("ota_runner_collision_test");
        let _ = std::fs::remove_dir_all(&dir);
        let out = dir.to_str().unwrap();
        let mut cfg = presets::smoke();
        cfg.iterations = 2;
        cfg.eval_every = 1;
        cfg.scheme = Scheme::ErrorFree;
        let spec = ExperimentSpec {
            id: "tcol".into(),
            title: "collision".into(),
            runs: vec![
                ("run 1".into(), cfg.clone()),
                ("run_1".into(), RunConfig { seed: cfg.seed + 1, ..cfg }),
            ],
        };
        run_experiment(&spec, out, false);
        let a = crate::util::csv::read_csv(dir.join("tcol/run_1.csv")).unwrap();
        let b = crate::util::csv::read_csv(dir.join("tcol/run_1_2.csv")).unwrap();
        assert_eq!(a.len(), 3, "header + 2 rounds");
        assert_eq!(b.len(), 3, "the second run must not be clobbered");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The run-parallel path must produce the same files as the sequential
    /// path: summary.csv byte-for-byte, per-run CSVs identical in every
    /// column except the wall-clock `round_secs` (timing is timing).
    #[test]
    fn parallel_runs_match_sequential_output() {
        let spec = || {
            let mut cfg = presets::smoke();
            cfg.iterations = 4;
            cfg.eval_every = 2;
            ExperimentSpec {
                id: "tpar".into(),
                title: "parallel-vs-sequential".into(),
                runs: vec![
                    (
                        "error-free".into(),
                        RunConfig {
                            scheme: Scheme::ErrorFree,
                            ..cfg.clone()
                        },
                    ),
                    (
                        "signsgd".into(),
                        RunConfig {
                            scheme: Scheme::SignSgd,
                            ..cfg.clone()
                        },
                    ),
                    (
                        "qsgd".into(),
                        RunConfig {
                            scheme: Scheme::Qsgd,
                            ..cfg
                        },
                    ),
                ],
            }
        };
        let seq_dir = std::env::temp_dir().join("ota_runner_seq");
        let par_dir = std::env::temp_dir().join("ota_runner_par");
        run_experiment_with_workers(&spec(), seq_dir.to_str().unwrap(), false, 1);
        run_experiment_with_workers(&spec(), par_dir.to_str().unwrap(), false, 4);

        // summary.csv is fully deterministic → byte identity.
        let read = |p: &std::path::Path| std::fs::read(p).expect("read csv");
        assert_eq!(
            read(&seq_dir.join("tpar/summary.csv")),
            read(&par_dir.join("tpar/summary.csv")),
            "summary.csv must be byte-identical"
        );
        // Per-run CSVs: identical after masking the timing column.
        for label in ["error-free", "signsgd", "qsgd"] {
            let seq = crate::util::csv::read_csv(&seq_dir.join(format!("tpar/{label}.csv")))
                .expect("seq csv");
            let par = crate::util::csv::read_csv(&par_dir.join(format!("tpar/{label}.csv")))
                .expect("par csv");
            assert_eq!(seq.len(), par.len(), "{label}: row count");
            let t_col = seq[0]
                .iter()
                .position(|h| h == "round_secs")
                .expect("round_secs column");
            for (i, (a, b)) in seq.iter().zip(&par).enumerate() {
                for (c, (va, vb)) in a.iter().zip(b).enumerate() {
                    if c != t_col {
                        assert_eq!(va, vb, "{label}: row {i} col {c}");
                    }
                }
            }
        }
        std::fs::remove_dir_all(&seq_dir).ok();
        std::fs::remove_dir_all(&par_dir).ok();
    }
}
