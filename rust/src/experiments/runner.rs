//! Shared experiment runner: execute a set of labeled runs, write one CSV
//! per run plus a combined summary, and print the paper-style series.

use crate::config::RunConfig;
use crate::coordinator::{TrainLog, Trainer};
use crate::model::PARAM_DIM;
use crate::util::csv::CsvWriter;

/// One experiment = one figure: several labeled runs over the same axis.
pub struct ExperimentSpec {
    /// Short id, e.g. "fig2a" (becomes the results directory name).
    pub id: String,
    /// Human title printed above the series.
    pub title: String,
    pub runs: Vec<(String, RunConfig)>,
}

/// Execute every run sequentially, writing `results/<id>/<label>.csv`.
pub fn run_experiment(spec: &ExperimentSpec, out_dir: &str, verbose: bool) -> Vec<TrainLog> {
    println!("\n### {} — {}", spec.id, spec.title);
    let mut logs = Vec::with_capacity(spec.runs.len());
    for (label, cfg) in &spec.runs {
        cfg.validate(PARAM_DIM).expect("invalid experiment config");
        println!(
            "--- run `{label}` [{} link]: {}",
            cfg.scheme.kind().name(),
            cfg.summary()
        );
        let mut trainer = Trainer::new(cfg.clone()).expect("trainer construction");
        trainer.verbose = verbose;
        let mut log = trainer.run();
        log.label = label.clone();
        let path = format!("{out_dir}/{}/{}.csv", spec.id, sanitize(label));
        log.write_csv(&path).expect("write csv");
        println!(
            "    final acc {:.4} (best {:.4}) in {:.1}s → {path}",
            log.final_accuracy,
            log.best_accuracy(),
            log.total_secs
        );
        assert!(
            log.power_constraint_ok(1e-6),
            "power constraint violated in `{label}`"
        );
        logs.push(log);
    }
    write_summary(spec, &logs, out_dir);
    print_series(spec, &logs);
    logs
}

fn sanitize(label: &str) -> String {
    label
        .chars()
        .map(|c| if c.is_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
        .collect()
}

/// Combined summary CSV: one row per (run, evaluated iteration).
fn write_summary(spec: &ExperimentSpec, logs: &[TrainLog], out_dir: &str) {
    let path = format!("{out_dir}/{}/summary.csv", spec.id);
    let mut w = CsvWriter::create(
        &path,
        &["run", "iter", "test_accuracy", "channel_uses", "pbar", "devices"],
    )
    .expect("create summary csv");
    for ((label, cfg), log) in spec.runs.iter().zip(logs) {
        for (iter, acc) in log.accuracy_series() {
            w.write_row_str(&[
                label,
                &iter.to_string(),
                &format!("{acc}"),
                &cfg.channel_uses.to_string(),
                &format!("{}", cfg.pbar),
                &cfg.devices.to_string(),
            ])
            .expect("summary row");
        }
    }
    w.flush().ok();
}

/// Paper-style printout: accuracy series side by side.
fn print_series(spec: &ExperimentSpec, logs: &[TrainLog]) {
    println!("\n{} — test accuracy vs iteration", spec.title);
    let mut header = format!("{:>6}", "t");
    for log in logs {
        header.push_str(&format!("  {:>18}", truncate(&log.label, 18)));
    }
    println!("{header}");
    // Union of evaluated iterations (assume aligned cadence; take first log).
    let iters: Vec<usize> = logs
        .first()
        .map(|l| l.accuracy_series().iter().map(|&(t, _)| t).collect())
        .unwrap_or_default();
    for t in iters {
        let mut line = format!("{t:>6}");
        for log in logs {
            let v = log
                .accuracy_series()
                .iter()
                .find(|&&(it, _)| it == t)
                .map(|&(_, a)| a);
            match v {
                Some(a) => line.push_str(&format!("  {a:>18.4}")),
                None => line.push_str(&format!("  {:>18}", "--")),
            }
        }
        println!("{line}");
    }
    // Final standings, best-first (the paper's qualitative ordering).
    let mut order: Vec<(&str, f64)> = logs
        .iter()
        .map(|l| (l.label.as_str(), l.final_accuracy))
        .collect();
    order.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("\nfinal ranking:");
    for (label, acc) in order {
        println!("  {acc:.4}  {label}");
    }
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("{}…", &s[..n - 1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Scheme};

    #[test]
    fn runner_executes_and_writes_csv() {
        let dir = std::env::temp_dir().join("ota_runner_test");
        let out = dir.to_str().unwrap();
        let mut cfg = presets::smoke();
        cfg.iterations = 4;
        cfg.eval_every = 2;
        let spec = ExperimentSpec {
            id: "t0".into(),
            title: "smoke".into(),
            runs: vec![
                ("error-free".into(), RunConfig { scheme: Scheme::ErrorFree, ..cfg.clone() }),
                ("adsgd".into(), cfg),
            ],
        };
        let logs = run_experiment(&spec, out, false);
        assert_eq!(logs.len(), 2);
        assert!(dir.join("t0/error-free.csv").exists());
        assert!(dir.join("t0/adsgd.csv").exists());
        assert!(dir.join("t0/summary.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
