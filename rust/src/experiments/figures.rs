//! Experiment drivers — one per figure of the paper's §VI evaluation.
//!
//! Each returns an [`ExperimentSpec`] whose runs reproduce the figure's
//! series; `full` switches between the paper's exact horizon and the
//! reduced default (see `config::presets`). The bench targets under
//! `rust/benches/` time one round of each spec; the CLI (`repro fig N`)
//! runs them to completion and writes `results/figN*/`.

use crate::config::presets::{self, MODEL_DIM};
use crate::config::{GraphFamily, PowerSchedule, RunConfig, Scheme};

use super::runner::ExperimentSpec;

/// All schemes compared in Fig. 2 (both panels).
const FIG2_SCHEMES: [Scheme; 5] = [
    Scheme::ErrorFree,
    Scheme::ADsgd,
    Scheme::DDsgd,
    Scheme::SignSgd,
    Scheme::Qsgd,
];

/// Fig. 2a (IID) / 2b (non-IID): scheme shoot-out at M=25, B=1000, P̄=500.
pub fn fig2(noniid: bool, full: bool) -> ExperimentSpec {
    let runs = FIG2_SCHEMES
        .iter()
        .map(|&s| (s.name().to_string(), presets::fig2(s, noniid, full)))
        .collect();
    ExperimentSpec {
        id: if noniid { "fig2b".into() } else { "fig2a".into() },
        title: format!(
            "Fig. 2{}: schemes under {} data distribution",
            if noniid { "b" } else { "a" },
            if noniid { "non-IID" } else { "IID" }
        ),
        runs,
    }
}

/// Fig. 3: D-DSGD power-allocation schedules at P̄=200 (+ A-DSGD + error-free).
pub fn fig3(full: bool) -> ExperimentSpec {
    let mut runs: Vec<(String, RunConfig)> = vec![
        (
            "error-free".into(),
            presets::fig3(Scheme::ErrorFree, PowerSchedule::Constant, full),
        ),
        (
            "A-DSGD Pt=Pbar".into(),
            presets::fig3(Scheme::ADsgd, PowerSchedule::Constant, full),
        ),
    ];
    for sched in [
        PowerSchedule::Constant,
        PowerSchedule::LhStair,
        PowerSchedule::Lh,
        PowerSchedule::Hl,
    ] {
        runs.push((
            format!("D-DSGD {}", sched.name()),
            presets::fig3(Scheme::DDsgd, sched, full),
        ));
    }
    ExperimentSpec {
        id: "fig3".into(),
        title: "Fig. 3: power allocation schedules (P̄=200)".into(),
        runs,
    }
}

/// Fig. 4: P̄ ∈ {200, 1000} for A-DSGD and D-DSGD.
pub fn fig4(full: bool) -> ExperimentSpec {
    let mut runs = vec![(
        "error-free".into(),
        presets::fig4(Scheme::ErrorFree, 1000.0, full),
    )];
    for pbar in [200.0, 1000.0] {
        runs.push((
            format!("A-DSGD Pbar={pbar}"),
            presets::fig4(Scheme::ADsgd, pbar, full),
        ));
        runs.push((
            format!("D-DSGD Pbar={pbar}"),
            presets::fig4(Scheme::DDsgd, pbar, full),
        ));
    }
    ExperimentSpec {
        id: "fig4".into(),
        title: "Fig. 4: average-power sweep".into(),
        runs,
    }
}

/// Fig. 5: bandwidth s ∈ {d/2, 3d/10} at M=20, P̄=500.
pub fn fig5(full: bool) -> ExperimentSpec {
    let mut runs = vec![(
        "error-free".into(),
        presets::fig5(Scheme::ErrorFree, MODEL_DIM / 2, full),
    )];
    for s in [MODEL_DIM / 2, 3 * MODEL_DIM / 10] {
        runs.push((
            format!("A-DSGD s={s}"),
            presets::fig5(Scheme::ADsgd, s, full),
        ));
        runs.push((
            format!("D-DSGD s={s}"),
            presets::fig5(Scheme::DDsgd, s, full),
        ));
    }
    ExperimentSpec {
        id: "fig5".into(),
        title: "Fig. 5: channel-bandwidth sweep".into(),
        runs,
    }
}

/// Fig. 6: device scaling (M,B) ∈ {(10,2000),(20,1000)} × P̄ ∈ {1,500},
/// MB fixed; D-DSGD at P̄=1 sends zero bits and fails (paper's point).
pub fn fig6(full: bool) -> ExperimentSpec {
    let mut runs = vec![(
        "error-free M=20".into(),
        presets::fig6(Scheme::ErrorFree, 20, 1000, 500.0, full),
    )];
    for (m, b) in [(10usize, 2000usize), (20, 1000)] {
        for pbar in [1.0, 500.0] {
            runs.push((
                format!("A-DSGD M={m} Pbar={pbar}"),
                presets::fig6(Scheme::ADsgd, m, b, pbar, full),
            ));
        }
        runs.push((
            format!("D-DSGD M={m} Pbar=500"),
            presets::fig6(Scheme::DDsgd, m, b, 500.0, full),
        ));
        // D-DSGD at P̄=1: included to demonstrate the zero-bit failure.
        runs.push((
            format!("D-DSGD M={m} Pbar=1"),
            presets::fig6(Scheme::DDsgd, m, b, 1.0, full),
        ));
    }
    ExperimentSpec {
        id: "fig6".into(),
        title: "Fig. 6: device scaling with MB fixed".into(),
        runs,
    }
}

/// Fig. 7: A-DSGD s ∈ {d/10, d/5, d/2}, k=⌊4s/5⌋, P̄=50. The driver prints
/// both the per-iteration axis (7a) and the total-symbols axis (7b).
pub fn fig7(full: bool) -> ExperimentSpec {
    let runs = [MODEL_DIM / 10, MODEL_DIM / 5, MODEL_DIM / 2]
        .iter()
        .map(|&s| (format!("A-DSGD s={s}"), presets::fig7(s, full)))
        .collect();
    ExperimentSpec {
        id: "fig7".into(),
        title: "Fig. 7: bandwidth vs iteration-count trade-off (P̄=50)".into(),
        runs,
    }
}

/// Fading-MAC sweep (beyond the source paper; companion works Amiri &
/// Gündüz 2019 / Amiri, Duman & Gündüz 2019): CSI truncated inversion
/// across gain thresholds, the blind no-CSI variant, partial participation,
/// and straggler deadlines, anchored by the static A-DSGD and error-free
/// runs.
pub fn fading(full: bool) -> ExperimentSpec {
    let mut runs: Vec<(String, RunConfig)> = vec![
        (
            "error-free".into(),
            presets::fading_sweep(Scheme::ErrorFree, full),
        ),
        (
            "A-DSGD static".into(),
            presets::fading_sweep(Scheme::ADsgd, full),
        ),
    ];
    for th in [0.1, 0.5, 1.0] {
        let mut cfg = presets::fading_sweep(Scheme::FadingADsgd, full);
        cfg.csi_threshold = th;
        runs.push((format!("fading CSI th={th}"), cfg));
    }
    runs.push((
        "fading blind".into(),
        presets::fading_sweep(Scheme::BlindADsgd, full),
    ));
    let mut half = presets::fading_sweep(Scheme::FadingADsgd, full);
    half.participation = crate::config::ParticipationPolicy::UniformK(half.devices / 2);
    runs.push(("fading CSI K=M/2".into(), half));
    let mut strag = presets::fading_sweep(Scheme::FadingADsgd, full);
    strag.latency_mean_secs = 0.01;
    strag.deadline_secs = 0.025;
    runs.push(("fading CSI stragglers".into(), strag));
    ExperimentSpec {
        id: "fading".into(),
        title: "Fading MAC: CSI thresholds, blind, participation, stragglers".into(),
        runs,
    }
}

/// Decentralized D2D sweep (beyond the source paper; Xing, Simeone & Bi
/// 2021): star A-DSGD vs over-the-air consensus on every graph family at
/// matched power/bandwidth. One axis — the communication topology — while
/// M, s, k, P̄ and the data split stay fixed, so the accuracy/consensus
/// gap isolates what decentralization costs.
pub fn d2d(full: bool) -> ExperimentSpec {
    let mut runs: Vec<(String, RunConfig)> = vec![(
        "star A-DSGD (PS)".into(),
        presets::d2d_star_anchor(full),
    )];
    for family in [
        GraphFamily::Full,
        GraphFamily::Ring,
        GraphFamily::Torus,
        GraphFamily::ErdosRenyi,
    ] {
        let cfg = presets::d2d_sweep(family, full);
        runs.push((format!("D2D {}", cfg.topology.describe()), cfg));
    }
    ExperimentSpec {
        id: "d2d".into(),
        title: "D2D over-the-air consensus: graph families at matched power/bandwidth".into(),
        runs,
    }
}

/// Fig. 7b view: accuracy against transmitted symbols t·s.
pub fn print_fig7b(logs: &[crate::coordinator::TrainLog], specs: &[(String, RunConfig)]) {
    println!("\nFig. 7b — test accuracy vs total transmitted symbols (t·s)");
    println!("{:>14} {:>18} {:>10}", "symbols", "run", "accuracy");
    for (log, (label, cfg)) in logs.iter().zip(specs) {
        for (t, acc) in log.accuracy_series() {
            println!(
                "{:>14} {:>18} {:>10.4}",
                (t + 1) * cfg.channel_uses,
                label,
                acc
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::PARAM_DIM;

    #[test]
    fn all_specs_validate() {
        for full in [false, true] {
            for spec in [
                fig2(false, full),
                fig2(true, full),
                fig3(full),
                fig4(full),
                fig5(full),
                fig6(full),
                fig7(full),
                fading(full),
                d2d(full),
            ] {
                assert!(!spec.runs.is_empty(), "{}", spec.id);
                for (label, cfg) in &spec.runs {
                    cfg.validate(PARAM_DIM)
                        .unwrap_or_else(|e| panic!("{}::{label}: {e}", spec.id));
                }
            }
        }
    }

    #[test]
    fn fig2_has_five_schemes() {
        assert_eq!(fig2(false, false).runs.len(), 5);
    }

    #[test]
    fn d2d_covers_star_and_four_families() {
        let spec = d2d(false);
        assert_eq!(spec.runs.len(), 5);
        assert!(spec.runs[0].1.scheme == crate::config::Scheme::ADsgd);
        for (label, cfg) in &spec.runs[1..] {
            assert_eq!(cfg.scheme, crate::config::Scheme::D2dADsgd, "{label}");
            // Matched power/bandwidth against the anchor.
            assert_eq!(cfg.channel_uses, spec.runs[0].1.channel_uses);
            assert_eq!(cfg.pbar, spec.runs[0].1.pbar);
            assert_eq!(cfg.devices, spec.runs[0].1.devices);
        }
        let labels: Vec<&str> = spec.runs.iter().map(|(l, _)| l.as_str()).collect();
        assert!(labels.iter().any(|l| l.contains("ring")));
        assert!(labels.iter().any(|l| l.contains("torus")));
        assert!(labels.iter().any(|l| l.contains("er")));
    }

    #[test]
    fn fig3_schedule_labels_unique() {
        let spec = fig3(false);
        let mut labels: Vec<&str> = spec.runs.iter().map(|(l, _)| l.as_str()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), spec.runs.len());
    }

    #[test]
    fn fig6_includes_pbar1_ddsgd_failure_case() {
        let spec = fig6(false);
        assert!(spec
            .runs
            .iter()
            .any(|(l, c)| l.contains("D-DSGD") && c.pbar == 1.0));
    }
}
