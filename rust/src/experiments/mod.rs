//! Reproductions of every table/figure in the paper's evaluation (§VI) plus
//! the Theorem-1 analytics (§V). See DESIGN.md §5 for the experiment index.

pub mod ablations;
pub mod figures;
pub mod runner;
pub mod theory;

pub use runner::{run_experiment, ExperimentSpec};
