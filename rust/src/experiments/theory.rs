//! Theorem 1 convergence-bound curves (§V).
//!
//! Evaluates the paper's analytical machinery numerically: λ (Corollary 1),
//! σ_max (Lemma 3), ρ(δ) (Lemma 2), the per-iteration error budget v(t)
//! (Lemma 4, Eq. 37b) and its closed-form sum for P_t = P̄ (Eq. 42), the
//! step-size cap (Eq. 40), and the failure-probability bound Pr{E_T}
//! (Eq. 41) — demonstrating Pr{E_T} → 0 as T → ∞.

use crate::util::csv::CsvWriter;
use crate::util::stats::rho_delta;

/// Parameters of the Theorem-1 setting.
#[derive(Clone, Debug)]
pub struct TheoryParams {
    pub d: usize,
    pub s: usize,
    pub k: usize,
    pub devices: usize,
    pub pbar: f64,
    pub noise_sd: f64,
    /// Gradient first-moment bound G (Assumption 1).
    pub grad_bound: f64,
    /// Strong-convexity constant c.
    pub convexity: f64,
    /// Success-region radius ε.
    pub epsilon: f64,
    /// ‖θ*‖² for the log term in Eq. 41.
    pub theta_star_sq: f64,
    /// Tail probability δ for ρ(δ).
    pub delta: f64,
}

impl Default for TheoryParams {
    fn default() -> Self {
        TheoryParams {
            d: 7850,
            s: 3925,
            k: 1962,
            devices: 25,
            pbar: 500.0,
            noise_sd: 1.0,
            grad_bound: 1.0,
            convexity: 40.0,
            epsilon: 1.0,
            theta_star_sq: 25.0,
            delta: 0.01,
        }
    }
}

/// Derived constants + series.
#[derive(Clone, Debug)]
pub struct TheoryCurve {
    pub lambda: f64,
    pub sigma_max: f64,
    pub rho: f64,
    /// v(t) for t = 0..T−1 (Eq. 37b).
    pub v: Vec<f64>,
    /// (T, η_max(T), Pr{E_T} bound) rows for the horizon sweep.
    pub rows: Vec<(usize, f64, f64)>,
}

impl TheoryParams {
    /// Corollary 1's sparsification constant λ = √((d−k)/d).
    pub fn lambda(&self) -> f64 {
        (((self.d - self.k) as f64) / self.d as f64).sqrt()
    }

    /// Lemma 3's σ_max = √(d/(s−1)) + 1 (asymptotic largest singular value).
    pub fn sigma_max(&self) -> f64 {
        (self.d as f64 / (self.s as f64 - 1.0)).sqrt() + 1.0
    }

    /// Eq. 37b: v(t) with P_t = P̄.
    pub fn v_t(&self, t: usize, rho: f64) -> f64 {
        let lam = self.lambda();
        let g = self.grad_bound;
        let m = self.devices as f64;
        let sig = self.noise_sd;
        let lam_t = lam.powi(t as i32);
        let lam_t1 = lam.powi(t as i32 + 1);
        let first = lam * ((1.0 + lam) * (1.0 - lam_t) / (1.0 - lam) + 1.0) * g;
        let second = rho * sig / (m * self.pbar.sqrt())
            * (self.sigma_max() * (1.0 - lam_t1) / (1.0 - lam) * g + 1.0);
        first + second
    }

    /// Closed-form Σ_{t=0}^{T−1} v(t) (Eq. 42) — cross-checked against the
    /// direct sum in tests.
    ///
    /// Note: the paper's printed Eq. 42 has `(1 − λ^{T+1})` in the second
    /// subtracted term; summing Eq. 37b exactly gives `λ(1 − λ^T)`
    /// (Σ_{t=0}^{T−1} λ^{t+1} = λ(1−λ^T)/(1−λ)) — a typo we correct here so
    /// the closed form matches the direct sum to machine precision.
    pub fn sum_v_closed_form(&self, t_horizon: usize, rho: f64) -> f64 {
        let lam = self.lambda();
        let g = self.grad_bound;
        let m = self.devices as f64;
        let sig = self.noise_sd;
        let t = t_horizon as f64;
        let a = 2.0 * lam * g / (1.0 - lam)
            + sig * rho / (m * self.pbar.sqrt()) * (self.sigma_max() * g / (1.0 - lam) + 1.0);
        let b = lam * (1.0 + lam) * (1.0 - lam.powi(t_horizon as i32)) * g / (1.0 - lam).powi(2)
            + sig * rho * self.sigma_max() * lam * (1.0 - lam.powi(t_horizon as i32)) * g
                / (m * self.pbar.sqrt() * (1.0 - lam).powi(2));
        a * t - b
    }

    /// Eq. 40: the step-size cap η_max(T).
    pub fn eta_max(&self, t_horizon: usize, sum_v: f64) -> f64 {
        let t = t_horizon as f64;
        2.0 * (self.convexity * self.epsilon * t - self.epsilon.sqrt() * sum_v)
            / (t * self.grad_bound * self.grad_bound)
    }

    /// Eq. 41 with η = η_max/2 (a feasible step size).
    pub fn failure_bound(&self, t_horizon: usize, rho: f64) -> (f64, f64) {
        let sum_v = self.sum_v_closed_form(t_horizon, rho);
        let eta_cap = self.eta_max(t_horizon, sum_v);
        if eta_cap <= 0.0 {
            return (eta_cap, 1.0); // infeasible horizon: vacuous bound
        }
        let eta = eta_cap / 2.0;
        let g2 = self.grad_bound * self.grad_bound;
        let denom_opt = 2.0 * eta * self.convexity * self.epsilon - eta * eta * g2;
        let l = 2.0 * self.epsilon.sqrt() / denom_opt;
        let t = t_horizon as f64;
        let effective_t = t - eta * l * sum_v;
        if effective_t <= 0.0 {
            return (eta, 1.0);
        }
        let log_term = (std::f64::consts::E * self.theta_star_sq / self.epsilon).ln();
        let bound = self.epsilon / (denom_opt * effective_t) * log_term;
        (eta, bound.min(1.0))
    }

    /// Full curve over a horizon sweep.
    pub fn curve(&self, horizons: &[usize]) -> TheoryCurve {
        let rho = rho_delta(self.d, self.delta);
        let t_max = horizons.iter().copied().max().unwrap_or(0);
        let v = (0..t_max).map(|t| self.v_t(t, rho)).collect();
        let rows = horizons
            .iter()
            .map(|&t| {
                let (eta, bound) = self.failure_bound(t, rho);
                (t, eta, bound)
            })
            .collect();
        TheoryCurve {
            lambda: self.lambda(),
            sigma_max: self.sigma_max(),
            rho,
            v,
            rows,
        }
    }
}

/// CLI driver: print + CSV the Theorem-1 curves.
pub fn run(params: &TheoryParams, out_dir: &str) -> TheoryCurve {
    let horizons: Vec<usize> = (1..=20).map(|i| i * 500).collect();
    let curve = params.curve(&horizons);
    println!("\n### Theorem 1 — convergence bound (strongly convex case)");
    println!(
        "λ = {:.4}, σ_max = {:.4}, ρ(δ={}) = {:.2}",
        curve.lambda, curve.sigma_max, params.delta, curve.rho
    );
    println!("{:>8} {:>14} {:>16}", "T", "eta_max/2", "Pr{E_T} bound");
    for &(t, eta, bound) in &curve.rows {
        println!("{t:>8} {eta:>14.6} {bound:>16.6}");
    }
    let path = format!("{out_dir}/theory/theorem1.csv");
    let mut w = CsvWriter::create(&path, &["T", "eta", "bound"]).expect("csv");
    for &(t, eta, bound) in &curve.rows {
        w.write_row(&[t as f64, eta, bound]).ok();
    }
    println!("→ {path}");
    curve
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_form_matches_direct_sum() {
        let p = TheoryParams::default();
        let rho = rho_delta(p.d, p.delta);
        for t_h in [1usize, 5, 50, 200] {
            let direct: f64 = (0..t_h).map(|t| p.v_t(t, rho)).sum();
            let closed = p.sum_v_closed_form(t_h, rho);
            assert!(
                (direct - closed).abs() < 1e-6 * direct.abs().max(1.0),
                "T={t_h}: direct {direct} vs closed {closed}"
            );
        }
    }

    #[test]
    fn v_t_increases_then_saturates() {
        let p = TheoryParams::default();
        let rho = rho_delta(p.d, p.delta);
        let v0 = p.v_t(0, rho);
        let v10 = p.v_t(10, rho);
        let v100 = p.v_t(100, rho);
        let v200 = p.v_t(200, rho);
        assert!(v10 > v0);
        assert!(v200 >= v100 * 0.999);
        // Saturation: geometric terms vanish.
        assert!((v200 - v100).abs() < 0.01 * v100);
    }

    #[test]
    fn bound_vanishes_as_t_grows() {
        let p = TheoryParams::default();
        let curve = p.curve(&[500, 2000, 10_000]);
        let bounds: Vec<f64> = curve.rows.iter().map(|r| r.2).collect();
        assert!(bounds[0] > bounds[1] && bounds[1] > bounds[2], "{bounds:?}");
        assert!(bounds[2] < 0.1, "Pr bound should approach 0: {bounds:?}");
    }

    #[test]
    fn more_power_tightens_noise_term() {
        let lo = TheoryParams {
            pbar: 1.0,
            ..TheoryParams::default()
        };
        let hi = TheoryParams {
            pbar: 1000.0,
            ..TheoryParams::default()
        };
        let rho = rho_delta(lo.d, lo.delta);
        assert!(hi.v_t(50, rho) < lo.v_t(50, rho));
    }

    #[test]
    fn lambda_and_sigma_max_formulas() {
        let p = TheoryParams {
            d: 100,
            k: 36,
            s: 26,
            ..TheoryParams::default()
        };
        assert!((p.lambda() - 0.8).abs() < 1e-12);
        assert!((p.sigma_max() - 3.0).abs() < 1e-12);
    }
}
