//! Ablation studies over A-DSGD's design choices (DESIGN.md §5), run via
//! `repro ablate`:
//!
//! * **mean removal** (§IV-A) on/off — does spending two side channel uses
//!   on the projected mean help early convergence?
//! * **sparsity level k** — Remark 5's trade-off: small k → reliable AMP
//!   recovery of an inaccurate average; large k → accurate average that
//!   AMP recovers unreliably.
//! * **AMP denoiser threshold α** — the decoder's only free parameter.
//! * **power schedule under A-DSGD** — Remark 3: is constant power really
//!   the robust choice for the analog scheme?

use crate::config::{PowerSchedule, RunConfig, Scheme};

use super::runner::ExperimentSpec;

fn base(full: bool) -> RunConfig {
    let mut cfg = crate::config::presets::fig2(Scheme::ADsgd, false, full);
    if !full {
        // Ablations sweep many runs; shrink the corpus (not the channel).
        cfg.devices = 15;
        cfg.local_samples = 400;
        cfg.dataset = crate::config::DatasetSpec::Synthetic {
            train: 8_000,
            test: 2_000,
        };
        cfg.iterations = 40;
        cfg.eval_every = 4;
    }
    cfg
}

/// Mean-removal ablation (§IV-A).
pub fn mean_removal(full: bool) -> ExperimentSpec {
    let runs = [0usize, 5, 20, usize::MAX]
        .iter()
        .map(|&rounds| {
            let mut cfg = base(full);
            cfg.mean_removal_rounds = if rounds == usize::MAX {
                cfg.iterations
            } else {
                rounds
            };
            let label = match rounds {
                0 => "no mean removal".to_string(),
                usize::MAX => "mean removal always".to_string(),
                r => format!("mean removal first {r}"),
            };
            (label, cfg)
        })
        .collect();
    ExperimentSpec {
        id: "ablate_mean_removal".into(),
        title: "Ablation: §IV-A mean removal".into(),
        runs,
    }
}

/// Sparsity-level ablation (Remark 5).
pub fn sparsity(full: bool) -> ExperimentSpec {
    let cfg0 = base(full);
    let s = cfg0.channel_uses;
    let runs = [s / 8, s / 4, s / 2, 4 * s / 5]
        .iter()
        .map(|&k| {
            let mut cfg = cfg0.clone();
            cfg.sparsity = k;
            (format!("k = {k} (s/{:.0})", s as f64 / k as f64), cfg)
        })
        .collect();
    ExperimentSpec {
        id: "ablate_sparsity".into(),
        title: "Ablation: sparsification level k (Remark 5)".into(),
        runs,
    }
}

/// AMP threshold ablation.
pub fn amp_threshold(full: bool) -> ExperimentSpec {
    let runs = [0.8f64, 1.0, 1.1, 1.4, 2.0]
        .iter()
        .map(|&alpha| {
            let mut cfg = base(full);
            cfg.amp_threshold_mult = alpha;
            (format!("alpha = {alpha}"), cfg)
        })
        .collect();
    ExperimentSpec {
        id: "ablate_amp_threshold".into(),
        title: "Ablation: AMP soft-threshold multiplier".into(),
        runs,
    }
}

/// Power schedule under the analog scheme (Remark 3).
pub fn analog_power(full: bool) -> ExperimentSpec {
    let runs = [
        PowerSchedule::Constant,
        PowerSchedule::LhStair,
        PowerSchedule::Lh,
        PowerSchedule::Hl,
    ]
    .iter()
    .map(|&p| {
        let mut cfg = base(full);
        cfg.power = p;
        (format!("A-DSGD {}", p.name()), cfg)
    })
    .collect();
    ExperimentSpec {
        id: "ablate_analog_power".into(),
        title: "Ablation: power schedule under A-DSGD (Remark 3)".into(),
        runs,
    }
}

/// All ablations, in the order they are reported.
pub fn all(full: bool) -> Vec<ExperimentSpec> {
    vec![
        mean_removal(full),
        sparsity(full),
        amp_threshold(full),
        analog_power(full),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::PARAM_DIM;

    #[test]
    fn all_ablations_validate() {
        for spec in all(false) {
            assert!(spec.runs.len() >= 4, "{}", spec.id);
            for (label, cfg) in &spec.runs {
                cfg.validate(PARAM_DIM)
                    .unwrap_or_else(|e| panic!("{}::{label}: {e}", spec.id));
            }
        }
    }

    #[test]
    fn sparsity_ablation_spans_remark5_range() {
        let spec = sparsity(false);
        let ks: Vec<usize> = spec.runs.iter().map(|(_, c)| c.sparsity).collect();
        assert!(ks.windows(2).all(|w| w[0] < w[1]));
        let s = spec.runs[0].1.channel_uses;
        assert!(*ks.last().unwrap() < s, "k must stay below s");
    }

    #[test]
    fn mean_removal_covers_never_and_always() {
        let spec = mean_removal(false);
        let rounds: Vec<usize> = spec
            .runs
            .iter()
            .map(|(_, c)| c.mean_removal_rounds)
            .collect();
        assert_eq!(rounds[0], 0);
        assert_eq!(*rounds.last().unwrap(), spec.runs[0].1.iterations);
    }
}
