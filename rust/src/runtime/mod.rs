//! The PJRT runtime: load AOT-compiled HLO artifacts (lowered once from the
//! L2 JAX graphs by `python/compile/aot.py`) and execute them from rust.
//! Python never runs on this path.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::{Artifact, Manifest};
pub use pjrt::{Executable, PjrtBackend, PjrtRuntime};
