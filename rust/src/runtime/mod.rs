//! The PJRT runtime: load AOT-compiled HLO artifacts (lowered once from the
//! L2 JAX graphs by `python/compile/aot.py`) and execute them from rust.
//! Python never runs on this path.
//!
//! The real client wraps the external `xla` crate (an XLA C++ build), which
//! this repository cannot assume is present. The default build therefore
//! compiles `pjrt_stub.rs` — same public surface, every entry point reports
//! PJRT as unavailable — and the real implementation sits behind the `xla`
//! cargo feature.

pub mod artifacts;

#[cfg(feature = "xla")]
pub mod pjrt;

#[cfg(not(feature = "xla"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use artifacts::{Artifact, Manifest};
pub use pjrt::{Executable, PjrtBackend, PjrtRuntime};
