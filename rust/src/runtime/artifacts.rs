//! Artifact manifest: which HLO graphs `make artifacts` produced, with
//! their input/output shapes, so the runtime can pick the right executable
//! for a run configuration (shapes are baked at AOT time).
//!
//! `artifacts/manifest.txt` format (one artifact per line):
//!
//! ```text
//! name=grad kind=grad file=grad_m5_b120.hlo.txt devices=5 batch=120 dim=7850
//! name=projection kind=projection file=projection_s511_d4096.hlo.txt s_tilde=511 dim=4096
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One AOT-compiled graph.
#[derive(Clone, Debug, PartialEq)]
pub struct Artifact {
    pub name: String,
    pub kind: String,
    pub file: PathBuf,
    /// Shape metadata (devices/batch/dim/s_tilde/...).
    pub meta: BTreeMap<String, usize>,
}

impl Artifact {
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).copied()
    }
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<Artifact>,
    pub root: PathBuf,
}

impl Manifest {
    /// Default artifact directory (repo-root `artifacts/`), overridable via
    /// `OTA_ARTIFACTS`.
    pub fn default_dir() -> PathBuf {
        std::env::var("OTA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn load_default() -> anyhow::Result<Manifest> {
        Self::load(&Self::default_dir())
    }

    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {path:?}: {e}; run `make artifacts`"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> anyhow::Result<Manifest> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut name = None;
            let mut kind = None;
            let mut file = None;
            let mut meta = BTreeMap::new();
            for tok in line.split_whitespace() {
                let (k, v) = tok
                    .split_once('=')
                    .ok_or_else(|| anyhow::anyhow!("manifest line {}: bad token {tok:?}", lineno + 1))?;
                match k {
                    "name" => name = Some(v.to_string()),
                    "kind" => kind = Some(v.to_string()),
                    "file" => file = Some(dir.join(v)),
                    other => {
                        let n: usize = v.parse().map_err(|_| {
                            anyhow::anyhow!("manifest line {}: non-numeric {other}={v}", lineno + 1)
                        })?;
                        meta.insert(other.to_string(), n);
                    }
                }
            }
            artifacts.push(Artifact {
                name: name.ok_or_else(|| anyhow::anyhow!("line {}: missing name", lineno + 1))?,
                kind: kind.ok_or_else(|| anyhow::anyhow!("line {}: missing kind", lineno + 1))?,
                file: file.ok_or_else(|| anyhow::anyhow!("line {}: missing file", lineno + 1))?,
                meta,
            });
        }
        Ok(Manifest {
            artifacts,
            root: dir.to_path_buf(),
        })
    }

    /// Find a gradient artifact matching (devices, batch).
    pub fn find_grad(&self, devices: usize, batch: usize) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| {
            a.kind == "grad"
                && a.meta_usize("devices") == Some(devices)
                && a.meta_usize("batch") == Some(batch)
        })
    }

    pub fn find_kind(&self, kind: &str) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.kind == kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_lines() {
        let text = "\
# comment
name=grad kind=grad file=grad_m5_b120.hlo.txt devices=5 batch=120 dim=7850
name=proj kind=projection file=proj.hlo.txt s_tilde=511 dim=4096
";
        let m = Manifest::parse(text, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let g = m.find_grad(5, 120).unwrap();
        assert_eq!(g.meta_usize("dim"), Some(7850));
        assert_eq!(g.file, Path::new("/tmp/a/grad_m5_b120.hlo.txt"));
        assert!(m.find_grad(7, 120).is_none());
        assert!(m.find_kind("projection").is_some());
    }

    #[test]
    fn rejects_bad_tokens() {
        assert!(Manifest::parse("name=x kind=y file=z shape=abc", Path::new(".")).is_err());
        assert!(Manifest::parse("noequals", Path::new(".")).is_err());
        assert!(Manifest::parse("kind=y file=z", Path::new(".")).is_err());
    }

    #[test]
    fn missing_dir_hint() {
        let err = Manifest::load(Path::new("/definitely/not/here")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
