//! Stub PJRT client, compiled when the `xla` feature is off (the default).
//!
//! Mirrors the public surface of `pjrt.rs` so `main.rs`, the integration
//! tests, and downstream callers compile unchanged; every constructor fails
//! with an actionable message instead of linking against libxla. The PJRT
//! integration tests skip themselves when no artifacts/manifest is present,
//! so the stub never panics under `cargo test` on a fresh checkout.

use std::path::Path;

use crate::coordinator::GradientBackend;
use crate::data::Dataset;
use crate::tensor::Matf;

use super::artifacts::Manifest;

const UNAVAILABLE: &str =
    "built without the `xla` cargo feature: PJRT execution is unavailable \
     (rebuild with `--features xla` and an xla_extension install)";

/// Stand-in for the live PJRT CPU client. Cannot be constructed.
#[derive(Debug)]
pub struct PjrtRuntime {
    _private: (),
}

impl PjrtRuntime {
    pub fn cpu() -> anyhow::Result<PjrtRuntime> {
        Err(anyhow::Error::msg(UNAVAILABLE))
    }

    pub fn platform(&self) -> String {
        unreachable!("PjrtRuntime cannot be constructed in stub builds")
    }

    pub fn load_hlo<P: AsRef<Path>>(&self, _path: P) -> anyhow::Result<Executable> {
        Err(anyhow::Error::msg(UNAVAILABLE))
    }
}

/// Stand-in for one compiled graph. Cannot be constructed.
#[derive(Debug)]
pub struct Executable {
    _private: (),
}

/// An f32 input buffer: data + dims (same shape as the real API).
pub struct InputF32<'a> {
    pub data: &'a [f32],
    pub dims: &'a [i64],
}

impl Executable {
    pub fn run_f32(&self, _inputs: &[InputF32<'_>]) -> anyhow::Result<Vec<Vec<f32>>> {
        Err(anyhow::Error::msg(UNAVAILABLE))
    }
}

/// Stand-in gradient backend. `from_manifest` always fails, so the trainer
/// falls back to [`crate::coordinator::RustBackend`] paths in stub builds.
pub struct PjrtBackend {
    _private: (),
}

impl PjrtBackend {
    pub fn from_manifest(
        _runtime: &PjrtRuntime,
        _manifest: &Manifest,
        _devices: usize,
        _batch: usize,
    ) -> anyhow::Result<PjrtBackend> {
        Err(anyhow::Error::msg(UNAVAILABLE))
    }
}

impl GradientBackend for PjrtBackend {
    fn per_device_gradients(
        &mut self,
        _params: &[f32],
        _train: &Dataset,
        _shards: &[Vec<usize>],
    ) -> Matf {
        unreachable!("PjrtBackend cannot be constructed in stub builds")
    }

    fn name(&self) -> &'static str {
        "pjrt-stub"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_constructors_fail_cleanly() {
        let err = PjrtRuntime::cpu().unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
