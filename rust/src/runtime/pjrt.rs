//! PJRT client wrapper: compile HLO **text** (the interchange format — see
//! DESIGN.md: jax ≥ 0.5 serialized protos use 64-bit ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids) and execute with f32
//! buffers. All graphs are lowered by `python/compile/aot.py` with
//! `return_tuple=True`, so outputs are always tuples.

use std::path::Path;

use crate::coordinator::GradientBackend;
use crate::data::{Dataset, IMG_PIXELS, NUM_CLASSES};
use crate::tensor::Matf;

use super::artifacts::Manifest;

/// A live PJRT CPU client.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> anyhow::Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file into an executable.
    pub fn load_hlo<P: AsRef<Path>>(&self, path: P) -> anyhow::Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path.as_ref())?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { exe })
    }
}

/// One compiled graph.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

/// An f32 input buffer: data + dims.
pub struct InputF32<'a> {
    pub data: &'a [f32],
    pub dims: &'a [i64],
}

impl Executable {
    /// Execute with f32 inputs; returns the tuple elements as flat f32
    /// vectors (aot.py lowers everything with return_tuple=True).
    pub fn run_f32(&self, inputs: &[InputF32<'_>]) -> anyhow::Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|inp| {
                let lit = xla::Literal::vec1(inp.data);
                let expect: i64 = inp.dims.iter().product();
                anyhow::ensure!(
                    expect == inp.data.len() as i64,
                    "dims {:?} do not match data length {}",
                    inp.dims,
                    inp.data.len()
                );
                Ok(lit.reshape(inp.dims)?)
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let out = result[0][0].to_literal_sync()?;
        let elements = out.to_tuple()?;
        elements
            .into_iter()
            .map(|lit| Ok(lit.to_vec::<f32>()?))
            .collect()
    }
}

/// Gradient backend that executes the AOT-lowered JAX gradient graph
/// (per-device batched: params[d], images[M,B,784], labels[M,B,10] →
/// grads[M,d]) through PJRT.
pub struct PjrtBackend {
    exe: Executable,
    devices: usize,
    batch: usize,
    dim: usize,
    /// Reused flattened input staging buffers.
    images_buf: Vec<f32>,
    labels_buf: Vec<f32>,
}

impl PjrtBackend {
    /// Build from the artifact manifest; fails with a clear message when no
    /// artifact matches the (M, B) of the run config.
    pub fn from_manifest(
        runtime: &PjrtRuntime,
        manifest: &Manifest,
        devices: usize,
        batch: usize,
    ) -> anyhow::Result<PjrtBackend> {
        let art = manifest.find_grad(devices, batch).ok_or_else(|| {
            anyhow::anyhow!(
                "no grad artifact for devices={devices} batch={batch}; \
                 regenerate with `python -m compile.aot --grad-shapes {devices}x{batch}`"
            )
        })?;
        let dim = art.meta_usize("dim").unwrap_or(crate::model::PARAM_DIM);
        let exe = runtime.load_hlo(&art.file)?;
        Ok(PjrtBackend {
            exe,
            devices,
            batch,
            dim,
            images_buf: vec![0.0; devices * batch * IMG_PIXELS],
            labels_buf: vec![0.0; devices * batch * NUM_CLASSES],
        })
    }
}

impl GradientBackend for PjrtBackend {
    fn per_device_gradients(
        &mut self,
        params: &[f32],
        train: &Dataset,
        shards: &[Vec<usize>],
    ) -> Matf {
        assert_eq!(shards.len(), self.devices, "artifact baked for M={}", self.devices);
        assert_eq!(params.len(), self.dim);
        self.labels_buf.fill(0.0);
        for (m, shard) in shards.iter().enumerate() {
            assert_eq!(shard.len(), self.batch, "artifact baked for B={}", self.batch);
            for (b, &i) in shard.iter().enumerate() {
                let off = (m * self.batch + b) * IMG_PIXELS;
                self.images_buf[off..off + IMG_PIXELS].copy_from_slice(train.image(i));
                let loff = (m * self.batch + b) * NUM_CLASSES;
                self.labels_buf[loff + train.label(i)] = 1.0;
            }
        }
        let outputs = self
            .exe
            .run_f32(&[
                InputF32 {
                    data: params,
                    dims: &[self.dim as i64],
                },
                InputF32 {
                    data: &self.images_buf,
                    dims: &[self.devices as i64, self.batch as i64, IMG_PIXELS as i64],
                },
                InputF32 {
                    data: &self.labels_buf,
                    dims: &[self.devices as i64, self.batch as i64, NUM_CLASSES as i64],
                },
            ])
            .expect("PJRT gradient execution failed");
        let grads = &outputs[0];
        assert_eq!(grads.len(), self.devices * self.dim);
        Matf::from_vec(self.devices, self.dim, grads.clone())
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

// Runtime tests that need real artifacts live in rust/tests/runtime_pjrt.rs
// (they skip with a notice when artifacts/ is absent).
