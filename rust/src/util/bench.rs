//! Micro/meso benchmark harness (criterion is not in the offline vendor
//! set). Used by every target under `rust/benches/`: warm up, run timed
//! iterations, report mean / p50 / p95 and optional throughput.
//!
//! [`BenchSuite`] adds machine-readable output: collect [`BenchResult`]s
//! and write them as a JSON document (hand-rolled — no serde in the vendor
//! set). `benches/components.rs` uses it to emit `BENCH_components.json`
//! at the repo root; CI regenerates and uploads it every run and
//! `scripts/bench_compare.py` gates regressions against the committed
//! snapshot.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::stats::percentile;

/// One benchmark's collected timing result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    /// items/sec if `throughput_items` was set.
    pub throughput: Option<f64>,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        let tp = match self.throughput {
            Some(t) if t >= 1e9 => format!("  {:8.2} Gitem/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:8.2} Mitem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:8.2} Kitem/s", t / 1e3),
            Some(t) => format!("  {t:8.2} item/s"),
            None => String::new(),
        };
        format!(
            "{:<44} {:>11} mean  {:>11} p50  {:>11} p95  ({} iters){}",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p95),
            self.iters,
            tp
        )
    }
}

impl BenchResult {
    /// One JSON object: name, iteration count, timings in ns, throughput.
    pub fn json_object(&self) -> String {
        let tp = match self.throughput {
            Some(t) => format!("{t:.3}"),
            None => "null".to_string(),
        };
        format!(
            concat!(
                "{{\"name\":{},\"iters\":{},\"mean_ns\":{},\"p50_ns\":{},",
                "\"p95_ns\":{},\"min_ns\":{},\"throughput_items_per_sec\":{}}}"
            ),
            json_string(&self.name),
            self.iters,
            self.mean.as_nanos(),
            self.p50.as_nanos(),
            self.p95.as_nanos(),
            self.min.as_nanos(),
            tp
        )
    }
}

fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Collects [`BenchResult`]s and serializes them to a JSON document with
/// host provenance, for the tracked `BENCH_*.json` perf trajectory.
pub struct BenchSuite {
    suite: String,
    results: Vec<BenchResult>,
}

impl BenchSuite {
    pub fn new(suite: impl Into<String>) -> BenchSuite {
        BenchSuite {
            suite: suite.into(),
            results: Vec::new(),
        }
    }

    /// Record one result (results appear in the JSON in insertion order).
    pub fn record(&mut self, r: BenchResult) {
        self.results.push(r);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    pub fn to_json(&self) -> String {
        let unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let cpus = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"suite\": {},\n", json_string(&self.suite)));
        out.push_str(&format!("  \"unix_time\": {unix},\n"));
        out.push_str(&format!("  \"arch\": {},\n", json_string(std::env::consts::ARCH)));
        out.push_str(&format!("  \"os\": {},\n", json_string(std::env::consts::OS)));
        out.push_str(&format!("  \"cpus\": {cpus},\n"));
        out.push_str("  \"results\": [\n");
        for (i, r) in self.results.iter().enumerate() {
            let sep = if i + 1 == self.results.len() { "" } else { "," };
            out.push_str(&format!("    {}{sep}\n", r.json_object()));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the JSON document to `path` (creating parent dirs not
    /// required — bench output paths live in the repo).
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }

    /// Where a bench target should write its JSON: `$OTA_BENCH_JSON` if
    /// set, else `<repo root>/<default_name>` (found by walking up from
    /// the cwd to the directory holding ROADMAP.md — `cargo bench` runs
    /// with cwd = `rust/`), else the cwd.
    pub fn output_path(default_name: &str) -> PathBuf {
        if let Ok(p) = std::env::var("OTA_BENCH_JSON") {
            if !p.is_empty() {
                return PathBuf::from(p);
            }
        }
        let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            if dir.join("ROADMAP.md").is_file() {
                return dir.join(default_name);
            }
            if !dir.pop() {
                return PathBuf::from(default_name);
            }
        }
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Builder-style bench runner.
pub struct Bench {
    name: String,
    warmup: usize,
    min_iters: usize,
    max_iters: usize,
    target_time: Duration,
    throughput_items: Option<u64>,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Bench {
            name: name.into(),
            warmup: 2,
            min_iters: 5,
            max_iters: 200,
            target_time: Duration::from_millis(800),
            throughput_items: None,
        }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    pub fn iters(mut self, min: usize, max: usize) -> Self {
        self.min_iters = min.max(1);
        self.max_iters = max.max(self.min_iters);
        self
    }

    pub fn target_time(mut self, d: Duration) -> Self {
        self.target_time = d;
        self
    }

    /// Report throughput as items/sec (e.g. elements processed per call).
    pub fn throughput(mut self, items: u64) -> Self {
        self.throughput_items = Some(items);
        self
    }

    /// Run `f` repeatedly; `f` should perform one full operation and return a
    /// value (black-boxed to keep the optimizer honest).
    pub fn run<T, F: FnMut() -> T>(self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples: Vec<f64> = Vec::new();
        let start_all = Instant::now();
        let mut iters = 0usize;
        while iters < self.min_iters
            || (start_all.elapsed() < self.target_time && iters < self.max_iters)
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
            iters += 1;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean_s = samples.iter().sum::<f64>() / samples.len() as f64;
        let result = BenchResult {
            name: self.name,
            iters,
            mean: Duration::from_secs_f64(mean_s),
            p50: Duration::from_secs_f64(percentile(&samples, 50.0)),
            p95: Duration::from_secs_f64(percentile(&samples, 95.0)),
            min: Duration::from_secs_f64(samples[0]),
            throughput: self.throughput_items.map(|n| n as f64 / mean_s),
        };
        println!("{}", result.report_line());
        result
    }
}

/// Optimizer barrier (stable-rust black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Group header for bench output.
pub fn group(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = Bench::new("noop")
            .warmup(1)
            .iters(3, 10)
            .target_time(Duration::from_millis(5))
            .throughput(1000)
            .run(|| 1 + 1);
        assert!(r.iters >= 3);
        assert!(r.mean >= r.min);
        assert!(r.p95 >= r.p50);
        assert!(r.throughput.unwrap() > 0.0);
    }

    #[test]
    fn json_object_shape_and_escaping() {
        let r = BenchResult {
            name: "dot \"fast\" path\n".to_string(),
            iters: 7,
            mean: Duration::from_nanos(1500),
            p50: Duration::from_nanos(1400),
            p95: Duration::from_nanos(1900),
            min: Duration::from_nanos(1300),
            throughput: Some(1234.5678),
        };
        let j = r.json_object();
        assert!(j.contains("\\\"fast\\\""), "{j}");
        assert!(j.contains("\\n"), "{j}");
        assert!(j.contains("\"mean_ns\":1500"), "{j}");
        assert!(j.contains("\"throughput_items_per_sec\":1234.568"), "{j}");
        let none = BenchResult {
            throughput: None,
            ..r
        };
        assert!(none.json_object().contains("\"throughput_items_per_sec\":null"));
    }

    #[test]
    fn suite_collects_and_serializes() {
        let mut suite = BenchSuite::new("components");
        let r = Bench::new("noop")
            .warmup(0)
            .iters(2, 3)
            .target_time(Duration::from_millis(1))
            .run(|| 0u8);
        suite.record(r);
        assert_eq!(suite.results().len(), 1);
        let j = suite.to_json();
        assert!(j.contains("\"suite\": \"components\""), "{j}");
        assert!(j.contains("\"results\": ["), "{j}");
        assert!(j.contains("\"name\":\"noop\""), "{j}");
        assert!(j.trim_end().ends_with('}'), "{j}");
    }

    #[test]
    fn output_path_env_override_wins() {
        // Avoid mutating the process env (tests run in parallel): only the
        // fallback logic is exercised here — the env var path is a simple
        // early return.
        let p = BenchSuite::output_path("BENCH_x.json");
        assert!(p.to_string_lossy().ends_with("BENCH_x.json"));
    }

    #[test]
    fn fmt_dur_scales() {
        assert!(fmt_dur(Duration::from_nanos(10)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(10)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(10)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(10)).contains(" s"));
    }
}
