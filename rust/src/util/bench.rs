//! Micro/meso benchmark harness (criterion is not in the offline vendor
//! set). Used by every target under `rust/benches/`: warm up, run timed
//! iterations, report mean / p50 / p95 and optional throughput.

use std::time::{Duration, Instant};

use crate::util::stats::percentile;

/// One benchmark's collected timing result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    /// items/sec if `throughput_items` was set.
    pub throughput: Option<f64>,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        let tp = match self.throughput {
            Some(t) if t >= 1e9 => format!("  {:8.2} Gitem/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:8.2} Mitem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:8.2} Kitem/s", t / 1e3),
            Some(t) => format!("  {t:8.2} item/s"),
            None => String::new(),
        };
        format!(
            "{:<44} {:>11} mean  {:>11} p50  {:>11} p95  ({} iters){}",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p95),
            self.iters,
            tp
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Builder-style bench runner.
pub struct Bench {
    name: String,
    warmup: usize,
    min_iters: usize,
    max_iters: usize,
    target_time: Duration,
    throughput_items: Option<u64>,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Bench {
            name: name.into(),
            warmup: 2,
            min_iters: 5,
            max_iters: 200,
            target_time: Duration::from_millis(800),
            throughput_items: None,
        }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    pub fn iters(mut self, min: usize, max: usize) -> Self {
        self.min_iters = min.max(1);
        self.max_iters = max.max(self.min_iters);
        self
    }

    pub fn target_time(mut self, d: Duration) -> Self {
        self.target_time = d;
        self
    }

    /// Report throughput as items/sec (e.g. elements processed per call).
    pub fn throughput(mut self, items: u64) -> Self {
        self.throughput_items = Some(items);
        self
    }

    /// Run `f` repeatedly; `f` should perform one full operation and return a
    /// value (black-boxed to keep the optimizer honest).
    pub fn run<T, F: FnMut() -> T>(self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples: Vec<f64> = Vec::new();
        let start_all = Instant::now();
        let mut iters = 0usize;
        while iters < self.min_iters
            || (start_all.elapsed() < self.target_time && iters < self.max_iters)
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
            iters += 1;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean_s = samples.iter().sum::<f64>() / samples.len() as f64;
        let result = BenchResult {
            name: self.name,
            iters,
            mean: Duration::from_secs_f64(mean_s),
            p50: Duration::from_secs_f64(percentile(&samples, 50.0)),
            p95: Duration::from_secs_f64(percentile(&samples, 95.0)),
            min: Duration::from_secs_f64(samples[0]),
            throughput: self.throughput_items.map(|n| n as f64 / mean_s),
        };
        println!("{}", result.report_line());
        result
    }
}

/// Optimizer barrier (stable-rust black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Group header for bench output.
pub fn group(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = Bench::new("noop")
            .warmup(1)
            .iters(3, 10)
            .target_time(Duration::from_millis(5))
            .throughput(1000)
            .run(|| 1 + 1);
        assert!(r.iters >= 3);
        assert!(r.mean >= r.min);
        assert!(r.p95 >= r.p50);
        assert!(r.throughput.unwrap() > 0.0);
    }

    #[test]
    fn fmt_dur_scales() {
        assert!(fmt_dur(Duration::from_nanos(10)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(10)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(10)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(10)).contains(" s"));
    }
}
