//! Minimal property-based testing harness.
//!
//! The offline vendor set has no `proptest`, so this module provides the same
//! methodology in ~150 lines: generate random cases from the repo RNG, check
//! an invariant, and on failure shrink the case (via a user-supplied
//! shrinker) to a minimal reproduction, reporting the seed for replay.

use crate::util::rng::Pcg64;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig {
            cases: 64,
            seed: 0xBEEF_CAFE,
            max_shrink_steps: 256,
        }
    }
}

/// Outcome of checking a single case.
pub enum Check {
    Pass,
    Fail(String),
}

impl Check {
    pub fn from_bool(ok: bool, msg: &str) -> Check {
        if ok {
            Check::Pass
        } else {
            Check::Fail(msg.to_string())
        }
    }
}

/// Run a property: `gen` draws a case from the RNG, `prop` checks it,
/// `shrink` proposes smaller candidates (return empty to stop shrinking).
///
/// Panics with a replayable report on failure.
pub fn run_property<T, G, P, S>(name: &str, cfg: PropConfig, gen: G, prop: P, shrink: S)
where
    T: Clone + std::fmt::Debug,
    G: Fn(&mut Pcg64) -> T,
    P: Fn(&T) -> Check,
    S: Fn(&T) -> Vec<T>,
{
    let mut rng = Pcg64::new(cfg.seed);
    for case_idx in 0..cfg.cases {
        let mut case_rng = rng.split(case_idx as u64);
        let case = gen(&mut case_rng);
        if let Check::Fail(first_msg) = prop(&case) {
            // Shrink to a minimal failing case.
            let mut best = case.clone();
            let mut best_msg = first_msg;
            let mut steps = 0;
            'outer: loop {
                for cand in shrink(&best) {
                    steps += 1;
                    if steps > cfg.max_shrink_steps {
                        break 'outer;
                    }
                    if let Check::Fail(msg) = prop(&cand) {
                        best = cand;
                        best_msg = msg;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (seed={:#x}, case #{case_idx})\n  original: {case:?}\n  shrunk:   {best:?}\n  reason:   {best_msg}",
                cfg.seed
            );
        }
    }
}

/// Convenience wrapper with no shrinking.
pub fn run_property_noshrink<T, G, P>(name: &str, cfg: PropConfig, gen: G, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: Fn(&mut Pcg64) -> T,
    P: Fn(&T) -> Check,
{
    run_property(name, cfg, gen, prop, |_| Vec::new());
}

/// Standard shrinker for f32 vectors: halve the length, zero elements,
/// halve magnitudes.
pub fn shrink_vec_f32(v: &Vec<f32>) -> Vec<Vec<f32>> {
    let mut out = Vec::new();
    if v.len() > 1 {
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
    }
    if v.iter().any(|&x| x != 0.0) {
        out.push(v.iter().map(|&x| x / 2.0).collect());
        let mut zeroed = v.clone();
        for x in zeroed.iter_mut() {
            if x.abs() < 0.5 {
                *x = 0.0;
            }
        }
        if &zeroed != v {
            out.push(zeroed);
        }
    }
    out
}

/// Standard shrinker for usize parameters: move toward 1.
pub fn shrink_usize(n: &usize) -> Vec<usize> {
    let n = *n;
    let mut out = Vec::new();
    if n > 1 {
        out.push(n / 2);
        out.push(n - 1);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        run_property_noshrink(
            "sum-nonneg",
            PropConfig::default(),
            |rng| (0..10).map(|_| rng.f32()).collect::<Vec<f32>>(),
            |v| Check::from_bool(v.iter().sum::<f32>() >= 0.0, "negative sum"),
        );
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            run_property(
                "always-small",
                PropConfig {
                    cases: 32,
                    ..Default::default()
                },
                |rng| {
                    (0..8)
                        .map(|_| rng.range_f64(0.0, 10.0) as f32)
                        .collect::<Vec<f32>>()
                },
                |v| Check::from_bool(v.iter().all(|&x| x < 5.0), "element >= 5"),
                shrink_vec_f32,
            );
        });
        let err = result.expect_err("property should fail");
        let msg = err.downcast_ref::<String>().expect("panic message");
        assert!(msg.contains("shrunk"), "msg={msg}");
    }
}
