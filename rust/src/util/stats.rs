//! Statistics helpers: summaries used by metrics/benches, plus the special
//! functions needed by the paper's convergence analysis (Lemma 2 uses the
//! inverse lower incomplete gamma function to define `ρ(δ)`).

/// Online mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (0 when fewer than 2 samples).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Percentile of an ascending-sorted sample (linear interpolation).
///
/// `p` must lie in `[0, 100]` — the old code silently saturated `p < 0`
/// to the minimum (float→usize casts clamp) while `p > 100` walked the
/// interpolation rank past the slice and panicked on an out-of-bounds
/// *index*, two different behaviors for the same class of caller bug.
/// Both now fail the explicit range assert (NaN included: a NaN `p`
/// fails `contains`). Sortedness is the caller's contract; debug builds
/// verify it because an unsorted sample returns a plausible-looking but
/// meaningless number.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!(
        (0.0..=100.0).contains(&p),
        "percentile p must be in [0, 100], got {p}"
    );
    debug_assert!(
        sorted.windows(2).all(|w| w[0] <= w[1]),
        "percentile input must be sorted ascending"
    );
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Natural log of the gamma function (Lanczos approximation, g=7, n=9).
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized lower incomplete gamma P(a, x) = γ(a,x)/Γ(a).
///
/// Series expansion for x < a+1, continued fraction otherwise
/// (Numerical Recipes §6.2).
pub fn gamma_p(a: f64, x: f64) -> f64 {
    assert!(a > 0.0 && x >= 0.0);
    if x == 0.0 {
        return 0.0;
    }
    if x < a + 1.0 {
        // Series: P(a,x) = e^{-x} x^a / Γ(a) Σ x^n Γ(a)/Γ(a+1+n)
        let mut ap = a;
        let mut sum = 1.0 / a;
        let mut del = sum;
        for _ in 0..500 {
            ap += 1.0;
            del *= x / ap;
            sum += del;
            if del.abs() < sum.abs() * 1e-15 {
                break;
            }
        }
        sum * (-x + a * x.ln() - ln_gamma(a)).exp()
    } else {
        // Continued fraction for Q(a,x); P = 1 - Q.
        let mut b = x + 1.0 - a;
        let mut c = 1.0 / 1e-300;
        let mut d = 1.0 / b;
        let mut h = d;
        for i in 1..500 {
            let an = -(i as f64) * (i as f64 - a);
            b += 2.0;
            d = an * d + b;
            if d.abs() < 1e-300 {
                d = 1e-300;
            }
            c = b + an / c;
            if c.abs() < 1e-300 {
                c = 1e-300;
            }
            d = 1.0 / d;
            let del = d * c;
            h *= del;
            if (del - 1.0).abs() < 1e-15 {
                break;
            }
        }
        1.0 - h * (-x + a * x.ln() - ln_gamma(a)).exp()
    }
}

/// Inverse of the regularized lower incomplete gamma: x with P(a, x) = p.
/// Bisection + Newton refinement; accurate to ~1e-10 relative.
pub fn gamma_p_inv(a: f64, p: f64) -> f64 {
    assert!((0.0..1.0).contains(&p));
    if p == 0.0 {
        return 0.0;
    }
    // Bracket the root.
    let (mut lo, mut hi) = (0.0f64, a.max(1.0));
    while gamma_p(a, hi) < p {
        hi *= 2.0;
        if hi > 1e12 {
            break;
        }
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if gamma_p(a, mid) < p {
            lo = mid;
        } else {
            hi = mid;
        }
        if hi - lo < 1e-12 * hi.max(1.0) {
            break;
        }
    }
    0.5 * (lo + hi)
}

/// ρ(δ) from Lemma 2 of the paper: the radius such that a d-dimensional
/// standard normal vector has `Pr{‖u‖ ≥ ρ(δ)} = δ`. With `‖u‖²` chi-square
/// with d degrees of freedom, `ρ(δ) = sqrt(2 γ^{-1}(Γ(d/2)(1−δ), d/2))` —
/// equivalently `sqrt(2 · P^{-1}(d/2, 1−δ))` in regularized form.
pub fn rho_delta(d: usize, delta: f64) -> f64 {
    assert!(d > 0 && delta > 0.0 && delta < 1.0);
    (2.0 * gamma_p_inv(d as f64 / 2.0, 1.0 - delta)).sqrt()
}

/// log2 of the binomial coefficient C(n, k), via lgamma (exact enough for
/// bit-budget accounting with n up to 10^7).
pub fn log2_binom(n: usize, k: usize) -> f64 {
    assert!(k <= n, "C({n},{k}) undefined");
    if k == 0 || k == n {
        return 0.0;
    }
    let n = n as f64;
    let k = k as f64;
    (ln_gamma(n + 1.0) - ln_gamma(k + 1.0) - ln_gamma(n - k + 1.0)) / std::f64::consts::LN_2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn ln_gamma_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(5)=24, Γ(0.5)=√π
        assert!(ln_gamma(1.0).abs() < 1e-10);
        assert!(ln_gamma(2.0).abs() < 1e-10);
        assert!((ln_gamma(5.0) - 24.0f64.ln()).abs() < 1e-10);
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-10);
    }

    #[test]
    fn gamma_p_basic() {
        // P(1, x) = 1 - e^{-x}
        for &x in &[0.1, 0.5, 1.0, 3.0, 10.0] {
            assert!((gamma_p(1.0, x) - (1.0 - (-x as f64).exp())).abs() < 1e-12);
        }
        // Chi-square d=2 median: P(1, x)=0.5 at x=ln 2.
        assert!((gamma_p_inv(1.0, 0.5) - std::f64::consts::LN_2).abs() < 1e-9);
    }

    #[test]
    fn gamma_p_inv_roundtrip() {
        for &a in &[0.5, 1.0, 2.5, 10.0, 3925.0] {
            for &p in &[0.01, 0.3, 0.5, 0.9, 0.999] {
                let x = gamma_p_inv(a, p);
                assert!(
                    (gamma_p(a, x) - p).abs() < 1e-8,
                    "a={a} p={p} x={x} P={}",
                    gamma_p(a, x)
                );
            }
        }
    }

    #[test]
    fn rho_delta_monotone_and_sane() {
        // For d=1, Pr{|u| >= rho} = delta → rho(0.3173) ≈ 1.0
        let r = rho_delta(1, 0.317_310_5);
        assert!((r - 1.0).abs() < 1e-3, "r={r}");
        // Larger d → larger radius; smaller delta → larger radius.
        assert!(rho_delta(100, 0.05) > rho_delta(10, 0.05));
        assert!(rho_delta(10, 0.01) > rho_delta(10, 0.5));
        // d-dim normal norm concentrates near sqrt(d).
        let d = 7850;
        let r = rho_delta(d, 0.5);
        assert!((r - (d as f64).sqrt()).abs() < 2.0, "r={r}");
    }

    #[test]
    fn log2_binom_exact_small() {
        assert!((log2_binom(10, 3) - (120f64).log2()).abs() < 1e-9);
        assert!((log2_binom(52, 5) - (2_598_960f64).log2()).abs() < 1e-9);
        assert_eq!(log2_binom(7, 0), 0.0);
        assert_eq!(log2_binom(7, 7), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    /// Random sorted vectors against a sort-based oracle: the result must
    /// land inside the bracketing order statistics at every probed `p`,
    /// hit the extremes exactly at 0/100, and hit the middle element
    /// exactly at p=50 on odd lengths.
    #[test]
    fn percentile_property_vs_sorted_oracle() {
        use crate::util::proptest::{run_property_noshrink, Check, PropConfig};
        run_property_noshrink(
            "percentile-sorted-oracle",
            PropConfig::default(),
            |rng| {
                let n = 1 + (rng.next_u64() % 40) as usize;
                let mut v: Vec<f64> = (0..n).map(|_| rng.range_f64(-1e3, 1e3)).collect();
                v.sort_by(f64::total_cmp);
                v
            },
            |v| {
                for &p in &[0.0, 50.0, 95.0, 100.0] {
                    let got = percentile(v, p);
                    let rank = p / 100.0 * (v.len() - 1) as f64;
                    let (lo, hi) = (v[rank.floor() as usize], v[rank.ceil() as usize]);
                    let tol = 1e-9 * lo.abs().max(hi.abs()).max(1.0);
                    if !(lo - tol <= got && got <= hi + tol) {
                        return Check::Fail(format!("p={p}: {got} outside [{lo}, {hi}]"));
                    }
                }
                if percentile(v, 0.0) != v[0] || percentile(v, 100.0) != *v.last().unwrap() {
                    return Check::Fail("extremes must be exact".into());
                }
                if v.len() % 2 == 1 && percentile(v, 50.0) != v[v.len() / 2] {
                    return Check::Fail("odd-length median must be the middle element".into());
                }
                Check::Pass
            },
        );
    }

    /// The regression this PR fixes: `p > 100` used to panic on an
    /// out-of-bounds *index* deep in the interpolation while `p < 0`
    /// silently saturated to the minimum — both now fail the contract
    /// assert up front.
    #[test]
    #[should_panic(expected = "percentile p must be in [0, 100]")]
    fn percentile_rejects_p_over_100() {
        percentile(&[1.0, 2.0], 150.0);
    }

    #[test]
    #[should_panic(expected = "percentile p must be in [0, 100]")]
    fn percentile_rejects_negative_p() {
        percentile(&[1.0, 2.0], -5.0);
    }

    #[test]
    #[should_panic(expected = "percentile p must be in [0, 100]")]
    fn percentile_rejects_nan_p() {
        percentile(&[1.0, 2.0], f64::NAN);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "sorted ascending")]
    fn percentile_rejects_unsorted_input_in_debug() {
        percentile(&[3.0, 1.0, 2.0], 50.0);
    }
}
