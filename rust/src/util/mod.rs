//! Substrate utilities: RNG, statistics, CSV, CLI parsing, thread pool,
//! property-testing and benchmarking harnesses, logging.
//!
//! All of these are hand-rolled because the build environment is fully
//! offline — see DESIGN.md §3 (Substitutions).

pub mod bench;
pub mod cli;
pub mod csv;
pub mod logging;
pub mod prof;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
