//! Tiny leveled stderr logger. Level is set once (CLI `--log-level` or
//! `OTA_LOG` env); macros elsewhere call through `log_at`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str_loose(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" | "warning" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn init(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
    START.get_or_init(Instant::now);
}

pub fn init_from_env() {
    let level = std::env::var("OTA_LOG")
        .map(|s| Level::from_str_loose(&s))
        .unwrap_or(Level::Info);
    init(level);
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log_at(level: Level, module: &str, msg: std::fmt::Arguments) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    eprintln!(
        "[{:>9.3}s {} {}] {}",
        t.as_secs_f64(),
        level.tag(),
        module,
        msg
    );
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log_at(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log_at(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log_at(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str_loose("ERROR"), Level::Error);
        assert_eq!(Level::from_str_loose("warn"), Level::Warn);
        assert_eq!(Level::from_str_loose("bogus"), Level::Info);
    }

    #[test]
    fn level_gating() {
        init(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Debug));
        init(Level::Info); // restore default for other tests
    }
}
