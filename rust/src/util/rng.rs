//! Deterministic pseudo-random number generation.
//!
//! The offline vendor set has no `rand` crate, so the repository carries its
//! own generator: PCG-XSH-RR 64/32 (O'Neill 2014) with splitmix64 seeding.
//! Everything downstream of a seed is bit-reproducible across runs, which the
//! paper's analog scheme *requires*: the projection matrix `A_s̃` must be the
//! same pseudo-random matrix at every device and at the PS (Section IV), so
//! devices and server construct it from a shared seed through this RNG.

/// splitmix64 — used to expand a single `u64` seed into PCG state/stream.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Derive an order-independent "counter-based" generator for one
/// `(seed, salt, a, b)` cell: the returned RNG depends only on those four
/// values, never on how many other cells were drawn before it or in which
/// order. The fading/participation/latency scenario generators build every
/// per-(device, round) draw through this, which is what makes them
/// invariant to thread-pool size and query order.
pub fn counter_rng(seed: u64, salt: u64, a: u64, b: u64) -> Pcg64 {
    let mut sm = seed
        ^ salt
        ^ a.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ b.wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
    let s0 = splitmix64(&mut sm);
    let s1 = splitmix64(&mut sm);
    Pcg64::with_stream(s0, s1)
}

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output, period 2^64 per stream.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
    /// Cached second Box–Muller normal.
    spare_normal: Option<f64>,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Pcg64 {
    /// Construct from a seed; the stream id defaults to the golden-ratio odd
    /// constant so two generators with different seeds are decorrelated.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xDA3E_39CB_94B9_5BDB)
    }

    /// Construct with an explicit stream id (`inc` is forced odd).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm);
        let mut rng = Pcg64 {
            state: 0,
            inc: (stream << 1) | 1,
            spare_normal: None,
        };
        rng.state = rng.inc.wrapping_add(s0);
        rng.next_u32();
        rng
    }

    /// Raw generator position for checkpointing: `(state, inc, cached
    /// Box–Muller spare)`. Together with [`Pcg64::from_raw_state`] this
    /// round-trips the generator bit-exactly — including the half-consumed
    /// normal pair — so a restored stream continues the original sequence.
    pub fn raw_state(&self) -> (u64, u64, Option<f64>) {
        (self.state, self.inc, self.spare_normal)
    }

    /// Rebuild a generator at an exact position captured by
    /// [`Pcg64::raw_state`]. Unlike [`Pcg64::with_stream`] this performs no
    /// seeding mix — the fields are restored verbatim.
    pub fn from_raw_state(state: u64, inc: u64, spare_normal: Option<f64>) -> Pcg64 {
        Pcg64 {
            state,
            inc,
            spare_normal,
        }
    }

    /// Derive an independent child generator (per-device / per-round streams).
    ///
    /// The child stream id mixes the label through splitmix64 so `split(0)`
    /// and `split(1)` are decorrelated even though the labels are adjacent.
    pub fn split(&mut self, label: u64) -> Pcg64 {
        let mut sm = self.next_u64() ^ label.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let seed = splitmix64(&mut sm);
        let stream = splitmix64(&mut sm);
        Pcg64::with_stream(seed, stream)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (bound as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return hi;
            }
        }
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (caches the second deviate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (std::f64::consts::TAU * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Normal with the given mean / standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Fill a slice with i.i.d. N(0, sd²) f32 values.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], sd: f32) {
        for v in out.iter_mut() {
            *v = (self.normal() as f32) * sd;
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `n` distinct indices from [0, pool) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, pool: usize, n: usize) -> Vec<usize> {
        assert!(n <= pool, "cannot sample {n} from pool of {pool}");
        let mut idx: Vec<usize> = (0..pool).collect();
        for i in 0..n {
            let j = i + self.below((pool - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(n);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Pcg64::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_unbiased_small_bound() {
        let mut r = Pcg64::new(3);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.below(3) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts={counts:?}");
        }
    }

    #[test]
    fn counter_rng_pure_in_its_cell() {
        let a = counter_rng(7, 0xABC, 3, 9).next_u64();
        let b = counter_rng(7, 0xABC, 3, 9).next_u64();
        assert_eq!(a, b);
        assert_ne!(a, counter_rng(7, 0xABC, 3, 10).next_u64());
        assert_ne!(a, counter_rng(7, 0xABC, 4, 9).next_u64());
        assert_ne!(a, counter_rng(8, 0xABC, 3, 9).next_u64());
        assert_ne!(a, counter_rng(7, 0xABD, 3, 9).next_u64());
    }

    #[test]
    fn raw_state_roundtrip_continues_the_stream() {
        let mut a = Pcg64::new(77);
        // Advance, leaving a cached spare normal behind.
        let _ = a.normal();
        let (s, inc, spare) = a.raw_state();
        assert!(spare.is_some(), "Box–Muller caches the second deviate");
        let mut b = Pcg64::from_raw_state(s, inc, spare);
        for _ in 0..50 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg64::new(5);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Pcg64::new(9);
        let idx = r.sample_indices(100, 20);
        assert_eq!(idx.len(), 20);
        let mut seen = std::collections::HashSet::new();
        for &i in &idx {
            assert!(i < 100);
            assert!(seen.insert(i));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
