//! Minimal CSV writer for experiment metric series.
//!
//! Every experiment driver writes its series under `results/<name>.csv` so
//! figures can be re-plotted outside the binary. No external serde crates in
//! the offline vendor set, so this is a small hand-rolled writer with proper
//! quoting.

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

/// Streaming CSV writer with header enforcement.
pub struct CsvWriter {
    out: BufWriter<File>,
    columns: usize,
    path: PathBuf,
}

impl CsvWriter {
    /// Create (truncating) `path`, writing the header row immediately.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            if !dir.as_os_str().is_empty() {
                fs::create_dir_all(dir)?;
            }
        }
        let mut w = CsvWriter {
            out: BufWriter::new(File::create(&path)?),
            columns: header.len(),
            path: path.as_ref().to_path_buf(),
        };
        w.write_row_str(header)?;
        Ok(w)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    fn escape(field: &str) -> String {
        if field.contains([',', '"', '\n']) {
            format!("\"{}\"", field.replace('"', "\"\""))
        } else {
            field.to_string()
        }
    }

    pub fn write_row_str(&mut self, fields: &[&str]) -> std::io::Result<()> {
        assert_eq!(
            fields.len(),
            self.columns,
            "row width {} != header width {}",
            fields.len(),
            self.columns
        );
        let line: Vec<String> = fields.iter().map(|f| Self::escape(f)).collect();
        writeln!(self.out, "{}", line.join(","))
    }

    /// Write a row of f64s (common case for metric series).
    pub fn write_row(&mut self, fields: &[f64]) -> std::io::Result<()> {
        let strs: Vec<String> = fields.iter().map(|v| format!("{v}")).collect();
        let refs: Vec<&str> = strs.iter().map(|s| s.as_str()).collect();
        self.write_row_str(&refs)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

/// Parse a simple CSV file back (no embedded newlines), used by tests and
/// report tooling.
pub fn read_csv<P: AsRef<Path>>(path: P) -> std::io::Result<Vec<Vec<String>>> {
    let text = fs::read_to_string(path)?;
    Ok(text.lines().map(parse_line).collect())
}

fn parse_line(line: &str) -> Vec<String> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            }
            '"' => in_quotes = true,
            ',' if !in_quotes => {
                fields.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    fields.push(cur);
    fields
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_with_quoting() {
        let dir = std::env::temp_dir().join("ota_dsgd_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b,c", "d\"e"]).unwrap();
            w.write_row_str(&["1", "x,y", "he said \"hi\""]).unwrap();
            w.write_row(&[1.5, -2.0, 3.25]).unwrap();
            w.flush().unwrap();
        }
        let rows = read_csv(&path).unwrap();
        assert_eq!(rows[0], vec!["a", "b,c", "d\"e"]);
        assert_eq!(rows[1], vec!["1", "x,y", "he said \"hi\""]);
        assert_eq!(rows[2], vec!["1.5", "-2", "3.25"]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let dir = std::env::temp_dir().join("ota_dsgd_csv_test2");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        let _ = w.write_row_str(&["only-one"]);
    }
}
