//! Hierarchical span profiler for the training pipeline, zero deps.
//!
//! Spans attribute wall-clock to the pipeline phases PERF.md names
//! (`encode → project → transmit → decode_amp → gradient → consensus`,
//! plus `eval`). The profiler is a process-global, gated by one relaxed
//! atomic load: while disabled (the default) a [`span`] call does no
//! clock read and no allocation, so instrumented hot paths cost one
//! branch. Enabling (`repro train --profile-out trace.json`) records
//! `(name, thread, start, duration)` tuples that export as Chrome
//! trace-event JSON (load in `chrome://tracing` / Perfetto) plus a
//! per-phase summary table.
//!
//! Everything here is wall-clock and therefore lives strictly *outside*
//! the deterministic core: spans never touch training state, RNG streams,
//! event logs, or content-addresses. Nested spans are naturally
//! hierarchical in the trace viewer because a child's `[start, start+dur)`
//! sits inside its parent's on the same thread ("X" complete events).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// One closed span. `tid` is a small per-thread ordinal (first profiled
/// thread = 0), not the OS thread id — stable across runs of the same
/// schedule and friendlier in trace viewers.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    pub name: &'static str,
    pub tid: u64,
    /// Microseconds since the profiler's epoch (first use in the process).
    pub start_us: u64,
    pub dur_us: u64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

/// The profiler's time base: a monotonic `Instant` paired with the
/// unix-microsecond wall clock captured at the same moment, so span
/// offsets can be rebased to absolute time (the fleet trace merges
/// spans from many processes and needs one shared axis).
fn epoch() -> &'static (Instant, u64) {
    static EPOCH: OnceLock<(Instant, u64)> = OnceLock::new();
    EPOCH.get_or_init(|| {
        let unix_us = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0);
        (Instant::now(), unix_us)
    })
}

/// Unix microseconds corresponding to span offset 0 ([`SpanRecord::start_us`]).
pub fn epoch_unix_us() -> u64 {
    epoch().1
}

/// This thread's profiler ordinal (first profiled thread = 0). Shared
/// with `fleet::trace` so directly-emitted worker spans land on the
/// same lane numbering as drained phase spans.
pub fn current_tid() -> u64 {
    TID.with(|t| *t)
}

fn records() -> &'static Mutex<Vec<SpanRecord>> {
    static RECORDS: OnceLock<Mutex<Vec<SpanRecord>>> = OnceLock::new();
    RECORDS.get_or_init(|| Mutex::new(Vec::new()))
}

/// Turn span recording on (also pins the epoch so the first span doesn't
/// pay the `OnceLock` init inside a timed region).
pub fn enable() {
    let _ = epoch();
    ENABLED.store(true, Ordering::Relaxed);
}

pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

pub fn is_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Drain every recorded span (oldest first per thread interleaving).
pub fn take() -> Vec<SpanRecord> {
    std::mem::take(&mut *records().lock().unwrap())
}

/// RAII span guard: records on drop. Obtain via [`span`].
pub struct SpanGuard {
    name: &'static str,
    start: Option<Instant>,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        // Re-check: if profiling was disabled mid-span, drop the record
        // rather than locking a drained buffer.
        if !is_enabled() {
            return;
        }
        let start_us = start.duration_since(epoch().0).as_micros() as u64;
        let dur_us = start.elapsed().as_micros() as u64;
        let tid = TID.with(|t| *t);
        records().lock().unwrap().push(SpanRecord {
            name: self.name,
            tid,
            start_us,
            dur_us,
        });
    }
}

/// Open a span; it closes (and records, if profiling is enabled) when the
/// returned guard drops. `name` should be one of the pipeline phases so
/// the summary maps onto the PERF.md kernel table.
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    let start = is_enabled().then(Instant::now);
    SpanGuard { name, start }
}

/// JSON string escaping for trace export. Span names are normally
/// static identifiers, but the exporter must stay valid JSON for any
/// name (quotes, backslashes, control characters).
pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Chrome trace-event JSON (the `traceEvents` array format): one complete
/// ("ph":"X") event per span, timestamps/durations in microseconds,
/// preceded by "M" metadata events naming the process and each thread
/// lane so viewers label rows instead of showing bare ordinals.
pub fn chrome_trace_json(spans: &[SpanRecord]) -> String {
    let mut events: Vec<String> = Vec::with_capacity(spans.len() + 4);
    events.push(
        "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\"args\":{\"name\":\"repro\"}}"
            .to_string(),
    );
    let mut tids: Vec<u64> = spans.iter().map(|s| s.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    for tid in tids {
        events.push(format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\"args\":{{\"name\":\"lane-{tid}\"}}}}"
        ));
    }
    for s in spans {
        events.push(format!(
            "{{\"name\":\"{}\",\"cat\":\"pipeline\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":1,\"tid\":{}}}",
            json_escape(s.name),
            s.start_us,
            s.dur_us,
            s.tid
        ));
    }
    format!(
        "{{\"traceEvents\":[{}],\"displayTimeUnit\":\"ms\"}}",
        events.join(",")
    )
}

/// Aggregated per-phase timing.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseSummary {
    pub name: &'static str,
    pub count: usize,
    pub total_us: u64,
    pub max_us: u64,
}

impl PhaseSummary {
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_us as f64 / self.count as f64
        }
    }
}

/// Fold spans into one row per phase, sorted by total time descending
/// (ties by name so the table is stable).
pub fn summarize(spans: &[SpanRecord]) -> Vec<PhaseSummary> {
    let mut rows: Vec<PhaseSummary> = Vec::new();
    for s in spans {
        match rows.iter_mut().find(|r| r.name == s.name) {
            Some(r) => {
                r.count += 1;
                r.total_us += s.dur_us;
                r.max_us = r.max_us.max(s.dur_us);
            }
            None => rows.push(PhaseSummary {
                name: s.name,
                count: 1,
                total_us: s.dur_us,
                max_us: s.dur_us,
            }),
        }
    }
    rows.sort_by(|a, b| b.total_us.cmp(&a.total_us).then(a.name.cmp(b.name)));
    rows
}

/// Render the summary as the fixed-width table `repro train` prints after
/// a profiled run.
pub fn render_summary(rows: &[PhaseSummary]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<12} {:>8} {:>12} {:>12} {:>12}\n",
        "phase", "spans", "total ms", "mean µs", "max µs"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<12} {:>8} {:>12.3} {:>12.1} {:>12}\n",
            r.name,
            r.count,
            r.total_us as f64 / 1000.0,
            r.mean_us(),
            r.max_us
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One test drives the whole lifecycle: the profiler is process-global
    /// state, so independent #[test]s toggling it would race under the
    /// parallel test harness.
    #[test]
    fn lifecycle_export_and_summary() {
        // Disabled spans record nothing and cost no clock read.
        disable();
        let _ = take();
        {
            let _sp = span("encode");
        }
        assert!(take().is_empty());

        enable();
        {
            let _outer = span("gradient");
            let _inner = span("project");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        {
            let _sp = span("project");
        }
        let t = std::thread::spawn(|| {
            let _sp = span("encode");
        });
        t.join().unwrap();
        disable();
        let spans = take();
        assert_eq!(spans.len(), 4, "{spans:?}");
        let names: Vec<&str> = spans.iter().map(|s| s.name).collect();
        assert!(names.contains(&"gradient") && names.contains(&"encode"));
        assert_eq!(names.iter().filter(|&&n| n == "project").count(), 2);
        // The spawned thread got its own tid.
        let main_tid = spans.iter().find(|s| s.name == "gradient").unwrap().tid;
        let enc_tid = spans.iter().find(|s| s.name == "encode").unwrap().tid;
        assert_ne!(main_tid, enc_tid);

        // Chrome trace export is structurally valid and contains each span.
        let json = chrome_trace_json(&spans);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.ends_with("],\"displayTimeUnit\":\"ms\"}"));
        assert_eq!(json.matches("\"ph\":\"X\"").count(), 4);
        assert!(json.contains("\"name\":\"gradient\""));

        // Summary folds, sorts by total desc, and renders.
        let rows = summarize(&spans);
        assert_eq!(rows.iter().map(|r| r.count).sum::<usize>(), 4);
        let proj = rows.iter().find(|r| r.name == "project").unwrap();
        assert_eq!(proj.count, 2);
        assert!(rows.windows(2).all(|w| w[0].total_us >= w[1].total_us));
        let table = render_summary(&rows);
        assert!(table.contains("phase") && table.contains("project"));

        // The nested span sat inside its parent on the same thread.
        let grad = spans.iter().find(|s| s.name == "gradient").unwrap();
        let inner = spans
            .iter()
            .filter(|s| s.name == "project" && s.tid == grad.tid)
            .max_by_key(|s| s.dur_us)
            .unwrap();
        assert!(inner.start_us >= grad.start_us);
        assert!(inner.start_us + inner.dur_us <= grad.start_us + grad.dur_us);
    }

    // The exporter is a pure function of its input, so these tests touch
    // no process-global profiler state and can run in parallel with the
    // lifecycle test above.

    #[test]
    fn chrome_export_escapes_hostile_names_and_parses() {
        let spans = vec![
            SpanRecord { name: "evil\"name\\with\ncontrol\u{1}", tid: 3, start_us: 10, dur_us: 5 },
            SpanRecord { name: "encode", tid: 0, start_us: 0, dur_us: 7 },
        ];
        let json = chrome_trace_json(&spans);
        // The hardening contract: the export must parse with the crate's
        // own strict JSON parser, hostile names and all.
        let doc = crate::fleet::client::Json::parse(&json).expect("valid JSON");
        let events = doc.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        let names: Vec<&str> = events
            .iter()
            .filter_map(|e| e.get("name").and_then(|n| n.as_str()))
            .collect();
        assert!(names.contains(&"evil\"name\\with\ncontrol\u{1}"), "{names:?}");
        assert!(names.contains(&"encode"));
    }

    #[test]
    fn chrome_export_emits_pid_tid_metadata_lanes() {
        let spans = vec![
            SpanRecord { name: "a", tid: 0, start_us: 0, dur_us: 1 },
            SpanRecord { name: "b", tid: 2, start_us: 1, dur_us: 1 },
            SpanRecord { name: "c", tid: 0, start_us: 2, dur_us: 1 },
        ];
        let json = chrome_trace_json(&spans);
        let doc = crate::fleet::client::Json::parse(&json).unwrap();
        let events = doc.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        let metas: Vec<&crate::fleet::client::Json> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("M"))
            .collect();
        // One process_name plus one thread_name per distinct tid.
        assert_eq!(metas.len(), 3, "{json}");
        assert_eq!(
            metas[0].get("name").and_then(|n| n.as_str()),
            Some("process_name")
        );
        let lanes: Vec<f64> = metas
            .iter()
            .filter(|m| m.get("name").and_then(|n| n.as_str()) == Some("thread_name"))
            .filter_map(|m| m.get("tid").and_then(|t| t.as_f64()))
            .collect();
        assert_eq!(lanes, vec![0.0, 2.0]);
        // Complete events still carry every span.
        let xs = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .count();
        assert_eq!(xs, 3);
    }
}
