//! Scoped parallel map over device workloads.
//!
//! No tokio/rayon offline; the coordinator fans device work out with
//! `std::thread::scope`. On the 1-core CI box this degrades gracefully to
//! near-sequential execution, but the structure mirrors a real deployment
//! (one worker per edge device) and scales with available cores.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

thread_local! {
    /// Set on pool worker threads for their whole lifetime. Nested
    /// `par_map`/`par_chunks_mut` calls issued from inside a worker run
    /// sequentially instead of spawning a second generation of threads —
    /// e.g. the experiments runner par_maps over runs while each run's
    /// `Projection::generate` would otherwise par_chunks_mut inside it.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// True when the current thread is a pool worker (see `IN_POOL`).
pub fn in_pool_worker() -> bool {
    IN_POOL.with(|c| c.get())
}

/// Number of worker threads to use for `n_items` independent items.
pub fn default_workers(n_items: usize) -> usize {
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    hw.min(n_items).max(1)
}

/// Parallel map with work stealing via an atomic cursor. Preserves order of
/// results. `f` must be `Sync`; items are taken by index.
pub fn par_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(workers >= 1);
    if n == 0 {
        return Vec::new();
    }
    if workers == 1 || n == 1 || in_pool_worker() {
        return (0..n).map(&f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers.min(n) {
            scope.spawn(|| {
                IN_POOL.with(|c| c.set(true));
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let out = f(i);
                    *results[i].lock().unwrap() = Some(out);
                }
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker missed an item"))
        .collect()
}

/// Parallel for-each over mutable chunks of a slice (used to fill large
/// buffers like the projection matrix in parallel, deterministically:
/// the caller derives an independent RNG per chunk index).
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0);
    if workers <= 1 || data.len() <= chunk || in_pool_worker() {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk).enumerate().collect();
    let pending = Mutex::new(chunks);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                IN_POOL.with(|c| c.set(true));
                loop {
                    let item = pending.lock().unwrap().pop();
                    match item {
                        Some((i, c)) => f(i, c),
                        None => break,
                    }
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let out = par_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_single_worker() {
        let out = par_map(10, 1, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<usize> = par_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn par_chunks_fill_all() {
        let mut data = vec![0u32; 1000];
        par_chunks_mut(&mut data, 64, 4, |ci, chunk| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (ci * 64 + j) as u32;
            }
        });
        assert_eq!(data, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn nested_parallelism_collapses_to_sequential() {
        assert!(!in_pool_worker());
        // A nested par_map inside a pool worker must run inline on that
        // worker (no second generation of threads) and still be correct.
        let out = par_map(8, 4, |i| {
            let inner = par_map(5, 4, move |j| i * 10 + j);
            inner.iter().sum::<usize>()
        });
        let want: Vec<usize> = (0..8).map(|i| (0..5).map(|j| i * 10 + j).sum()).collect();
        assert_eq!(out, want);
        // Nested par_chunks_mut likewise stays sequential and correct.
        let sums = par_map(4, 4, |i| {
            let mut buf = vec![0u32; 100];
            par_chunks_mut(&mut buf, 16, 4, |ci, chunk| {
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = (i + ci * 16 + j) as u32;
                }
            });
            buf.iter().sum::<u32>()
        });
        assert_eq!(sums.len(), 4);
        assert!(!in_pool_worker());
    }

    #[test]
    fn default_workers_bounded() {
        assert_eq!(default_workers(0), 1);
        assert!(default_workers(100) >= 1);
        assert!(default_workers(2) <= 2);
    }
}
