//! Scoped parallel map over device workloads.
//!
//! No tokio/rayon offline; the coordinator fans device work out with
//! `std::thread::scope`. On the 1-core CI box this degrades gracefully to
//! near-sequential execution, but the structure mirrors a real deployment
//! (one worker per edge device) and scales with available cores.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use for `n_items` independent items.
pub fn default_workers(n_items: usize) -> usize {
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    hw.min(n_items).max(1)
}

/// Parallel map with work stealing via an atomic cursor. Preserves order of
/// results. `f` must be `Sync`; items are taken by index.
pub fn par_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(workers >= 1);
    if n == 0 {
        return Vec::new();
    }
    if workers == 1 || n == 1 {
        return (0..n).map(&f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers.min(n) {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker missed an item"))
        .collect()
}

/// Parallel for-each over mutable chunks of a slice (used to fill large
/// buffers like the projection matrix in parallel, deterministically:
/// the caller derives an independent RNG per chunk index).
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0);
    if workers <= 1 || data.len() <= chunk {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk).enumerate().collect();
    let pending = Mutex::new(chunks);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let item = pending.lock().unwrap().pop();
                match item {
                    Some((i, c)) => f(i, c),
                    None => break,
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let out = par_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_single_worker() {
        let out = par_map(10, 1, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<usize> = par_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn par_chunks_fill_all() {
        let mut data = vec![0u32; 1000];
        par_chunks_mut(&mut data, 64, 4, |ci, chunk| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (ci * 64 + j) as u32;
            }
        });
        assert_eq!(data, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn default_workers_bounded() {
        assert_eq!(default_workers(0), 1);
        assert!(default_workers(100) >= 1);
        assert!(default_workers(2) <= 2);
    }
}
