//! Hand-rolled command-line parsing (no `clap` in the offline vendor set).
//!
//! Supports `program <subcommand> [--flag] [--key value] [--key=value]
//! [positional...]` with typed accessors and an auto-generated usage string.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Parsed arguments for one (sub)command invocation.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (excluding program name). The first non-`--` token is
    /// the subcommand; later bare tokens are positionals.
    pub fn parse<I, S>(argv: I) -> Args
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut args = Args::default();
        let mut it = argv.into_iter().map(Into::into).peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    // `--key value`: consume the value ONLY if the key is
                    // conventionally valued; we treat every non-flag-looking
                    // next token as a value.
                    let v = it.next().unwrap();
                    args.options.insert(body.to_string(), v);
                } else {
                    args.flags.push(body.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        // A bare `--name` OR `--name true`.
        self.flags.iter().any(|f| f == name)
            || self
                .options
                .get(name)
                .map(|v| v == "true" || v == "1")
                .unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.typed(name, default)
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.typed(name, default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.typed(name, default)
    }

    fn typed<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            None => default,
            Some(raw) => raw.parse().unwrap_or_else(|_| {
                panic!("--{name}: cannot parse {raw:?}");
            }),
        }
    }

    /// All `--key value` options, for echoing configuration into logs.
    pub fn options(&self) -> impl Iterator<Item = (&str, &str)> {
        self.options.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }
}

/// Declarative usage/help rendering.
pub struct Usage {
    pub program: &'static str,
    pub about: &'static str,
    pub subcommands: &'static [(&'static str, &'static str)],
    pub options: &'static [(&'static str, &'static str)],
}

impl Usage {
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.program, self.about);
        let _ = writeln!(s, "\nUSAGE:\n  {} <subcommand> [options]", self.program);
        if !self.subcommands.is_empty() {
            let _ = writeln!(s, "\nSUBCOMMANDS:");
            for (name, desc) in self.subcommands {
                let _ = writeln!(s, "  {name:<18} {desc}");
            }
        }
        if !self.options.is_empty() {
            let _ = writeln!(s, "\nOPTIONS:");
            for (name, desc) in self.options {
                let _ = writeln!(s, "  {name:<24} {desc}");
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_subcommand_options_flags() {
        // Positionals go before flags (a bare token right after `--flag`
        // is consumed as that flag's value — documented CLI behavior).
        let a = Args::parse(["fig", "2", "--iters=50", "--full"]);
        assert_eq!(a.subcommand.as_deref(), Some("fig"));
        assert_eq!(a.usize("iters", 0), 50);
        assert!(a.flag("full"));
        assert_eq!(a.positional, vec!["2"]);
    }

    #[test]
    fn space_separated_value() {
        let a = Args::parse(["train", "--scheme", "adsgd", "--pbar", "500"]);
        assert_eq!(a.get("scheme"), Some("adsgd"));
        assert_eq!(a.f64("pbar", 0.0), 500.0);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(["x"]);
        assert_eq!(a.usize("missing", 7), 7);
        assert!(!a.flag("nope"));
        assert_eq!(a.get_or("key", "dflt"), "dflt");
    }

    #[test]
    #[should_panic(expected = "cannot parse")]
    fn bad_typed_value_panics() {
        let a = Args::parse(["x", "--n", "abc"]);
        let _ = a.usize("n", 0);
    }

    #[test]
    fn usage_renders() {
        let u = Usage {
            program: "repro",
            about: "over-the-air DSGD",
            subcommands: &[("train", "run one training job")],
            options: &[("--seed <u64>", "rng seed")],
        };
        let text = u.render();
        assert!(text.contains("repro"));
        assert!(text.contains("train"));
        assert!(text.contains("--seed"));
    }
}
