//! IDX-format MNIST loader (LeCun file layout).
//!
//! If the user drops the four canonical files (optionally without the
//! `.idx3-ubyte` suffixes) into a directory, `load_dir` builds the real
//! corpus; every experiment then runs on genuine MNIST with no other change.

use std::fs;
use std::path::{Path, PathBuf};

use super::{Corpus, Dataset, IMG_PIXELS};
use crate::tensor::Matf;

const IMAGES_MAGIC: u32 = 2051;
const LABELS_MAGIC: u32 = 2049;

fn be_u32(b: &[u8], off: usize) -> u32 {
    u32::from_be_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

/// Parse an IDX image file (magic 2051) into an n×784 matrix in [0,1].
pub fn parse_images(bytes: &[u8]) -> anyhow::Result<Matf> {
    anyhow::ensure!(bytes.len() >= 16, "image file too short");
    let magic = be_u32(bytes, 0);
    anyhow::ensure!(magic == IMAGES_MAGIC, "bad image magic {magic}");
    let n = be_u32(bytes, 4) as usize;
    let rows = be_u32(bytes, 8) as usize;
    let cols = be_u32(bytes, 12) as usize;
    anyhow::ensure!(
        rows * cols == IMG_PIXELS,
        "expected 28x28 images, got {rows}x{cols}"
    );
    anyhow::ensure!(
        bytes.len() == 16 + n * IMG_PIXELS,
        "image payload size mismatch"
    );
    let mut m = Matf::zeros(n, IMG_PIXELS);
    for (v, &b) in m.data.iter_mut().zip(&bytes[16..]) {
        *v = b as f32 / 255.0;
    }
    Ok(m)
}

/// Parse an IDX label file (magic 2049).
pub fn parse_labels(bytes: &[u8]) -> anyhow::Result<Vec<u8>> {
    anyhow::ensure!(bytes.len() >= 8, "label file too short");
    let magic = be_u32(bytes, 0);
    anyhow::ensure!(magic == LABELS_MAGIC, "bad label magic {magic}");
    let n = be_u32(bytes, 4) as usize;
    anyhow::ensure!(bytes.len() == 8 + n, "label payload size mismatch");
    let labels = bytes[8..].to_vec();
    anyhow::ensure!(labels.iter().all(|&l| l < 10), "label out of range");
    Ok(labels)
}

fn find_file(dir: &Path, stems: &[&str]) -> Option<PathBuf> {
    for stem in stems {
        for suffix in ["", ".idx3-ubyte", ".idx1-ubyte", "-idx3-ubyte", "-idx1-ubyte"] {
            let p = dir.join(format!("{stem}{suffix}"));
            if p.is_file() {
                return Some(p);
            }
        }
    }
    None
}

/// True if the directory looks like it holds the MNIST IDX files.
pub fn available(dir: &str) -> bool {
    let d = Path::new(dir);
    find_file(d, &["train-images-ubyte", "train-images.idx3-ubyte", "train-images"]).is_some()
}

/// Load the four canonical files from `dir`.
pub fn load_dir(dir: &str) -> anyhow::Result<Corpus> {
    let d = Path::new(dir);
    let paths = [
        find_file(d, &["train-images-ubyte", "train-images"]),
        find_file(d, &["train-labels-ubyte", "train-labels"]),
        find_file(d, &["t10k-images-ubyte", "t10k-images", "test-images"]),
        find_file(d, &["t10k-labels-ubyte", "t10k-labels", "test-labels"]),
    ];
    let [ti, tl, vi, vl] = paths;
    let (ti, tl, vi, vl) = match (ti, tl, vi, vl) {
        (Some(a), Some(b), Some(c), Some(d)) => (a, b, c, d),
        _ => anyhow::bail!("MNIST IDX files not found under {dir}"),
    };
    let train = Dataset {
        images: parse_images(&fs::read(ti)?)?,
        labels: parse_labels(&fs::read(tl)?)?,
    };
    let test = Dataset {
        images: parse_images(&fs::read(vi)?)?,
        labels: parse_labels(&fs::read(vl)?)?,
    };
    train.validate().map_err(anyhow::Error::msg)?;
    test.validate().map_err(anyhow::Error::msg)?;
    anyhow::ensure!(train.images.rows == train.labels.len());
    anyhow::ensure!(test.images.rows == test.labels.len());
    Ok(Corpus { train, test })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_images(n: usize) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&IMAGES_MAGIC.to_be_bytes());
        b.extend_from_slice(&(n as u32).to_be_bytes());
        b.extend_from_slice(&28u32.to_be_bytes());
        b.extend_from_slice(&28u32.to_be_bytes());
        b.extend((0..n * IMG_PIXELS).map(|i| (i % 256) as u8));
        b
    }

    fn fake_labels(n: usize) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&LABELS_MAGIC.to_be_bytes());
        b.extend_from_slice(&(n as u32).to_be_bytes());
        b.extend((0..n).map(|i| (i % 10) as u8));
        b
    }

    #[test]
    fn parses_synthetic_idx_bytes() {
        let imgs = parse_images(&fake_images(5)).unwrap();
        assert_eq!(imgs.rows, 5);
        assert_eq!(imgs.cols, IMG_PIXELS);
        assert!((imgs.at(0, 255) - 255.0 / 255.0).abs() < 1e-6);
        let labels = parse_labels(&fake_labels(5)).unwrap();
        assert_eq!(labels, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn rejects_bad_magic_and_sizes() {
        let mut b = fake_images(2);
        b[0] = 9;
        assert!(parse_images(&b).is_err());
        let mut b = fake_images(2);
        b.pop();
        assert!(parse_images(&b).is_err());
        let mut l = fake_labels(3);
        l[8] = 11;
        assert!(parse_labels(&l).is_err());
    }

    #[test]
    fn load_dir_roundtrip() {
        let dir = std::env::temp_dir().join("ota_mnist_idx_test");
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join("train-images-ubyte"), fake_images(6)).unwrap();
        fs::write(dir.join("train-labels-ubyte"), fake_labels(6)).unwrap();
        fs::write(dir.join("t10k-images-ubyte"), fake_images(4)).unwrap();
        fs::write(dir.join("t10k-labels-ubyte"), fake_labels(4)).unwrap();
        let corpus = load_dir(dir.to_str().unwrap()).unwrap();
        assert_eq!(corpus.train.len(), 6);
        assert_eq!(corpus.test.len(), 4);
        assert!(available(dir.to_str().unwrap()));
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_fails_cleanly() {
        assert!(load_dir("/nonexistent/mnist").is_err());
        assert!(!available("/nonexistent/mnist"));
    }
}
