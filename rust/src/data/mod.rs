//! Datasets and device partitioning.
//!
//! The paper trains on MNIST. This environment has no network access, so the
//! default corpus is a deterministic **synthetic MNIST-like** generator
//! ([`synthetic`]) with identical shapes (28×28 grayscale, 10 classes); if
//! real MNIST IDX files are present under `data/mnist/`, [`mnist_idx`] loads
//! them instead (see DESIGN.md §3 for the substitution rationale).

pub mod mnist_idx;
pub mod partition;
pub mod synthetic;

use crate::tensor::Matf;

/// Image side length and derived sizes (MNIST geometry).
pub const IMG_SIDE: usize = 28;
pub const IMG_PIXELS: usize = IMG_SIDE * IMG_SIDE;
pub const NUM_CLASSES: usize = 10;

/// An in-memory labeled image dataset. `images` is n×784 row-major with
/// pixel values in [0, 1]; `labels` holds class ids in 0..10.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub images: Matf,
    pub labels: Vec<u8>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn image(&self, i: usize) -> &[f32] {
        self.images.row(i)
    }

    pub fn label(&self, i: usize) -> usize {
        self.labels[i] as usize
    }

    /// Select a subset by indices (copies rows).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut images = Matf::zeros(idx.len(), self.images.cols);
        let mut labels = Vec::with_capacity(idx.len());
        for (r, &i) in idx.iter().enumerate() {
            images.row_mut(r).copy_from_slice(self.images.row(i));
            labels.push(self.labels[i]);
        }
        Dataset { images, labels }
    }

    /// Sanity checks used by tests and loaders.
    pub fn validate(&self) -> Result<(), String> {
        if self.images.rows != self.labels.len() {
            return Err(format!(
                "image rows {} != labels {}",
                self.images.rows,
                self.labels.len()
            ));
        }
        if self.images.cols != IMG_PIXELS {
            return Err(format!("expected {IMG_PIXELS} pixels, got {}", self.images.cols));
        }
        for (i, &l) in self.labels.iter().enumerate() {
            if l as usize >= NUM_CLASSES {
                return Err(format!("label {l} out of range at row {i}"));
            }
        }
        if self
            .images
            .data
            .iter()
            .any(|&p| !(0.0..=1.0).contains(&p) || p.is_nan())
        {
            return Err("pixel outside [0,1]".into());
        }
        Ok(())
    }
}

/// Train/test pair.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub train: Dataset,
    pub test: Dataset,
}

/// Load the corpus described by a config: real MNIST when IDX files exist
/// at the configured directory, the synthetic generator otherwise.
pub fn load_corpus(spec: &crate::config::DatasetSpec, seed: u64) -> anyhow::Result<Corpus> {
    match spec {
        crate::config::DatasetSpec::Synthetic { train, test } => {
            Ok(synthetic::generate_corpus(*train, *test, seed))
        }
        crate::config::DatasetSpec::MnistIdx { dir } => mnist_idx::load_dir(dir),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subset_copies_right_rows() {
        let corpus = synthetic::generate_corpus(50, 10, 3);
        let sub = corpus.train.subset(&[0, 7, 4]);
        assert_eq!(sub.len(), 3);
        assert_eq!(sub.image(1), corpus.train.image(7));
        assert_eq!(sub.label(2), corpus.train.label(4));
    }
}
