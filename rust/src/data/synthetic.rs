//! Deterministic synthetic MNIST-like corpus.
//!
//! Each of the 10 classes is a smooth prototype intensity field on the 28×28
//! grid (a mixture of 3–5 Gaussian blobs whose centers/widths are drawn from
//! a class-seeded RNG). A sample is its class prototype under a random
//! global brightness, a small random translation, and i.i.d. pixel noise,
//! clamped to [0, 1] — structurally similar to MNIST for a linear softmax
//! classifier: classes overlap but are largely linearly separable, so the
//! single-layer d = 7850 model reaches high accuracy, and gradients have the
//! decaying-variance profile the paper's power-allocation discussion relies
//! on.

use super::{Corpus, Dataset, IMG_PIXELS, IMG_SIDE, NUM_CLASSES};
use crate::tensor::Matf;
use crate::util::rng::Pcg64;

/// Blob mixture defining one class prototype.
#[derive(Clone, Debug)]
struct Prototype {
    /// (cx, cy, width, amplitude) per blob.
    blobs: Vec<(f64, f64, f64, f64)>,
}

impl Prototype {
    fn generate(class: usize, seed: u64) -> Prototype {
        let mut rng = Pcg64::with_stream(seed ^ 0xC1A5_5000, class as u64);
        let n_blobs = 3 + rng.below(3) as usize; // 3..=5
        let blobs = (0..n_blobs)
            .map(|_| {
                let cx = rng.range_f64(6.0, 22.0);
                let cy = rng.range_f64(6.0, 22.0);
                let w = rng.range_f64(2.0, 5.0);
                let a = rng.range_f64(0.5, 1.0);
                (cx, cy, w, a)
            })
            .collect();
        Prototype { blobs }
    }

    /// Intensity at pixel (x, y) with the prototype shifted by (dx, dy).
    #[inline]
    fn intensity(&self, x: f64, y: f64, dx: f64, dy: f64) -> f64 {
        let mut v = 0.0;
        for &(cx, cy, w, a) in &self.blobs {
            let ddx = x - (cx + dx);
            let ddy = y - (cy + dy);
            v += a * (-(ddx * ddx + ddy * ddy) / (2.0 * w * w)).exp();
        }
        v.min(1.0)
    }
}

/// Generate `n` samples with labels drawn uniformly over classes.
pub fn generate(n: usize, seed: u64, stream: u64) -> Dataset {
    let prototypes: Vec<Prototype> = (0..NUM_CLASSES)
        .map(|c| Prototype::generate(c, seed))
        .collect();
    let mut rng = Pcg64::with_stream(seed, stream);
    let mut images = Matf::zeros(n, IMG_PIXELS);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = rng.below(NUM_CLASSES as u64) as usize;
        labels.push(class as u8);
        let brightness = rng.normal_ms(1.0, 0.15).clamp(0.55, 1.45);
        let dx = rng.range_f64(-2.0, 2.0);
        let dy = rng.range_f64(-2.0, 2.0);
        let noise_sd = 0.08;
        let row = images.row_mut(i);
        let proto = &prototypes[class];
        for py in 0..IMG_SIDE {
            for px in 0..IMG_SIDE {
                let base = proto.intensity(px as f64, py as f64, dx, dy);
                let v = brightness * base + rng.normal() * noise_sd;
                row[py * IMG_SIDE + px] = (v as f32).clamp(0.0, 1.0);
            }
        }
    }
    Dataset { images, labels }
}

/// Train/test corpus with disjoint RNG streams (so test samples are drawn
/// from the same distribution but are never training samples).
pub fn generate_corpus(train: usize, test: usize, seed: u64) -> Corpus {
    Corpus {
        train: generate(train, seed, 0x7EA1),
        test: generate(test, seed, 0x7E57),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let ds = generate(100, 1, 0);
        ds.validate().unwrap();
        assert_eq!(ds.len(), 100);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(20, 9, 0);
        let b = generate(20, 9, 0);
        assert_eq!(a.images.data, b.images.data);
        assert_eq!(a.labels, b.labels);
        let c = generate(20, 10, 0);
        assert_ne!(a.images.data, c.images.data);
    }

    #[test]
    fn classes_roughly_balanced() {
        let ds = generate(2000, 5, 0);
        let mut counts = [0usize; NUM_CLASSES];
        for &l in &ds.labels {
            counts[l as usize] += 1;
        }
        for c in counts {
            assert!(c > 120 && c < 280, "counts={counts:?}");
        }
    }

    #[test]
    fn classes_are_distinguishable() {
        // Mean images of two classes should differ substantially more than
        // within-class variation — a proxy for linear separability.
        let ds = generate(500, 3, 0);
        let mut means = vec![vec![0f64; IMG_PIXELS]; NUM_CLASSES];
        let mut counts = [0usize; NUM_CLASSES];
        for i in 0..ds.len() {
            let c = ds.label(i);
            counts[c] += 1;
            for (m, &p) in means[c].iter_mut().zip(ds.image(i)) {
                *m += p as f64;
            }
        }
        for (c, m) in means.iter_mut().enumerate() {
            for v in m.iter_mut() {
                *v /= counts[c].max(1) as f64;
            }
        }
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter()
                .zip(b)
                .map(|(x, y)| (x - y).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let mut min_pair = f64::INFINITY;
        for i in 0..NUM_CLASSES {
            for j in (i + 1)..NUM_CLASSES {
                min_pair = min_pair.min(dist(&means[i], &means[j]));
            }
        }
        assert!(min_pair > 1.0, "class prototypes too close: {min_pair}");
    }

    #[test]
    fn train_test_streams_disjoint() {
        let c = generate_corpus(50, 50, 11);
        assert_ne!(c.train.images.data, c.test.images.data);
    }
}
