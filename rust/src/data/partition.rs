//! Device data partitioning: IID and the paper's non-IID split.
//!
//! §VI: *IID* assigns each device B random training samples; *non-IID*
//! assigns each device B/2 samples from each of two randomly-selected
//! classes — the biased distribution Fig. 2b stresses.

use super::Dataset;
use crate::util::rng::Pcg64;

/// IID split: each device receives `local` samples drawn without
/// replacement from the corpus (devices are disjoint).
pub fn iid(train: &Dataset, devices: usize, local: usize, rng: &mut Pcg64) -> Vec<Vec<usize>> {
    assert!(
        devices * local <= train.len(),
        "M*B = {} exceeds corpus {}",
        devices * local,
        train.len()
    );
    let order = rng.sample_indices(train.len(), devices * local);
    order.chunks(local).map(|c| c.to_vec()).collect()
}

/// Non-IID split: per device, pick two classes at random and take B/2
/// samples of each (sampling within a class without replacement while
/// supplies last; falls back to other samples of the same class already
/// used elsewhere only if a class pool is exhausted).
pub fn non_iid(
    train: &Dataset,
    devices: usize,
    local: usize,
    rng: &mut Pcg64,
) -> Vec<Vec<usize>> {
    let n_classes = super::NUM_CLASSES;
    // Index pool per class, shuffled.
    let mut pools: Vec<Vec<usize>> = vec![Vec::new(); n_classes];
    for i in 0..train.len() {
        pools[train.label(i)].push(i);
    }
    for pool in pools.iter_mut() {
        rng.shuffle(pool);
    }
    let mut cursors = vec![0usize; n_classes];
    let half = local / 2;
    // Only classes actually present in the corpus are assignable.
    let present: Vec<usize> = (0..n_classes).filter(|&c| !pools[c].is_empty()).collect();
    assert!(!present.is_empty(), "corpus has no labeled samples");
    let mut out = Vec::with_capacity(devices);
    for _ in 0..devices {
        // Two distinct random classes (or the same one twice if only one
        // class exists in the corpus).
        let c1 = present[rng.below(present.len() as u64) as usize];
        let c2 = if present.len() == 1 {
            c1
        } else {
            loop {
                let c = present[rng.below(present.len() as u64) as usize];
                if c != c1 {
                    break c;
                }
            }
        };
        let mut idx = Vec::with_capacity(local);
        for (c, want) in [(c1, half), (c2, local - half)] {
            let pool = &pools[c];
            let cur = &mut cursors[c];
            for _ in 0..want {
                if *cur >= pool.len() {
                    // Pool exhausted: wrap (sample reuse across devices is
                    // acceptable — the paper keeps MB = N so this triggers
                    // only in reduced smoke configs).
                    *cur = 0;
                }
                idx.push(pool[*cur]);
                *cur += 1;
            }
        }
        out.push(idx);
    }
    out
}

/// Count distinct labels present in a device's shard (test helper / metric).
pub fn distinct_labels(train: &Dataset, shard: &[usize]) -> usize {
    let mut seen = [false; super::NUM_CLASSES];
    for &i in shard {
        seen[train.label(i)] = true;
    }
    seen.iter().filter(|&&s| s).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn iid_shards_disjoint_and_sized() {
        let ds = synthetic::generate(1000, 1, 0);
        let mut rng = Pcg64::new(2);
        let shards = iid(&ds, 8, 100, &mut rng);
        assert_eq!(shards.len(), 8);
        let mut seen = std::collections::HashSet::new();
        for s in &shards {
            assert_eq!(s.len(), 100);
            for &i in s {
                assert!(seen.insert(i), "index {i} duplicated across shards");
            }
        }
    }

    #[test]
    fn noniid_two_classes_per_device() {
        let ds = synthetic::generate(4000, 1, 0);
        let mut rng = Pcg64::new(3);
        let shards = non_iid(&ds, 10, 200, &mut rng);
        for s in &shards {
            assert_eq!(s.len(), 200);
            let k = distinct_labels(&ds, s);
            assert!(k <= 2, "device shard has {k} classes");
        }
    }

    #[test]
    fn noniid_half_and_half() {
        let ds = synthetic::generate(4000, 7, 0);
        let mut rng = Pcg64::new(4);
        let shards = non_iid(&ds, 5, 100, &mut rng);
        for s in &shards {
            let mut counts = std::collections::HashMap::new();
            for &i in s {
                *counts.entry(ds.label(i)).or_insert(0usize) += 1;
            }
            let mut vals: Vec<usize> = counts.values().cloned().collect();
            vals.sort_unstable();
            assert_eq!(vals, vec![50, 50], "split should be B/2 + B/2");
        }
    }

    #[test]
    #[should_panic(expected = "exceeds corpus")]
    fn iid_overflow_panics() {
        let ds = synthetic::generate(100, 1, 0);
        let mut rng = Pcg64::new(5);
        let _ = iid(&ds, 10, 100, &mut rng);
    }

    /// Property sweep over random fleet shapes: IID shards are pairwise
    /// disjoint, exactly `B`-sized, in-range, and deterministic per seed.
    #[test]
    fn prop_iid_disjoint_exact_and_seed_deterministic() {
        let ds = synthetic::generate(2_000, 9, 0);
        let mut meta = Pcg64::new(0xBEEF);
        for trial in 0..25u64 {
            let devices = 1 + meta.below(12) as usize;
            let local = 1 + meta.below((ds.len() / devices) as u64) as usize;
            let shards = iid(&ds, devices, local, &mut Pcg64::new(trial));
            assert_eq!(shards.len(), devices, "trial {trial}");
            let mut seen = std::collections::HashSet::new();
            for (dev, shard) in shards.iter().enumerate() {
                assert_eq!(shard.len(), local, "trial {trial} device {dev}");
                for &i in shard {
                    assert!(i < ds.len(), "trial {trial}: index {i} out of range");
                    assert!(
                        seen.insert(i),
                        "trial {trial}: index {i} appears in two shards"
                    );
                }
            }
            // Same seed ⇒ identical partition; the driving RNG is the only
            // randomness source.
            assert_eq!(
                shards,
                iid(&ds, devices, local, &mut Pcg64::new(trial)),
                "trial {trial}: iid must be deterministic per seed"
            );
        }
    }

    /// Property sweep: every non-IID shard holds at most two classes,
    /// exact size, deterministic splits per seed, and distinct seeds
    /// produce distinct assignments.
    #[test]
    fn prop_noniid_two_classes_sized_and_seed_deterministic() {
        let ds = synthetic::generate(3_000, 5, 0);
        let mut meta = Pcg64::new(0xFACE);
        let mut all_runs = Vec::new();
        for trial in 0..20u64 {
            let devices = 2 + meta.below(10) as usize;
            let local = 2 + 2 * meta.below(60) as usize;
            let shards = non_iid(&ds, devices, local, &mut Pcg64::new(trial));
            assert_eq!(shards.len(), devices, "trial {trial}");
            for (dev, shard) in shards.iter().enumerate() {
                assert_eq!(shard.len(), local, "trial {trial} device {dev}");
                let k = distinct_labels(&ds, shard);
                assert!(
                    (1..=2).contains(&k),
                    "trial {trial} device {dev}: {k} classes in a 2-class shard"
                );
            }
            assert_eq!(
                shards,
                non_iid(&ds, devices, local, &mut Pcg64::new(trial)),
                "trial {trial}: non_iid must be deterministic per seed"
            );
            all_runs.push(shards);
        }
        // Different seeds almost surely differ somewhere; identical output
        // across all 20 trials would mean the seed is ignored.
        let first = &all_runs[0];
        assert!(
            all_runs.iter().any(|s| s != first),
            "every seed produced the identical non-IID split"
        );
    }
}
