//! Approximate Message Passing (AMP) for sparse recovery at the PS.
//!
//! Donoho–Maleki–Montanari AMP [31] with the soft-threshold denoiser:
//!
//! ```text
//! r^t  = y − A x^t + (‖x^t‖₀ / s) · r^{t−1}      (Onsager correction)
//! τ^t  = α · ‖r^t‖₂ / √s                           (noise-level estimate)
//! x^{t+1} = η_{τ}(x^t + Aᵀ r^t)                    (soft threshold)
//! ```
//!
//! Lemma 1 of the paper: for a k-sparse signal observed through an s×d
//! Gaussian matrix with s > k, AMP's effective noise σ_τ decreases
//! monotonically toward the channel noise σ — the reconstruction behaves
//! like `x + σω`. The state-evolution trace exposed here lets tests verify
//! that monotone contraction on synthetic signals.

use crate::tensor::{gemv_t, soft_threshold, Matf};

/// AMP hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct AmpConfig {
    pub max_iters: usize,
    /// Stop when ‖x^{t+1} − x^t‖ / max(‖x^t‖, ε) < tol.
    pub tol: f64,
    /// Threshold multiplier α in τ = α‖r‖/√s (1.0–1.5 typical).
    pub threshold_mult: f32,
}

impl Default for AmpConfig {
    fn default() -> Self {
        AmpConfig {
            max_iters: 30,
            tol: 1e-4,
            threshold_mult: 1.1,
        }
    }
}

/// Per-iteration diagnostics (state-evolution trace).
#[derive(Clone, Debug)]
pub struct AmpTrace {
    /// Effective-noise estimates τ_t per iteration.
    pub tau: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
}

/// Recover x̂ from y = A·x + z. Returns (x̂, trace).
pub fn recover(a: &Matf, y: &[f32], cfg: &AmpConfig) -> (Vec<f32>, AmpTrace) {
    recover_with(a, None, y, cfg)
}

/// Recovery with an optional precomputed Aᵀ (d×s̃). When provided, the
/// A·x̂ residual pass runs as contiguous axpys over rows of Aᵀ instead of
/// strided column gathers — the §Perf hot-path variant used by
/// [`crate::analog::AnalogPs`].
pub fn recover_with(
    a: &Matf,
    a_t: Option<&Matf>,
    y: &[f32],
    cfg: &AmpConfig,
) -> (Vec<f32>, AmpTrace) {
    let s = a.rows;
    let d = a.cols;
    if let Some(at) = a_t {
        assert_eq!((at.rows, at.cols), (d, s), "Aᵀ shape mismatch");
    }
    assert_eq!(y.len(), s, "observation length must equal rows of A");
    // x^0 = 0, r^0 = y (A·x^0 = 0, no Onsager term yet).
    let mut x = vec![0f32; d];
    let mut r = y.to_vec();
    let mut pseudo = vec![0f32; d];
    let mut ax = vec![0f32; s];
    let mut trace = AmpTrace {
        tau: Vec::with_capacity(cfg.max_iters),
        iterations: 0,
        converged: false,
    };
    let mut x_prev = vec![0f32; d];
    let inv_sqrt_s = 1.0 / (s as f32).sqrt();

    for it in 0..cfg.max_iters {
        // Noise-level estimate and threshold from the current residual.
        let sigma_hat = (crate::tensor::norm(&r) as f32) * inv_sqrt_s;
        let tau = cfg.threshold_mult * sigma_hat;
        trace.tau.push(sigma_hat as f64);

        // Pseudo-data u = x^t + Aᵀ r^t, then denoise: x^{t+1} = η_τ(u).
        match a_t {
            Some(at) => crate::tensor::gemv(at, &r, &mut pseudo),
            None => gemv_t(a, &r, &mut pseudo),
        }
        for (p, &xi) in pseudo.iter_mut().zip(&x) {
            *p += xi;
        }
        x_prev.copy_from_slice(&x);
        x.copy_from_slice(&pseudo);
        soft_threshold(&mut x, tau);

        // Next residual with the Onsager correction:
        // r^{t+1} = y − A x^{t+1} + (‖x^{t+1}‖₀/s)·r^t.
        let nnz = x.iter().filter(|&&v| v != 0.0).count();
        let b = nnz as f32 / s as f32;
        match a_t {
            Some(at) => mul_sparse_with_t(at, &x, &mut ax),
            None => mul_sparse(a, &x, &mut ax),
        }
        for i in 0..s {
            r[i] = y[i] - ax[i] + b * r[i];
        }

        trace.iterations = it + 1;
        // Convergence check on relative change.
        let mut diff = 0f64;
        for (a, b) in x.iter().zip(&x_prev) {
            let dlt = (a - b) as f64;
            diff += dlt * dlt;
        }
        let base = crate::tensor::norm_sq(&x_prev).max(1e-12);
        if (diff / base).sqrt() < cfg.tol {
            trace.converged = true;
            break;
        }
    }
    (x, trace)
}

/// A·x via the transpose layout: contiguous axpys over rows of Aᵀ for the
/// non-zero entries of x (always wins — the axpy streams s floats per
/// non-zero, no strided gathers, and skips zero entries entirely).
pub fn mul_sparse_with_t(a_t: &Matf, x: &[f32], out: &mut [f32]) {
    assert_eq!(a_t.rows, x.len());
    assert_eq!(a_t.cols, out.len());
    out.fill(0.0);
    for (j, &xj) in x.iter().enumerate() {
        if xj != 0.0 {
            crate::tensor::axpy(xj, a_t.row(j), out);
        }
    }
}

/// A·x exploiting sparsity of x: cost s·nnz instead of s·d.
/// Falls back to dense row dots when x is mostly dense.
pub fn mul_sparse(a: &Matf, x: &[f32], out: &mut [f32]) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, out.len());
    let support: Vec<usize> = x
        .iter()
        .enumerate()
        .filter(|(_, &v)| v != 0.0)
        .map(|(i, _)| i)
        .collect();
    if support.len() * 4 > a.cols {
        // Dense path.
        crate::tensor::gemv(a, x, out);
        return;
    }
    for (i, o) in out.iter_mut().enumerate() {
        let row = a.row(i);
        let mut acc = 0f32;
        for &j in &support {
            acc += row[j] * x[j];
        }
        *o = acc;
    }
}

/// Generate the shared pseudo-random measurement matrix A ∈ R^{s̃×d} with
/// i.i.d. N(0, 1/s̃) entries from a shared seed (§IV). Devices and the PS
/// call this with identical arguments and obtain identical matrices.
pub fn measurement_matrix(s_tilde: usize, d: usize, seed: u64) -> Matf {
    let mut m = Matf::zeros(s_tilde, d);
    let sd = (1.0 / s_tilde as f64).sqrt() as f32;
    // Parallel deterministic fill: one RNG stream per row.
    let workers = crate::util::threadpool::default_workers(s_tilde);
    crate::util::threadpool::par_chunks_mut(&mut m.data, d, workers, |row, chunk| {
        let mut rng = crate::util::rng::Pcg64::with_stream(seed ^ 0xA117_0000, row as u64);
        rng.fill_normal_f32(chunk, sd);
    });
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn sparse_signal(d: usize, k: usize, rng: &mut Pcg64) -> Vec<f32> {
        let mut x = vec![0f32; d];
        let idx = rng.sample_indices(d, k);
        for i in idx {
            x[i] = rng.normal_ms(0.0, 1.0) as f32;
        }
        x
    }

    #[test]
    fn recovers_sparse_signal_noiseless() {
        let (d, s, k) = (400, 200, 20);
        let mut rng = Pcg64::new(1);
        let a = measurement_matrix(s, d, 7);
        let x = sparse_signal(d, k, &mut rng);
        let mut y = vec![0f32; s];
        crate::tensor::gemv(&a, &x, &mut y);
        let (xhat, trace) = recover(
            &a,
            &y,
            &AmpConfig {
                max_iters: 60,
                tol: 1e-7,
                threshold_mult: 1.1,
            },
        );
        let err = rel_err(&x, &xhat);
        assert!(err < 0.05, "relative error {err}, trace={:?}", trace.tau);
    }

    #[test]
    fn recovery_degrades_gracefully_with_noise() {
        let (d, s, k) = (400, 200, 20);
        let mut rng = Pcg64::new(3);
        let a = measurement_matrix(s, d, 9);
        let x = sparse_signal(d, k, &mut rng);
        let mut y = vec![0f32; s];
        crate::tensor::gemv(&a, &x, &mut y);
        for v in y.iter_mut() {
            *v += rng.normal_ms(0.0, 0.05) as f32;
        }
        let (xhat, _) = recover(&a, &y, &AmpConfig::default());
        let err = rel_err(&x, &xhat);
        assert!(err < 0.35, "relative error {err}");
    }

    #[test]
    fn tau_contracts_monotonically_lemma1() {
        // Lemma 1: σ_τ decreases monotonically (here: on a well-conditioned
        // instance the state-evolution estimate should be non-increasing
        // after the first iteration, within jitter).
        let (d, s, k) = (600, 300, 15);
        let mut rng = Pcg64::new(5);
        let a = measurement_matrix(s, d, 11);
        let x = sparse_signal(d, k, &mut rng);
        let mut y = vec![0f32; s];
        crate::tensor::gemv(&a, &x, &mut y);
        let (_, trace) = recover(
            &a,
            &y,
            &AmpConfig {
                max_iters: 25,
                tol: 0.0,
                threshold_mult: 1.1,
            },
        );
        for w in trace.tau.windows(2).skip(1) {
            assert!(
                w[1] <= w[0] * 1.05,
                "tau increased: {:?}",
                trace.tau
            );
        }
        assert!(trace.tau.last().unwrap() < &(trace.tau[0] * 0.1));
    }

    #[test]
    fn zero_observation_gives_zero() {
        let a = measurement_matrix(50, 100, 1);
        let y = vec![0f32; 50];
        let (xhat, trace) = recover(&a, &y, &AmpConfig::default());
        assert!(xhat.iter().all(|&v| v == 0.0));
        assert!(trace.converged);
    }

    #[test]
    fn matrix_is_shared_and_normalized() {
        let a1 = measurement_matrix(100, 200, 42);
        let a2 = measurement_matrix(100, 200, 42);
        assert_eq!(a1.data, a2.data);
        let a3 = measurement_matrix(100, 200, 43);
        assert_ne!(a1.data, a3.data);
        // Column norms concentrate near 1 (entries N(0, 1/s)).
        let mut norms = Vec::new();
        for c in 0..200 {
            let mut n = 0f64;
            for r in 0..100 {
                n += (a1.at(r, c) as f64).powi(2);
            }
            norms.push(n.sqrt());
        }
        let mean = norms.iter().sum::<f64>() / norms.len() as f64;
        assert!((mean - 1.0).abs() < 0.05, "mean col norm {mean}");
    }

    #[test]
    fn mul_sparse_matches_dense() {
        let a = measurement_matrix(30, 80, 2);
        let mut rng = Pcg64::new(8);
        let x = sparse_signal(80, 6, &mut rng);
        let mut sparse_out = vec![0f32; 30];
        let mut dense_out = vec![0f32; 30];
        mul_sparse(&a, &x, &mut sparse_out);
        crate::tensor::gemv(&a, &x, &mut dense_out);
        for (s, d) in sparse_out.iter().zip(&dense_out) {
            assert!((s - d).abs() < 1e-5);
        }
    }

    fn rel_err(x: &[f32], xhat: &[f32]) -> f64 {
        let num: f64 = x
            .iter()
            .zip(xhat)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        num / crate::tensor::norm(x).max(1e-12)
    }
}
