//! Approximate Message Passing (AMP) for sparse recovery at the PS.
//!
//! Donoho–Maleki–Montanari AMP [31] with the soft-threshold denoiser:
//!
//! ```text
//! r^t  = y − A x^t + (‖x^t‖₀ / s) · r^{t−1}      (Onsager correction)
//! τ^t  = α · ‖r^t‖₂ / √s                           (noise-level estimate)
//! x^{t+1} = η_{τ}(x^t + Aᵀ r^t)                    (soft threshold)
//! ```
//!
//! Lemma 1 of the paper: for a k-sparse signal observed through an s×d
//! Gaussian matrix with s > k, AMP's effective noise σ_τ decreases
//! monotonically toward the channel noise σ — the reconstruction behaves
//! like `x + σω`. The state-evolution trace exposed here lets tests verify
//! that monotone contraction on synthetic signals.
//!
//! # Perf (see PERF.md)
//!
//! With a precomputed Aᵀ, [`recover_with`] runs a **fused single-stream
//! iteration**: one pass over the rows of Aᵀ computes the pseudo-data dot
//! `Aᵀr`, applies the soft threshold, and accumulates the surviving
//! coefficient's contribution to `A·x̂` while the 16 KB row is still
//! cache-hot. The seed formulation streamed the 123 MB (at paper shape)
//! matrix twice per iteration; the fused pass streams it once, which on a
//! memory-bound host roughly halves AMP iteration time. Per-element
//! floating-point order is exactly the seed's (dot reduction tree,
//! threshold expression, ascending-j accumulation with the `x̂_j == 0`
//! skip), so results are **bit-identical** to
//! [`recover_with_reference`] — enforced by `rust/tests/kernel_contracts.rs`.

use crate::tensor::{gemv_t, soft_threshold, Matf};

/// AMP hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct AmpConfig {
    pub max_iters: usize,
    /// Stop when ‖x^{t+1} − x^t‖ / max(‖x^t‖, ε) < tol.
    pub tol: f64,
    /// Threshold multiplier α in τ = α‖r‖/√s (1.0–1.5 typical).
    pub threshold_mult: f32,
}

impl Default for AmpConfig {
    fn default() -> Self {
        AmpConfig {
            max_iters: 30,
            tol: 1e-4,
            threshold_mult: 1.1,
        }
    }
}

/// Per-iteration diagnostics (state-evolution trace).
#[derive(Clone, Debug)]
pub struct AmpTrace {
    /// Effective-noise estimates τ_t per iteration.
    pub tau: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
}

/// Recover x̂ from y = A·x + z. Returns (x̂, trace).
pub fn recover(a: &Matf, y: &[f32], cfg: &AmpConfig) -> (Vec<f32>, AmpTrace) {
    recover_with(a, None, y, cfg)
}

/// Recovery with an optional precomputed Aᵀ (d×s̃). When provided, the
/// whole iteration runs as one fused pass over the rows of Aᵀ (pseudo-data
/// dot + threshold + A·x̂ accumulation per row while it is cache-hot) —
/// bit-identical to the unfused [`recover_with_reference`] but streaming
/// the matrix once per iteration instead of twice. Without Aᵀ the seed
/// row-major formulation runs unchanged.
pub fn recover_with(
    a: &Matf,
    a_t: Option<&Matf>,
    y: &[f32],
    cfg: &AmpConfig,
) -> (Vec<f32>, AmpTrace) {
    match a_t {
        Some(at) => recover_fused(a, at, y, cfg),
        None => recover_with_reference(a, None, y, cfg),
    }
}

/// The fused-iteration hot path (requires Aᵀ).
fn recover_fused(a: &Matf, at: &Matf, y: &[f32], cfg: &AmpConfig) -> (Vec<f32>, AmpTrace) {
    let s = a.rows;
    let d = a.cols;
    assert_eq!((at.rows, at.cols), (d, s), "Aᵀ shape mismatch");
    assert_eq!(y.len(), s, "observation length must equal rows of A");
    // x^0 = 0, r^0 = y (A·x^0 = 0, no Onsager term yet).
    let mut x = vec![0f32; d];
    let mut r = y.to_vec();
    let mut ax = vec![0f32; s];
    let mut trace = AmpTrace {
        tau: Vec::with_capacity(cfg.max_iters),
        iterations: 0,
        converged: false,
    };
    let inv_sqrt_s = 1.0 / (s as f32).sqrt();

    for it in 0..cfg.max_iters {
        // Noise-level estimate and threshold from the current residual.
        let sigma_hat = (crate::tensor::norm(&r) as f32) * inv_sqrt_s;
        let tau = cfg.threshold_mult * sigma_hat;
        trace.tau.push(sigma_hat as f64);

        // ‖x^t‖² before the update — the convergence denominator.
        let base = crate::tensor::norm_sq(&x).max(1e-12);

        // Fused pass over rows of Aᵀ, four at a time: pseudo-data
        // u_j = (Aᵀr)_j + x_j, denoise x^{t+1}_j = η_τ(u_j), and fold the
        // surviving coefficient into A·x^{t+1} while row j is cache-hot.
        ax.fill(0.0);
        let mut nnz = 0usize;
        let mut diff = 0f64;
        let mut j = 0usize;
        while j + 4 <= d {
            let (r0, r1, r2, r3) = (at.row(j), at.row(j + 1), at.row(j + 2), at.row(j + 3));
            let u = crate::tensor::dot4(r0, r1, r2, r3, &r);
            let mut xn = [0f32; 4];
            for (l, xl) in xn.iter_mut().enumerate() {
                let uj = u[l] + x[j + l];
                let aj = uj.abs() - tau;
                let v = if aj > 0.0 { aj * uj.signum() } else { 0.0 };
                let dlt = (v - x[j + l]) as f64;
                diff += dlt * dlt;
                *xl = v;
                x[j + l] = v;
            }
            if xn[0] != 0.0 && xn[1] != 0.0 && xn[2] != 0.0 && xn[3] != 0.0 {
                nnz += 4;
                crate::tensor::axpy4(xn, r0, r1, r2, r3, &mut ax);
            } else {
                for (l, &v) in xn.iter().enumerate() {
                    if v != 0.0 {
                        nnz += 1;
                        crate::tensor::axpy(v, at.row(j + l), &mut ax);
                    }
                }
            }
            j += 4;
        }
        while j < d {
            let uj = crate::tensor::dot(at.row(j), &r) + x[j];
            let aj = uj.abs() - tau;
            let v = if aj > 0.0 { aj * uj.signum() } else { 0.0 };
            let dlt = (v - x[j]) as f64;
            diff += dlt * dlt;
            x[j] = v;
            if v != 0.0 {
                nnz += 1;
                crate::tensor::axpy(v, at.row(j), &mut ax);
            }
            j += 1;
        }

        // Next residual with the Onsager correction:
        // r^{t+1} = y − A x^{t+1} + (‖x^{t+1}‖₀/s)·r^t.
        let b = nnz as f32 / s as f32;
        crate::tensor::residual_update(&mut r, y, &ax, b);

        trace.iterations = it + 1;
        if (diff / base).sqrt() < cfg.tol {
            trace.converged = true;
            break;
        }
    }
    (x, trace)
}

/// The seed's unfused iteration (gemv pseudo-data pass, separate threshold
/// and A·x̂ passes), kept verbatim: it is the live bit-identity oracle for
/// the fused path, the fallback when no Aᵀ is available, and the "before"
/// timing in the components bench.
pub fn recover_with_reference(
    a: &Matf,
    a_t: Option<&Matf>,
    y: &[f32],
    cfg: &AmpConfig,
) -> (Vec<f32>, AmpTrace) {
    let s = a.rows;
    let d = a.cols;
    if let Some(at) = a_t {
        assert_eq!((at.rows, at.cols), (d, s), "Aᵀ shape mismatch");
    }
    assert_eq!(y.len(), s, "observation length must equal rows of A");
    // x^0 = 0, r^0 = y (A·x^0 = 0, no Onsager term yet).
    let mut x = vec![0f32; d];
    let mut r = y.to_vec();
    let mut pseudo = vec![0f32; d];
    let mut ax = vec![0f32; s];
    let mut trace = AmpTrace {
        tau: Vec::with_capacity(cfg.max_iters),
        iterations: 0,
        converged: false,
    };
    let mut x_prev = vec![0f32; d];
    let inv_sqrt_s = 1.0 / (s as f32).sqrt();

    for it in 0..cfg.max_iters {
        // Noise-level estimate and threshold from the current residual.
        let sigma_hat = (crate::tensor::norm(&r) as f32) * inv_sqrt_s;
        let tau = cfg.threshold_mult * sigma_hat;
        trace.tau.push(sigma_hat as f64);

        // Pseudo-data u = x^t + Aᵀ r^t, then denoise: x^{t+1} = η_τ(u).
        match a_t {
            Some(at) => crate::tensor::gemv(at, &r, &mut pseudo),
            None => gemv_t(a, &r, &mut pseudo),
        }
        for (p, &xi) in pseudo.iter_mut().zip(&x) {
            *p += xi;
        }
        x_prev.copy_from_slice(&x);
        x.copy_from_slice(&pseudo);
        soft_threshold(&mut x, tau);

        // Next residual with the Onsager correction:
        // r^{t+1} = y − A x^{t+1} + (‖x^{t+1}‖₀/s)·r^t.
        let nnz = x.iter().filter(|&&v| v != 0.0).count();
        let b = nnz as f32 / s as f32;
        match a_t {
            Some(at) => mul_sparse_with_t(at, &x, &mut ax),
            None => mul_sparse(a, &x, &mut ax),
        }
        for i in 0..s {
            r[i] = y[i] - ax[i] + b * r[i];
        }

        trace.iterations = it + 1;
        // Convergence check on relative change.
        let mut diff = 0f64;
        for (a, b) in x.iter().zip(&x_prev) {
            let dlt = (a - b) as f64;
            diff += dlt * dlt;
        }
        let base = crate::tensor::norm_sq(&x_prev).max(1e-12);
        if (diff / base).sqrt() < cfg.tol {
            trace.converged = true;
            break;
        }
    }
    (x, trace)
}

/// A·x via the transpose layout: contiguous axpys over rows of Aᵀ for the
/// non-zero entries of x (always wins — the axpy streams s floats per
/// non-zero, no strided gathers, and skips zero entries entirely).
pub fn mul_sparse_with_t(a_t: &Matf, x: &[f32], out: &mut [f32]) {
    assert_eq!(a_t.rows, x.len());
    assert_eq!(a_t.cols, out.len());
    out.fill(0.0);
    for (j, &xj) in x.iter().enumerate() {
        if xj != 0.0 {
            crate::tensor::axpy(xj, a_t.row(j), out);
        }
    }
}

/// A·x exploiting sparsity of x: cost s·nnz instead of s·d.
/// Falls back to dense row dots when x is mostly dense.
pub fn mul_sparse(a: &Matf, x: &[f32], out: &mut [f32]) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, out.len());
    let support: Vec<usize> = x
        .iter()
        .enumerate()
        .filter(|(_, &v)| v != 0.0)
        .map(|(i, _)| i)
        .collect();
    if support.len() * 4 > a.cols {
        // Dense path.
        crate::tensor::gemv(a, x, out);
        return;
    }
    for (i, o) in out.iter_mut().enumerate() {
        let row = a.row(i);
        let mut acc = 0f32;
        for &j in &support {
            acc += row[j] * x[j];
        }
        *o = acc;
    }
}

/// Generate the shared pseudo-random measurement matrix A ∈ R^{s̃×d} with
/// i.i.d. N(0, 1/s̃) entries from a shared seed (§IV). Devices and the PS
/// call this with identical arguments and obtain identical matrices.
pub fn measurement_matrix(s_tilde: usize, d: usize, seed: u64) -> Matf {
    let workers = crate::util::threadpool::default_workers(s_tilde);
    measurement_matrix_with_workers(s_tilde, d, seed, workers)
}

/// [`measurement_matrix`] with an explicit worker count. Row r's entries
/// come from the counter-seeded stream `(seed ^ 0xA117_0000, r)`, which
/// depends only on `(seed, r)` — never on which worker drew it or in what
/// order — so any `workers` value yields bit-identical matrices (asserted
/// by `rust/tests/kernel_contracts.rs`).
pub fn measurement_matrix_with_workers(
    s_tilde: usize,
    d: usize,
    seed: u64,
    workers: usize,
) -> Matf {
    let mut m = Matf::zeros(s_tilde, d);
    let sd = (1.0 / s_tilde as f64).sqrt() as f32;
    // Parallel deterministic fill: one RNG stream per row.
    crate::util::threadpool::par_chunks_mut(&mut m.data, d, workers, |row, chunk| {
        let mut rng = crate::util::rng::Pcg64::with_stream(seed ^ 0xA117_0000, row as u64);
        rng.fill_normal_f32(chunk, sd);
    });
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn sparse_signal(d: usize, k: usize, rng: &mut Pcg64) -> Vec<f32> {
        let mut x = vec![0f32; d];
        let idx = rng.sample_indices(d, k);
        for i in idx {
            x[i] = rng.normal_ms(0.0, 1.0) as f32;
        }
        x
    }

    #[test]
    fn recovers_sparse_signal_noiseless() {
        let (d, s, k) = (400, 200, 20);
        let mut rng = Pcg64::new(1);
        let a = measurement_matrix(s, d, 7);
        let x = sparse_signal(d, k, &mut rng);
        let mut y = vec![0f32; s];
        crate::tensor::gemv(&a, &x, &mut y);
        let (xhat, trace) = recover(
            &a,
            &y,
            &AmpConfig {
                max_iters: 60,
                tol: 1e-7,
                threshold_mult: 1.1,
            },
        );
        let err = rel_err(&x, &xhat);
        assert!(err < 0.05, "relative error {err}, trace={:?}", trace.tau);
    }

    #[test]
    fn fused_path_matches_reference_bitwise() {
        // The fused single-stream iteration must reproduce the seed's
        // unfused iteration bit-for-bit, trace included.
        let (d, s, k) = (403, 201, 25); // odd shapes exercise the j-tail
        let mut rng = Pcg64::new(17);
        let a = measurement_matrix(s, d, 19);
        let at = crate::analog::projection::transpose(&a);
        let x = sparse_signal(d, k, &mut rng);
        let mut y = vec![0f32; s];
        crate::tensor::gemv(&a, &x, &mut y);
        for v in y.iter_mut() {
            *v += rng.normal_ms(0.0, 0.02) as f32;
        }
        for cfg in [
            AmpConfig::default(),
            AmpConfig {
                max_iters: 40,
                tol: 1e-7,
                threshold_mult: 1.3,
            },
        ] {
            let (x_fused, t_fused) = recover_with(&a, Some(&at), &y, &cfg);
            let (x_ref, t_ref) = recover_with_reference(&a, Some(&at), &y, &cfg);
            for (f, r) in x_fused.iter().zip(&x_ref) {
                assert_eq!(f.to_bits(), r.to_bits());
            }
            assert_eq!(t_fused.iterations, t_ref.iterations);
            assert_eq!(t_fused.converged, t_ref.converged);
            for (f, r) in t_fused.tau.iter().zip(&t_ref.tau) {
                assert_eq!(f.to_bits(), r.to_bits());
            }
        }
    }

    #[test]
    fn recovery_degrades_gracefully_with_noise() {
        let (d, s, k) = (400, 200, 20);
        let mut rng = Pcg64::new(3);
        let a = measurement_matrix(s, d, 9);
        let x = sparse_signal(d, k, &mut rng);
        let mut y = vec![0f32; s];
        crate::tensor::gemv(&a, &x, &mut y);
        for v in y.iter_mut() {
            *v += rng.normal_ms(0.0, 0.05) as f32;
        }
        let (xhat, _) = recover(&a, &y, &AmpConfig::default());
        let err = rel_err(&x, &xhat);
        assert!(err < 0.35, "relative error {err}");
    }

    #[test]
    fn tau_contracts_monotonically_lemma1() {
        // Lemma 1: σ_τ decreases monotonically (here: on a well-conditioned
        // instance the state-evolution estimate should be non-increasing
        // after the first iteration, within jitter).
        let (d, s, k) = (600, 300, 15);
        let mut rng = Pcg64::new(5);
        let a = measurement_matrix(s, d, 11);
        let x = sparse_signal(d, k, &mut rng);
        let mut y = vec![0f32; s];
        crate::tensor::gemv(&a, &x, &mut y);
        let (_, trace) = recover(
            &a,
            &y,
            &AmpConfig {
                max_iters: 25,
                tol: 0.0,
                threshold_mult: 1.1,
            },
        );
        for w in trace.tau.windows(2).skip(1) {
            assert!(
                w[1] <= w[0] * 1.05,
                "tau increased: {:?}",
                trace.tau
            );
        }
        assert!(trace.tau.last().unwrap() < &(trace.tau[0] * 0.1));
    }

    #[test]
    fn zero_observation_gives_zero() {
        let a = measurement_matrix(50, 100, 1);
        let y = vec![0f32; 50];
        let (xhat, trace) = recover(&a, &y, &AmpConfig::default());
        assert!(xhat.iter().all(|&v| v == 0.0));
        assert!(trace.converged);
        // Same through the fused path.
        let at = crate::analog::projection::transpose(&a);
        let (xhat2, trace2) = recover_with(&a, Some(&at), &y, &AmpConfig::default());
        assert!(xhat2.iter().all(|&v| v == 0.0));
        assert!(trace2.converged);
    }

    #[test]
    fn matrix_is_shared_and_normalized() {
        let a1 = measurement_matrix(100, 200, 42);
        let a2 = measurement_matrix(100, 200, 42);
        assert_eq!(a1.data, a2.data);
        let a3 = measurement_matrix(100, 200, 43);
        assert_ne!(a1.data, a3.data);
        // Column norms concentrate near 1 (entries N(0, 1/s)).
        let mut norms = Vec::new();
        for c in 0..200 {
            let mut n = 0f64;
            for r in 0..100 {
                n += (a1.at(r, c) as f64).powi(2);
            }
            norms.push(n.sqrt());
        }
        let mean = norms.iter().sum::<f64>() / norms.len() as f64;
        assert!((mean - 1.0).abs() < 0.05, "mean col norm {mean}");
    }

    #[test]
    fn measurement_matrix_worker_invariant() {
        for workers in [1usize, 2, 4, 7] {
            let m = measurement_matrix_with_workers(33, 50, 42, workers);
            let m1 = measurement_matrix_with_workers(33, 50, 42, 1);
            assert_eq!(m.data, m1.data, "workers={workers}");
        }
    }

    #[test]
    fn mul_sparse_matches_dense() {
        let a = measurement_matrix(30, 80, 2);
        let mut rng = Pcg64::new(8);
        let x = sparse_signal(80, 6, &mut rng);
        let mut sparse_out = vec![0f32; 30];
        let mut dense_out = vec![0f32; 30];
        mul_sparse(&a, &x, &mut sparse_out);
        crate::tensor::gemv(&a, &x, &mut dense_out);
        for (s, d) in sparse_out.iter().zip(&dense_out) {
            assert!((s - d).abs() < 1e-5);
        }
    }

    fn rel_err(x: &[f32], xhat: &[f32]) -> f64 {
        let num: f64 = x
            .iter()
            .zip(xhat)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        num / crate::tensor::norm(x).max(1e-12)
    }
}
