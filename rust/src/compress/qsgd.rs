//! QSGD baseline [2] adapted to the capacity-limited MAC (§VI, Eq. 44).
//!
//! The device selects the q_{t,Q} largest-magnitude entries, then applies
//! QSGD stochastic quantization to that sparse vector: each selected entry
//! v_i is encoded as `‖v‖₂ · sign(v_i) · ξ_i` with ξ_i on a uniform grid of
//! 2^{l_Q} levels in [0, 1], rounded stochastically so the quantizer is
//! unbiased. Bit cost: `r_{t,Q} = 32 + log2 C(d, q) + (1 + l_Q)·q`
//! (32-bit norm + positions + sign&level per entry); q is budget-fitted.

use super::bits::{max_q_within_budget, position_bits};
use super::{DigitalCompressor, DigitalPayload};
use crate::tensor::topk_indices;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct QsgdCompressor {
    /// l_Q: number of quantization bits (paper uses 2 → 4 levels).
    pub levels_bits: u32,
    rng: Pcg64,
}

impl QsgdCompressor {
    pub fn new(levels_bits: u32, seed: u64) -> QsgdCompressor {
        QsgdCompressor {
            levels_bits,
            rng: Pcg64::with_stream(seed, 0x0516D),
        }
    }

    /// Eq. 44 bit cost.
    pub fn bit_cost(d: usize, q: usize, levels_bits: u32) -> f64 {
        32.0 + position_bits(d, q) + (1.0 + levels_bits as f64) * q as f64
    }

    pub fn pick_q(d: usize, budget_bits: f64, levels_bits: u32) -> usize {
        max_q_within_budget(d, budget_bits, |q| Self::bit_cost(d, q, levels_bits))
    }
}

impl DigitalCompressor for QsgdCompressor {
    fn encode(&mut self, g: &[f32], budget_bits: f64) -> DigitalPayload {
        let d = g.len();
        let q = Self::pick_q(d, budget_bits, self.levels_bits);
        if q == 0 {
            return DigitalPayload::silent(d);
        }
        let idx = topk_indices(g, q);
        // ‖v‖ over the selected entries only (that's the vector QSGD sees).
        let norm = idx
            .iter()
            .map(|&i| (g[i] as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        if norm == 0.0 {
            return DigitalPayload {
                reconstruction: vec![0.0; d],
                nnz: 0,
                bits: Self::bit_cost(d, q, self.levels_bits),
            };
        }
        let s_levels = (1u32 << self.levels_bits) as f64; // number of grid cells
        let mut recon = vec![0f32; d];
        let mut nnz = 0usize;
        for &i in &idx {
            let v = g[i] as f64;
            let ratio = v.abs() / norm * s_levels; // in [0, s]
            let floor = ratio.floor();
            let frac = ratio - floor;
            // Stochastic rounding: up with prob = frac (unbiased).
            let level = if self.rng.f64() < frac { floor + 1.0 } else { floor };
            let val = norm * level / s_levels * v.signum();
            if val != 0.0 {
                recon[i] = val as f32;
                nnz += 1;
            }
        }
        DigitalPayload {
            reconstruction: recon,
            nnz,
            bits: Self::bit_cost(d, q, self.levels_bits),
        }
    }

    fn name(&self) -> &'static str {
        "qsgd"
    }

    fn rng_state(&self) -> Option<(u64, u64, Option<f64>)> {
        Some(self.rng.raw_state())
    }

    fn restore_rng(&mut self, state: (u64, u64, Option<f64>)) {
        self.rng = Pcg64::from_raw_state(state.0, state.1, state.2);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantizer_is_unbiased() {
        // Average many stochastic encodings: E[Q(v)] = v.
        let g = [0.3f32, -0.7, 0.05, 0.0];
        let budget = QsgdCompressor::bit_cost(4, 3, 2) + 0.1;
        let mut sums = vec![0f64; 4];
        let trials = 20_000;
        let mut c = QsgdCompressor::new(2, 99);
        for _ in 0..trials {
            let p = c.encode(&g, budget);
            for (s, &r) in sums.iter_mut().zip(&p.reconstruction) {
                *s += r as f64;
            }
        }
        for (i, s) in sums.iter().enumerate() {
            let mean = s / trials as f64;
            assert!(
                (mean - g[i] as f64).abs() < 0.01,
                "coord {i}: E[Q]={mean} vs {}",
                g[i]
            );
        }
    }

    #[test]
    fn values_on_grid() {
        let g = [0.5f32, -1.0, 0.25, 0.0, 0.0];
        let budget = QsgdCompressor::bit_cost(5, 3, 2) + 0.1;
        let mut c = QsgdCompressor::new(2, 7);
        let p = c.encode(&g, budget);
        let idx = topk_indices(&g, 3);
        let norm = idx
            .iter()
            .map(|&i| (g[i] as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        for &r in &p.reconstruction {
            if r != 0.0 {
                let level = (r as f64).abs() * 4.0 / norm;
                assert!((level - level.round()).abs() < 1e-5, "off-grid value {r}");
            }
        }
    }

    #[test]
    fn bits_match_eq44() {
        let d = 7850;
        for q in [1usize, 10, 200] {
            let expect = 32.0 + position_bits(d, q) + 3.0 * q as f64;
            assert!((QsgdCompressor::bit_cost(d, q, 2) - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let g: Vec<f32> = (0..100).map(|i| ((i * 37) % 19) as f32 / 19.0 - 0.5).collect();
        let budget = 500.0;
        let mut a = QsgdCompressor::new(2, 5);
        let mut b = QsgdCompressor::new(2, 5);
        assert_eq!(
            a.encode(&g, budget).reconstruction,
            b.encode(&g, budget).reconstruction
        );
    }

    #[test]
    fn needs_at_least_35_bits() {
        let mut c = QsgdCompressor::new(2, 1);
        let p = c.encode(&vec![1.0; 100], 30.0);
        assert_eq!(p.nnz, 0);
    }
}
