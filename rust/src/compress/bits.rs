//! Bit accounting for the digital schemes.
//!
//! §III: positions of q non-zero entries can always be described with
//! `log2 C(d, q)` bits (enumerative coding of the sparsity pattern); the
//! paper argues this beats the Golomb-coded inter-arrival distances of [21]
//! whose cost is also implemented here for comparison benches. The MAC
//! capacity bound `R_t` of Eq. 8 lives here too.

use crate::util::stats::log2_binom;

/// Eq. 8: per-device bit budget over s channel uses of the Gaussian MAC at
/// iteration t: `R_t = s/(2M) · log2(1 + M·P_t/(s·σ²))`.
pub fn capacity_bits(s: usize, devices: usize, p_t: f64, noise_var: f64) -> f64 {
    assert!(devices > 0 && s > 0);
    assert!(p_t >= 0.0 && noise_var > 0.0);
    let snr = devices as f64 * p_t / (s as f64 * noise_var);
    (s as f64 / (2.0 * devices as f64)) * (1.0 + snr).log2()
}

/// Enumerative position cost: log2 C(d, q) bits.
pub fn position_bits(d: usize, q: usize) -> f64 {
    log2_binom(d, q)
}

/// Golomb-coding position cost from [21] (Sparse Binary Compression):
/// with sparsity probability p = q/d, the optimal Golomb parameter is
/// `b* = 1 + ⌊log2( ln(φ−1) / ln(1−p) )⌋` (φ the golden ratio) and the
/// expected bits per non-zero entry are `b* + 1/(1 − (1−p)^{2^{b*}})`.
pub fn golomb_bits_per_entry(d: usize, q: usize) -> f64 {
    assert!(q > 0 && q <= d);
    let p = q as f64 / d as f64;
    if p >= 1.0 {
        return 1.0;
    }
    let phi_term = ((5f64.sqrt() - 1.0) / 2.0).ln(); // ln((√5−1)/2) < 0
    let b_star = 1.0 + (phi_term / (1.0 - p).ln()).log2().floor();
    let b_star = b_star.max(1.0);
    b_star + 1.0 / (1.0 - (1.0 - p).powf(2f64.powf(b_star)))
}

/// Total Golomb position cost for q entries.
pub fn golomb_total_bits(d: usize, q: usize) -> f64 {
    golomb_bits_per_entry(d, q) * q as f64
}

/// Largest q (≤ q_max) such that `cost(q) ≤ budget`, where `cost` is
/// monotone non-decreasing in q. Binary search; returns 0 when even q = 1
/// does not fit.
pub fn max_q_within_budget<F: Fn(usize) -> f64>(q_max: usize, budget: f64, cost: F) -> usize {
    if q_max == 0 || cost(1) > budget {
        return 0;
    }
    let (mut lo, mut hi) = (1usize, q_max); // cost(lo) <= budget
    while lo < hi {
        let mid = lo + (hi - lo + 1) / 2;
        if cost(mid) <= budget {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_matches_formula() {
        // s=100, M=4, P=50, σ²=1 → R = 100/8 · log2(1 + 200/100)
        let r = capacity_bits(100, 4, 50.0, 1.0);
        assert!((r - 12.5 * (3f64).log2()).abs() < 1e-9);
    }

    #[test]
    fn capacity_monotone_in_power_and_bandwidth() {
        assert!(capacity_bits(100, 10, 200.0, 1.0) > capacity_bits(100, 10, 100.0, 1.0));
        assert!(capacity_bits(200, 10, 100.0, 1.0) > capacity_bits(100, 10, 100.0, 1.0));
        // More devices → smaller per-device share.
        assert!(capacity_bits(100, 20, 100.0, 1.0) < capacity_bits(100, 10, 100.0, 1.0));
    }

    #[test]
    fn zero_power_gives_zero_bits() {
        assert_eq!(capacity_bits(100, 10, 0.0, 1.0), 0.0);
    }

    #[test]
    fn position_bits_monotone_up_to_half() {
        let d = 7850;
        let mut prev = 0.0;
        for q in [1usize, 10, 100, 1000, d / 2] {
            let b = position_bits(d, q);
            assert!(b > prev, "q={q}");
            prev = b;
        }
    }

    #[test]
    fn golomb_not_cheaper_than_enumerative() {
        // Enumerative coding is information-theoretically optimal for a
        // uniform sparsity pattern; Golomb should cost at least as much.
        let d = 7850;
        for q in [5usize, 50, 500, 2000] {
            let enumerative = position_bits(d, q);
            let golomb = golomb_total_bits(d, q);
            assert!(
                golomb >= enumerative * 0.99,
                "q={q}: golomb {golomb} < enum {enumerative}"
            );
        }
    }

    #[test]
    fn max_q_budget_search() {
        let cost = |q: usize| q as f64 * 10.0;
        assert_eq!(max_q_within_budget(100, 55.0, cost), 5);
        assert_eq!(max_q_within_budget(100, 5.0, cost), 0);
        assert_eq!(max_q_within_budget(3, 1e9, cost), 3);
        // Real D-DSGD cost shape:
        let d = 7850;
        let budget = 2000.0;
        let q = max_q_within_budget(d / 2, budget, |q| position_bits(d, q) + 33.0);
        assert!(q > 0);
        assert!(position_bits(d, q) + 33.0 <= budget);
        assert!(position_bits(d, q + 1) + 33.0 > budget);
    }
}
