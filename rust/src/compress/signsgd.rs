//! SignSGD baseline [16] adapted to the capacity-limited MAC (§VI, Eq. 43).
//!
//! Each device selects the q_{t,S} largest-magnitude entries of its gradient
//! and transmits one sign bit per entry plus the enumerative position code:
//! `r_{t,S} = log2 C(d, q) + q` bits; q is the largest integer fitting R_t.
//! The PS reconstructs ±1 at the selected positions (the magnitude scale is
//! absorbed by the PS optimizer, as in [16]).

use super::bits::{max_q_within_budget, position_bits};
use super::{DigitalCompressor, DigitalPayload};
use crate::tensor::topk_indices;

#[derive(Clone, Debug, Default)]
pub struct SignSgdCompressor;

impl SignSgdCompressor {
    pub fn new() -> SignSgdCompressor {
        SignSgdCompressor
    }

    /// Eq. 43 bit cost.
    pub fn bit_cost(d: usize, q: usize) -> f64 {
        position_bits(d, q) + q as f64
    }

    pub fn pick_q(d: usize, budget_bits: f64) -> usize {
        max_q_within_budget(d, budget_bits, |q| Self::bit_cost(d, q))
    }
}

impl DigitalCompressor for SignSgdCompressor {
    fn encode(&mut self, g: &[f32], budget_bits: f64) -> DigitalPayload {
        let d = g.len();
        let q = Self::pick_q(d, budget_bits);
        if q == 0 {
            return DigitalPayload::silent(d);
        }
        let idx = topk_indices(g, q);
        let mut recon = vec![0f32; d];
        let mut nnz = 0usize;
        for &i in &idx {
            if g[i] != 0.0 {
                recon[i] = g[i].signum();
                nnz += 1;
            }
        }
        DigitalPayload {
            reconstruction: recon,
            nnz,
            bits: Self::bit_cost(d, q),
        }
    }

    fn name(&self) -> &'static str {
        "signsgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signs_at_topk_positions() {
        let mut c = SignSgdCompressor::new();
        // d = 9 so that bit_cost is strictly monotone around q = 3.
        let g = [3.0, -4.0, 0.1, -0.2, 2.0, 0.0, 0.05, -0.01, 0.02];
        let budget = SignSgdCompressor::bit_cost(9, 3) + 0.1;
        assert_eq!(SignSgdCompressor::pick_q(9, budget), 3);
        let p = c.encode(&g, budget);
        assert_eq!(
            p.reconstruction,
            vec![1.0, -1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0]
        );
        assert_eq!(p.nnz, 3);
    }

    #[test]
    fn bits_match_eq43() {
        let d = 1000;
        for q in [1usize, 7, 100] {
            let expect = position_bits(d, q) + q as f64;
            assert!((SignSgdCompressor::bit_cost(d, q) - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn per_entry_sign_bits_cost_more_than_sbc_header_at_scale() {
        // SBC pays a flat 33-bit header; SignSGD pays 1 bit per entry. Once
        // q > 33 the per-entry sign bits dominate, so for a healthy budget
        // SBC affords more entries than SignSGD.
        let d = 7850;
        let budget = 3000.0;
        let q_sign = SignSgdCompressor::pick_q(d, budget);
        let q_sbc = super::super::sbc::SbcCompressor::pick_q(d, budget);
        assert!(q_sign > 33, "q_sign={q_sign}");
        assert!(q_sbc >= q_sign, "q_sbc={q_sbc} q_sign={q_sign}");
    }

    #[test]
    fn silent_under_tiny_budget() {
        let mut c = SignSgdCompressor::new();
        let p = c.encode(&vec![1.0; 50], 2.0);
        assert_eq!(p.nnz, 0);
    }
}
