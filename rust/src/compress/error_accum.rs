//! Local error accumulation (§III / §IV, Eq. 10).
//!
//! Every lossy compressor in the paper keeps the compression residual
//! Δ_m(t+1) = g_m(θ_t) + Δ_m(t) − compress(g_m(θ_t) + Δ_m(t))
//! at the device and folds it into the next iteration's estimate, so
//! information suppressed by sparsification is eventually delivered.

/// Per-device error accumulator.
#[derive(Clone, Debug)]
pub struct ErrorAccumulator {
    delta: Vec<f32>,
}

impl ErrorAccumulator {
    pub fn new(dim: usize) -> ErrorAccumulator {
        ErrorAccumulator {
            delta: vec![0.0; dim],
        }
    }

    pub fn dim(&self) -> usize {
        self.delta.len()
    }

    /// g_ec = g + Δ(t) (the error-compensated gradient, Alg. 1 line 5).
    pub fn compensate(&self, g: &[f32]) -> Vec<f32> {
        assert_eq!(g.len(), self.delta.len());
        g.iter().zip(&self.delta).map(|(a, b)| a + b).collect()
    }

    /// A silent round: nothing was transmitted, so the whole gradient joins
    /// the residual in place — Δ(t+1) = g + Δ(t). Equivalent to
    /// `compensate` + `update` against a zero transmission, without the two
    /// d-length allocations (silent devices are the common case in fading
    /// runs with aggressive thresholds or deadlines).
    pub fn bank(&mut self, g: &[f32]) {
        assert_eq!(g.len(), self.delta.len());
        for (d, &gi) in self.delta.iter_mut().zip(g) {
            *d += gi;
        }
    }

    /// Record the new residual: Δ(t+1) = g_ec − transmitted.
    pub fn update(&mut self, g_ec: &[f32], transmitted: &[f32]) {
        assert_eq!(g_ec.len(), self.delta.len());
        assert_eq!(transmitted.len(), self.delta.len());
        for (d, (e, t)) in self.delta.iter_mut().zip(g_ec.iter().zip(transmitted)) {
            *d = e - t;
        }
    }

    /// ‖Δ‖₂ — used by metrics and the Lemma-3 bound check.
    pub fn norm(&self) -> f64 {
        crate::tensor::norm(&self.delta)
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.delta
    }

    /// Restore a residual captured by [`ErrorAccumulator::as_slice`]
    /// (checkpoint restore).
    pub fn load(&mut self, delta: &[f32]) {
        assert_eq!(
            delta.len(),
            self.delta.len(),
            "accumulator restore must match the model dimension"
        );
        self.delta.copy_from_slice(delta);
    }

    pub fn reset(&mut self) {
        self.delta.fill(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::sparsify_topk;

    #[test]
    fn starts_at_zero() {
        let acc = ErrorAccumulator::new(8);
        assert_eq!(acc.norm(), 0.0);
        let g = vec![1.0; 8];
        assert_eq!(acc.compensate(&g), g);
    }

    #[test]
    fn accumulates_sparsification_residual() {
        let mut acc = ErrorAccumulator::new(4);
        let g = vec![4.0, 1.0, -3.0, 0.5];
        let g_ec = acc.compensate(&g);
        let sent = sparsify_topk(&g_ec, 2); // keeps 4.0, -3.0
        acc.update(&g_ec, &sent);
        assert_eq!(acc.as_slice(), &[0.0, 1.0, 0.0, 0.5]);
        // Next round: residual rides along.
        let g2 = vec![0.0, 1.0, 0.0, 0.0];
        assert_eq!(acc.compensate(&g2), vec![0.0, 2.0, 0.0, 0.5]);
    }

    #[test]
    fn everything_eventually_transmitted() {
        // With a k=1 compressor and zero new gradient, repeated rounds must
        // drain the accumulator to zero — no information is lost forever.
        let mut acc = ErrorAccumulator::new(5);
        let g0 = vec![5.0, 4.0, 3.0, 2.0, 1.0];
        let mut total_sent = vec![0.0f32; 5];
        let zero = vec![0.0f32; 5];
        let mut g = g0.clone();
        for round in 0..10 {
            let g_ec = acc.compensate(&g);
            let sent = sparsify_topk(&g_ec, 1);
            for (t, s) in total_sent.iter_mut().zip(&sent) {
                *t += s;
            }
            acc.update(&g_ec, &sent);
            g = zero.clone();
            if round >= 4 {
                break;
            }
        }
        assert!(acc.norm() < 1e-6, "norm={}", acc.norm());
        assert_eq!(total_sent, g0);
    }

    #[test]
    fn bank_matches_silent_update() {
        // bank(g) ≡ compensate + update against a zero transmission.
        let g = vec![1.5f32, -2.0, 0.25];
        let mut via_bank = ErrorAccumulator::new(3);
        via_bank.update(&[0.5, 0.5, 0.5], &[0.0, 0.0, 0.0]);
        let mut via_update = via_bank.clone();
        via_bank.bank(&g);
        let g_ec = via_update.compensate(&g);
        via_update.update(&g_ec, &[0.0, 0.0, 0.0]);
        assert_eq!(via_bank.as_slice(), via_update.as_slice());
    }

    #[test]
    fn reset_clears() {
        let mut acc = ErrorAccumulator::new(3);
        acc.update(&[1.0, 2.0, 3.0], &[0.0, 0.0, 0.0]);
        assert!(acc.norm() > 0.0);
        acc.reset();
        assert_eq!(acc.norm(), 0.0);
    }
}
