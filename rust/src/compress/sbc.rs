//! The D-DSGD quantizer (§III), a modified Sparse Binary Compression [21].
//!
//! At iteration t the device keeps the q_t most-positive and q_t
//! most-negative entries of its error-compensated gradient, computes the
//! mean of the remaining positives μ⁺ and negatives μ⁻, and transmits only
//! the winning-sign side, every survivor set to that side's mean. The
//! encoding costs `r_t = log2 C(d, q_t) + 33` bits (enumerative positions +
//! 32-bit mean magnitude + 1 sign bit, Eq. 9); q_t is the largest integer
//! fitting the capacity budget R_t with q_t ≤ d/2.

use super::bits::{max_q_within_budget, position_bits};
use super::{DigitalCompressor, DigitalPayload};

#[derive(Clone, Debug, Default)]
pub struct SbcCompressor;

impl SbcCompressor {
    pub fn new() -> SbcCompressor {
        SbcCompressor
    }

    /// Eq. 9 bit cost for a given q.
    pub fn bit_cost(d: usize, q: usize) -> f64 {
        position_bits(d, q) + 33.0
    }

    /// The largest q_t with bit_cost(q) ≤ budget and q ≤ d/2.
    pub fn pick_q(d: usize, budget_bits: f64) -> usize {
        max_q_within_budget(d / 2, budget_bits, |q| Self::bit_cost(d, q))
    }

    /// Core SBC transform for a fixed q (exposed for tests/benches).
    pub fn compress_with_q(g: &[f32], q: usize) -> DigitalPayload {
        let d = g.len();
        if q == 0 {
            return DigitalPayload::silent(d);
        }
        // Indices of the q most-positive and q most-negative values.
        // (Selection is by *value*, not magnitude — §III keeps the highest
        // q_t and the smallest q_t entries.)
        let mut order: Vec<usize> = (0..d).collect();
        order.sort_unstable_by(|&a, &b| g[a].partial_cmp(&g[b]).unwrap());
        let lowest = &order[..q.min(d)];
        let highest = &order[d.saturating_sub(q)..];

        // Means over the *positive* survivors and *negative* survivors.
        let mut pos_sum = 0f64;
        let mut pos_cnt = 0usize;
        let mut neg_sum = 0f64;
        let mut neg_cnt = 0usize;
        for &i in highest.iter().chain(lowest.iter()) {
            let v = g[i];
            if v > 0.0 {
                pos_sum += v as f64;
                pos_cnt += 1;
            } else if v < 0.0 {
                neg_sum += v as f64;
                neg_cnt += 1;
            }
        }
        let mu_plus = if pos_cnt > 0 { pos_sum / pos_cnt as f64 } else { 0.0 };
        let mu_minus = if neg_cnt > 0 { neg_sum / neg_cnt as f64 } else { 0.0 };

        let mut recon = vec![0f32; d];
        let mut nnz = 0usize;
        if mu_plus > mu_minus.abs() {
            for &i in highest.iter().chain(lowest.iter()) {
                if g[i] > 0.0 {
                    recon[i] = mu_plus as f32;
                    nnz += 1;
                }
            }
        } else if mu_minus != 0.0 || mu_plus > 0.0 {
            for &i in highest.iter().chain(lowest.iter()) {
                if g[i] < 0.0 {
                    recon[i] = mu_minus as f32;
                    nnz += 1;
                }
            }
        }
        DigitalPayload {
            reconstruction: recon,
            nnz,
            bits: Self::bit_cost(d, q),
        }
    }
}

impl DigitalCompressor for SbcCompressor {
    fn encode(&mut self, g: &[f32], budget_bits: f64) -> DigitalPayload {
        let q = Self::pick_q(g.len(), budget_bits);
        if q == 0 {
            return DigitalPayload::silent(g.len());
        }
        Self::compress_with_q(g, q)
    }

    fn name(&self) -> &'static str {
        "sbc"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn positive_side_wins() {
        let g = [5.0, 4.0, -1.0, -0.5, 0.1, 0.0];
        let p = SbcCompressor::compress_with_q(&g, 2);
        // highest 2: {5,4}; lowest 2: {-1,-0.5}; μ+ = 4.5, μ− = −0.75 →
        // positives win; entries 0,1 set to 4.5.
        assert_eq!(p.nnz, 2);
        assert!((p.reconstruction[0] - 4.5).abs() < 1e-6);
        assert!((p.reconstruction[1] - 4.5).abs() < 1e-6);
        assert!(p.reconstruction[2..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn negative_side_wins() {
        let g = [-5.0, -4.0, 1.0, 0.5, 0.0, 0.0];
        let p = SbcCompressor::compress_with_q(&g, 2);
        assert_eq!(p.nnz, 2);
        assert!((p.reconstruction[0] + 4.5).abs() < 1e-6);
        assert!((p.reconstruction[1] + 4.5).abs() < 1e-6);
        assert!(p.reconstruction[2..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn budget_controls_q() {
        let d = 1000;
        let tight = SbcCompressor::bit_cost(d, 3) + 0.5;
        assert_eq!(SbcCompressor::pick_q(d, tight), 3);
        assert_eq!(SbcCompressor::pick_q(d, 10.0), 0); // below cost(q=1)
    }

    #[test]
    fn silent_when_budget_too_small() {
        let mut c = SbcCompressor::new();
        let g = vec![1.0f32; 100];
        let p = c.encode(&g, 5.0);
        assert_eq!(p.nnz, 0);
        assert_eq!(p.bits, 0.0);
    }

    #[test]
    fn bits_match_eq9() {
        let mut c = SbcCompressor::new();
        let g: Vec<f32> = (0..500).map(|i| (i as f32 - 250.0) / 100.0).collect();
        let budget = 200.0;
        let p = c.encode(&g, budget);
        assert!(p.bits <= budget);
        let q = SbcCompressor::pick_q(500, budget);
        assert!((p.bits - (position_bits(500, q) + 33.0)).abs() < 1e-9);
    }

    #[test]
    fn all_zero_gradient_reconstructs_zero() {
        let p = SbcCompressor::compress_with_q(&[0.0; 64], 4);
        assert!(p.reconstruction.iter().all(|&v| v == 0.0));
        assert_eq!(p.nnz, 0);
    }

    #[test]
    fn q_bounded_by_half_d() {
        assert!(SbcCompressor::pick_q(10, 1e9) <= 5);
    }
}
