//! Gradient compression: the digital quantizers and the shared
//! error-accumulation machinery.
//!
//! Every digital scheme in the paper reduces to: select entries, quantize
//! their values, count the bits needed to describe (values + positions),
//! and fit inside the iteration's capacity budget `R_t`. The codecs here
//! are *faithful bit-accounting* codecs — they produce the exact
//! reconstruction the PS would decode and the exact number of bits the
//! encoding costs (the paper assumes capacity-achieving channel codes, so
//! transport is error-free once the payload fits the budget; see §III).

pub mod bits;
pub mod error_accum;
pub mod qsgd;
pub mod sbc;
pub mod signsgd;

pub use error_accum::ErrorAccumulator;

/// A digitally-encoded gradient: the dense reconstruction the PS recovers
/// plus the exact bill of bits it cost.
#[derive(Clone, Debug)]
pub struct DigitalPayload {
    /// Dense d-dimensional reconstruction (what the decoder outputs).
    pub reconstruction: Vec<f32>,
    /// Number of non-zero (transmitted) entries.
    pub nnz: usize,
    /// Total bits of the encoding (values + positions + headers).
    pub bits: f64,
}

impl DigitalPayload {
    /// An empty payload (device stays silent this iteration).
    pub fn silent(dim: usize) -> DigitalPayload {
        DigitalPayload {
            reconstruction: vec![0.0; dim],
            nnz: 0,
            bits: 0.0,
        }
    }
}

/// Common interface for the digital compressors (D-DSGD's SBC, SignSGD,
/// QSGD). `budget_bits` is the capacity bound R_t for this iteration; the
/// encoder picks its sparsity q_t as the largest value that fits.
pub trait DigitalCompressor: Send {
    /// Encode `g` (already error-compensated) within `budget_bits`.
    /// `&mut self` because QSGD's stochastic rounding draws from an
    /// encoder-owned RNG stream.
    fn encode(&mut self, g: &[f32], budget_bits: f64) -> DigitalPayload;
    fn name(&self) -> &'static str;

    /// RNG position for checkpointing. Deterministic compressors (SBC,
    /// SignSGD) have no stream and return `None`; stochastic ones (QSGD)
    /// return their exact generator position so a resumed run reproduces
    /// the uninterrupted rounding sequence bit-for-bit.
    fn rng_state(&self) -> Option<(u64, u64, Option<f64>)> {
        None
    }

    /// Restore a position captured by [`DigitalCompressor::rng_state`].
    /// No-op for deterministic compressors.
    fn restore_rng(&mut self, _state: (u64, u64, Option<f64>)) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn silent_payload_is_zero() {
        let p = DigitalPayload::silent(16);
        assert_eq!(p.reconstruction.len(), 16);
        assert!(p.reconstruction.iter().all(|&v| v == 0.0));
        assert_eq!(p.bits, 0.0);
        assert_eq!(p.nnz, 0);
    }
}
