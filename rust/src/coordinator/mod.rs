//! The L3 coordinator: synchronous leader/worker rounds of DSGD over the
//! simulated wireless MAC, scheme-agnostic.

pub mod device;
pub mod grad;
pub mod metrics;
pub mod orchestrator;

pub use grad::{GradientBackend, RustBackend};
pub use metrics::{RoundRecord, TrainLog};
pub use orchestrator::Trainer;
