//! The L3 coordinator: synchronous leader/worker rounds of DSGD over the
//! simulated wireless MAC. The round loop ([`Trainer`]) is scheme-agnostic;
//! each transmission scheme plugs in as a [`link::LinkScheme`].

pub mod device;
pub mod grad;
pub mod link;
pub mod metrics;
pub mod orchestrator;

pub use device::DeviceSet;
pub use grad::{GradientBackend, RustBackend};
pub use link::{AnalogLink, DigitalLink, ErrorFreeLink, LinkRound, LinkScheme, RoundCtx};
pub use metrics::{RoundRecord, TrainLog};
pub use orchestrator::Trainer;
