//! The L3 coordinator: synchronous leader/worker rounds of DSGD over the
//! simulated wireless MAC. The round loop ([`Trainer`]) is scheme-agnostic;
//! each transmission scheme plugs in as a [`link::LinkScheme`].

pub mod device;
pub mod grad;
pub mod link;
pub mod metrics;
pub mod orchestrator;
pub mod participation;

pub use device::DeviceSet;
pub use grad::{GradientBackend, RustBackend};
pub use link::{
    AnalogLink, D2dAnalogLink, DigitalLink, ErrorFreeLink, FadingAnalogLink, LinkRound,
    LinkScheme, ParticipationStats, RoundCtx,
};
pub use metrics::{RoundRecord, TrainLog};
pub use orchestrator::Trainer;
pub use participation::ParticipationSelector;
